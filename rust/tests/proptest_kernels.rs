//! Bit-identity properties of the restructured (autovectorization-
//! friendly) deconvolution kernels against their frozen scalar
//! references (`deconv_*_ref`), across random geometries, tile sizes,
//! strides, zero-skip settings and element types.
//!
//! The restructure moved loop-invariant arithmetic (tap spans, row
//! bases, hoisted bounds) without reordering any per-output-element tap
//! accumulation, so the results must be **bit-for-bit** equal — not
//! merely close — in `f32` as well as fixed point, and the reverse
//! loop's `OpStats` accounting (the FPGA cycle model's input) must be
//! untouched too.

use edgedcnn::deconv::{
    deconv_reverse_loop, deconv_reverse_loop_blocked, deconv_reverse_loop_ref,
    deconv_standard, deconv_standard_blocked, deconv_standard_ref, deconv_tdc,
    deconv_tdc_blocked, deconv_tdc_ref, BlockSchedule, ReverseLoopOpts,
    SUPPORTED_LANES,
};
use edgedcnn::quant::{
    calibrate_channel_exps, quantize_network, Element, Rounding, Q16_16, Q2_6,
    Q8_8,
};
use edgedcnn::tensor::{Tensor, TensorT};
use edgedcnn::util::{Rng, WorkerPool};

const CASES: usize = 120;

/// Random legal layer geometry (small: every case runs six kernels).
fn random_geometry(
    rng: &mut Rng,
) -> (usize, usize, usize, usize, usize, usize, usize) {
    loop {
        let k = rng.range_usize(1, 8);
        let s = rng.range_usize(1, 4);
        let p = rng.range_usize(0, k.max(1));
        let i_h = rng.range_usize(1, 7);
        let c_in = rng.range_usize(1, 4);
        let c_out = rng.range_usize(1, 4);
        let n = rng.range_usize(1, 3);
        let o = (i_h - 1) * s + k;
        if o > 2 * p {
            return (n, c_in, c_out, k, s, p, i_h);
        }
    }
}

/// One random case at one element type: all three kernels bit-equal to
/// their frozen references, reverse-loop stats equal too.
fn check_case<T: Element>(rng: &mut Rng, case: usize, label: &str) {
    let (n, c_in, c_out, k, s, p, i_h) = random_geometry(rng);
    let tile = rng.range_usize(1, 13);
    let zero_skip = rng.gen_bool(0.5);
    let x = TensorT::<T>::from_fn(vec![n, c_in, i_h, i_h], |_| {
        T::from_f32(rng.range_f32(-1.0, 1.0))
    });
    // ~1/3 exact zeros so the zero-skip predicate and the branchless
    // skip paths are both exercised
    let w = TensorT::<T>::from_fn(vec![c_in, c_out, k, k], |_| {
        if rng.gen_bool(1.0 / 3.0) {
            T::ZERO
        } else {
            T::from_f32(rng.range_f32(-1.0, 1.0))
        }
    });
    let b: Vec<T> = (0..c_out)
        .map(|_| T::from_f32(rng.range_f32(-0.5, 0.5)))
        .collect();
    let ctx = format!(
        "{label} case {case}: n {n} c_in {c_in} c_out {c_out} k {k} s {s} \
         p {p} i_h {i_h} tile {tile} zero_skip {zero_skip}"
    );

    let want = deconv_standard_ref(&x, &w, &b, s, p);
    let got = deconv_standard(&x, &w, &b, s, p);
    assert_eq!(got.shape(), want.shape(), "standard shape, {ctx}");
    assert!(got.data() == want.data(), "standard data, {ctx}");

    let opts = ReverseLoopOpts { tile, zero_skip };
    let (want_rl, want_stats) = deconv_reverse_loop_ref(&x, &w, &b, s, p, opts);
    let (got_rl, got_stats) = deconv_reverse_loop(&x, &w, &b, s, p, opts);
    assert_eq!(got_rl.shape(), want_rl.shape(), "reverse-loop shape, {ctx}");
    assert!(got_rl.data() == want_rl.data(), "reverse-loop data, {ctx}");
    assert_eq!(got_stats, want_stats, "reverse-loop OpStats, {ctx}");

    let want_tdc = deconv_tdc_ref(&x, &w, &b, s, p);
    let got_tdc = deconv_tdc(&x, &w, &b, s, p);
    assert_eq!(got_tdc.shape(), want_tdc.shape(), "tdc shape, {ctx}");
    assert!(got_tdc.data() == want_tdc.data(), "tdc data, {ctx}");
}

/// One random case at one element type for the cache-blocked entry
/// points: a random [`BlockSchedule`] (micro × macro × lanes) and a
/// random pool width must leave all three blocked kernels bit-equal to
/// the frozen scalar references — tensors *and*, for the reverse loop,
/// its `OpStats` (the blocked dispatch pins `tile == micro`, so the
/// stats geometry is part of the contract).
fn check_blocked_case<T: Element>(rng: &mut Rng, case: usize, label: &str) {
    let (n, c_in, c_out, k, s, p, i_h) = random_geometry(rng);
    let sched = BlockSchedule {
        micro: rng.range_usize(1, 13),
        macro_tiles: rng.range_usize(1, 9),
        lanes: SUPPORTED_LANES[rng.range_usize(0, SUPPORTED_LANES.len())],
    };
    let workers = rng.range_usize(1, 5);
    let pool = WorkerPool::new(workers);
    let zero_skip = rng.gen_bool(0.5);
    let x = TensorT::<T>::from_fn(vec![n, c_in, i_h, i_h], |_| {
        T::from_f32(rng.range_f32(-1.0, 1.0))
    });
    let w = TensorT::<T>::from_fn(vec![c_in, c_out, k, k], |_| {
        if rng.gen_bool(1.0 / 3.0) {
            T::ZERO
        } else {
            T::from_f32(rng.range_f32(-1.0, 1.0))
        }
    });
    let b: Vec<T> = (0..c_out)
        .map(|_| T::from_f32(rng.range_f32(-0.5, 0.5)))
        .collect();
    let ctx = format!(
        "{label} blocked case {case}: n {n} c_in {c_in} c_out {c_out} k {k} \
         s {s} p {p} i_h {i_h} micro {} macro {} lanes {} workers {workers} \
         zero_skip {zero_skip}",
        sched.micro, sched.macro_tiles, sched.lanes
    );

    let want = deconv_standard_ref(&x, &w, &b, s, p);
    let got = deconv_standard_blocked(&x, &w, &b, s, p, Some(sched), &pool);
    assert_eq!(got.shape(), want.shape(), "blocked standard shape, {ctx}");
    assert!(got.data() == want.data(), "blocked standard data, {ctx}");

    let opts = ReverseLoopOpts { tile: sched.micro, zero_skip };
    let (want_rl, want_stats) = deconv_reverse_loop_ref(&x, &w, &b, s, p, opts);
    let (got_rl, got_stats) = deconv_reverse_loop_blocked(
        &x,
        &w,
        &b,
        s,
        p,
        zero_skip,
        Some(sched),
        &pool,
    );
    assert_eq!(
        got_rl.shape(),
        want_rl.shape(),
        "blocked reverse-loop shape, {ctx}"
    );
    assert!(
        got_rl.data() == want_rl.data(),
        "blocked reverse-loop data, {ctx}"
    );
    assert_eq!(got_stats, want_stats, "blocked reverse-loop OpStats, {ctx}");

    let want_tdc = deconv_tdc_ref(&x, &w, &b, s, p);
    let got_tdc = deconv_tdc_blocked(&x, &w, &b, s, p, Some(sched), &pool);
    assert_eq!(got_tdc.shape(), want_tdc.shape(), "blocked tdc shape, {ctx}");
    assert!(got_tdc.data() == want_tdc.data(), "blocked tdc data, {ctx}");
}

#[test]
fn prop_f32_kernels_bit_identical_to_frozen_references() {
    let mut rng = Rng::seed_from_u64(0xF32_BEEF);
    for case in 0..CASES {
        check_case::<f32>(&mut rng, case, "f32");
    }
}

#[test]
fn prop_q8_8_kernels_bit_identical_to_frozen_references() {
    let mut rng = Rng::seed_from_u64(0x0808_BEEF);
    for case in 0..CASES {
        check_case::<Q8_8>(&mut rng, case, "q8.8");
    }
}

#[test]
fn prop_q16_16_kernels_bit_identical_to_frozen_references() {
    let mut rng = Rng::seed_from_u64(0x1616_BEEF);
    for case in 0..CASES {
        check_case::<Q16_16>(&mut rng, case, "q16.16");
    }
}

#[test]
fn prop_q2_6_kernels_bit_identical_to_frozen_references() {
    // the packed-int8 datapath: i8 stores, exact i32 accumulation
    let mut rng = Rng::seed_from_u64(0x0806_BEEF);
    for case in 0..CASES {
        check_case::<Q2_6>(&mut rng, case, "q2.6");
    }
}

#[test]
fn prop_f32_blocked_kernels_bit_identical_to_frozen_references() {
    let mut rng = Rng::seed_from_u64(0xB10C_F32);
    for case in 0..CASES {
        check_blocked_case::<f32>(&mut rng, case, "f32");
    }
}

#[test]
fn prop_q8_8_blocked_kernels_bit_identical_to_frozen_references() {
    let mut rng = Rng::seed_from_u64(0xB10C_0808);
    for case in 0..CASES {
        check_blocked_case::<Q8_8>(&mut rng, case, "q8.8");
    }
}

#[test]
fn prop_q16_16_blocked_kernels_bit_identical_to_frozen_references() {
    let mut rng = Rng::seed_from_u64(0xB10C_1616);
    for case in 0..CASES {
        check_blocked_case::<Q16_16>(&mut rng, case, "q16.16");
    }
}

#[test]
fn prop_q2_6_blocked_kernels_bit_identical_to_frozen_references() {
    // blocked dispatch covers the doubled i8 lane widths (8 and 16)
    let mut rng = Rng::seed_from_u64(0xB10C_0806);
    for case in 0..CASES {
        check_blocked_case::<Q2_6>(&mut rng, case, "q2.6");
    }
}

#[test]
fn prop_per_channel_calibration_error_bounded_by_half_a_step() {
    // Per-output-channel calibrate → quantize → dequantize at Q2.6 with
    // round-to-nearest: every weight and bias of channel `co` must land
    // within half a quantization step *at that channel's scale* —
    // 0.5 · 2^-6 · 2^exp(co) — not merely within the layer-wide bound a
    // single shared exponent would give.  Calibration guarantees
    // max|w|/2^exp(co) fits the representable range (the scale is an
    // exact power of two, so the pre-quantization multiply is lossless),
    // which makes the half-step bound exact, not statistical.
    let mut rng = Rng::seed_from_u64(0xCA11_0806);
    for case in 0..CASES {
        let c_in = rng.range_usize(1, 4);
        let c_out = rng.range_usize(1, 6);
        let k = rng.range_usize(1, 6);
        // per-channel magnitude spread of ~2^±6 so channels genuinely
        // calibrate to different exponents
        let mags: Vec<f32> = (0..c_out)
            .map(|_| 2f32.powi(rng.range_usize(0, 13) as i32 - 6))
            .collect();
        let w = Tensor::from_fn(vec![c_in, c_out, k, k], |i| {
            let co = (i / (k * k)) % c_out;
            mags[co] * rng.range_f32(-1.0, 1.0)
        });
        let b: Vec<f32> = (0..c_out)
            .map(|co| mags[co] * rng.range_f32(-0.5, 0.5))
            .collect();
        let scales = calibrate_channel_exps::<i8, 6>(&w, &b);
        let q = quantize_network::<i8, 6>(
            &[(w.clone(), b.clone())],
            Rounding::Nearest,
        );
        assert_eq!(q[0].scales, scales, "case {case}: calibration agrees");
        let plane = k * k;
        for (i, (qv, fv)) in q[0].w.data().iter().zip(w.data()).enumerate() {
            let co = (i / plane) % c_out;
            let s = 2f32.powi(scales.exp(co));
            let err = (qv.to_f32() * s - fv).abs();
            assert!(
                err <= 0.5 * Q2_6::step() * s,
                "case {case} weight {i} (channel {co}): err {err} exceeds \
                 half a step at scale 2^{}",
                scales.exp(co)
            );
        }
        for (co, (qv, fv)) in q[0].b.iter().zip(&b).enumerate() {
            let s = 2f32.powi(scales.exp(co));
            let err = (qv.to_f32() * s - fv).abs();
            assert!(
                err <= 0.5 * Q2_6::step() * s,
                "case {case} bias {co}: err {err} exceeds half a step at \
                 scale 2^{}",
                scales.exp(co)
            );
        }
    }
}
