//! Coordinator integration: the serving stack end to end against the
//! real artifacts — batching, determinism, metrics, annotations.
//! Skips when `make artifacts` has not run.

use edgedcnn::artifacts::artifacts_or_skip;
use edgedcnn::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, WorkloadSpec,
};
use std::time::Duration;

fn start_coordinator(networks: &[&str]) -> Option<Coordinator> {
    let artifacts = artifacts_or_skip()?;
    Some(
        Coordinator::start(CoordinatorConfig {
            artifacts_dir: artifacts.root.clone(),
            networks: networks.iter().map(|s| s.to_string()).collect(),
            batcher: BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(2),
            },
            executors: 0, // auto: one per network
            ..Default::default()
        })
        .expect("coordinator startup"),
    )
}

#[test]
fn serves_single_requests_deterministically() {
    let Some(coord) = start_coordinator(&["mnist"]) else { return };
    let a = coord.request("mnist").images(2).seed(777).blocking().unwrap();
    let b = coord.request("mnist").images(2).seed(777).blocking().unwrap();
    assert_eq!(a.images.shape(), &[2, 1, 28, 28]);
    assert_eq!(a.images.data(), b.images.data(), "seeded determinism");
    let c = coord.request("mnist").images(2).seed(778).blocking().unwrap();
    assert!(
        a.images.max_abs_diff(&c.images) > 0.0,
        "different seeds differ"
    );
    // edge annotations present and plausible
    assert!(a.fpga_time_s > 0.0);
    assert!(a.gpu_time_s > 0.0);
    assert!(a.latency_s >= a.execute_s * 0.0); // both recorded
}

#[test]
fn concurrent_requests_get_batched() {
    let Some(coord) = start_coordinator(&["mnist"]) else { return };
    // submit a burst without waiting; the batcher should coalesce
    let handles: Vec<_> = (0..8)
        .map(|i| coord.request("mnist").images(1).seed(1000 + i).submit().unwrap())
        .collect();
    let responses: Vec<_> =
        handles.into_iter().map(|h| h.wait().unwrap()).collect();
    assert_eq!(responses.len(), 8);
    // at least one response should report a batch larger than itself
    let max_batch = responses.iter().map(|r| r.batch_size).max().unwrap();
    assert!(
        max_batch >= 2,
        "burst should have been coalesced (max batch {max_batch})"
    );
    // ids map 1:1, images all valid
    for r in &responses {
        assert_eq!(r.images.shape(), &[1, 1, 28, 28]);
        assert!(r.images.data().iter().all(|v| v.abs() <= 1.0));
    }
}

#[test]
fn workload_report_is_consistent() {
    let Some(coord) = start_coordinator(&["mnist"]) else { return };
    let report = coord
        .serve_workload(&WorkloadSpec {
            network: "mnist".into(),
            requests: 12,
            images_per_request: 2,
            interarrival: Duration::from_millis(1),
            seed: 5,
        })
        .unwrap();
    assert_eq!(report.requests, 12);
    assert_eq!(report.images, 24);
    assert!(report.batches >= 1 && report.batches <= 12);
    assert!(report.images_per_s > 0.0);
    assert!(report.gops > 0.0);
    assert!(report.latency.p99_s >= report.latency.p50_s);
    assert!(report.mean_power_w > 0.0, "power meter integrated");
    assert!(report.gops_per_w > 0.0);
}

#[test]
fn serves_multiple_networks() {
    let Some(coord) = start_coordinator(&["mnist", "celeba"]) else {
        return;
    };
    let m = coord.request("mnist").images(1).seed(1).blocking().unwrap();
    let c = coord.request("celeba").images(1).seed(1).blocking().unwrap();
    assert_eq!(m.images.shape(), &[1, 1, 28, 28]);
    assert_eq!(c.images.shape(), &[1, 3, 64, 64]);
    // celeba is ~20x the ops: its edge annotation must be slower
    assert!(c.fpga_time_s > m.fpga_time_s);
}

#[test]
fn unknown_network_fails_cleanly() {
    let Some(coord) = start_coordinator(&["mnist"]) else { return };
    // request for an unloaded network: the device errors, the handle
    // resolves with an error (request dropped), but the coordinator
    // survives and keeps serving
    let bad = coord.request("imagenet").images(1).seed(0).blocking();
    assert!(bad.is_err());
    let good = coord.request("mnist").images(1).seed(0).blocking();
    assert!(good.is_ok(), "coordinator must survive a bad request");
}
