//! Heterogeneous backend-pool integration: a mixed `fpga,gpu,cpu` pool
//! serving real workloads off a synthetic artifact set (no `make
//! artifacts` needed).  Asserts the acceptance criteria of the backend
//! layer: per-backend metrics columns, bit-identical f32 outputs across
//! backends, capability routing (`.q` twins never land on the GPU), and
//! the per-network ordering guarantee.

use edgedcnn::artifacts::write_synthetic;
use edgedcnn::config::{BackendCfg, DeviceKind};
use edgedcnn::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, WorkloadSpec,
};
use edgedcnn::quant::QFormat;
use edgedcnn::util::TempDir;
use std::time::Duration;

fn synthetic_dir() -> TempDir {
    let dir = TempDir::new().unwrap();
    write_synthetic(dir.path(), &["mnist"], 2, 17).unwrap();
    dir
}

fn start_pool(
    dir: &TempDir,
    kinds: Vec<DeviceKind>,
    quant: Option<QFormat>,
) -> anyhow::Result<Coordinator> {
    Coordinator::start(CoordinatorConfig {
        artifacts_dir: dir.path().to_path_buf(),
        networks: vec!["mnist".to_string()],
        batcher: BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
        },
        backends: BackendCfg {
            kinds,
            ..Default::default()
        },
        executors: 0,
        quant,
        quant8: None,
        shard_batches: false,
        clock: None,
    })
}

const MIXED: [DeviceKind; 3] =
    [DeviceKind::Fpga, DeviceKind::Gpu, DeviceKind::Cpu];

#[test]
fn mixed_pool_serves_with_per_backend_metrics() {
    let dir = synthetic_dir();
    let coord = start_pool(&dir, MIXED.to_vec(), None).unwrap();
    assert_eq!(coord.executors(), 3);
    assert_eq!(coord.backend_names(), &["fpga0", "gpu0", "cpu0"]);
    let report = coord
        .serve_workload(&WorkloadSpec {
            network: "mnist".into(),
            requests: 16,
            images_per_request: 2,
            interarrival: Duration::from_millis(1),
            seed: 9,
        })
        .unwrap();
    assert_eq!(report.requests, 16);
    assert_eq!(report.images, 32);
    assert_eq!(report.rejected, 0);
    assert!(!report.per_backend.is_empty(), "per-backend columns present");
    let images: u64 = report.per_backend.iter().map(|b| b.images).sum();
    let batches: u64 = report.per_backend.iter().map(|b| b.batches).sum();
    assert_eq!(images, report.images, "every image accounted to a backend");
    assert_eq!(batches, report.batches);
    for b in &report.per_backend {
        assert!(b.batches > 0, "{}: listed backends actually served", b.name);
        assert!(b.images_per_s > 0.0, "{}: nonzero throughput", b.name);
        assert!(b.mean_device_latency_s > 0.0, "{}: device latency", b.name);
        assert!(b.energy_j > 0.0, "{}: energy accounted", b.name);
    }
    let rendered = report.render();
    assert!(rendered.contains("backend "), "{rendered}");
}

#[test]
fn f32_outputs_bit_identical_across_backends() {
    let dir = synthetic_dir();
    let mut images: Vec<(String, Vec<f32>)> = Vec::new();
    for kind in MIXED {
        let coord = start_pool(&dir, vec![kind], None).unwrap();
        let resp = coord.request("mnist").images(3).seed(4242).blocking().unwrap();
        assert_eq!(resp.images.shape(), &[3, 1, 28, 28]);
        assert!(
            resp.backend.starts_with(kind.as_str()),
            "served by {} on a {kind}-only pool",
            resp.backend
        );
        assert!(resp.device_time_s > 0.0);
        images.push((resp.backend, resp.images.data().to_vec()));
    }
    let (ref name0, ref data0) = images[0];
    for (name, data) in &images[1..] {
        assert_eq!(
            data0, data,
            "{name0} and {name} must produce bit-identical f32 images"
        );
    }
}

#[test]
fn ordering_preserved_per_network() {
    let dir = synthetic_dir();
    let coord = start_pool(&dir, MIXED.to_vec(), None).unwrap();
    // rapid-fire burst: batches spread over the pool, but a network's
    // batches must execute in submission order (lane pinning + FIFO)
    let handles: Vec<_> = (0..24)
        .map(|i| coord.request("mnist").images(1).seed(5000 + i).submit().unwrap())
        .collect();
    let responses: Vec<_> =
        handles.into_iter().map(|h| h.wait().unwrap()).collect();
    // responses are collected in submission (id) order; the pool-global
    // execution sequence must be non-decreasing along it — a later
    // request never executed in an earlier batch
    for pair in responses.windows(2) {
        assert!(pair[0].id < pair[1].id, "collection order is id order");
        assert!(
            pair[0].exec_seq <= pair[1].exec_seq,
            "request {} (seq {}) executed after request {} (seq {})",
            pair[1].id,
            pair[1].exec_seq,
            pair[0].id,
            pair[0].exec_seq,
        );
    }
}

#[test]
fn quant_twin_routes_around_the_gpu() {
    let dir = synthetic_dir();
    let q = QFormat::new(16, 8);
    let coord = start_pool(&dir, MIXED.to_vec(), Some(q)).unwrap();
    let report = coord
        .serve_workload(&WorkloadSpec {
            network: "mnist.q".into(),
            requests: 10,
            images_per_request: 2,
            interarrival: Duration::from_millis(1),
            seed: 3,
        })
        .unwrap();
    assert_eq!(report.requests, 10);
    let gpu_images: u64 = report
        .per_backend
        .iter()
        .filter(|b| b.name.starts_with("gpu"))
        .map(|b| b.images)
        .sum();
    assert_eq!(gpu_images, 0, "fixed-point twins never land on the GPU");
    let others: u64 = report.per_backend.iter().map(|b| b.images).sum();
    assert_eq!(others, 20, "fpga/cpu lanes served the whole workload");
}

#[test]
fn unservable_network_fails_at_startup() {
    let dir = synthetic_dir();
    let err = start_pool(
        &dir,
        vec![DeviceKind::Gpu],
        Some(QFormat::new(16, 8)),
    )
    .unwrap_err();
    assert!(
        err.to_string().contains("no capable backend"),
        "capability gap is a startup error, got: {err}"
    );
}

#[test]
fn sharded_mixed_pool_stays_deterministic() {
    let dir = synthetic_dir();
    let plain = start_pool(&dir, MIXED.to_vec(), None).unwrap();
    let reference = plain.request("mnist").images(2).seed(777).blocking().unwrap();
    drop(plain);
    let sharded = Coordinator::start(CoordinatorConfig {
        artifacts_dir: dir.path().to_path_buf(),
        networks: vec!["mnist".to_string()],
        batcher: BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
        },
        backends: BackendCfg {
            kinds: MIXED.to_vec(),
            ..Default::default()
        },
        executors: 0,
        quant: None,
        quant8: None,
        shard_batches: true,
        clock: None,
    })
    .unwrap();
    // a burst that batches then shards across the capable lanes
    let handles: Vec<_> = (0..8)
        .map(|_| sharded.request("mnist").images(2).seed(777).submit().unwrap())
        .collect();
    for h in handles {
        let resp = h.wait().unwrap();
        assert_eq!(
            resp.images.data(),
            reference.images.data(),
            "sharding across heterogeneous lanes must not change images"
        );
    }
}
