//! Experiment drivers end to end: every table/figure regenerates and
//! satisfies the paper's qualitative result shape (see DESIGN.md
//! §Per-experiment index).  Fig. 6 runs against the real artifacts and
//! skips if they are absent.

use edgedcnn::artifacts::artifacts_or_skip;
use edgedcnn::config::{JETSON_TX1, PYNQ_Z2};
use edgedcnn::experiments as exp;

#[test]
fn table1_regenerates_paper_rows() {
    let rows = exp::run_table1(&PYNQ_Z2).unwrap();
    assert_eq!(rows.len(), 2);
    // paper: both designs use 134 DSP48s and fit the -7020
    for r in &rows {
        assert_eq!(r.utilization.dsp, 134);
        assert!(r.fits);
    }
    // MNIST row reproduced exactly (calibration anchor)
    assert_eq!(rows[0].utilization.bram18, 50);
    assert_eq!(rows[0].utilization.ff, 43218);
    assert_eq!(rows[0].utilization.lut, 36469);
    // CelebA row within the documented tolerance of Table I
    assert!((rows[1].utilization.bram18 as i64 - 74).abs() <= 10);
    assert!((rows[1].utilization.ff as i64 - 48938).abs() <= 200);
    assert!((rows[1].utilization.lut as i64 - 40923).abs() <= 200);
}

#[test]
fn table2_headline_shape_holds() {
    for net in ["mnist", "celeba"] {
        let d = exp::run_table2(net, &PYNQ_Z2, &JETSON_TX1, 50, 42).unwrap();
        // (1) FPGA wins the total GOps/s/W on both networks
        assert!(
            d.fpga.total.mean > d.gpu.total.mean,
            "{net}: FPGA {:.2} must beat GPU {:.2}",
            d.fpga.total.mean,
            d.gpu.total.mean
        );
        // (2) FPGA run-to-run variation is far below the GPU's
        assert!(
            d.fpga.total.std * 5.0 < d.gpu.total.std,
            "{net}: σ_FPGA={} σ_GPU={}",
            d.fpga.total.std,
            d.gpu.total.std
        );
        // (3) every layer measured over the requested runs
        for l in d.fpga.per_layer.iter().chain(&d.gpu.per_layer) {
            assert_eq!(l.n, 50);
            assert!(l.mean > 0.0);
        }
    }
}

#[test]
fn table2_celeba_crossover() {
    // paper: the unified T_OH leaves some CelebA layers GPU-favoured
    // (L2 and L4 in Table II) — but not the total
    let d = exp::run_table2("celeba", &PYNQ_Z2, &JETSON_TX1, 50, 42).unwrap();
    let gpu_wins: Vec<usize> = d
        .fpga
        .per_layer
        .iter()
        .zip(&d.gpu.per_layer)
        .enumerate()
        .filter(|(_, (f, g))| g.mean > f.mean)
        .map(|(i, _)| i)
        .collect();
    assert!(
        !gpu_wins.is_empty(),
        "at least one CelebA layer must favour the GPU"
    );
    assert!(
        gpu_wins.len() < d.fpga.per_layer.len(),
        "...but not all of them"
    );
}

#[test]
fn fig5_regenerates_for_both_networks() {
    for net in ["mnist", "celeba"] {
        let d = exp::run_fig5(net, &PYNQ_Z2).unwrap();
        assert!(d.points.len() > 5);
        let best = &d.points[d.optimal];
        assert!(best.fits_resources);
        // all feasible points are dominated by the optimum
        for p in &d.points {
            if p.fits_resources {
                assert!(best.attainable_gops >= p.attainable_gops - 1e-9);
            }
        }
        let rendered = exp::render_fig5(&d);
        assert!(rendered.contains("T_OH*"));
    }
}

#[test]
fn fig6_full_sweep_mnist() {
    let Some(artifacts) = artifacts_or_skip() else { return };
    let levels = vec![0.0, 0.3, 0.6, 0.8, 0.9, 0.95];
    let d =
        exp::run_fig6("mnist", &PYNQ_Z2, &artifacts, &levels, 32, 7).unwrap();
    // Fig 6a: latency falls monotonically with sparsity
    for w in d.latencies_s.windows(2) {
        assert!(w[1] <= w[0] * 1.001, "latency must not rise: {w:?}");
    }
    assert!(
        d.latencies_s[0] / d.latencies_s.last().unwrap() > 1.5,
        "95% pruning must clearly speed the FPGA up"
    );
    // Fig 6b: quality degrades overall (dense MMD is the best)
    let d0 = d.mmds[0];
    let d_last = *d.mmds.last().unwrap();
    assert!(
        d_last > d0,
        "heavy pruning must hurt MMD: {d0} -> {d_last}"
    );
    // Fig 6c: Eq. 6 has an interior or boundary peak > the extremes' min
    assert_eq!(d.curve.len(), levels.len());
    assert!((d.curve[0].score - 1.0).abs() < 1e-9, "baseline score is 1");
    // (achieved sparsity can slightly exceed the 0.95 target when the
    // magnitude threshold ties)
    assert!(d.peak_sparsity >= 0.0 && d.peak_sparsity <= 1.0);
}

#[test]
fn fig6_renders() {
    let Some(artifacts) = artifacts_or_skip() else { return };
    let levels = vec![0.0, 0.5, 0.9];
    let d =
        exp::run_fig6("mnist", &PYNQ_Z2, &artifacts, &levels, 16, 3).unwrap();
    let s = exp::render_fig6(&d);
    assert!(s.contains("Eq.6 peak"));
    assert!(s.contains("speedup"));
}

#[test]
fn ablations_all_positive() {
    for net in ["mnist", "celeba"] {
        let rows = exp::run_ablations(net, &PYNQ_Z2, 0.8).unwrap();
        assert_eq!(rows.len(), 4);
        for r in &rows {
            assert!(
                r.factor() >= 1.0,
                "{}: {} vs {}",
                r.name,
                r.with_enh,
                r.without_enh
            );
        }
    }
}

#[test]
fn experiments_are_deterministic() {
    let a = exp::run_table2("mnist", &PYNQ_Z2, &JETSON_TX1, 20, 7).unwrap();
    let b = exp::run_table2("mnist", &PYNQ_Z2, &JETSON_TX1, 20, 7).unwrap();
    assert_eq!(a.fpga.total.mean, b.fpga.total.mean);
    assert_eq!(a.gpu.total.mean, b.gpu.total.mean);
    let c = exp::run_table2("mnist", &PYNQ_Z2, &JETSON_TX1, 20, 8).unwrap();
    assert_ne!(a.gpu.total.mean, c.gpu.total.mean, "seed matters");
}
