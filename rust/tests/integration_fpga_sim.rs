//! FPGA simulator integration: whole-network behaviour, the paper's
//! qualitative claims (workload-insensitive throughput, zero-skipping
//! speed-ups, pipelining benefits), and Table I legality.

use edgedcnn::config::{celeba, mnist, network_by_name, PYNQ_Z2};
use edgedcnn::deconv::{
    deconv_reverse_loop_blocked, deconv_reverse_loop_ref, BlockSchedule,
    ReverseLoopOpts,
};
use edgedcnn::fpga::{
    estimate_resources, measured_run, measurement_rng, simulate_layer,
    simulate_network, CuModel, CuWorkload, SimOpts,
};
use edgedcnn::stats::Summary;
use edgedcnn::tensor::Tensor;
use edgedcnn::util::WorkerPool;

fn dense_opts(net: &edgedcnn::config::NetworkCfg) -> Vec<SimOpts> {
    net.layers.iter().map(|_| SimOpts::dense(net.tile)).collect()
}

#[test]
fn network_time_is_sum_of_multiplexed_layers() {
    for net in [mnist(), celeba()] {
        let sim = simulate_network(&net, &PYNQ_Z2, &dense_opts(&net));
        let sum: f64 = sim.layers.iter().map(|l| l.time_s).sum();
        assert!((sim.total_time_s - sum).abs() < 1e-12);
        assert_eq!(sim.total_ops, net.total_ops());
        assert!(sim.gops_per_w > 0.5 && sim.gops_per_w < 20.0);
    }
}

#[test]
fn throughput_never_exceeds_rooflines() {
    for net in [mnist(), celeba()] {
        for l in &simulate_network(&net, &PYNQ_Z2, &dense_opts(&net)).layers {
            assert!(l.gops <= PYNQ_Z2.peak_gops() + 1e-9);
            let bw_roof_gops =
                (l.ops as f64 / (l.read_cycles.max(1) as f64 / PYNQ_Z2.clock_hz))
                    / 1e9;
            // sanity: read stage really moves the bytes it claims
            assert!(bw_roof_gops.is_finite());
        }
    }
}

#[test]
fn fpga_run_to_run_variation_is_workload_insensitive() {
    // the paper's core FPGA claim: deterministic dataflow → tiny σ on
    // EVERY layer, dense or sparse
    let net = celeba();
    let mut rng = measurement_rng(9);
    for (i, layer) in net.layers.iter().enumerate() {
        for sparsity in [0.0, 0.7] {
            let opts = SimOpts {
                zero_skip: sparsity > 0.0,
                weight_sparsity: sparsity,
                ..SimOpts::dense(net.tile)
            };
            let base = simulate_layer(layer, &PYNQ_Z2, &opts);
            let runs: Vec<f64> = (0..50)
                .map(|_| measured_run(&base, &mut rng).gops_per_w)
                .collect();
            let s = Summary::of(&runs);
            assert!(
                s.std / s.mean < 0.01,
                "L{} sparsity {sparsity}: cv={}",
                i + 1,
                s.std / s.mean
            );
        }
    }
}

#[test]
fn zero_skip_speedup_grows_with_sparsity() {
    for net in [mnist(), celeba()] {
        let dense =
            simulate_network(&net, &PYNQ_Z2, &dense_opts(&net)).total_time_s;
        let mut prev = dense * 1.0001; // skipping machinery overhead slack
        for sparsity in [0.2, 0.5, 0.8, 0.95] {
            let opts: Vec<SimOpts> = net
                .layers
                .iter()
                .map(|_| SimOpts {
                    zero_skip: true,
                    weight_sparsity: sparsity,
                    ..SimOpts::dense(net.tile)
                })
                .collect();
            let t = simulate_network(&net, &PYNQ_Z2, &opts).total_time_s;
            assert!(
                t <= prev,
                "{}: time must fall with sparsity ({t} vs {prev} at {sparsity})",
                net.name
            );
            prev = t;
        }
        assert!(
            dense / prev > 1.5,
            "{}: 95% sparsity must give a clear speed-up (got {:.2}x)",
            net.name,
            dense / prev
        );
    }
}

#[test]
fn decoupled_access_beats_serialized_random() {
    for net in [mnist(), celeba()] {
        let on = simulate_network(&net, &PYNQ_Z2, &dense_opts(&net));
        let coupled: Vec<SimOpts> = net
            .layers
            .iter()
            .map(|_| SimOpts {
                decouple: false,
                ..SimOpts::dense(net.tile)
            })
            .collect();
        let off = simulate_network(&net, &PYNQ_Z2, &coupled);
        assert!(
            off.total_time_s > 1.5 * on.total_time_s,
            "{}: enhancement 3 must matter",
            net.name
        );
    }
}

#[test]
fn table1_designs_fit_and_scale() {
    for net in [mnist(), celeba()] {
        let u = estimate_resources(&net, net.tile, PYNQ_Z2.n_cu);
        assert!(u.fits(&PYNQ_Z2), "{}: paper design must fit", net.name);
        assert_eq!(u.dsp, 134);
        // doubling the CU array busts the DSP budget (the paper's 16 is
        // near the -7020 limit)
        let u2 = estimate_resources(&net, net.tile, PYNQ_Z2.n_cu * 2);
        assert!(!u2.fits(&PYNQ_Z2));
    }
}

#[test]
fn cpu_blocking_and_cu_cycle_model_share_one_schedule_struct() {
    // the unified-geometry contract: the BlockSchedule the CPU kernel
    // executes is the same struct the CU cycle model consumes, so a
    // tuned software schedule *is* a hardware design point
    let (c_in, c_out, k, s, p, i_h) = (4usize, 3usize, 4usize, 2, 1, 7);
    let pool = WorkerPool::new(2);
    let x = Tensor::from_fn(vec![1, c_in, i_h, i_h], |i| (i as f32 * 0.31).sin());
    let w = Tensor::from_fn(vec![c_in, c_out, k, k], |i| (i as f32 * 0.23).cos());
    let b = vec![0.1f32; c_out];
    for sched in [
        BlockSchedule { micro: 6, macro_tiles: 2, lanes: 4 },
        BlockSchedule { micro: 12, macro_tiles: 4, lanes: 8 },
    ] {
        // software side: the blocked kernel executes `sched` and stays
        // bit-identical to the frozen scalar reference
        let opts = ReverseLoopOpts { tile: sched.micro, zero_skip: false };
        let (want, want_stats) = deconv_reverse_loop_ref(&x, &w, &b, s, p, opts);
        let (got, got_stats) = deconv_reverse_loop_blocked(
            &x, &w, &b, s, p, false, Some(sched), &pool,
        );
        assert_eq!(got.data(), want.data());
        assert_eq!(got_stats, want_stats);

        // hardware side: the SAME struct parameterizes the CU workload,
        // and the model's cycle count is exactly the Algorithm 1 cost
        // of that geometry
        let wl = CuWorkload::from_block_schedule(&sched, c_in, k, s);
        assert_eq!(wl.tile_elems, sched.micro * sched.micro);
        assert_eq!(wl.macs_per_tap, sched.micro.div_ceil(s).pow(2));
        assert_eq!(wl.taps, k * k);
        let cu = CuModel {
            lanes: sched.lanes,
            workload_overhead: 12,
            per_channel_overhead: 4,
        };
        let lanes = sched.lanes as u64;
        let expect = 12
            + (wl.tile_elems as u64).div_ceil(lanes)
            + c_in as u64
                * (4 + (k * k) as u64
                    * (wl.macs_per_tap as u64).div_ceil(lanes));
        assert_eq!(
            cu.dense_cycles(&wl),
            expect,
            "micro {} lanes {}: cycle model diverged from the shared \
             schedule geometry",
            sched.micro,
            sched.lanes
        );
        // per-workload MACs come from the same ⌈T/S⌉² the CPU tiles use
        assert_eq!(
            cu.dense_macs(&wl),
            (c_in * k * k) as u64 * sched.micro.div_ceil(s).pow(2) as u64
        );
    }
}

#[test]
fn unified_tile_is_suboptimal_for_some_layers() {
    // the paper's own observation (Section V-B): a single T_OH across
    // layers leaves some layers worse than their per-layer best
    let net = network_by_name("celeba").unwrap();
    for (i, layer) in net.layers.iter().enumerate() {
        let unified =
            simulate_layer(layer, &PYNQ_Z2, &SimOpts::dense(net.tile));
        let mut best = unified.gops_per_w;
        for t in [2, 4, 8, 16, 32, 64] {
            let s = simulate_layer(layer, &PYNQ_Z2, &SimOpts::dense(t));
            best = best.max(s.gops_per_w);
        }
        if best > unified.gops_per_w * 1.05 {
            // at least one layer benefits from a different tile: done
            println!(
                "L{}: unified {:.2} vs per-layer best {:.2}",
                i + 1,
                unified.gops_per_w,
                best
            );
            return;
        }
    }
    panic!("expected ≥1 CelebA layer where the unified T_OH is sub-optimal");
}
