//! Workload & telemetry integration: the loadtest end to end against a
//! synthetic artifact set — the paper's run-to-run-variation verdict as
//! a live, asserted experiment, and its deadline restatement (FPGA
//! attainment >= GPU attainment at equal deadlines) — plus scheduler
//! overload behaviour (admission-control rejection accounting, the
//! shed-early / served-late split, deferred-queue drain order,
//! cross-priority non-starvation, no-starvation across two networks
//! under a bursty scenario) and trace record/replay determinism.

use edgedcnn::artifacts::write_synthetic;
use edgedcnn::config::{BackendCfg, DeviceKind};
use edgedcnn::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, PriorityClass, RequestCtx,
    StageBreakdown, WorkloadSpec,
};
use edgedcnn::quant::QFormat;
use edgedcnn::telemetry::Stage;
use edgedcnn::util::TempDir;
use edgedcnn::workload::{run_loadtest, LoadtestOpts, Scenario, Trace};
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

fn synthetic_dir() -> TempDir {
    let dir = TempDir::new().unwrap();
    write_synthetic(dir.path(), &["mnist"], 2, 17).unwrap();
    dir
}

/// The acceptance experiment: a bursty scenario over an fpga+gpu pool,
/// repeated trials, and the paper's claim — the FPGA-sim lane shows
/// strictly lower device-latency variation than the GPU-model lane.
#[test]
fn burst_loadtest_reproduces_the_variation_verdict() {
    let dir = synthetic_dir();
    let mut scenario = Scenario::builtin("burst").unwrap();
    scenario.requests = 64;
    let trace = Trace::generate(&scenario).unwrap();
    let report = run_loadtest(
        &trace,
        &LoadtestOpts {
            artifacts_dir: dir.path().to_path_buf(),
            backends: BackendCfg {
                kinds: vec![DeviceKind::Fpga, DeviceKind::Gpu],
                ..Default::default()
            },
            trials: 5,
            drift_csv: Some(dir.path().join("drift.csv")),
            ..Default::default()
        },
    )
    .unwrap();

    assert_eq!(report.trials, 5);
    assert_eq!(report.requests_per_trial, 64);
    // every lane row carries populated percentile and CV columns
    for lane in &report.lanes {
        assert!(lane.batches > 0, "{}: served nothing", lane.name);
        assert!(lane.latency.p50_s > 0.0, "{}", lane.name);
        assert!(lane.latency.p95_s >= lane.latency.p50_s);
        assert!(lane.latency.p99_s >= lane.latency.p95_s);
        assert!(lane.latency.p999_s >= lane.latency.p99_s);
        assert!((0.0..=1.0).contains(&lane.slo_attainment));
        assert!(lane.mean_device_per_image_s > 0.0);
        assert!(lane.throughput.mean > 0.0);
    }
    assert!(report.latency.p99_s > 0.0, "overall p99 populated");

    // the paper's Table-2 claim, live: FPGA strictly more stable
    let v = report
        .verdict
        .as_ref()
        .expect("both fpga and gpu lanes must have served batches");
    assert!(
        v.fpga_cv < v.gpu_cv,
        "FPGA lane must vary strictly less: {} cv {:.4} vs {} cv {:.4}",
        v.fpga_lane,
        v.fpga_cv,
        v.gpu_lane,
        v.gpu_cv
    );
    assert!(v.fpga_wins);

    // the request lifecycle closes: every submitted request is exactly
    // one of served / shed (deadline infeasible) / rejected (overload)
    // / lost, and every served one's images landed on exactly one lane
    assert_eq!(report.lost, 0, "no backend execution failures expected");
    assert_eq!(
        report.served + report.shed + report.rejected + report.lost,
        report.total_requests,
        "accounting must close"
    );
    let served_images: u64 = report.lanes.iter().map(|l| l.images).sum();
    assert_eq!(
        served_images,
        report.served * 2,
        "trace requests carry 2 images each"
    );
    // the burst scenario is deadline-bearing (deadline = SLO): every
    // served request got a deadline verdict on some lane
    let verdicts: u64 = report
        .lanes
        .iter()
        .map(|l| l.deadline_met + l.served_late)
        .sum();
    assert_eq!(verdicts, report.served, "every completion gets a verdict");
    assert!(
        report.deadline_verdict.is_some(),
        "deadline-bearing traffic on both lanes ⇒ a deadline verdict"
    );

    let rendered = report.render();
    assert!(rendered.contains("verdict:"), "{rendered}");
    assert!(rendered.contains("deadline verdict:"), "{rendered}");
    assert!(rendered.contains("cv_pct"), "{rendered}");
    assert!(rendered.contains("p99_ms"), "{rendered}");
    assert!(rendered.contains("att_pct"), "{rendered}");
    assert!(rendered.contains("accounting: submitted"), "{rendered}");

    // --drift-csv landed the final trial's windowed drift shards
    let csv = std::fs::read_to_string(dir.path().join("drift.csv")).unwrap();
    let mut lines = csv.lines();
    assert_eq!(
        lines.next(),
        Some("window_start_s,count,p50_s,p99_s"),
        "{csv}"
    );
    assert!(lines.next().is_some(), "64 requests must fill a window");
}

/// Same seed + scenario file ⇒ identical arrival timestamps and request
/// mix across two independent resolve→generate runs; record → replay
/// roundtrips the trace bit-for-bit.
#[test]
fn trace_replay_is_deterministic() {
    let dir = TempDir::new().unwrap();
    let scenario_path = dir.path().join("scenario.json");
    let mut s = Scenario::builtin("burst").unwrap();
    s.requests = 50;
    std::fs::write(&scenario_path, s.to_json()).unwrap();

    let arg = scenario_path.to_str().unwrap();
    let a = Trace::generate(&Scenario::resolve(arg).unwrap()).unwrap();
    let b = Trace::generate(&Scenario::resolve(arg).unwrap()).unwrap();
    assert_eq!(a, b, "two runs from the same scenario file must agree");
    let ts_a: Vec<f64> = a.events.iter().map(|e| e.t_s).collect();
    let ts_b: Vec<f64> = b.events.iter().map(|e| e.t_s).collect();
    assert_eq!(ts_a, ts_b, "identical arrival timestamps");
    let mix_a: Vec<&str> =
        a.events.iter().map(|e| e.network.as_str()).collect();
    let mix_b: Vec<&str> =
        b.events.iter().map(|e| e.network.as_str()).collect();
    assert_eq!(mix_a, mix_b, "identical request mix");

    let trace_path = dir.path().join("trace.json");
    a.save(&trace_path).unwrap();
    let replayed = Trace::load(&trace_path).unwrap();
    assert_eq!(replayed, a, "record → replay is exact");
}

/// Overload a single slow lane behind a tiny deferral budget: intake
/// must reject (not queue unboundedly), the serving report must count
/// exactly the rejected callers, and the survivors must still resolve.
#[test]
fn admission_control_rejects_and_accounts_under_flood() {
    let dir = synthetic_dir();
    let coord = Coordinator::start(CoordinatorConfig {
        artifacts_dir: dir.path().to_path_buf(),
        networks: vec!["mnist".to_string()],
        batcher: BatcherConfig {
            max_batch: 2,
            max_wait: Duration::from_millis(1),
        },
        backends: BackendCfg {
            kinds: vec![DeviceKind::Fpga],
            max_queue_depth: 1,
            admit_max_deferred: 2,
            ..Default::default()
        },
        executors: 0,
        quant: None,
        quant8: None,
        shard_batches: false,
        clock: None,
    })
    .unwrap();

    // wave 1 saturates the lane and fills the deferred queue (40
    // oversize single-request batches against a depth-1 lane: even a
    // fast host cannot drain them before wave 2) …
    let mut handles = Vec::new();
    for i in 0..40u64 {
        handles.push(coord.request("mnist").images(4).seed(100 + i).submit().unwrap());
    }
    std::thread::sleep(Duration::from_millis(20));
    // … wave 2 arrives against a full deferral budget
    for i in 0..16u64 {
        handles.push(coord.request("mnist").images(4).seed(200 + i).submit().unwrap());
    }

    let mut ok = 0u64;
    let mut rejected = 0u64;
    for h in handles {
        match h.wait() {
            Ok(resp) => {
                assert!(resp.images.numel() > 0);
                ok += 1;
            }
            Err(_) => rejected += 1,
        }
    }
    assert!(rejected > 0, "flood against admit_max_deferred=2 must reject");
    assert!(ok > 0, "survivors must still be served");

    let report = coord.report();
    assert_eq!(report.rejected, rejected, "report counts the rejections");
    assert!(report.deferred > 0, "backpressure deferrals observed");
    // lane telemetry: dispatch-time depth never exceeded the bound
    assert!(!report.lanes.is_empty());
    for lane in &report.lanes {
        assert!(
            lane.max_depth <= 1,
            "{}: queue depth bound violated ({})",
            lane.name,
            lane.max_depth
        );
        assert!(lane.dispatches > 0);
    }
}

/// Bursty two-network traffic through one depth-bounded lane: the
/// deferred queue must drain FIFO per network (exec_seq non-decreasing
/// in submission order) and neither network may starve.
#[test]
fn deferred_drain_order_and_no_starvation_across_networks() {
    let dir = synthetic_dir();
    let coord = Coordinator::start(CoordinatorConfig {
        artifacts_dir: dir.path().to_path_buf(),
        networks: vec!["mnist".to_string()],
        batcher: BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
        },
        backends: BackendCfg {
            kinds: vec![DeviceKind::Fpga],
            max_queue_depth: 1,
            // starvation test: everything must eventually be served
            admit_max_deferred: 10_000,
            ..Default::default()
        },
        executors: 0,
        quant: Some(QFormat::new(16, 8)),
        quant8: None,
        shard_batches: false,
        clock: None,
    })
    .unwrap();

    // a bursty scenario over the f32 network and its .q twin, driven
    // as fast as the trace allows (timestamps compressed to zero gap)
    let mut scenario = Scenario::builtin("burst").unwrap();
    scenario.requests = 48;
    let trace = Trace::generate(&scenario).unwrap();
    let mut handles = Vec::new();
    for e in &trace.events {
        handles.push((
            e.network.clone(),
            coord.request(&e.network).images(e.n_images).seed(e.seed).submit().unwrap(),
        ));
    }

    let mut per_network: BTreeMap<String, Vec<(u64, u64)>> = BTreeMap::new();
    for (network, h) in handles {
        let resp = h.wait().expect("no rejections at this deferral budget");
        per_network
            .entry(network)
            .or_default()
            .push((resp.id, resp.exec_seq));
    }
    assert_eq!(per_network.len(), 2, "both networks present in the mix");
    for (network, mut seen) in per_network {
        assert!(
            !seen.is_empty(),
            "{network}: starved under burst + backpressure"
        );
        // submission order = id order; deferred batches must drain FIFO
        seen.sort_by_key(|(id, _)| *id);
        for pair in seen.windows(2) {
            assert!(
                pair[0].1 <= pair[1].1,
                "{network}: request {} (seq {}) overtook request {} (seq {})",
                pair[1].0,
                pair[1].1,
                pair[0].0,
                pair[0].1,
            );
        }
    }

    let report = coord.report();
    assert_eq!(report.rejected, 0);
    assert!(
        report.deferred > 0,
        "a depth-1 lane under burst traffic must defer"
    );
}

/// The acceptance experiment for the deadline lifecycle: the burst
/// workload driven through an fpga-only and a gpu-only pool at *equal*
/// per-request deadlines, one request in flight at a time so both
/// devices are measured at the same operating point (batch = 1, no
/// queueing) — the paper's variation verdict restated as a deadline
/// verdict.  At a 9 ms deadline the FPGA's 1-image service time
/// (~7.1 ms ± 0.6% bounded jitter) always fits, while the GPU's
/// (~8.1 ms × nvprof-style noise + interference stalls) sometimes
/// doesn't: predictability pays as attainment.
#[test]
fn deadline_attainment_fpga_at_least_gpu_at_equal_deadlines() {
    let dir = synthetic_dir();
    let mut scenario = Scenario::builtin("burst").unwrap();
    scenario.requests = 64;
    let trace = Trace::generate(&scenario).unwrap();
    let deadline = Duration::from_millis(9);

    let mut attainment: BTreeMap<&str, (u64, u64)> = BTreeMap::new();
    for kind in [DeviceKind::Fpga, DeviceKind::Gpu] {
        let coord = Coordinator::start(CoordinatorConfig {
            artifacts_dir: dir.path().to_path_buf(),
            networks: vec!["mnist".to_string()],
            backends: BackendCfg {
                kinds: vec![kind],
                ..Default::default()
            },
            ..Default::default()
        })
        .unwrap();
        // warm the pipeline (thread wakeup paths, allocator) with
        // best-effort requests so cold-start wall hiccups don't land in
        // the measured attainment
        for w in 0..4u64 {
            coord.request("mnist").images(1).seed(900 + w).blocking().unwrap();
        }
        for e in &trace.events {
            // the lane decrements its depth counter just *after* the
            // previous reply resolves; give it a beat so the next
            // intake feasibility check sees an idle lane
            std::thread::sleep(Duration::from_millis(1));
            // identical workload per device: the burst trace's seeds on
            // the f32 network, one image per request, equal deadlines
            let ctx = RequestCtx::new(e.seed)
                .with_class(e.class)
                .with_deadline(Instant::now() + deadline);
            let resp = coord
                .request("mnist").images(1).ctx(ctx).submit()
                .unwrap()
                .wait()
                .expect("1-image requests are feasible at intake");
            let met = resp
                .deadline_met
                .expect("deadline-bearing request must carry a verdict");
            let cell = attainment.entry(kind.as_str()).or_insert((0, 0));
            if met {
                cell.0 += 1;
            } else {
                cell.1 += 1;
            }
            assert!(resp.charged_s > 0.0);
        }
        // the per-(backend, class) attainment columns are populated
        let report = coord.report();
        let with_deadlines: u64 = report
            .per_backend
            .iter()
            .flat_map(|b| b.deadline.iter())
            .map(|d| d.met + d.late)
            .sum();
        assert_eq!(with_deadlines, trace.events.len() as u64);
    }

    let (fpga_met, fpga_late) = attainment["fpga"];
    let (gpu_met, gpu_late) = attainment["gpu"];
    let att = |met: u64, late: u64| met as f64 / (met + late) as f64;
    let fpga_att = att(fpga_met, fpga_late);
    let gpu_att = att(gpu_met, gpu_late);
    assert!(
        fpga_att >= gpu_att,
        "the FPGA lane must attain at least the GPU lane at equal \
         deadlines: fpga {fpga_att:.3} ({fpga_met}/{fpga_late}) vs gpu \
         {gpu_att:.3} ({gpu_met}/{gpu_late})"
    );
}

/// The flight recorder's integration payoff: the stage breakdown
/// separates *where* latency varies.  Aggregate request-latency CV
/// mixes queue congestion with device jitter; the per-stage CV columns
/// pull them apart — the FPGA lane's device-execute stage varies less
/// than the GPU lane's (the paper's Table II claim at stage
/// granularity), while both lanes' queue-wait variation under a backlog
/// dwarfs the FPGA's device jitter (so the aggregate CV says nothing
/// about the device until the stages are separated).
#[test]
fn stage_breakdown_separates_device_execute_cv_from_queue_wait() {
    let dir = synthetic_dir();
    let coord = Coordinator::start(CoordinatorConfig {
        artifacts_dir: dir.path().to_path_buf(),
        networks: vec!["mnist".to_string()],
        // single-request batches: every device-execute span measures one
        // 1-image execute, so the stage CV is pure device jitter (no
        // batch-size mixing)
        batcher: BatcherConfig {
            max_batch: 1,
            max_wait: Duration::from_millis(1),
        },
        backends: BackendCfg {
            kinds: vec![DeviceKind::Fpga, DeviceKind::Gpu],
            // the open-loop schedule outruns the pool on purpose (queue
            // wait must be nonzero); nothing may be turned away
            admit_max_deferred: 10_000,
            ..Default::default()
        },
        executors: 0,
        quant: None,
        quant8: None,
        shard_batches: true,
        clock: None,
    })
    .unwrap();
    let report = coord
        .serve_workload(&WorkloadSpec {
            network: "mnist".to_string(),
            requests: 64,
            images_per_request: 1,
            interarrival: Duration::from_millis(1),
            seed: 42,
        })
        .unwrap();

    // schema sanity: every cell carries all seven stages, finite and
    // ordered
    assert!(!report.stage_breakdown.is_empty(), "stages recorded");
    let mut total = 0u64;
    for cell in &report.stage_breakdown {
        assert!(cell.count > 0, "{}: empty cell", cell.backend);
        total += cell.count;
        assert_eq!(cell.stages.len(), Stage::ALL.len());
        for row in &cell.stages {
            assert!(row.mean_s.is_finite() && row.mean_s >= 0.0);
            assert!(row.p99_s >= row.p50_s, "{}: {:?}", cell.backend, row);
            assert!(row.cv.is_finite() && row.cv >= 0.0);
        }
    }
    assert_eq!(total, 64, "every served request decomposed into stages");

    let cell = |prefix: &str| -> &StageBreakdown {
        report
            .stage_breakdown
            .iter()
            .find(|c| c.backend.starts_with(prefix))
            .unwrap_or_else(|| panic!("no {prefix} cell"))
    };
    let fpga_dev = cell("fpga").stage(Stage::DeviceExecute).unwrap();
    let gpu_dev = cell("gpu").stage(Stage::DeviceExecute).unwrap();
    let fpga_queue = cell("fpga").stage(Stage::QueueWait).unwrap();

    // the device-stage CV gap: FPGA executes with bounded jitter, the
    // GPU model carries measurement noise + interference stalls
    assert!(
        fpga_dev.cv < gpu_dev.cv,
        "FPGA device-execute must vary less: fpga cv {:.4} vs gpu cv {:.4}",
        fpga_dev.cv,
        gpu_dev.cv
    );
    // …and queue congestion (which aggregate latency CV folds in) is a
    // different axis entirely: under this backlog the FPGA lane's
    // queue-wait varies far more than its device execute
    assert!(
        fpga_queue.cv > fpga_dev.cv,
        "queue-wait cv {:.4} must dominate fpga device cv {:.4}",
        fpga_queue.cv,
        fpga_dev.cv
    );
    assert!(
        fpga_queue.mean_s > 0.0,
        "the open-loop schedule must actually build a queue"
    );
}

/// Stage spans must telescope to the end-to-end latency the response
/// reports — for the f32 network *and* its fixed-point `.q` twin (the
/// quantized path shares the lifecycle plumbing, not just the f32
/// path).  Both numbers measure charged-arrival → reply with separate
/// `Instant` captures, so equality holds to sub-millisecond slack.
#[test]
fn stage_spans_telescope_to_reported_latency_for_both_precisions() {
    let dir = synthetic_dir();
    let coord = Coordinator::start(CoordinatorConfig {
        artifacts_dir: dir.path().to_path_buf(),
        networks: vec!["mnist".to_string()],
        batcher: BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
        },
        backends: BackendCfg {
            kinds: vec![DeviceKind::Fpga],
            admit_max_deferred: 10_000,
            ..Default::default()
        },
        executors: 0,
        quant: Some(QFormat::new(16, 8)),
        quant8: None,
        shard_batches: false,
        clock: None,
    })
    .unwrap();

    for network in ["mnist", "mnist.q"] {
        for i in 0..16u64 {
            let resp = coord
                .request(network)
                .images(2)
                .seed(3000 + i)
                .blocking()
                .unwrap();
            let spans = resp
                .stamps
                .stage_spans()
                .expect("served request has a complete lifecycle");
            let sum: f64 = spans.iter().sum();
            let tolerance = 2e-3 + 0.05 * resp.latency_s;
            assert!(
                (sum - resp.latency_s).abs() <= tolerance,
                "{network} req {i}: stage sum {sum:.6} vs latency \
                 {:.6} (tolerance {tolerance:.6}, spans {spans:?})",
                resp.latency_s
            );
            // within the lifecycle, device execute is bounded by the
            // response's own substrate wall time plus queueing slack
            assert!(
                spans[Stage::DeviceExecute.index()] > 0.0,
                "{network} req {i}: device stage must take time"
            );
        }
    }
}

/// Shed-at-intake and served-late are distinct columns: a deadline the
/// pool cannot meet is refused on arrival (counted as `shed`), never
/// silently folded into overload rejections or served-late completions
/// — and the lifecycle accounting closes exactly.
#[test]
fn shed_early_is_counted_separately_from_served_late() {
    let dir = synthetic_dir();
    let mut scenario = Scenario::builtin("burst").unwrap();
    scenario.requests = 48;
    // tight deadline: comfortably above the 1-2-image service time but
    // inside the queue-backlog ETA a burst builds up, so intake sheds
    // under the burst and serves the calm stretches
    scenario.deadline_s = Some(0.025);
    let trace = Trace::generate(&scenario).unwrap();
    let report = run_loadtest(
        &trace,
        &LoadtestOpts {
            artifacts_dir: dir.path().to_path_buf(),
            backends: BackendCfg {
                kinds: vec![DeviceKind::Fpga, DeviceKind::Gpu],
                ..Default::default()
            },
            trials: 3,
            ..Default::default()
        },
    )
    .unwrap();

    assert_eq!(report.lost, 0, "sheds must not read as failures");
    assert!(
        report.shed > 0,
        "a 25 ms deadline under MMPP bursts must shed at intake"
    );
    assert_eq!(
        report.served + report.shed + report.rejected,
        report.total_requests,
        "served + shed + rejected must cover every submission"
    );
    // served-late lives on the lanes, not in the shed counter
    let late: u64 = report.lanes.iter().map(|l| l.served_late).sum();
    assert_eq!(report.served_late, late);
    let verdicts: u64 = report
        .lanes
        .iter()
        .map(|l| l.deadline_met + l.served_late)
        .sum();
    assert_eq!(verdicts, report.served, "shed requests get no lane verdict");
    let rendered = report.render();
    assert!(rendered.contains("shed"), "{rendered}");
    assert!(rendered.contains("late"), "{rendered}");
}

/// Cross-priority non-starvation: EDF orders by deadline, class only
/// shapes shedding — so a Low-class request with a loose deadline is
/// eventually served even while Normal-class traffic with tighter
/// deadlines keeps arriving (a strict priority queue would starve it).
#[test]
fn low_class_is_not_starved_by_tighter_normal_traffic() {
    let dir = synthetic_dir();
    let coord = Coordinator::start(CoordinatorConfig {
        artifacts_dir: dir.path().to_path_buf(),
        networks: vec!["mnist".to_string()],
        batcher: BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
        },
        backends: BackendCfg {
            kinds: vec![DeviceKind::Fpga],
            ..Default::default()
        },
        ..Default::default()
    })
    .unwrap();

    let mut low_handles = Vec::new();
    let mut normal_handles = Vec::new();
    for i in 0..30u64 {
        let now = Instant::now();
        // a steady stream of tighter-deadline Normal traffic …
        let normal = RequestCtx::new(1000 + i)
            .with_deadline(now + Duration::from_millis(400));
        normal_handles.push(coord.request("mnist").images(2).ctx(normal).submit().unwrap());
        // … with a loose-deadline Low request interleaved every fifth
        if i % 5 == 0 {
            let low = RequestCtx::new(2000 + i)
                .with_class(PriorityClass::Low)
                .with_deadline(now + Duration::from_secs(30));
            low_handles.push(coord.request("mnist").images(2).ctx(low).submit().unwrap());
        }
    }

    let mut low_served = 0u64;
    for h in low_handles {
        let resp = h.wait().expect("low class must not starve under EDF");
        assert_eq!(resp.class, PriorityClass::Low);
        assert_eq!(
            resp.deadline_met,
            Some(true),
            "a 30 s deadline gives the low class all the slack it needs"
        );
        low_served += 1;
    }
    assert_eq!(low_served, 6);
    // normals may be served or shed (their deadlines are honest), but
    // never silently dropped
    let mut normal_outcomes = 0u64;
    for h in normal_handles {
        if h.wait().is_ok() {
            normal_outcomes += 1;
        }
    }
    let report = coord.report();
    assert_eq!(
        normal_outcomes + report.shed + report.rejected,
        30,
        "every normal request resolved or was counted shed/rejected"
    );
    // the per-class split reaches the report
    let classes: Vec<PriorityClass> = report
        .per_backend
        .iter()
        .flat_map(|b| b.deadline.iter())
        .map(|d| d.class)
        .collect();
    assert!(classes.contains(&PriorityClass::Low), "{classes:?}");
}
