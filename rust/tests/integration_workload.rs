//! Workload & telemetry integration: the loadtest end to end against a
//! synthetic artifact set — the paper's run-to-run-variation verdict as
//! a live, asserted experiment — plus scheduler overload behaviour
//! (admission-control rejection accounting, deferred-queue drain order,
//! no-starvation across two networks under a bursty scenario) and
//! trace record/replay determinism.

use edgedcnn::artifacts::write_synthetic;
use edgedcnn::config::{BackendCfg, DeviceKind};
use edgedcnn::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig,
};
use edgedcnn::quant::QFormat;
use edgedcnn::util::TempDir;
use edgedcnn::workload::{run_loadtest, LoadtestOpts, Scenario, Trace};
use std::collections::BTreeMap;
use std::time::Duration;

fn synthetic_dir() -> TempDir {
    let dir = TempDir::new().unwrap();
    write_synthetic(dir.path(), &["mnist"], 2, 17).unwrap();
    dir
}

/// The acceptance experiment: a bursty scenario over an fpga+gpu pool,
/// repeated trials, and the paper's claim — the FPGA-sim lane shows
/// strictly lower device-latency variation than the GPU-model lane.
#[test]
fn burst_loadtest_reproduces_the_variation_verdict() {
    let dir = synthetic_dir();
    let mut scenario = Scenario::builtin("burst").unwrap();
    scenario.requests = 64;
    let trace = Trace::generate(&scenario).unwrap();
    let report = run_loadtest(
        &trace,
        &LoadtestOpts {
            artifacts_dir: dir.path().to_path_buf(),
            backends: BackendCfg {
                kinds: vec![DeviceKind::Fpga, DeviceKind::Gpu],
                ..Default::default()
            },
            executors: 0,
            trials: 5,
            shard_batches: true,
        },
    )
    .unwrap();

    assert_eq!(report.trials, 5);
    assert_eq!(report.requests_per_trial, 64);
    // every lane row carries populated percentile and CV columns
    for lane in &report.lanes {
        assert!(lane.batches > 0, "{}: served nothing", lane.name);
        assert!(lane.latency.p50_s > 0.0, "{}", lane.name);
        assert!(lane.latency.p95_s >= lane.latency.p50_s);
        assert!(lane.latency.p99_s >= lane.latency.p95_s);
        assert!(lane.latency.p999_s >= lane.latency.p99_s);
        assert!((0.0..=1.0).contains(&lane.slo_attainment));
        assert!(lane.mean_device_per_image_s > 0.0);
        assert!(lane.throughput.mean > 0.0);
    }
    assert!(report.latency.p99_s > 0.0, "overall p99 populated");

    // the paper's Table-2 claim, live: FPGA strictly more stable
    let v = report
        .verdict
        .as_ref()
        .expect("both fpga and gpu lanes must have served batches");
    assert!(
        v.fpga_cv < v.gpu_cv,
        "FPGA lane must vary strictly less: {} cv {:.4} vs {} cv {:.4}",
        v.fpga_lane,
        v.fpga_cv,
        v.gpu_lane,
        v.gpu_cv
    );
    assert!(v.fpga_wins);

    // image accounting closes: every non-rejected request's images
    // landed on exactly one lane, and nothing was lost to failures
    assert_eq!(report.lost, 0, "no backend execution failures expected");
    let served: u64 = report.lanes.iter().map(|l| l.images).sum();
    assert_eq!(
        served,
        (report.total_requests - report.rejected) * 2,
        "trace requests carry 2 images each"
    );

    let rendered = report.render();
    assert!(rendered.contains("verdict:"), "{rendered}");
    assert!(rendered.contains("cv_pct"), "{rendered}");
    assert!(rendered.contains("p99_ms"), "{rendered}");
}

/// Same seed + scenario file ⇒ identical arrival timestamps and request
/// mix across two independent resolve→generate runs; record → replay
/// roundtrips the trace bit-for-bit.
#[test]
fn trace_replay_is_deterministic() {
    let dir = TempDir::new().unwrap();
    let scenario_path = dir.path().join("scenario.json");
    let mut s = Scenario::builtin("burst").unwrap();
    s.requests = 50;
    std::fs::write(&scenario_path, s.to_json()).unwrap();

    let arg = scenario_path.to_str().unwrap();
    let a = Trace::generate(&Scenario::resolve(arg).unwrap()).unwrap();
    let b = Trace::generate(&Scenario::resolve(arg).unwrap()).unwrap();
    assert_eq!(a, b, "two runs from the same scenario file must agree");
    let ts_a: Vec<f64> = a.events.iter().map(|e| e.t_s).collect();
    let ts_b: Vec<f64> = b.events.iter().map(|e| e.t_s).collect();
    assert_eq!(ts_a, ts_b, "identical arrival timestamps");
    let mix_a: Vec<&str> =
        a.events.iter().map(|e| e.network.as_str()).collect();
    let mix_b: Vec<&str> =
        b.events.iter().map(|e| e.network.as_str()).collect();
    assert_eq!(mix_a, mix_b, "identical request mix");

    let trace_path = dir.path().join("trace.json");
    a.save(&trace_path).unwrap();
    let replayed = Trace::load(&trace_path).unwrap();
    assert_eq!(replayed, a, "record → replay is exact");
}

/// Overload a single slow lane behind a tiny deferral budget: intake
/// must reject (not queue unboundedly), the serving report must count
/// exactly the rejected callers, and the survivors must still resolve.
#[test]
fn admission_control_rejects_and_accounts_under_flood() {
    let dir = synthetic_dir();
    let coord = Coordinator::start(CoordinatorConfig {
        artifacts_dir: dir.path().to_path_buf(),
        networks: vec!["mnist".to_string()],
        batcher: BatcherConfig {
            max_batch: 2,
            max_wait: Duration::from_millis(1),
        },
        backends: BackendCfg {
            kinds: vec![DeviceKind::Fpga],
            max_queue_depth: 1,
            admit_max_deferred: 2,
            ..Default::default()
        },
        executors: 0,
        quant: None,
        shard_batches: false,
    })
    .unwrap();

    // wave 1 saturates the lane and fills the deferred queue (40
    // oversize single-request batches against a depth-1 lane: even a
    // fast host cannot drain them before wave 2) …
    let mut handles = Vec::new();
    for i in 0..40u64 {
        handles.push(coord.submit("mnist", 4, 100 + i).unwrap());
    }
    std::thread::sleep(Duration::from_millis(20));
    // … wave 2 arrives against a full deferral budget
    for i in 0..16u64 {
        handles.push(coord.submit("mnist", 4, 200 + i).unwrap());
    }

    let mut ok = 0u64;
    let mut rejected = 0u64;
    for h in handles {
        match h.wait() {
            Ok(resp) => {
                assert!(resp.images.numel() > 0);
                ok += 1;
            }
            Err(_) => rejected += 1,
        }
    }
    assert!(rejected > 0, "flood against admit_max_deferred=2 must reject");
    assert!(ok > 0, "survivors must still be served");

    let report = coord.report();
    assert_eq!(report.rejected, rejected, "report counts the rejections");
    assert!(report.deferred > 0, "backpressure deferrals observed");
    // lane telemetry: dispatch-time depth never exceeded the bound
    assert!(!report.lanes.is_empty());
    for lane in &report.lanes {
        assert!(
            lane.max_depth <= 1,
            "{}: queue depth bound violated ({})",
            lane.name,
            lane.max_depth
        );
        assert!(lane.dispatches > 0);
    }
}

/// Bursty two-network traffic through one depth-bounded lane: the
/// deferred queue must drain FIFO per network (exec_seq non-decreasing
/// in submission order) and neither network may starve.
#[test]
fn deferred_drain_order_and_no_starvation_across_networks() {
    let dir = synthetic_dir();
    let coord = Coordinator::start(CoordinatorConfig {
        artifacts_dir: dir.path().to_path_buf(),
        networks: vec!["mnist".to_string()],
        batcher: BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
        },
        backends: BackendCfg {
            kinds: vec![DeviceKind::Fpga],
            max_queue_depth: 1,
            // starvation test: everything must eventually be served
            admit_max_deferred: 10_000,
            ..Default::default()
        },
        executors: 0,
        quant: Some(QFormat::new(16, 8)),
        shard_batches: false,
    })
    .unwrap();

    // a bursty scenario over the f32 network and its .q twin, driven
    // as fast as the trace allows (timestamps compressed to zero gap)
    let mut scenario = Scenario::builtin("burst").unwrap();
    scenario.requests = 48;
    let trace = Trace::generate(&scenario).unwrap();
    let mut handles = Vec::new();
    for e in &trace.events {
        handles.push((
            e.network.clone(),
            coord.submit(&e.network, e.n_images, e.seed).unwrap(),
        ));
    }

    let mut per_network: BTreeMap<String, Vec<(u64, u64)>> = BTreeMap::new();
    for (network, h) in handles {
        let resp = h.wait().expect("no rejections at this deferral budget");
        per_network
            .entry(network)
            .or_default()
            .push((resp.id, resp.exec_seq));
    }
    assert_eq!(per_network.len(), 2, "both networks present in the mix");
    for (network, mut seen) in per_network {
        assert!(
            !seen.is_empty(),
            "{network}: starved under burst + backpressure"
        );
        // submission order = id order; deferred batches must drain FIFO
        seen.sort_by_key(|(id, _)| *id);
        for pair in seen.windows(2) {
            assert!(
                pair[0].1 <= pair[1].1,
                "{network}: request {} (seq {}) overtook request {} (seq {})",
                pair[1].0,
                pair[1].1,
                pair[0].0,
                pair[0].1,
            );
        }
    }

    let report = coord.report();
    assert_eq!(report.rejected, 0);
    assert!(
        report.deferred > 0,
        "a depth-1 lane under burst traffic must defer"
    );
}
