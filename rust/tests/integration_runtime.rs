//! Runtime integration — the heart of the three-layer claim: the AOT
//! artifact (Pallas reverse-loop kernel → JAX generator → HLO text)
//! executed through PJRT must agree with the independent pure-Rust
//! reverse-loop forward, weight file by weight file.
//!
//! These tests skip (with a notice) when `make artifacts` has not run.

use edgedcnn::artifacts::artifacts_or_skip;
use edgedcnn::deconv::generator_forward;
use edgedcnn::runtime::Runtime;
use edgedcnn::tensor::Tensor;
use edgedcnn::util::Rng;

#[test]
fn pjrt_generator_matches_rust_forward_mnist() {
    let Some(artifacts) = artifacts_or_skip() else { return };
    let runtime = Runtime::cpu().expect("PJRT CPU client");
    let exe = runtime.load_generator(&artifacts, "mnist", 1).unwrap();
    let weights = artifacts.load_weights("mnist").unwrap();
    let net = artifacts.network_cfg("mnist").unwrap();
    let mut rng = Rng::seed_from_u64(17);
    let z = Tensor::from_fn(vec![1, net.z_dim], |_| rng.normal_f32());
    let via_pjrt = exe.generate(&z, &weights).unwrap();
    let via_rust = generator_forward(&net, &weights, &z);
    let diff = via_pjrt.max_abs_diff(&via_rust);
    assert!(
        diff < 2e-3,
        "PJRT artifact and Rust substrate disagree: max|Δ| = {diff}"
    );
}

#[test]
fn pjrt_generator_matches_rust_forward_celeba() {
    let Some(artifacts) = artifacts_or_skip() else { return };
    let runtime = Runtime::cpu().expect("PJRT CPU client");
    let exe = runtime.load_generator(&artifacts, "celeba", 1).unwrap();
    let weights = artifacts.load_weights("celeba").unwrap();
    let net = artifacts.network_cfg("celeba").unwrap();
    let mut rng = Rng::seed_from_u64(23);
    let z = Tensor::from_fn(vec![1, net.z_dim], |_| rng.normal_f32());
    let via_pjrt = exe.generate(&z, &weights).unwrap();
    let via_rust = generator_forward(&net, &weights, &z);
    assert_eq!(via_pjrt.shape(), &[1, 3, 64, 64]);
    let diff = via_pjrt.max_abs_diff(&via_rust);
    assert!(diff < 2e-3, "max|Δ| = {diff}");
}

#[test]
fn batch_buckets_agree_with_each_other() {
    let Some(artifacts) = artifacts_or_skip() else { return };
    let runtime = Runtime::cpu().unwrap();
    let weights = artifacts.load_weights("mnist").unwrap();
    let net = artifacts.network_cfg("mnist").unwrap();
    let e1 = runtime.load_generator(&artifacts, "mnist", 1).unwrap();
    let e4 = runtime.load_generator(&artifacts, "mnist", 4).unwrap();
    assert_eq!(e1.batch, 1);
    assert_eq!(e4.batch, 4);
    let mut rng = Rng::seed_from_u64(29);
    let z4 = Tensor::from_fn(vec![4, net.z_dim], |_| rng.normal_f32());
    let out4 = e4.generate(&z4, &weights).unwrap();
    // row 2 of the batch-4 run == batch-1 run of the same latent
    let z1 = Tensor::new(
        vec![1, net.z_dim],
        z4.data()[2 * net.z_dim..3 * net.z_dim].to_vec(),
    )
    .unwrap();
    let out1 = e1.generate(&z1, &weights).unwrap();
    let numel = 28 * 28;
    let got = &out4.data()[2 * numel..3 * numel];
    let want = &out1.data()[..numel];
    let diff = got
        .iter()
        .zip(want)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(diff < 1e-4, "bucket inconsistency: {diff}");
}

#[test]
fn per_layer_artifacts_load_and_execute() {
    let Some(artifacts) = artifacts_or_skip() else { return };
    if !edgedcnn::runtime::has_pjrt() {
        eprintln!("(skipping: single-layer HLO execution needs `pjrt`)");
        return;
    }
    let runtime = Runtime::cpu().unwrap();
    for name in ["mnist", "celeba"] {
        let net = artifacts.network_cfg(name).unwrap();
        let manifest = artifacts.network(name).unwrap();
        for (i, layer) in net.layers.iter().enumerate() {
            let path = artifacts.layer_hlo(name, i).unwrap();
            let hlo = runtime.load_hlo(&path).unwrap();
            let mut rng = Rng::seed_from_u64(i as u64);
            let x = Tensor::from_fn(
                vec![1, layer.c_in, layer.i_h, layer.i_h],
                |_| rng.range_f32(-1.0, 1.0),
            );
            let w = Tensor::from_fn(
                vec![layer.c_in, layer.c_out, layer.k, layer.k],
                |_| 0.05 * rng.normal_f32(),
            );
            let b = vec![0.0f32; layer.c_out];
            let inputs = vec![
                edgedcnn::runtime::tensor_to_literal(&x).unwrap(),
                edgedcnn::runtime::tensor_to_literal(&w).unwrap(),
                edgedcnn::runtime::data_to_literal(&b, &[layer.c_out])
                    .unwrap(),
            ];
            let out = hlo
                .run_to_tensor(
                    &inputs,
                    vec![1, layer.c_out, layer.o_h(), layer.o_h()],
                )
                .unwrap();
            // activation applied: relu (mid layers) or tanh (last)
            let last = i == net.layers.len() - 1;
            for v in out.data() {
                if last {
                    assert!(v.abs() <= 1.0);
                } else {
                    assert!(*v >= 0.0);
                }
            }
            // cross-check numerics against the Rust reverse-loop + act
            let (mut want, _) = edgedcnn::deconv::deconv_reverse_loop(
                &x,
                &w,
                &b,
                layer.stride,
                layer.padding,
                edgedcnn::deconv::ReverseLoopOpts {
                    tile: net.tile,
                    zero_skip: false,
                },
            );
            for v in want.data_mut().iter_mut() {
                *v = if last { v.tanh() } else { v.max(0.0) };
            }
            let diff = out.max_abs_diff(&want);
            assert!(diff < 2e-3, "{name} L{i}: max|Δ| = {diff}");
        }
        let _ = manifest; // silence unused in case of future trims
    }
}

#[test]
fn truth_batch_has_declared_geometry() {
    let Some(artifacts) = artifacts_or_skip() else { return };
    for name in ["mnist", "celeba"] {
        let net = artifacts.network(name).unwrap();
        let truth = artifacts.load_truth(name).unwrap();
        assert_eq!(truth.shape()[1], net.image_channels);
        assert_eq!(truth.shape()[2], net.image_size);
        assert_eq!(truth.shape()[3], net.image_size);
        assert!(truth.shape()[0] >= 64, "need enough P_g samples for MMD");
        // [-1, 1] normalized
        assert!(truth.data().iter().all(|v| v.abs() <= 1.0 + 1e-6));
    }
}
