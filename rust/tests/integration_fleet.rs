//! Fleet integration: a multi-site coordinator fleet replaying one
//! recorded trace end to end against a synthetic artifact set.  Pins
//! the ISSUE-level acceptance claims: (1) a 3-site fleet's merged
//! [`ServingReport`] is **bit-identical** to folding the same per-site
//! telemetry shards directly (and any association order agrees on every
//! counter/quantile, with float-derived columns equal to rounding);
//! (2) cross-site overflow spill engages under a flash crowd with the
//! `submitted = served + shed + rejected + lost` accounting intact;
//! (3) the versioned JSON report schema round-trips; (4) a mid-run site
//! failure goes drain-then-dark and the fold still closes; (5) a
//! spilled request's two-site lifecycle re-bases onto one monotone
//! fleet timeline under seeded clock skew, and the fleet Chrome-trace
//! export splices the hop in as a flow-event pair.

use edgedcnn::artifacts::write_synthetic;
use edgedcnn::config::{BackendCfg, DeviceKind};
use edgedcnn::coordinator::ServingReport;
use edgedcnn::fleet::{fold_shards, run_fleet, FleetCfg};
use edgedcnn::util::{parse_json, TempDir};
use edgedcnn::workload::{Scenario, Trace};

fn synthetic_dir() -> TempDir {
    let dir = TempDir::new().unwrap();
    write_synthetic(dir.path(), &["mnist"], 2, 17).unwrap();
    dir
}

/// Equal to floating-point rounding: merge order may legally reorder
/// f64 summation, so derived columns (means, CVs) agree to ulps, not
/// necessarily bits.
fn close(a: f64, b: f64, what: &str) {
    let tol = 1e-9 * (a.abs() + b.abs() + 1.0);
    assert!((a - b).abs() <= tol, "{what}: {a} vs {b}");
}

/// The headline acceptance run: a recorded steady trace fanned over
/// three sites; the fleet report must *be* the fold of the per-site
/// shards, bit-identically, and the schema must round-trip.
#[test]
fn three_site_fleet_on_a_recorded_trace_folds_bit_identically() {
    let dir = synthetic_dir();
    let mut scenario = Scenario::builtin("steady").unwrap();
    scenario.requests = 36;
    let generated = Trace::generate(&scenario).unwrap();
    let trace_path = dir.path().join("trace.json");
    generated.save(&trace_path).unwrap();
    // the fleet replays the *recorded* trace, as a driver box would
    let trace = Trace::load(&trace_path).unwrap();
    assert_eq!(trace, generated, "record → replay is exact");

    let run = run_fleet(
        &trace,
        &FleetCfg {
            artifacts_dir: dir.path().to_path_buf(),
            sites: 3,
            skew_s: 0.002,
            seed: 7,
            ..Default::default()
        },
    )
    .unwrap();

    // every submission reaches exactly one terminal outcome
    assert_eq!(run.submitted, 36);
    assert_eq!(
        run.submitted,
        run.served + run.shed + run.rejected + run.lost,
        "accounting must close"
    );
    assert!(run.served > 0, "steady load at a 50 ms deadline must serve");
    assert_eq!(run.shards.len(), 3);
    let placed: u64 = run.sites.iter().map(|s| s.placed).sum();
    assert_eq!(placed, run.submitted, "each event has one home site");
    assert!(
        run.sites.iter().filter(|s| s.placed > 0).count() >= 2,
        "hash placement must spread the trace across sites: {:?}",
        run.sites
    );

    // (1a) merged fleet report == direct fold of the shards, bit-exact
    let direct = fold_shards(&run.shards).report();
    assert_eq!(
        direct.to_json(),
        run.report.to_json(),
        "fleet report is the direct shard fold"
    );
    // (1b) pairwise left fold == direct fold: merging into an empty
    // registry is lossless, so both run the same f64 op sequence
    let mut ab = run.shards[0].clone();
    ab.merge_from(&run.shards[1]);
    ab.merge_from(&run.shards[2]);
    assert_eq!(
        ab.report().to_json(),
        run.report.to_json(),
        "fold(fold(a,b),c) == direct aggregate, bit-identical"
    );

    // (1c) the opposite association: counters, quantiles and extremes
    // are set/sum-monoid exact in any order; float-derived columns
    // agree to rounding (f64 summation reorders)
    let mut bc = run.shards[1].clone();
    bc.merge_from(&run.shards[2]);
    let mut right = run.shards[0].clone();
    right.merge_from(&bc);
    let r = right.report();
    let d = &run.report;
    assert_eq!(r.requests, d.requests);
    assert_eq!(r.images, d.images);
    assert_eq!(r.batches, d.batches);
    assert_eq!(r.rejected, d.rejected);
    assert_eq!(r.shed, d.shed);
    assert_eq!(r.deferred, d.deferred);
    assert_eq!(r.wall_s, d.wall_s, "wall is a max: order-exact");
    assert_eq!(
        [r.latency.p50_s, r.latency.p95_s, r.latency.p99_s, r.latency.p999_s],
        [d.latency.p50_s, d.latency.p95_s, d.latency.p99_s, d.latency.p999_s],
        "histogram quantiles are bucket-count exact in any fold order"
    );
    assert_eq!(r.latency_drift, d.latency_drift);
    close(r.latency.mean_s, d.latency.mean_s, "mean_s");
    assert_eq!(r.per_backend.len(), d.per_backend.len());
    for (rb, db) in r.per_backend.iter().zip(&d.per_backend) {
        assert_eq!(rb.name, db.name);
        assert_eq!(rb.batches, db.batches);
        assert_eq!(rb.images, db.images);
        assert_eq!(rb.deadline, db.deadline);
        assert_eq!([rb.p50_s, rb.p99_s], [db.p50_s, db.p99_s]);
        close(
            rb.mean_device_latency_s,
            db.mean_device_latency_s,
            &format!("{} mean_device_latency_s", rb.name),
        );
        close(rb.latency_cv, db.latency_cv, &format!("{} cv", rb.name));
    }

    // (3) the versioned schema round-trips the merged report bit-exact
    let back = ServingReport::from_json(&run.report.to_json()).unwrap();
    assert_eq!(back, run.report, "schema v1 roundtrip");

    // per-site columns stay distinguishable after the fold
    assert!(!run.report.per_backend.is_empty());
    assert!(run.report.per_backend.iter().all(|b| {
        ["s0/", "s1/", "s2/"].iter().any(|p| b.name.starts_with(p))
    }));
}

/// Flash crowd against deliberately tiny per-site capacity: home sites
/// deny (reject on a depth-1 lane behind a defer-1 budget), the front
/// tier spills to the next site in preference order, and the terminal
/// accounting still closes — a spilled request is counted exactly once.
#[test]
fn flash_crowd_spills_cross_site_and_accounting_stays_closed() {
    let dir = synthetic_dir();
    let mut scenario = Scenario::builtin("flash").unwrap();
    scenario.requests = 48;
    let trace = Trace::generate(&scenario).unwrap();

    let run = run_fleet(
        &trace,
        &FleetCfg {
            artifacts_dir: dir.path().to_path_buf(),
            sites: 3,
            backends: BackendCfg {
                kinds: vec![DeviceKind::Fpga],
                max_queue_depth: 1,
                admit_max_deferred: 1,
                ..Default::default()
            },
            seed: 11,
            ..Default::default()
        },
    )
    .unwrap();

    assert_eq!(run.submitted, 48);
    assert_eq!(
        run.submitted,
        run.served + run.shed + run.rejected + run.lost,
        "spilling must not double- or under-count: {run:?}"
    );
    assert_eq!(run.lost, 0, "no site died: nothing may read as lost");
    assert!(
        run.spilled > 0,
        "a 2000 Hz spike against depth-1/defer-1 sites must overflow \
         cross-site (spilled {}, shed {}, rejected {})",
        run.spilled,
        run.shed,
        run.rejected
    );
    let hops: u64 = run.sites.iter().map(|s| s.spilled_in).sum();
    assert!(
        hops >= run.spilled,
        "every spilled request made >= 1 cross-site hop ({hops} hops, \
         {} spilled)",
        run.spilled
    );
    assert!(run.spill_served <= run.spilled);
    assert!(run.spill_served <= run.served);

    // the fleet JSON envelope carries the spill accounting verbatim
    let v = parse_json(&run.to_json()).unwrap();
    assert_eq!(v.req("version").unwrap().as_u64().unwrap(), 1);
    assert_eq!(
        v.req("submitted").unwrap().as_u64().unwrap(),
        run.submitted
    );
    assert_eq!(v.req("spilled").unwrap().as_u64().unwrap(), run.spilled);
    assert_eq!(
        v.req("spill_served").unwrap().as_u64().unwrap(),
        run.spill_served
    );
    assert_eq!(v.req("sites").unwrap().as_arr().unwrap().len(), 3);
    let report = v.req("report").unwrap();
    assert_eq!(report.req("version").unwrap().as_u64().unwrap(), 1);
}

/// The flight-recorder acceptance claim: with deliberately skewed site
/// clocks, a served spilled request's two-site lifecycle re-bases onto
/// ONE monotone fleet timeline — home-site intake before every
/// landing-site stamp, landing stamps in lifecycle order — and the
/// fleet Chrome trace export renders the hop as a flow-event pair
/// between the site tracks.
#[test]
fn spilled_lifecycle_rebases_onto_a_monotone_two_site_timeline() {
    let dir = synthetic_dir();
    let mut scenario = Scenario::builtin("flash").unwrap();
    scenario.requests = 48;
    let trace = Trace::generate(&scenario).unwrap();

    // flash against depth-1/defer-1 sites forces spills, but only a
    // spilled request that is also *served* carries the full two-site
    // timeline — retry a few fleet seeds until one completes
    let mut picked = None;
    for seed in [11u64, 13, 29] {
        let run = run_fleet(
            &trace,
            &FleetCfg {
                artifacts_dir: dir.path().to_path_buf(),
                sites: 3,
                skew_s: 0.004,
                backends: BackendCfg {
                    kinds: vec![DeviceKind::Fpga],
                    max_queue_depth: 1,
                    admit_max_deferred: 1,
                    ..Default::default()
                },
                seed,
                ..Default::default()
            },
        )
        .unwrap();
        assert!(run.spilled > 0, "flash vs depth-1 sites must spill");
        if !run.spill_stamps.is_empty() {
            picked = Some(run);
            break;
        }
    }
    let run = picked
        .expect("three flash seeds served no spilled request end to end");
    assert!(run.spill_served > 0);

    for s in &run.spill_stamps {
        assert!(s.spilled() && s.complete(), "examples are full spills");
        assert_ne!(s.site, s.prev_site, "the hop crossed sites");
        let home_ingest = s.rebased_prev_ingest().unwrap();
        let starts = s.rebased_starts().unwrap();
        // skew-corrected ordering: scheduled arrival, then the home
        // hop's intake, then the entire landing-site lifecycle
        assert!(
            starts[0] <= home_ingest,
            "arrival {} must precede home intake {home_ingest}",
            starts[0]
        );
        assert!(
            home_ingest <= starts[1],
            "home intake {home_ingest} must precede landing ingest {}",
            starts[1]
        );
        for w in starts[1..].windows(2) {
            assert!(
                w[0] <= w[1] + 1e-12,
                "landing timeline must stay monotone: {starts:?}"
            );
        }
        // stage spans still telescope arrival -> reply (same-site
        // differences, so the site skews cancel out of the sum)
        let spans = s.stage_spans().unwrap();
        let total: f64 = spans.iter().sum();
        close(total, s.reply_s - s.arrival_s, "spill spans telescope");
    }

    // the fleet trace export splices the hop in as a flow pair
    let json = run.chrome_trace();
    let v = parse_json(&json).expect("fleet trace must be valid JSON");
    let evs = v.req("traceEvents").unwrap().as_arr().unwrap();
    for ph in ["s", "f"] {
        assert!(
            evs.iter()
                .any(|e| e.req("ph").unwrap().as_str().unwrap() == ph),
            "fleet trace must carry a \"{ph}\" flow event for the spill"
        );
    }
    assert!(
        evs.iter().any(|e| {
            e.req("name").unwrap().as_str().unwrap() == "spill_origin"
        }),
        "the home hop renders a spill_origin slice"
    );
}

/// The site-failure scenario: one site fail-stops mid-run
/// (drain-then-dark), its hash range re-places onto the survivors, its
/// drained telemetry shard still folds, and accounting closes.
#[test]
fn mid_run_site_failure_goes_dark_and_the_fold_still_closes() {
    let dir = synthetic_dir();
    let mut scenario = Scenario::builtin("steady").unwrap();
    scenario.requests = 36;
    let trace = Trace::generate(&scenario).unwrap();

    let run = run_fleet(
        &trace,
        &FleetCfg {
            artifacts_dir: dir.path().to_path_buf(),
            sites: 3,
            fail_site: Some(0),
            fail_at_s: 0.05,
            seed: 7,
            ..Default::default()
        },
    )
    .unwrap();

    assert!(run.sites[0].dark, "site 0 must have fail-stopped");
    assert!(!run.sites[1].dark && !run.sites[2].dark);
    assert_eq!(
        run.shards.len(),
        3,
        "the dark site's drained shard is still folded"
    );
    assert_eq!(run.submitted, 36);
    assert_eq!(
        run.submitted,
        run.served + run.shed + run.rejected + run.lost,
        "accounting closes across the failure: {run:?}"
    );
    assert!(run.served > 0, "survivors keep serving");
    assert!(
        run.sites[1].placed + run.sites[2].placed > 0,
        "the dead site's hash range re-placed onto the survivors"
    );
    assert_eq!(
        fold_shards(&run.shards).report().to_json(),
        run.report.to_json(),
        "fold stays bit-identical with a dark shard in the mix"
    );
}
