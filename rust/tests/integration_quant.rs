//! Quantized-path integration: the fixed-point generator end to end
//! (reverse-loop kernels → scale epilogue → FPGA-simulated datapath),
//! the artifact export/import roundtrip, and the coordinator serving a
//! quantized twin side by side with f32 — all on a synthetic artifact
//! set, no Python build layer required.

use edgedcnn::artifacts::{export_quantized, write_synthetic};
use edgedcnn::config::{network_by_name, Precision, QFormat, PYNQ_Z2};
use edgedcnn::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, WorkloadSpec,
};
use edgedcnn::deconv::generator_forward;
use edgedcnn::experiments::{run_quant_error, QuantErrorData};
use edgedcnn::fpga::{simulate_network, SimOpts};
use edgedcnn::quant::{psnr_db, QuantizedGenerator, Rounding};
use edgedcnn::tensor::Tensor;
use edgedcnn::util::{Rng, TempDir, WorkerPool};
use std::time::Duration;

#[test]
fn quantized_generator_end_to_end_matches_f32_closely() {
    let dir = TempDir::new().unwrap();
    let artifacts = write_synthetic(dir.path(), &["mnist"], 4, 17).unwrap();
    let net = network_by_name("mnist").unwrap();
    let weights = artifacts.load_weights("mnist").unwrap();
    let mut rng = Rng::seed_from_u64(23);
    let z = Tensor::from_fn(vec![4, net.z_dim], |_| rng.normal_f32());
    let reference = generator_forward(&net, &weights, &z);

    let pool = WorkerPool::new(4);
    let gen = QuantizedGenerator::quantize(
        QFormat::new(16, 12),
        &weights,
        Rounding::Nearest,
    )
    .unwrap();
    let (images, stats) = gen.generate(&net, &z, &pool);
    assert_eq!(images.shape(), &[4, 1, 28, 28]);
    assert_eq!(stats.len(), net.layers.len());
    // tanh range (up to one quantization step over)
    assert!(images.data().iter().all(|v| v.abs() <= 1.001));
    // close to the f32 path on a fine format
    let psnr = psnr_db(&reference, &images, 2.0);
    assert!(psnr > 10.0, "q4.12 end-to-end PSNR too low: {psnr:.1} dB");
    // deterministic at any pool width (bit-identical parallel kernel)
    let (serial, _) = gen.generate(&net, &z, &WorkerPool::new(1));
    assert_eq!(serial.data(), images.data(), "pool width must not matter");
}

#[test]
fn quantized_weights_roundtrip_through_artifacts() {
    let dir = TempDir::new().unwrap();
    let artifacts = write_synthetic(dir.path(), &["mnist"], 2, 9).unwrap();
    let weights = artifacts.load_weights("mnist").unwrap();
    let fmt = QFormat::new(16, 8);
    let gen =
        QuantizedGenerator::quantize(fmt, &weights, Rounding::Nearest).unwrap();
    export_quantized(dir.path(), "mnist", &gen).unwrap();

    let (got_fmt, raw) = artifacts.load_quantized("mnist").unwrap();
    assert_eq!(got_fmt, fmt);
    let back = QuantizedGenerator::from_raw(got_fmt, &raw).unwrap();
    // bit-exact generation after the disk roundtrip
    let net = network_by_name("mnist").unwrap();
    let mut rng = Rng::seed_from_u64(5);
    let z = Tensor::from_fn(vec![2, net.z_dim], |_| rng.normal_f32());
    let pool = WorkerPool::new(2);
    let (a, _) = gen.generate(&net, &z, &pool);
    let (b, _) = back.generate(&net, &z, &pool);
    assert_eq!(a.data(), b.data());
}

#[test]
fn quant_error_sweep_psnr_improves_with_fraction_bits() {
    let dir = TempDir::new().unwrap();
    let artifacts = write_synthetic(dir.path(), &["mnist"], 8, 41).unwrap();
    let formats = vec![
        QFormat::new(16, 4),
        QFormat::new(16, 8),
        QFormat::new(16, 12),
        QFormat::new(32, 16),
    ];
    let data: QuantErrorData =
        run_quant_error("mnist", &PYNQ_Z2, &artifacts, &formats, 8, 3).unwrap();
    assert_eq!(data.points.len(), 4);
    let p4 = data.points[0].psnr_db;
    let p12 = data.points[2].psnr_db;
    let p16 = data.points[3].psnr_db;
    assert!(p12 > p4, "more fraction bits must help: {p4:.1} vs {p12:.1}");
    assert!(p16 >= p12, "q16.16 at least as good: {p12:.1} vs {p16:.1}");
    // 16-bit datapaths simulate faster than f32; 32-bit ties f32 widths
    assert!(data.points[1].fpga_time_s < data.f32_time_s);
    assert!(data.points[1].fpga_gops_per_w > data.f32_gops_per_w);
}

#[test]
fn fpga_simulator_models_the_quantized_network_datapath() {
    let net = network_by_name("mnist").unwrap();
    let f32_opts: Vec<SimOpts> =
        net.layers.iter().map(|_| SimOpts::dense(net.tile)).collect();
    let q_opts: Vec<SimOpts> = net
        .layers
        .iter()
        .map(|_| {
            SimOpts::dense_at(
                net.tile,
                Precision::Fixed(QFormat::new(16, 8)),
            )
        })
        .collect();
    let f = simulate_network(&net, &PYNQ_Z2, &f32_opts);
    let q = simulate_network(&net, &PYNQ_Z2, &q_opts);
    assert_eq!(q.total_ops, f.total_ops, "workload is precision-independent");
    assert!(q.total_time_s < f.total_time_s, "q8.8 must be faster");
    assert!(q.gops_per_w > f.gops_per_w, "and more efficient per watt");
}

fn quant_coordinator(dir: &TempDir, shard: bool, executors: usize) -> Coordinator {
    Coordinator::start(CoordinatorConfig {
        artifacts_dir: dir.path().to_path_buf(),
        networks: vec!["mnist".to_string()],
        batcher: BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(2),
        },
        executors,
        quant: Some(QFormat::new(16, 10)),
        shard_batches: shard,
        ..Default::default()
    })
    .expect("coordinator startup")
}

#[test]
fn coordinator_serves_quantized_twin_side_by_side() {
    let dir = TempDir::new().unwrap();
    write_synthetic(dir.path(), &["mnist"], 4, 77).expect("synthetic set");
    let coord = quant_coordinator(&dir, false, 2);
    // f32 and quantized twins answer concurrently
    let hf = coord.request("mnist").images(2).seed(4242).submit().unwrap();
    let hq = coord.request("mnist.q").images(2).seed(4242).submit().unwrap();
    let f = hf.wait().unwrap();
    let q = hq.wait().unwrap();
    assert_eq!(f.images.shape(), &[2, 1, 28, 28]);
    assert_eq!(q.images.shape(), &[2, 1, 28, 28]);
    // same seed, same latents: the twins must agree closely (q6.10)
    let err = f.images.max_abs_diff(&q.images);
    assert!(err < 0.25, "quantized twin diverged: max|err|={err}");
    assert!(err > 0.0, "twins must not be literally identical");
    // quantized twin is annotated with the faster fixed-point datapath
    assert!(q.fpga_time_s < f.fpga_time_s, "q twin must simulate faster");
    // deterministic across repeats
    let q2 = coord.request("mnist.q").images(2).seed(4242).blocking().unwrap();
    assert_eq!(q.images.data(), q2.images.data());
}

#[test]
fn sharded_dispatch_preserves_per_request_images() {
    let dir = TempDir::new().unwrap();
    write_synthetic(dir.path(), &["mnist"], 4, 13).expect("synthetic set");
    // same synthetic set served by an unsharded and a sharded pool
    let plain = quant_coordinator(&dir, false, 2);
    let sharded = quant_coordinator(&dir, true, 3);

    for network in ["mnist", "mnist.q"] {
        // a burst that batches together, then shards across executors
        let hp: Vec<_> = (0..6)
            .map(|i| plain.request(network).images(1).seed(9000 + i).submit().unwrap())
            .collect();
        let hs: Vec<_> = (0..6)
            .map(|i| sharded.request(network).images(1).seed(9000 + i).submit().unwrap())
            .collect();
        let rp: Vec<_> = hp.into_iter().map(|h| h.wait().unwrap()).collect();
        let rs: Vec<_> = hs.into_iter().map(|h| h.wait().unwrap()).collect();
        for (a, b) in rp.iter().zip(&rs) {
            assert_eq!(
                a.images.data(),
                b.images.data(),
                "{network}: sharding must not change request numerics"
            );
        }
    }
    // the sharded workload path still reports consistently
    let report = sharded
        .serve_workload(&WorkloadSpec {
            network: "mnist".into(),
            requests: 8,
            images_per_request: 1,
            interarrival: Duration::ZERO,
            seed: 2,
        })
        .unwrap();
    assert_eq!(report.requests, 8);
    assert_eq!(report.images, 8);
    assert!(report.images_per_s > 0.0);
}
