//! Cross-algorithm integration: the three Rust deconvolution paths
//! (standard Eq. 1 scatter, reverse-loop Algorithm 1, TDC transform)
//! must agree on every layer geometry of the paper's two networks, and
//! the pure-Rust generator forward must behave like a generator.

use edgedcnn::config::{celeba, mnist, network_by_name};
use edgedcnn::deconv::{
    deconv_reverse_loop, deconv_standard, deconv_tdc, generator_forward,
    ReverseLoopOpts,
};
use edgedcnn::tensor::Tensor;
use edgedcnn::util::Rng;

fn rand_tensor(shape: Vec<usize>, rng: &mut Rng) -> Tensor {
    Tensor::from_fn(shape, |_| rng.range_f32(-1.0, 1.0))
}

#[test]
fn all_algorithms_agree_on_every_paper_layer() {
    let mut rng = Rng::seed_from_u64(99);
    for net in [mnist(), celeba()] {
        for layer in &net.layers {
            // shrink channel counts to keep the scalar loops fast while
            // preserving the spatial geometry (K, S, P, I_H)
            let c_in = layer.c_in.min(4);
            let c_out = layer.c_out.min(3);
            let x = rand_tensor(vec![1, c_in, layer.i_h, layer.i_h], &mut rng);
            let w = rand_tensor(vec![c_in, c_out, layer.k, layer.k], &mut rng);
            let b: Vec<f32> = (0..c_out).map(|i| i as f32 * 0.1).collect();
            let std = deconv_standard(&x, &w, &b, layer.stride, layer.padding);
            let (rev, stats) = deconv_reverse_loop(
                &x,
                &w,
                &b,
                layer.stride,
                layer.padding,
                ReverseLoopOpts {
                    tile: net.tile,
                    zero_skip: false,
                },
            );
            let tdc = deconv_tdc(&x, &w, &b, layer.stride, layer.padding);
            assert_eq!(
                std.shape(),
                &[1, c_out, layer.o_h(), layer.o_h()],
                "{}: output geometry",
                net.name
            );
            assert!(
                rev.max_abs_diff(&std) < 1e-4,
                "{}: reverse-loop disagrees on K={} S={} P={} I={}",
                net.name,
                layer.k,
                layer.stride,
                layer.padding,
                layer.i_h
            );
            assert!(tdc.max_abs_diff(&std) < 1e-4);
            assert!(stats.macs_issued > 0);
            // Enhancement 1: modulo cost is 2K, independent of the image
            assert_eq!(stats.modulo_ops, 2 * layer.k as u64);
        }
    }
}

#[test]
fn zero_skip_equals_dense_on_pruned_weights() {
    let mut rng = Rng::seed_from_u64(5);
    let x = rand_tensor(vec![2, 3, 6, 6], &mut rng);
    let mut w = rand_tensor(vec![3, 4, 4, 4], &mut rng);
    for (i, v) in w.data_mut().iter_mut().enumerate() {
        if i % 3 != 0 {
            *v = 0.0; // ~2/3 sparsity
        }
    }
    let b = vec![0.1, -0.1, 0.2, 0.0];
    let dense = deconv_standard(&x, &w, &b, 2, 1);
    let (skip, stats) = deconv_reverse_loop(
        &x,
        &w,
        &b,
        2,
        1,
        ReverseLoopOpts {
            tile: 8,
            zero_skip: true,
        },
    );
    assert!(skip.max_abs_diff(&dense) < 1e-5);
    assert!(stats.macs_skipped > stats.macs_issued, "mostly skipped");
}

#[test]
fn generator_forward_produces_tanh_bounded_images() {
    let net = network_by_name("mnist").unwrap();
    let mut rng = Rng::seed_from_u64(3);
    let weights: Vec<(Tensor, Vec<f32>)> = net
        .layers
        .iter()
        .map(|l| {
            (
                Tensor::from_fn(vec![l.c_in, l.c_out, l.k, l.k], |_| {
                    0.02 * rng.normal_f32()
                }),
                vec![0.0; l.c_out],
            )
        })
        .collect();
    let z = Tensor::from_fn(vec![2, net.z_dim], |_| rng.normal_f32());
    let img = generator_forward(&net, &weights, &z);
    assert_eq!(img.shape(), &[2, 1, 28, 28]);
    assert!(img.data().iter().all(|v| v.abs() <= 1.0), "tanh range");
    // different latents → different images
    let z2 = Tensor::from_fn(vec![2, net.z_dim], |_| rng.normal_f32());
    let img2 = generator_forward(&net, &weights, &z2);
    assert!(img.max_abs_diff(&img2) > 0.0);
}

#[test]
fn generator_forward_deterministic() {
    let net = network_by_name("mnist").unwrap();
    let mut rng = Rng::seed_from_u64(11);
    let weights: Vec<(Tensor, Vec<f32>)> = net
        .layers
        .iter()
        .map(|l| {
            (
                Tensor::from_fn(vec![l.c_in, l.c_out, l.k, l.k], |_| {
                    0.05 * rng.normal_f32()
                }),
                vec![0.01; l.c_out],
            )
        })
        .collect();
    let z = Tensor::from_fn(vec![1, net.z_dim], |_| rng.normal_f32());
    let a = generator_forward(&net, &weights, &z);
    let b = generator_forward(&net, &weights, &z);
    assert_eq!(a.data(), b.data());
}
