//! Property-based invariants (hand-rolled generator sweep; the offline
//! environment ships no proptest crate — `util::Rng` drives randomized
//! cases with printed-on-failure seeds instead).
//!
//! Each property runs a few hundred random cases over the coordinator
//! and algorithm state spaces.

use edgedcnn::backend::CostModel;
use edgedcnn::config::DeconvLayerCfg;
use edgedcnn::coordinator::{
    BatcherConfig, DynamicBatcher, InferenceRequest, PriorityClass,
    RequestCtx,
};
use edgedcnn::deconv::{
    deconv_reverse_loop, deconv_reverse_loop_par, deconv_standard,
    input_tile_extent, stride_hole_offsets, ReverseLoopOpts,
};
use edgedcnn::quant::{
    quantize_tensor, Element, Fixed, Q4_12, Q8_8, Rounding,
};
use edgedcnn::sparsity::{magnitude_prune, mmd_biased, Mmd};
use edgedcnn::tensor::{read_npy_f32, write_npy_f32, Tensor, TensorT};
use edgedcnn::util::{parse_json, Rng, TempDir, WorkerPool};
use std::time::{Duration, Instant};

const CASES: usize = 200;

/// Random legal layer geometry (kept small: the checks are O(n⁴) loops).
fn random_geometry(rng: &mut Rng) -> (usize, usize, usize, usize, usize, usize) {
    loop {
        let k = rng.range_usize(1, 6);
        let s = rng.range_usize(1, 4);
        let p = rng.range_usize(0, k.max(1));
        let i_h = rng.range_usize(1, 7);
        let c_in = rng.range_usize(1, 4);
        let c_out = rng.range_usize(1, 4);
        let o = (i_h - 1) * s + k;
        if o > 2 * p {
            return (c_in, c_out, k, s, p, i_h);
        }
    }
}

#[test]
fn prop_reverse_loop_equals_standard() {
    let mut rng = Rng::seed_from_u64(0xA11CE);
    for case in 0..CASES {
        let (c_in, c_out, k, s, p, i_h) = random_geometry(&mut rng);
        let tile = rng.range_usize(1, 12);
        let x = Tensor::from_fn(vec![1, c_in, i_h, i_h], |_| {
            rng.range_f32(-1.0, 1.0)
        });
        let w = Tensor::from_fn(vec![c_in, c_out, k, k], |_| {
            rng.range_f32(-1.0, 1.0)
        });
        let b: Vec<f32> = (0..c_out).map(|_| rng.range_f32(-0.5, 0.5)).collect();
        let want = deconv_standard(&x, &w, &b, s, p);
        let (got, stats) = deconv_reverse_loop(
            &x,
            &w,
            &b,
            s,
            p,
            ReverseLoopOpts {
                tile,
                zero_skip: rng.gen_bool(0.5),
            },
        );
        assert!(
            got.max_abs_diff(&want) < 1e-4,
            "case {case}: geometry ({c_in},{c_out},{k},{s},{p},{i_h}) tile {tile}"
        );
        // one-shot write invariant: every output element written once
        assert_eq!(stats.ext_write_bytes, 4 * want.numel() as u64);
    }
}

#[test]
fn prop_parallel_reverse_loop_bit_identical_to_serial() {
    // the spatio-temporal engine must be a pure accelerator: identical
    // tensors AND identical OpStats for random shapes, tiles, sparsity
    // patterns and pool widths
    let mut rng = Rng::seed_from_u64(0xBA11E1);
    for case in 0..CASES / 2 {
        let (c_in, c_out, k, s, p, i_h) = random_geometry(&mut rng);
        let tile = rng.range_usize(1, 12);
        let n = rng.range_usize(1, 3);
        let x = Tensor::from_fn(vec![n, c_in, i_h, i_h], |_| {
            rng.range_f32(-1.0, 1.0)
        });
        let mut w = Tensor::from_fn(vec![c_in, c_out, k, k], |_| {
            rng.range_f32(-1.0, 1.0)
        });
        // random exact zeros so zero-skipping has work to skip
        for v in w.data_mut().iter_mut() {
            if rng.gen_bool(0.3) {
                *v = 0.0;
            }
        }
        let b: Vec<f32> =
            (0..c_out).map(|_| rng.range_f32(-0.5, 0.5)).collect();
        let opts = ReverseLoopOpts {
            tile,
            zero_skip: rng.gen_bool(0.5),
        };
        let workers = rng.range_usize(2, 9);
        let (ys, ss) = deconv_reverse_loop(&x, &w, &b, s, p, opts);
        let pool = WorkerPool::new(workers);
        let (yp, sp) =
            deconv_reverse_loop_par(&x, &w, &b, s, p, opts, &pool);
        assert_eq!(
            ys.data(),
            yp.data(),
            "case {case}: ({c_in},{c_out},{k},{s},{p},{i_h}) tile {tile} \
             workers {workers}"
        );
        assert_eq!(ss, sp, "case {case}: OpStats must merge exactly");
    }
}

#[test]
fn prop_quantize_dequantize_error_bounded_by_step() {
    // |x - deq(quant(x))| ≤ 2^-F for every in-range input, at both a
    // coarse and a fine i16 format (nearest rounding actually achieves
    // 2^-(F+1); the asserted contract is the looser paper-level bound)
    let mut rng = Rng::seed_from_u64(0x0F1C);
    for case in 0..CASES {
        // stay inside the representable range so saturation (a scale
        // concern, handled by calibration) doesn't enter the bound
        let v8 = rng.range_f32(-100.0, 100.0);
        let q8 = Q8_8::from_f32(v8);
        assert!(
            (q8.to_f32() - v8).abs() <= 1.0 / 256.0 + 1e-6,
            "case {case}: Q8.8 v={v8} deq={}",
            q8.to_f32()
        );
        let v12 = rng.range_f32(-7.0, 7.0);
        let q12 = Q4_12::from_f32(v12);
        assert!(
            (q12.to_f32() - v12).abs() <= 1.0 / 4096.0 + 1e-6,
            "case {case}: Q4.12 v={v12} deq={}",
            q12.to_f32()
        );
        // truncation stays within one full step too
        let t = Fixed::<i16, 8>::from_f32_round(v8, Rounding::Truncate);
        assert!((t.to_f32() - v8).abs() < 1.0 / 256.0 + 1e-6);
    }
}

#[test]
fn prop_quantized_reverse_loop_bit_exact_vs_standard() {
    // the fixed-point twin of `prop_reverse_loop_equals_standard`, with
    // the tolerance tightened to *bit-for-bit equality*: the wide-
    // accumulator design makes the two loop orders produce identical
    // storage words, for random geometry, tiles, sparsity and pools
    let mut rng = Rng::seed_from_u64(0x0F2D);
    for case in 0..CASES / 2 {
        let (c_in, c_out, k, s, p, i_h) = random_geometry(&mut rng);
        let tile = rng.range_usize(1, 12);
        let xf = Tensor::from_fn(vec![1, c_in, i_h, i_h], |_| {
            rng.range_f32(-1.0, 1.0)
        });
        let mut wf = Tensor::from_fn(vec![c_in, c_out, k, k], |_| {
            rng.range_f32(-1.0, 1.0)
        });
        for v in wf.data_mut().iter_mut() {
            if rng.gen_bool(0.3) {
                *v = 0.0; // exact zeros → quantize to exact zeros
            }
        }
        let x: TensorT<Q8_8> = quantize_tensor::<i16, 8>(&xf, Rounding::Nearest);
        let w: TensorT<Q8_8> = quantize_tensor::<i16, 8>(&wf, Rounding::Nearest);
        let b: Vec<Q8_8> = (0..c_out)
            .map(|_| Q8_8::from_f32(rng.range_f32(-0.5, 0.5)))
            .collect();
        let want = deconv_standard(&x, &w, &b, s, p);
        let opts = ReverseLoopOpts {
            tile,
            zero_skip: rng.gen_bool(0.5),
        };
        let (got, stats) = deconv_reverse_loop(&x, &w, &b, s, p, opts);
        assert_eq!(
            got.data(),
            want.data(),
            "case {case}: ({c_in},{c_out},{k},{s},{p},{i_h}) tile {tile}"
        );
        // 2-byte one-shot writes
        assert_eq!(stats.ext_write_bytes, 2 * want.numel() as u64);
        // and the parallel path stays exact on the quantized tensors
        let pool = WorkerPool::new(rng.range_usize(2, 7));
        let (par, sp) = deconv_reverse_loop_par(&x, &w, &b, s, p, opts, &pool);
        assert_eq!(par.data(), got.data(), "case {case}: parallel quantized");
        assert_eq!(sp, stats, "case {case}: OpStats must merge exactly");
    }
}

#[test]
fn prop_offsets_solve_eq4_divisibility() {
    let mut rng = Rng::seed_from_u64(0xBEEF);
    for _ in 0..CASES {
        let k = rng.range_usize(1, 12);
        let s = rng.range_usize(1, 8);
        let p = rng.range_usize(0, 12);
        let f = stride_hole_offsets(k, s, p);
        for (kk, &fk) in f.iter().enumerate() {
            assert!(fk < s);
            assert_eq!(
                (fk as i64 + p as i64 - kk as i64).rem_euclid(s as i64),
                0
            );
            // minimality: no smaller offset satisfies the congruence
            for smaller in 0..fk {
                assert_ne!(
                    (smaller as i64 + p as i64 - kk as i64)
                        .rem_euclid(s as i64),
                    0
                );
            }
        }
    }
}

#[test]
fn prop_eq5_input_tile_covers_dependencies() {
    // Eq. 5's T_IH must cover every input index any output pixel of a
    // tile can reference
    let mut rng = Rng::seed_from_u64(0xCAFE);
    for _ in 0..CASES {
        let k = rng.range_usize(1, 8);
        let s = rng.range_usize(1, 5);
        let p = rng.range_usize(0, k);
        let t_oh = rng.range_usize(s, 33);
        let t_ih = input_tile_extent(t_oh, k, s);
        // worst-case span of i = (o + P - k')/S over one tile
        let o0 = 0i64;
        let mut min_i = i64::MAX;
        let mut max_i = i64::MIN;
        for o in o0..o0 + t_oh as i64 {
            for kk in 0..k as i64 {
                let num = o + p as i64 - kk;
                if num.rem_euclid(s as i64) == 0 {
                    let i = num.div_euclid(s as i64);
                    min_i = min_i.min(i);
                    max_i = max_i.max(i);
                }
            }
        }
        if min_i <= max_i {
            let span = (max_i - min_i + 1) as usize;
            assert!(
                span <= t_ih + 1,
                "Eq.5 tile too small: span {span} > T_IH {t_ih} \
                 (K={k} S={s} P={p} T={t_oh})"
            );
        }
    }
}

#[test]
fn prop_layer_op_accounting_consistent() {
    let mut rng = Rng::seed_from_u64(0xD00D);
    for _ in 0..CASES {
        let (c_in, c_out, k, s, p, i_h) = random_geometry(&mut rng);
        let layer = DeconvLayerCfg {
            c_in,
            c_out,
            k,
            stride: s,
            padding: p,
            i_h,
        };
        // taps formula == brute force count
        let o = layer.o_h();
        let f = layer.offsets();
        let mut brute = 0usize;
        for kh in 0..k {
            for kw in 0..k {
                brute += (f[kh]..o).step_by(s).count()
                    * (f[kw]..o).step_by(s).count();
            }
        }
        assert_eq!(layer.taps(), brute);
        assert_eq!(layer.ops(), 2 * layer.macs());
        // issued MACs of the dense reverse loop ≤ schedule trip count
        let x = Tensor::from_fn(vec![1, c_in, i_h, i_h], |_| 1.0);
        let w = Tensor::from_fn(vec![c_in, c_out, k, k], |_| 1.0);
        let (_, stats) = deconv_reverse_loop(
            &x,
            &w,
            &vec![0.0; c_out],
            s,
            p,
            ReverseLoopOpts {
                tile: 8,
                zero_skip: false,
            },
        );
        assert!(stats.macs_issued <= layer.macs());
    }
}

#[test]
fn prop_batcher_conserves_requests() {
    // no request is lost or duplicated, regardless of arrival pattern
    let mut rng = Rng::seed_from_u64(0xFEED);
    for case in 0..100 {
        let max_batch = rng.range_usize(1, 10);
        let mut b = DynamicBatcher::new(BatcherConfig {
            max_batch,
            max_wait: Duration::from_millis(rng.range_usize(0, 5) as u64),
        });
        let n_requests = rng.range_usize(1, 30);
        let t0 = Instant::now();
        let mut emitted: Vec<u64> = Vec::new();
        for id in 0..n_requests as u64 {
            let net = if rng.gen_bool(0.3) { "celeba" } else { "mnist" };
            let req =
                InferenceRequest::new(id, net, rng.range_usize(1, 5), id);
            if let Some(batch) = b.push(req, t0) {
                assert!(!batch.requests.is_empty());
                emitted.extend(batch.requests.iter().map(|r| r.id));
            }
        }
        // drain with an expired clock
        let later = t0 + Duration::from_secs(60);
        while let Some(batch) = b.poll(later) {
            emitted.extend(batch.requests.iter().map(|r| r.id));
        }
        emitted.sort_unstable();
        let expect: Vec<u64> = (0..n_requests as u64).collect();
        assert_eq!(emitted, expect, "case {case}: lost/duplicated requests");
        assert_eq!(b.queued(), 0);
    }
}

#[test]
fn prop_batcher_respects_bucket_unless_oversize() {
    let mut rng = Rng::seed_from_u64(0xB00);
    for _ in 0..100 {
        let max_batch = rng.range_usize(2, 9);
        let mut b = DynamicBatcher::new(BatcherConfig {
            max_batch,
            max_wait: Duration::from_millis(0),
        });
        let t0 = Instant::now();
        for id in 0..20u64 {
            let n = rng.range_usize(1, 2 * max_batch);
            let req = InferenceRequest::new(id, "mnist", n, id);
            let oversize = n > max_batch;
            if let Some(batch) = b.push(req, t0) {
                if !oversize && batch.requests.len() > 1 {
                    assert!(
                        batch.n_images <= max_batch,
                        "multi-request batch exceeded the bucket"
                    );
                }
            }
        }
    }
}

#[test]
fn prop_edf_cut_never_serves_feasible_after_infeasible_same_class() {
    // skip-over EDF: in every cut batch, a request that can still make
    // its deadline is never served after one (of the same priority
    // class) that already cannot — and feasible same-class requests
    // come out in deadline order.
    let mut rng = Rng::seed_from_u64(0xEDF0);
    let classes =
        [PriorityClass::High, PriorityClass::Normal, PriorityClass::Low];
    for case in 0..CASES {
        let max_batch = rng.range_usize(2, 9);
        let mut b = DynamicBatcher::new(BatcherConfig {
            max_batch,
            max_wait: Duration::from_millis(rng.range_usize(1, 50) as u64),
        });
        // constant-cost model (c1 == c8): the predicted batch cost is
        // the same at every batch size, so the test can recompute the
        // batcher's feasibility split exactly
        let cost_s = rng.range_f64(0.001, 0.030);
        b.set_cost_hint(
            "mnist",
            CostModel {
                c1_s: cost_s,
                c8_s: cost_s,
            },
        );
        let t0 = Instant::now();
        let n_requests = rng.range_usize(1, 25);
        let mut emitted: Vec<u64> = Vec::new();
        for id in 0..n_requests as u64 {
            let deadline = rng.gen_bool(0.8).then(|| {
                t0 + Duration::from_micros(rng.range_usize(1, 80_000) as u64)
            });
            let ctx = RequestCtx {
                arrival: t0,
                deadline,
                class: classes[rng.range_usize(0, classes.len())],
                seed: id,
                stamps: Default::default(),
            };
            if let Some(batch) =
                b.push(InferenceRequest::with_ctx(id, "mnist", 1, ctx), t0)
            {
                check_edf_batch(&batch, t0, cost_s, case);
                emitted.extend(batch.requests.iter().map(|r| r.id));
            }
        }
        // drain at a random later clock; every cut must satisfy the
        // property at *its* cut time
        let mut now = t0;
        while b.queued() > 0 {
            now += Duration::from_millis(rng.range_usize(1, 40) as u64);
            while let Some(batch) = b.poll(now) {
                check_edf_batch(&batch, now, cost_s, case);
                emitted.extend(batch.requests.iter().map(|r| r.id));
            }
        }
        // conservation still holds under EDF reordering
        emitted.sort_unstable();
        let full: Vec<u64> = (0..n_requests as u64).collect();
        assert_eq!(emitted, full, "case {case}: lost/duplicated requests");
    }
}

/// The per-batch EDF/skip-over assertions shared by push- and poll-side
/// cuts.
fn check_edf_batch(
    batch: &edgedcnn::coordinator::Batch,
    now: Instant,
    cost_s: f64,
    case: usize,
) {
    let feasible = |r: &InferenceRequest| match r.ctx.deadline {
        Some(d) => now + Duration::from_secs_f64(cost_s) <= d,
        None => true,
    };
    for (i, a) in batch.requests.iter().enumerate() {
        for b in &batch.requests[i + 1..] {
            if a.ctx.class == b.ctx.class {
                assert!(
                    feasible(a) || !feasible(b),
                    "case {case}: feasible request {} served after \
                     infeasible request {} of class {}",
                    b.id,
                    a.id,
                    a.ctx.class,
                );
                if let (Some(da), Some(db)) = (a.ctx.deadline, b.ctx.deadline)
                {
                    if feasible(a) && feasible(b) {
                        assert!(
                            da <= db,
                            "case {case}: same-class feasible requests out \
                             of deadline order ({} before {})",
                            a.id,
                            b.id,
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn prop_pruning_monotone_and_magnitude_correct() {
    let mut rng = Rng::seed_from_u64(0x9999);
    for _ in 0..100 {
        let n = rng.range_usize(4, 200);
        let base = Tensor::from_fn(vec![n], |_| rng.normal_f32());
        let f1 = rng.next_f64() * 0.5;
        let f2 = f1 + rng.next_f64() * 0.5;
        let mut a = base.clone();
        let mut b = base.clone();
        let za = magnitude_prune(&mut a, f1);
        let zb = magnitude_prune(&mut b, f2.min(1.0));
        assert!(zb >= za - 1e-9, "sparsity must be monotone in fraction");
        // heavier pruning zeroes a superset of elements
        for (va, vb) in a.data().iter().zip(b.data()) {
            if *va == 0.0 {
                assert_eq!(*vb, 0.0, "pruned sets must nest");
            }
        }
    }
}

#[test]
fn prop_mmd_symmetry_and_nonnegativity() {
    let mut rng = Rng::seed_from_u64(0xABCD);
    for _ in 0..40 {
        let d = rng.range_usize(2, 6);
        let n = rng.range_usize(3, 12);
        let m = rng.range_usize(3, 12);
        let x: Vec<f32> = (0..n * d).map(|_| rng.normal_f32()).collect();
        let y: Vec<f32> =
            (0..m * d).map(|_| rng.normal_f32() + 0.5).collect();
        let mmd = Mmd { sigma: rng.range_f64(0.5, 3.0) };
        let xy = mmd_biased(&x, &y, d, &mmd);
        let yx = mmd_biased(&y, &x, d, &mmd);
        assert!(xy >= 0.0);
        assert!((xy - yx).abs() < 1e-9, "MMD must be symmetric");
    }
}

#[test]
fn prop_npy_roundtrip_random_shapes() {
    let mut rng = Rng::seed_from_u64(0x4141);
    let dir = TempDir::new().unwrap();
    for case in 0..60 {
        let rank = rng.range_usize(1, 5);
        let shape: Vec<usize> =
            (0..rank).map(|_| rng.range_usize(1, 6)).collect();
        let numel: usize = shape.iter().product();
        let data: Vec<f32> = (0..numel).map(|_| rng.normal_f32()).collect();
        let path = dir.path().join(format!("t{case}.npy"));
        write_npy_f32(&path, &shape, &data).unwrap();
        let (s2, d2) = read_npy_f32(&path).unwrap();
        assert_eq!(s2, shape);
        assert_eq!(d2, data);
    }
}

/// Flight-recorder algebra: for ANY monotone boundary walk under ANY
/// site skews — spilled or not — the seven stage spans are non-negative
/// and telescope exactly to reply − arrival, the skew-corrected
/// timeline is monotone, and a spill's home intake lands between the
/// arrival and the landing-site ingest.
#[test]
fn prop_stage_spans_telescope_under_random_walks_and_skew() {
    use edgedcnn::telemetry::{RunClock, StageStamps};
    let mut rng = Rng::seed_from_u64(0xF11);
    let epoch = Instant::now();
    let at = |us: u64| epoch + Duration::from_micros(us);
    fn step(rng: &mut Rng, t: &mut u64) -> u64 {
        *t += 1 + rng.range_usize(0, 2000) as u64;
        *t
    }
    for case in 0..200u64 {
        let home = RunClock::with_site(epoch, rng.range_f64(-0.01, 0.01), 0);
        let land = RunClock::with_site(epoch, rng.range_f64(-0.01, 0.01), 1);
        let mut t = rng.range_usize(0, 1000) as u64;
        let arrival = at(t);
        let spilled = case % 3 == 0;
        let mut st = StageStamps::default();
        if spilled {
            // a denied home hop: ingest there, then re-ingest on the
            // landing site as the fleet's spill resubmission does
            let ti = step(&mut rng, &mut t);
            st.on_ingest(&home, arrival, at(ti), case);
        }
        let clock = if spilled { &land } else { &home };
        let ti = step(&mut rng, &mut t);
        st.on_ingest(clock, arrival, at(ti), case);
        st.on_admit(clock, at(step(&mut rng, &mut t)));
        st.on_cut(clock, at(step(&mut rng, &mut t)));
        st.on_dispatch(clock, at(step(&mut rng, &mut t)));
        st.on_exec_start(clock, at(step(&mut rng, &mut t)));
        st.on_exec_end(clock, at(step(&mut rng, &mut t)));
        st.on_reply(clock, at(step(&mut rng, &mut t)));

        assert!(st.complete(), "case {case}: all boundaries stamped");
        assert_eq!(st.spilled(), spilled, "case {case}");
        let spans = st.stage_spans().unwrap();
        assert!(spans.iter().all(|s| *s >= 0.0), "case {case}: {spans:?}");
        let total: f64 = spans.iter().sum();
        let e2e = st.reply_s - st.arrival_s;
        assert!(
            (total - e2e).abs() <= 1e-9 * (1.0 + e2e.abs()),
            "case {case}: spans must telescope: {total} vs {e2e}"
        );
        let starts = st.rebased_starts().unwrap();
        for w in starts.windows(2) {
            assert!(
                w[0] <= w[1] + 1e-12,
                "case {case}: rebased timeline monotone: {starts:?}"
            );
        }
        if let Some(prev) = st.rebased_prev_ingest() {
            assert!(
                starts[0] <= prev + 1e-12 && prev <= starts[1] + 1e-12,
                "case {case}: home intake {prev} must land between \
                 arrival {} and landing ingest {}",
                starts[0],
                starts[1]
            );
        }
    }
}

#[test]
fn prop_json_parses_generated_documents() {
    // generate random JSON-ish trees, print them, parse them back
    fn emit(rng: &mut Rng, depth: usize, out: &mut String) {
        if depth == 0 || rng.gen_bool(0.4) {
            match rng.range_usize(0, 4) {
                0 => out.push_str(&format!("{}", rng.range_usize(0, 1000))),
                1 => out.push_str(&format!("{:.3}", rng.normal_with(0.0, 5.0))),
                2 => out.push_str("\"s\""),
                _ => out.push_str(if rng.gen_bool(0.5) { "true" } else { "null" }),
            }
            return;
        }
        if rng.gen_bool(0.5) {
            out.push('[');
            let n = rng.range_usize(0, 4);
            for i in 0..n {
                if i > 0 {
                    out.push(',');
                }
                emit(rng, depth - 1, out);
            }
            out.push(']');
        } else {
            out.push('{');
            let n = rng.range_usize(0, 4);
            for i in 0..n {
                if i > 0 {
                    out.push(',');
                }
                out.push_str(&format!("\"k{i}\":"));
                emit(rng, depth - 1, out);
            }
            out.push('}');
        }
    }
    let mut rng = Rng::seed_from_u64(0x7777);
    for case in 0..200 {
        let mut doc = String::new();
        emit(&mut rng, 4, &mut doc);
        parse_json(&doc).unwrap_or_else(|e| {
            panic!("case {case}: failed to parse {doc:?}: {e:#}")
        });
    }
}
