//! Parallel execution engine integration: the spatio-temporal worker
//! pool must be a *pure* accelerator — bit-identical tensors and exact
//! `OpStats` against the serial paths at every layer of the stack
//! (reverse-loop substrate, generator forward, FPGA simulator), and the
//! coordinator's executor pool must serve correctly end to end on a
//! synthetic artifact set (no Python build layer required).

use edgedcnn::artifacts::write_synthetic;
use edgedcnn::config::{celeba, mnist, network_by_name, PYNQ_Z2};
use edgedcnn::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, RequestCtx, WorkloadSpec,
};
use edgedcnn::deconv::{
    deconv_reverse_loop, deconv_reverse_loop_par, generator_forward,
    generator_forward_par, ReverseLoopOpts,
};
use edgedcnn::fpga::{simulate_network, simulate_network_par, SimOpts};
use edgedcnn::tensor::Tensor;
use edgedcnn::util::{Rng, TempDir, WorkerPool};
use std::time::Duration;

#[test]
fn reverse_loop_parallel_equals_serial_on_paper_layers() {
    let mut rng = Rng::seed_from_u64(0x5EED);
    for net in [mnist(), celeba()] {
        for layer in &net.layers {
            // shrink channels to keep the scalar loops fast while
            // preserving the spatial geometry (K, S, P, I_H)
            let c_in = layer.c_in.min(4);
            let c_out = layer.c_out.min(3);
            let x = Tensor::from_fn(vec![2, c_in, layer.i_h, layer.i_h], |_| {
                rng.range_f32(-1.0, 1.0)
            });
            let mut w =
                Tensor::from_fn(vec![c_in, c_out, layer.k, layer.k], |_| {
                    rng.range_f32(-1.0, 1.0)
                });
            for (i, v) in w.data_mut().iter_mut().enumerate() {
                if i % 3 == 0 {
                    *v = 0.0; // exercise zero-skipping too
                }
            }
            let b: Vec<f32> = (0..c_out).map(|i| i as f32 * 0.1).collect();
            for zero_skip in [false, true] {
                let opts = ReverseLoopOpts {
                    tile: net.tile,
                    zero_skip,
                };
                let (ys, ss) = deconv_reverse_loop(
                    &x,
                    &w,
                    &b,
                    layer.stride,
                    layer.padding,
                    opts,
                );
                for workers in [2, 4, 7] {
                    let pool = WorkerPool::new(workers);
                    let (yp, sp) = deconv_reverse_loop_par(
                        &x,
                        &w,
                        &b,
                        layer.stride,
                        layer.padding,
                        opts,
                        &pool,
                    );
                    assert_eq!(
                        ys.data(),
                        yp.data(),
                        "{}: K={} S={} workers={workers} zs={zero_skip}",
                        net.name,
                        layer.k,
                        layer.stride
                    );
                    assert_eq!(ss, sp, "OpStats must merge exactly");
                }
            }
        }
    }
}

#[test]
fn generator_forward_parallel_is_bit_identical() {
    let net = network_by_name("mnist").unwrap();
    let mut rng = Rng::seed_from_u64(17);
    let weights: Vec<(Tensor, Vec<f32>)> = net
        .layers
        .iter()
        .map(|l| {
            (
                Tensor::from_fn(vec![l.c_in, l.c_out, l.k, l.k], |_| {
                    0.03 * rng.normal_f32()
                }),
                vec![0.0; l.c_out],
            )
        })
        .collect();
    let z = Tensor::from_fn(vec![2, net.z_dim], |_| rng.normal_f32());
    let serial = generator_forward(&net, &weights, &z);
    for workers in [2, 4] {
        let pool = WorkerPool::new(workers);
        let par = generator_forward_par(&net, &weights, &z, &pool);
        assert_eq!(serial.data(), par.data(), "workers={workers}");
    }
}

#[test]
fn fpga_simulator_parallel_sweep_is_exact() {
    for net in [mnist(), celeba()] {
        let opts: Vec<SimOpts> = net
            .layers
            .iter()
            .map(|_| SimOpts {
                zero_skip: true,
                weight_sparsity: 0.6,
                ..SimOpts::dense(net.tile)
            })
            .collect();
        let a = simulate_network(&net, &PYNQ_Z2, &opts);
        let pool = WorkerPool::new(4);
        let b = simulate_network_par(&net, &PYNQ_Z2, &opts, &pool);
        assert_eq!(a.total_time_s, b.total_time_s);
        assert_eq!(a.gops_per_w, b.gops_per_w);
        for (la, lb) in a.layers.iter().zip(&b.layers) {
            assert_eq!(la.cycles, lb.cycles);
            assert_eq!(la.compute_cycles, lb.compute_cycles);
        }
    }
}

fn synthetic_coordinator(
    dir: &TempDir,
    networks: &[&str],
    executors: usize,
) -> Coordinator {
    write_synthetic(dir.path(), networks, 4, 99).expect("synthetic set");
    Coordinator::start(CoordinatorConfig {
        artifacts_dir: dir.path().to_path_buf(),
        networks: networks.iter().map(|s| s.to_string()).collect(),
        batcher: BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(2),
        },
        executors,
        ..Default::default()
    })
    .expect("coordinator startup")
}

/// Pins the 0.2.0 deprecation shims: `submit` / `submit_with` /
/// `submit_blocking` must keep working (routed through the builder)
/// for one release before removal.
#[test]
#[allow(deprecated)]
fn deprecated_submit_shims_still_serve() {
    let dir = TempDir::new().unwrap();
    let coord = synthetic_coordinator(&dir, &["mnist"], 2);
    let via_shim = coord.submit_blocking("mnist", 2, 777).unwrap();
    let via_builder =
        coord.request("mnist").images(2).seed(777).blocking().unwrap();
    assert_eq!(via_shim.images.data(), via_builder.images.data());
    let h = coord.submit("mnist", 1, 778).unwrap();
    assert_eq!(h.wait().unwrap().images.shape(), &[1, 1, 28, 28]);
    let ctx = RequestCtx::new(779);
    let h = coord.client().submit_with("mnist", 1, ctx).unwrap();
    assert_eq!(h.wait().unwrap().images.shape(), &[1, 1, 28, 28]);
}

#[test]
fn executor_pool_serves_synthetic_artifacts() {
    let dir = TempDir::new().unwrap();
    let coord = synthetic_coordinator(&dir, &["mnist"], 2);
    assert_eq!(coord.executors(), 2);
    let a = coord.request("mnist").images(1).seed(4242).blocking().unwrap();
    let b = coord.request("mnist").images(1).seed(4242).blocking().unwrap();
    assert_eq!(a.images.shape(), &[1, 1, 28, 28]);
    assert_eq!(a.images.data(), b.images.data(), "seeded determinism");
    assert!(a.images.data().iter().all(|v| v.abs() <= 1.0));
    assert!(a.fpga_time_s > 0.0);
    assert!(a.gpu_time_s > 0.0);
}

#[test]
fn executor_pool_workload_report_is_consistent() {
    let dir = TempDir::new().unwrap();
    let coord = synthetic_coordinator(&dir, &["mnist"], 2);
    let report = coord
        .serve_workload(&WorkloadSpec {
            network: "mnist".into(),
            requests: 6,
            images_per_request: 1,
            interarrival: Duration::ZERO,
            seed: 5,
        })
        .unwrap();
    assert_eq!(report.requests, 6);
    assert_eq!(report.images, 6);
    assert!(report.batches >= 1 && report.batches <= 6);
    assert!(report.images_per_s > 0.0);
    assert!(report.gops > 0.0);
    assert!(report.latency.p99_s >= report.latency.p50_s);
    assert!(report.mean_power_w > 0.0);
    assert!(report.gops_per_w > 0.0);
}

#[test]
fn executor_pool_serves_networks_concurrently() {
    let dir = TempDir::new().unwrap();
    let coord = synthetic_coordinator(&dir, &["mnist", "celeba"], 0);
    assert_eq!(coord.executors(), 3, "auto: one lane per default backend");
    // submit to both networks at once; each can resolve on its own lane
    let hm = coord.request("mnist").images(1).seed(7).submit().unwrap();
    let hc = coord.request("celeba").images(1).seed(7).submit().unwrap();
    let m = hm.wait().unwrap();
    let c = hc.wait().unwrap();
    assert_eq!(m.images.shape(), &[1, 1, 28, 28]);
    assert_eq!(c.images.shape(), &[1, 3, 64, 64]);
    // celeba is ~20x the ops: its edge annotation must be slower
    assert!(c.fpga_time_s > m.fpga_time_s);
}

#[test]
fn executor_pool_survives_unknown_network() {
    let dir = TempDir::new().unwrap();
    let coord = synthetic_coordinator(&dir, &["mnist"], 2);
    let bad = coord.request("imagenet").images(1).seed(0).blocking();
    assert!(bad.is_err(), "unloaded network must error, not hang");
    let good = coord.request("mnist").images(1).seed(0).blocking();
    assert!(good.is_ok(), "pool must survive a bad request");
}

#[test]
fn executor_pool_coalesces_bursts() {
    let dir = TempDir::new().unwrap();
    let coord = synthetic_coordinator(&dir, &["mnist"], 1);
    let handles: Vec<_> = (0..8)
        .map(|i| coord.request("mnist").images(1).seed(1000 + i).submit().unwrap())
        .collect();
    let responses: Vec<_> =
        handles.into_iter().map(|h| h.wait().unwrap()).collect();
    assert_eq!(responses.len(), 8);
    let max_batch = responses.iter().map(|r| r.batch_size).max().unwrap();
    assert!(
        max_batch >= 2,
        "burst should have been coalesced (max batch {max_batch})"
    );
    for r in &responses {
        assert_eq!(r.images.shape(), &[1, 1, 28, 28]);
    }
}
