//! Allocation discipline of the numeric hot path, proven with a
//! counting global allocator (per-thread counters, so concurrently
//! running tests don't pollute each other) plus the scratch arena's own
//! hit/miss counters:
//!
//! * steady-state kernel calls allocate a small constant amount (the
//!   output tensor and per-tile bookkeeping) — the tile accumulator
//!   block comes from the per-worker arena, never the heap;
//! * requests batched together receive zero-copy windows of **one**
//!   shared batch allocation ([`ImageBlock::shares_allocation`]);
//! * the caller thread never allocates the reply payload — images are
//!   generated and wrapped on the executor side and only an `Arc`
//!   window crosses the channel.
//!
//! [`ImageBlock::shares_allocation`]:
//! edgedcnn::tensor::ImageBlock::shares_allocation

use edgedcnn::artifacts::write_synthetic;
use edgedcnn::config::{BackendCfg, DeviceKind};
use edgedcnn::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, InferenceResponse,
};
use edgedcnn::deconv::{
    deconv_reverse_loop, deconv_reverse_loop_blocked, BlockSchedule,
    ReverseLoopOpts,
};
use edgedcnn::tensor::Tensor;
use edgedcnn::util::{
    reset_scratch_stats, scratch_allocs, scratch_hits, scratch_hwm_bytes,
    TempDir, WorkerPool,
};
use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::collections::BTreeMap;
use std::time::Duration;

// ---------------------------------------------------------------- hook

/// System allocator wrapper counting this thread's allocations.
/// Thread-local (const-initialized, so the TLS access itself never
/// allocates): the Rust test harness runs each test on its own thread,
/// which makes the counters deterministic per test.
struct CountingAlloc;

thread_local! {
    static TL_ALLOCS: Cell<u64> = const { Cell::new(0) };
    static TL_BYTES: Cell<u64> = const { Cell::new(0) };
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        // try_with: TLS may be gone during thread teardown
        let _ = TL_ALLOCS.try_with(|c| c.set(c.get() + 1));
        let _ = TL_BYTES.try_with(|c| c.set(c.get() + layout.size() as u64));
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// (allocation count, bytes) charged to this thread by `f`.
fn measure<T>(f: impl FnOnce() -> T) -> (u64, u64) {
    let a0 = TL_ALLOCS.with(Cell::get);
    let b0 = TL_BYTES.with(Cell::get);
    std::hint::black_box(f());
    (TL_ALLOCS.with(Cell::get) - a0, TL_BYTES.with(Cell::get) - b0)
}

// --------------------------------------------------------------- tests

#[test]
fn kernel_steady_state_allocates_a_small_constant_off_the_arena() {
    let x = Tensor::from_fn(vec![2, 4, 7, 7], |i| (i as f32 * 0.37).sin());
    let w = Tensor::from_fn(vec![4, 6, 4, 4], |i| {
        if i % 3 == 0 {
            0.0
        } else {
            (i as f32 * 0.11).cos()
        }
    });
    let b = vec![0.05f32; 6];
    let opts = ReverseLoopOpts { tile: 8, zero_skip: true };
    // warm pass: grows this thread's arena to the tile block size
    let (y0, _) = deconv_reverse_loop(&x, &w, &b, 2, 1, opts);

    reset_scratch_stats();
    let (a1, _) = measure(|| deconv_reverse_loop(&x, &w, &b, 2, 1, opts));
    let (a2, _) = measure(|| deconv_reverse_loop(&x, &w, &b, 2, 1, opts));
    assert!(a1 > 0, "the counting hook must observe the output tensor");
    assert_eq!(a1, a2, "steady-state allocation count must not drift");
    assert!(
        a1 <= 64,
        "per-call allocations escaped the arena: {a1} (expected only the \
         output tensor + per-tile bookkeeping)"
    );
    // the arena's own counters: warm steady state never re-allocates
    assert_eq!(scratch_allocs(), 0, "tile accumulators must reuse the arena");
    assert!(scratch_hits() > 0, "every tile takes the arena path");
    // and the warm pass produced the same numerics (sanity)
    let (y1, _) = deconv_reverse_loop(&x, &w, &b, 2, 1, opts);
    assert_eq!(y0.data(), y1.data());
}

#[test]
fn blocked_dispatch_does_not_grow_the_scratch_high_water_mark() {
    let x = Tensor::from_fn(vec![2, 4, 7, 7], |i| (i as f32 * 0.29).sin());
    let w = Tensor::from_fn(vec![4, 6, 4, 4], |i| (i as f32 * 0.13).cos());
    let b = vec![0.02f32; 6];
    let opts = ReverseLoopOpts { tile: 8, zero_skip: false };
    // plain serial kernel at tile 8: the baseline arena footprint
    reset_scratch_stats();
    let (want, want_stats) = deconv_reverse_loop(&x, &w, &b, 2, 1, opts);
    let plain_hwm = scratch_hwm_bytes();
    assert!(plain_hwm > 0, "the tile accumulator must go through the arena");
    // blocked dispatch at micro == tile on a serial pool (inline, so
    // the arena observed is this thread's): the accumulator block size
    // depends only on the micro-tile, so macro grouping and lane
    // blocking must leave the high-water mark untouched
    let pool = WorkerPool::new(1);
    for macro_tiles in [1usize, 2, 8] {
        for lanes in [1usize, 4, 8] {
            reset_scratch_stats();
            let sched = BlockSchedule { micro: 8, macro_tiles, lanes };
            let (got, got_stats) = deconv_reverse_loop_blocked(
                &x,
                &w,
                &b,
                2,
                1,
                false,
                Some(sched),
                &pool,
            );
            let blocked_hwm = scratch_hwm_bytes();
            assert_eq!(got.data(), want.data(), "macro {macro_tiles} lanes {lanes}");
            assert_eq!(got_stats, want_stats, "macro {macro_tiles} lanes {lanes}");
            assert!(
                blocked_hwm <= plain_hwm,
                "macro {macro_tiles} lanes {lanes}: blocked HWM {blocked_hwm} \
                 grew past the plain kernel's {plain_hwm}"
            );
        }
    }
}

fn start_single_lane(dir: &TempDir, max_wait_ms: u64) -> Coordinator {
    Coordinator::start(CoordinatorConfig {
        artifacts_dir: dir.path().to_path_buf(),
        networks: vec!["mnist".to_string()],
        batcher: BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(max_wait_ms),
        },
        backends: BackendCfg {
            kinds: vec![DeviceKind::Fpga],
            ..Default::default()
        },
        executors: 0,
        quant: None,
        quant8: None,
        shard_batches: false,
        clock: None,
    })
    .unwrap()
}

#[test]
fn batched_responses_share_one_backing_allocation() {
    let dir = TempDir::new().unwrap();
    write_synthetic(dir.path(), &["mnist"], 2, 17).unwrap();
    let coord = start_single_lane(&dir, 10);
    // rapid-fire single-image requests at one lane: while the lane
    // works off the first cut, the rest coalesce into shared batches
    let handles: Vec<_> = (0..12)
        .map(|i| {
            coord
                .request("mnist")
                .images(1)
                .seed(7000 + i)
                .submit()
                .unwrap()
        })
        .collect();
    let responses: Vec<InferenceResponse> =
        handles.into_iter().map(|h| h.wait().unwrap()).collect();

    let mut by_batch: BTreeMap<u64, Vec<&InferenceResponse>> = BTreeMap::new();
    for r in &responses {
        assert_eq!(r.images.shape(), &[1, 1, 28, 28]);
        by_batch.entry(r.exec_seq).or_default().push(r);
    }
    assert!(
        by_batch.values().any(|g| g.len() >= 2),
        "12 rapid-fire requests over one lane must co-batch at least once \
         (batch sizes: {:?})",
        by_batch.values().map(|g| g.len()).collect::<Vec<_>>()
    );
    for group in by_batch.values() {
        // the zero-copy property: same batch ⇒ same backing buffer
        for pair in group.windows(2) {
            assert!(
                pair[0].images.shares_allocation(&pair[1].images),
                "same-batch responses must alias one allocation"
            );
            assert_eq!(pair[0].batch_size, pair[1].batch_size);
        }
    }
    // and distinct batches never alias
    let firsts: Vec<&&InferenceResponse> =
        by_batch.values().map(|g| &g[0]).collect();
    for pair in firsts.windows(2) {
        assert!(
            !pair[0].images.shares_allocation(&pair[1].images),
            "distinct batches must not share a buffer"
        );
    }
}

#[test]
fn caller_thread_never_allocates_the_reply_payload() {
    let dir = TempDir::new().unwrap();
    write_synthetic(dir.path(), &["mnist"], 2, 17).unwrap();
    let coord = start_single_lane(&dir, 2);
    // a deliberately large payload: 32 images ≈ 100 KiB of f32
    let handle = coord.request("mnist").images(32).seed(31).submit().unwrap();
    // 32 images × 1 channel × 28×28 pixels × 4 bytes/f32
    let payload_bytes = 32 * 28 * 28 * 4u64;
    let ((_, caller_bytes), resp) = {
        let mut out = None;
        let counts = measure(|| out = Some(handle.wait().unwrap()));
        (counts, out.unwrap())
    };
    assert_eq!(resp.images.numel() as u64 * 4, payload_bytes);
    assert!(
        caller_bytes < payload_bytes / 2,
        "receiving a {payload_bytes}-byte payload allocated {caller_bytes} \
         bytes on the caller thread — the reply path is copying"
    );
}
