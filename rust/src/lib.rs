//! # edgedcnn
//!
//! Reproduction of *"A Competitive Edge: Can FPGAs Beat GPUs at DCNN
//! Inference Acceleration in Resource-Limited Edge Computing
//! Applications?"* (Colbert, Daly, Kreutz-Delgado, Das — 2021) as a
//! three-layer Rust + JAX + Pallas stack.
//!
//! Layer map:
//! * **L1/L2 (build time)** — `python/compile/` authors the reverse-loop
//!   deconvolution Pallas kernel and the WGAN-GP DCNN generators, and
//!   AOT-lowers them to HLO text artifacts (`make artifacts`).
//! * **L3 (this crate)** — the runtime system: a PJRT CPU client (or the
//!   numerics-identical pure-Rust fallback, see [`runtime`]) executes
//!   the artifacts for real numerics, while cycle-level simulators of the
//!   paper's PYNQ-Z2 accelerator ([`fpga`]) and the Jetson TX1 baseline
//!   ([`gpu`]) supply the timing/power evaluation, orchestrated by an
//!   edge-serving coordinator ([`coordinator`]) and regenerated per paper
//!   table/figure by [`experiments`].
//!
//! Cross-cutting: the **element-type axis** ([`quant`]) — tensors, the
//! three deconvolution kernels and the generator forward are generic
//! over [`quant::Element`], so the same Algorithm 1 code runs in `f32`
//! or Qm.n fixed point ([`quant::Fixed`]); the FPGA simulator models
//! the chosen datapath (byte traffic, BRAM word widths, DSP lane
//! packing), the artifact layer exports/imports scale-calibrated
//! quantized weights, and the coordinator serves quantized twins
//! (`<name>.q`) side by side with f32.
//!
//! Cross-cutting: the **device-backend layer** ([`backend`]) — the
//! FPGA simulator, the GPU thermal model and the host CPU numeric path
//! wrapped as first-class schedulable backends behind one trait
//! (capabilities, cost model, `execute → outcome`), pooled by the
//! coordinator with capability- and cost-aware routing so the paper's
//! FPGA-vs-GPU comparison happens per batch, live, with per-backend
//! serving metrics.
//!
//! Cross-cutting: the **spatio-temporal parallel execution engine**
//! ([`util::WorkerPool`]) — a dependency-free scoped worker pool with
//! deterministic result ordering that mirrors the paper's hardware
//! parallelism in software.  It shards reverse-loop output tiles
//! ([`deconv::deconv_reverse_loop_par`], spatial), runs the simulated CU
//! array concurrently ([`fpga::CuArray`], spatial) and fans layer sweeps
//! out ([`fpga::simulate_network_par`], temporal); the coordinator's
//! executor pool ([`coordinator::Coordinator`]) extends the same shape to
//! serving.  Every parallel path is bit-identical to its serial twin
//! (tensors *and* op counts), asserted by the integration and property
//! tests.
//!
//! Cross-cutting: the **workload & telemetry subsystem** ([`workload`],
//! [`telemetry`]) — scenario-driven open-loop load generation (seeded
//! Poisson / MMPP / diurnal / flash-crowd arrivals, JSON trace
//! record/replay) feeding streaming log-bucketed latency histograms,
//! SLO counters and repeated-trial variation statistics, so the
//! paper's run-to-run-stability verdict is a live, CI-checkable
//! experiment (`edgedcnn loadtest`).
//!
//! The **fleet layer** ([`fleet`]) scales the coordinator out: a front
//! tier consistent-hashes one recorded trace across N per-site
//! coordinators (cross-site overflow spill, seeded clock skew, mid-run
//! site failure) and folds the per-site telemetry shards into one
//! fleet-level [`coordinator::ServingReport`] (`edgedcnn fleet`).
//!
//! See `DESIGN.md` for the full system inventory and the per-experiment
//! index, and `EXPERIMENTS.md` for paper-vs-measured results.

pub mod artifacts;
pub mod backend;
pub mod config;
pub mod coordinator;
pub mod deconv;
pub mod dse;
pub mod experiments;
pub mod fleet;
pub mod fpga;
pub mod gpu;
pub mod quant;
pub mod runtime;
pub mod sparsity;
pub mod stats;
pub mod telemetry;
pub mod tensor;
pub mod tune;
pub mod util;
pub mod workload;

pub use anyhow::{Context, Result};
