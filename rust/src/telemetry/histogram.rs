//! Streaming log-bucketed latency histogram — the bounded-memory
//! replacement for the serving path's old `Vec<f64>` of raw latencies.
//!
//! Buckets are geometric: bucket `i` covers `[min·g^i, min·g^(i+1))`
//! with `g = (1 + ε)²`, so the geometric midpoint of any bucket is
//! within a factor `1 + ε` of every value the bucket holds — quantile
//! queries are therefore exact to one bucket's relative error, by
//! construction, at **O(1) memory per histogram** regardless of how
//! many samples stream through.  Histograms with the same geometry
//! merge by adding counts (shard-per-backend, merge at report time),
//! and merging shards is *identical* to histogramming the concatenated
//! stream (asserted by a property test).
//!
//! Coordinated omission: [`LogHistogram::record_corrected`] back-fills
//! the samples a stalled open-loop generator failed to issue
//! (HdrHistogram's `recordValueWithExpectedInterval` scheme) — without
//! it, one long stall hides every request that *would* have been issued
//! and measured during the stall, and the tail quantiles lie.

/// A fixed-geometry streaming histogram over positive values.
#[derive(Debug, Clone)]
pub struct LogHistogram {
    /// Lower bound of bucket 0; smaller samples land in the underflow
    /// counter.
    min: f64,
    /// Bucket boundary ratio, `(1 + rel_err)²`.
    growth: f64,
    rel_err: f64,
    inv_ln_growth: f64,
    counts: Vec<u64>,
    underflow: u64,
    /// Samples refused by [`Self::record`] (non-finite or negative).
    dropped: u64,
    total: u64,
    sum: f64,
    min_seen: f64,
    max_seen: f64,
}

impl LogHistogram {
    /// Histogram over `[min, max)` with quantiles exact to `rel_err`
    /// relative error (values above `max` clamp into the last bucket;
    /// their quantiles degrade gracefully, `max_seen` stays exact).
    pub fn new(min: f64, max: f64, rel_err: f64) -> Self {
        assert!(min > 0.0 && max > min, "bad histogram range");
        assert!(rel_err > 0.0 && rel_err < 1.0, "bad relative error");
        let growth = (1.0 + rel_err) * (1.0 + rel_err);
        let n = ((max / min).ln() / growth.ln()).ceil() as usize;
        LogHistogram {
            min,
            growth,
            rel_err,
            inv_ln_growth: 1.0 / growth.ln(),
            counts: vec![0; n.max(1)],
            underflow: 0,
            dropped: 0,
            total: 0,
            sum: 0.0,
            min_seen: f64::INFINITY,
            max_seen: f64::NEG_INFINITY,
        }
    }

    /// The serving default: 1 µs … 10 000 s at 2% relative error
    /// (≈ 580 buckets ≈ 4.6 KiB — the whole point versus an unbounded
    /// `Vec<f64>` growing by 8 bytes per request forever).
    pub fn latency_default() -> Self {
        Self::new(1e-6, 1e4, 0.02)
    }

    /// Maximum relative error of a quantile that lands in-range.
    pub fn relative_error(&self) -> f64 {
        self.rel_err
    }

    /// Record one sample.  Non-finite or negative values are *refused*
    /// and counted in [`Self::dropped`] — a NaN would otherwise poison
    /// the exact sum forever and land in bucket 0 (`NaN as usize == 0`),
    /// silently bending the median toward the floor.
    pub fn record(&mut self, v: f64) {
        if !v.is_finite() || v < 0.0 {
            self.dropped += 1;
            return;
        }
        self.total += 1;
        self.sum += v;
        self.min_seen = self.min_seen.min(v);
        self.max_seen = self.max_seen.max(v);
        if v < self.min {
            self.underflow += 1;
        } else {
            let i = ((v / self.min).ln() * self.inv_ln_growth) as usize;
            let i = i.min(self.counts.len() - 1);
            self.counts[i] += 1;
        }
    }

    /// Record one sample with coordinated-omission correction: when a
    /// measured latency exceeds the interval the open-loop generator
    /// *intended* between samples, the requests that would have been
    /// issued (and stalled) during it are back-filled at `v - k·interval`
    /// — HdrHistogram's expected-interval scheme.
    pub fn record_corrected(&mut self, v: f64, expected_interval_s: f64) {
        self.record(v);
        if !v.is_finite() || v < 0.0 || expected_interval_s <= 0.0 {
            // a refused sample back-fills nothing (an inf stall must
            // not spin the back-fill budget recording 10⁴ drops)
            return;
        }
        let mut missing = v - expected_interval_s;
        // cap the back-fill so one absurd outlier cannot wedge the
        // reporter (10⁴ synthetic samples ≫ any honest stall)
        let mut budget = 10_000;
        while missing >= expected_interval_s && budget > 0 {
            self.record(missing);
            missing -= expected_interval_s;
            budget -= 1;
        }
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    /// Samples refused by [`Self::record`] (non-finite or negative).
    /// Excluded from `count`/`sum`/extremes/quantiles; merges
    /// additively like every other counter.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Exact mean of everything recorded (the sum is tracked exactly;
    /// only *quantiles* are bucketed).
    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum / self.total as f64
        }
    }

    pub fn min(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.min_seen
        }
    }

    pub fn max(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.max_seen
        }
    }

    /// Nearest-rank quantile, `p` in `[0, 100]`.  The returned value is
    /// the geometric midpoint of the bucket holding the rank-`⌈p·n/100⌉`
    /// order statistic (clamped to the exactly-tracked min/max), so it
    /// is within one bucket's relative error of the true order
    /// statistic.  Returns 0 on an empty histogram.
    pub fn quantile(&self, p: f64) -> f64 {
        assert!((0.0..=100.0).contains(&p), "quantile out of range");
        if self.total == 0 {
            return 0.0;
        }
        let rank = ((p / 100.0 * self.total as f64).ceil() as u64).max(1);
        let mut seen = self.underflow;
        if rank <= seen {
            // underflow samples are below bucket 0: the tracked min is
            // the best (and for a single sample, exact) answer
            return self.min_seen;
        }
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if rank <= seen {
                let mid = self.min
                    * self.growth.powi(i as i32)
                    * self.growth.sqrt();
                return mid.clamp(self.min_seen, self.max_seen);
            }
        }
        self.max_seen
    }

    /// Merge another histogram of identical geometry (shards → report).
    pub fn merge(&mut self, other: &LogHistogram) {
        assert_eq!(self.counts.len(), other.counts.len(), "geometry mismatch");
        assert!(
            self.min == other.min && self.growth == other.growth,
            "geometry mismatch"
        );
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.underflow += other.underflow;
        self.dropped += other.dropped;
        self.total += other.total;
        self.sum += other.sum;
        self.min_seen = self.min_seen.min(other.min_seen);
        self.max_seen = self.max_seen.max(other.max_seen);
    }

    /// Bucket occupancy (underflow, per-bucket counts) — exposed so the
    /// merge-equals-concatenation property is assertable exactly.
    pub fn buckets(&self) -> (u64, &[u64]) {
        (self.underflow, &self.counts)
    }
}

/// Nearest-rank percentile over a raw slice — the exact reference the
/// histogram approximates (used by tests and the bootstrap).
pub fn nearest_rank(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty(), "nearest_rank of empty slice");
    let rank = ((p / 100.0 * sorted.len() as f64).ceil() as usize).max(1);
    sorted[rank.min(sorted.len()) - 1]
}

/// One time slice of a [`WindowedHistogram`].
#[derive(Debug, Clone)]
struct WindowShard {
    /// Which absolute slice (`floor(t / slice_s)`) the shard currently
    /// holds; `u64::MAX` = never written.
    epoch: u64,
    hist: LogHistogram,
}

/// Time-sliced latency histogram — a ring of [`LogHistogram`] shards,
/// one per `slice_s` of run time, holding the most recent `len` slices
/// at O(len) memory.  A single all-run histogram answers "what was the
/// p99" but not "*when* did the tail happen"; the ring keeps enough
/// time structure to localize a deadline-miss burst (the drift column:
/// worst-window p99 over best-window p99) without reintroducing
/// unbounded per-request storage.
#[derive(Debug, Clone)]
pub struct WindowedHistogram {
    slice_s: f64,
    ring: Vec<WindowShard>,
    /// Fresh shard template (cloning beats re-deriving the geometry).
    template: LogHistogram,
}

impl WindowedHistogram {
    /// `len` slices of `slice_s` seconds over the latency-default
    /// geometry.
    pub fn latency_default(slice_s: f64, len: usize) -> Self {
        Self::new(slice_s, len, LogHistogram::latency_default())
    }

    pub fn new(slice_s: f64, len: usize, template: LogHistogram) -> Self {
        assert!(slice_s > 0.0, "bad window slice");
        assert!(len >= 2, "a drift needs at least two windows");
        WindowedHistogram {
            slice_s,
            ring: vec![
                WindowShard {
                    epoch: u64::MAX,
                    hist: template.clone(),
                };
                len
            ],
            template,
        }
    }

    /// Record one sample observed `t_s` seconds into the run.
    pub fn record(&mut self, t_s: f64, v: f64) {
        let epoch = (t_s.max(0.0) / self.slice_s) as u64;
        let slot = (epoch as usize) % self.ring.len();
        let shard = &mut self.ring[slot];
        if shard.epoch != epoch {
            // the ring wrapped: this slot's old slice ages out
            shard.hist = self.template.clone();
            shard.epoch = epoch;
        }
        shard.hist.record(v);
    }

    /// Populated windows in time order: `(window start seconds,
    /// histogram)`.
    pub fn windows(&self) -> Vec<(f64, &LogHistogram)> {
        let mut live: Vec<(u64, &LogHistogram)> = self
            .ring
            .iter()
            .filter(|s| s.epoch != u64::MAX && s.hist.count() > 0)
            .map(|s| (s.epoch, &s.hist))
            .collect();
        live.sort_by_key(|(e, _)| *e);
        live.into_iter()
            .map(|(e, h)| (e as f64 * self.slice_s, h))
            .collect()
    }

    /// Drift of the tail across the retained windows: worst-window p99
    /// over best-window p99 (`1.0` with fewer than two populated
    /// windows — nothing to drift between).  A steady run reads ≈ 1;
    /// a deadline-miss burst confined to one slice reads ≫ 1.
    pub fn drift(&self) -> f64 {
        let p99s: Vec<f64> = self
            .windows()
            .iter()
            .map(|(_, h)| h.quantile(99.0))
            .filter(|q| *q > 0.0)
            .collect();
        if p99s.len() < 2 {
            return 1.0;
        }
        let worst = p99s.iter().cloned().fold(f64::MIN, f64::max);
        let best = p99s.iter().cloned().fold(f64::MAX, f64::min);
        worst / best
    }

    /// All retained windows merged (the whole-run view of what the ring
    /// still holds).
    pub fn merged(&self) -> LogHistogram {
        let mut out = self.template.clone();
        for (_, h) in self.windows() {
            out.merge(h);
        }
        out
    }

    /// Merge another ring of identical geometry (per-site telemetry
    /// shards → the fleet report).  Each slot resolves by **max epoch**:
    /// the newer slice wins the slot outright, equal epochs merge their
    /// histograms, older slices are dropped — the same aging rule
    /// [`Self::record`] applies when the ring wraps.  Taking the newest
    /// epoch is a join (max) and equal-epoch histogram merging is
    /// commutative + associative, so the slot resolution is a
    /// semilattice: fleet folds give the same ring in any association
    /// order (asserted by a property test).
    pub fn merge(&mut self, other: &WindowedHistogram) {
        self.try_merge(other).expect("windowed histogram merge");
    }

    /// Fallible form of [`Self::merge`].  Merging rings of different
    /// `slice_s` (or ring length) has no defined semantics — the
    /// slot ↔ epoch mapping disagrees, so "the same window" does not
    /// exist on both sides — and is *refused* with an error instead of
    /// silently mixing slices of different widths.
    pub fn try_merge(
        &mut self,
        other: &WindowedHistogram,
    ) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.slice_s == other.slice_s,
            "window slice mismatch: {} s vs {} s",
            self.slice_s,
            other.slice_s
        );
        anyhow::ensure!(
            self.ring.len() == other.ring.len(),
            "ring length mismatch: {} vs {}",
            self.ring.len(),
            other.ring.len()
        );
        for (slot, theirs) in other.ring.iter().enumerate() {
            if theirs.epoch == u64::MAX {
                continue;
            }
            let ours = &mut self.ring[slot];
            if ours.epoch == theirs.epoch {
                ours.hist.merge(&theirs.hist);
            } else if ours.epoch == u64::MAX || ours.epoch < theirs.epoch {
                *ours = theirs.clone();
            }
            // else ours is newer: the other's slice already aged out
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn log_uniform(rng: &mut Rng, lo: f64, hi: f64) -> f64 {
        (rng.range_f64(lo.ln(), hi.ln())).exp()
    }

    #[test]
    fn empty_histogram_reports_zeroes() {
        let h = LogHistogram::latency_default();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.quantile(50.0), 0.0);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 0.0);
    }

    #[test]
    fn mean_and_extremes_are_exact() {
        let mut h = LogHistogram::latency_default();
        for v in [0.001, 0.002, 0.003, 0.010] {
            h.record(v);
        }
        assert!((h.mean() - 0.004).abs() < 1e-15);
        assert_eq!(h.min(), 0.001);
        assert_eq!(h.max(), 0.010);
        assert_eq!(h.count(), 4);
    }

    #[test]
    fn prop_quantiles_within_one_bucket_relative_error() {
        // the acceptance property: histogram quantiles vs the exact
        // sorted-vector nearest-rank percentile, over random streams
        let mut rng = Rng::seed_from_u64(0x4157);
        for case in 0..200 {
            let n = rng.range_usize(1, 400);
            let mut h = LogHistogram::latency_default();
            let mut vals = Vec::with_capacity(n);
            for _ in 0..n {
                let v = log_uniform(&mut rng, 2e-6, 5e3);
                h.record(v);
                vals.push(v);
            }
            vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
            for p in [0.0, 10.0, 50.0, 90.0, 95.0, 99.0, 99.9, 100.0] {
                let exact = nearest_rank(&vals, p);
                let got = h.quantile(p);
                let rel = (got / exact - 1.0).abs();
                assert!(
                    rel <= h.relative_error() + 1e-12,
                    "case {case} p{p}: got {got} exact {exact} rel {rel}"
                );
            }
        }
    }

    #[test]
    fn prop_merging_shards_equals_concatenated_stream() {
        let mut rng = Rng::seed_from_u64(77);
        for case in 0..100 {
            let n = rng.range_usize(2, 300);
            let shards = rng.range_usize(2, 5);
            let mut whole = LogHistogram::latency_default();
            let mut parts: Vec<LogHistogram> =
                (0..shards).map(|_| LogHistogram::latency_default()).collect();
            for i in 0..n {
                let v = log_uniform(&mut rng, 1e-7, 1e5); // incl. out-of-range
                whole.record(v);
                parts[i % shards].record(v);
            }
            let mut merged = parts.remove(0);
            for p in &parts {
                merged.merge(p);
            }
            assert_eq!(merged.count(), whole.count(), "case {case}");
            assert_eq!(merged.buckets(), whole.buckets(), "case {case}");
            assert_eq!(merged.min(), whole.min());
            assert_eq!(merged.max(), whole.max());
            for p in [1.0, 50.0, 99.0, 99.9] {
                assert_eq!(merged.quantile(p), whole.quantile(p));
            }
            assert!((merged.sum() - whole.sum()).abs() <= 1e-9 * whole.sum().abs());
        }
    }

    #[test]
    fn out_of_range_samples_stay_accounted() {
        let mut h = LogHistogram::new(1e-3, 1.0, 0.02);
        h.record(1e-6); // underflow
        h.record(50.0); // clamps into the top bucket
        assert_eq!(h.count(), 2);
        assert_eq!(h.min(), 1e-6, "extremes tracked exactly");
        assert_eq!(h.max(), 50.0);
        assert_eq!(h.quantile(100.0), 50.0);
        let (under, _) = h.buckets();
        assert_eq!(under, 1);
    }

    #[test]
    fn coordinated_omission_backfills_the_stall() {
        // a 1 s stall at a 100 ms intended interval hides 9 requests;
        // correction recovers them at 0.9, 0.8, … 0.1 s
        let mut h = LogHistogram::latency_default();
        h.record_corrected(1.0, 0.1);
        assert_eq!(h.count(), 10);
        let mut plain = LogHistogram::latency_default();
        plain.record(1.0);
        assert!(
            h.quantile(50.0) < plain.quantile(50.0),
            "backfilled samples must pull the median below the stall"
        );
        assert_eq!(h.max(), 1.0);
        // non-stalled samples add nothing
        let mut ok = LogHistogram::latency_default();
        ok.record_corrected(0.05, 0.1);
        assert_eq!(ok.count(), 1);
        // zero interval means no correction
        let mut z = LogHistogram::latency_default();
        z.record_corrected(1.0, 0.0);
        assert_eq!(z.count(), 1);
    }

    #[test]
    fn non_finite_and_negative_samples_are_dropped_not_recorded() {
        let mut h = LogHistogram::latency_default();
        h.record(0.005);
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY, -1e-3] {
            h.record(bad);
        }
        assert_eq!(h.count(), 1, "refused samples never enter the total");
        assert_eq!(h.dropped(), 4);
        assert_eq!(h.min(), 0.005, "extremes untouched by refused samples");
        assert_eq!(h.max(), 0.005);
        assert!((h.sum() - 0.005).abs() < 1e-15, "sum stays finite");
        let q = h.quantile(50.0);
        assert!(
            (q / 0.005 - 1.0).abs() <= h.relative_error() + 1e-12,
            "median unbent by the NaN: {q}"
        );
    }

    #[test]
    fn corrected_path_refuses_bad_samples_without_backfill() {
        let mut c = LogHistogram::latency_default();
        c.record_corrected(f64::INFINITY, 0.1);
        assert_eq!(c.count(), 0);
        assert_eq!(c.dropped(), 1, "an inf stall must not spin the budget");
        c.record_corrected(f64::NAN, 0.1);
        c.record_corrected(-0.5, 0.1);
        assert_eq!(c.count(), 0);
        assert_eq!(c.dropped(), 3);
        // a bad *interval* degrades to a plain record, never a spin
        c.record_corrected(0.05, f64::NAN);
        assert_eq!(c.count(), 1);
        assert_eq!(c.dropped(), 3);
    }

    #[test]
    fn dropped_counter_merges_additively() {
        let mut a = LogHistogram::latency_default();
        a.record(-5.0);
        a.record(0.001);
        let mut b = LogHistogram::latency_default();
        b.record(f64::NAN);
        b.record(f64::INFINITY);
        a.merge(&b);
        assert_eq!(a.dropped(), 3);
        assert_eq!(a.count(), 1);
    }

    #[test]
    #[should_panic]
    fn merge_rejects_geometry_mismatch() {
        let mut a = LogHistogram::new(1e-6, 1.0, 0.02);
        let b = LogHistogram::new(1e-3, 1.0, 0.02);
        a.merge(&b);
    }

    #[test]
    fn windowed_slices_by_time_and_localizes_a_burst() {
        let mut w = WindowedHistogram::latency_default(0.5, 8);
        assert_eq!(w.drift(), 1.0, "empty ring has nothing to drift");
        // steady 1 ms traffic for 2 s …
        for i in 0..200 {
            w.record(i as f64 * 0.01, 0.001);
        }
        assert_eq!(w.windows().len(), 4, "2 s at 0.5 s slices");
        assert!((w.drift() - 1.0).abs() < 1e-9, "steady traffic: no drift");
        // … then a tail burst confined to one later slice
        for _ in 0..50 {
            w.record(2.2, 0.080);
        }
        assert_eq!(w.windows().len(), 5);
        let drift = w.drift();
        assert!(drift > 10.0, "an 80 ms burst over 1 ms steady: drift {drift}");
        // the burst is localizable: exactly one window carries the tail
        let hot: Vec<f64> = w
            .windows()
            .iter()
            .filter(|(_, h)| h.quantile(99.0) > 0.01)
            .map(|(t, _)| *t)
            .collect();
        assert_eq!(hot, vec![2.0], "burst pinned to the [2.0, 2.5) slice");
        // merged view equals the sum of the windows
        assert_eq!(w.merged().count(), 250);
    }

    /// Exact fingerprint of a ring's retained state (start times plus
    /// bucket occupancy per window) — what the associativity assertions
    /// compare.
    fn ring_fingerprint(w: &WindowedHistogram) -> Vec<(u64, u64, Vec<u64>)> {
        w.windows()
            .iter()
            .map(|(t, h)| {
                let (under, counts) = h.buckets();
                ((*t * 1000.0).round() as u64, under, counts.to_vec())
            })
            .collect()
    }

    #[test]
    fn prop_windowed_merge_is_associative_across_three_shards() {
        // three sites record into their own rings over overlapping (but
        // not identical) time ranges, including epochs far enough apart
        // that ring slots collide and the max-epoch rule must fire
        let mut rng = Rng::seed_from_u64(0xF1EE7);
        for case in 0..50 {
            let len = 4;
            let mk = || WindowedHistogram::latency_default(0.5, len);
            let mut shards = [mk(), mk(), mk()];
            for (i, s) in shards.iter_mut().enumerate() {
                let n = rng.range_usize(5, 60);
                for _ in 0..n {
                    // per-site time offset forces slot collisions at
                    // different epochs between shards
                    let t = rng.range_f64(0.0, 3.0) + i as f64 * 0.7;
                    s.record(t, log_uniform(&mut rng, 1e-4, 1e-1));
                }
            }
            let [a, b, c] = &shards;
            // fold(fold(a, b), c)
            let mut left = a.clone();
            left.merge(b);
            left.merge(c);
            // fold(a, fold(b, c))
            let mut bc = b.clone();
            bc.merge(c);
            let mut right = a.clone();
            right.merge(&bc);
            assert_eq!(
                ring_fingerprint(&left),
                ring_fingerprint(&right),
                "case {case}: associativity"
            );
            // commutativity of the same fold
            let mut rev = c.clone();
            rev.merge(b);
            rev.merge(a);
            assert_eq!(ring_fingerprint(&left), ring_fingerprint(&rev));
        }
    }

    #[test]
    fn windowed_merge_resolves_slot_collisions_by_max_epoch() {
        // len-2 ring: epochs 0 and 2 map to slot 0; the merge must keep
        // the *newer* slice, exactly like record()'s wrap rule
        let mut old = WindowedHistogram::latency_default(1.0, 2);
        old.record(0.5, 0.001); // epoch 0 → slot 0
        let mut new = WindowedHistogram::latency_default(1.0, 2);
        new.record(2.5, 0.004); // epoch 2 → slot 0
        let mut a = old.clone();
        a.merge(&new);
        let starts: Vec<f64> = a.windows().iter().map(|(t, _)| *t).collect();
        assert_eq!(starts, vec![2.0], "newer epoch wins the slot");
        // merging the other direction drops the stale slice instead
        let mut b = new.clone();
        b.merge(&old);
        assert_eq!(ring_fingerprint(&a), ring_fingerprint(&b));
        // equal epochs merge counts
        let mut c = WindowedHistogram::latency_default(1.0, 2);
        c.record(2.2, 0.002);
        c.merge(&new);
        assert_eq!(c.merged().count(), 2);
        assert_eq!(c.windows().len(), 1);
    }

    #[test]
    fn windowed_merge_refuses_mismatched_slices() {
        let mut a = WindowedHistogram::latency_default(0.25, 4);
        let err = a
            .try_merge(&WindowedHistogram::latency_default(0.5, 4))
            .unwrap_err()
            .to_string();
        assert!(err.contains("window slice mismatch"), "{err}");
        let err = a
            .try_merge(&WindowedHistogram::latency_default(0.25, 8))
            .unwrap_err()
            .to_string();
        assert!(err.contains("ring length mismatch"), "{err}");
        // the refusal left the target untouched, and matching geometry
        // still merges
        let mut d = WindowedHistogram::latency_default(0.25, 4);
        d.record(0.1, 0.002);
        a.try_merge(&d).unwrap();
        assert_eq!(a.merged().count(), 1);
    }

    #[test]
    #[should_panic(expected = "window slice mismatch")]
    fn windowed_merge_panics_on_slice_mismatch() {
        let mut a = WindowedHistogram::latency_default(0.25, 4);
        a.merge(&WindowedHistogram::latency_default(0.5, 4));
    }

    #[test]
    fn windowed_ring_ages_out_old_slices() {
        let mut w = WindowedHistogram::latency_default(1.0, 4);
        w.record(0.5, 0.001); // slice 0
        for t in [1.5, 2.5, 3.5, 4.5] {
            w.record(t, 0.002); // slices 1-4; slice 4 evicts slice 0
        }
        let starts: Vec<f64> = w.windows().iter().map(|(t, _)| *t).collect();
        assert_eq!(starts, vec![1.0, 2.0, 3.0, 4.0], "slice 0 aged out");
        assert_eq!(w.merged().count(), 4);
    }
}
