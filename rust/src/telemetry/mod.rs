//! Serving telemetry — bounded-memory streaming statistics for the
//! coordinator's metrics path and the loadtest verdict:
//!
//! * [`LogHistogram`] — log-bucketed latency histogram: O(1) memory,
//!   mergeable shards, quantiles exact to one bucket's relative error,
//!   coordinated-omission correction
//!   ([`LogHistogram::record_corrected`]).  This replaced the
//!   unbounded `Vec<f64>` the serving report used to sort per query
//!   (see DESIGN.md §Telemetry).
//! * [`WindowedHistogram`] — a ring of time-sliced histogram shards:
//!   the drift column that localizes a deadline-miss burst in time at
//!   O(ring) memory.
//! * [`SloCounter`] — deadline attainment as two integers.
//! * [`trace`] — the request-lifecycle flight recorder: per-stage span
//!   stamps carried on the request context against a skewable
//!   [`RunClock`], drained into bounded per-lane [`SpanRecorder`]
//!   rings under deterministic seed-keyed head sampling, and exported
//!   as Perfetto-loadable Chrome trace JSON ([`chrome_trace`]).
//! * [`variation`](variation_of) — repeated-trial coefficient of
//!   variation and seeded-bootstrap confidence intervals over
//!   throughput/latency/energy, the statistic behind the paper's
//!   FPGA-vs-GPU run-to-run stability verdict (Table II and the
//!   `edgedcnn loadtest` live experiment).

mod histogram;
mod slo;
pub mod trace;
mod variation;

pub use histogram::{nearest_rank, LogHistogram, WindowedHistogram};
pub use slo::SloCounter;
pub use trace::{
    chrome_trace, head_sample, RunClock, SpanRecord, SpanRecorder, Stage,
    StageStamps, NO_SITE, SPAN_RING_CAPACITY, STAGE_COUNT,
};
pub use variation::{cv_of, variation_of, weighted_cv, Variation};
