//! Request-lifecycle flight recorder — per-stage spans for every
//! request the coordinator serves, recorded at fixed cost and exported
//! as a Chrome trace-event file Perfetto can open.
//!
//! The lifecycle has seven observable stages (see DESIGN.md §Tracing):
//! intake → admission → EDF queue wait → batch formation → dispatch →
//! device execute → reply.  Each boundary is a single clock stamp
//! carried on the request's [`RequestCtx`](crate::coordinator::RequestCtx)
//! (`StageStamps` — fixed-size, `Copy`, so the context stays `Copy`),
//! taken against a per-coordinator [`RunClock`]: a monotonic offset
//! from a run epoch plus the site's seeded clock skew.  In a fleet the
//! sites share one epoch but disagree by their skews — exactly the
//! imperfect-clock replay model of DESIGN.md §Fleet — and every stamp
//! carries the skew it was taken under, so a fold can re-base spans to
//! fleet time after the fact ([`StageStamps::rebased_starts`]).
//!
//! Completed span sets drain into per-lane [`SpanRecorder`] ring
//! buffers: fixed capacity, overwrite-oldest, one pre-allocated buffer
//! per lane — zero steady-state allocation, per the hotpath discipline.
//! Which requests drain is decided by [`head_sample`]: a deterministic
//! predicate over the request's *latent seed*, so replaying a recorded
//! trace reproduces the bit-identical sampled span set on any machine.

use crate::coordinator::PriorityClass;
use crate::util::escape_json;
use std::collections::BTreeMap;
use std::time::Instant;

/// Number of lifecycle stages a completed request's span set covers.
pub const STAGE_COUNT: usize = 7;

/// Site id meaning "no site" (single-coordinator runs use site 0; a
/// request that never spilled has `prev_site == NO_SITE`).
pub const NO_SITE: u32 = u32::MAX;

/// Default per-lane span ring capacity.
pub const SPAN_RING_CAPACITY: usize = 1024;

/// One lifecycle stage of the request's journey.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Stage {
    /// Scheduled arrival → the intake gate (generator/submission lag —
    /// charged to the system, the coordinated-omission stance).
    Intake,
    /// Intake entry → admission verdict (feasibility + budget checks).
    Admission,
    /// Admission → the EDF batcher cutting a batch containing it.
    QueueWait,
    /// Batch cut → the scheduler handing the batch to a lane.
    BatchForm,
    /// Lane hand-off → the lane thread starting execution (FIFO wait).
    Dispatch,
    /// Backend execute call, start → end.
    DeviceExecute,
    /// Execute end → the response being materialized and sent.
    Reply,
}

impl Stage {
    pub const ALL: [Stage; STAGE_COUNT] = [
        Stage::Intake,
        Stage::Admission,
        Stage::QueueWait,
        Stage::BatchForm,
        Stage::Dispatch,
        Stage::DeviceExecute,
        Stage::Reply,
    ];

    pub fn index(self) -> usize {
        self as usize
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Stage::Intake => "intake",
            Stage::Admission => "admission",
            Stage::QueueWait => "queue_wait",
            Stage::BatchForm => "batch_form",
            Stage::Dispatch => "dispatch",
            Stage::DeviceExecute => "device_execute",
            Stage::Reply => "reply",
        }
    }

    pub fn parse(s: &str) -> Option<Stage> {
        Stage::ALL.into_iter().find(|st| st.as_str() == s)
    }
}

/// The clock every stamp is taken against: a monotonic offset from a
/// shared run epoch, plus the owning site's seeded skew — site `i`'s
/// clock reads `true_run_time + skew_s`, the fleet's imperfect-clock
/// model made observable.  Reading the clock never allocates.
#[derive(Debug, Clone, Copy)]
pub struct RunClock {
    epoch: Instant,
    skew_s: f64,
    site: u32,
}

impl RunClock {
    /// A skew-free clock for a standalone coordinator (site 0).
    pub fn at(epoch: Instant) -> Self {
        RunClock { epoch, skew_s: 0.0, site: 0 }
    }

    /// A fleet site's clock: shared epoch, seeded skew, site id.
    pub fn with_site(epoch: Instant, skew_s: f64, site: u32) -> Self {
        RunClock { epoch, skew_s, site }
    }

    pub fn site(&self) -> u32 {
        self.site
    }

    pub fn skew_s(&self) -> f64 {
        self.skew_s
    }

    /// This site's clock reading for instant `t` (seconds; signed — an
    /// arrival scheduled before the epoch reads negative).
    pub fn offset_of(&self, t: Instant) -> f64 {
        let raw = if t >= self.epoch {
            t.duration_since(self.epoch).as_secs_f64()
        } else {
            -self.epoch.duration_since(t).as_secs_f64()
        };
        raw + self.skew_s
    }

    /// This site's clock reading for "now".
    pub fn now_s(&self) -> f64 {
        self.offset_of(Instant::now())
    }
}

impl Default for RunClock {
    fn default() -> Self {
        RunClock::at(Instant::now())
    }
}

/// Deterministic head-sampling predicate: a SplitMix64 finalizer over
/// the request's latent seed keeps half of all requests.  Keyed off
/// the *seed* — not arrival order, thread timing or wall clock — so a
/// recorded trace replayed anywhere reproduces the bit-identical
/// sampled span set.
pub fn head_sample(seed: u64) -> bool {
    mix64(seed ^ 0x9E37_79B9_7F4A_7C15) & 1 == 0
}

fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// The stamp set one request accumulates across its lifecycle.  All
/// offsets are in the stamping site's clock (`NaN` = not stamped yet);
/// everything from `ingest_s` on is guaranteed same-site, because a
/// spill hop re-bases `arrival_s` into the landing site's clock and
/// retires the home hop into the `prev_*` fields.  Fixed-size and
/// `Copy` so `RequestCtx` stays `Copy`.
#[derive(Debug, Clone, Copy)]
pub struct StageStamps {
    /// Scheduled arrival, re-based into `site`'s clock at ingest.
    pub arrival_s: f64,
    pub ingest_s: f64,
    pub admit_s: f64,
    pub cut_s: f64,
    pub dispatch_s: f64,
    pub exec_start_s: f64,
    pub exec_end_s: f64,
    pub reply_s: f64,
    /// Site whose clock stamped everything from `ingest_s` on.
    pub site: u32,
    /// That site's clock skew — carried so folds can re-base.
    pub skew_s: f64,
    /// Home site a spill hop left (`NO_SITE`: never spilled).  Only the
    /// first hop is retained: home → final landing site is the story a
    /// flow event tells.
    pub prev_site: u32,
    pub prev_skew_s: f64,
    /// The home site's intake stamp, on the home site's own clock.
    pub prev_ingest_s: f64,
    /// Deterministic head-sampling verdict ([`head_sample`]).
    pub sampled: bool,
}

impl Default for StageStamps {
    fn default() -> Self {
        StageStamps {
            arrival_s: f64::NAN,
            ingest_s: f64::NAN,
            admit_s: f64::NAN,
            cut_s: f64::NAN,
            dispatch_s: f64::NAN,
            exec_start_s: f64::NAN,
            exec_end_s: f64::NAN,
            reply_s: f64::NAN,
            site: NO_SITE,
            skew_s: 0.0,
            prev_site: NO_SITE,
            prev_skew_s: 0.0,
            prev_ingest_s: f64::NAN,
            sampled: false,
        }
    }
}

impl StageStamps {
    /// Stamp intake at `now`.  A re-ingest on a *different* site (a
    /// fleet spill hop) retires the previous hop into `prev_*`, voids
    /// the abandoned hop's later stamps, and re-bases the arrival into
    /// the new site's clock — so every subsequent same-site span is a
    /// plain difference, no skew arithmetic at record time.
    pub fn on_ingest(
        &mut self,
        clock: &RunClock,
        arrival: Instant,
        now: Instant,
        seed: u64,
    ) {
        if self.site != NO_SITE && self.site != clock.site() {
            if self.prev_site == NO_SITE {
                self.prev_site = self.site;
                self.prev_skew_s = self.skew_s;
                self.prev_ingest_s = self.ingest_s;
            }
            self.admit_s = f64::NAN;
            self.cut_s = f64::NAN;
            self.dispatch_s = f64::NAN;
            self.exec_start_s = f64::NAN;
            self.exec_end_s = f64::NAN;
            self.reply_s = f64::NAN;
        }
        self.site = clock.site();
        self.skew_s = clock.skew_s();
        self.arrival_s = clock.offset_of(arrival);
        self.ingest_s = clock.offset_of(now);
        self.sampled = head_sample(seed);
    }

    pub fn on_admit(&mut self, clock: &RunClock, now: Instant) {
        self.admit_s = clock.offset_of(now);
    }

    pub fn on_cut(&mut self, clock: &RunClock, now: Instant) {
        self.cut_s = clock.offset_of(now);
    }

    pub fn on_dispatch(&mut self, clock: &RunClock, now: Instant) {
        self.dispatch_s = clock.offset_of(now);
    }

    pub fn on_exec_start(&mut self, clock: &RunClock, now: Instant) {
        self.exec_start_s = clock.offset_of(now);
    }

    pub fn on_exec_end(&mut self, clock: &RunClock, now: Instant) {
        self.exec_end_s = clock.offset_of(now);
    }

    pub fn on_reply(&mut self, clock: &RunClock, now: Instant) {
        self.reply_s = clock.offset_of(now);
    }

    /// True once every lifecycle boundary is stamped.
    pub fn complete(&self) -> bool {
        self.starts().iter().all(|t| t.is_finite())
            && self.reply_s.is_finite()
    }

    /// True if this request overflowed cross-site at least once.
    pub fn spilled(&self) -> bool {
        self.prev_site != NO_SITE
    }

    /// Stage start stamps in lifecycle order, site-local clock.
    fn starts(&self) -> [f64; STAGE_COUNT] {
        [
            self.arrival_s,
            self.ingest_s,
            self.admit_s,
            self.cut_s,
            self.dispatch_s,
            self.exec_start_s,
            self.exec_end_s,
        ]
    }

    /// Per-stage durations in seconds, indexed by [`Stage::index`],
    /// clamped non-negative (all boundaries are same-site stamps of one
    /// monotonic clock, so only f64 noise can go sub-zero).  `None`
    /// until the lifecycle completed.
    pub fn stage_spans(&self) -> Option<[f64; STAGE_COUNT]> {
        if !self.complete() {
            return None;
        }
        let s = self.starts();
        let mut out = [0.0; STAGE_COUNT];
        for i in 0..STAGE_COUNT {
            let end = if i + 1 < STAGE_COUNT { s[i + 1] } else { self.reply_s };
            out[i] = (end - s[i]).max(0.0);
        }
        Some(out)
    }

    /// Stage start times re-based to *fleet* time (site skew removed) —
    /// the skew-corrected coherent timeline the exporter renders.
    pub fn rebased_starts(&self) -> Option<[f64; STAGE_COUNT]> {
        if !self.complete() {
            return None;
        }
        Some(self.starts().map(|t| t - self.skew_s))
    }

    /// The home-site intake stamp re-based to fleet time (`None` when
    /// the request never spilled).
    pub fn rebased_prev_ingest(&self) -> Option<f64> {
        if self.spilled() {
            Some(self.prev_ingest_s - self.prev_skew_s)
        } else {
            None
        }
    }
}

/// One drained span set: the request identity plus its stamps.
#[derive(Debug, Clone, Copy)]
pub struct SpanRecord {
    pub id: u64,
    pub seed: u64,
    pub class: PriorityClass,
    pub n_images: usize,
    pub stamps: StageStamps,
}

/// Bounded per-lane ring of [`SpanRecord`]s: fixed capacity, overwrite
/// oldest.  The buffer is allocated once (lane warm-up); every
/// steady-state push is a slot overwrite — zero allocation, per the
/// hotpath discipline.
#[derive(Debug, Clone)]
pub struct SpanRecorder {
    buf: Vec<SpanRecord>,
    /// Oldest slot once the ring is full (also the next write slot).
    head: usize,
    cap: usize,
    overwritten: u64,
}

impl SpanRecorder {
    pub fn new() -> Self {
        Self::with_capacity(SPAN_RING_CAPACITY)
    }

    pub fn with_capacity(cap: usize) -> Self {
        let cap = cap.max(1);
        SpanRecorder {
            buf: Vec::with_capacity(cap),
            head: 0,
            cap,
            overwritten: 0,
        }
    }

    pub fn push(&mut self, rec: SpanRecord) {
        if self.buf.len() < self.cap {
            self.buf.push(rec);
        } else {
            self.buf[self.head] = rec;
            self.head = (self.head + 1) % self.cap;
            self.overwritten += 1;
        }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Records the ring has dropped to make room (overwrite-oldest).
    pub fn overwritten(&self) -> u64 {
        self.overwritten
    }

    /// Retained records, oldest → newest.
    pub fn iter(&self) -> impl Iterator<Item = &SpanRecord> {
        let (tail, head) = self.buf.split_at(self.head);
        head.iter().chain(tail.iter())
    }

    /// Append another ring's records in order (fleet shard fold); the
    /// combined ring keeps the newest `capacity()` records overall.
    pub fn merge(&mut self, other: &SpanRecorder) {
        for r in other.iter() {
            self.push(*r);
        }
    }
}

impl Default for SpanRecorder {
    fn default() -> Self {
        Self::new()
    }
}

/// Render per-lane span rings as Chrome trace-event JSON (the format
/// Perfetto and `chrome://tracing` load): one track per lane
/// (`pid` = site, `tid` = lane), one complete (`"ph":"X"`) event per
/// lifecycle stage of every sampled request, and a flow-event pair
/// (`"s"` → `"f"`) plus a home-hop slice for every spill between site
/// tracks.  `spill_hops` carries hop stamp sets the rings never saw
/// (requests denied everywhere, or unsampled) so a fleet export always
/// shows its spills.  All timestamps are re-based to fleet time — the
/// per-site clock-skew correction — so a spilled request's cross-site
/// timeline renders coherently.
pub fn chrome_trace<'a>(
    lanes: impl IntoIterator<Item = (&'a str, &'a SpanRecorder)>,
    spill_hops: &[StageStamps],
) -> String {
    let mut events: Vec<String> = Vec::new();
    let mut sites_seen: BTreeMap<u32, ()> = BTreeMap::new();
    let mut flow_id: u64 = 0;
    // ts must be non-negative for chrome://tracing; clamp the rare
    // pre-epoch arrival stamp to the epoch
    let us = |t: f64| (t.max(0.0) * 1e6).round() as u64;

    for (tid, (lane, ring)) in lanes.into_iter().enumerate() {
        let tid = tid as u64 + 1;
        let mut lane_site = None;
        for rec in ring.iter() {
            let (Some(starts), Some(spans)) =
                (rec.stamps.rebased_starts(), rec.stamps.stage_spans())
            else {
                continue;
            };
            let site = if rec.stamps.site == NO_SITE { 0 } else { rec.stamps.site };
            sites_seen.insert(site, ());
            if lane_site.is_none() {
                lane_site = Some(site);
                events.push(format!(
                    "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":{site},\
                     \"tid\":{tid},\"args\":{{\"name\":\"{}\"}}}}",
                    escape_json(lane)
                ));
            }
            for stage in Stage::ALL {
                let i = stage.index();
                events.push(format!(
                    "{{\"name\":\"{}\",\"cat\":\"stage\",\"ph\":\"X\",\
                     \"ts\":{},\"dur\":{},\"pid\":{site},\"tid\":{tid},\
                     \"args\":{{\"id\":{},\"seed\":{},\"class\":\"{}\",\
                     \"images\":{}}}}}",
                    stage.as_str(),
                    us(starts[i]),
                    us(spans[i]),
                    rec.id,
                    rec.seed,
                    rec.class.as_str(),
                    rec.n_images,
                ));
            }
            if let Some(prev_t) = rec.stamps.rebased_prev_ingest() {
                flow_id += 1;
                spill_events(
                    &mut events,
                    &mut sites_seen,
                    flow_id,
                    rec.stamps.prev_site,
                    prev_t,
                    site,
                    tid,
                    starts[Stage::Intake.index()]
                        + spans[Stage::Intake.index()],
                );
            }
        }
    }

    // hop stamp sets the rings never captured (denied or unsampled)
    for hop in spill_hops {
        let Some(prev_t) = hop.rebased_prev_ingest() else { continue };
        if !hop.ingest_s.is_finite() {
            continue;
        }
        let site = if hop.site == NO_SITE { 0 } else { hop.site };
        sites_seen.insert(site, ());
        flow_id += 1;
        spill_events(
            &mut events,
            &mut sites_seen,
            flow_id,
            hop.prev_site,
            prev_t,
            site,
            0,
            hop.ingest_s - hop.skew_s,
        );
    }

    let mut meta: Vec<String> = sites_seen
        .keys()
        .map(|site| {
            format!(
                "{{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":{site},\
                 \"args\":{{\"name\":\"site{site}\"}}}}"
            )
        })
        .collect();
    meta.extend(events);
    format!(
        "{{\"displayTimeUnit\":\"ms\",\"traceEvents\":[{}]}}\n",
        meta.join(",")
    )
}

/// The three events one spill hop renders: a home-hop slice on the
/// origin site's spill track, and the `"s"` → `"f"` flow pair landing
/// on the destination's intake.
#[allow(clippy::too_many_arguments)]
fn spill_events(
    events: &mut Vec<String>,
    sites_seen: &mut BTreeMap<u32, ()>,
    flow_id: u64,
    prev_site: u32,
    prev_t: f64,
    site: u32,
    tid: u64,
    land_t: f64,
) {
    let us = |t: f64| (t.max(0.0) * 1e6).round() as u64;
    let prev_site = if prev_site == NO_SITE { 0 } else { prev_site };
    sites_seen.insert(prev_site, ());
    let dur = ((land_t - prev_t).max(1e-6) * 1e6).round() as u64;
    events.push(format!(
        "{{\"name\":\"spill_origin\",\"cat\":\"spill\",\"ph\":\"X\",\
         \"ts\":{},\"dur\":{dur},\"pid\":{prev_site},\"tid\":0}}",
        us(prev_t)
    ));
    events.push(format!(
        "{{\"name\":\"spill\",\"cat\":\"spill\",\"ph\":\"s\",\
         \"id\":{flow_id},\"ts\":{},\"pid\":{prev_site},\"tid\":0}}",
        us(prev_t)
    ));
    events.push(format!(
        "{{\"name\":\"spill\",\"cat\":\"spill\",\"ph\":\"f\",\"bp\":\"e\",\
         \"id\":{flow_id},\"ts\":{},\"pid\":{site},\"tid\":{tid}}}",
        us(land_t)
    ));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::parse_json;
    use std::time::Duration;

    fn clock(skew_ms: f64, site: u32, epoch: Instant) -> RunClock {
        RunClock::with_site(epoch, skew_ms / 1000.0, site)
    }

    /// Walk a request through every boundary on one clock, `step` apart.
    fn full_stamps(clock: &RunClock, epoch: Instant, seed: u64) -> StageStamps {
        let mut st = StageStamps::default();
        let t = |k: u32| epoch + Duration::from_millis(k as u64);
        st.on_ingest(clock, t(0), t(1), seed);
        st.on_admit(clock, t(2));
        st.on_cut(clock, t(4));
        st.on_dispatch(clock, t(5));
        st.on_exec_start(clock, t(6));
        st.on_exec_end(clock, t(9));
        st.on_reply(clock, t(10));
        st
    }

    #[test]
    fn stage_spans_telescope_to_end_to_end() {
        let epoch = Instant::now();
        let c = clock(0.0, 0, epoch);
        let st = full_stamps(&c, epoch, 7);
        assert!(st.complete());
        assert!(!st.spilled());
        let spans = st.stage_spans().unwrap();
        let total: f64 = spans.iter().sum();
        let e2e = st.reply_s - st.arrival_s;
        assert!(
            (total - e2e).abs() < 1e-9,
            "spans must telescope: {total} vs {e2e}"
        );
        // and each boundary is where the walk put it
        assert!((spans[Stage::DeviceExecute.index()] - 0.003).abs() < 1e-9);
        assert!((spans[Stage::Intake.index()] - 0.001).abs() < 1e-9);
    }

    #[test]
    fn skewed_clocks_rebase_to_a_monotone_cross_site_timeline() {
        let epoch = Instant::now();
        // home site runs 5 ms fast, landing site 4 ms slow: the raw
        // stamps lie about ordering, the re-based ones cannot
        let home = clock(5.0, 0, epoch);
        let land = clock(-4.0, 1, epoch);
        let mut st = StageStamps::default();
        let t = |k: u64| epoch + Duration::from_millis(k);
        st.on_ingest(&home, t(0), t(1), 3);
        // denied at home; the fleet resubmits the same ctx at site 1
        st.on_ingest(&land, t(0), t(3), 3);
        assert!(st.spilled());
        assert_eq!(st.prev_site, 0);
        assert_eq!(st.site, 1);
        // raw: home ingest reads 6 ms, landing ingest reads -1 ms —
        // non-monotone on the face of it
        assert!(st.prev_ingest_s > st.ingest_s);
        // re-based: 1 ms then 3 ms — coherent
        let prev = st.rebased_prev_ingest().unwrap();
        let ingest = st.ingest_s - st.skew_s;
        assert!(prev < ingest, "skew correction restores order");
        assert!((prev - 0.001).abs() < 1e-9);
        assert!((ingest - 0.003).abs() < 1e-9);
        // complete the landing hop: spans are same-site differences
        st.on_admit(&land, t(4));
        st.on_cut(&land, t(5));
        st.on_dispatch(&land, t(6));
        st.on_exec_start(&land, t(7));
        st.on_exec_end(&land, t(8));
        st.on_reply(&land, t(9));
        let spans = st.stage_spans().unwrap();
        let total: f64 = spans.iter().sum();
        assert!((total - 0.009).abs() < 1e-9, "arrival → reply, skew-free");
        let starts = st.rebased_starts().unwrap();
        for w in starts.windows(2) {
            assert!(w[0] <= w[1] + 1e-12, "re-based timeline monotone");
        }
    }

    #[test]
    fn head_sampling_is_deterministic_and_near_half() {
        let kept = (0..10_000u64).filter(|s| head_sample(*s)).count();
        assert!(
            (3_500..=6_500).contains(&kept),
            "a mixed predicate keeps about half: {kept}"
        );
        for seed in [0u64, 1, 42, u64::MAX] {
            assert_eq!(head_sample(seed), head_sample(seed));
        }
    }

    #[test]
    fn span_ring_overwrites_oldest_at_fixed_capacity() {
        let epoch = Instant::now();
        let c = clock(0.0, 0, epoch);
        let mut ring = SpanRecorder::with_capacity(4);
        for id in 0..6u64 {
            ring.push(SpanRecord {
                id,
                seed: id,
                class: PriorityClass::Normal,
                n_images: 1,
                stamps: full_stamps(&c, epoch, id),
            });
        }
        assert_eq!(ring.len(), 4);
        assert_eq!(ring.capacity(), 4);
        assert_eq!(ring.overwritten(), 2);
        let ids: Vec<u64> = ring.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![2, 3, 4, 5], "oldest → newest, oldest dropped");
        // merge appends in order under the same bound
        let mut other = SpanRecorder::with_capacity(4);
        other.push(SpanRecord {
            id: 9,
            seed: 9,
            class: PriorityClass::Low,
            n_images: 2,
            stamps: full_stamps(&c, epoch, 9),
        });
        ring.merge(&other);
        let ids: Vec<u64> = ring.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![3, 4, 5, 9]);
    }

    #[test]
    fn chrome_trace_renders_stages_and_spill_flows() {
        let epoch = Instant::now();
        let c0 = clock(2.0, 0, epoch);
        let c1 = clock(-1.0, 1, epoch);
        let mut ring = SpanRecorder::with_capacity(8);
        ring.push(SpanRecord {
            id: 1,
            seed: 1,
            class: PriorityClass::Normal,
            n_images: 2,
            stamps: full_stamps(&c0, epoch, 1),
        });
        // a spilled request that landed on site 1
        let mut spilled = StageStamps::default();
        let t = |k: u64| epoch + Duration::from_millis(k);
        spilled.on_ingest(&c0, t(0), t(1), 2);
        spilled.on_ingest(&c1, t(0), t(3), 2);
        spilled.on_admit(&c1, t(4));
        spilled.on_cut(&c1, t(5));
        spilled.on_dispatch(&c1, t(6));
        spilled.on_exec_start(&c1, t(7));
        spilled.on_exec_end(&c1, t(8));
        spilled.on_reply(&c1, t(9));
        let mut ring1 = SpanRecorder::with_capacity(8);
        ring1.push(SpanRecord {
            id: 2,
            seed: 2,
            class: PriorityClass::High,
            n_images: 1,
            stamps: spilled,
        });
        let lanes: Vec<(&str, &SpanRecorder)> =
            vec![("s0/fpga0", &ring), ("s1/fpga0", &ring1)];
        let json = chrome_trace(lanes, &[]);

        let v = parse_json(&json).expect("trace must be valid JSON");
        let evs = v.req("traceEvents").unwrap().as_arr().unwrap();
        for stage in Stage::ALL {
            assert!(
                evs.iter().any(|e| {
                    e.req("ph").unwrap().as_str().unwrap() == "X"
                        && e.req("name").unwrap().as_str().unwrap()
                            == stage.as_str()
                }),
                "missing a complete event for stage {}",
                stage.as_str()
            );
        }
        for ph in ["s", "f"] {
            assert!(
                evs.iter().any(|e| {
                    e.req("ph").unwrap().as_str().unwrap() == ph
                }),
                "spilled record must emit a {ph} flow event"
            );
        }
        // both site tracks named
        assert!(json.contains("site0") && json.contains("site1"));

        // an un-ringed denial hop still renders its flow pair
        let mut denied = StageStamps::default();
        denied.on_ingest(&c0, t(0), t(1), 5);
        denied.on_ingest(&c1, t(0), t(2), 5);
        let empty: Vec<(&str, &SpanRecorder)> = Vec::new();
        let json = chrome_trace(empty, &[denied]);
        let v = parse_json(&json).unwrap();
        let evs = v.req("traceEvents").unwrap().as_arr().unwrap();
        assert!(evs.iter().any(|e| {
            e.req("ph").unwrap().as_str().unwrap() == "s"
        }));
    }
}
