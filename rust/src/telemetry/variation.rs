//! Run-to-run variation statistics — the quantitative form of the
//! paper's headline claim (the FPGA's σ/μ is tiny, the TX1 GPU's is
//! not).  Coefficient of variation over repeated trials plus a
//! seeded-bootstrap confidence interval for the mean, built on
//! [`crate::stats::Welford`].

use crate::stats::{percentile, Welford};
use crate::util::Rng;

/// Bootstrap resamples drawn for the CI of the mean (percentile
/// bootstrap; Efron 1979).  256 keeps the report path cheap while the
/// CI endpoints stabilize to well under the effect sizes compared here.
const BOOTSTRAP_RESAMPLES: usize = 256;

/// Summary of a repeated-measurement series.
#[derive(Debug, Clone, Copy, Default)]
pub struct Variation {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    /// Coefficient of variation σ/μ (0 when the mean is 0 or n < 2).
    pub cv: f64,
    /// 95% percentile-bootstrap CI of the mean.
    pub ci_lo: f64,
    pub ci_hi: f64,
}

/// Coefficient of variation of an accumulated [`Welford`] series.
pub fn cv_of(w: &Welford) -> f64 {
    let mean = w.mean();
    if w.count() < 2 || mean == 0.0 {
        0.0
    } else {
        w.sample_std() / mean.abs()
    }
}

/// Sample-weighted mean CV over several [`Welford`] series — one
/// stability number for a source with several legitimately different
/// operating points (a lane serving `mnist` *and* its `mnist.q` twin
/// runs two service times; pooling them into one series would report
/// the workload mix as device jitter).
pub fn weighted_cv<'a>(series: impl Iterator<Item = &'a Welford>) -> f64 {
    let mut total = 0usize;
    let mut acc = 0.0;
    for w in series {
        total += w.count();
        acc += cv_of(w) * w.count() as f64;
    }
    if total == 0 {
        0.0
    } else {
        acc / total as f64
    }
}

/// Summarize repeated trial measurements: mean/σ/CV plus a seeded
/// percentile-bootstrap 95% CI of the mean (deterministic given `seed`).
pub fn variation_of(values: &[f64], seed: u64) -> Variation {
    if values.is_empty() {
        return Variation::default();
    }
    let mut w = Welford::new();
    for &v in values {
        w.push(v);
    }
    let (ci_lo, ci_hi) = if values.len() < 2 {
        (w.mean(), w.mean())
    } else {
        let mut rng = Rng::seed_from_u64(seed);
        let mut means = Vec::with_capacity(BOOTSTRAP_RESAMPLES);
        for _ in 0..BOOTSTRAP_RESAMPLES {
            let mut r = Welford::new();
            for _ in 0..values.len() {
                r.push(values[rng.range_usize(0, values.len())]);
            }
            means.push(r.mean());
        }
        (percentile(&means, 2.5), percentile(&means, 97.5))
    };
    Variation {
        n: w.count(),
        mean: w.mean(),
        std: w.sample_std(),
        cv: cv_of(&w),
        ci_lo,
        ci_hi,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cv_matches_hand_computation() {
        let mut w = Welford::new();
        for v in [9.0, 10.0, 11.0] {
            w.push(v);
        }
        assert!((cv_of(&w) - 1.0 / 10.0).abs() < 1e-12);
        let mut one = Welford::new();
        one.push(5.0);
        assert_eq!(cv_of(&one), 0.0, "undefined below two samples");
    }

    #[test]
    fn bootstrap_ci_brackets_the_mean_and_is_seeded() {
        let vals: Vec<f64> = (0..40).map(|i| 10.0 + (i as f64).sin()).collect();
        let a = variation_of(&vals, 9);
        let b = variation_of(&vals, 9);
        assert_eq!(a.ci_lo, b.ci_lo, "deterministic given seed");
        assert_eq!(a.ci_hi, b.ci_hi);
        assert!(a.ci_lo <= a.mean && a.mean <= a.ci_hi);
        assert!(a.ci_hi - a.ci_lo < 2.0 * a.std, "CI tighter than ±2σ at n=40");
        // a wider-spread series yields a wider CI
        let noisy: Vec<f64> =
            (0..40).map(|i| 10.0 + 5.0 * (i as f64 * 1.7).sin()).collect();
        let c = variation_of(&noisy, 9);
        assert!(c.ci_hi - c.ci_lo > a.ci_hi - a.ci_lo);
        assert!(c.cv > a.cv);
    }

    #[test]
    fn weighted_cv_ignores_cross_series_spread() {
        // two constant series at very different levels: each has cv 0,
        // so the weighted CV must be 0 (pooling them would not be)
        let mut slow = Welford::new();
        let mut fast = Welford::new();
        for _ in 0..10 {
            slow.push(4.0);
            fast.push(1.0);
        }
        assert_eq!(weighted_cv([&slow, &fast].into_iter()), 0.0);
        // weighting: a 3x-larger series pulls the average toward it
        let mut noisy = Welford::new();
        for i in 0..30 {
            noisy.push(10.0 + (i % 2) as f64);
        }
        let w = weighted_cv([&slow, &noisy].into_iter());
        assert!(w > 0.5 * cv_of(&noisy), "cv {w} vs {}", cv_of(&noisy));
        assert!(w < cv_of(&noisy));
        assert_eq!(weighted_cv(std::iter::empty::<&Welford>()), 0.0);
    }

    #[test]
    fn degenerate_series() {
        let empty = variation_of(&[], 1);
        assert_eq!(empty.n, 0);
        assert_eq!(empty.cv, 0.0);
        let one = variation_of(&[3.5], 1);
        assert_eq!(one.n, 1);
        assert_eq!(one.mean, 3.5);
        assert_eq!((one.ci_lo, one.ci_hi), (3.5, 3.5));
        let constant = variation_of(&[2.0; 10], 1);
        assert_eq!(constant.cv, 0.0);
    }
}
