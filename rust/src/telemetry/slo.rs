//! SLO attainment counters — "what fraction of requests came back under
//! the deadline" as two integers, not a sample vector.

/// Counts samples under a fixed latency objective.
#[derive(Debug, Clone, Copy)]
pub struct SloCounter {
    threshold_s: f64,
    total: u64,
    met: u64,
}

impl SloCounter {
    pub fn new(threshold_s: f64) -> Self {
        assert!(threshold_s > 0.0, "SLO threshold must be positive");
        SloCounter {
            threshold_s,
            total: 0,
            met: 0,
        }
    }

    pub fn record(&mut self, latency_s: f64) {
        self.total += 1;
        if latency_s <= self.threshold_s {
            self.met += 1;
        }
    }

    pub fn threshold_s(&self) -> f64 {
        self.threshold_s
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    pub fn met(&self) -> u64 {
        self.met
    }

    /// Attainment in `[0, 1]`; an empty window attains vacuously.
    pub fn attainment(&self) -> f64 {
        if self.total == 0 {
            1.0
        } else {
            self.met as f64 / self.total as f64
        }
    }

    /// Merge a shard (same threshold).
    pub fn merge(&mut self, other: &SloCounter) {
        assert_eq!(self.threshold_s, other.threshold_s, "threshold mismatch");
        self.total += other.total;
        self.met += other.met;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn attainment_counts_boundary_inclusive() {
        let mut s = SloCounter::new(0.050);
        assert_eq!(s.attainment(), 1.0, "vacuous on empty");
        s.record(0.010);
        s.record(0.050); // exactly at the objective counts as met
        s.record(0.051);
        s.record(0.500);
        assert_eq!(s.total(), 4);
        assert_eq!(s.met(), 2);
        assert!((s.attainment() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn shards_merge() {
        let mut a = SloCounter::new(0.1);
        a.record(0.05);
        let mut b = SloCounter::new(0.1);
        b.record(0.2);
        b.record(0.01);
        a.merge(&b);
        assert_eq!(a.total(), 3);
        assert_eq!(a.met(), 2);
    }

    #[test]
    fn merge_is_associative_across_three_shards() {
        // the property the fleet fold relies on: fold(a, fold(b, c)) ==
        // fold(fold(a, b), c) == counting the concatenated stream
        let streams = [
            vec![0.01, 0.09, 0.20],
            vec![0.05, 0.11],
            vec![0.02, 0.02, 0.30, 0.04],
        ];
        let shard = |vals: &[f64]| {
            let mut s = SloCounter::new(0.1);
            for v in vals {
                s.record(*v);
            }
            s
        };
        let [a, b, c] = [shard(&streams[0]), shard(&streams[1]), shard(&streams[2])];
        let mut left = a; // Copy
        left.merge(&b);
        left.merge(&c);
        let mut bc = b;
        bc.merge(&c);
        let mut right = a;
        right.merge(&bc);
        let mut direct = SloCounter::new(0.1);
        for v in streams.iter().flatten() {
            direct.record(*v);
        }
        for s in [left, right] {
            assert_eq!(s.total(), direct.total());
            assert_eq!(s.met(), direct.met());
            assert_eq!(s.attainment(), direct.attainment());
        }
    }

    #[test]
    #[should_panic]
    fn merge_rejects_threshold_mismatch() {
        let mut a = SloCounter::new(0.1);
        a.merge(&SloCounter::new(0.2));
    }
}
