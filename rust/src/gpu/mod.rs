//! Jetson TX1 edge-GPU baseline model (Section V-B).
//!
//! The paper's GPU story has two parts: (a) mean per-layer GOps/s/W from
//! Torch+cuDNN-style execution, and (b) *large run-to-run variation*
//! caused by the GPU's time-varying optimizations and thermal throttling
//! ("reducing clock frequency to lower power and cool the chip").  Both
//! are modeled here: an analytical kernel-timing model (launch overhead +
//! roofline of compute and memory) driven by a DVFS thermal state
//! machine, with nvprof-style measurement noise.

mod model;
mod throttle;

pub use model::{
    expected_gpu_network_run, expected_gpu_network_time,
    expected_gpu_network_time_at, expected_time_s, measured_gpu_network_run,
    simulate_gpu_layer, simulate_gpu_network, GpuLayerRun, GpuRunOpts,
};
pub use throttle::ThermalThrottle;
