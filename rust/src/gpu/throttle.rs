//! DVFS thermal-throttling state machine for the TX1 model.
//!
//! A leaky-integrator die temperature rises with dissipated power and
//! relaxes toward ambient; when it crosses the throttle threshold the
//! governor steps the clock down (and back up once cool).  This is the
//! mechanism the paper cites (via the Jetson Linux Developer Guide) for
//! the GPU's run-to-run variance: two identical runs land at different
//! points of the heat-up/cool-down cycle and see different clocks.

use crate::config::GpuBoard;

/// Thermal + DVFS state, advanced per simulated kernel execution.
#[derive(Debug, Clone)]
pub struct ThermalThrottle {
    /// Die temperature above ambient, °C.
    pub temp_c: f64,
    /// Current core clock, Hz.
    pub clock_hz: f64,
    board: GpuBoard,
    /// Temperature rise per joule dissipated (°C/J).
    heat_per_joule: f64,
    /// Exponential cooling time constant, seconds.
    cool_tau_s: f64,
    /// Throttle engage threshold (°C above ambient).
    hot_c: f64,
    /// Throttle release threshold.
    cool_c: f64,
}

impl ThermalThrottle {
    pub fn new(board: GpuBoard) -> Self {
        ThermalThrottle {
            temp_c: 0.0,
            clock_hz: board.boost_clock_hz,
            board,
            // TX1 module: ~3 J heats the small die+plate ≈ 1 °C
            heat_per_joule: 0.35,
            cool_tau_s: 12.0,
            hot_c: 28.0,  // ≈ 25 °C ambient + 28 → 53 °C soft limit
            cool_c: 22.0,
        }
    }

    /// Advance the state by one kernel execution dissipating
    /// `power_w × dt_s` joules, then applying `idle_s` of cooling.
    pub fn step(&mut self, power_w: f64, dt_s: f64, idle_s: f64) {
        self.temp_c += power_w * dt_s * self.heat_per_joule;
        let total = dt_s + idle_s;
        self.temp_c *= (-total / self.cool_tau_s).exp();
        if self.temp_c >= self.hot_c {
            self.clock_hz = self.board.throttle_clock_hz;
        } else if self.temp_c <= self.cool_c {
            self.clock_hz = self.board.boost_clock_hz;
        }
        // between thresholds: hysteresis keeps the previous clock
    }

    /// Is the governor currently throttling?
    pub fn throttled(&self) -> bool {
        self.clock_hz < self.board.boost_clock_hz
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::JETSON_TX1;

    #[test]
    fn starts_cool_at_boost() {
        let t = ThermalThrottle::new(JETSON_TX1);
        assert!(!t.throttled());
        assert_eq!(t.clock_hz, JETSON_TX1.boost_clock_hz);
    }

    #[test]
    fn sustained_load_throttles_then_recovers() {
        let mut t = ThermalThrottle::new(JETSON_TX1);
        // hammer: 11 W for 3 s chunks, no idle
        let mut throttled_seen = false;
        for _ in 0..40 {
            t.step(11.0, 3.0, 0.0);
            throttled_seen |= t.throttled();
        }
        assert!(throttled_seen, "sustained load must throttle");
        // long idle cools it back down
        for _ in 0..20 {
            t.step(0.0, 0.0, 10.0);
        }
        assert!(!t.throttled(), "cooldown must restore boost clock");
    }

    #[test]
    fn hysteresis_holds_between_thresholds() {
        let mut t = ThermalThrottle::new(JETSON_TX1);
        t.temp_c = 25.0; // between cool (22) and hot (28)
        t.clock_hz = JETSON_TX1.throttle_clock_hz;
        t.step(0.0, 0.0, 1e-9); // negligible change
        assert!(t.throttled(), "hysteresis must keep throttled clock");
    }
}
