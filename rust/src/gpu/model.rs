//! Analytical Jetson TX1 timing/power model (Torch + cuDNN-style
//! deconvolution execution, measured nvprof-style).
//!
//! Per layer: `time = launch + max(compute, memory)` where compute runs
//! at the DVFS-governed clock with a utilization factor shaped by the
//! implicit-GEMM dimensions of the transposed convolution
//! (`M = C_out`, `N = O_H·O_W`, `K = C_in·K_h·K_w`), and memory moves the
//! feature maps + weights at LPDDR4 bandwidth.  Calibration constants are
//! documented inline; the run-to-run *variance* comes from the
//! [`ThermalThrottle`] state machine plus measurement noise, not from a
//! dialed-in σ table.
//!
//! Unstructured sparsity deliberately gives **no** speed-up here: the
//! SIMT pipeline executes the zero-multiplies anyway (the paper's
//! Section V-C premise for why pruning only helps the FPGA).

use super::throttle::ThermalThrottle;
use crate::config::{DeconvLayerCfg, GpuBoard, NetworkCfg};
use crate::util::Rng;

/// Peak fraction a deconvolution reaches on this part even with perfect
/// shapes (Maxwell fp32 implicit-gemm ceiling ≈ 12% on edge parts:
/// cuDNN's transposed conv never approaches the dense-gemm roofline).
const U_MAX: f64 = 0.10;
/// MACs at which utilization reaches half of its asymptote.
const MACS_HALF: f64 = 2.0e6;
/// Penalty for non-power-of-two kernels (K=7 hits cuDNN's generic path).
const ODD_KERNEL_PENALTY: f64 = 0.35;
/// GEMM-N half-saturation (output pixels per image).
const N_HALF: f64 = 48.0;
/// GEMM-M half-saturation (output channels).
const M_HALF: f64 = 6.0;
/// Probability of an OS/daemon interference stall on a measured run.
const STALL_PROB: f64 = 0.05;
/// Multiplicative magnitude of an interference stall.
const STALL_FACTOR: f64 = 1.25;
/// σ of the multiplicative timing noise (time-varying optimizations,
/// cache state, nvprof sampling).
const TIME_NOISE_SD: f64 = 0.09;
/// σ of the power measurement noise.
const POWER_NOISE_SD: f64 = 0.05;

/// Options for a GPU layer execution.
#[derive(Debug, Clone, Copy)]
pub struct GpuRunOpts {
    /// Images per batch (the paper evaluates batch 1 at the edge).
    pub batch: usize,
    /// Weight sparsity — present for interface parity with the FPGA;
    /// it does NOT change the timing (SIMT executes the zeros).
    pub weight_sparsity: f64,
}

impl Default for GpuRunOpts {
    fn default() -> Self {
        GpuRunOpts {
            batch: 1,
            weight_sparsity: 0.0,
        }
    }
}

/// One measured layer execution.
#[derive(Debug, Clone, Copy)]
pub struct GpuLayerRun {
    pub ops: u64,
    pub time_s: f64,
    pub gops: f64,
    pub power_w: f64,
    pub gops_per_w: f64,
    /// Clock the DVFS governor held during this run.
    pub clock_hz: f64,
    pub throttled: bool,
}

/// Deterministic (noise-free) utilization of a layer at batch size `n`.
fn utilization(layer: &DeconvLayerCfg, batch: usize) -> f64 {
    let o = layer.o_h();
    let macs = layer.macs() as f64 * batch as f64;
    let sat = macs / (macs + MACS_HALF);
    let n_dim = (o * o * batch) as f64;
    let m_dim = layer.c_out as f64;
    let k_pen = if layer.k.is_power_of_two() {
        1.0
    } else {
        ODD_KERNEL_PENALTY
    };
    U_MAX * sat * k_pen * (n_dim / (n_dim + N_HALF))
        * (m_dim / (m_dim + M_HALF)).sqrt()
}

/// Bytes the kernel moves through LPDDR4 (activations + weights, plus
/// the zero-inserted scratch cuDNN materializes for strided deconv).
fn memory_bytes(layer: &DeconvLayerCfg, batch: usize) -> u64 {
    let scratch = if layer.stride > 1 {
        // zero-inserted input scratch: (I·S)² per channel
        4 * layer.c_in as u64
            * ((layer.i_h * layer.stride) as u64).pow(2)
    } else {
        0
    };
    batch as u64 * (layer.input_bytes() + layer.output_bytes() + scratch)
        + layer.weight_bytes()
}

/// Noise-free expected execution time at a given clock.
pub fn expected_time_s(
    layer: &DeconvLayerCfg,
    board: &GpuBoard,
    clock_hz: f64,
    batch: usize,
) -> f64 {
    let util = utilization(layer, batch);
    let flops = 2.0 * layer.macs() as f64 * batch as f64;
    let compute = flops / (board.peak_gops_at(clock_hz) * 1e9 * util);
    let memory = memory_bytes(layer, batch) as f64 / board.mem_bw_bytes;
    board.launch_overhead_s + compute.max(memory)
}

/// Execute one layer once, advancing the thermal state and applying
/// measurement noise — one nvprof sample.
pub fn simulate_gpu_layer(
    layer: &DeconvLayerCfg,
    board: &GpuBoard,
    opts: &GpuRunOpts,
    throttle: &mut ThermalThrottle,
    rng: &mut Rng,
) -> GpuLayerRun {
    let clock = throttle.clock_hz;
    let base_time = expected_time_s(layer, board, clock, opts.batch);
    let mut time = base_time * rng.normal_with(1.0, TIME_NOISE_SD).max(0.6);
    if rng.gen_bool(STALL_PROB) {
        time *= STALL_FACTOR;
    }

    let util = utilization(layer, opts.batch);
    // Power scales with achieved occupancy; throttled clock also drops V.
    let clock_frac = clock / board.boost_clock_hz;
    let base_power = board.idle_power_w
        + (board.load_power_w - board.idle_power_w)
            * (0.25 + 0.75 * util / U_MAX)
            * clock_frac.powi(2);
    let power = (base_power * rng.normal_with(1.0, POWER_NOISE_SD))
        .max(board.idle_power_w);

    // Heat the die with the dissipated energy; brief host-side gap after.
    throttle.step(power, time, 0.2e-3);

    let ops = layer.ops() * opts.batch as u64;
    let gops = ops as f64 / time / 1e9;
    GpuLayerRun {
        ops,
        time_s: time,
        gops,
        power_w: power,
        gops_per_w: gops / power,
        clock_hz: clock,
        throttled: clock < board.boost_clock_hz,
    }
}

/// Noise-free expected time for a whole network at the *current* DVFS
/// state, advancing the thermal model (used by the coordinator for the
/// per-batch GPU annotation).
pub fn expected_gpu_network_time(
    net: &NetworkCfg,
    board: &GpuBoard,
    throttle: &mut ThermalThrottle,
    batch: usize,
) -> f64 {
    expected_gpu_network_run(net, board, throttle, batch).0
}

/// Noise-free expected `(time_s, energy_j)` for a whole network at the
/// *current* DVFS state, advancing the thermal model per layer — this is
/// the [`crate::backend::GpuModelBackend`] execution model: the throttle
/// is owned device state, so back-to-back batches heat the die and land
/// at different clocks.
pub fn expected_gpu_network_run(
    net: &NetworkCfg,
    board: &GpuBoard,
    throttle: &mut ThermalThrottle,
    batch: usize,
) -> (f64, f64) {
    let mut total = 0.0;
    let mut energy = 0.0;
    for l in &net.layers {
        let t = expected_time_s(l, board, throttle.clock_hz, batch);
        let util = utilization(l, batch);
        let power = board.idle_power_w
            + (board.load_power_w - board.idle_power_w)
                * (0.25 + 0.75 * util / U_MAX);
        throttle.step(power, t, 0.0);
        total += t;
        energy += power * t;
    }
    (total, energy)
}

/// One *measured* whole-network run at the current DVFS state — a
/// [`simulate_gpu_layer`] sample per layer (expected account × the
/// nvprof-style time/stall/power noise), summed.  This is the per-batch
/// execution model of [`crate::backend::GpuModelBackend`], whose
/// serving lane is a stream of measured runs, not of noise-free
/// expectations — the same one model Table II draws from.  Advances
/// the thermal state per layer; returns `(time_s, energy_j)`.
pub fn measured_gpu_network_run(
    net: &NetworkCfg,
    board: &GpuBoard,
    throttle: &mut ThermalThrottle,
    batch: usize,
    rng: &mut Rng,
) -> (f64, f64) {
    let opts = GpuRunOpts {
        batch,
        weight_sparsity: 0.0,
    };
    let mut total = 0.0;
    let mut energy = 0.0;
    for l in &net.layers {
        let run = simulate_gpu_layer(l, board, &opts, throttle, rng);
        total += run.time_s;
        energy += run.time_s * run.power_w;
    }
    (total, energy)
}

/// Noise-free expected network time at a *fixed* clock, touching no
/// thermal state — the scheduler's cost estimate (a routing probe must
/// not heat the die it is only asking about).
pub fn expected_gpu_network_time_at(
    net: &NetworkCfg,
    board: &GpuBoard,
    clock_hz: f64,
    batch: usize,
) -> f64 {
    net.layers
        .iter()
        .map(|l| expected_time_s(l, board, clock_hz, batch))
        .sum()
}

/// Execute all layers of a network once (layer-by-layer, as Torch does).
pub fn simulate_gpu_network(
    net: &NetworkCfg,
    board: &GpuBoard,
    opts: &GpuRunOpts,
    throttle: &mut ThermalThrottle,
    rng: &mut Rng,
) -> Vec<GpuLayerRun> {
    net.layers
        .iter()
        .map(|l| simulate_gpu_layer(l, board, opts, throttle, rng))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{celeba, mnist, JETSON_TX1};
    use crate::stats::Summary;

    #[test]
    fn utilization_in_bounds_and_shape_sensitive() {
        for net in [mnist(), celeba()] {
            for l in &net.layers {
                let u = utilization(l, 1);
                assert!(u > 0.0 && u <= U_MAX, "u={u}");
            }
        }
        // the 7×7 mnist head is penalized relative to a 4×4 layer of
        // comparable work
        let m = mnist();
        assert!(
            utilization(&m.layers[0], 1) < utilization(&celeba().layers[1], 1)
        );
    }

    #[test]
    fn batching_helps_throughput() {
        let l = &mnist().layers[1];
        let t1 = expected_time_s(l, &JETSON_TX1, JETSON_TX1.boost_clock_hz, 1);
        let t8 = expected_time_s(l, &JETSON_TX1, JETSON_TX1.boost_clock_hz, 8);
        assert!(t8 < 8.0 * t1, "batching must amortize");
    }

    #[test]
    fn sparsity_gives_no_gpu_speedup() {
        let l = &celeba().layers[2];
        let mut th = ThermalThrottle::new(JETSON_TX1);
        let mut rng = Rng::seed_from_u64(5);
        let dense: Vec<f64> = (0..30)
            .map(|_| {
                simulate_gpu_layer(
                    l, &JETSON_TX1, &GpuRunOpts::default(), &mut th, &mut rng,
                )
                .time_s
            })
            .collect();
        let mut th2 = ThermalThrottle::new(JETSON_TX1);
        let mut rng2 = Rng::seed_from_u64(5);
        let sparse: Vec<f64> = (0..30)
            .map(|_| {
                simulate_gpu_layer(
                    l,
                    &JETSON_TX1,
                    &GpuRunOpts { batch: 1, weight_sparsity: 0.9 },
                    &mut th2,
                    &mut rng2,
                )
                .time_s
            })
            .collect();
        assert_eq!(dense, sparse, "SIMT executes the zeros");
    }

    #[test]
    fn run_to_run_variation_is_large() {
        let net = mnist();
        let mut th = ThermalThrottle::new(JETSON_TX1);
        let mut rng = Rng::seed_from_u64(7);
        let mut ratios = Vec::new();
        for _ in 0..50 {
            let runs = simulate_gpu_network(
                &net, &JETSON_TX1, &GpuRunOpts::default(), &mut th, &mut rng,
            );
            let ops: u64 = runs.iter().map(|r| r.ops).sum();
            let t: f64 = runs.iter().map(|r| r.time_s).sum();
            let e: f64 = runs.iter().map(|r| r.time_s * r.power_w).sum();
            ratios.push(ops as f64 / t / 1e9 / (e / t));
        }
        let s = Summary::of(&ratios);
        // the paper's GPU σ/μ is ~9% (mnist total: 2.1 (0.18))
        assert!(
            s.std / s.mean > 0.03,
            "GPU must show visible run-to-run variation, cv={}",
            s.std / s.mean
        );
    }

    #[test]
    fn gops_per_w_in_edge_gpu_zone() {
        // magnitudes should land in the paper's 1-5 GOps/s/W zone
        let mut th = ThermalThrottle::new(JETSON_TX1);
        let mut rng = Rng::seed_from_u64(11);
        for net in [mnist(), celeba()] {
            let runs = simulate_gpu_network(
                &net, &JETSON_TX1, &GpuRunOpts::default(), &mut th, &mut rng,
            );
            for (l, r) in net.layers.iter().zip(&runs) {
                assert!(
                    r.gops_per_w > 0.05 && r.gops_per_w < 20.0,
                    "{}: layer {:?} -> {}",
                    net.name,
                    l,
                    r.gops_per_w
                );
            }
        }
    }
}
