//! Output-space tiling math — Eq. 5, the tile-enumeration helpers the
//! design-space exploration (Fig. 5) sweeps over, and the two-level
//! [`BlockSchedule`] shared by the CPU kernels and the CU simulator.

/// Eq. 5: input tile extent needed to cover a `T_OH`-wide output tile:
/// `T_IH = ⌈T_OH / S⌉ + ⌈K / S⌉`.
pub fn input_tile_extent(t_oh: usize, k: usize, s: usize) -> usize {
    t_oh.div_ceil(s) + k.div_ceil(s)
}

/// Square output tile factors that are legal for a network whose largest
/// layer output is `o_max`: `2 ≤ T ≤ o_max`, and `T ≡ 0 (mod S_max)` so a
/// tile always covers whole stride classes.
///
/// Never returns an empty set: a degenerate network (`o_max < 2`, e.g. a
/// single 1×1 output layer) falls back to the smallest stride-covering
/// tile, `max(S_max, 2)`, so DSE sweeps and tile pickers always have a
/// candidate instead of panicking on an empty range.
pub fn legal_tiles(o_max: usize, s_max: usize) -> Vec<usize> {
    let tiles: Vec<usize> =
        (2..=o_max).filter(|t| t % s_max == 0).collect();
    if tiles.is_empty() {
        return vec![s_max.max(2)];
    }
    tiles
}

/// Static tiling schedule of one layer at one tile factor — how many CU
/// workloads exist and how big each block transfer is.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TileSchedule {
    pub t_oh: usize,
    pub t_ih: usize,
    /// Output tiles along one spatial axis.
    pub tiles_per_axis: usize,
    /// Total output tiles (both axes, one image, one output channel pass).
    pub tiles_total: usize,
    /// Bytes of input block fetched per tile per input channel (f32).
    pub input_block_bytes: usize,
    /// Bytes of output block written per tile per output channel (f32).
    pub output_block_bytes: usize,
}

impl TileSchedule {
    /// Schedule for a layer with output extent `o_h`, kernel `k`,
    /// stride `s`, at tile factor `t_oh`.
    pub fn new(o_h: usize, k: usize, s: usize, t_oh: usize) -> Self {
        let t = t_oh.min(o_h.max(1)).max(1);
        let t_ih = input_tile_extent(t, k, s);
        let tiles_per_axis = o_h.div_ceil(t);
        TileSchedule {
            t_oh: t,
            t_ih,
            tiles_per_axis,
            tiles_total: tiles_per_axis * tiles_per_axis,
            input_block_bytes: 4 * t_ih * t_ih,
            output_block_bytes: 4 * t * t,
        }
    }
}

/// Lane-accumulator widths the blocked kernels monomorphize for (16
/// exists for the ×4-packed i8 datapath, where each DSP-equivalent
/// issues four MACs per cycle).
pub const SUPPORTED_LANES: [usize; 5] = [1, 2, 4, 8, 16];

/// Two-level blocking geometry — the single struct both the CPU
/// kernels and the FPGA CU model consume, so software cache blocking
/// and hardware DSE sweep one tile space.
///
/// The hierarchy, outermost first:
///
/// * **macro-tile** — `macro_tiles` consecutive micro-tile jobs
///   claimed as one [`WorkerPool`](crate::util::WorkerPool) dispatch
///   unit; its combined input footprint is what should fit in L2.
/// * **micro-tile** — one `micro × micro` output tile, identical to
///   the `ReverseLoopOpts::tile` factor (and to the CU workload's
///   `tile_elems`), so `OpStats` geometry is unchanged by blocking.
/// * **lane** — the innermost `[Acc; LANES]` accumulator block over
///   *independent output columns*.  Each column keeps its own
///   accumulation chain, so any lane width is bit-identical to the
///   scalar references by construction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BlockSchedule {
    /// Micro-tile output extent (`T_OH`).
    pub micro: usize,
    /// Micro-tiles per macro-tile (dispatch unit).
    pub macro_tiles: usize,
    /// Lane-accumulator width (must be in [`SUPPORTED_LANES`]).
    pub lanes: usize,
}

impl BlockSchedule {
    /// The static default used when no tuned schedule is available:
    /// the caller's tile factor as the micro-tile, four micro-tiles
    /// per macro-tile, four lanes.
    pub fn default_for(tile: usize) -> Self {
        BlockSchedule {
            micro: tile.max(1),
            macro_tiles: 4,
            lanes: 4,
        }
        .normalized()
    }

    /// Clamp every field to a legal value: `micro ≥ 1`,
    /// `macro_tiles ≥ 1`, and `lanes` rounded *down* to the nearest
    /// supported width.  Dispatch always normalizes, so a hand-edited
    /// tune file can never produce a zero-extent block.
    pub fn normalized(self) -> Self {
        let lanes = SUPPORTED_LANES
            .iter()
            .copied()
            .filter(|l| *l <= self.lanes)
            .max()
            .unwrap_or(1);
        BlockSchedule {
            micro: self.micro.max(1),
            macro_tiles: self.macro_tiles.max(1),
            lanes,
        }
    }

    /// Input bytes one micro-tile streams per image (Eq. 5 extent on
    /// both axes, all input channels).
    pub fn input_block_bytes(
        &self,
        k: usize,
        s: usize,
        c_in: usize,
        elem_bytes: usize,
    ) -> usize {
        let t_i = input_tile_extent(self.micro, k, s);
        c_in * t_i * t_i * elem_bytes
    }

    /// Accumulator bytes one micro-tile pins in the scratch arena
    /// (all output channels, wide-accumulator domain).
    pub fn acc_block_bytes(&self, c_out: usize, acc_bytes: usize) -> usize {
        c_out * self.micro * self.micro * acc_bytes
    }

    /// Working set one micro-tile keeps hot — input block, one output
    /// channel's weights, and the accumulator block.  The L1 residency
    /// test of the cache roofline.
    pub fn l1_footprint_bytes(
        &self,
        k: usize,
        s: usize,
        c_in: usize,
        c_out: usize,
        elem_bytes: usize,
        acc_bytes: usize,
    ) -> usize {
        self.input_block_bytes(k, s, c_in, elem_bytes)
            + c_in * k * k * elem_bytes
            + self.acc_block_bytes(c_out, acc_bytes)
    }

    /// Working set one macro-tile keeps hot — every member micro-tile's
    /// input block, the full weight tensor, and one accumulator block
    /// (micro-tiles within a macro run sequentially, so accumulators
    /// are reused, not stacked).  The L2 residency test.
    pub fn l2_footprint_bytes(
        &self,
        k: usize,
        s: usize,
        c_in: usize,
        c_out: usize,
        elem_bytes: usize,
        acc_bytes: usize,
    ) -> usize {
        self.macro_tiles * self.input_block_bytes(k, s, c_in, elem_bytes)
            + c_in * c_out * k * k * elem_bytes
            + self.acc_block_bytes(c_out, acc_bytes)
    }
}

/// Every legal (micro, macro, lanes) triple for a network whose
/// largest layer output is `o_max` at max stride `s_max`: micro from
/// [`legal_tiles`], macro grouping and lane width from the supported
/// power-of-two sets.  This is the space `edgedcnn tune` sweeps and
/// `dse` scores — one enumeration for both.
pub fn legal_block_schedules(
    o_max: usize,
    s_max: usize,
) -> Vec<BlockSchedule> {
    let mut out = Vec::new();
    for micro in legal_tiles(o_max, s_max) {
        for macro_tiles in [1usize, 2, 4, 8] {
            for lanes in SUPPORTED_LANES {
                out.push(BlockSchedule {
                    micro,
                    macro_tiles,
                    lanes,
                });
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq5_paper_values() {
        // K=4, S=2: T_IH = T/2 + 2
        assert_eq!(input_tile_extent(12, 4, 2), 8);
        assert_eq!(input_tile_extent(24, 4, 2), 14);
        // K=7, S=1: T_IH = T + 7
        assert_eq!(input_tile_extent(12, 7, 1), 19);
    }

    #[test]
    fn eq5_monotone_in_tile() {
        for k in 1..6 {
            for s in 1..4 {
                let mut prev = 0;
                for t in (s..40).step_by(s) {
                    let cur = input_tile_extent(t, k, s);
                    assert!(cur >= prev);
                    prev = cur;
                }
            }
        }
    }

    #[test]
    fn legal_tiles_respect_stride() {
        let tiles = legal_tiles(28, 2);
        assert!(tiles.contains(&12));
        assert!(tiles.contains(&24));
        assert!(tiles.iter().all(|t| t % 2 == 0));
        assert!(!tiles.contains(&13));
    }

    #[test]
    fn legal_tiles_never_empty_on_degenerate_outputs() {
        // o_max < 2: the old range (2..=o_max) was empty
        assert_eq!(legal_tiles(1, 1), vec![2]);
        assert_eq!(legal_tiles(0, 2), vec![2]);
        assert_eq!(legal_tiles(1, 3), vec![3]);
        // stride larger than every candidate tile is also non-empty
        assert_eq!(legal_tiles(3, 4), vec![4]);
        // and the fallback still covers whole stride classes
        for (o, s) in [(1usize, 1usize), (0, 2), (1, 3), (3, 4)] {
            let tiles = legal_tiles(o, s);
            assert!(!tiles.is_empty());
            assert!(tiles.iter().all(|t| t % s == 0 && *t >= 2));
        }
    }

    #[test]
    fn block_schedule_normalizes_every_field() {
        let s = BlockSchedule {
            micro: 0,
            macro_tiles: 0,
            lanes: 0,
        }
        .normalized();
        assert_eq!(s, BlockSchedule { micro: 1, macro_tiles: 1, lanes: 1 });
        let s = BlockSchedule {
            micro: 12,
            macro_tiles: 3,
            lanes: 7,
        }
        .normalized();
        assert_eq!(s.lanes, 4, "lanes round down to a supported width");
        assert_eq!(s.macro_tiles, 3);
        assert_eq!(BlockSchedule::default_for(12).micro, 12);
        assert_eq!(BlockSchedule::default_for(0).micro, 1);
    }

    #[test]
    fn block_footprints_follow_eq5() {
        let s = BlockSchedule {
            micro: 12,
            macro_tiles: 2,
            lanes: 4,
        };
        // K=4, S=2 → t_i = 8; c_in=3 f32 input block = 3·8·8·4
        assert_eq!(s.input_block_bytes(4, 2, 3, 4), 3 * 64 * 4);
        assert_eq!(s.acc_block_bytes(5, 8), 5 * 144 * 8);
        let l1 = s.l1_footprint_bytes(4, 2, 3, 5, 4, 8);
        let l2 = s.l2_footprint_bytes(4, 2, 3, 5, 4, 8);
        assert_eq!(l1, 3 * 64 * 4 + 3 * 16 * 4 + 5 * 144 * 8);
        assert_eq!(l2, 2 * 3 * 64 * 4 + 3 * 5 * 16 * 4 + 5 * 144 * 8);
        assert!(l2 > l1 - 5 * 144 * 8, "macro footprint dominates");
    }

    #[test]
    fn legal_block_schedules_cover_the_cross_product() {
        let space = legal_block_schedules(28, 2);
        let micros = legal_tiles(28, 2);
        assert_eq!(space.len(), micros.len() * 4 * SUPPORTED_LANES.len());
        assert!(space.iter().all(|b| {
            micros.contains(&b.micro)
                && SUPPORTED_LANES.contains(&b.lanes)
                && b.macro_tiles >= 1
        }));
        // degenerate outputs still enumerate something
        assert!(!legal_block_schedules(1, 1).is_empty());
    }

    #[test]
    fn schedule_covers_output() {
        let s = TileSchedule::new(28, 4, 2, 12);
        assert_eq!(s.tiles_per_axis, 3); // 12+12+4
        assert_eq!(s.tiles_total, 9);
        let s2 = TileSchedule::new(7, 7, 1, 12);
        assert_eq!(s2.t_oh, 7); // clamped to layer output
        assert_eq!(s2.tiles_per_axis, 1);
    }
}
