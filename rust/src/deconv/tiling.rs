//! Output-space tiling math — Eq. 5 and the tile-enumeration helpers the
//! design-space exploration (Fig. 5) sweeps over.

/// Eq. 5: input tile extent needed to cover a `T_OH`-wide output tile:
/// `T_IH = ⌈T_OH / S⌉ + ⌈K / S⌉`.
pub fn input_tile_extent(t_oh: usize, k: usize, s: usize) -> usize {
    t_oh.div_ceil(s) + k.div_ceil(s)
}

/// Square output tile factors that are legal for a network whose largest
/// layer output is `o_max`: `2 ≤ T ≤ o_max`, and `T ≡ 0 (mod S_max)` so a
/// tile always covers whole stride classes.
///
/// Never returns an empty set: a degenerate network (`o_max < 2`, e.g. a
/// single 1×1 output layer) falls back to the smallest stride-covering
/// tile, `max(S_max, 2)`, so DSE sweeps and tile pickers always have a
/// candidate instead of panicking on an empty range.
pub fn legal_tiles(o_max: usize, s_max: usize) -> Vec<usize> {
    let tiles: Vec<usize> =
        (2..=o_max).filter(|t| t % s_max == 0).collect();
    if tiles.is_empty() {
        return vec![s_max.max(2)];
    }
    tiles
}

/// Static tiling schedule of one layer at one tile factor — how many CU
/// workloads exist and how big each block transfer is.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TileSchedule {
    pub t_oh: usize,
    pub t_ih: usize,
    /// Output tiles along one spatial axis.
    pub tiles_per_axis: usize,
    /// Total output tiles (both axes, one image, one output channel pass).
    pub tiles_total: usize,
    /// Bytes of input block fetched per tile per input channel (f32).
    pub input_block_bytes: usize,
    /// Bytes of output block written per tile per output channel (f32).
    pub output_block_bytes: usize,
}

impl TileSchedule {
    /// Schedule for a layer with output extent `o_h`, kernel `k`,
    /// stride `s`, at tile factor `t_oh`.
    pub fn new(o_h: usize, k: usize, s: usize, t_oh: usize) -> Self {
        let t = t_oh.min(o_h.max(1)).max(1);
        let t_ih = input_tile_extent(t, k, s);
        let tiles_per_axis = o_h.div_ceil(t);
        TileSchedule {
            t_oh: t,
            t_ih,
            tiles_per_axis,
            tiles_total: tiles_per_axis * tiles_per_axis,
            input_block_bytes: 4 * t_ih * t_ih,
            output_block_bytes: 4 * t * t,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq5_paper_values() {
        // K=4, S=2: T_IH = T/2 + 2
        assert_eq!(input_tile_extent(12, 4, 2), 8);
        assert_eq!(input_tile_extent(24, 4, 2), 14);
        // K=7, S=1: T_IH = T + 7
        assert_eq!(input_tile_extent(12, 7, 1), 19);
    }

    #[test]
    fn eq5_monotone_in_tile() {
        for k in 1..6 {
            for s in 1..4 {
                let mut prev = 0;
                for t in (s..40).step_by(s) {
                    let cur = input_tile_extent(t, k, s);
                    assert!(cur >= prev);
                    prev = cur;
                }
            }
        }
    }

    #[test]
    fn legal_tiles_respect_stride() {
        let tiles = legal_tiles(28, 2);
        assert!(tiles.contains(&12));
        assert!(tiles.contains(&24));
        assert!(tiles.iter().all(|t| t % 2 == 0));
        assert!(!tiles.contains(&13));
    }

    #[test]
    fn legal_tiles_never_empty_on_degenerate_outputs() {
        // o_max < 2: the old range (2..=o_max) was empty
        assert_eq!(legal_tiles(1, 1), vec![2]);
        assert_eq!(legal_tiles(0, 2), vec![2]);
        assert_eq!(legal_tiles(1, 3), vec![3]);
        // stride larger than every candidate tile is also non-empty
        assert_eq!(legal_tiles(3, 4), vec![4]);
        // and the fallback still covers whole stride classes
        for (o, s) in [(1usize, 1usize), (0, 2), (1, 3), (3, 4)] {
            let tiles = legal_tiles(o, s);
            assert!(!tiles.is_empty());
            assert!(tiles.iter().all(|t| t % s == 0 && *t >= 2));
        }
    }

    #[test]
    fn schedule_covers_output() {
        let s = TileSchedule::new(28, 4, 2, 12);
        assert_eq!(s.tiles_per_axis, 3); // 12+12+4
        assert_eq!(s.tiles_total, 9);
        let s2 = TileSchedule::new(7, 7, 1, 12);
        assert_eq!(s2.t_oh, 7); // clamped to layer output
        assert_eq!(s2.tiles_per_axis, 1);
    }
}
