//! Pure-Rust deconvolution substrate (Section III of the paper).
//!
//! Three interchangeable algorithms over the same NCHW tensors:
//!
//! * [`standard`] — textbook input-space scatter (Eq. 1), the baseline
//!   with the overlapping-sum problem;
//! * [`reverse_loop`] — the paper's output-space Algorithm 1 with
//!   pre-computed Eq. 3 offsets, tiling, and optional zero-skipping
//!   (this is what each simulated CU executes);
//! * [`tdc`] — the deconvolution-to-convolution transform baseline
//!   (Chang et al.), requiring `stride²` filters and zero padding.
//!
//! All three are verified equal (and equal to the Python oracles through
//! the AOT artifacts) by unit, integration and property tests.  The
//! [`OpStats`] accounting they emit is what the FPGA cycle model consumes.
//!
//! Each kernel also has a `*_blocked` entry point restructured around
//! the two-level [`BlockSchedule`] (macro-tile → micro-tile → lane
//! accumulators) shared with the CU simulator and the tune table
//! ([`crate::tune`]); every legal schedule is bit-identical to the
//! frozen scalar references, tensors *and* op counts.

mod offsets;
mod reference;
mod reverse_loop;
mod standard;
mod tdc;
mod tiling;

pub use offsets::{modulo_cost_naive, modulo_cost_precomputed, stride_hole_offsets};
pub use reference::{
    deconv_reverse_loop_ref, deconv_standard_ref, deconv_tdc_ref,
};
pub use reverse_loop::{
    deconv_reverse_loop, deconv_reverse_loop_blocked,
    deconv_reverse_loop_par, OpStats, ReverseLoopOpts,
};
pub use standard::{deconv_standard, deconv_standard_blocked};
pub use tdc::{
    deconv_tdc, deconv_tdc_blocked, tdc_filter_count, tdc_subfilter_extent,
    tdc_transform_weights,
};
pub use tiling::{
    input_tile_extent, legal_block_schedules, legal_tiles, BlockSchedule,
    TileSchedule, SUPPORTED_LANES,
};

use crate::config::{DeconvLayerCfg, NetworkCfg};
use crate::quant::Element;
use crate::tensor::{Tensor, TensorT};
use crate::util::WorkerPool;

/// Output spatial extent of a layer: `(I-1)·S + K - 2P`.
pub fn output_size(i: usize, k: usize, s: usize, p: usize) -> usize {
    (i - 1) * s + k - 2 * p
}

/// Convenience: run the reference (standard) algorithm for a layer config.
pub fn layer_forward_standard(
    cfg: &DeconvLayerCfg,
    x: &Tensor,
    w: &Tensor,
    b: &[f32],
) -> Tensor {
    deconv_standard(x, w, b, cfg.stride, cfg.padding)
}

/// Full generator forward pass in pure Rust (reverse-loop kernels + ReLU
/// between layers, tanh at the output) — the numeric cross-check for the
/// PJRT path and the fallback for artifact-less environments.  Generic
/// over the element type; `f32` call sites are unchanged, and the
/// scale-calibrated fixed-point epilogue lives in
/// [`crate::quant::generator_forward_quant`].
///
/// `z` is `[N, z_dim]`; returns `[N, C, H, W]`.
pub fn generator_forward<T: Element>(
    net: &NetworkCfg,
    weights: &[(TensorT<T>, Vec<T>)],
    z: &TensorT<T>,
) -> TensorT<T> {
    generator_forward_par(net, weights, z, &WorkerPool::new(1))
}

/// [`generator_forward`] with every layer's output tiles sharded across
/// a [`WorkerPool`].  Bit-identical to the serial forward (the parallel
/// reverse loop is bit-identical per layer), so seeded generation stays
/// deterministic at any pool width.
pub fn generator_forward_par<T: Element>(
    net: &NetworkCfg,
    weights: &[(TensorT<T>, Vec<T>)],
    z: &TensorT<T>,
    pool: &WorkerPool,
) -> TensorT<T> {
    assert_eq!(weights.len(), net.layers.len());
    assert_eq!(z.shape()[1], net.z_dim);
    let n = z.shape()[0];
    let mut x = z
        .clone()
        .reshape(vec![n, net.z_dim, 1, 1])
        .expect("z reshape");
    let last = net.layers.len() - 1;
    for (i, (layer, (w, b))) in net.layers.iter().zip(weights).enumerate() {
        let (mut y, _) = deconv_reverse_loop_par(
            &x,
            w,
            b,
            layer.stride,
            layer.padding,
            ReverseLoopOpts {
                tile: net.tile,
                zero_skip: true, // numerics identical; skips the zeros
            },
            pool,
        );
        for v in y.data_mut().iter_mut() {
            *v = if i == last {
                Element::tanh(*v)
            } else {
                Element::relu(*v)
            };
        }
        x = y;
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn output_size_identities() {
        assert_eq!(output_size(1, 7, 1, 0), 7);
        assert_eq!(output_size(7, 4, 2, 1), 14);
        assert_eq!(output_size(14, 4, 2, 1), 28);
        assert_eq!(output_size(32, 4, 2, 1), 64);
    }
}
