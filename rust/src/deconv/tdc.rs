//! TDC baseline — "Transforming Deconvolution to Convolution" (Chang et
//! al., ASP-DAC'20 / TCSVT'18), the related-work approach the paper
//! contrasts with: split the K×K deconvolution into `S²` smaller
//! convolutions (one per output stride class), which requires `stride²`
//! as many filters and zero-padding when `K` is not a multiple of `S`.
//!
//! Implemented both for numeric verification (it must agree with the
//! other two algorithms) and for the ablation bench that quantifies the
//! zero-padding overhead the paper's reverse-loop algorithm avoids.

use super::standard::shape4;
use super::tiling::BlockSchedule;
use crate::quant::Element;
use crate::tensor::TensorT;
use crate::util::WorkerPool;

/// Number of sub-convolution filters the TDC transform produces per
/// original filter: `stride²`.
pub fn tdc_filter_count(stride: usize) -> usize {
    stride * stride
}

/// Sub-filter spatial extent: `⌈K / S⌉` (zero-padded when `S ∤ K`).
pub fn tdc_subfilter_extent(k: usize, s: usize) -> usize {
    k.div_ceil(s)
}

/// Transform deconvolution weights `[C_in, C_out, K, K]` into the
/// `S²` stride-class convolution filter banks, each
/// `[C_in, C_out, Kc, Kc]` with `Kc = ⌈K/S⌉` (zero-padded entries where
/// the class has no tap — the load-imbalance the paper cites).
///
/// Returns `banks[ry][rx]` for output residues `(ry, rx)` and the count
/// of *zero-padded* taps inserted (the wasted work of the method).
pub fn tdc_transform_weights<T: Element>(
    w: &TensorT<T>,
    stride: usize,
    padding: usize,
) -> (Vec<Vec<TensorT<T>>>, u64) {
    let [c_in, c_out, k, _] = shape4(w);
    let s = stride;
    let kc = tdc_subfilter_extent(k, s);
    let mut padded_zeros = 0u64;
    let mut banks = Vec::with_capacity(s);
    for ry in 0..s {
        let mut row = Vec::with_capacity(s);
        for rx in 0..s {
            let mut bank = TensorT::<T>::zeros(vec![c_in, c_out, kc, kc]);
            // Tap k contributes to residue r = (k - P) mod S, at
            // sub-position (k - P + needed offset)/S relative to the class.
            let mut filled = vec![false; kc * kc];
            for kh in 0..k {
                let rh = (kh as i64 - padding as i64).rem_euclid(s as i64)
                    as usize;
                if rh != ry {
                    continue;
                }
                for kw in 0..k {
                    let rw = (kw as i64 - padding as i64)
                        .rem_euclid(s as i64) as usize;
                    if rw != rx {
                        continue;
                    }
                    let sh = (kh as i64 - padding as i64).div_euclid(s as i64);
                    let sw = (kw as i64 - padding as i64).div_euclid(s as i64);
                    // normalize to non-negative sub-index within the bank
                    let base_h = (0..k)
                        .filter(|&q| {
                            (q as i64 - padding as i64).rem_euclid(s as i64)
                                as usize
                                == ry
                        })
                        .map(|q| (q as i64 - padding as i64).div_euclid(s as i64))
                        .min()
                        .unwrap();
                    let base_w = (0..k)
                        .filter(|&q| {
                            (q as i64 - padding as i64).rem_euclid(s as i64)
                                as usize
                                == rx
                        })
                        .map(|q| (q as i64 - padding as i64).div_euclid(s as i64))
                        .min()
                        .unwrap();
                    let ih = (sh - base_h) as usize;
                    let iw = (sw - base_w) as usize;
                    if ih < kc && iw < kc {
                        for ci in 0..c_in {
                            for co in 0..c_out {
                                bank.set4(
                                    ci, co, ih, iw, w.get4(ci, co, kh, kw),
                                );
                            }
                        }
                        filled[ih * kc + iw] = true;
                    }
                }
            }
            padded_zeros += filled.iter().filter(|f| !**f).count() as u64
                * (c_in * c_out) as u64;
            row.push(bank);
        }
        banks.push(row);
    }
    (banks, padded_zeros)
}

/// Full TDC deconvolution: run the transform and evaluate each stride
/// class by direct correlation, re-stitching the interleaved outputs
/// (Tu et al.'s disjoint feature maps).  Numerically identical to the
/// other two algorithms (bit-identical in fixed point: the per-pixel
/// gather accumulates in the wide [`Element::Acc`] domain and narrows
/// once, like the other kernels).
pub fn deconv_tdc<T: Element>(
    x: &TensorT<T>,
    w: &TensorT<T>,
    b: &[T],
    stride: usize,
    padding: usize,
) -> TensorT<T> {
    // The transform-based method is only defined for S ≥ 1; for S == 1 it
    // degenerates to a single correlation == standard path.
    let [n, c_in, i_h, i_w] = shape4(x);
    let [_, c_out, k, _] = shape4(w);
    let s = stride;
    let p = padding;
    let o_h = super::output_size(i_h, k, s, p);
    let o_w = super::output_size(i_w, k, s, p);
    let mut y = TensorT::<T>::zeros(vec![n, c_out, o_h, o_w]);

    // For each output pixel o, its stride class is r = o mod S... but the
    // sub-convolutions are easiest stated via the reverse mapping: for
    // class r the taps are {k : (k - P) ≡ -r? }.  Rather than re-derive
    // sub-conv index algebra here (the banks above carry it), evaluate
    // per class by direct gather, which IS the sub-convolution.
    //
    // SIMD-shaped gather: the per-pixel modulo/division/bounds tests
    // depend only on the output coordinate along one axis, so the valid
    // `(k, i)` tap pairs are precomputed once per `oh` and once per
    // `ow`.  The per-pixel loop then walks pre-resolved pairs and the
    // innermost `ci` reduction uses fixed-stride index increments —
    // no modulo, division or branch per tap.  Per output element the
    // taps still accumulate in ascending `(kh, kw, ci)` order, so the
    // result is bit-identical to the pinned scalar reference
    // ([`super::reference::deconv_tdc_ref`]).
    let taps_along = |o_extent: usize, i_extent: usize| -> Vec<Vec<(usize, usize)>> {
        (0..o_extent)
            .map(|o| {
                (0..k)
                    .filter_map(|kk| {
                        let num = o as i64 + p as i64 - kk as i64;
                        if num % s as i64 != 0 {
                            return None;
                        }
                        let i = num / s as i64;
                        if i < 0 || i >= i_extent as i64 {
                            return None;
                        }
                        Some((kk, i as usize))
                    })
                    .collect()
            })
            .collect()
    };
    let taps_h = taps_along(o_h, i_h);
    let taps_w = taps_along(o_w, i_w);

    let xdata = x.data();
    let wdata = w.data();
    let ydata = y.data_mut();
    let w_ci_stride = c_out * k * k;
    let x_ci_stride = i_h * i_w;
    for bi in 0..n {
        for co in 0..c_out {
            for oh in 0..o_h {
                let orow = &mut ydata
                    [((bi * c_out + co) * o_h + oh) * o_w..][..o_w];
                for (ow, yv) in orow.iter_mut().enumerate() {
                    let mut acc = b[co].widen();
                    for &(kh, ih) in &taps_h[oh] {
                        for &(kw, iw) in &taps_w[ow] {
                            let mut wi = (co * k + kh) * k + kw;
                            let mut xi =
                                (bi * c_in * i_h + ih) * i_w + iw;
                            for _ in 0..c_in {
                                acc = T::mac(acc, wdata[wi], xdata[xi]);
                                wi += w_ci_stride;
                                xi += x_ci_stride;
                            }
                        }
                    }
                    *yv = T::narrow(acc);
                }
            }
        }
    }
    y
}

/// Shared read-only context for the blocked TDC gather jobs.
struct TdcCtx<'a, T: Element> {
    x: &'a TensorT<T>,
    w: &'a TensorT<T>,
    b: &'a [T],
    taps_h: &'a [Vec<(usize, usize)>],
    taps_w: &'a [Vec<(usize, usize)>],
    c_in: usize,
    c_out: usize,
    k: usize,
    i_h: usize,
    i_w: usize,
    o_w: usize,
}

/// One output-row block of one `(bi, co)` plane.
#[derive(Debug, Clone, Copy)]
struct TdcJob {
    bi: usize,
    co: usize,
    r0: usize,
    r1: usize,
}

/// Gather one row block, appending narrowed pixels row-major to `out`.
/// The `ow` walk runs in `LANES`-wide blocks whose `[Element::Acc;
/// LANES]` accumulators each own one output column: per column the
/// taps still accumulate in ascending `(kh, kw, ci)` order, so any
/// lane width is bit-identical to the scalar gather.
fn tdc_block_kernel<T: Element, const LANES: usize>(
    ctx: &TdcCtx<'_, T>,
    job: TdcJob,
    out: &mut Vec<T>,
) {
    let TdcJob { bi, co, r0, r1 } = job;
    let (k, c_in) = (ctx.k, ctx.c_in);
    let (i_h, i_w, o_w) = (ctx.i_h, ctx.i_w, ctx.o_w);
    let xdata = ctx.x.data();
    let wdata = ctx.w.data();
    let w_ci_stride = ctx.c_out * k * k;
    let x_ci_stride = i_h * i_w;
    let bias = ctx.b[co].widen();
    for oh in r0..r1 {
        let th = &ctx.taps_h[oh];
        let mut ow = 0usize;
        while ow + LANES <= o_w {
            let mut lane = [T::ACC_ZERO; LANES];
            for l in 0..LANES {
                let mut acc = bias;
                for &(kh, ih) in th {
                    for &(kw, iw) in &ctx.taps_w[ow + l] {
                        let mut wi = (co * k + kh) * k + kw;
                        let mut xi = (bi * c_in * i_h + ih) * i_w + iw;
                        for _ in 0..c_in {
                            acc = T::mac(acc, wdata[wi], xdata[xi]);
                            wi += w_ci_stride;
                            xi += x_ci_stride;
                        }
                    }
                }
                lane[l] = acc;
            }
            for &acc in &lane {
                out.push(T::narrow(acc));
            }
            ow += LANES;
        }
        while ow < o_w {
            let mut acc = bias;
            for &(kh, ih) in th {
                for &(kw, iw) in &ctx.taps_w[ow] {
                    let mut wi = (co * k + kh) * k + kw;
                    let mut xi = (bi * c_in * i_h + ih) * i_w + iw;
                    for _ in 0..c_in {
                        acc = T::mac(acc, wdata[wi], xdata[xi]);
                        wi += w_ci_stride;
                        xi += x_ci_stride;
                    }
                }
            }
            out.push(T::narrow(acc));
            ow += 1;
        }
    }
}

fn tdc_block_into<T: Element>(
    ctx: &TdcCtx<'_, T>,
    job: TdcJob,
    lanes: usize,
    out: &mut Vec<T>,
) {
    match lanes {
        1 => tdc_block_kernel::<T, 1>(ctx, job, out),
        2 => tdc_block_kernel::<T, 2>(ctx, job, out),
        8 => tdc_block_kernel::<T, 8>(ctx, job, out),
        16 => tdc_block_kernel::<T, 16>(ctx, job, out),
        _ => tdc_block_kernel::<T, 4>(ctx, job, out),
    }
}

/// [`deconv_tdc`] restructured around a two-level [`BlockSchedule`]:
/// `micro`-row blocks of each `(bi, co)` plane are the jobs,
/// `macro_tiles` consecutive jobs form one pool claim unit, and the
/// pixel walk runs `lanes`-wide independent-column accumulators.
/// Bit-identical to [`deconv_tdc`] (and the frozen scalar reference)
/// for every legal schedule; `sched: None` consults the persisted tune
/// table, falling back to the static default.
pub fn deconv_tdc_blocked<T: Element>(
    x: &TensorT<T>,
    w: &TensorT<T>,
    b: &[T],
    stride: usize,
    padding: usize,
    sched: Option<BlockSchedule>,
    pool: &WorkerPool,
) -> TensorT<T> {
    let [n, c_in, i_h, i_w] = shape4(x);
    let [_, c_out, k, _] = shape4(w);
    let s = stride;
    let p = padding;
    let o_h = super::output_size(i_h, k, s, p);
    let o_w = super::output_size(i_w, k, s, p);
    let sched = sched.map(BlockSchedule::normalized).unwrap_or_else(|| {
        crate::tune::schedule_for::<T>(
            crate::tune::TuneKernel::Tdc,
            c_in,
            c_out,
            k,
            stride,
            o_h,
            None,
        )
    });
    // Same pre-resolved tap pairs as the serial gather.
    let taps_along = |o_extent: usize,
                      i_extent: usize|
     -> Vec<Vec<(usize, usize)>> {
        (0..o_extent)
            .map(|o| {
                (0..k)
                    .filter_map(|kk| {
                        let num = o as i64 + p as i64 - kk as i64;
                        if num % s as i64 != 0 {
                            return None;
                        }
                        let i = num / s as i64;
                        if i < 0 || i >= i_extent as i64 {
                            return None;
                        }
                        Some((kk, i as usize))
                    })
                    .collect()
            })
            .collect()
    };
    let taps_h = taps_along(o_h, i_h);
    let taps_w = taps_along(o_w, i_w);
    let ctx = TdcCtx {
        x,
        w,
        b,
        taps_h: &taps_h,
        taps_w: &taps_w,
        c_in,
        c_out,
        k,
        i_h,
        i_w,
        o_w,
    };
    let micro = sched.micro.max(1);
    let mut jobs = Vec::new();
    for bi in 0..n {
        for co in 0..c_out {
            let mut r0 = 0;
            while r0 < o_h {
                let r1 = (r0 + micro).min(o_h);
                jobs.push(TdcJob { bi, co, r0, r1 });
                r0 = r1;
            }
        }
    }
    let g = sched.macro_tiles.max(1);
    let lanes = sched.lanes;
    let n_macro = jobs.len().div_ceil(g);
    let results = pool.map_indexed_auto(n_macro, |m| {
        let lo = m * g;
        let hi = (lo + g).min(jobs.len());
        let member = &jobs[lo..hi];
        let total: usize =
            member.iter().map(|j| (j.r1 - j.r0) * o_w).sum();
        let mut out = Vec::with_capacity(total);
        for &job in member {
            tdc_block_into(&ctx, job, lanes, &mut out);
        }
        out
    });
    let mut y = TensorT::<T>::zeros(vec![n, c_out, o_h, o_w]);
    let ydata = y.data_mut();
    for (m, mblock) in results.iter().enumerate() {
        let lo = m * g;
        let hi = (lo + g).min(jobs.len());
        let mut off = 0usize;
        for job in &jobs[lo..hi] {
            let len = (job.r1 - job.r0) * o_w;
            let dst =
                ((job.bi * c_out + job.co) * o_h + job.r0) * o_w;
            ydata[dst..dst + len]
                .copy_from_slice(&mblock[off..off + len]);
            off += len;
        }
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::deconv::deconv_standard;
    use crate::tensor::Tensor;
    use crate::util::Rng;

    #[test]
    fn filter_count_is_stride_squared() {
        assert_eq!(tdc_filter_count(1), 1);
        assert_eq!(tdc_filter_count(2), 4);
        assert_eq!(tdc_filter_count(3), 9);
    }

    #[test]
    fn subfilter_extent_rounds_up() {
        assert_eq!(tdc_subfilter_extent(4, 2), 2); // K divisible: no padding
        assert_eq!(tdc_subfilter_extent(7, 2), 4); // padding required
        assert_eq!(tdc_subfilter_extent(3, 2), 2);
    }

    #[test]
    fn no_padding_when_stride_divides_k() {
        let w = Tensor::from_fn(vec![2, 2, 4, 4], |i| i as f32 + 1.0);
        let (banks, padded) = tdc_transform_weights(&w, 2, 1);
        assert_eq!(banks.len(), 2);
        assert_eq!(banks[0].len(), 2);
        assert_eq!(padded, 0, "K=4,S=2 packs exactly");
    }

    #[test]
    fn padding_counted_when_k_not_divisible() {
        let w = Tensor::from_fn(vec![1, 1, 3, 3], |_| 1.0);
        let (_, padded) = tdc_transform_weights(&w, 2, 1);
        // K=3, S=2 → sub-filters 2×2; 3² taps spread over 4 banks of 4
        // slots = 16 slots, 9 filled → 7 zero-padded
        assert_eq!(padded, 7);
    }

    #[test]
    fn tdc_matches_standard_bit_for_bit_in_fixed_point() {
        use crate::quant::{quantize_tensor, Q8_8, Rounding};
        let mut rng = Rng::seed_from_u64(13);
        for (c_in, c_out, k, s, p, i_h) in
            [(2, 3, 4, 2, 1, 5), (1, 2, 3, 2, 1, 4), (1, 1, 5, 3, 2, 4)]
        {
            let xf = Tensor::from_fn(vec![1, c_in, i_h, i_h], |_| {
                rng.range_f32(-1.0, 1.0)
            });
            let wf = Tensor::from_fn(vec![c_in, c_out, k, k], |_| {
                rng.range_f32(-1.0, 1.0)
            });
            let x = quantize_tensor::<i16, 8>(&xf, Rounding::Nearest);
            let w = quantize_tensor::<i16, 8>(&wf, Rounding::Nearest);
            let b: Vec<Q8_8> = (0..c_out)
                .map(|i| Q8_8::from_f32(i as f32 * 0.25))
                .collect();
            let expect = deconv_standard(&x, &w, &b, s, p);
            let got = deconv_tdc(&x, &w, &b, s, p);
            assert_eq!(got.data(), expect.data(), "({c_in},{c_out},{k},{s},{p})");
        }
    }

    /// The precomputed-taps gather is bit-identical to the pinned
    /// pre-PR scalar reference (inline modulo per tap).
    #[test]
    fn bit_identical_to_pinned_scalar_reference() {
        use crate::deconv::deconv_tdc_ref;
        let mut rng = Rng::seed_from_u64(37);
        for (c_in, c_out, k, s, p, i_h) in [
            (2, 3, 4, 2, 1, 5),
            (1, 2, 3, 2, 1, 4),
            (2, 1, 7, 1, 0, 3),
            (1, 1, 5, 3, 2, 4),
        ] {
            let x = Tensor::from_fn(vec![2, c_in, i_h, i_h], |_| {
                rng.range_f32(-1.0, 1.0)
            });
            let w = Tensor::from_fn(vec![c_in, c_out, k, k], |_| {
                rng.range_f32(-1.0, 1.0)
            });
            let b: Vec<f32> =
                (0..c_out).map(|_| rng.range_f32(-0.5, 0.5)).collect();
            let want = deconv_tdc_ref(&x, &w, &b, s, p);
            let got = deconv_tdc(&x, &w, &b, s, p);
            assert_eq!(
                got.data(),
                want.data(),
                "({c_in},{c_out},{k},{s},{p},{i_h}): f32 must match the \
                 scalar reference bit for bit"
            );
        }
    }

    /// Blocked gather is bit-identical to the frozen scalar reference
    /// for every (micro, macro, lanes) triple, serial and parallel.
    #[test]
    fn blocked_is_bit_identical_to_pinned_scalar_reference() {
        use crate::deconv::deconv_tdc_ref;
        let mut rng = Rng::seed_from_u64(43);
        for (c_in, c_out, k, s, p, i_h) in
            [(2, 3, 4, 2, 1, 5), (1, 2, 3, 2, 1, 4), (2, 1, 7, 1, 0, 3)]
        {
            let x = Tensor::from_fn(vec![2, c_in, i_h, i_h], |_| {
                rng.range_f32(-1.0, 1.0)
            });
            let w = Tensor::from_fn(vec![c_in, c_out, k, k], |_| {
                rng.range_f32(-1.0, 1.0)
            });
            let b: Vec<f32> =
                (0..c_out).map(|_| rng.range_f32(-0.5, 0.5)).collect();
            let want = deconv_tdc_ref(&x, &w, &b, s, p);
            for micro in [1usize, 3, 64] {
                for macro_tiles in [1usize, 2, 8] {
                    for lanes in [1usize, 2, 4, 8] {
                        let sched = BlockSchedule {
                            micro,
                            macro_tiles,
                            lanes,
                        };
                        for workers in [1usize, 4] {
                            let got = deconv_tdc_blocked(
                                &x,
                                &w,
                                &b,
                                s,
                                p,
                                Some(sched),
                                &WorkerPool::new(workers),
                            );
                            assert_eq!(
                                got.data(),
                                want.data(),
                                "micro={micro} macro={macro_tiles} \
                                 lanes={lanes} w={workers}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn tdc_matches_standard() {
        let mut rng = Rng::seed_from_u64(11);
        for (c_in, c_out, k, s, p, i_h) in [
            (2, 3, 4, 2, 1, 5),
            (1, 2, 3, 2, 1, 4),
            (2, 1, 7, 1, 0, 3),
            (1, 1, 5, 3, 2, 4),
        ] {
            let x = Tensor::from_fn(vec![1, c_in, i_h, i_h], |_| {
                rng.range_f32(-1.0, 1.0)
            });
            let w = Tensor::from_fn(vec![c_in, c_out, k, k], |_| {
                rng.range_f32(-1.0, 1.0)
            });
            let b: Vec<f32> = (0..c_out).map(|i| i as f32 * 0.25).collect();
            let expect = deconv_standard(&x, &w, &b, s, p);
            let got = deconv_tdc(&x, &w, &b, s, p);
            assert!(
                got.max_abs_diff(&expect) < 1e-4,
                "({c_in},{c_out},{k},{s},{p},{i_h})"
            );
        }
    }
}
