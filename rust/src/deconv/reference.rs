//! Pinned scalar reference kernels — frozen copies of the pre-SIMD
//! (pre-PR-7) loop nests of all three deconvolution algorithms.
//!
//! The hot kernels in [`super::standard`], [`super::reverse_loop`] and
//! [`super::tdc`] are restructured for autovectorization (contiguous
//! innermost loops, hoisted bounds, no per-element division).  The
//! restructure is engineered to be **bit-identical**: per output
//! element, the accumulation chain visits the same taps in the same
//! order with the same [`Element::mac`] operation, so even `f32`
//! results match bit for bit (fixed point is order-independent in the
//! wide accumulator domain regardless).  This module keeps the original
//! scalar element-at-a-time formulations verbatim so the property tests
//! can assert that claim against a reference that never changes, rather
//! than against the very code being optimized.
//!
//! Deliberately self-contained (own tile enumeration, own offset
//! helpers) and serial-only: a frozen oracle, not a fast path.  Do not
//! "optimize" this module.

use super::offsets::stride_hole_offsets;
use super::reverse_loop::{OpStats, ReverseLoopOpts};
use super::standard::shape4;
use super::tiling::input_tile_extent;
use crate::quant::Element;
use crate::tensor::TensorT;

/// Frozen scalar standard (input-space scatter) deconvolution — the
/// pre-restructure loop nest of [`super::deconv_standard`].
pub fn deconv_standard_ref<T: Element>(
    x: &TensorT<T>,
    w: &TensorT<T>,
    b: &[T],
    stride: usize,
    padding: usize,
) -> TensorT<T> {
    let [n, c_in, i_h, i_w] = shape4(x);
    let [wc_in, c_out, k, k2] = shape4(w);
    assert_eq!(c_in, wc_in, "weight C_in mismatch");
    assert_eq!(k, k2, "kernel must be square");
    assert_eq!(b.len(), c_out, "bias length mismatch");
    let o_h = super::output_size(i_h, k, stride, padding);
    let o_w = super::output_size(i_w, k, stride, padding);

    let at = |bi: usize, co: usize, oh: usize, ow: usize| {
        ((bi * c_out + co) * o_h + oh) * o_w + ow
    };
    let mut acc: Vec<T::Acc> = vec![T::ACC_ZERO; n * c_out * o_h * o_w];
    for bi in 0..n {
        for co in 0..c_out {
            let bw = b[co].widen();
            for oh in 0..o_h {
                for ow in 0..o_w {
                    acc[at(bi, co, oh, ow)] = bw;
                }
            }
        }
    }
    for bi in 0..n {
        for ci in 0..c_in {
            for ih in 0..i_h {
                for iw in 0..i_w {
                    let v = x.get4(bi, ci, ih, iw);
                    if v.is_zero() {
                        continue;
                    }
                    for kh in 0..k {
                        let oh = (ih * stride + kh) as i64 - padding as i64;
                        if oh < 0 || oh >= o_h as i64 {
                            continue;
                        }
                        for kw in 0..k {
                            let ow =
                                (iw * stride + kw) as i64 - padding as i64;
                            if ow < 0 || ow >= o_w as i64 {
                                continue;
                            }
                            for co in 0..c_out {
                                let i =
                                    at(bi, co, oh as usize, ow as usize);
                                acc[i] =
                                    T::mac(acc[i], w.get4(ci, co, kh, kw), v);
                            }
                        }
                    }
                }
            }
        }
    }
    let data: Vec<T> = acc.into_iter().map(T::narrow).collect();
    TensorT::new(vec![n, c_out, o_h, o_w], data).expect("output shape")
}

/// Frozen scalar reverse-loop (Algorithm 1) deconvolution — the
/// pre-restructure per-tile kernel of [`super::deconv_reverse_loop`],
/// with its per-tile accumulator allocation and per-element `i64`
/// division intact.  Returns the tensor *and* the [`OpStats`] so the
/// property tests can pin both.
pub fn deconv_reverse_loop_ref<T: Element>(
    x: &TensorT<T>,
    w: &TensorT<T>,
    b: &[T],
    stride: usize,
    padding: usize,
    opts: ReverseLoopOpts,
) -> (TensorT<T>, OpStats) {
    let [n, c_in, i_h, i_w] = shape4(x);
    let [wc_in, c_out, k, _] = shape4(w);
    assert_eq!(c_in, wc_in);
    assert_eq!(b.len(), c_out);
    let s = stride;
    let p = padding;
    let o_h = super::output_size(i_h, k, s, p);
    let o_w = super::output_size(i_w, k, s, p);
    let t = opts.tile.max(s);
    let t_i = input_tile_extent(t, k, s);

    let f = stride_hole_offsets(k, s, p);
    let mut stats = OpStats {
        modulo_ops: super::offsets::modulo_cost_precomputed(k),
        ..Default::default()
    };

    let eb = T::BYTES as u64;
    let mut y = TensorT::zeros(vec![n, c_out, o_h, o_w]);
    for bi in 0..n {
        let mut th = 0;
        while th < o_h {
            let tile_h = t.min(o_h - th);
            let mut tw = 0;
            while tw < o_w {
                let tile_w = t.min(o_w - tw);
                stats.tiles += 1;
                stats.ext_read_bytes += eb * (c_in * t_i * t_i) as u64;
                stats.ext_read_bytes += eb * (c_in * c_out * k * k) as u64
                    / ((o_h.div_ceil(t) * o_w.div_ceil(t)) as u64).max(1);

                let mut block: Vec<T::Acc> =
                    vec![T::ACC_ZERO; c_out * tile_h * tile_w];
                for co in 0..c_out {
                    let base = co * tile_h * tile_w;
                    let bw = b[co].widen();
                    for v in &mut block[base..base + tile_h * tile_w] {
                        *v = bw;
                    }
                    for ci in 0..c_in {
                        for kh in 0..k {
                            let fh = f[kh];
                            for kw in 0..k {
                                let fw = f[kw];
                                let wv = w.get4(ci, co, kh, kw);
                                if opts.zero_skip {
                                    stats.weight_tests += 1;
                                    if wv.is_zero() {
                                        stats.macs_skipped += tap_count_ref(
                                            th, tile_h, tw, tile_w, fh, fw,
                                            s,
                                        );
                                        continue;
                                    }
                                }
                                let mut oh = next_aligned_ref(th, fh, s);
                                while oh < th + tile_h {
                                    let ih_num =
                                        oh as i64 + p as i64 - kh as i64;
                                    let ih = ih_num / s as i64;
                                    if ih >= 0 && (ih as usize) < i_h {
                                        let row = base + (oh - th) * tile_w;
                                        let mut ow =
                                            next_aligned_ref(tw, fw, s);
                                        while ow < tw + tile_w {
                                            let iw_num = ow as i64 + p as i64
                                                - kw as i64;
                                            let iw = iw_num / s as i64;
                                            if iw >= 0
                                                && (iw as usize) < i_w
                                            {
                                                let xv = x.get4(
                                                    bi,
                                                    ci,
                                                    ih as usize,
                                                    iw as usize,
                                                );
                                                let idx = row + (ow - tw);
                                                block[idx] = T::mac(
                                                    block[idx],
                                                    wv,
                                                    xv,
                                                );
                                                stats.macs_issued += 1;
                                            }
                                            ow += s;
                                        }
                                    }
                                    oh += s;
                                }
                            }
                        }
                    }
                    stats.ext_write_bytes += eb * (tile_h * tile_w) as u64;
                }
                // one-shot write of the finished block
                for co in 0..c_out {
                    let base = co * tile_h * tile_w;
                    for r in 0..tile_h {
                        for c in 0..tile_w {
                            y.set4(
                                bi,
                                co,
                                th + r,
                                tw + c,
                                T::narrow(block[base + r * tile_w + c]),
                            );
                        }
                    }
                }
                tw += t;
            }
            th += t;
        }
    }
    (y, stats)
}

/// Frozen scalar TDC (gather) deconvolution — the pre-restructure
/// per-output-pixel loop nest of [`super::deconv_tdc`] with its inline
/// modulo tests.
pub fn deconv_tdc_ref<T: Element>(
    x: &TensorT<T>,
    w: &TensorT<T>,
    b: &[T],
    stride: usize,
    padding: usize,
) -> TensorT<T> {
    let [n, c_in, i_h, i_w] = shape4(x);
    let [_, c_out, k, _] = shape4(w);
    let s = stride;
    let p = padding;
    let o_h = super::output_size(i_h, k, s, p);
    let o_w = super::output_size(i_w, k, s, p);
    let mut y = TensorT::<T>::zeros(vec![n, c_out, o_h, o_w]);

    for bi in 0..n {
        for co in 0..c_out {
            for oh in 0..o_h {
                for ow in 0..o_w {
                    let mut acc = b[co].widen();
                    for kh in 0..k {
                        let num_h = oh as i64 + p as i64 - kh as i64;
                        if num_h % s as i64 != 0 {
                            continue;
                        }
                        let ih = num_h / s as i64;
                        if ih < 0 || ih >= i_h as i64 {
                            continue;
                        }
                        for kw in 0..k {
                            let num_w = ow as i64 + p as i64 - kw as i64;
                            if num_w % s as i64 != 0 {
                                continue;
                            }
                            let iw = num_w / s as i64;
                            if iw < 0 || iw >= i_w as i64 {
                                continue;
                            }
                            for ci in 0..c_in {
                                acc = T::mac(
                                    acc,
                                    w.get4(ci, co, kh, kw),
                                    x.get4(
                                        bi, ci, ih as usize, iw as usize,
                                    ),
                                );
                            }
                        }
                    }
                    y.set4(bi, co, oh, ow, T::narrow(acc));
                }
            }
        }
    }
    y
}

/// Frozen copy of `next_aligned` (first `o ≥ start` with `o ≡ f mod s`).
#[inline]
fn next_aligned_ref(start: usize, f: usize, s: usize) -> usize {
    let r = start % s;
    if r <= f {
        start + (f - r)
    } else {
        start + (s - r) + f
    }
}

/// Frozen copy of `tap_count` (skip accounting).
#[inline]
fn tap_count_ref(
    th: usize,
    tile_h: usize,
    tw: usize,
    tile_w: usize,
    fh: usize,
    fw: usize,
    s: usize,
) -> u64 {
    let nh = {
        let first = next_aligned_ref(th, fh, s);
        if first >= th + tile_h {
            0
        } else {
            (th + tile_h - first).div_ceil(s)
        }
    };
    let nw = {
        let first = next_aligned_ref(tw, fw, s);
        if first >= tw + tile_w {
            0
        } else {
            (tw + tile_w - first).div_ceil(s)
        }
    };
    (nh * nw) as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;
    use crate::util::Rng;

    /// The three frozen references agree with each other (sanity that
    /// the copies were taken faithfully).
    #[test]
    fn references_agree_in_f32() {
        let mut rng = Rng::seed_from_u64(3);
        let x = Tensor::from_fn(vec![1, 2, 5, 5], |_| {
            rng.range_f32(-1.0, 1.0)
        });
        let w = Tensor::from_fn(vec![2, 3, 4, 4], |_| {
            rng.range_f32(-1.0, 1.0)
        });
        let b = vec![0.1, -0.2, 0.3];
        let std = deconv_standard_ref(&x, &w, &b, 2, 1);
        let (rev, stats) = deconv_reverse_loop_ref(
            &x,
            &w,
            &b,
            2,
            1,
            ReverseLoopOpts {
                tile: 4,
                zero_skip: false,
            },
        );
        let tdc = deconv_tdc_ref(&x, &w, &b, 2, 1);
        assert!(rev.max_abs_diff(&std) < 1e-4);
        assert!(tdc.max_abs_diff(&std) < 1e-4);
        assert!(stats.macs_issued > 0);
    }
}
