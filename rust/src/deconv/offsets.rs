//! Eq. 3 stride-hole offsets and the modulo-operation cost accounting
//! behind the paper's enhancement (1): "preprocessing modulo arithmetic".
//!
//! The offsets `f[k] = mod(S - mod(P - k, S), S)` depend only on the
//! weight index `k`, so a hardware implementation can pre-compute all `K`
//! of them per axis (2K modulo ops total) instead of evaluating Eq. 3 for
//! every output pixel (K² · O_H · O_W / S² evaluations).  Both costs are
//! modeled here; the `ablations` bench quantifies the gap.

/// Non-negative mathematical modulo (the paper's `mod`).
#[inline]
pub fn modulo(a: i64, m: i64) -> i64 {
    ((a % m) + m) % m
}

/// Eq. 3: `f[k] = mod(S - mod(P - k, S), S)` for `k = 0..K`.
pub fn stride_hole_offsets(k: usize, s: usize, p: usize) -> Vec<usize> {
    (0..k)
        .map(|kk| {
            let inner = modulo(p as i64 - kk as i64, s as i64);
            modulo(s as i64 - inner, s as i64) as usize
        })
        .collect()
}

/// Modulo operations required when Eq. 3 is evaluated *inline* for every
/// (k_h, k_w, o_h, o_w) visit of Algorithm 1 (2 `mod`s per evaluation,
/// two axes resolved independently).
pub fn modulo_cost_naive(k: usize, s: usize, o_h: usize, o_w: usize) -> u64 {
    let visits_h = (k * o_h).div_ceil(s) as u64;
    let visits_w = (k * o_w).div_ceil(s) as u64;
    2 * (visits_h * k as u64 + visits_w * k as u64) + 2 * (visits_h * visits_w)
}

/// Modulo operations with the paper's pre-computation: 2 per weight index
/// per axis, i.e. `2K` per layer (K tends to be small, so the offset LUT
/// costs almost nothing in LUT/BRAM terms).
pub fn modulo_cost_precomputed(k: usize) -> u64 {
    2 * k as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn modulo_is_nonnegative() {
        assert_eq!(modulo(-1, 2), 1);
        assert_eq!(modulo(-7, 3), 2);
        assert_eq!(modulo(5, 3), 2);
        assert_eq!(modulo(0, 4), 0);
    }

    #[test]
    fn offsets_match_definition() {
        // K=4, S=2, P=1 (the paper's workhorse layer shape)
        assert_eq!(stride_hole_offsets(4, 2, 1), vec![1, 0, 1, 0]);
        // S=1 degenerates to all zeros (no stride holes)
        assert_eq!(stride_hole_offsets(7, 1, 0), vec![0; 7]);
    }

    #[test]
    fn offsets_make_eq4_divisible() {
        // (o + P - k) must be divisible by S at o = f[k] — the whole point
        for s in 1..5usize {
            for p in 0..4usize {
                for k in 1..8usize {
                    let f = stride_hole_offsets(k, s, p);
                    for (kk, &fk) in f.iter().enumerate() {
                        assert!(fk < s);
                        let num = fk as i64 + p as i64 - kk as i64;
                        assert_eq!(modulo(num, s as i64), 0, "k={kk} s={s} p={p}");
                    }
                }
            }
        }
    }

    #[test]
    fn precompute_beats_naive_for_paper_layers() {
        // K=4, S=2, 32×32 output: inline modulo is thousands of ops,
        // pre-computation is 8.
        let naive = modulo_cost_naive(4, 2, 32, 32);
        let pre = modulo_cost_precomputed(4);
        assert!(naive > 1000 * pre, "naive={naive} pre={pre}");
    }
}
