//! Standard (input-space) deconvolution — Eq. 1 scatter with the
//! overlapping-sum problem.  The unambiguous reference every other path
//! is checked against, and the "complex dataflow" baseline the paper's
//! Section III motivates against.
//!
//! Generic over the element type: contributions scatter into a wide
//! accumulator buffer ([`Element::Acc`]) and narrow once at the end, so
//! the `f32` numerics are unchanged (same addition sequence) and the
//! fixed-point result is bit-identical to the reverse-loop and TDC
//! kernels despite the different loop order.
//!
//! SIMD-shaped loop nest: `c_out` is hoisted to the second-outermost
//! position so each `(bi, co)` pass owns one contiguous output plane,
//! and the innermost loop is a contiguous zip of one kernel row against
//! one output row (the `kw` range pre-clamped to the output frame).
//! Per output element the contribution order is still ascending
//! `(ci, ih, iw, kh, kw)` — exactly the order of the original nest,
//! whose `co` loop was innermost and therefore order-neutral across
//! output elements — so `f32` results are bit-identical to the pinned
//! scalar reference ([`super::reference::deconv_standard_ref`]).

use super::tiling::BlockSchedule;
use crate::quant::Element;
use crate::tensor::TensorT;
use crate::util::{with_scratch, WorkerPool};

/// Transposed convolution by scattering each input pixel to
/// `o = i·S + k - P` (Eq. 1), accumulating over overlaps.
///
/// * `x` — `[N, C_in, I_H, I_W]`
/// * `w` — `[C_in, C_out, K, K]`
/// * `b` — `[C_out]`
///
/// Returns `[N, C_out, O_H, O_W]`.
pub fn deconv_standard<T: Element>(
    x: &TensorT<T>,
    w: &TensorT<T>,
    b: &[T],
    stride: usize,
    padding: usize,
) -> TensorT<T> {
    let [n, c_in, i_h, i_w] = shape4(x);
    let [wc_in, c_out, k, k2] = shape4(w);
    assert_eq!(c_in, wc_in, "weight C_in mismatch");
    assert_eq!(k, k2, "kernel must be square");
    assert_eq!(b.len(), c_out, "bias length mismatch");
    let o_h = super::output_size(i_h, k, stride, padding);
    let o_w = super::output_size(i_w, k, stride, padding);

    let xdata = x.data();
    let wdata = w.data();
    let mut acc: Vec<T::Acc> = vec![T::ACC_ZERO; n * c_out * o_h * o_w];
    for bi in 0..n {
        for co in 0..c_out {
            // each (bi, co) pass owns one contiguous output plane
            let plane =
                &mut acc[(bi * c_out + co) * o_h * o_w..][..o_h * o_w];
            // initialize the accumulator plane to the (widened) bias
            let bw = b[co].widen();
            for v in plane.iter_mut() {
                *v = bw;
            }
            for ci in 0..c_in {
                let x_img =
                    &xdata[(bi * c_in + ci) * i_h * i_w..][..i_h * i_w];
                let w_chan = &wdata[(ci * c_out + co) * k * k..][..k * k];
                for ih in 0..i_h {
                    let xrow = &x_img[ih * i_w..][..i_w];
                    for (iw, &v) in xrow.iter().enumerate() {
                        if v.is_zero() {
                            continue;
                        }
                        // clamp the kw range so ow = iw·S + kw - P stays
                        // inside [0, O_W) — resolves the per-element
                        // bounds branch once per input pixel
                        let ow_base = (iw * stride) as i64 - padding as i64;
                        let kw_lo = (-ow_base).clamp(0, k as i64) as usize;
                        let kw_hi =
                            (o_w as i64 - ow_base).clamp(0, k as i64) as usize;
                        if kw_lo >= kw_hi {
                            continue;
                        }
                        let ow_first = (ow_base + kw_lo as i64) as usize;
                        for kh in 0..k {
                            let oh =
                                (ih * stride + kh) as i64 - padding as i64;
                            if oh < 0 || oh >= o_h as i64 {
                                continue;
                            }
                            let wrow = &w_chan[kh * k + kw_lo..][..kw_hi - kw_lo];
                            let arow = &mut plane
                                [oh as usize * o_w + ow_first..]
                                [..kw_hi - kw_lo];
                            // contiguous scatter of one kernel row into
                            // one output row — autovectorizes
                            for (a, &wv) in arow.iter_mut().zip(wrow) {
                                *a = T::mac(*a, wv, v);
                            }
                        }
                    }
                }
            }
        }
    }
    let data: Vec<T> = acc.into_iter().map(T::narrow).collect();
    TensorT::new(vec![n, c_out, o_h, o_w], data).expect("output shape")
}

pub(crate) fn shape4<T: Element>(t: &TensorT<T>) -> [usize; 4] {
    let s = t.shape();
    assert_eq!(s.len(), 4, "expected rank-4 tensor, got {s:?}");
    [s[0], s[1], s[2], s[3]]
}

/// Shared read-only context for the blocked scatter jobs.
struct StdCtx<'a, T: Element> {
    x: &'a TensorT<T>,
    w: &'a TensorT<T>,
    b: &'a [T],
    s: usize,
    p: usize,
    c_in: usize,
    c_out: usize,
    k: usize,
    i_h: usize,
    i_w: usize,
    o_w: usize,
}

/// One output-row block of one `(bi, co)` plane — the blocked scatter's
/// unit of work.
#[derive(Debug, Clone, Copy)]
struct StdJob {
    bi: usize,
    co: usize,
    /// Output rows `[r0, r1)`.
    r0: usize,
    r1: usize,
}

/// Scatter Eq. 1 into one row block, appending the narrowed rows to
/// `out`.  The input-row range is pre-restricted to the rows that can
/// reach the block (`oh = ih·S + kh − P ∈ [r0, r1)` for some `kh`), and
/// the innermost kernel-row zip runs `LANES`-wide lane accumulators
/// over independent output columns.  Per output element the
/// contribution order is still ascending `(ci, ih, iw, kh, kw)` — the
/// reference order — because restricting `ih` to the superset of rows
/// touching the block drops only zero-contribution iterations, and
/// each lane column keeps its own chain.
fn standard_block_kernel<T: Element, const LANES: usize>(
    ctx: &StdCtx<'_, T>,
    job: StdJob,
    out: &mut Vec<T>,
) {
    let StdJob { bi, co, r0, r1 } = job;
    let (s, p, k) = (ctx.s, ctx.p, ctx.k);
    let (i_h, i_w, o_w) = (ctx.i_h, ctx.i_w, ctx.o_w);
    let rows = r1 - r0;
    let xdata = ctx.x.data();
    let wdata = ctx.w.data();
    // Input rows that can touch this block:
    // ih·S ≥ r0 + P − (K−1)  and  ih·S ≤ r1 − 1 + P.
    let si = s as i64;
    let lo_num = r0 as i64 + p as i64 - (k as i64 - 1);
    let ih_lo = (lo_num + si - 1).div_euclid(si).max(0) as usize;
    let ih_hi = ((r1 as i64 - 1 + p as i64).div_euclid(si))
        .min(i_h as i64 - 1);
    with_scratch(rows * o_w, T::ACC_ZERO, |plane| {
        let bw = ctx.b[co].widen();
        for v in plane.iter_mut() {
            *v = bw;
        }
        if ih_hi >= ih_lo as i64 {
            let ih_hi = ih_hi as usize;
            for ci in 0..ctx.c_in {
                let x_img =
                    &xdata[(bi * ctx.c_in + ci) * i_h * i_w..][..i_h * i_w];
                let w_chan =
                    &wdata[(ci * ctx.c_out + co) * k * k..][..k * k];
                for ih in ih_lo..=ih_hi {
                    let xrow = &x_img[ih * i_w..][..i_w];
                    for (iw, &v) in xrow.iter().enumerate() {
                        if v.is_zero() {
                            continue;
                        }
                        let ow_base = (iw * s) as i64 - p as i64;
                        let kw_lo =
                            (-ow_base).clamp(0, k as i64) as usize;
                        let kw_hi = (o_w as i64 - ow_base)
                            .clamp(0, k as i64)
                            as usize;
                        if kw_lo >= kw_hi {
                            continue;
                        }
                        let ow_first = (ow_base + kw_lo as i64) as usize;
                        for kh in 0..k {
                            let oh = (ih * s + kh) as i64 - p as i64;
                            if oh < r0 as i64 || oh >= r1 as i64 {
                                continue;
                            }
                            let wrow = &w_chan[kh * k + kw_lo..]
                                [..kw_hi - kw_lo];
                            let arow = &mut plane[(oh as usize - r0)
                                * o_w
                                + ow_first..]
                                [..kw_hi - kw_lo];
                            let mut ab = arow.chunks_exact_mut(LANES);
                            let mut wb = wrow.chunks_exact(LANES);
                            for (a_lane, w_lane) in
                                (&mut ab).zip(&mut wb)
                            {
                                let mut lane: [T::Acc; LANES] =
                                    (&*a_lane)
                                        .try_into()
                                        .expect("lane chunk");
                                for l in 0..LANES {
                                    lane[l] =
                                        T::mac(lane[l], w_lane[l], v);
                                }
                                a_lane.copy_from_slice(&lane);
                            }
                            for (a, &wv) in ab
                                .into_remainder()
                                .iter_mut()
                                .zip(wb.remainder())
                            {
                                *a = T::mac(*a, wv, v);
                            }
                        }
                    }
                }
            }
        }
        out.extend(plane.iter().map(|&a| T::narrow(a)));
    });
}

fn standard_block_into<T: Element>(
    ctx: &StdCtx<'_, T>,
    job: StdJob,
    lanes: usize,
    out: &mut Vec<T>,
) {
    match lanes {
        1 => standard_block_kernel::<T, 1>(ctx, job, out),
        2 => standard_block_kernel::<T, 2>(ctx, job, out),
        8 => standard_block_kernel::<T, 8>(ctx, job, out),
        16 => standard_block_kernel::<T, 16>(ctx, job, out),
        _ => standard_block_kernel::<T, 4>(ctx, job, out),
    }
}

/// [`deconv_standard`] restructured around a two-level
/// [`BlockSchedule`]: `micro`-row output blocks of each `(bi, co)`
/// plane are the jobs, `macro_tiles` consecutive jobs form one pool
/// claim unit, and the innermost kernel-row zip runs `lanes`-wide
/// accumulators.  Bit-identical to [`deconv_standard`] (and therefore
/// to the frozen scalar reference) for every legal schedule, which the
/// property tests pin.
///
/// `sched: None` consults the persisted tune table for this (kernel,
/// element, shape), falling back to the static default.
pub fn deconv_standard_blocked<T: Element>(
    x: &TensorT<T>,
    w: &TensorT<T>,
    b: &[T],
    stride: usize,
    padding: usize,
    sched: Option<BlockSchedule>,
    pool: &WorkerPool,
) -> TensorT<T> {
    let [n, c_in, i_h, i_w] = shape4(x);
    let [wc_in, c_out, k, k2] = shape4(w);
    assert_eq!(c_in, wc_in, "weight C_in mismatch");
    assert_eq!(k, k2, "kernel must be square");
    assert_eq!(b.len(), c_out, "bias length mismatch");
    let o_h = super::output_size(i_h, k, stride, padding);
    let o_w = super::output_size(i_w, k, stride, padding);
    let sched = sched.map(BlockSchedule::normalized).unwrap_or_else(|| {
        crate::tune::schedule_for::<T>(
            crate::tune::TuneKernel::Standard,
            c_in,
            c_out,
            k,
            stride,
            o_h,
            None,
        )
    });
    let ctx = StdCtx {
        x,
        w,
        b,
        s: stride,
        p: padding,
        c_in,
        c_out,
        k,
        i_h,
        i_w,
        o_w,
    };
    // Row-block jobs in (bi, co, r0) order — disjoint output regions.
    let micro = sched.micro.max(1);
    let mut jobs = Vec::new();
    for bi in 0..n {
        for co in 0..c_out {
            let mut r0 = 0;
            while r0 < o_h {
                let r1 = (r0 + micro).min(o_h);
                jobs.push(StdJob { bi, co, r0, r1 });
                r0 = r1;
            }
        }
    }
    let g = sched.macro_tiles.max(1);
    let lanes = sched.lanes;
    let n_macro = jobs.len().div_ceil(g);
    let results = pool.map_indexed_auto(n_macro, |m| {
        let lo = m * g;
        let hi = (lo + g).min(jobs.len());
        let member = &jobs[lo..hi];
        let total: usize =
            member.iter().map(|j| (j.r1 - j.r0) * o_w).sum();
        let mut out = Vec::with_capacity(total);
        for &job in member {
            standard_block_into(&ctx, job, lanes, &mut out);
        }
        out
    });
    let mut y = TensorT::zeros(vec![n, c_out, o_h, o_w]);
    let ydata = y.data_mut();
    for (m, mblock) in results.iter().enumerate() {
        let lo = m * g;
        let hi = (lo + g).min(jobs.len());
        let mut off = 0usize;
        for job in &jobs[lo..hi] {
            let len = (job.r1 - job.r0) * o_w;
            let dst =
                ((job.bi * c_out + job.co) * o_h + job.r0) * o_w;
            ydata[dst..dst + len]
                .copy_from_slice(&mblock[off..off + len]);
            off += len;
        }
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::Q8_8;
    use crate::tensor::Tensor;

    /// 1×1 input: output is just the (bias-shifted) kernel scaled by x.
    #[test]
    fn single_pixel_emits_kernel() {
        let x = Tensor::new(vec![1, 1, 1, 1], vec![2.0]).unwrap();
        let w = Tensor::from_fn(vec![1, 1, 3, 3], |i| i as f32);
        let y = deconv_standard(&x, &w, &[1.0], 1, 0);
        assert_eq!(y.shape(), &[1, 1, 3, 3]);
        for i in 0..9 {
            assert_eq!(y.data()[i], 2.0 * i as f32 + 1.0);
        }
    }

    /// Stride-2 upsampling: identity kernel doubles extent with holes.
    #[test]
    fn stride_two_places_pixels() {
        let x = Tensor::new(vec![1, 1, 2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let w = Tensor::new(vec![1, 1, 1, 1], vec![1.0]).unwrap();
        let y = deconv_standard(&x, &w, &[0.0], 2, 0);
        // O = (2-1)*2 + 1 = 3
        assert_eq!(y.shape(), &[1, 1, 3, 3]);
        assert_eq!(y.get4(0, 0, 0, 0), 1.0);
        assert_eq!(y.get4(0, 0, 0, 2), 2.0);
        assert_eq!(y.get4(0, 0, 2, 0), 3.0);
        assert_eq!(y.get4(0, 0, 2, 2), 4.0);
        assert_eq!(y.get4(0, 0, 1, 1), 0.0);
    }

    /// Padding crops the output frame.
    #[test]
    fn padding_crops_output() {
        let x = Tensor::new(vec![1, 1, 2, 2], vec![1.0; 4]).unwrap();
        let w = Tensor::new(vec![1, 1, 4, 4], vec![1.0; 16]).unwrap();
        let y = deconv_standard(&x, &w, &[0.0], 2, 1);
        assert_eq!(y.shape(), &[1, 1, 4, 4]);
    }

    /// Overlapping contributions must accumulate (the overlapping-sum
    /// behaviour the reverse-loop algorithm is designed to avoid *in
    /// hardware* while staying numerically identical).
    #[test]
    fn overlaps_accumulate() {
        // two stacked input pixels, 3×3 ones kernel, S=1: the middle
        // output rows receive two contributions each
        let x = Tensor::new(vec![1, 1, 2, 1], vec![1.0, 1.0]).unwrap();
        let w = Tensor::new(vec![1, 1, 3, 3], vec![1.0; 9]).unwrap();
        let y = deconv_standard(&x, &w, &[0.0], 1, 0);
        assert_eq!(y.shape(), &[1, 1, 4, 3]);
        for col in 0..3 {
            assert_eq!(y.get4(0, 0, 0, col), 1.0);
            assert_eq!(y.get4(0, 0, 1, col), 2.0);
            assert_eq!(y.get4(0, 0, 2, col), 2.0);
            assert_eq!(y.get4(0, 0, 3, col), 1.0);
        }
    }

    /// The restructured nest (hoisted `co`, clamped contiguous `kw`
    /// zip) is bit-identical to the pinned pre-PR scalar reference.
    #[test]
    fn bit_identical_to_pinned_scalar_reference() {
        use crate::deconv::deconv_standard_ref;
        use crate::util::Rng;
        let mut rng = Rng::seed_from_u64(29);
        for (n, c_in, c_out, k, s, p, i_h) in [
            (1, 2, 3, 4, 2, 1, 5),
            (2, 3, 2, 7, 1, 0, 3),
            (1, 2, 2, 3, 3, 1, 4),
            (1, 1, 1, 5, 2, 2, 6),
        ] {
            let x = Tensor::from_fn(vec![n, c_in, i_h, i_h], |_| {
                rng.range_f32(-1.0, 1.0)
            });
            let mut w = Tensor::from_fn(vec![c_in, c_out, k, k], |_| {
                rng.range_f32(-1.0, 1.0)
            });
            for (i, v) in w.data_mut().iter_mut().enumerate() {
                if i % 3 == 0 {
                    *v = 0.0;
                }
            }
            let b: Vec<f32> =
                (0..c_out).map(|_| rng.range_f32(-0.5, 0.5)).collect();
            let want = deconv_standard_ref(&x, &w, &b, s, p);
            let got = deconv_standard(&x, &w, &b, s, p);
            assert_eq!(
                got.data(),
                want.data(),
                "({n},{c_in},{c_out},{k},{s},{p},{i_h}): f32 must match \
                 the scalar reference bit for bit"
            );
        }
    }

    /// Row-blocked, lane-accumulated scatter is bit-identical to the
    /// frozen scalar reference for every (micro, macro, lanes) triple,
    /// serial and parallel.
    #[test]
    fn blocked_is_bit_identical_to_pinned_scalar_reference() {
        use crate::deconv::deconv_standard_ref;
        use crate::util::Rng;
        let mut rng = Rng::seed_from_u64(41);
        for (n, c_in, c_out, k, s, p, i_h) in
            [(1, 2, 3, 4, 2, 1, 5), (2, 3, 2, 7, 1, 0, 3)]
        {
            let x = Tensor::from_fn(vec![n, c_in, i_h, i_h], |_| {
                rng.range_f32(-1.0, 1.0)
            });
            let mut w = Tensor::from_fn(vec![c_in, c_out, k, k], |_| {
                rng.range_f32(-1.0, 1.0)
            });
            for (i, v) in w.data_mut().iter_mut().enumerate() {
                if i % 3 == 0 {
                    *v = 0.0;
                }
            }
            let b: Vec<f32> =
                (0..c_out).map(|_| rng.range_f32(-0.5, 0.5)).collect();
            let want = deconv_standard_ref(&x, &w, &b, s, p);
            for micro in [1usize, 3, 5, 64] {
                for macro_tiles in [1usize, 2, 8] {
                    for lanes in [1usize, 2, 4, 8] {
                        let sched = BlockSchedule {
                            micro,
                            macro_tiles,
                            lanes,
                        };
                        for workers in [1usize, 4] {
                            let got = deconv_standard_blocked(
                                &x,
                                &w,
                                &b,
                                s,
                                p,
                                Some(sched),
                                &WorkerPool::new(workers),
                            );
                            assert_eq!(
                                got.data(),
                                want.data(),
                                "micro={micro} macro={macro_tiles} \
                                 lanes={lanes} w={workers}"
                            );
                        }
                    }
                }
            }
        }
    }

    /// The same scatter in Q8.8: grid-point inputs give exact outputs.
    #[test]
    fn fixed_point_scatter_is_exact_on_grid() {
        let q = Q8_8::from_f32;
        let x = TensorT::new(vec![1, 1, 1, 1], vec![q(2.0)]).unwrap();
        let w = TensorT::from_fn(vec![1, 1, 3, 3], |i| q(i as f32 * 0.25));
        let y = deconv_standard(&x, &w, &[q(1.0)], 1, 0);
        assert_eq!(y.shape(), &[1, 1, 3, 3]);
        for i in 0..9 {
            assert_eq!(y.data()[i].to_f32(), 2.0 * (i as f32 * 0.25) + 1.0);
        }
    }
}
