//! Algorithm 1 — the paper's reverse-looping deconvolution kernel, as
//! executed by each simulated CU: output-space traversal, pre-computed
//! Eq. 3 offsets, weight-stationary loop order (enhancement 2), tiled
//! output blocks with one-shot writes, and optional zero-skipping.
//!
//! The kernel is organized exactly like the hardware: the output space is
//! cut into independent tile jobs, each job produces its own output block
//! plus its own [`OpStats`], and the blocks are merged back one-shot.
//! [`deconv_reverse_loop`] walks the jobs serially;
//! [`deconv_reverse_loop_par`] shards them across a [`WorkerPool`] — the
//! software mirror of the paper's spatial CU parallelism.  Both paths run
//! the same per-tile kernel in the same order per tile, so they are
//! **bit-identical** (tensors *and* op counts), which the integration and
//! property tests assert.
//!
//! Execution follows the two-level [`BlockSchedule`] geometry shared
//! with the CU simulator: micro-tile jobs (the `ReverseLoopOpts::tile`
//! factor — unchanged OpStats geometry) are grouped into **macro-tiles**
//! of `macro_tiles` consecutive jobs, which are the units
//! [`WorkerPool::map_indexed_auto`] claims (the first macro-tile's
//! measured cost seeds the claim granularity), and the innermost column
//! walk runs `lanes`-wide **lane accumulators** over independent output
//! columns.  Neither level changes results: macro grouping only batches
//! disjoint jobs, and each output column keeps its own accumulation
//! chain at any lane width.
//!
//! Generic over the element type ([`Element`]): each tile accumulates in
//! the wide [`Element::Acc`] domain and narrows once at the one-shot
//! write — the DSP48 shape — so `f32` numerics are unchanged and fixed
//! point is bit-identical to the standard kernel.  [`OpStats`] byte
//! counts use [`Element::BYTES`], so the FPGA cycle model sees the real
//! external-memory traffic of the chosen precision.

use super::offsets::stride_hole_offsets;
use super::standard::shape4;
use super::tiling::{input_tile_extent, BlockSchedule};
use crate::quant::Element;
use crate::tensor::TensorT;
use crate::util::{with_scratch, WorkerPool};

/// Execution options for the reverse-loop kernel.
#[derive(Debug, Clone, Copy)]
pub struct ReverseLoopOpts {
    /// Output tiling factor `T_OH == T_OW` (the paper's DSE knob).
    pub tile: usize,
    /// Conditional-execution paradigm: skip MACs whose weight is exactly
    /// zero (the paper's Section V-C speed-up mechanism).
    pub zero_skip: bool,
}

impl Default for ReverseLoopOpts {
    fn default() -> Self {
        ReverseLoopOpts {
            tile: 12,
            zero_skip: false,
        }
    }
}

/// Operation counts accumulated while executing Algorithm 1 — the
/// contract between the algorithm substrate and the FPGA cycle model.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct OpStats {
    /// Multiply-accumulates actually issued.
    pub macs_issued: u64,
    /// MACs elided by zero-skipping (still cost a 1-cycle weight test in
    /// the CU model).
    pub macs_skipped: u64,
    /// Weight-zero tests performed (= weight taps visited when
    /// zero-skipping is on).
    pub weight_tests: u64,
    /// Modulo operations executed (2K per layer thanks to enhancement 1).
    pub modulo_ops: u64,
    /// Bytes read from "external memory" (input tiles + weight blocks).
    pub ext_read_bytes: u64,
    /// Bytes written to "external memory" (one-shot output blocks).
    pub ext_write_bytes: u64,
    /// Output tiles processed (CU workloads dispatched).
    pub tiles: u64,
}

impl OpStats {
    pub fn merge(&mut self, o: &OpStats) {
        self.macs_issued += o.macs_issued;
        self.macs_skipped += o.macs_skipped;
        self.weight_tests += o.weight_tests;
        self.modulo_ops += o.modulo_ops;
        self.ext_read_bytes += o.ext_read_bytes;
        self.ext_write_bytes += o.ext_write_bytes;
        self.tiles += o.tiles;
    }
}

/// Everything a tile job needs, borrowed from the caller (shared
/// read-only across workers).
struct TileCtx<'a, T: Element> {
    x: &'a TensorT<T>,
    w: &'a TensorT<T>,
    b: &'a [T],
    s: usize,
    p: usize,
    zero_skip: bool,
    /// Pre-computed Eq. 3 offsets.
    f: &'a [usize],
    c_in: usize,
    c_out: usize,
    k: usize,
    i_h: usize,
    i_w: usize,
    o_h: usize,
    o_w: usize,
    /// Effective tile factor.
    t: usize,
    /// Eq. 5 input tile extent.
    t_i: usize,
}

/// One spatial output tile of one batch image — the unit of work a CU
/// (or pool worker) claims.
#[derive(Debug, Clone, Copy)]
struct TileJob {
    bi: usize,
    th: usize,
    tw: usize,
    tile_h: usize,
    tile_w: usize,
}

/// Enumerate tile jobs in the serial traversal order (batch-major,
/// row-major tiles) so serial and parallel merges see the same sequence.
fn tile_jobs(n: usize, o_h: usize, o_w: usize, t: usize) -> Vec<TileJob> {
    let mut jobs = Vec::new();
    for bi in 0..n {
        let mut th = 0;
        while th < o_h {
            let tile_h = t.min(o_h - th);
            let mut tw = 0;
            while tw < o_w {
                let tile_w = t.min(o_w - tw);
                jobs.push(TileJob {
                    bi,
                    th,
                    tw,
                    tile_h,
                    tile_w,
                });
                tw += t;
            }
            th += t;
        }
    }
    jobs
}

/// One tap's hoisted traversal range along one axis: the `j`-th visit
/// touches output `o0 + j·s` and input `i0 + j`, for `j ∈ [lo, hi)`.
/// All Eq. 3/Eq. 4 arithmetic (alignment, the exact `(o + P - k)/S`
/// division, and both input-bounds checks) is resolved here, once per
/// tap per axis, so the MAC loops below run with no division and no
/// branch per element.
#[derive(Clone, Copy)]
struct TapSpan {
    /// First aligned output coordinate in the tile (absolute).
    o0: usize,
    /// Input coordinate paired with `o0` (may be out of bounds; only
    /// `j ∈ [lo, hi)` is valid).
    i0: i64,
    lo: usize,
    hi: usize,
}

impl TapSpan {
    #[inline]
    fn of(
        t0: usize,
        tile: usize,
        f: usize,
        k: usize,
        p: usize,
        s: usize,
        i_extent: usize,
    ) -> TapSpan {
        let o0 = next_aligned(t0, f, s);
        let end = t0 + tile;
        let n = if o0 >= end { 0 } else { (end - o0).div_ceil(s) };
        // exact by the Eq. 3 offset invariant: (o0 + P - k) ≡ 0 (mod S)
        let i0 = (o0 as i64 + p as i64 - k as i64).div_euclid(s as i64);
        let lo = (-i0).max(0).min(n as i64) as usize;
        let hi = (i_extent as i64 - i0).clamp(0, n as i64) as usize;
        TapSpan { o0, i0, lo, hi }
    }

    #[inline]
    fn len(&self) -> usize {
        self.hi.saturating_sub(self.lo)
    }
}

/// Execute Algorithm 1 for one micro-tile job, appending the finished
/// output block (`[c_out, tile_h, tile_w]`, row-major, narrowed) to
/// `out` and returning the tile's op counts.  This is the kernel both
/// the serial and the parallel path run, so their numerics are
/// identical by construction.
///
/// SIMD-shaped formulation: per-tap output/input ranges are hoisted
/// ([`TapSpan`]), the accumulator block comes from the per-worker
/// scratch arena ([`with_scratch`]) instead of a per-tile allocation,
/// and the innermost loop walks one input row against a (unit- or
/// `S`-strided) accumulator row in `LANES`-wide register blocks
/// (`[Element::Acc; LANES]` over independent output columns) — no
/// division, no bounds check, no branch per element, so it
/// autovectorizes for `f32` and `Fixed` alike.  Bit-identity with the
/// pinned scalar reference ([`super::reference`]) holds for **any**
/// lane width because each output column keeps its own accumulation
/// chain: every output element still receives its taps in ascending
/// `(ci, kh, kw)` order with the same [`Element::mac`]; only
/// loop-invariant arithmetic and the traversal batching moved.
fn tile_kernel<T: Element, const LANES: usize>(
    ctx: &TileCtx<'_, T>,
    job: TileJob,
    out: &mut Vec<T>,
) -> OpStats {
    let TileJob {
        bi,
        th,
        tw,
        tile_h,
        tile_w,
    } = job;
    let s = ctx.s;
    let p = ctx.p;
    let k = ctx.k;
    let (i_h, i_w) = (ctx.i_h, ctx.i_w);
    let eb = T::BYTES as u64;
    let mut stats = OpStats {
        tiles: 1,
        ..Default::default()
    };
    // Decoupled prefetch accounting (enhancement 3): the input block
    // covering this output tile is read once per c_in pass, sequentially;
    // weights once per (c_in, tile).
    stats.ext_read_bytes += eb * (ctx.c_in * ctx.t_i * ctx.t_i) as u64;
    stats.ext_read_bytes += eb * (ctx.c_in * ctx.c_out * ctx.k * ctx.k) as u64
        / ((ctx.o_h.div_ceil(ctx.t) * ctx.o_w.div_ceil(ctx.t)) as u64).max(1);

    let xdata = ctx.x.data();
    let wdata = ctx.w.data();

    // Hoist the per-tap spans: they depend only on (k index, axis), not
    // on (co, ci), so K spans per axis cover every tap of the tile.
    let mut spans_h = [TapSpan {
        o0: 0,
        i0: 0,
        lo: 0,
        hi: 0,
    }; 16];
    let mut spans_w = spans_h;
    let spans_heap_h: Vec<TapSpan>;
    let spans_heap_w: Vec<TapSpan>;
    let (spans_h, spans_w): (&[TapSpan], &[TapSpan]) = if k <= 16 {
        for kk in 0..k {
            spans_h[kk] = TapSpan::of(th, tile_h, ctx.f[kk], kk, p, s, i_h);
            spans_w[kk] = TapSpan::of(tw, tile_w, ctx.f[kk], kk, p, s, i_w);
        }
        (&spans_h[..k], &spans_w[..k])
    } else {
        spans_heap_h = (0..k)
            .map(|kk| TapSpan::of(th, tile_h, ctx.f[kk], kk, p, s, i_h))
            .collect();
        spans_heap_w = (0..k)
            .map(|kk| TapSpan::of(tw, tile_w, ctx.f[kk], kk, p, s, i_w))
            .collect();
        (&spans_heap_h, &spans_heap_w)
    };

    // Per-tile accumulator block in the wide domain, leased from the
    // per-worker scratch arena (re-zeroed on acquisition); narrowed
    // once at the one-shot write below.
    with_scratch(
        ctx.c_out * tile_h * tile_w,
        T::ACC_ZERO,
        |block| {
            for co in 0..ctx.c_out {
                let base = co * tile_h * tile_w;
                // y <- initializeToBias()
                let bw = ctx.b[co].widen();
                for v in &mut block[base..base + tile_h * tile_w] {
                    *v = bw;
                }
                for ci in 0..ctx.c_in {
                    let x_img = &xdata
                        [(bi * ctx.c_in + ci) * i_h * i_w..][..i_h * i_w];
                    let w_base = (ci * ctx.c_out + co) * k * k;
                    // weight-stationary loops (enhancement 2)
                    for kh in 0..k {
                        let sh = spans_h[kh];
                        for kw in 0..k {
                            let wv = wdata[w_base + kh * k + kw];
                            if ctx.zero_skip {
                                stats.weight_tests += 1;
                                if wv.is_zero() {
                                    // skip the whole tap for this tile
                                    stats.macs_skipped += tap_count(
                                        th, tile_h, tw, tile_w, ctx.f[kh],
                                        ctx.f[kw], s,
                                    );
                                    continue;
                                }
                            }
                            let sw = spans_w[kw];
                            let cols = sw.len();
                            if cols == 0 || sh.len() == 0 {
                                continue;
                            }
                            stats.macs_issued +=
                                (sh.len() * cols) as u64;
                            let iw_first = (sw.i0
                                + sw.lo as i64)
                                as usize;
                            let bw_first =
                                sw.o0 + sw.lo * s - tw;
                            // o = f + S·t traversal, bounds pre-resolved
                            for j in sh.lo..sh.hi {
                                let oh = sh.o0 + j * s;
                                let ih = (sh.i0 + j as i64) as usize;
                                let xrow = &x_img
                                    [ih * i_w + iw_first..][..cols];
                                let row_off =
                                    base + (oh - th) * tile_w + bw_first;
                                if s == 1 {
                                    let brow =
                                        &mut block[row_off..][..cols];
                                    let mut ob =
                                        brow.chunks_exact_mut(LANES);
                                    let mut xb =
                                        xrow.chunks_exact(LANES);
                                    for (o_lane, x_lane) in
                                        (&mut ob).zip(&mut xb)
                                    {
                                        let mut lane: [T::Acc; LANES] =
                                            (&*o_lane)
                                                .try_into()
                                                .expect("lane chunk");
                                        for l in 0..LANES {
                                            lane[l] = T::mac(
                                                lane[l], wv, x_lane[l],
                                            );
                                        }
                                        o_lane.copy_from_slice(&lane);
                                    }
                                    for (o, &xv) in ob
                                        .into_remainder()
                                        .iter_mut()
                                        .zip(xb.remainder())
                                    {
                                        *o = T::mac(*o, wv, xv);
                                    }
                                } else {
                                    let brow = &mut block[row_off..]
                                        [..(cols - 1) * s + 1];
                                    let mut j = 0usize;
                                    while j + LANES <= cols {
                                        let mut lane =
                                            [T::ACC_ZERO; LANES];
                                        for l in 0..LANES {
                                            lane[l] =
                                                brow[(j + l) * s];
                                        }
                                        for l in 0..LANES {
                                            lane[l] = T::mac(
                                                lane[l],
                                                wv,
                                                xrow[j + l],
                                            );
                                        }
                                        for l in 0..LANES {
                                            brow[(j + l) * s] =
                                                lane[l];
                                        }
                                        j += LANES;
                                    }
                                    let mut bidx = j * s;
                                    for &xv in &xrow[j..] {
                                        brow[bidx] =
                                            T::mac(brow[bidx], wv, xv);
                                        bidx += s;
                                    }
                                }
                            }
                        }
                    }
                }
                // one-shot write of the finished output block
                stats.ext_write_bytes += eb * (tile_h * tile_w) as u64;
            }
            // narrow the finished block into the caller's (pre-sized)
            // macro buffer — no per-tile result allocation
            out.extend(block.iter().map(|&a| T::narrow(a)));
        },
    );
    stats
}

/// Route one micro-tile to the monomorphized `LANES`-wide kernel
/// instance.  Unsupported widths are rounded down by
/// [`BlockSchedule::normalized`] before dispatch; 4 is the defensive
/// fallback.
fn execute_tile_into<T: Element>(
    ctx: &TileCtx<'_, T>,
    job: TileJob,
    lanes: usize,
    out: &mut Vec<T>,
) -> OpStats {
    match lanes {
        1 => tile_kernel::<T, 1>(ctx, job, out),
        2 => tile_kernel::<T, 2>(ctx, job, out),
        8 => tile_kernel::<T, 8>(ctx, job, out),
        16 => tile_kernel::<T, 16>(ctx, job, out),
        _ => tile_kernel::<T, 4>(ctx, job, out),
    }
}

/// One macro-tile: run its member micro-tile jobs sequentially on this
/// worker, concatenating their finished blocks (in job order) into one
/// buffer — a single allocation per macro-tile instead of one per tile
/// — and merging their [`OpStats`].  Blocking changes neither tensors
/// nor stats: member output regions are disjoint and every `OpStats`
/// field is a commutative `u64` sum.
fn execute_macro<T: Element>(
    ctx: &TileCtx<'_, T>,
    jobs: &[TileJob],
    lanes: usize,
) -> (Vec<T>, OpStats) {
    let total: usize = jobs
        .iter()
        .map(|j| ctx.c_out * j.tile_h * j.tile_w)
        .sum();
    let mut out = Vec::with_capacity(total);
    let mut stats = OpStats::default();
    for &job in jobs {
        let tile_stats = execute_tile_into(ctx, job, lanes, &mut out);
        stats.merge(&tile_stats);
    }
    (out, stats)
}

/// Shared driver: enumerate micro-tile jobs, group them into
/// macro-tiles per the [`BlockSchedule`], run the macro-tiles on the
/// given pool, merge the blocks and stats in job order.
///
/// Invariant: `sched.micro == opts.tile` — the micro-tile *is* the
/// OpStats tile factor, so blocking is invisible to the stats contract.
fn run_reverse_loop<T: Element>(
    x: &TensorT<T>,
    w: &TensorT<T>,
    b: &[T],
    stride: usize,
    padding: usize,
    opts: ReverseLoopOpts,
    sched: BlockSchedule,
    pool: &WorkerPool,
) -> (TensorT<T>, OpStats) {
    let [n, c_in, i_h, i_w] = shape4(x);
    let [wc_in, c_out, k, _] = shape4(w);
    assert_eq!(c_in, wc_in);
    assert_eq!(b.len(), c_out);
    let s = stride;
    let p = padding;
    let o_h = super::output_size(i_h, k, s, p);
    let o_w = super::output_size(i_w, k, s, p);
    let t = opts.tile.max(s);

    // Enhancement (1): pre-compute the Eq. 3 offsets once per layer.
    let f = stride_hole_offsets(k, s, p);
    let mut stats = OpStats {
        modulo_ops: super::offsets::modulo_cost_precomputed(k),
        ..Default::default()
    };

    let ctx = TileCtx {
        x,
        w,
        b,
        s,
        p,
        zero_skip: opts.zero_skip,
        f: &f,
        c_in,
        c_out,
        k,
        i_h,
        i_w,
        o_h,
        o_w,
        t,
        t_i: input_tile_extent(t, k, s),
    };
    let jobs = tile_jobs(n, o_h, o_w, t);
    // Macro-tile dispatch: `macro_tiles` consecutive micro-tile jobs
    // form one pool claim unit whose combined input footprint targets
    // L2, and the first macro-tile's measured cost seeds the adaptive
    // claim granularity ([`WorkerPool::map_indexed_auto`]).  Results
    // are identical for any grouping (each macro owns its slot and its
    // members run in job order).
    let g = sched.macro_tiles.max(1);
    let lanes = sched.lanes;
    let n_macro = jobs.len().div_ceil(g);
    let results = pool.map_indexed_auto(n_macro, |m| {
        let lo = m * g;
        let hi = (lo + g).min(jobs.len());
        execute_macro(&ctx, &jobs[lo..hi], lanes)
    });

    // Deterministic merge in job order: one-shot block writes into the
    // (disjoint) output regions, exact OpStats accumulation.  Rows are
    // contiguous in both the tile block and the output tensor, so each
    // row is a single memcpy.
    let mut y = TensorT::zeros(vec![n, c_out, o_h, o_w]);
    let ydata = y.data_mut();
    for (m, (mblock, mstats)) in results.iter().enumerate() {
        stats.merge(mstats);
        let lo = m * g;
        let hi = (lo + g).min(jobs.len());
        let mut off = 0usize;
        for job in &jobs[lo..hi] {
            for co in 0..c_out {
                let base = off + co * job.tile_h * job.tile_w;
                for r in 0..job.tile_h {
                    let src =
                        &mblock[base + r * job.tile_w..][..job.tile_w];
                    let dst_off = ((job.bi * c_out + co) * o_h
                        + job.th
                        + r)
                        * o_w
                        + job.tw;
                    ydata[dst_off..dst_off + job.tile_w]
                        .copy_from_slice(src);
                }
            }
            off += c_out * job.tile_h * job.tile_w;
        }
    }
    (y, stats)
}

/// Reverse-loop transposed convolution (Algorithm 1), tiled over the
/// output space.  Numerically identical to [`super::deconv_standard`]
/// (bit-identical in fixed point); additionally returns the [`OpStats`]
/// of the execution.
///
/// * `x` — `[N, C_in, I_H, I_W]`, `w` — `[C_in, C_out, K, K]`,
///   `b` — `[C_out]` → `[N, C_out, O_H, O_W]`.
pub fn deconv_reverse_loop<T: Element>(
    x: &TensorT<T>,
    w: &TensorT<T>,
    b: &[T],
    stride: usize,
    padding: usize,
    opts: ReverseLoopOpts,
) -> (TensorT<T>, OpStats) {
    let sched = classic_schedule::<T>(x, w, stride, padding, opts.tile);
    run_reverse_loop(
        x,
        w,
        b,
        stride,
        padding,
        opts,
        sched,
        &WorkerPool::new(1),
    )
}

/// [`deconv_reverse_loop`] with the output tiles sharded across a
/// [`WorkerPool`] — the spatial CU parallelism of the paper, in
/// software.  Bit-identical to the serial path: same tensors, same
/// [`OpStats`], for any pool width.
pub fn deconv_reverse_loop_par<T: Element>(
    x: &TensorT<T>,
    w: &TensorT<T>,
    b: &[T],
    stride: usize,
    padding: usize,
    opts: ReverseLoopOpts,
    pool: &WorkerPool,
) -> (TensorT<T>, OpStats) {
    let sched = classic_schedule::<T>(x, w, stride, padding, opts.tile);
    run_reverse_loop(x, w, b, stride, padding, opts, sched, pool)
}

/// Reverse-loop deconvolution driven by an explicit two-level
/// [`BlockSchedule`] — the autotuner's entry point and the production
/// dispatch for tuned shapes.  `sched: None` consults the persisted
/// tune table ([`crate::tune`]) for this (kernel, element, shape) and
/// falls back to the static default when no entry matches.
///
/// Bit-identical to [`deconv_reverse_loop`] *called at
/// `tile == sched.micro`* — tensors and [`OpStats`] — for every legal
/// (macro, lanes) pair, which the property tests pin against the frozen
/// scalar references.
pub fn deconv_reverse_loop_blocked<T: Element>(
    x: &TensorT<T>,
    w: &TensorT<T>,
    b: &[T],
    stride: usize,
    padding: usize,
    zero_skip: bool,
    sched: Option<BlockSchedule>,
    pool: &WorkerPool,
) -> (TensorT<T>, OpStats) {
    let sched = sched.map(BlockSchedule::normalized).unwrap_or_else(|| {
        let [_, c_in, i_h, _] = shape4(x);
        let [_, c_out, k, _] = shape4(w);
        let o_h = super::output_size(i_h, k, stride, padding);
        crate::tune::schedule_for::<T>(
            crate::tune::TuneKernel::ReverseLoop,
            c_in,
            c_out,
            k,
            stride,
            o_h,
            None,
        )
    });
    let opts = ReverseLoopOpts {
        tile: sched.micro,
        zero_skip,
    };
    run_reverse_loop(x, w, b, stride, padding, opts, sched, pool)
}

/// Resolve the schedule for a classic (tile-factor) call site: the
/// micro-tile is pinned to the caller's `tile` (the OpStats geometry is
/// part of the kernel contract), while macro grouping and lane width
/// come from the tuned table when a matching entry exists, else the
/// static default.
fn classic_schedule<T: Element>(
    x: &TensorT<T>,
    w: &TensorT<T>,
    stride: usize,
    padding: usize,
    tile: usize,
) -> BlockSchedule {
    let [_, c_in, i_h, _] = shape4(x);
    let [_, c_out, k, _] = shape4(w);
    let o_h = super::output_size(i_h, k, stride, padding);
    crate::tune::schedule_for::<T>(
        crate::tune::TuneKernel::ReverseLoop,
        c_in,
        c_out,
        k,
        stride,
        o_h,
        Some(tile),
    )
}

/// First o ≥ start with o ≡ f (mod s).
#[inline]
fn next_aligned(start: usize, f: usize, s: usize) -> usize {
    let r = start % s;
    if r <= f {
        start + (f - r)
    } else {
        start + (s - r) + f
    }
}

/// Number of (oh, ow) visits a tap would have made in the tile (for
/// skip accounting).
#[inline]
fn tap_count(
    th: usize,
    tile_h: usize,
    tw: usize,
    tile_w: usize,
    fh: usize,
    fw: usize,
    s: usize,
) -> u64 {
    let nh = {
        let first = next_aligned(th, fh, s);
        if first >= th + tile_h {
            0
        } else {
            (th + tile_h - first).div_ceil(s)
        }
    };
    let nw = {
        let first = next_aligned(tw, fw, s);
        if first >= tw + tile_w {
            0
        } else {
            (tw + tile_w - first).div_ceil(s)
        }
    };
    (nh * nw) as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::deconv::deconv_standard;
    use crate::quant::{quantize_tensor, Q8_8, Rounding};
    use crate::tensor::Tensor;
    use crate::util::Rng;

    fn rand_tensor(shape: Vec<usize>, rng: &mut Rng) -> Tensor {
        Tensor::from_fn(shape, |_| rng.range_f32(-1.0, 1.0))
    }

    fn check_case(
        n: usize,
        c_in: usize,
        c_out: usize,
        k: usize,
        s: usize,
        p: usize,
        i_h: usize,
        tile: usize,
    ) {
        let mut rng = Rng::seed_from_u64(42);
        let x = rand_tensor(vec![n, c_in, i_h, i_h], &mut rng);
        let w = rand_tensor(vec![c_in, c_out, k, k], &mut rng);
        let b: Vec<f32> = (0..c_out).map(|i| i as f32 * 0.1).collect();
        let expect = deconv_standard(&x, &w, &b, s, p);
        let (got, stats) = deconv_reverse_loop(
            &x,
            &w,
            &b,
            s,
            p,
            ReverseLoopOpts {
                tile,
                zero_skip: false,
            },
        );
        assert!(
            got.max_abs_diff(&expect) < 1e-4,
            "mismatch for ({n},{c_in},{c_out},{k},{s},{p},{i_h},{tile})"
        );
        assert!(stats.macs_issued > 0);
        assert_eq!(stats.macs_skipped, 0);
    }

    #[test]
    fn matches_standard_across_geometries() {
        check_case(1, 2, 3, 4, 2, 1, 5, 4);
        check_case(2, 3, 2, 7, 1, 0, 1, 12);
        check_case(1, 2, 2, 3, 3, 1, 4, 6);
        check_case(1, 1, 1, 5, 2, 2, 6, 5); // tile not multiple of stride
        check_case(1, 4, 4, 4, 2, 1, 7, 12); // mnist L2 shape class
    }

    #[test]
    fn tile_size_does_not_change_numerics() {
        let mut rng = Rng::seed_from_u64(7);
        let x = rand_tensor(vec![1, 3, 6, 6], &mut rng);
        let w = rand_tensor(vec![3, 2, 4, 4], &mut rng);
        let b = vec![0.5, -0.5];
        let mut results = Vec::new();
        for tile in [2, 3, 4, 5, 8, 64] {
            let (y, _) = deconv_reverse_loop(
                &x,
                &w,
                &b,
                2,
                1,
                ReverseLoopOpts {
                    tile,
                    zero_skip: false,
                },
            );
            results.push(y);
        }
        for y in &results[1..] {
            assert!(y.max_abs_diff(&results[0]) < 1e-5);
        }
    }

    #[test]
    fn zero_skip_preserves_numerics_and_counts_skips() {
        let mut rng = Rng::seed_from_u64(9);
        let x = rand_tensor(vec![1, 2, 5, 5], &mut rng);
        let mut w = rand_tensor(vec![2, 3, 4, 4], &mut rng);
        // zero out ~half the weights
        for (i, v) in w.data_mut().iter_mut().enumerate() {
            if i % 2 == 0 {
                *v = 0.0;
            }
        }
        let b = vec![0.0; 3];
        let (dense, d_stats) = deconv_reverse_loop(
            &x,
            &w,
            &b,
            2,
            1,
            ReverseLoopOpts {
                tile: 6,
                zero_skip: false,
            },
        );
        let (skip, s_stats) = deconv_reverse_loop(
            &x,
            &w,
            &b,
            2,
            1,
            ReverseLoopOpts {
                tile: 6,
                zero_skip: true,
            },
        );
        assert!(skip.max_abs_diff(&dense) < 1e-6);
        assert!(s_stats.macs_skipped > 0);
        assert!(s_stats.macs_issued < d_stats.macs_issued);
        assert!(s_stats.weight_tests > 0);
        // issued + skipped covers at least the in-bounds dense taps
        assert!(
            s_stats.macs_issued + s_stats.macs_skipped
                >= d_stats.macs_issued
        );
    }

    #[test]
    fn modulo_count_is_2k() {
        let x = Tensor::zeros(vec![1, 1, 4, 4]);
        let w = Tensor::zeros(vec![1, 1, 4, 4]);
        let (_, stats) = deconv_reverse_loop(
            &x,
            &w,
            &[0.0],
            2,
            1,
            ReverseLoopOpts::default(),
        );
        assert_eq!(stats.modulo_ops, 8); // 2K with K=4
    }

    #[test]
    fn next_aligned_basics() {
        assert_eq!(next_aligned(0, 1, 2), 1);
        assert_eq!(next_aligned(5, 1, 2), 5);
        assert_eq!(next_aligned(6, 1, 2), 7);
        assert_eq!(next_aligned(7, 0, 2), 8);
        assert_eq!(next_aligned(4, 0, 1), 4);
    }

    #[test]
    fn one_shot_write_bytes_match_output() {
        let mut rng = Rng::seed_from_u64(3);
        let x = rand_tensor(vec![1, 2, 4, 4], &mut rng);
        let w = rand_tensor(vec![2, 3, 4, 4], &mut rng);
        let b = vec![0.0; 3];
        let (y, stats) = deconv_reverse_loop(
            &x,
            &w,
            &b,
            2,
            1,
            ReverseLoopOpts {
                tile: 4,
                zero_skip: false,
            },
        );
        // every output element written exactly once per channel pass
        assert_eq!(stats.ext_write_bytes, 4 * y.numel() as u64);
    }

    #[test]
    fn fixed_point_matches_standard_bit_for_bit() {
        let mut rng = Rng::seed_from_u64(31);
        for (n, c_in, c_out, k, s, p, i_h, tile) in [
            (1, 2, 3, 4, 2, 1, 5, 4),
            (2, 3, 2, 7, 1, 0, 3, 5),
            (1, 2, 2, 3, 3, 1, 4, 6),
        ] {
            let x = quantize_tensor::<i16, 8>(
                &rand_tensor(vec![n, c_in, i_h, i_h], &mut rng),
                Rounding::Nearest,
            );
            let w = quantize_tensor::<i16, 8>(
                &rand_tensor(vec![c_in, c_out, k, k], &mut rng),
                Rounding::Nearest,
            );
            let b: Vec<Q8_8> = (0..c_out)
                .map(|_| Q8_8::from_f32(rng.range_f32(-0.5, 0.5)))
                .collect();
            let want = deconv_standard(&x, &w, &b, s, p);
            for zero_skip in [false, true] {
                let (got, stats) = deconv_reverse_loop(
                    &x,
                    &w,
                    &b,
                    s,
                    p,
                    ReverseLoopOpts { tile, zero_skip },
                );
                assert_eq!(
                    got.data(),
                    want.data(),
                    "fixed point must be bit-exact (zs={zero_skip})"
                );
                // 2-byte elements drive the byte accounting
                assert_eq!(stats.ext_write_bytes, 2 * want.numel() as u64);
            }
        }
    }

    #[test]
    fn parallel_is_bit_identical_to_serial() {
        let mut rng = Rng::seed_from_u64(21);
        for (n, c_in, c_out, k, s, p, i_h, tile) in [
            (1, 2, 3, 4, 2, 1, 5, 4),
            (2, 3, 2, 7, 1, 0, 3, 5),
            (1, 2, 2, 3, 3, 1, 4, 6),
            (2, 4, 4, 4, 2, 1, 7, 12),
        ] {
            let x = rand_tensor(vec![n, c_in, i_h, i_h], &mut rng);
            let mut w = rand_tensor(vec![c_in, c_out, k, k], &mut rng);
            // some exact zeros so the zero-skip path is exercised too
            for (i, v) in w.data_mut().iter_mut().enumerate() {
                if i % 4 == 0 {
                    *v = 0.0;
                }
            }
            let b: Vec<f32> =
                (0..c_out).map(|_| rng.range_f32(-0.5, 0.5)).collect();
            for zero_skip in [false, true] {
                let opts = ReverseLoopOpts { tile, zero_skip };
                let (ys, ss) = deconv_reverse_loop(&x, &w, &b, s, p, opts);
                for workers in [2, 3, 8] {
                    let pool = WorkerPool::new(workers);
                    let (yp, sp) = deconv_reverse_loop_par(
                        &x, &w, &b, s, p, opts, &pool,
                    );
                    assert_eq!(
                        ys.data(),
                        yp.data(),
                        "w={workers} zs={zero_skip}: tensors must be \
                         bit-identical"
                    );
                    assert_eq!(ss, sp, "w={workers}: OpStats must be exact");
                }
            }
        }
    }

    /// Satellite (a): two successive tiles on the same thread reuse the
    /// same arena buffer (no per-tile allocation after the first) and
    /// the reuse is correctly re-zeroed — results match a fresh run.
    #[test]
    fn successive_tiles_reuse_and_rezero_the_arena_buffer() {
        use crate::util::{reset_scratch_stats, scratch_allocs, scratch_hits};
        let mut rng = Rng::seed_from_u64(17);
        let x = rand_tensor(vec![1, 2, 6, 6], &mut rng);
        let w = rand_tensor(vec![2, 3, 4, 4], &mut rng);
        let b = vec![0.25, -0.5, 0.75];
        let opts = ReverseLoopOpts {
            tile: 4,
            zero_skip: false,
        };
        // Warm the arena (WorkerPool::new(1) runs inline on this
        // thread), then measure a steady-state pass: many tiles, zero
        // fresh allocations, all hits.
        let (y0, _) = deconv_reverse_loop(&x, &w, &b, 2, 1, opts);
        reset_scratch_stats();
        let (y1, stats) = deconv_reverse_loop(&x, &w, &b, 2, 1, opts);
        assert!(stats.tiles > 1, "need multiple tiles to prove reuse");
        assert_eq!(
            scratch_allocs(),
            0,
            "steady state must not allocate accumulator blocks"
        );
        assert_eq!(
            scratch_hits(),
            stats.tiles,
            "every tile must be served from the reused buffer"
        );
        // Reuse is observationally invisible: bit-identical output.
        assert_eq!(y0.data(), y1.data(), "re-zeroing must be exact");
    }

    /// The SIMD-shaped kernel is bit-identical to the pinned pre-PR
    /// scalar reference — tensors AND OpStats.
    #[test]
    fn bit_identical_to_pinned_scalar_reference() {
        use crate::deconv::deconv_reverse_loop_ref;
        let mut rng = Rng::seed_from_u64(23);
        for (n, c_in, c_out, k, s, p, i_h, tile) in [
            (1, 2, 3, 4, 2, 1, 5, 4),
            (2, 3, 2, 7, 1, 0, 3, 5),
            (1, 2, 2, 3, 3, 1, 4, 6),
            (1, 1, 1, 5, 2, 2, 6, 5),
        ] {
            let x = rand_tensor(vec![n, c_in, i_h, i_h], &mut rng);
            let mut w = rand_tensor(vec![c_in, c_out, k, k], &mut rng);
            for (i, v) in w.data_mut().iter_mut().enumerate() {
                if i % 3 == 0 {
                    *v = 0.0;
                }
            }
            let b: Vec<f32> =
                (0..c_out).map(|_| rng.range_f32(-0.5, 0.5)).collect();
            for zero_skip in [false, true] {
                let opts = ReverseLoopOpts { tile, zero_skip };
                let (want, want_stats) =
                    deconv_reverse_loop_ref(&x, &w, &b, s, p, opts);
                let (got, got_stats) =
                    deconv_reverse_loop(&x, &w, &b, s, p, opts);
                assert_eq!(
                    got.data(),
                    want.data(),
                    "({n},{c_in},{c_out},{k},{s},{p},{i_h},{tile}) \
                     zs={zero_skip}: f32 must match the scalar \
                     reference bit for bit"
                );
                assert_eq!(
                    got_stats, want_stats,
                    "OpStats must match the scalar reference exactly"
                );
            }
        }
    }

    /// Two-level blocking is invisible: every (macro, lanes) pair —
    /// including widths that don't divide the tile — reproduces the
    /// frozen scalar reference bit for bit, tensors AND OpStats, on
    /// serial and parallel pools alike.
    #[test]
    fn blocked_is_bit_identical_for_any_macro_and_lane_width() {
        use crate::deconv::deconv_reverse_loop_ref;
        let mut rng = Rng::seed_from_u64(47);
        for (n, c_in, c_out, k, s, p, i_h, tile) in [
            (1, 2, 3, 4, 2, 1, 5, 4),
            (2, 3, 2, 7, 1, 0, 3, 5),
            (1, 2, 2, 3, 3, 1, 4, 6),
        ] {
            let x = rand_tensor(vec![n, c_in, i_h, i_h], &mut rng);
            let mut w = rand_tensor(vec![c_in, c_out, k, k], &mut rng);
            for (i, v) in w.data_mut().iter_mut().enumerate() {
                if i % 3 == 0 {
                    *v = 0.0;
                }
            }
            let b: Vec<f32> =
                (0..c_out).map(|_| rng.range_f32(-0.5, 0.5)).collect();
            for zero_skip in [false, true] {
                let opts = ReverseLoopOpts { tile, zero_skip };
                let (want, want_stats) =
                    deconv_reverse_loop_ref(&x, &w, &b, s, p, opts);
                for macro_tiles in [1usize, 2, 3, 8] {
                    for lanes in [1usize, 2, 4, 8] {
                        let sched = BlockSchedule {
                            micro: tile,
                            macro_tiles,
                            lanes,
                        };
                        for workers in [1usize, 4] {
                            let pool = WorkerPool::new(workers);
                            let (got, got_stats) =
                                deconv_reverse_loop_blocked(
                                    &x,
                                    &w,
                                    &b,
                                    s,
                                    p,
                                    zero_skip,
                                    Some(sched),
                                    &pool,
                                );
                            assert_eq!(
                                got.data(),
                                want.data(),
                                "macro={macro_tiles} lanes={lanes} \
                                 w={workers} zs={zero_skip}"
                            );
                            assert_eq!(
                                got_stats, want_stats,
                                "OpStats must survive blocking exactly"
                            );
                        }
                    }
                }
            }
        }
    }

    /// The default (no explicit schedule, no tune table) blocked entry
    /// matches the classic entry exactly at the default tile factor.
    #[test]
    fn blocked_default_schedule_matches_classic_entry() {
        let mut rng = Rng::seed_from_u64(53);
        let x = rand_tensor(vec![1, 2, 6, 6], &mut rng);
        let w = rand_tensor(vec![2, 3, 4, 4], &mut rng);
        let b = vec![0.25, -0.5, 0.75];
        let opts = ReverseLoopOpts::default();
        let (want, want_stats) =
            deconv_reverse_loop(&x, &w, &b, 2, 1, opts);
        let (got, got_stats) = deconv_reverse_loop_blocked(
            &x,
            &w,
            &b,
            2,
            1,
            opts.zero_skip,
            None,
            &WorkerPool::new(1),
        );
        assert_eq!(got.data(), want.data());
        assert_eq!(got_stats, want_stats);
    }

    #[test]
    fn tile_job_enumeration_covers_output_once() {
        let jobs = tile_jobs(2, 7, 7, 3);
        // 2 images × ⌈7/3⌉² tiles
        assert_eq!(jobs.len(), 2 * 9);
        let mut covered = vec![0u32; 2 * 7 * 7];
        for j in &jobs {
            for r in 0..j.tile_h {
                for c in 0..j.tile_w {
                    covered[(j.bi * 7 + j.th + r) * 7 + j.tw + c] += 1;
                }
            }
        }
        assert!(covered.iter().all(|c| *c == 1), "exact cover");
    }
}
