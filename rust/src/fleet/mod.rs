//! Distributed edge fleet: a front tier routing one recorded trace over
//! N per-site coordinators (see DESIGN.md §Fleet).
//!
//! * [`placement`] — pluggable placement: requests hash to a home site
//!   on a seeded consistent-hash ring (vnodes), so killing one site
//!   re-places only that site's hash range; round-robin is the
//!   unstable control.
//! * [`site`] — one [`Site`] per coordinator: its own backend pool,
//!   capacity and seeded clock skew; fail-stop mid-run with
//!   drain-then-dark semantics.
//! * [`run_fleet`] — the multi-machine trace replayer: fans one trace
//!   across the sites (per-site arrival offsets from the skew model),
//!   spills admission-control denials to the next site in preference
//!   order (the spilled request keeps its *original* arrival stamp and
//!   deadline — attainment stays honest), injects an optional site
//!   failure, and folds the per-site telemetry shards
//!   ([`MetricsRegistry::merge_from`]) into one fleet-level
//!   [`ServingReport`] whose lanes are prefixed `s0/`, `s1/`, … so
//!   per-site columns stay distinguishable.
//!
//! Accounting closes by construction: the front tier counts every
//! request's single terminal outcome off its typed reply channel, so
//! `submitted = served + shed + rejected + lost` regardless of how many
//! times a request spilled.

mod placement;
mod site;

pub use placement::{
    placement_by_name, ConsistentHashRing, Placement, RoundRobin,
};
pub use site::Site;

use crate::config::{BackendCfg, QFormat};
use crate::coordinator::{
    BatcherConfig, CoordinatorClient, CoordinatorConfig, MetricsRegistry,
    RequestCtx, RequestOutcome, ResponseHandle, ServingReport,
};
use crate::telemetry::{RunClock, StageStamps};
use crate::util::{escape_json, Rng};
use crate::workload::loadtest::event_ctx;
use crate::workload::{Trace, TraceEvent};
use anyhow::Result;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Mutex};
use std::time::{Duration, Instant};

/// Version of the fleet JSON envelope (`fleet --json`); the embedded
/// `report` object carries the [`ServingReport`] schema version.
const FLEET_SCHEMA_VERSION: u64 = 1;

/// Fleet construction options (the trace supplies the traffic).
#[derive(Debug, Clone)]
pub struct FleetCfg {
    pub artifacts_dir: PathBuf,
    /// Number of sites (per-site coordinators).
    pub sites: usize,
    /// Every site runs the same pool shape; per-site noise seeds are
    /// drawn from `seed`.
    pub backends: BackendCfg,
    /// Lane-count override per site, as in
    /// [`CoordinatorConfig::executors`].
    pub executors: usize,
    pub shard_batches: bool,
    /// Placement kind: `hash` (consistent-hash ring) or `round-robin`.
    pub placement: String,
    /// Virtual nodes per site on the hash ring.
    pub vnodes: usize,
    /// Cross-site overflow: when a site's shed-early admission control
    /// denies a request, re-submit it (original arrival + deadline) at
    /// the next site in preference order.
    pub spill: bool,
    /// Max |clock skew| per site, seconds: each site gets a seeded
    /// offset in `[-skew_s, +skew_s]` applied to arrivals scheduled
    /// there (the multi-machine replay model).
    pub skew_s: f64,
    /// Fleet-level seed: ring geometry, per-site skews and noise seeds.
    pub seed: u64,
    /// Site-failure scenario: this site fail-stops at `fail_at_s`.
    pub fail_site: Option<usize>,
    /// Trace-time of the failure injection, seconds.
    pub fail_at_s: f64,
}

impl Default for FleetCfg {
    fn default() -> Self {
        FleetCfg {
            artifacts_dir: "artifacts".into(),
            sites: 3,
            backends: BackendCfg::default(),
            executors: 0,
            shard_batches: true,
            placement: "hash".to_string(),
            vnodes: 64,
            spill: true,
            skew_s: 0.0,
            seed: 0,
            fail_site: None,
            fail_at_s: 0.0,
        }
    }
}

/// One site's front-tier summary line.
#[derive(Debug, Clone, PartialEq)]
pub struct SiteSummary {
    pub name: String,
    pub skew_s: f64,
    /// Requests initially placed at this site.
    pub placed: u64,
    /// Cross-site resubmissions that landed here.
    pub spilled_in: u64,
    /// Fail-stopped mid-run.
    pub dark: bool,
}

/// Result of one fleet run: the merged fleet-level report plus the raw
/// per-site telemetry shards it was folded from (lane-prefixed `s{i}/`,
/// walls aligned to the fleet window) — exposed so callers can re-fold
/// them in any association order and verify the merge invariants.
#[derive(Debug, Clone)]
pub struct FleetRun {
    pub report: ServingReport,
    pub shards: Vec<MetricsRegistry>,
    pub sites: Vec<SiteSummary>,
    pub placement: String,
    pub spill: bool,
    pub submitted: u64,
    pub served: u64,
    pub shed: u64,
    pub rejected: u64,
    pub lost: u64,
    /// Requests that overflowed their home site at least once.
    pub spilled: u64,
    /// Spilled requests another site eventually served.
    pub spill_served: u64,
    pub wall_s: f64,
    /// Bounded sample of completed cross-site lifecycles (a served
    /// request whose stamps retired an origin-site hop): the trace
    /// export renders these as flow events even when head sampling
    /// skipped them, and the integration suite checks the two-site
    /// timeline stays monotone after skew correction.  Not part of the
    /// JSON envelope.
    pub spill_stamps: Vec<StageStamps>,
}

/// Fold per-site telemetry shards into one fleet registry.  Every
/// constituent merge is associative and commutative, so any association
/// order yields the same fleet report (the integration suite pins this
/// bit-exactly via the JSON serialization).
pub fn fold_shards(shards: &[MetricsRegistry]) -> MetricsRegistry {
    let mut acc = MetricsRegistry::new();
    for s in shards {
        acc.merge_from(s);
    }
    acc
}

/// Front-tier terminal-outcome tally (one atomic bump per request).
struct Tally {
    served: AtomicU64,
    shed: AtomicU64,
    rejected: AtomicU64,
    lost: AtomicU64,
    spilled: AtomicU64,
    spill_served: AtomicU64,
    placed: Vec<AtomicU64>,
    spilled_in: Vec<AtomicU64>,
    /// First `SPILL_STAMP_CAP` completed cross-site lifecycles.
    spill_stamps: Mutex<Vec<StageStamps>>,
}

/// Cap on collected spill-lifecycle examples (diagnostics, not stats —
/// the stage histograms carry the population).
const SPILL_STAMP_CAP: usize = 64;

impl Tally {
    fn new(n_sites: usize) -> Tally {
        Tally {
            served: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            lost: AtomicU64::new(0),
            spilled: AtomicU64::new(0),
            spill_served: AtomicU64::new(0),
            placed: (0..n_sites).map(|_| AtomicU64::new(0)).collect(),
            spilled_in: (0..n_sites).map(|_| AtomicU64::new(0)).collect(),
            spill_stamps: Mutex::new(Vec::new()),
        }
    }
}

/// One in-flight request the waiter pool shepherds to its terminal
/// outcome (following it across spill hops).
struct Job {
    network: String,
    n_images: usize,
    ctx: RequestCtx,
    key: u64,
    tried: Vec<usize>,
    handle: ResponseHandle,
}

/// Submit at the first preferred site not yet tried; a dark site
/// discovered here (closed submission channel) is marked dead so later
/// placements skip it.  `None` = every preference exhausted.
fn submit_next(
    clients: &[CoordinatorClient],
    alive: &[AtomicBool],
    placement: &dyn Placement,
    key: u64,
    network: &str,
    n_images: usize,
    ctx: RequestCtx,
    tried: &mut Vec<usize>,
) -> Option<(usize, ResponseHandle)> {
    loop {
        let mask: Vec<bool> =
            alive.iter().map(|a| a.load(Ordering::Relaxed)).collect();
        let next = placement
            .place(key, &mask)
            .into_iter()
            .find(|s| !tried.contains(s))?;
        tried.push(next);
        match clients[next].request(network).images(n_images).ctx(ctx).submit()
        {
            Ok(h) => return Some((next, h)),
            Err(_) => alive[next].store(false, Ordering::Relaxed),
        }
    }
}

/// Follow one job to its terminal outcome, spilling denials to the next
/// preferred site when enabled.  The resubmission reuses the job's
/// original [`RequestCtx`] — arrival stamp and absolute deadline travel
/// with the request, so deadline attainment charges the full cross-site
/// journey.
fn resolve(
    job: Job,
    clients: &[CoordinatorClient],
    alive: &[AtomicBool],
    placement: &dyn Placement,
    spill: bool,
    tally: &Tally,
) {
    let Job {
        network,
        n_images,
        mut ctx,
        key,
        mut tried,
        mut handle,
    } = job;
    let mut spills = 0u64;
    loop {
        let outcome = handle.outcome();
        if let RequestOutcome::Served(resp) = &outcome {
            tally.served.fetch_add(1, Ordering::Relaxed);
            if spills > 0 {
                tally.spill_served.fetch_add(1, Ordering::Relaxed);
                if resp.stamps.spilled() && resp.stamps.complete() {
                    let mut examples = tally.spill_stamps.lock().unwrap();
                    if examples.len() < SPILL_STAMP_CAP {
                        examples.push(resp.stamps);
                    }
                }
            }
            return;
        }
        // a denial hands the lifecycle context back with the denying
        // site's intake stamps; carrying them into the resubmission
        // lets the next site's re-ingest retire the hop (origin site +
        // ingest time) onto the cross-site record
        if let RequestOutcome::Shed { ctx: denied }
        | RequestOutcome::Rejected { ctx: denied } = &outcome
        {
            ctx.stamps = denied.stamps;
        }
        if spill {
            if let Some((site, h)) = submit_next(
                clients, alive, placement, key, &network, n_images, ctx,
                &mut tried,
            ) {
                spills += 1;
                if spills == 1 {
                    tally.spilled.fetch_add(1, Ordering::Relaxed);
                }
                tally.spilled_in[site].fetch_add(1, Ordering::Relaxed);
                handle = h;
                continue;
            }
        }
        let cell = match outcome {
            RequestOutcome::Shed { .. } => &tally.shed,
            RequestOutcome::Rejected { .. } => &tally.rejected,
            _ => &tally.lost,
        };
        cell.fetch_add(1, Ordering::Relaxed);
        return;
    }
}

fn waiter_loop(
    rx: &Mutex<mpsc::Receiver<Job>>,
    clients: &[CoordinatorClient],
    alive: &[AtomicBool],
    placement: &dyn Placement,
    spill: bool,
    tally: &Tally,
) {
    loop {
        // hold the lock only for the handoff, not while resolving
        let job = { rx.lock().unwrap().recv() };
        let Ok(job) = job else {
            return; // submitter hung up and the queue is drained
        };
        resolve(job, clients, alive, placement, spill, tally);
    }
}

/// Replay one trace across a fleet of `cfg.sites` coordinators and
/// merge the per-site telemetry into a fleet-level report.
pub fn run_fleet(trace: &Trace, cfg: &FleetCfg) -> Result<FleetRun> {
    anyhow::ensure!(cfg.sites >= 1, "a fleet needs at least one site");
    anyhow::ensure!(!trace.events.is_empty(), "trace has no events");
    if let Some(fs) = cfg.fail_site {
        anyhow::ensure!(
            fs < cfg.sites,
            "--fail-site {fs} out of range (fleet has {} sites)",
            cfg.sites
        );
    }

    let (networks, twins) = trace.networks();
    let mut rng = Rng::seed_from_u64(cfg.seed);
    // all site clocks share one run epoch and differ only by their
    // seeded skew, so folded spans re-base onto a single fleet timeline
    let epoch = Instant::now();
    let mut sites = Vec::with_capacity(cfg.sites);
    for i in 0..cfg.sites {
        let skew_s = rng.range_f64(-cfg.skew_s, cfg.skew_s);
        let mut backends = cfg.backends.clone();
        backends.noise_seed = rng.next_u64();
        sites.push(Site::start(
            format!("s{i}"),
            skew_s,
            CoordinatorConfig {
                artifacts_dir: cfg.artifacts_dir.clone(),
                networks: networks.clone(),
                batcher: BatcherConfig::default(),
                backends,
                executors: cfg.executors,
                quant: twins.q.then_some(QFormat::new(16, 8)),
                quant8: twins.q8.then_some(QFormat::new(8, 6)),
                shard_batches: cfg.shard_batches,
                clock: Some(RunClock::with_site(epoch, skew_s, i as u32)),
            },
        )?);
    }
    let placement =
        placement_by_name(&cfg.placement, cfg.sites, cfg.vnodes, cfg.seed)?;
    let placement: &dyn Placement = placement.as_ref();
    let clients: Vec<CoordinatorClient> =
        sites.iter().map(|s| s.client().expect("site started")).collect();

    // Multi-machine replay plan: each event hashes to its home site
    // (placement key derived from the event seed, stable across runs
    // and replays), then gets that site's arrival offset applied.
    struct Planned<'t> {
        t_s: f64,
        key: u64,
        event: &'t TraceEvent,
    }
    let all_alive = vec![true; cfg.sites];
    let mut planned: Vec<Planned> = trace
        .events
        .iter()
        .map(|e| {
            let key = Rng::seed_from_u64(e.seed).next_u64();
            let home = placement.place(key, &all_alive)[0];
            Planned {
                t_s: (e.t_s + sites[home].skew_s).max(0.0),
                key,
                event: e,
            }
        })
        .collect();
    planned.sort_by(|a, b| a.t_s.total_cmp(&b.t_s));

    let alive: Vec<AtomicBool> =
        (0..cfg.sites).map(|_| AtomicBool::new(true)).collect();
    let tally = Tally::new(cfg.sites);
    let mut shards: Vec<Option<MetricsRegistry>> = vec![None; cfg.sites];
    let mut dark = vec![false; cfg.sites];
    let mut submitted = 0u64;
    let waiters = (cfg.sites * 2).clamp(2, 8);
    let (tx, rx) = mpsc::channel::<Job>();
    let rx = Mutex::new(rx);
    let t0 = Instant::now();

    std::thread::scope(|s| {
        for _ in 0..waiters {
            s.spawn(|| {
                waiter_loop(
                    &rx, &clients, &alive, placement, cfg.spill, &tally,
                )
            });
        }
        let mut pending_fail = cfg.fail_site;
        for p in &planned {
            if let Some(fs) = pending_fail {
                if p.t_s >= cfg.fail_at_s {
                    // fail-stop: mark dark first (placements re-route
                    // from here on), then drain and keep the shard
                    alive[fs].store(false, Ordering::Relaxed);
                    shards[fs] = sites[fs].shutdown();
                    dark[fs] = true;
                    pending_fail = None;
                }
            }
            let target = t0 + Duration::from_secs_f64(p.t_s);
            let now = Instant::now();
            if target > now {
                std::thread::sleep(target - now);
            }
            submitted += 1;
            let ctx = event_ctx(p.event, target);
            let mut tried = Vec::new();
            match submit_next(
                &clients,
                &alive,
                placement,
                p.key,
                &p.event.network,
                p.event.n_images,
                ctx,
                &mut tried,
            ) {
                Some((home, handle)) => {
                    tally.placed[home].fetch_add(1, Ordering::Relaxed);
                    tx.send(Job {
                        network: p.event.network.clone(),
                        n_images: p.event.n_images,
                        ctx,
                        key: p.key,
                        tried,
                        handle,
                    })
                    .expect("waiter pool alive");
                }
                // the whole fleet is dark
                None => {
                    tally.lost.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        drop(tx); // waiters drain the queue, then exit
    });

    let wall_s = t0.elapsed().as_secs_f64();
    for (i, site) in sites.iter_mut().enumerate() {
        if shards[i].is_none() {
            shards[i] = site.shutdown();
        }
    }
    let mut shards: Vec<MetricsRegistry> = shards
        .into_iter()
        .map(|s| s.expect("every site yields one shard"))
        .collect();
    for (i, shard) in shards.iter_mut().enumerate() {
        // sites serve concurrently: every shard reports against the
        // fleet measurement window (merge takes the max anyway)
        shard.set_wall(wall_s);
        shard.prefix_lanes(&format!("s{i}/"));
    }
    let report = fold_shards(&shards).report();

    let site_rows = sites
        .iter()
        .enumerate()
        .map(|(i, s)| SiteSummary {
            name: s.name.clone(),
            skew_s: s.skew_s,
            placed: tally.placed[i].load(Ordering::Relaxed),
            spilled_in: tally.spilled_in[i].load(Ordering::Relaxed),
            dark: dark[i],
        })
        .collect();

    Ok(FleetRun {
        report,
        shards,
        sites: site_rows,
        placement: placement.name().to_string(),
        spill: cfg.spill,
        submitted,
        served: tally.served.load(Ordering::Relaxed),
        shed: tally.shed.load(Ordering::Relaxed),
        rejected: tally.rejected.load(Ordering::Relaxed),
        lost: tally.lost.load(Ordering::Relaxed),
        spilled: tally.spilled.load(Ordering::Relaxed),
        spill_served: tally.spill_served.load(Ordering::Relaxed),
        wall_s,
        spill_stamps: tally.spill_stamps.into_inner().unwrap(),
    })
}

impl FleetRun {
    /// Perfetto-loadable Chrome trace of the run: the folded shards'
    /// sampled span rings (one track per `s{i}/lane`), plus flow events
    /// for collected cross-site lifecycles head sampling skipped (the
    /// sampled ones already render their own spill flows).
    pub fn chrome_trace(&self) -> String {
        let folded = fold_shards(&self.shards);
        let unsampled: Vec<StageStamps> = self
            .spill_stamps
            .iter()
            .copied()
            .filter(|s| !s.sampled)
            .collect();
        crate::telemetry::chrome_trace(folded.span_lanes(), &unsampled)
    }

    /// Render the fleet summary followed by the merged serving report.
    /// The `accounting:` line is the same shape the loadtest prints
    /// (the CI smoke jobs parse both with one awk program).
    pub fn render(&self) -> String {
        let mut out = format!(
            "== fleet: {} sites, placement {}, spill {}, wall {:.3} s ==\n",
            self.sites.len(),
            self.placement,
            if self.spill { "on" } else { "off" },
            self.wall_s,
        );
        for s in &self.sites {
            out.push_str(&format!(
                "site {}  skew {:+.1} ms  placed {}  spilled-in {}{}\n",
                s.name,
                s.skew_s * 1e3,
                s.placed,
                s.spilled_in,
                if s.dark { "  [dark]" } else { "" },
            ));
        }
        out.push_str(&format!(
            "spill: {} spilled, {} served after spilling\n",
            self.spilled, self.spill_served,
        ));
        out.push_str(&format!(
            "accounting: submitted {} served {} shed {} rejected {} lost {}\n",
            self.submitted, self.served, self.shed, self.rejected, self.lost,
        ));
        out.push_str(&self.report.render());
        out
    }

    /// Serialize the fleet envelope (schema v1); the embedded `report`
    /// is the versioned [`ServingReport`] schema, parseable on its own
    /// with [`ServingReport::from_json`].
    pub fn to_json(&self) -> String {
        let sites = self
            .sites
            .iter()
            .map(|s| {
                format!(
                    "    {{\"name\": \"{}\", \"skew_s\": {}, \
                     \"placed\": {}, \"spilled_in\": {}, \"dark\": {}}}",
                    escape_json(&s.name),
                    s.skew_s,
                    s.placed,
                    s.spilled_in,
                    s.dark,
                )
            })
            .collect::<Vec<_>>()
            .join(",\n");
        format!(
            "{{\n  \"version\": {FLEET_SCHEMA_VERSION},\n  \
             \"placement\": \"{}\",\n  \"spill\": {},\n  \
             \"submitted\": {},\n  \"served\": {},\n  \"shed\": {},\n  \
             \"rejected\": {},\n  \"lost\": {},\n  \"spilled\": {},\n  \
             \"spill_served\": {},\n  \"wall_s\": {},\n  \
             \"sites\": [\n{}\n  ],\n  \"report\": {}\n}}\n",
            escape_json(&self.placement),
            self.spill,
            self.submitted,
            self.served,
            self.shed,
            self.rejected,
            self.lost,
            self.spilled,
            self.spill_served,
            self.wall_s,
            sites,
            self.report.to_json().trim_end(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::parse_json;

    fn sample_run() -> FleetRun {
        let mut shard = MetricsRegistry::new();
        shard.set_wall(1.5);
        shard.prefix_lanes("s0/");
        FleetRun {
            report: shard.report(),
            shards: vec![shard],
            sites: vec![SiteSummary {
                name: "s0".to_string(),
                skew_s: -0.0021,
                placed: 12,
                spilled_in: 3,
                dark: true,
            }],
            placement: "hash".to_string(),
            spill: true,
            submitted: 12,
            served: 9,
            shed: 2,
            rejected: 1,
            lost: 0,
            spilled: 3,
            spill_served: 2,
            wall_s: 1.5,
            spill_stamps: Vec::new(),
        }
    }

    #[test]
    fn render_accounting_line_matches_the_ci_contract() {
        let text = sample_run().render();
        let line = text
            .lines()
            .find(|l| l.starts_with("accounting:"))
            .expect("accounting line present");
        let f: Vec<&str> = line.split_whitespace().collect();
        assert_eq!(
            f,
            vec![
                "accounting:",
                "submitted",
                "12",
                "served",
                "9",
                "shed",
                "2",
                "rejected",
                "1",
                "lost",
                "0"
            ]
        );
        assert!(text.contains("site s0"));
        assert!(text.contains("[dark]"));
    }

    #[test]
    fn fleet_json_envelope_parses_and_embeds_a_v1_report() {
        let run = sample_run();
        let v = parse_json(&run.to_json()).unwrap();
        assert_eq!(v.req("version").unwrap().as_u64().unwrap(), 1);
        assert_eq!(v.req("submitted").unwrap().as_u64().unwrap(), 12);
        let sites = v.req("sites").unwrap().as_arr().unwrap();
        assert_eq!(
            sites[0].req("name").unwrap().as_str().unwrap(),
            "s0"
        );
        // the embedded report is independently parseable + versioned
        let report = v.req("report").unwrap();
        assert_eq!(report.req("version").unwrap().as_u64().unwrap(), 1);
        let round = ServingReport::from_json(
            &run.report.to_json(),
        )
        .unwrap();
        assert_eq!(round, run.report);
    }
}
