//! One edge site of the fleet: a named [`Coordinator`] instance owning
//! its backend pool and capacity, plus the site-local clock skew the
//! trace replayer applies to arrivals scheduled there.  A site can
//! fail-stop mid-run ([`Site::shutdown`]): the coordinator drains
//! in-flight work, goes dark, and hands back its final telemetry shard
//! so the fleet report still accounts for everything it served.

use crate::coordinator::{
    Coordinator, CoordinatorClient, CoordinatorConfig, MetricsRegistry,
};
use anyhow::{Context, Result};

pub struct Site {
    /// Display name (`s0`, `s1`, …) — also the lane prefix its shard
    /// carries in the merged fleet report (`s0/fpga0`).
    pub name: String,
    /// Clock skew the multi-machine replayer applies to arrivals
    /// scheduled at this site, seconds (seeded, may be negative).
    pub skew_s: f64,
    coord: Option<Coordinator>,
}

impl Site {
    pub fn start(
        name: String,
        skew_s: f64,
        cfg: CoordinatorConfig,
    ) -> Result<Site> {
        let coord = Coordinator::start(cfg)
            .with_context(|| format!("starting site {name}"))?;
        Ok(Site {
            name,
            skew_s,
            coord: Some(coord),
        })
    }

    pub fn is_alive(&self) -> bool {
        self.coord.is_some()
    }

    /// Submission handle; `None` once the site is dark.
    pub fn client(&self) -> Option<CoordinatorClient> {
        self.coord.as_ref().map(|c| c.client())
    }

    /// Fail-stop (or end-of-run collect): drain in-flight work, go
    /// dark, return the final telemetry shard.  Idempotent — a second
    /// call returns `None`.
    pub fn shutdown(&mut self) -> Option<MetricsRegistry> {
        self.coord.take().map(Coordinator::shutdown)
    }
}
