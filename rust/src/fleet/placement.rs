//! Pluggable request placement for the fleet front tier: a placement
//! maps a request's stable hash key to a *preference-ordered* list of
//! alive sites.  The head of the list is where the request runs; the
//! tail is the spill order when the head's admission control turns it
//! away (see `DESIGN.md` §Fleet).
//!
//! The default is a seeded consistent-hash ring with virtual nodes:
//! when a site goes dark, only the keys that hashed *to that site*
//! re-place — every other request keeps its home, which is exactly the
//! property the site-failure scenario relies on (and
//! `failure_moves_only_the_dead_sites_keys` pins).

use crate::util::Rng;
use anyhow::{bail, Result};

/// Where a request may run, in preference order.
pub trait Placement: Send + Sync {
    fn name(&self) -> &'static str;

    /// Preference-ordered distinct alive sites for `key`; empty when
    /// the whole fleet is dark.  `alive[i]` gates site `i`.
    fn place(&self, key: u64, alive: &[bool]) -> Vec<usize>;
}

/// Seeded consistent-hash ring: each site owns `vnodes` pseudo-random
/// points on the u64 ring; a key belongs to the first point at or after
/// it (clockwise), and the preference order is the clockwise sweep of
/// distinct sites from there.
pub struct ConsistentHashRing {
    /// `(ring point, site)` sorted by point.
    ring: Vec<(u64, usize)>,
    n_sites: usize,
}

impl ConsistentHashRing {
    pub fn new(n_sites: usize, vnodes: usize, seed: u64) -> ConsistentHashRing {
        assert!(n_sites >= 1, "a fleet has at least one site");
        let vnodes = vnodes.max(1);
        let mut ring = Vec::with_capacity(n_sites * vnodes);
        for site in 0..n_sites {
            for v in 0..vnodes {
                // independent, reproducible point per (seed, site, vnode)
                let point = Rng::seed_from_u64(
                    seed ^ (site as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
                        ^ (v as u64 + 1).wrapping_mul(0xD1B5_4A32_D192_ED03),
                )
                .next_u64();
                ring.push((point, site));
            }
        }
        ring.sort_unstable();
        ConsistentHashRing { ring, n_sites }
    }
}

impl Placement for ConsistentHashRing {
    fn name(&self) -> &'static str {
        "hash"
    }

    fn place(&self, key: u64, alive: &[bool]) -> Vec<usize> {
        let start =
            self.ring.partition_point(|&(p, _)| p < key) % self.ring.len();
        let mut order = Vec::new();
        for i in 0..self.ring.len() {
            let (_, site) = self.ring[(start + i) % self.ring.len()];
            if alive.get(site).copied().unwrap_or(false)
                && !order.contains(&site)
            {
                order.push(site);
                if order.len() == self.n_sites {
                    break;
                }
            }
        }
        order
    }
}

/// Key-offset round robin — the control placement: cheap and uniform,
/// but *every* key re-places when a site dies (no stability).
pub struct RoundRobin {
    pub n_sites: usize,
}

impl Placement for RoundRobin {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn place(&self, key: u64, alive: &[bool]) -> Vec<usize> {
        (0..self.n_sites)
            .map(|i| ((key as usize).wrapping_add(i)) % self.n_sites)
            .filter(|&s| alive.get(s).copied().unwrap_or(false))
            .collect()
    }
}

/// Construct a placement by CLI name (`--placement hash|round-robin`).
pub fn placement_by_name(
    name: &str,
    n_sites: usize,
    vnodes: usize,
    seed: u64,
) -> Result<Box<dyn Placement>> {
    match name {
        "hash" => Ok(Box::new(ConsistentHashRing::new(n_sites, vnodes, seed))),
        "round-robin" | "rr" => Ok(Box::new(RoundRobin { n_sites })),
        other => bail!("unknown placement {other:?} (hash|round-robin)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn keys(n: usize) -> Vec<u64> {
        let mut rng = Rng::seed_from_u64(0xFEED);
        (0..n).map(|_| rng.next_u64()).collect()
    }

    #[test]
    fn preference_lists_are_distinct_alive_sites() {
        let ring = ConsistentHashRing::new(4, 16, 7);
        let alive = [true, false, true, true];
        for key in keys(200) {
            let order = ring.place(key, &alive);
            assert_eq!(order.len(), 3, "one dark site drops out");
            let mut sorted = order.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), order.len(), "sites listed once");
            assert!(!order.contains(&1), "dark site never placed");
        }
    }

    #[test]
    fn hash_ring_spreads_keys_over_every_site() {
        let ring = ConsistentHashRing::new(3, 64, 42);
        let alive = [true; 3];
        let mut per_site = [0usize; 3];
        for key in keys(600) {
            per_site[ring.place(key, &alive)[0]] += 1;
        }
        for (site, &n) in per_site.iter().enumerate() {
            assert!(
                n > 600 / 10,
                "site {site} starved: {per_site:?} (ring too lumpy)"
            );
        }
    }

    #[test]
    fn failure_moves_only_the_dead_sites_keys() {
        // the consistent-hash property: killing site 1 re-places site
        // 1's keys and *no others*
        let ring = ConsistentHashRing::new(3, 64, 42);
        let all = [true; 3];
        let degraded = [true, false, true];
        let mut moved = 0usize;
        for key in keys(400) {
            let before = ring.place(key, &all)[0];
            let after = ring.place(key, &degraded)[0];
            if before == 1 {
                moved += 1;
                assert_ne!(after, 1);
            } else {
                assert_eq!(before, after, "live site's key moved");
            }
        }
        assert!(moved > 0, "test needs some keys on the dead site");
    }

    #[test]
    fn round_robin_re_places_everything_by_construction() {
        let rr = RoundRobin { n_sites: 3 };
        assert_eq!(rr.place(5, &[true; 3]), vec![2, 0, 1]);
        assert_eq!(rr.place(5, &[true, true, false]), vec![0, 1]);
        assert!(placement_by_name("warp", 3, 8, 0).is_err());
    }
}
