//! PJRT backend — loads the AOT HLO-text artifacts and executes them on
//! the CPU PJRT client (`xla` crate).  Compiled only with the `pjrt`
//! feature (which additionally requires the `xla` dependency; the
//! offline image does not ship it).
//!
//! Interchange is HLO *text* (see `python/compile/aot.py` and
//! /opt/xla-example/README.md): jax ≥ 0.5 emits protos with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids and round-trips cleanly.

use super::executable::{GeneratorExecutable, LoadedHlo};
use crate::artifacts::ArtifactDir;
use crate::tensor::Tensor;
use anyhow::{Context, Result};
use std::path::Path;

/// Thin wrapper over the PJRT CPU client.
///
/// NOT `Sync`: PJRT handles are raw pointers.  The coordinator owns one
/// `Runtime` per executor thread and communicates through channels (see
/// [`crate::coordinator`]).
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("creating PJRT CPU client: {e:?}"))?;
        Ok(Runtime { client })
    }

    /// PJRT manages its own intra-op thread pool; the worker budget
    /// only steers the fallback backend, so it is ignored here (the
    /// method exists to keep the two backends API-compatible).
    pub fn cpu_with_workers(_workers: usize) -> Result<Self> {
        Self::cpu()
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO text file and compile it into an executable.
    pub fn load_hlo(&self, path: &Path) -> Result<LoadedHlo> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| anyhow::anyhow!("non-utf8 path {path:?}"))?,
        )
        .map_err(|e| anyhow::anyhow!("parsing HLO text {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {path:?}: {e:?}"))?;
        Ok(LoadedHlo::new(exe))
    }

    /// Load a generator executable for a network at (bucketed) batch size
    /// `want`, wiring in its manifest metadata.
    pub fn load_generator(
        &self,
        artifacts: &ArtifactDir,
        network: &str,
        want_batch: usize,
    ) -> Result<GeneratorExecutable> {
        let (batch, path) = artifacts.generator_hlo(network, want_batch)?;
        let net = artifacts.network(network)?;
        let hlo = self
            .load_hlo(&path)
            .with_context(|| format!("loading generator {path:?}"))?;
        Ok(GeneratorExecutable {
            hlo,
            batch,
            z_dim: net.z_dim,
            image_channels: net.image_channels,
            image_size: net.image_size,
            network: network.to_string(),
        })
    }
}

/// Convert a [`Tensor`] to an `xla::Literal` (f32, row-major).
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape().iter().map(|d| *d as i64).collect();
    xla::Literal::vec1(t.data())
        .reshape(&dims)
        .map_err(|e| anyhow::anyhow!("reshaping literal: {e:?}"))
}

/// Convert raw f32 data + shape to a literal.
pub fn data_to_literal(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|d| *d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims)
        .map_err(|e| anyhow::anyhow!("reshaping literal: {e:?}"))
}
