//! Pure-Rust runtime backend (default build, no `pjrt` feature).
//!
//! Generator execution routes through the reverse-loop deconvolution
//! substrate — the same Algorithm 1 the Pallas kernel implements — with
//! output tiles sharded across a [`WorkerPool`] (the software analogue
//! of the paper's CU array).  The parallel path is bit-identical to the
//! serial one, so seeded serving stays deterministic.
//!
//! Single-layer HLO execution has no fallback (there is nothing to
//! interpret the HLO with); [`LoadedHlo::run`] reports the missing
//! feature instead of pretending.

use crate::artifacts::ArtifactDir;
use crate::config::NetworkCfg;
use crate::deconv::generator_forward_par;
use crate::tensor::Tensor;
use crate::util::WorkerPool;
use anyhow::{ensure, Result};
use std::path::{Path, PathBuf};

/// Stand-in for `xla::Literal`: shape + row-major f32 data.  Lets the
/// literal-building call sites compile (and carry data) without PJRT.
#[derive(Debug, Clone)]
pub struct Literal {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

/// The fallback "device": a worker pool for the reverse-loop substrate.
pub struct Runtime {
    pool: WorkerPool,
}

impl Runtime {
    /// Create the fallback runtime.  Worker count comes from
    /// `EDGEDCNN_WORKERS` or `available_parallelism`.
    pub fn cpu() -> Result<Self> {
        Ok(Runtime {
            pool: WorkerPool::with_default_parallelism(),
        })
    }

    /// Fallback runtime with an explicit worker budget — the
    /// coordinator divides the host among its executors so concurrent
    /// executors do not oversubscribe the CPU.
    pub fn cpu_with_workers(workers: usize) -> Result<Self> {
        Ok(Runtime {
            pool: WorkerPool::new(workers),
        })
    }

    pub fn platform_name(&self) -> String {
        format!(
            "rust-reverse-loop ({} workers; build without `pjrt` feature)",
            self.pool.workers()
        )
    }

    /// "Load" an HLO artifact: the file must exist, but execution is
    /// unavailable in this backend.
    pub fn load_hlo(&self, path: &Path) -> Result<LoadedHlo> {
        ensure!(
            path.exists(),
            "HLO artifact {} not found",
            path.display()
        );
        Ok(LoadedHlo {
            path: path.to_path_buf(),
        })
    }

    /// Load a generator "executable": the manifest metadata plus the
    /// pure-Rust forward bound to this runtime's worker pool.
    pub fn load_generator(
        &self,
        artifacts: &ArtifactDir,
        network: &str,
        want_batch: usize,
    ) -> Result<GeneratorExecutable> {
        let (batch, _path) = artifacts.generator_hlo(network, want_batch)?;
        let net = artifacts.network(network)?;
        let cfg = artifacts.network_cfg(network)?;
        Ok(GeneratorExecutable {
            cfg,
            batch,
            z_dim: net.z_dim,
            image_channels: net.image_channels,
            image_size: net.image_size,
            network: network.to_string(),
            pool: self.pool,
        })
    }
}

/// A "loaded" HLO module in the fallback backend — path only.
pub struct LoadedHlo {
    path: PathBuf,
}

impl LoadedHlo {
    /// Always errors: HLO execution requires the `pjrt` feature.
    pub fn run(&self, _inputs: &[Literal]) -> Result<Vec<f32>> {
        anyhow::bail!(
            "cannot execute {}: this build has no PJRT backend (enable the \
             `pjrt` feature in an environment that ships the `xla` crate)",
            self.path.display()
        )
    }

    pub fn run_to_tensor(
        &self,
        inputs: &[Literal],
        out_shape: Vec<usize>,
    ) -> Result<Tensor> {
        let data = self.run(inputs)?;
        Tensor::new(out_shape, data)
    }
}

/// A generator bound to its metadata, executing `z + weights → images`
/// through the parallel reverse-loop substrate.
pub struct GeneratorExecutable {
    cfg: NetworkCfg,
    pub batch: usize,
    pub z_dim: usize,
    pub image_channels: usize,
    pub image_size: usize,
    pub network: String,
    pool: WorkerPool,
}

impl GeneratorExecutable {
    /// Generate a batch of images from latent `z` (`[batch, z_dim]`) and
    /// a weight set `[(w, bias)]` (dense or pruned).
    pub fn generate(
        &self,
        z: &Tensor,
        weights: &[(Tensor, Vec<f32>)],
    ) -> Result<Tensor> {
        ensure!(
            z.shape() == [self.batch, self.z_dim],
            "z shape {:?} != [{}, {}]",
            z.shape(),
            self.batch,
            self.z_dim
        );
        ensure!(
            weights.len() == self.cfg.layers.len(),
            "weight set has {} layers, network has {}",
            weights.len(),
            self.cfg.layers.len()
        );
        Ok(generator_forward_par(&self.cfg, weights, z, &self.pool))
    }

    /// Output elements per generated image.
    pub fn image_numel(&self) -> usize {
        self.image_channels * self.image_size * self.image_size
    }
}

/// Convert a [`Tensor`] to a [`Literal`].
pub fn tensor_to_literal(t: &Tensor) -> Result<Literal> {
    Ok(Literal {
        shape: t.shape().to_vec(),
        data: t.data().to_vec(),
    })
}

/// Convert raw f32 data + shape to a [`Literal`].
pub fn data_to_literal(data: &[f32], shape: &[usize]) -> Result<Literal> {
    let numel: usize = shape.iter().product();
    ensure!(numel == data.len(), "literal shape/data mismatch");
    Ok(Literal {
        shape: shape.to_vec(),
        data: data.to_vec(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::artifacts::write_synthetic;
    use crate::util::{Rng, TempDir};

    #[test]
    fn fallback_generator_runs_end_to_end() {
        let dir = TempDir::new().unwrap();
        let artifacts =
            write_synthetic(dir.path(), &["mnist"], 2, 11).unwrap();
        let runtime = Runtime::cpu().unwrap();
        assert!(runtime.platform_name().contains("rust-reverse-loop"));
        let exe = runtime.load_generator(&artifacts, "mnist", 1).unwrap();
        assert_eq!(exe.batch, 1);
        let weights = artifacts.load_weights("mnist").unwrap();
        let mut rng = Rng::seed_from_u64(3);
        let z = Tensor::from_fn(vec![1, exe.z_dim], |_| rng.normal_f32());
        let img = exe.generate(&z, &weights).unwrap();
        assert_eq!(img.shape(), &[1, 1, 28, 28]);
        assert!(img.data().iter().all(|v| v.abs() <= 1.0), "tanh range");
        // deterministic
        let img2 = exe.generate(&z, &weights).unwrap();
        assert_eq!(img.data(), img2.data());
    }

    #[test]
    fn hlo_execution_reports_missing_backend() {
        let dir = TempDir::new().unwrap();
        let path = dir.path().join("x.hlo.txt");
        std::fs::write(&path, "HloModule x").unwrap();
        let runtime = Runtime::cpu().unwrap();
        let hlo = runtime.load_hlo(&path).unwrap();
        let err = hlo.run(&[]).unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
        assert!(runtime.load_hlo(&dir.path().join("nope.hlo")).is_err());
    }

    #[test]
    fn literal_helpers_validate() {
        let t = Tensor::from_fn(vec![2, 3], |i| i as f32);
        let l = tensor_to_literal(&t).unwrap();
        assert_eq!(l.shape, vec![2, 3]);
        assert_eq!(l.data.len(), 6);
        assert!(data_to_literal(&[1.0, 2.0], &[3]).is_err());
    }
}
