//! Execution runtime for the AOT artifacts.
//!
//! Two interchangeable backends behind one API:
//!
//! * **`pjrt` feature** — loads the HLO-text artifacts and executes them
//!   on the CPU PJRT client (`xla` crate).  This is the full three-layer
//!   path; it requires an environment that ships the `xla` crate (the
//!   offline image does not — see Cargo.toml).
//! * **default (no feature)** — the pure-Rust fallback: generators run
//!   through the reverse-loop deconvolution substrate
//!   ([`crate::deconv::generator_forward_par`]), sharded across a
//!   [`crate::util::WorkerPool`].  Numerically identical to the artifact
//!   path (asserted by the integration tests when both are available);
//!   single-layer HLO execution is unavailable and reports so.
//!
//! Either way the `Runtime` is owned by one executor thread; the
//! coordinator runs a pool of them and communicates over channels (see
//! [`crate::coordinator`]).

#[cfg(feature = "pjrt")]
mod executable;
#[cfg(feature = "pjrt")]
mod pjrt;

#[cfg(feature = "pjrt")]
pub use executable::{GeneratorExecutable, LoadedHlo};
#[cfg(feature = "pjrt")]
pub use pjrt::{data_to_literal, tensor_to_literal, Runtime};

#[cfg(not(feature = "pjrt"))]
mod fallback;

#[cfg(not(feature = "pjrt"))]
pub use fallback::{
    data_to_literal, tensor_to_literal, GeneratorExecutable, Literal,
    LoadedHlo, Runtime,
};

/// Was this build compiled with the PJRT backend?
pub fn has_pjrt() -> bool {
    cfg!(feature = "pjrt")
}
