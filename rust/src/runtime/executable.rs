//! Compiled-executable wrappers: a generic loaded HLO module and the
//! generator-specific convenience layer (z + weights → images).

use crate::tensor::Tensor;
use anyhow::{ensure, Result};

/// A compiled PJRT executable (1-tuple output convention — every AOT
/// artifact is lowered with `return_tuple=True`).
pub struct LoadedHlo {
    exe: xla::PjRtLoadedExecutable,
}

impl LoadedHlo {
    pub(crate) fn new(exe: xla::PjRtLoadedExecutable) -> Self {
        LoadedHlo { exe }
    }

    /// Execute with literal inputs; returns the unwrapped first tuple
    /// element as raw f32 data.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<f32>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow::anyhow!("executing: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching result: {e:?}"))?;
        let out = result
            .to_tuple1()
            .map_err(|e| anyhow::anyhow!("unwrapping tuple: {e:?}"))?;
        out.to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("reading f32 output: {e:?}"))
    }

    /// Execute and shape the output into a [`Tensor`].
    pub fn run_to_tensor(
        &self,
        inputs: &[xla::Literal],
        out_shape: Vec<usize>,
    ) -> Result<Tensor> {
        let data = self.run(inputs)?;
        Tensor::new(out_shape, data)
    }
}

/// A generator artifact bound to its metadata: executes
/// `(z, w0, b0, w1, b1, …) → images` per the manifest's `param_order`.
pub struct GeneratorExecutable {
    pub(crate) hlo: LoadedHlo,
    pub batch: usize,
    pub z_dim: usize,
    pub image_channels: usize,
    pub image_size: usize,
    pub network: String,
}

impl GeneratorExecutable {
    /// Generate a batch of images from latent `z` (`[batch, z_dim]`) and
    /// a weight set `[(w, bias)]` (dense or pruned).
    pub fn generate(
        &self,
        z: &Tensor,
        weights: &[(Tensor, Vec<f32>)],
    ) -> Result<Tensor> {
        ensure!(
            z.shape() == [self.batch, self.z_dim],
            "z shape {:?} != [{}, {}]",
            z.shape(),
            self.batch,
            self.z_dim
        );
        let mut literals = Vec::with_capacity(1 + 2 * weights.len());
        literals.push(super::tensor_to_literal(z)?);
        for (w, b) in weights {
            literals.push(super::tensor_to_literal(w)?);
            literals.push(super::data_to_literal(b, &[b.len()])?);
        }
        self.hlo.run_to_tensor(
            &literals,
            vec![
                self.batch,
                self.image_channels,
                self.image_size,
                self.image_size,
            ],
        )
    }

    /// Output elements per generated batch.
    pub fn image_numel(&self) -> usize {
        self.image_channels * self.image_size * self.image_size
    }
}
