//! Open-loop loadtest — the paper's run-to-run-variation verdict as a
//! live experiment.
//!
//! A [`Trace`] is driven against a fresh coordinator once per trial:
//! requests are submitted at their *scheduled* timestamps (never gated
//! on responses — open loop), and each request's latency is measured
//! from its scheduled arrival, so generator lag is charged to the
//! system rather than hidden (the open-loop form of coordinated-
//! omission correction; see DESIGN.md §Telemetry).  Each trial re-seeds
//! the device measurement-noise streams, so trials are independent
//! measurements of the same workload — exactly the repeated-run
//! campaign behind Table II, but through the serving stack.
//!
//! The verdict aggregates per lane: request-latency quantiles (merged
//! histogram shards), SLO attainment, pooled per-image device-latency
//! CV (the stability metric — FPGA ≈ clock jitter, GPU ≈ DVFS +
//! measurement noise), and across-trial throughput with bootstrap CIs.
//!
//! Batches are sharded across the capable lanes by default: the
//! loadtest is a per-device measurement campaign, so it wants every
//! lane exercised rather than the per-network ordering guarantee
//! (`LoadtestOpts::shard_batches` restores it if needed).

use super::trace::Trace;
use crate::config::{BackendCfg, QFormat};
use crate::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, LatencyReport,
};
use crate::stats::Welford;
use crate::telemetry::{
    variation_of, weighted_cv, LogHistogram, SloCounter, Variation,
};
use crate::util::Rng;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::{Duration, Instant};

/// Loadtest configuration (the trace supplies the traffic).
#[derive(Debug, Clone)]
pub struct LoadtestOpts {
    pub artifacts_dir: PathBuf,
    pub backends: BackendCfg,
    /// Lane-count override, as in [`CoordinatorConfig::executors`].
    pub executors: usize,
    /// Independent repetitions of the trace (device noise re-seeded per
    /// trial).
    pub trials: usize,
    /// Split multi-request batches across the capable lanes (default:
    /// the verdict wants every device measured under the same traffic).
    pub shard_batches: bool,
}

impl Default for LoadtestOpts {
    fn default() -> Self {
        LoadtestOpts {
            artifacts_dir: "artifacts".into(),
            backends: BackendCfg::default(),
            executors: 0,
            trials: 5,
            shard_batches: true,
        }
    }
}

/// One lane's row of the verdict table.
#[derive(Debug, Clone)]
pub struct LaneVerdict {
    pub name: String,
    /// Batches/images across all trials.
    pub batches: u64,
    pub images: u64,
    pub energy_j: f64,
    /// Request-latency quantiles (coordinated-omission corrected,
    /// merged across trials).
    pub latency: LatencyReport,
    /// SLO attainment in [0, 1].
    pub slo_attainment: f64,
    /// Mean device latency per image, seconds.
    pub mean_device_per_image_s: f64,
    /// Pooled CV of the per-image device latency — the run-to-run
    /// stability column of the verdict.
    pub latency_cv: f64,
    /// Across-trial throughput (img/s): mean/CV/bootstrap CI.
    pub throughput: Variation,
}

/// The FPGA-vs-GPU stability comparison, when both lanes served work.
#[derive(Debug, Clone)]
pub struct VariationVerdict {
    pub fpga_lane: String,
    pub fpga_cv: f64,
    pub gpu_lane: String,
    pub gpu_cv: f64,
    /// The paper's claim: the FPGA lane varies strictly less.
    pub fpga_wins: bool,
}

/// Aggregated loadtest outcome.
#[derive(Debug, Clone)]
pub struct LoadtestReport {
    pub scenario: String,
    pub trials: usize,
    pub requests_per_trial: usize,
    pub total_requests: u64,
    /// Requests turned away by admission control (the coordinator's
    /// own counter — the intended load-shedding path).
    pub rejected: u64,
    /// Requests whose replies were dropped for any *other* reason
    /// (backend execution failure) — nonzero means infrastructure
    /// trouble, not load shedding, and the verdict flags it.
    pub lost: u64,
    pub deferred: u64,
    pub slo_s: f64,
    /// Pool-wide latency quantiles (all lanes, all trials).
    pub latency: LatencyReport,
    pub slo_attainment: f64,
    /// Mean trial wall time, seconds.
    pub mean_wall_s: f64,
    pub lanes: Vec<LaneVerdict>,
    pub verdict: Option<VariationVerdict>,
    /// One summary line per trial (requests, wall, img/s, p99).
    pub trial_lines: Vec<String>,
}

#[derive(Debug)]
struct LaneAgg {
    batches: u64,
    images: u64,
    energy_j: f64,
    hist: LogHistogram,
    slo: SloCounter,
    /// Per-image device latency, split per (network, batch size) so
    /// neither precision twins' different service times nor batch-size
    /// amortization (the GPU's launch overhead shrinking per image as
    /// batches grow) read as device jitter.
    dev_per_image: BTreeMap<(String, usize), Welford>,
    /// All per-image device samples (for the mean column only).
    dev_all: Welford,
    throughput_by_trial: Vec<f64>,
}

impl LaneAgg {
    fn new(slo_s: f64) -> Self {
        LaneAgg {
            batches: 0,
            images: 0,
            energy_j: 0.0,
            hist: LogHistogram::latency_default(),
            slo: SloCounter::new(slo_s),
            dev_per_image: BTreeMap::new(),
            dev_all: Welford::new(),
            throughput_by_trial: Vec::new(),
        }
    }
}

fn quantiles(h: &LogHistogram) -> LatencyReport {
    LatencyReport {
        mean_s: h.mean(),
        p50_s: h.quantile(50.0),
        p95_s: h.quantile(95.0),
        p99_s: h.quantile(99.0),
        p999_s: h.quantile(99.9),
    }
}

/// Run the trace `opts.trials` times and aggregate the verdict.
pub fn run_loadtest(trace: &Trace, opts: &LoadtestOpts) -> Result<LoadtestReport> {
    anyhow::ensure!(opts.trials >= 1, "loadtest needs at least one trial");
    anyhow::ensure!(!trace.events.is_empty(), "trace has no events");

    // networks to preload (base names) and whether any .q twin is mixed
    let (networks, any_quant) = trace.networks();

    let mut overall = LogHistogram::latency_default();
    let mut overall_slo = SloCounter::new(trace.slo_s);
    let mut lanes: BTreeMap<String, LaneAgg> = BTreeMap::new();
    let mut rejected = 0u64;
    let mut lost = 0u64;
    let mut deferred = 0u64;
    let mut walls = Vec::with_capacity(opts.trials);
    let mut trial_lines = Vec::with_capacity(opts.trials);

    for trial in 0..opts.trials {
        // independent measurement noise per trial, deterministic overall
        let mut backends = opts.backends.clone();
        backends.noise_seed = Rng::seed_from_u64(
            trace.seed.wrapping_add(0xC0FFEE + trial as u64),
        )
        .next_u64();
        let coord = Coordinator::start(CoordinatorConfig {
            artifacts_dir: opts.artifacts_dir.clone(),
            networks: networks.clone(),
            batcher: BatcherConfig::default(),
            backends,
            executors: opts.executors,
            quant: any_quant.then_some(QFormat::new(16, 8)),
            shard_batches: opts.shard_batches,
        })
        .with_context(|| format!("starting the pool for trial {trial}"))?;

        // open-loop submission at the scheduled timestamps
        let t0 = Instant::now();
        let mut pending = Vec::with_capacity(trace.events.len());
        for e in &trace.events {
            let target = t0 + Duration::from_secs_f64(e.t_s);
            let now = Instant::now();
            if target > now {
                std::thread::sleep(target - now);
            }
            // generator lag is charged to the measurement (coordinated
            // omission: latency counts from the *scheduled* arrival)
            let lag = Instant::now()
                .saturating_duration_since(target)
                .as_secs_f64();
            pending.push((e, lag, coord.submit(&e.network, e.n_images, e.seed)?));
        }
        let mut trial_hist = LogHistogram::latency_default();
        let mut trial_errors = 0u64;
        for (e, lag, handle) in pending {
            match handle.wait() {
                Ok(resp) => {
                    let latency = lag + resp.latency_s;
                    overall.record(latency);
                    overall_slo.record(latency);
                    trial_hist.record(latency);
                    let lane = lanes
                        .entry(resp.backend.clone())
                        .or_insert_with(|| LaneAgg::new(trace.slo_s));
                    lane.hist.record(latency);
                    lane.slo.record(latency);
                    let per_image =
                        resp.device_time_s / e.n_images.max(1) as f64;
                    lane.dev_per_image
                        .entry((e.network.clone(), resp.batch_size))
                        .or_default()
                        .push(per_image);
                    lane.dev_all.push(per_image);
                }
                // dropped reply: admission rejection or backend failure
                // (told apart below via the coordinator's own counter)
                Err(_) => trial_errors += 1,
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        walls.push(wall);

        let report = coord.report_for_wall(wall);
        // the coordinator knows how many it *chose* to reject; any
        // further dropped replies were execution failures
        let trial_rejected = report.rejected.min(trial_errors);
        rejected += trial_rejected;
        lost += trial_errors - trial_rejected;
        deferred += report.deferred;
        for b in &report.per_backend {
            let lane = lanes
                .entry(b.name.clone())
                .or_insert_with(|| LaneAgg::new(trace.slo_s));
            lane.batches += b.batches;
            lane.images += b.images;
            lane.energy_j += b.energy_j;
            lane.throughput_by_trial.push(b.images_per_s);
        }
        trial_lines.push(format!(
            "trial {trial}: {} requests  wall {:.3} s  {:.1} img/s  \
             p99 {:.2} ms  rejected {trial_rejected}",
            trace.events.len(),
            wall,
            report.images_per_s,
            trial_hist.quantile(99.0) * 1e3,
        ));
    }

    let lane_verdicts: Vec<LaneVerdict> = lanes
        .iter()
        .map(|(name, l)| LaneVerdict {
            name: name.clone(),
            batches: l.batches,
            images: l.images,
            energy_j: l.energy_j,
            latency: quantiles(&l.hist),
            slo_attainment: l.slo.attainment(),
            mean_device_per_image_s: l.dev_all.mean(),
            latency_cv: weighted_cv(l.dev_per_image.values()),
            throughput: variation_of(&l.throughput_by_trial, trace.seed),
        })
        .collect();

    // the paper's comparison: first FPGA-sim lane vs first GPU-model
    // lane, both with enough batches for a CV to mean anything
    let find = |prefix: &str| {
        lane_verdicts
            .iter()
            .find(|l| l.name.starts_with(prefix) && l.batches >= 2)
    };
    let verdict = match (find("fpga"), find("gpu")) {
        (Some(f), Some(g)) => Some(VariationVerdict {
            fpga_lane: f.name.clone(),
            fpga_cv: f.latency_cv,
            gpu_lane: g.name.clone(),
            gpu_cv: g.latency_cv,
            fpga_wins: f.latency_cv < g.latency_cv,
        }),
        _ => None,
    };

    Ok(LoadtestReport {
        scenario: trace.scenario.clone(),
        trials: opts.trials,
        requests_per_trial: trace.events.len(),
        total_requests: (trace.events.len() * opts.trials) as u64,
        rejected,
        lost,
        deferred,
        slo_s: trace.slo_s,
        latency: quantiles(&overall),
        slo_attainment: overall_slo.attainment(),
        mean_wall_s: walls.iter().sum::<f64>() / walls.len() as f64,
        lanes: lane_verdicts,
        verdict,
        trial_lines,
    })
}

impl LoadtestReport {
    /// Render the verdict table.  Lane rows are stable `key value`
    /// pairs (the CI smoke job parses them).
    pub fn render(&self) -> String {
        let mut out = format!(
            "== loadtest: scenario {}  ({} trials × {} requests, SLO {:.0} ms) ==\n",
            self.scenario,
            self.trials,
            self.requests_per_trial,
            self.slo_s * 1e3,
        );
        for line in &self.trial_lines {
            out.push_str(line);
            out.push('\n');
        }
        out.push_str(&format!(
            "overall: p50 {:.2}  p95 {:.2}  p99 {:.2}  p99.9 {:.2} ms  \
             (coordinated-omission corrected)  slo {:.1}%  rejected {}  \
             deferred {}\n",
            self.latency.p50_s * 1e3,
            self.latency.p95_s * 1e3,
            self.latency.p99_s * 1e3,
            self.latency.p999_s * 1e3,
            self.slo_attainment * 100.0,
            self.rejected,
            self.deferred,
        ));
        if self.lost > 0 {
            out.push_str(&format!(
                "WARNING: {} request(s) lost to backend execution failures \
                 (not admission control) — results are incomplete\n",
                self.lost,
            ));
        }
        for l in &self.lanes {
            out.push_str(&format!(
                "lane {} batches {} images {} p50_ms {:.3} p95_ms {:.3} \
                 p99_ms {:.3} p999_ms {:.3} cv_pct {:.3} slo_pct {:.1} \
                 dev_ms_img {:.3} img_s {:.1} ci95 {:.1}-{:.1} energy_j {:.3}\n",
                l.name,
                l.batches,
                l.images,
                l.latency.p50_s * 1e3,
                l.latency.p95_s * 1e3,
                l.latency.p99_s * 1e3,
                l.latency.p999_s * 1e3,
                l.latency_cv * 100.0,
                l.slo_attainment * 100.0,
                l.mean_device_per_image_s * 1e3,
                l.throughput.mean,
                l.throughput.ci_lo,
                l.throughput.ci_hi,
                l.energy_j,
            ));
        }
        match &self.verdict {
            Some(v) if v.fpga_wins => out.push_str(&format!(
                "verdict: device-latency variation {} cv {:.2}% < {} cv \
                 {:.2}% — the FPGA lane is the stable one (paper Table II)\n",
                v.fpga_lane,
                v.fpga_cv * 100.0,
                v.gpu_lane,
                v.gpu_cv * 100.0,
            )),
            Some(v) => out.push_str(&format!(
                "verdict: NOT reproduced — {} cv {:.2}% vs {} cv {:.2}%\n",
                v.fpga_lane,
                v.fpga_cv * 100.0,
                v.gpu_lane,
                v.gpu_cv * 100.0,
            )),
            None => out.push_str(
                "verdict: n/a (needs both an fpga and a gpu lane with work)\n",
            ),
        }
        out
    }
}
