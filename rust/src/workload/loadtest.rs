//! Loadtest driver — the paper's run-to-run-variation verdict as a
//! live experiment, now deadline-aware end to end.
//!
//! **Open loop** (default): a [`Trace`] is driven against a fresh
//! coordinator once per trial.  Each event becomes a [`RequestCtx`]
//! stamped with its *scheduled* arrival — the context the whole stack
//! charges latency from — so generator lag counts against the system
//! (the open-loop form of coordinated-omission correction; before
//! `RequestCtx` existed the loadtest kept a side-channel lag term the
//! coordinator never saw).  Deadlines and priority classes ride the
//! same context: the scheduler sheds infeasible requests at intake and
//! EDF-orders the rest.
//!
//! **Closed loop** (`--closed N --think-ms T`): N clients each keep one
//! request in flight, think `T` ms between completions, and draw the
//! same trace events (mix, seeds, classes, relative deadlines) with
//! arrivals stamped at submission.  Same context type, same verdict
//! table — the ROADMAP's think-time loop without a second code path.
//!
//! Each trial re-seeds the device measurement-noise streams, so trials
//! are independent measurements of the same workload — exactly the
//! repeated-run campaign behind Table II, but through the serving
//! stack.
//!
//! The verdict aggregates per lane: request-latency quantiles (merged
//! histogram shards), SLO attainment, **deadline attainment with the
//! shed / served-late split** (shed-early at intake vs completed past
//! the deadline — the split that lets the FPGA-vs-GPU comparison be
//! made at a fixed attainment target), pooled per-image device-latency
//! CV, and across-trial throughput with bootstrap CIs.
//!
//! Batches are sharded across the capable lanes by default: the
//! loadtest is a per-device measurement campaign, so it wants every
//! lane exercised rather than the per-network ordering guarantee
//! (`LoadtestOpts::shard_batches` restores it if needed).

use super::trace::{Trace, TraceEvent};
use crate::config::{BackendCfg, QFormat};
use crate::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, LatencyReport, RequestCtx,
    RequestOutcome,
};
use crate::stats::Welford;
use crate::telemetry::{
    chrome_trace, variation_of, weighted_cv, LogHistogram, SloCounter,
    Variation,
};
use crate::util::Rng;
use anyhow::{Context, Result};
use std::collections::{BTreeMap, VecDeque};
use std::path::PathBuf;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Loadtest configuration (the trace supplies the traffic).
#[derive(Debug, Clone)]
pub struct LoadtestOpts {
    pub artifacts_dir: PathBuf,
    pub backends: BackendCfg,
    /// Lane-count override, as in [`CoordinatorConfig::executors`].
    pub executors: usize,
    /// Independent repetitions of the trace (device noise re-seeded per
    /// trial).
    pub trials: usize,
    /// Split multi-request batches across the capable lanes (default:
    /// the verdict wants every device measured under the same traffic).
    pub shard_batches: bool,
    /// Closed-loop client count; `0` = open loop (the default).  In
    /// closed-loop mode the trace supplies the mix/seeds/deadlines and
    /// the clients supply the pacing.
    pub closed: usize,
    /// Think time between a closed-loop client's completions.
    pub think: Duration,
    /// Write the final trial's windowed latency-drift histogram shards
    /// as CSV (`ServingReport::drift_csv`) to this path.
    pub drift_csv: Option<PathBuf>,
    /// Write the final trial's sampled request lifecycles as a Chrome
    /// trace-event JSON file (Perfetto-loadable; one track per lane).
    pub trace_out: Option<PathBuf>,
}

impl Default for LoadtestOpts {
    fn default() -> Self {
        LoadtestOpts {
            artifacts_dir: "artifacts".into(),
            backends: BackendCfg::default(),
            executors: 0,
            trials: 5,
            shard_batches: true,
            closed: 0,
            think: Duration::ZERO,
            drift_csv: None,
            trace_out: None,
        }
    }
}

/// One lane's row of the verdict table.
#[derive(Debug, Clone)]
pub struct LaneVerdict {
    pub name: String,
    /// Batches/images across all trials.
    pub batches: u64,
    pub images: u64,
    pub energy_j: f64,
    /// Request-latency quantiles (coordinated-omission corrected,
    /// merged across trials).
    pub latency: LatencyReport,
    /// SLO attainment in [0, 1] (wall latency vs the scenario SLO).
    pub slo_attainment: f64,
    /// Deadline-bearing requests this lane completed on time
    /// (edge-charged completion ≤ deadline).
    pub deadline_met: u64,
    /// Deadline-bearing requests this lane completed *past* their
    /// deadline (the serve-late half of the shed/served-late split).
    pub served_late: u64,
    /// Mean device latency per image, seconds.
    pub mean_device_per_image_s: f64,
    /// Pooled CV of the per-image device latency — the run-to-run
    /// stability column of the verdict.
    pub latency_cv: f64,
    /// Across-trial throughput (img/s): mean/CV/bootstrap CI.
    pub throughput: Variation,
}

impl LaneVerdict {
    /// Deadline attainment in [0, 1] over the lane's deadline-bearing
    /// completions (vacuous 1.0 when the traffic carried no deadlines).
    pub fn deadline_attainment(&self) -> f64 {
        let total = self.deadline_met + self.served_late;
        if total == 0 {
            1.0
        } else {
            self.deadline_met as f64 / total as f64
        }
    }
}

/// The FPGA-vs-GPU stability comparison, when both lanes served work.
#[derive(Debug, Clone)]
pub struct VariationVerdict {
    pub fpga_lane: String,
    pub fpga_cv: f64,
    pub gpu_lane: String,
    pub gpu_cv: f64,
    /// The paper's claim: the FPGA lane varies strictly less.
    pub fpga_wins: bool,
}

/// The stability claim restated as a deadline claim: at equal deadlines
/// the predictable device attains at least as much.
#[derive(Debug, Clone)]
pub struct DeadlineVerdict {
    pub fpga_lane: String,
    pub fpga_attainment: f64,
    pub gpu_lane: String,
    pub gpu_attainment: f64,
    pub fpga_wins: bool,
}

/// Aggregated loadtest outcome.
#[derive(Debug, Clone)]
pub struct LoadtestReport {
    pub scenario: String,
    pub trials: usize,
    pub requests_per_trial: usize,
    pub total_requests: u64,
    /// Closed-loop client count (0 = open loop).
    pub closed: usize,
    /// Requests that resolved with a response (on time or late).
    pub served: u64,
    /// Requests shed at intake: their deadline was already infeasible
    /// given queue depth × predicted cost (shed-early, the coordinator's
    /// own counter).
    pub shed: u64,
    /// Served requests that completed past their deadline (summed over
    /// lanes) — the other half of the shed/served-late split.
    pub served_late: u64,
    /// Requests turned away by overload admission control (the deferred
    /// queue outgrew the class budget).
    pub rejected: u64,
    /// Requests whose replies were dropped for any *other* reason
    /// (backend execution failure) — nonzero means infrastructure
    /// trouble, not load shedding, and the verdict flags it.
    pub lost: u64,
    pub deferred: u64,
    pub slo_s: f64,
    /// Pool-wide latency quantiles (all lanes, all trials).
    pub latency: LatencyReport,
    pub slo_attainment: f64,
    /// Mean trial wall time, seconds.
    pub mean_wall_s: f64,
    pub lanes: Vec<LaneVerdict>,
    pub verdict: Option<VariationVerdict>,
    pub deadline_verdict: Option<DeadlineVerdict>,
    /// One summary line per trial (requests, wall, img/s, p99).
    pub trial_lines: Vec<String>,
}

#[derive(Debug)]
struct LaneAgg {
    batches: u64,
    images: u64,
    energy_j: f64,
    hist: LogHistogram,
    slo: SloCounter,
    deadline_met: u64,
    served_late: u64,
    /// Per-image device latency, split per (network, batch size) so
    /// neither precision twins' different service times nor batch-size
    /// amortization (the GPU's launch overhead shrinking per image as
    /// batches grow) read as device jitter.
    dev_per_image: BTreeMap<(String, usize), Welford>,
    /// All per-image device samples (for the mean column only).
    dev_all: Welford,
    throughput_by_trial: Vec<f64>,
}

impl LaneAgg {
    fn new(slo_s: f64) -> Self {
        LaneAgg {
            batches: 0,
            images: 0,
            energy_j: 0.0,
            hist: LogHistogram::latency_default(),
            slo: SloCounter::new(slo_s),
            deadline_met: 0,
            served_late: 0,
            dev_per_image: BTreeMap::new(),
            dev_all: Welford::new(),
            throughput_by_trial: Vec::new(),
        }
    }
}

fn quantiles(h: &LogHistogram) -> LatencyReport {
    LatencyReport {
        mean_s: h.mean(),
        p50_s: h.quantile(50.0),
        p95_s: h.quantile(95.0),
        p99_s: h.quantile(99.0),
        p999_s: h.quantile(99.9),
    }
}

/// The request context one trace event submits under: arrival is the
/// caller-chosen charge point (scheduled target in open loop, "now" in
/// closed loop), the absolute deadline and class come off the event.
/// Shared with the fleet driver so a spilled request re-submits under
/// the *same* context it first arrived with.
pub(crate) fn event_ctx(e: &TraceEvent, arrival: Instant) -> RequestCtx {
    RequestCtx {
        arrival,
        deadline: e
            .deadline_s
            .map(|d| arrival + Duration::from_secs_f64(d)),
        class: e.class,
        seed: e.seed,
        stamps: Default::default(),
    }
}

/// One trial's raw outcomes: per request, the (network, n_images) it
/// asked for and the typed outcome it resolved to — served / shed /
/// rejected / lost straight off the reply channel, so the accounting
/// below is exact instead of reconciled against coordinator counters.
type Outcome = (String, usize, RequestOutcome);

/// Open-loop submission at the scheduled timestamps; latency is charged
/// from the scheduled arrival via the request context itself.
fn drive_open_loop(coord: &Coordinator, trace: &Trace) -> Result<Vec<Outcome>> {
    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(trace.events.len());
    for e in &trace.events {
        let target = t0 + Duration::from_secs_f64(e.t_s);
        let now = Instant::now();
        if target > now {
            std::thread::sleep(target - now);
        }
        // generator lag is charged to the measurement: the context's
        // arrival stays the *scheduled* instant (coordinated omission)
        pending.push((
            e,
            coord
                .request(&e.network)
                .images(e.n_images)
                .ctx(event_ctx(e, target))
                .submit()?,
        ));
    }
    Ok(pending
        .into_iter()
        .map(|(e, h)| (e.network.clone(), e.n_images, h.outcome()))
        .collect())
}

/// Closed-loop driver: `clients` threads each keep one request in
/// flight over the shared event queue, thinking `think` between
/// completions.
fn drive_closed_loop(
    coord: &Coordinator,
    trace: &Trace,
    clients: usize,
    think: Duration,
) -> Vec<Outcome> {
    let queue: Mutex<VecDeque<&TraceEvent>> =
        Mutex::new(trace.events.iter().collect());
    let results: Mutex<Vec<Outcome>> =
        Mutex::new(Vec::with_capacity(trace.events.len()));
    std::thread::scope(|scope| {
        for _ in 0..clients.max(1) {
            let client = coord.client();
            let queue = &queue;
            let results = &results;
            scope.spawn(move || loop {
                let next = queue.lock().unwrap().pop_front();
                let Some(e) = next else { break };
                let outcome = match client
                    .request(&e.network)
                    .images(e.n_images)
                    .ctx(event_ctx(e, Instant::now()))
                    .submit()
                {
                    Ok(h) => h.outcome(),
                    // submission failed = coordinator gone: the request
                    // never entered the system, count it lost
                    Err(_) => RequestOutcome::Lost,
                };
                results
                    .lock()
                    .unwrap()
                    .push((e.network.clone(), e.n_images, outcome));
                if !think.is_zero() {
                    std::thread::sleep(think);
                }
            });
        }
    });
    results.into_inner().unwrap()
}

/// Run the trace `opts.trials` times and aggregate the verdict.
pub fn run_loadtest(trace: &Trace, opts: &LoadtestOpts) -> Result<LoadtestReport> {
    anyhow::ensure!(opts.trials >= 1, "loadtest needs at least one trial");
    anyhow::ensure!(!trace.events.is_empty(), "trace has no events");

    // networks to preload (base names) and whether any .q twin is mixed
    let (networks, twins) = trace.networks();

    let mut overall = LogHistogram::latency_default();
    let mut overall_slo = SloCounter::new(trace.slo_s);
    let mut lanes: BTreeMap<String, LaneAgg> = BTreeMap::new();
    let mut served = 0u64;
    let mut rejected = 0u64;
    let mut shed = 0u64;
    let mut lost = 0u64;
    let mut deferred = 0u64;
    let mut walls = Vec::with_capacity(opts.trials);
    let mut trial_lines = Vec::with_capacity(opts.trials);

    for trial in 0..opts.trials {
        // independent measurement noise per trial, deterministic overall
        let mut backends = opts.backends.clone();
        backends.noise_seed = Rng::seed_from_u64(
            trace.seed.wrapping_add(0xC0FFEE + trial as u64),
        )
        .next_u64();
        let coord = Coordinator::start(CoordinatorConfig {
            artifacts_dir: opts.artifacts_dir.clone(),
            networks: networks.clone(),
            batcher: BatcherConfig::default(),
            backends,
            executors: opts.executors,
            quant: twins.q.then_some(QFormat::new(16, 8)),
            quant8: twins.q8.then_some(QFormat::new(8, 6)),
            shard_batches: opts.shard_batches,
            clock: None,
        })
        .with_context(|| format!("starting the pool for trial {trial}"))?;

        let t0 = Instant::now();
        let outcomes = if opts.closed > 0 {
            drive_closed_loop(&coord, trace, opts.closed, opts.think)
        } else {
            drive_open_loop(&coord, trace)?
        };
        let mut trial_hist = LogHistogram::latency_default();
        let mut trial_shed = 0u64;
        let mut trial_rejected = 0u64;
        for (network, n_images, outcome) in outcomes {
            match outcome {
                RequestOutcome::Served(resp) => {
                    served += 1;
                    let latency = resp.latency_s;
                    overall.record(latency);
                    overall_slo.record(latency);
                    trial_hist.record(latency);
                    let lane = lanes
                        .entry(resp.backend.clone())
                        .or_insert_with(|| LaneAgg::new(trace.slo_s));
                    lane.hist.record(latency);
                    lane.slo.record(latency);
                    match resp.deadline_met {
                        Some(true) => lane.deadline_met += 1,
                        Some(false) => lane.served_late += 1,
                        None => {}
                    }
                    let per_image =
                        resp.device_time_s / n_images.max(1) as f64;
                    lane.dev_per_image
                        .entry((network, resp.batch_size))
                        .or_default()
                        .push(per_image);
                    lane.dev_all.push(per_image);
                }
                RequestOutcome::Shed { .. } => trial_shed += 1,
                RequestOutcome::Rejected { .. } => trial_rejected += 1,
                RequestOutcome::Lost => lost += 1,
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        walls.push(wall);

        let report = coord.report_for_wall(wall);
        if trial + 1 == opts.trials {
            if let Some(path) = &opts.drift_csv {
                std::fs::write(path, report.drift_csv()).with_context(
                    || format!("writing drift CSV to {}", path.display()),
                )?;
            }
            if let Some(path) = &opts.trace_out {
                // single-site run: the span rings alone carry every
                // sampled lifecycle, no cross-site hops to splice in
                let snapshot = coord.metrics_snapshot();
                std::fs::write(path, chrome_trace(snapshot.span_lanes(), &[]))
                    .with_context(|| {
                        format!("writing Chrome trace to {}", path.display())
                    })?;
            }
        }
        shed += trial_shed;
        rejected += trial_rejected;
        deferred += report.deferred;
        for b in &report.per_backend {
            let lane = lanes
                .entry(b.name.clone())
                .or_insert_with(|| LaneAgg::new(trace.slo_s));
            lane.batches += b.batches;
            lane.images += b.images;
            lane.energy_j += b.energy_j;
            lane.throughput_by_trial.push(b.images_per_s);
        }
        trial_lines.push(format!(
            "trial {trial}: {} requests  wall {:.3} s  {:.1} img/s  \
             p99 {:.2} ms  shed {trial_shed}  rejected {trial_rejected}",
            trace.events.len(),
            wall,
            report.images_per_s,
            trial_hist.quantile(99.0) * 1e3,
        ));
    }

    let lane_verdicts: Vec<LaneVerdict> = lanes
        .iter()
        .map(|(name, l)| LaneVerdict {
            name: name.clone(),
            batches: l.batches,
            images: l.images,
            energy_j: l.energy_j,
            latency: quantiles(&l.hist),
            slo_attainment: l.slo.attainment(),
            deadline_met: l.deadline_met,
            served_late: l.served_late,
            mean_device_per_image_s: l.dev_all.mean(),
            latency_cv: weighted_cv(l.dev_per_image.values()),
            throughput: variation_of(&l.throughput_by_trial, trace.seed),
        })
        .collect();

    // the paper's comparison: first FPGA-sim lane vs first GPU-model
    // lane, both with enough batches for a CV to mean anything
    let find = |prefix: &str| {
        lane_verdicts
            .iter()
            .find(|l| l.name.starts_with(prefix) && l.batches >= 2)
    };
    let verdict = match (find("fpga"), find("gpu")) {
        (Some(f), Some(g)) => Some(VariationVerdict {
            fpga_lane: f.name.clone(),
            fpga_cv: f.latency_cv,
            gpu_lane: g.name.clone(),
            gpu_cv: g.latency_cv,
            fpga_wins: f.latency_cv < g.latency_cv,
        }),
        _ => None,
    };
    // the same comparison on the deadline axis, when deadlines flowed
    let with_deadlines = |l: &&LaneVerdict| l.deadline_met + l.served_late > 0;
    let deadline_verdict = match (
        find("fpga").filter(with_deadlines),
        find("gpu").filter(with_deadlines),
    ) {
        (Some(f), Some(g)) => Some(DeadlineVerdict {
            fpga_lane: f.name.clone(),
            fpga_attainment: f.deadline_attainment(),
            gpu_lane: g.name.clone(),
            gpu_attainment: g.deadline_attainment(),
            fpga_wins: f.deadline_attainment() >= g.deadline_attainment(),
        }),
        _ => None,
    };

    let served_late: u64 = lane_verdicts.iter().map(|l| l.served_late).sum();
    Ok(LoadtestReport {
        scenario: trace.scenario.clone(),
        trials: opts.trials,
        requests_per_trial: trace.events.len(),
        total_requests: (trace.events.len() * opts.trials) as u64,
        closed: opts.closed,
        served,
        shed,
        served_late,
        rejected,
        lost,
        deferred,
        slo_s: trace.slo_s,
        latency: quantiles(&overall),
        slo_attainment: overall_slo.attainment(),
        mean_wall_s: walls.iter().sum::<f64>() / walls.len() as f64,
        lanes: lane_verdicts,
        verdict,
        deadline_verdict,
        trial_lines,
    })
}

impl LoadtestReport {
    /// Render the verdict table.  Lane rows are stable `key value`
    /// pairs (the CI smoke job parses them).
    pub fn render(&self) -> String {
        let mode = if self.closed > 0 {
            format!("closed loop × {} clients", self.closed)
        } else {
            "open loop".to_string()
        };
        let mut out = format!(
            "== loadtest: scenario {}  ({} trials × {} requests, {mode}, \
             SLO {:.0} ms) ==\n",
            self.scenario,
            self.trials,
            self.requests_per_trial,
            self.slo_s * 1e3,
        );
        for line in &self.trial_lines {
            out.push_str(line);
            out.push('\n');
        }
        out.push_str(&format!(
            "overall: p50 {:.2}  p95 {:.2}  p99 {:.2}  p99.9 {:.2} ms  \
             (coordinated-omission corrected)  slo {:.1}%  shed {}  \
             served_late {}  rejected {}  deferred {}\n",
            self.latency.p50_s * 1e3,
            self.latency.p95_s * 1e3,
            self.latency.p99_s * 1e3,
            self.latency.p999_s * 1e3,
            self.slo_attainment * 100.0,
            self.shed,
            self.served_late,
            self.rejected,
            self.deferred,
        ));
        // the lifecycle must close: every submitted request is exactly
        // one of served / shed / rejected / lost (CI asserts this)
        out.push_str(&format!(
            "accounting: submitted {} served {} shed {} rejected {} lost {}\n",
            self.total_requests, self.served, self.shed, self.rejected,
            self.lost,
        ));
        if self.lost > 0 {
            out.push_str(&format!(
                "WARNING: {} request(s) lost to backend execution failures \
                 (not load shedding) — results are incomplete\n",
                self.lost,
            ));
        }
        for l in &self.lanes {
            out.push_str(&format!(
                "lane {} batches {} images {} p50_ms {:.3} p95_ms {:.3} \
                 p99_ms {:.3} p999_ms {:.3} cv_pct {:.3} slo_pct {:.1} \
                 att_pct {:.1} late {} dev_ms_img {:.3} img_s {:.1} \
                 ci95 {:.1}-{:.1} energy_j {:.3}\n",
                l.name,
                l.batches,
                l.images,
                l.latency.p50_s * 1e3,
                l.latency.p95_s * 1e3,
                l.latency.p99_s * 1e3,
                l.latency.p999_s * 1e3,
                l.latency_cv * 100.0,
                l.slo_attainment * 100.0,
                l.deadline_attainment() * 100.0,
                l.served_late,
                l.mean_device_per_image_s * 1e3,
                l.throughput.mean,
                l.throughput.ci_lo,
                l.throughput.ci_hi,
                l.energy_j,
            ));
        }
        match &self.verdict {
            Some(v) if v.fpga_wins => out.push_str(&format!(
                "verdict: device-latency variation {} cv {:.2}% < {} cv \
                 {:.2}% — the FPGA lane is the stable one (paper Table II)\n",
                v.fpga_lane,
                v.fpga_cv * 100.0,
                v.gpu_lane,
                v.gpu_cv * 100.0,
            )),
            Some(v) => out.push_str(&format!(
                "verdict: NOT reproduced — {} cv {:.2}% vs {} cv {:.2}%\n",
                v.fpga_lane,
                v.fpga_cv * 100.0,
                v.gpu_lane,
                v.gpu_cv * 100.0,
            )),
            None => out.push_str(
                "verdict: n/a (needs both an fpga and a gpu lane with work)\n",
            ),
        }
        match &self.deadline_verdict {
            Some(d) if d.fpga_wins => out.push_str(&format!(
                "deadline verdict: {} att {:.1}% >= {} att {:.1}% at equal \
                 deadlines — predictability pays as attainment\n",
                d.fpga_lane,
                d.fpga_attainment * 100.0,
                d.gpu_lane,
                d.gpu_attainment * 100.0,
            )),
            Some(d) => out.push_str(&format!(
                "deadline verdict: NOT reproduced — {} att {:.1}% < {} att \
                 {:.1}%\n",
                d.fpga_lane,
                d.fpga_attainment * 100.0,
                d.gpu_lane,
                d.gpu_attainment * 100.0,
            )),
            None => out.push_str(
                "deadline verdict: n/a (needs deadline-bearing traffic on \
                 both an fpga and a gpu lane)\n",
            ),
        }
        out
    }
}
