//! Open-loop arrival processes — the request clock of a scenario.
//!
//! All four processes are driven by one seeded [`Rng`], so a scenario
//! is reproducible bit-for-bit: same seed, same arrival timestamps.
//! The non-homogeneous processes (diurnal, flash crowd) are generated
//! by Lewis–Shedler thinning against their peak rate, which keeps the
//! draw count (and therefore determinism) independent of how the rate
//! function is shaped.

use crate::util::Rng;

/// The arrival process of a scenario (rates in requests/second).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalProcess {
    /// Homogeneous Poisson: exponential inter-arrivals at `rate_hz`.
    Poisson { rate_hz: f64 },
    /// Two-state Markov-modulated Poisson process — the bursty edge
    /// workload: exponential dwell in a calm and a burst state, each
    /// with its own Poisson rate.
    Mmpp {
        calm_hz: f64,
        burst_hz: f64,
        /// Mean dwell in the calm state, seconds.
        calm_dwell_s: f64,
        /// Mean dwell in the burst state, seconds.
        burst_dwell_s: f64,
    },
    /// Diurnal ramp: sinusoidal rate between `base_hz` and `peak_hz`
    /// with the given period (a day compressed to seconds).
    Diurnal {
        base_hz: f64,
        peak_hz: f64,
        period_s: f64,
    },
    /// Flash crowd: Poisson at `base_hz` with a `spike_hz` window of
    /// `spike_len_s` starting at `spike_at_s`.
    FlashCrowd {
        base_hz: f64,
        spike_hz: f64,
        spike_at_s: f64,
        spike_len_s: f64,
    },
}

impl ArrivalProcess {
    /// The process's peak instantaneous rate (thinning envelope).
    fn peak_hz(&self) -> f64 {
        match *self {
            ArrivalProcess::Poisson { rate_hz } => rate_hz,
            ArrivalProcess::Mmpp {
                calm_hz, burst_hz, ..
            } => calm_hz.max(burst_hz),
            ArrivalProcess::Diurnal {
                base_hz, peak_hz, ..
            } => base_hz.max(peak_hz),
            ArrivalProcess::FlashCrowd {
                base_hz, spike_hz, ..
            } => base_hz.max(spike_hz),
        }
    }

    /// Instantaneous rate at time `t` (used by the thinning sampler;
    /// the Markov-modulated state is tracked by the sampler, not here).
    fn rate_at(&self, t_s: f64) -> f64 {
        match *self {
            ArrivalProcess::Poisson { rate_hz } => rate_hz,
            ArrivalProcess::Mmpp { .. } => unreachable!("MMPP is stateful"),
            ArrivalProcess::Diurnal {
                base_hz,
                peak_hz,
                period_s,
            } => {
                let phase = 2.0 * std::f64::consts::PI * t_s / period_s;
                base_hz + (peak_hz - base_hz) * 0.5 * (1.0 - phase.cos())
            }
            ArrivalProcess::FlashCrowd {
                base_hz,
                spike_hz,
                spike_at_s,
                spike_len_s,
            } => {
                if (spike_at_s..spike_at_s + spike_len_s).contains(&t_s) {
                    spike_hz
                } else {
                    base_hz
                }
            }
        }
    }

    fn validate(&self) -> anyhow::Result<()> {
        let positive = |v: f64, what: &str| {
            anyhow::ensure!(
                v > 0.0 && v.is_finite(),
                "{what} must be positive and finite, got {v}"
            );
            Ok(())
        };
        match *self {
            ArrivalProcess::Poisson { rate_hz } => positive(rate_hz, "rate_hz"),
            ArrivalProcess::Mmpp {
                calm_hz,
                burst_hz,
                calm_dwell_s,
                burst_dwell_s,
            } => {
                positive(calm_hz, "calm_hz")?;
                positive(burst_hz, "burst_hz")?;
                positive(calm_dwell_s, "calm_dwell_s")?;
                positive(burst_dwell_s, "burst_dwell_s")
            }
            ArrivalProcess::Diurnal {
                base_hz,
                peak_hz,
                period_s,
            } => {
                positive(base_hz, "base_hz")?;
                positive(peak_hz, "peak_hz")?;
                positive(period_s, "period_s")
            }
            ArrivalProcess::FlashCrowd {
                base_hz,
                spike_hz,
                spike_at_s,
                spike_len_s,
            } => {
                positive(base_hz, "base_hz")?;
                positive(spike_hz, "spike_hz")?;
                anyhow::ensure!(spike_at_s >= 0.0, "spike_at_s must be >= 0");
                positive(spike_len_s, "spike_len_s")
            }
        }
    }

    /// A stateful sampler starting at `t = 0` (checks parameters once).
    pub fn sampler(self) -> anyhow::Result<ArrivalSampler> {
        self.validate()?;
        Ok(ArrivalSampler {
            process: self,
            t_s: 0.0,
            mmpp_burst: false,
            mmpp_switch_at: f64::NAN,
        })
    }
}

/// Draw from Exp(rate) — inter-arrival of a Poisson stream.
fn exp_gap(rng: &mut Rng, rate_hz: f64) -> f64 {
    // 1 - u in (0, 1]: ln never sees zero
    -(1.0 - rng.next_f64()).ln() / rate_hz
}

/// Stateful arrival-timestamp generator for one [`ArrivalProcess`].
#[derive(Debug, Clone)]
pub struct ArrivalSampler {
    process: ArrivalProcess,
    t_s: f64,
    mmpp_burst: bool,
    /// Absolute time of the next MMPP state flip (NaN until first use).
    mmpp_switch_at: f64,
}

impl ArrivalSampler {
    /// Absolute timestamp (seconds from scenario start) of the next
    /// arrival.  Successive calls are strictly non-decreasing.
    pub fn next_arrival(&mut self, rng: &mut Rng) -> f64 {
        match self.process {
            ArrivalProcess::Poisson { rate_hz } => {
                self.t_s += exp_gap(rng, rate_hz);
                self.t_s
            }
            ArrivalProcess::Mmpp {
                calm_hz,
                burst_hz,
                calm_dwell_s,
                burst_dwell_s,
            } => {
                if self.mmpp_switch_at.is_nan() {
                    self.mmpp_switch_at = exp_gap(rng, 1.0 / calm_dwell_s);
                }
                loop {
                    let rate = if self.mmpp_burst { burst_hz } else { calm_hz };
                    let candidate = self.t_s + exp_gap(rng, rate);
                    if candidate < self.mmpp_switch_at {
                        self.t_s = candidate;
                        return self.t_s;
                    }
                    // memoryless: discard the draw past the flip, switch
                    // state and re-draw from the flip time
                    self.t_s = self.mmpp_switch_at;
                    self.mmpp_burst = !self.mmpp_burst;
                    let dwell = if self.mmpp_burst {
                        burst_dwell_s
                    } else {
                        calm_dwell_s
                    };
                    self.mmpp_switch_at = self.t_s + exp_gap(rng, 1.0 / dwell);
                }
            }
            // non-homogeneous: thin a peak-rate Poisson stream
            ArrivalProcess::Diurnal { .. }
            | ArrivalProcess::FlashCrowd { .. } => {
                let peak = self.process.peak_hz();
                loop {
                    self.t_s += exp_gap(rng, peak);
                    if rng.next_f64() * peak <= self.process.rate_at(self.t_s) {
                        return self.t_s;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arrivals(p: ArrivalProcess, n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::seed_from_u64(seed);
        let mut s = p.sampler().unwrap();
        (0..n).map(|_| s.next_arrival(&mut rng)).collect()
    }

    #[test]
    fn deterministic_and_monotone_given_seed() {
        for p in [
            ArrivalProcess::Poisson { rate_hz: 200.0 },
            ArrivalProcess::Mmpp {
                calm_hz: 100.0,
                burst_hz: 1500.0,
                calm_dwell_s: 0.05,
                burst_dwell_s: 0.02,
            },
            ArrivalProcess::Diurnal {
                base_hz: 50.0,
                peak_hz: 400.0,
                period_s: 1.0,
            },
            ArrivalProcess::FlashCrowd {
                base_hz: 100.0,
                spike_hz: 2000.0,
                spike_at_s: 0.1,
                spike_len_s: 0.1,
            },
        ] {
            let a = arrivals(p, 300, 42);
            let b = arrivals(p, 300, 42);
            assert_eq!(a, b, "{p:?} must be seed-deterministic");
            assert!(
                a.windows(2).all(|w| w[1] >= w[0]),
                "{p:?} timestamps must be non-decreasing"
            );
            assert_ne!(a, arrivals(p, 300, 43), "{p:?} seeds must matter");
        }
    }

    #[test]
    fn poisson_hits_its_mean_rate() {
        let n = 4000;
        let a = arrivals(ArrivalProcess::Poisson { rate_hz: 500.0 }, n, 7);
        let measured = n as f64 / a.last().unwrap();
        assert!(
            (measured / 500.0 - 1.0).abs() < 0.08,
            "measured {measured} Hz"
        );
    }

    #[test]
    fn mmpp_is_burstier_than_poisson() {
        // squared CV of inter-arrivals: 1 for Poisson, > 1 for MMPP
        let cv2 = |ts: &[f64]| {
            let gaps: Vec<f64> =
                ts.windows(2).map(|w| w[1] - w[0]).collect();
            let s = crate::stats::Summary::of(&gaps);
            (s.std / s.mean).powi(2)
        };
        let p = arrivals(ArrivalProcess::Poisson { rate_hz: 300.0 }, 3000, 11);
        let m = arrivals(
            ArrivalProcess::Mmpp {
                calm_hz: 60.0,
                burst_hz: 3000.0,
                calm_dwell_s: 0.05,
                burst_dwell_s: 0.02,
            },
            3000,
            11,
        );
        assert!(cv2(&m) > 1.5 * cv2(&p), "mmpp {} poisson {}", cv2(&m), cv2(&p));
    }

    #[test]
    fn flash_crowd_concentrates_in_the_spike() {
        let p = ArrivalProcess::FlashCrowd {
            base_hz: 50.0,
            spike_hz: 5000.0,
            spike_at_s: 0.2,
            spike_len_s: 0.1,
        };
        let a = arrivals(p, 800, 3);
        let in_spike =
            a.iter().filter(|t| (0.2..0.3).contains(*t)).count();
        assert!(
            in_spike > a.len() / 2,
            "spike window must dominate: {in_spike}/{}",
            a.len()
        );
    }

    #[test]
    fn diurnal_peak_beats_trough() {
        let p = ArrivalProcess::Diurnal {
            base_hz: 20.0,
            peak_hz: 2000.0,
            period_s: 1.0,
        };
        let a = arrivals(p, 2000, 5);
        // trough at t≈0/1, peak at t≈0.5
        let near_peak = a
            .iter()
            .filter(|t| (0.35..0.65).contains(&(*t % 1.0)))
            .count();
        let near_trough = a
            .iter()
            .filter(|t| {
                let ph = *t % 1.0;
                !(0.15..0.85).contains(&ph)
            })
            .count();
        assert!(near_peak > 3 * near_trough.max(1), "{near_peak} vs {near_trough}");
    }

    #[test]
    fn bad_parameters_are_rejected() {
        assert!(ArrivalProcess::Poisson { rate_hz: 0.0 }.sampler().is_err());
        assert!(ArrivalProcess::Mmpp {
            calm_hz: 10.0,
            burst_hz: -1.0,
            calm_dwell_s: 0.1,
            burst_dwell_s: 0.1
        }
        .sampler()
        .is_err());
        assert!(ArrivalProcess::FlashCrowd {
            base_hz: 10.0,
            spike_hz: 100.0,
            spike_at_s: -0.5,
            spike_len_s: 0.1
        }
        .sampler()
        .is_err());
    }
}
