//! Traces — a scenario *materialized*: the exact arrival timestamps,
//! network/image mix and per-request latent seeds, recordable to JSON
//! and replayable bit-for-bit.  Generation is a pure function of the
//! scenario (one SplitMix64 stream drives arrivals, mix draws and
//! latent seeds in a fixed order), so the same seed + scenario always
//! yields the identical trace — and a recorded file replays the same
//! run on another machine.

use super::scenario::Scenario;
use crate::coordinator::PriorityClass;
use crate::util::{escape_json, parse_json, Rng};
use anyhow::{Context, Result};
use std::path::Path;

/// Trace schema version written by [`Trace::to_json`].  v1 (PR 4) had
/// no deadline/priority fields; v1 files still load (as best-effort,
/// all-Normal traffic).
const TRACE_VERSION: u64 = 2;

/// One scheduled request.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    /// Scheduled arrival, seconds from trace start.
    pub t_s: f64,
    pub network: String,
    pub n_images: usize,
    /// Latent seed the request carries (deterministic generation).
    pub seed: u64,
    /// Priority class (v2; v1 traces read back as Normal).
    pub class: PriorityClass,
    /// Relative deadline, seconds from the scheduled arrival (v2;
    /// `None` = best-effort, and what v1 traces read back as).
    pub deadline_s: Option<f64>,
}

/// A materialized scenario.
#[derive(Debug, Clone, PartialEq)]
pub struct Trace {
    pub scenario: String,
    pub seed: u64,
    pub slo_s: f64,
    pub events: Vec<TraceEvent>,
}

impl Trace {
    /// Materialize a scenario (deterministic: arrivals, mix draws and
    /// latent seeds all come from one seeded stream, in event order).
    pub fn generate(s: &Scenario) -> Result<Trace> {
        anyhow::ensure!(!s.mix.is_empty(), "scenario mix is empty");
        let mut rng = Rng::seed_from_u64(s.seed);
        let mut sampler = s.arrival.sampler()?;
        let total_weight: f64 = s.mix.iter().map(|e| e.weight).sum();
        let mut events = Vec::with_capacity(s.requests);
        for _ in 0..s.requests {
            let t_s = sampler.next_arrival(&mut rng);
            let mut pick = rng.next_f64() * total_weight;
            let mut chosen = s.mix.last().expect("mix checked non-empty");
            for e in &s.mix {
                if pick < e.weight {
                    chosen = e;
                    break;
                }
                pick -= e.weight;
            }
            events.push(TraceEvent {
                t_s,
                network: chosen.network.clone(),
                n_images: chosen.images,
                // 53 bits: JSON numbers are f64, and a latent seed must
                // survive record → replay *exactly*
                seed: rng.next_u64() >> 11,
                class: chosen.class,
                deadline_s: chosen.deadline_s.or(s.deadline_s),
            });
        }
        Ok(Trace {
            scenario: s.name.clone(),
            seed: s.seed,
            slo_s: s.slo_s,
            events,
        })
    }

    /// Scheduled duration (timestamp of the last event).
    pub fn duration_s(&self) -> f64 {
        self.events.last().map(|e| e.t_s).unwrap_or(0.0)
    }

    /// Base (f32) networks the trace touches, deduplicated, plus which
    /// `.q` / `.q8` precision twins the events target — what a
    /// coordinator must preload (and which quantized twins to enable)
    /// to serve this trace.
    pub fn networks(&self) -> (Vec<String>, super::scenario::TwinMix) {
        super::scenario::base_networks(
            self.events.iter().map(|e| e.network.as_str()),
        )
    }

    /// Serialize (schema v2).  f64 timestamps and deadlines print
    /// shortest-roundtrip, so record → replay reproduces the schedule
    /// — including the new deadline/priority fields — *bit-exactly*.
    pub fn to_json(&self) -> String {
        let mut out = format!(
            "{{\n  \"version\": {},\n  \"scenario\": \"{}\",\n  \
             \"seed\": {},\n  \"slo_s\": {},\n  \"events\": [\n",
            TRACE_VERSION,
            escape_json(&self.scenario),
            self.seed,
            self.slo_s
        );
        for (i, e) in self.events.iter().enumerate() {
            let deadline = e
                .deadline_s
                .map(|d| format!(", \"deadline_s\": {d}"))
                .unwrap_or_default();
            out.push_str(&format!(
                "    {{\"t_s\": {}, \"network\": \"{}\", \"n_images\": {}, \
                 \"seed\": {}, \"class\": \"{}\"{}}}{}\n",
                e.t_s,
                escape_json(&e.network),
                e.n_images,
                e.seed,
                e.class,
                deadline,
                if i + 1 < self.events.len() { "," } else { "" }
            ));
        }
        out.push_str("  ]\n}\n");
        out
    }

    pub fn from_json(text: &str) -> Result<Trace> {
        let v = parse_json(text)?;
        // no "version" field = a v1 (pre-deadline) trace: it loads as
        // best-effort all-Normal traffic, the exact semantics it was
        // recorded under
        let version = match v.get("version") {
            Some(ver) => ver.as_u64()?,
            None => 1,
        };
        anyhow::ensure!(
            version <= TRACE_VERSION,
            "trace schema v{version} is newer than this build (v{TRACE_VERSION})"
        );
        let events = v
            .req("events")?
            .as_arr()?
            .iter()
            .map(|e| {
                Ok(TraceEvent {
                    t_s: e.req("t_s")?.as_f64()?,
                    network: e.req("network")?.as_str()?.to_string(),
                    n_images: e.req("n_images")?.as_usize()?,
                    seed: e.req("seed")?.as_u64()?,
                    class: match e.get("class") {
                        Some(c) => c.as_str()?.parse()?,
                        None => PriorityClass::Normal,
                    },
                    deadline_s: match e.get("deadline_s") {
                        Some(d) => Some(d.as_f64()?),
                        None => None,
                    },
                })
            })
            .collect::<Result<Vec<_>>>()?;
        anyhow::ensure!(!events.is_empty(), "trace has no events");
        anyhow::ensure!(
            events.windows(2).all(|w| w[1].t_s >= w[0].t_s),
            "trace timestamps must be non-decreasing"
        );
        Ok(Trace {
            scenario: v.req("scenario")?.as_str()?.to_string(),
            seed: v.req("seed")?.as_u64()?,
            slo_s: v.req("slo_s")?.as_f64()?,
            events,
        })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json())
            .with_context(|| format!("writing trace {path:?}"))
    }

    pub fn load(path: &Path) -> Result<Trace> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading trace {path:?}"))?;
        Trace::from_json(&text)
            .with_context(|| format!("parsing trace {path:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::TempDir;

    #[test]
    fn generation_is_deterministic_per_seed() {
        let s = Scenario::builtin("burst").unwrap();
        let a = Trace::generate(&s).unwrap();
        let b = Trace::generate(&s).unwrap();
        assert_eq!(a, b, "same seed + scenario ⇒ identical trace");
        let mut reseeded = s.clone();
        reseeded.seed ^= 1;
        let c = Trace::generate(&reseeded).unwrap();
        assert_ne!(a.events, c.events);
        assert_eq!(a.events.len(), s.requests);
    }

    #[test]
    fn mix_weights_shape_the_draw() {
        let mut s = Scenario::builtin("steady").unwrap();
        s.requests = 600;
        let t = Trace::generate(&s).unwrap();
        let quant = t
            .events
            .iter()
            .filter(|e| e.network.ends_with(".q"))
            .count();
        // builtin mix is 65/35: the .q share must land near 35%
        let share = quant as f64 / t.events.len() as f64;
        assert!((share - 0.35).abs() < 0.08, "share {share}");
        // latent seeds are unique (one stream, no reuse)
        let mut seeds: Vec<u64> = t.events.iter().map(|e| e.seed).collect();
        seeds.sort_unstable();
        seeds.dedup();
        assert_eq!(seeds.len(), t.events.len());
    }

    #[test]
    fn record_replay_roundtrips_exactly() {
        let s = Scenario::builtin("flash").unwrap();
        let t = Trace::generate(&s).unwrap();
        let replayed = Trace::from_json(&t.to_json()).unwrap();
        assert_eq!(
            replayed, t,
            "timestamps and mix must survive the JSON roundtrip bit-for-bit"
        );
        let dir = TempDir::new().unwrap();
        let path = dir.path().join("trace.json");
        t.save(&path).unwrap();
        assert_eq!(Trace::load(&path).unwrap(), t);
    }

    #[test]
    fn v2_deadline_and_class_fields_roundtrip_bit_exactly() {
        let mut s = Scenario::builtin("burst").unwrap();
        s.requests = 24;
        // awkward (non-representable-in-decimal) deadline: the
        // shortest-roundtrip printer must still reproduce it exactly
        s.deadline_s = Some(0.1 + 1e-17 + std::f64::consts::PI / 62.0);
        s.mix[0].deadline_s = Some(0.012345678901234567);
        let t = Trace::generate(&s).unwrap();
        assert!(t.events.iter().all(|e| e.deadline_s.is_some()));
        assert!(t
            .events
            .iter()
            .any(|e| e.class == crate::coordinator::PriorityClass::Low));
        let replayed = Trace::from_json(&t.to_json()).unwrap();
        assert_eq!(replayed, t, "v2 fields must survive bit-for-bit");
        for (a, b) in t.events.iter().zip(&replayed.events) {
            assert_eq!(a.deadline_s.map(f64::to_bits), b.deadline_s.map(f64::to_bits));
            assert_eq!(a.class, b.class);
        }
    }

    #[test]
    fn v1_traces_still_load_as_best_effort() {
        // the exact PR-4 schema: no version, no class, no deadline_s
        let v1 = r#"{"scenario": "legacy", "seed": 7, "slo_s": 0.05,
            "events": [
              {"t_s": 0.001, "network": "mnist", "n_images": 2, "seed": 11},
              {"t_s": 0.002, "network": "mnist.q", "n_images": 2, "seed": 12}
            ]}"#;
        let t = Trace::from_json(v1).unwrap();
        assert_eq!(t.events.len(), 2);
        for e in &t.events {
            assert_eq!(e.class, crate::coordinator::PriorityClass::Normal);
            assert_eq!(e.deadline_s, None, "v1 traffic stays best-effort");
        }
        // re-saving upgrades it to the current schema
        let upgraded = t.to_json();
        assert!(upgraded.contains("\"version\": 2"), "{upgraded}");
        assert_eq!(Trace::from_json(&upgraded).unwrap(), t);
        // a future schema is refused instead of misread
        let v9 = v1.replacen("{\"scenario\"", "{\"version\": 9, \"scenario\"", 1);
        assert!(Trace::from_json(&v9).is_err());
    }

    #[test]
    fn malformed_traces_are_rejected() {
        assert!(Trace::from_json("{}").is_err());
        let empty = r#"{"scenario": "x", "seed": 1, "slo_s": 0.1, "events": []}"#;
        assert!(Trace::from_json(empty).is_err());
        let unsorted = r#"{"scenario": "x", "seed": 1, "slo_s": 0.1, "events": [
            {"t_s": 0.5, "network": "mnist", "n_images": 1, "seed": 1},
            {"t_s": 0.1, "network": "mnist", "n_images": 1, "seed": 2}]}"#;
        assert!(Trace::from_json(unsorted).is_err());
    }
}
