//! Traces — a scenario *materialized*: the exact arrival timestamps,
//! network/image mix and per-request latent seeds, recordable to JSON
//! and replayable bit-for-bit.  Generation is a pure function of the
//! scenario (one SplitMix64 stream drives arrivals, mix draws and
//! latent seeds in a fixed order), so the same seed + scenario always
//! yields the identical trace — and a recorded file replays the same
//! run on another machine.

use super::scenario::Scenario;
use crate::util::{escape_json, parse_json, Rng};
use anyhow::{Context, Result};
use std::path::Path;

/// One scheduled request.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    /// Scheduled arrival, seconds from trace start.
    pub t_s: f64,
    pub network: String,
    pub n_images: usize,
    /// Latent seed the request carries (deterministic generation).
    pub seed: u64,
}

/// A materialized scenario.
#[derive(Debug, Clone, PartialEq)]
pub struct Trace {
    pub scenario: String,
    pub seed: u64,
    pub slo_s: f64,
    pub events: Vec<TraceEvent>,
}

impl Trace {
    /// Materialize a scenario (deterministic: arrivals, mix draws and
    /// latent seeds all come from one seeded stream, in event order).
    pub fn generate(s: &Scenario) -> Result<Trace> {
        anyhow::ensure!(!s.mix.is_empty(), "scenario mix is empty");
        let mut rng = Rng::seed_from_u64(s.seed);
        let mut sampler = s.arrival.sampler()?;
        let total_weight: f64 = s.mix.iter().map(|e| e.weight).sum();
        let mut events = Vec::with_capacity(s.requests);
        for _ in 0..s.requests {
            let t_s = sampler.next_arrival(&mut rng);
            let mut pick = rng.next_f64() * total_weight;
            let mut chosen = s.mix.last().expect("mix checked non-empty");
            for e in &s.mix {
                if pick < e.weight {
                    chosen = e;
                    break;
                }
                pick -= e.weight;
            }
            events.push(TraceEvent {
                t_s,
                network: chosen.network.clone(),
                n_images: chosen.images,
                // 53 bits: JSON numbers are f64, and a latent seed must
                // survive record → replay *exactly*
                seed: rng.next_u64() >> 11,
            });
        }
        Ok(Trace {
            scenario: s.name.clone(),
            seed: s.seed,
            slo_s: s.slo_s,
            events,
        })
    }

    /// Scheduled duration (timestamp of the last event).
    pub fn duration_s(&self) -> f64 {
        self.events.last().map(|e| e.t_s).unwrap_or(0.0)
    }

    /// Base (f32) networks the trace touches, deduplicated, plus
    /// whether any event targets a `.q` precision twin — what a
    /// coordinator must preload (and whether with quantized twins) to
    /// serve this trace.
    pub fn networks(&self) -> (Vec<String>, bool) {
        super::scenario::base_networks(
            self.events.iter().map(|e| e.network.as_str()),
        )
    }

    /// Serialize.  f64 timestamps print shortest-roundtrip, so
    /// record → replay reproduces the arrival schedule *exactly*.
    pub fn to_json(&self) -> String {
        let mut out = format!(
            "{{\n  \"scenario\": \"{}\",\n  \"seed\": {},\n  \"slo_s\": {},\n  \
             \"events\": [\n",
            escape_json(&self.scenario),
            self.seed,
            self.slo_s
        );
        for (i, e) in self.events.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"t_s\": {}, \"network\": \"{}\", \"n_images\": {}, \
                 \"seed\": {}}}{}\n",
                e.t_s,
                escape_json(&e.network),
                e.n_images,
                e.seed,
                if i + 1 < self.events.len() { "," } else { "" }
            ));
        }
        out.push_str("  ]\n}\n");
        out
    }

    pub fn from_json(text: &str) -> Result<Trace> {
        let v = parse_json(text)?;
        let events = v
            .req("events")?
            .as_arr()?
            .iter()
            .map(|e| {
                Ok(TraceEvent {
                    t_s: e.req("t_s")?.as_f64()?,
                    network: e.req("network")?.as_str()?.to_string(),
                    n_images: e.req("n_images")?.as_usize()?,
                    seed: e.req("seed")?.as_u64()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        anyhow::ensure!(!events.is_empty(), "trace has no events");
        anyhow::ensure!(
            events.windows(2).all(|w| w[1].t_s >= w[0].t_s),
            "trace timestamps must be non-decreasing"
        );
        Ok(Trace {
            scenario: v.req("scenario")?.as_str()?.to_string(),
            seed: v.req("seed")?.as_u64()?,
            slo_s: v.req("slo_s")?.as_f64()?,
            events,
        })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json())
            .with_context(|| format!("writing trace {path:?}"))
    }

    pub fn load(path: &Path) -> Result<Trace> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading trace {path:?}"))?;
        Trace::from_json(&text)
            .with_context(|| format!("parsing trace {path:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::TempDir;

    #[test]
    fn generation_is_deterministic_per_seed() {
        let s = Scenario::builtin("burst").unwrap();
        let a = Trace::generate(&s).unwrap();
        let b = Trace::generate(&s).unwrap();
        assert_eq!(a, b, "same seed + scenario ⇒ identical trace");
        let mut reseeded = s.clone();
        reseeded.seed ^= 1;
        let c = Trace::generate(&reseeded).unwrap();
        assert_ne!(a.events, c.events);
        assert_eq!(a.events.len(), s.requests);
    }

    #[test]
    fn mix_weights_shape_the_draw() {
        let mut s = Scenario::builtin("steady").unwrap();
        s.requests = 600;
        let t = Trace::generate(&s).unwrap();
        let quant = t
            .events
            .iter()
            .filter(|e| e.network.ends_with(".q"))
            .count();
        // builtin mix is 65/35: the .q share must land near 35%
        let share = quant as f64 / t.events.len() as f64;
        assert!((share - 0.35).abs() < 0.08, "share {share}");
        // latent seeds are unique (one stream, no reuse)
        let mut seeds: Vec<u64> = t.events.iter().map(|e| e.seed).collect();
        seeds.sort_unstable();
        seeds.dedup();
        assert_eq!(seeds.len(), t.events.len());
    }

    #[test]
    fn record_replay_roundtrips_exactly() {
        let s = Scenario::builtin("flash").unwrap();
        let t = Trace::generate(&s).unwrap();
        let replayed = Trace::from_json(&t.to_json()).unwrap();
        assert_eq!(
            replayed, t,
            "timestamps and mix must survive the JSON roundtrip bit-for-bit"
        );
        let dir = TempDir::new().unwrap();
        let path = dir.path().join("trace.json");
        t.save(&path).unwrap();
        assert_eq!(Trace::load(&path).unwrap(), t);
    }

    #[test]
    fn malformed_traces_are_rejected() {
        assert!(Trace::from_json("{}").is_err());
        let empty = r#"{"scenario": "x", "seed": 1, "slo_s": 0.1, "events": []}"#;
        assert!(Trace::from_json(empty).is_err());
        let unsorted = r#"{"scenario": "x", "seed": 1, "slo_s": 0.1, "events": [
            {"t_s": 0.5, "network": "mnist", "n_images": 1, "seed": 1},
            {"t_s": 0.1, "network": "mnist", "n_images": 1, "seed": 2}]}"#;
        assert!(Trace::from_json(unsorted).is_err());
    }
}
