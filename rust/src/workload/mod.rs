//! Workload subsystem — scenario-driven open-loop load generation for
//! the serving coordinator (see DESIGN.md §Workload):
//!
//! * [`ArrivalProcess`] — seeded arrival clocks: deterministic Poisson,
//!   two-state MMPP (bursty), diurnal ramp and flash crowd.
//! * [`Scenario`] — a named, seeded traffic description: arrival
//!   process + request mix over logical networks (including precision
//!   twins like `mnist` vs `mnist.q`) + request budget + SLO; four
//!   built-ins (`steady`, `burst`, `diurnal`, `flash`) or a JSON file.
//! * [`Trace`] — a scenario materialized to exact timestamps/mix/seeds
//!   plus per-event priority classes and relative deadlines (schema v2;
//!   v1 traces still load as best-effort traffic), recordable and
//!   replayable bit-for-bit (a workload is a shareable artifact).
//! * [`loadtest`] — drives a trace against the backend pool (open loop
//!   at the scheduled arrivals, or closed loop with think time), every
//!   request carrying its deadline/class through a
//!   [`RequestCtx`](crate::coordinator::RequestCtx); repeats it over
//!   seeded trials and renders the paper's Table-2-style FPGA-vs-GPU
//!   run-to-run-variation verdict — plus its deadline restatement
//!   (attainment with the shed / served-late split) — from live
//!   serving telemetry.

mod arrival;
pub mod loadtest;
mod scenario;
mod trace;

pub use arrival::{ArrivalProcess, ArrivalSampler};
pub use loadtest::{
    run_loadtest, DeadlineVerdict, LaneVerdict, LoadtestOpts, LoadtestReport,
    VariationVerdict,
};
pub use scenario::{MixEntry, Scenario, TwinMix};
pub use trace::{Trace, TraceEvent};

use crate::config::TrafficCfg;
use anyhow::Result;

/// Materialize the trace a [`TrafficCfg`] names — the one place the
/// serve/loadtest/fleet subcommands turn shared traffic flags into
/// traffic: `replay` wins over `scenario`, then the explicit
/// seed/requests/deadline overrides apply before generation.  `smoke`
/// shrinks the default request budget for CI when the caller didn't pin
/// one.
pub fn resolve_trace(traffic: &TrafficCfg, smoke: bool) -> Result<Trace> {
    if let Some(path) = &traffic.replay {
        return Trace::load(path);
    }
    let mut scenario = Scenario::resolve(&traffic.scenario)?;
    if let Some(seed) = traffic.seed {
        scenario.seed = seed;
    }
    scenario.requests = match traffic.requests {
        Some(n) => n,
        None if smoke => 24,
        None => scenario.requests,
    };
    if traffic.deadline_s.is_some() {
        scenario.deadline_s = traffic.deadline_s;
    }
    Trace::generate(&scenario)
}
