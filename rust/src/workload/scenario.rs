//! Scenarios — a named, seeded description of open-loop traffic: an
//! arrival process, a request mix over logical networks (including
//! precision twins like `mnist` vs `mnist.q`), a request budget and an
//! SLO.  Four built-ins cover the shapes the paper's edge setting
//! cares about (`steady`, `burst`, `diurnal`, `flash`); arbitrary
//! scenarios load from a JSON file, so a workload is a shareable,
//! versionable artifact rather than a flag soup.

use super::arrival::ArrivalProcess;
use crate::coordinator::PriorityClass;
use crate::util::{escape_json, parse_json, Json};
use anyhow::{bail, Context, Result};

/// One entry of a scenario's request mix.
#[derive(Debug, Clone, PartialEq)]
pub struct MixEntry {
    /// Logical network name (`mnist`, `mnist.q`, `celeba`, …).
    pub network: String,
    /// Images per request drawn from this entry.
    pub images: usize,
    /// Relative draw weight (need not sum to 1).
    pub weight: f64,
    /// Priority class requests drawn from this entry carry.
    pub class: PriorityClass,
    /// Relative deadline override for this entry (seconds from the
    /// scheduled arrival); `None` inherits [`Scenario::deadline_s`].
    pub deadline_s: Option<f64>,
}

/// A complete traffic scenario.
#[derive(Debug, Clone, PartialEq)]
pub struct Scenario {
    pub name: String,
    pub arrival: ArrivalProcess,
    pub mix: Vec<MixEntry>,
    /// Total requests the scenario issues.
    pub requests: usize,
    /// Seed for arrivals, mix draws and per-request latents.
    pub seed: u64,
    /// Latency objective for the attainment column.
    pub slo_s: f64,
    /// Default relative deadline every request carries (seconds from
    /// its scheduled arrival); `None` = best-effort traffic.  The
    /// built-ins set it to their SLO, so the serving layer can act on
    /// the target the telemetry previously only measured after the
    /// fact.
    pub deadline_s: Option<f64>,
}

/// The default mix: the f32 network alongside its fixed-point twin —
/// the paper's precision axis as live traffic.  The twin doubles as the
/// low-priority bulk class, so every built-in scenario exercises
/// cross-class shedding.
fn twin_mix() -> Vec<MixEntry> {
    vec![
        MixEntry {
            network: "mnist".into(),
            images: 2,
            weight: 0.65,
            class: PriorityClass::Normal,
            deadline_s: None,
        },
        MixEntry {
            network: "mnist.q".into(),
            images: 2,
            weight: 0.35,
            class: PriorityClass::Low,
            deadline_s: None,
        },
    ]
}

impl Scenario {
    /// The built-in scenario catalogue.
    pub fn builtin(name: &str) -> Result<Scenario> {
        let (arrival, slo_s) = match name {
            "steady" => (ArrivalProcess::Poisson { rate_hz: 250.0 }, 0.050),
            "burst" => (
                ArrivalProcess::Mmpp {
                    calm_hz: 150.0,
                    burst_hz: 1500.0,
                    calm_dwell_s: 0.08,
                    burst_dwell_s: 0.04,
                },
                0.050,
            ),
            "diurnal" => (
                ArrivalProcess::Diurnal {
                    base_hz: 100.0,
                    peak_hz: 600.0,
                    period_s: 1.0,
                },
                0.050,
            ),
            "flash" => (
                ArrivalProcess::FlashCrowd {
                    base_hz: 120.0,
                    spike_hz: 2000.0,
                    spike_at_s: 0.15,
                    spike_len_s: 0.2,
                },
                0.100,
            ),
            other => bail!(
                "unknown scenario {other:?} (steady|burst|diurnal|flash, \
                 or a path to a scenario JSON file)"
            ),
        };
        Ok(Scenario {
            name: name.to_string(),
            arrival,
            mix: twin_mix(),
            requests: 96,
            seed: 42,
            slo_s,
            // the SLO is also the deadline: what telemetry measured
            // after the fact, the scheduler now acts on
            deadline_s: Some(slo_s),
        })
    }

    /// Resolve a CLI argument: a built-in name, or a path to a JSON
    /// scenario file.
    pub fn resolve(arg: &str) -> Result<Scenario> {
        if let Ok(s) = Scenario::builtin(arg) {
            return Ok(s);
        }
        let text = std::fs::read_to_string(arg)
            .with_context(|| format!("reading scenario file {arg:?}"))?;
        Scenario::from_json(&text)
            .with_context(|| format!("parsing scenario file {arg:?}"))
    }

    /// Base (f32) networks the scenario touches, deduplicated, plus
    /// which `.q` / `.q8` precision twins the mix serves (the
    /// coordinator then enables the matching twins at startup).
    pub fn networks(&self) -> (Vec<String>, TwinMix) {
        base_networks(self.mix.iter().map(|e| e.network.as_str()))
    }

    /// Parse the JSON scenario schema (see `Scenario::to_json`).
    /// `class` and the `deadline_s` fields are optional, so pre-deadline
    /// scenario files keep parsing (as all-Normal, best-effort traffic).
    pub fn from_json(text: &str) -> Result<Scenario> {
        let v = parse_json(text)?;
        let arrival = parse_arrival(v.req("arrival")?)?;
        let mix = v
            .req("mix")?
            .as_arr()?
            .iter()
            .map(|e| {
                Ok(MixEntry {
                    network: e.req("network")?.as_str()?.to_string(),
                    images: e.req("images")?.as_usize()?,
                    weight: e.req("weight")?.as_f64()?,
                    class: match e.get("class") {
                        Some(c) => c.as_str()?.parse()?,
                        None => PriorityClass::Normal,
                    },
                    deadline_s: match e.get("deadline_s") {
                        Some(d) => Some(d.as_f64()?),
                        None => None,
                    },
                })
            })
            .collect::<Result<Vec<_>>>()?;
        anyhow::ensure!(!mix.is_empty(), "scenario mix is empty");
        anyhow::ensure!(
            mix.iter().all(|e| e.weight > 0.0 && e.images > 0),
            "mix weights and image counts must be positive"
        );
        let s = Scenario {
            name: v.req("name")?.as_str()?.to_string(),
            arrival,
            mix,
            requests: v.req("requests")?.as_usize()?,
            seed: v.req("seed")?.as_u64()?,
            slo_s: v.req("slo_s")?.as_f64()?,
            deadline_s: match v.get("deadline_s") {
                Some(d) => Some(d.as_f64()?),
                None => None,
            },
        };
        anyhow::ensure!(s.requests > 0, "scenario needs at least one request");
        anyhow::ensure!(
            s.deadline_s.unwrap_or(1.0) > 0.0
                && s.mix.iter().all(|e| e.deadline_s.unwrap_or(1.0) > 0.0),
            "deadlines must be positive"
        );
        s.arrival.sampler()?; // parameter validation
        Ok(s)
    }

    /// Serialize (f64s print shortest-roundtrip, so a written scenario
    /// re-parses to the identical value).
    pub fn to_json(&self) -> String {
        let mix = self
            .mix
            .iter()
            .map(|e| {
                let deadline = e
                    .deadline_s
                    .map(|d| format!(", \"deadline_s\": {d}"))
                    .unwrap_or_default();
                format!(
                    "{{\"network\": \"{}\", \"images\": {}, \"weight\": {}, \
                     \"class\": \"{}\"{}}}",
                    escape_json(&e.network),
                    e.images,
                    e.weight,
                    e.class,
                    deadline
                )
            })
            .collect::<Vec<_>>()
            .join(", ");
        let deadline = self
            .deadline_s
            .map(|d| format!("\n  \"deadline_s\": {d},"))
            .unwrap_or_default();
        format!(
            "{{\n  \"name\": \"{}\",\n  \"seed\": {},\n  \"requests\": {},\n  \
             \"slo_s\": {},{}\n  \"arrival\": {},\n  \"mix\": [{}]\n}}\n",
            escape_json(&self.name),
            self.seed,
            self.requests,
            self.slo_s,
            deadline,
            arrival_json(&self.arrival),
            mix
        )
    }
}

/// Which precision twins a workload's logical names mix in (what the
/// coordinator must enable at startup to serve them).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TwinMix {
    /// Any `<name>.q` (16-bit default) twin referenced.
    pub q: bool,
    /// Any `<name>.q8` (8-bit) twin referenced.
    pub q8: bool,
}

impl TwinMix {
    pub fn any(&self) -> bool {
        self.q || self.q8
    }
}

/// Base (f32) network names behind an iterator of logical names,
/// deduplicated in first-seen order, plus which precision twins the
/// names mix in — the one place the twin-naming convention is decoded
/// for workload purposes (scenarios *and* traces).
pub(crate) fn base_networks<'a>(
    names: impl Iterator<Item = &'a str>,
) -> (Vec<String>, TwinMix) {
    let mut bases: Vec<String> = Vec::new();
    let mut twins = TwinMix::default();
    for name in names {
        // `.q8` checked first: a `.q8` name must not decode as `.q`
        let base = match name.strip_suffix(".q8") {
            Some(b) => {
                twins.q8 = true;
                b
            }
            None => match name.strip_suffix(".q") {
                Some(b) => {
                    twins.q = true;
                    b
                }
                None => name,
            },
        };
        if !bases.iter().any(|b| b == base) {
            bases.push(base.to_string());
        }
    }
    (bases, twins)
}

fn arrival_json(a: &ArrivalProcess) -> String {
    match *a {
        ArrivalProcess::Poisson { rate_hz } => {
            format!("{{\"kind\": \"poisson\", \"rate_hz\": {rate_hz}}}")
        }
        ArrivalProcess::Mmpp {
            calm_hz,
            burst_hz,
            calm_dwell_s,
            burst_dwell_s,
        } => format!(
            "{{\"kind\": \"mmpp\", \"calm_hz\": {calm_hz}, \"burst_hz\": \
             {burst_hz}, \"calm_dwell_s\": {calm_dwell_s}, \
             \"burst_dwell_s\": {burst_dwell_s}}}"
        ),
        ArrivalProcess::Diurnal {
            base_hz,
            peak_hz,
            period_s,
        } => format!(
            "{{\"kind\": \"diurnal\", \"base_hz\": {base_hz}, \"peak_hz\": \
             {peak_hz}, \"period_s\": {period_s}}}"
        ),
        ArrivalProcess::FlashCrowd {
            base_hz,
            spike_hz,
            spike_at_s,
            spike_len_s,
        } => format!(
            "{{\"kind\": \"flash\", \"base_hz\": {base_hz}, \"spike_hz\": \
             {spike_hz}, \"spike_at_s\": {spike_at_s}, \"spike_len_s\": \
             {spike_len_s}}}"
        ),
    }
}

fn parse_arrival(v: &Json) -> Result<ArrivalProcess> {
    Ok(match v.req("kind")?.as_str()? {
        "poisson" => ArrivalProcess::Poisson {
            rate_hz: v.req("rate_hz")?.as_f64()?,
        },
        "mmpp" => ArrivalProcess::Mmpp {
            calm_hz: v.req("calm_hz")?.as_f64()?,
            burst_hz: v.req("burst_hz")?.as_f64()?,
            calm_dwell_s: v.req("calm_dwell_s")?.as_f64()?,
            burst_dwell_s: v.req("burst_dwell_s")?.as_f64()?,
        },
        "diurnal" => ArrivalProcess::Diurnal {
            base_hz: v.req("base_hz")?.as_f64()?,
            peak_hz: v.req("peak_hz")?.as_f64()?,
            period_s: v.req("period_s")?.as_f64()?,
        },
        "flash" => ArrivalProcess::FlashCrowd {
            base_hz: v.req("base_hz")?.as_f64()?,
            spike_hz: v.req("spike_hz")?.as_f64()?,
            spike_at_s: v.req("spike_at_s")?.as_f64()?,
            spike_len_s: v.req("spike_len_s")?.as_f64()?,
        },
        other => bail!("unknown arrival kind {other:?}"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtins_resolve_and_validate() {
        for name in ["steady", "burst", "diurnal", "flash"] {
            let s = Scenario::builtin(name).unwrap();
            assert_eq!(s.name, name);
            assert!(s.requests > 0 && s.slo_s > 0.0);
            s.arrival.sampler().unwrap();
        }
        assert!(Scenario::builtin("nope").is_err());
    }

    #[test]
    fn mix_names_the_precision_twins() {
        let (bases, twins) = Scenario::builtin("burst").unwrap().networks();
        assert_eq!(bases, vec!["mnist".to_string()], "twins share one base");
        assert!(twins.q, "the default mix serves a .q twin");
    }

    #[test]
    fn q8_twin_names_decode_separately_from_q() {
        let (bases, twins) = base_networks(
            ["mnist", "mnist.q8", "celeba.q"].iter().copied(),
        );
        assert_eq!(
            bases,
            vec!["mnist".to_string(), "celeba".to_string()],
            ".q8 must strip to its base, not to \"mnist.q8\""
        );
        assert!(twins.q && twins.q8 && twins.any());
        let (_, only8) = base_networks(["mnist.q8"].iter().copied());
        assert!(only8.q8 && !only8.q, ".q8 is not a .q");
    }

    #[test]
    fn json_roundtrips_every_builtin() {
        for name in ["steady", "burst", "diurnal", "flash"] {
            let s = Scenario::builtin(name).unwrap();
            let parsed = Scenario::from_json(&s.to_json()).unwrap();
            assert_eq!(parsed, s, "{name} must roundtrip exactly");
        }
    }

    #[test]
    fn resolve_prefers_builtin_then_file() {
        assert_eq!(Scenario::resolve("steady").unwrap().name, "steady");
        let dir = crate::util::TempDir::new().unwrap();
        let path = dir.path().join("custom.json");
        let mut custom = Scenario::builtin("flash").unwrap();
        custom.name = "my-flash".into();
        custom.requests = 7;
        std::fs::write(&path, custom.to_json()).unwrap();
        let loaded = Scenario::resolve(path.to_str().unwrap()).unwrap();
        assert_eq!(loaded, custom);
        assert!(Scenario::resolve("/does/not/exist.json").is_err());
    }

    #[test]
    fn builtins_carry_deadlines_and_classes() {
        for name in ["steady", "burst", "diurnal", "flash"] {
            let s = Scenario::builtin(name).unwrap();
            assert_eq!(s.deadline_s, Some(s.slo_s), "{name}: deadline = SLO");
            assert_eq!(s.mix[0].class, PriorityClass::Normal);
            assert_eq!(
                s.mix[1].class,
                PriorityClass::Low,
                "{name}: the .q twin is the bulk class"
            );
        }
    }

    #[test]
    fn pre_deadline_scenario_json_still_parses() {
        // the PR-4 schema: no class, no deadline fields anywhere
        let v1 = r#"{"name": "legacy", "seed": 1, "requests": 4, "slo_s": 0.1,
            "arrival": {"kind": "poisson", "rate_hz": 10},
            "mix": [{"network": "mnist", "images": 1, "weight": 1}]}"#;
        let s = Scenario::from_json(v1).unwrap();
        assert_eq!(s.deadline_s, None, "legacy traffic stays best-effort");
        assert_eq!(s.mix[0].class, PriorityClass::Normal);
        assert_eq!(s.mix[0].deadline_s, None);
        // per-entry overrides parse and roundtrip
        let mut s2 = s.clone();
        s2.deadline_s = Some(0.05);
        s2.mix[0].class = PriorityClass::High;
        s2.mix[0].deadline_s = Some(0.02);
        let re = Scenario::from_json(&s2.to_json()).unwrap();
        assert_eq!(re, s2, "deadline/class fields roundtrip exactly");
        // a non-positive deadline is rejected
        let bad = r#"{"name": "x", "seed": 1, "requests": 4, "slo_s": 0.1,
            "deadline_s": 0,
            "arrival": {"kind": "poisson", "rate_hz": 10},
            "mix": [{"network": "mnist", "images": 1, "weight": 1}]}"#;
        assert!(Scenario::from_json(bad).is_err());
    }

    #[test]
    fn malformed_scenarios_are_rejected() {
        assert!(Scenario::from_json("{}").is_err());
        let no_mix = r#"{"name": "x", "seed": 1, "requests": 4, "slo_s": 0.1,
            "arrival": {"kind": "poisson", "rate_hz": 10}, "mix": []}"#;
        assert!(Scenario::from_json(no_mix).is_err());
        let bad_rate = r#"{"name": "x", "seed": 1, "requests": 4, "slo_s": 0.1,
            "arrival": {"kind": "poisson", "rate_hz": 0},
            "mix": [{"network": "mnist", "images": 1, "weight": 1}]}"#;
        assert!(Scenario::from_json(bad_rate).is_err());
    }
}
