//! The host as a schedulable backend: the real numeric path.  f32
//! networks execute through the [`Runtime`]'s AOT batch buckets (PJRT
//! when the feature is on, the reverse-loop substrate otherwise),
//! `.q` twins through the calibrated [`QuantizedGenerator`].  Unlike the
//! simulator backends its latency is *measured*, so the cost model the
//! scheduler routes on is seeded from a timed probe forward at load.

use super::{
    Backend, Capabilities, CostModel, DeviceState, ExecutionOutcome, NetSpec,
};
use crate::artifacts::ArtifactDir;
use crate::config::{DeviceKind, NetworkCfg, Precision};
use crate::quant::{QuantizedGenerator, Rounding};
use crate::runtime::{GeneratorExecutable, Runtime};
use crate::tensor::Tensor;
use crate::util::WorkerPool;
use anyhow::Result;
use std::collections::HashMap;
use std::time::Instant;

/// Nominal host CPU package power while serving, watts — the energy
/// column needs *some* denominator for the host path; an edge-class
/// x86/ARM host under vectorized load sits around this figure.  The
/// paper's energy comparisons are FPGA-vs-GPU; this constant only keeps
/// the CPU column honest about being the power-hungriest option.
const HOST_POWER_W: f64 = 12.0;

struct CpuNet {
    cfg: NetworkCfg,
    buckets: Vec<usize>,
    /// f32 executables keyed by batch bucket (empty for `.q`).
    executables: HashMap<usize, GeneratorExecutable>,
    weights: Vec<(Tensor, Vec<f32>)>,
    quant: Option<QuantizedGenerator>,
    /// Measured at load: one timed probe forward.
    cost: CostModel,
}

/// [`crate::runtime`] wrapped as a [`Backend`].
pub struct CpuBackend {
    name: String,
    caps: Capabilities,
    runtime: Runtime,
    pool: WorkerPool,
    nets: HashMap<String, CpuNet>,
}

impl CpuBackend {
    pub fn new(name: String, pool: WorkerPool) -> Result<Self> {
        Ok(CpuBackend {
            name,
            caps: Capabilities::of_kind(DeviceKind::Cpu),
            runtime: Runtime::cpu_with_workers(pool.workers())?,
            pool,
            nets: HashMap::new(),
        })
    }

    /// Bucketed f32 execution: smallest exported bucket ≥ remaining,
    /// else the largest repeatedly (vLLM-style bucketed batching),
    /// padding partial buckets with zero latents.
    fn execute_f32(&self, net: &CpuNet, z: &Tensor) -> Result<Tensor> {
        let n = z.shape()[0];
        let zd = net.cfg.z_dim;
        let largest = *net.buckets.iter().max().expect("load checked buckets");
        let numel =
            net.cfg.image_channels * net.cfg.image_size * net.cfg.image_size;
        let mut rows: Vec<f32> = Vec::with_capacity(n * numel);
        let mut remaining = n;
        let mut offset = 0usize;
        while remaining > 0 {
            let bucket = net
                .buckets
                .iter()
                .copied()
                .filter(|b| *b >= remaining)
                .min()
                .unwrap_or(largest);
            let take = bucket.min(remaining);
            let exe = net.executables.get(&bucket).unwrap();
            let mut zb = vec![0.0f32; bucket * zd];
            zb[..take * zd]
                .copy_from_slice(&z.data()[offset * zd..(offset + take) * zd]);
            let zt = Tensor::new(vec![bucket, zd], zb)?;
            let out = exe.generate(&zt, &net.weights)?;
            rows.extend_from_slice(&out.data()[..take * numel]);
            remaining -= take;
            offset += take;
        }
        Tensor::new(
            vec![
                n,
                net.cfg.image_channels,
                net.cfg.image_size,
                net.cfg.image_size,
            ],
            rows,
        )
    }

    fn forward(&self, net: &CpuNet, z: &Tensor) -> Result<Tensor> {
        match &net.quant {
            Some(qgen) => Ok(qgen.generate(&net.cfg, z, &self.pool).0),
            None => self.execute_f32(net, z),
        }
    }
}

impl Backend for CpuBackend {
    fn kind(&self) -> DeviceKind {
        DeviceKind::Cpu
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn capabilities(&self) -> &Capabilities {
        &self.caps
    }

    fn load(&mut self, spec: &NetSpec, artifacts: &ArtifactDir) -> Result<()> {
        let mut net = match spec.precision {
            Precision::Fixed(fmt) => CpuNet {
                cfg: spec.cfg.clone(),
                buckets: Vec::new(),
                executables: HashMap::new(),
                weights: Vec::new(),
                quant: Some(QuantizedGenerator::quantize(
                    fmt,
                    &spec.weights,
                    Rounding::Nearest,
                )?),
                cost: CostModel::linear(0.0),
            },
            Precision::F32 => {
                anyhow::ensure!(
                    !spec.buckets.is_empty(),
                    "{}: network {:?} exports no batch buckets",
                    self.name,
                    spec.name
                );
                let mut executables = HashMap::new();
                for &bs in &spec.buckets {
                    executables.insert(
                        bs,
                        self.runtime.load_generator(artifacts, &spec.base, bs)?,
                    );
                }
                CpuNet {
                    cfg: spec.cfg.clone(),
                    buckets: spec.buckets.clone(),
                    executables,
                    weights: spec.weights.clone(),
                    quant: None,
                    cost: CostModel::linear(0.0),
                }
            }
        };
        // measured cost seed: one timed batch-1 probe (startup only)
        let z = Tensor::new(vec![1, net.cfg.z_dim], vec![0.0; net.cfg.z_dim])?;
        let t0 = Instant::now();
        self.forward(&net, &z)?;
        net.cost = CostModel::linear(t0.elapsed().as_secs_f64().max(1e-9));
        self.nets.insert(spec.name.clone(), net);
        Ok(())
    }

    fn cost_model(&self, network: &str) -> Option<CostModel> {
        self.nets.get(network).map(|n| n.cost)
    }

    fn execute(&mut self, network: &str, z: &Tensor) -> Result<ExecutionOutcome> {
        let net = self.nets.get(network).ok_or_else(|| {
            anyhow::anyhow!("{}: network {network:?} not loaded", self.name)
        })?;
        let n = z.shape()[0];
        let t0 = Instant::now();
        let images = self.forward(net, z)?;
        let execute_s = t0.elapsed().as_secs_f64();
        Ok(ExecutionOutcome {
            images,
            execute_s,
            device_time_s: execute_s,
            energy_j: HOST_POWER_W * execute_s,
            ops: net.cfg.total_ops() * n as u64,
            state: DeviceState::default(),
        })
    }
}
