//! The Jetson TX1 as a schedulable backend: numerics through the shared
//! reverse-loop substrate (f32 only — the paper's cuDNN baseline has no
//! fixed-point datapath), timing/energy from the analytical kernel model
//! with the [`ThermalThrottle`] as **owned device state**.  This is the
//! refactor the old executor loop could not express: the throttle used
//! to be executor-local ad hoc state shared by whatever networks landed
//! on that thread; now it is the GPU device itself — back-to-back
//! batches heat the die, and a later batch (any network) sees the
//! stepped-down clock, exactly the run-to-run variance mechanism the
//! paper attributes to DVFS.

use super::{
    Backend, Capabilities, CostModel, DeviceState, ExecutionOutcome, NetSpec,
};
use crate::artifacts::ArtifactDir;
use crate::config::{DeviceKind, NetworkCfg, JETSON_TX1};
use crate::deconv::generator_forward_par;
use crate::gpu::{
    expected_gpu_network_time_at, measured_gpu_network_run, ThermalThrottle,
};
use crate::tensor::Tensor;
use crate::util::{Rng, WorkerPool};
use anyhow::Result;
use std::collections::HashMap;
use std::time::Instant;

struct GpuNet {
    cfg: NetworkCfg,
    weights: Vec<(Tensor, Vec<f32>)>,
}

/// [`crate::gpu`] wrapped as a [`Backend`], owning the thermal state.
pub struct GpuModelBackend {
    name: String,
    caps: Capabilities,
    pool: WorkerPool,
    nets: HashMap<String, GpuNet>,
    /// The device: DVFS/thermal state advanced per executed batch.
    throttle: ThermalThrottle,
    /// Measurement-noise stream: each executed batch is one nvprof-style
    /// *measured* run (time σ, interference stalls, power σ) — the
    /// run-to-run variation half of the paper's Table II, live.
    noise: Rng,
}

impl GpuModelBackend {
    pub fn new(name: String, pool: WorkerPool, noise_seed: u64) -> Self {
        GpuModelBackend {
            name,
            caps: Capabilities::of_kind(DeviceKind::Gpu),
            pool,
            nets: HashMap::new(),
            throttle: ThermalThrottle::new(JETSON_TX1),
            noise: Rng::seed_from_u64(noise_seed),
        }
    }
}

impl Backend for GpuModelBackend {
    fn kind(&self) -> DeviceKind {
        DeviceKind::Gpu
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn capabilities(&self) -> &Capabilities {
        &self.caps
    }

    fn load(&mut self, spec: &NetSpec, _artifacts: &ArtifactDir) -> Result<()> {
        anyhow::ensure!(
            self.caps.supports(spec.precision),
            "{}: precision {} not supported (f32-only datapath)",
            self.name,
            spec.precision
        );
        self.nets.insert(
            spec.name.clone(),
            GpuNet {
                cfg: spec.cfg.clone(),
                weights: spec.weights.clone(),
            },
        );
        Ok(())
    }

    fn cost_model(&self, network: &str) -> Option<CostModel> {
        // estimate at the clock the governor currently holds: reading
        // the clock must not *advance* the thermal state (a routing
        // probe never heats the die), but it must *see* it — the
        // executor re-probes on throttle transitions so sustained load
        // routes on throttled-clock costs, not boost-clock ones
        let net = self.nets.get(network)?;
        let clock = self.throttle.clock_hz;
        Some(CostModel {
            c1_s: expected_gpu_network_time_at(&net.cfg, &JETSON_TX1, clock, 1),
            c8_s: expected_gpu_network_time_at(&net.cfg, &JETSON_TX1, clock, 8),
        })
    }

    fn execute(&mut self, network: &str, z: &Tensor) -> Result<ExecutionOutcome> {
        let net = self.nets.get(network).ok_or_else(|| {
            anyhow::anyhow!("{}: network {network:?} not loaded", self.name)
        })?;
        let n = z.shape()[0];
        let t0 = Instant::now();
        let images = generator_forward_par(&net.cfg, &net.weights, z, &self.pool);
        let execute_s = t0.elapsed().as_secs_f64();
        // the device accounting: one *measured* run (expected account ×
        // nvprof-style noise), advancing the thermal state per layer
        let (device_time_s, energy_j) = measured_gpu_network_run(
            &net.cfg,
            &JETSON_TX1,
            &mut self.throttle,
            n,
            &mut self.noise,
        );
        Ok(ExecutionOutcome {
            images,
            execute_s,
            device_time_s,
            energy_j,
            ops: net.cfg.total_ops() * n as u64,
            state: DeviceState {
                temp_c: self.throttle.temp_c,
                clock_hz: self.throttle.clock_hz,
                throttled: self.throttle.throttled(),
            },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::artifacts::write_synthetic;
    use crate::backend::NetSpec;
    use crate::config::{network_by_name, Precision, QFormat};
    use crate::util::{Rng, TempDir};

    fn mnist_spec() -> NetSpec {
        let cfg = network_by_name("mnist").unwrap();
        let mut rng = Rng::seed_from_u64(3);
        let weights = cfg
            .layers
            .iter()
            .map(|l| {
                (
                    Tensor::from_fn(vec![l.c_in, l.c_out, l.k, l.k], |_| {
                        0.05 * rng.normal_f32()
                    }),
                    vec![0.0; l.c_out],
                )
            })
            .collect();
        NetSpec {
            name: "mnist".into(),
            base: "mnist".into(),
            precision: Precision::F32,
            weights,
            buckets: vec![1, 4],
            cfg,
        }
    }

    #[test]
    fn owned_thermal_state_evolves_across_batches() {
        let dir = TempDir::new().unwrap();
        let artifacts = write_synthetic(dir.path(), &["mnist"], 2, 5).unwrap();
        let mut be =
            GpuModelBackend::new("gpu0".into(), WorkerPool::new(1), 3);
        be.load(&mnist_spec(), &artifacts).unwrap();
        // the cost probe must not heat the die
        let cost = be.cost_model("mnist").unwrap();
        assert!(cost.c1_s > 0.0 && cost.c8_s > cost.c1_s);
        assert_eq!(be.throttle.temp_c, 0.0, "probe touched thermal state");
        let z = Tensor::from_fn(vec![2, 100], |i| (i as f32 * 0.01).sin());
        let a = be.execute("mnist", &z).unwrap();
        let b = be.execute("mnist", &z).unwrap();
        assert_eq!(a.images.data(), b.images.data(), "numerics are stateless");
        assert!(a.device_time_s > 0.0 && a.energy_j > 0.0);
        assert!(
            b.state.temp_c > 0.0,
            "back-to-back batches must heat the owned die"
        );
        assert!(a.state.clock_hz > 0.0);
    }

    #[test]
    fn fixed_point_networks_are_rejected() {
        let mut be =
            GpuModelBackend::new("gpu0".into(), WorkerPool::new(1), 3);
        let dir = TempDir::new().unwrap();
        let artifacts = write_synthetic(dir.path(), &["mnist"], 2, 5).unwrap();
        let mut spec = mnist_spec();
        spec.precision = Precision::Fixed(QFormat::new(16, 8));
        assert!(be.load(&spec, &artifacts).is_err(), "f32-only datapath");
    }

    #[test]
    fn measured_runs_vary_and_are_seeded() {
        let dir = TempDir::new().unwrap();
        let artifacts = write_synthetic(dir.path(), &["mnist"], 2, 5).unwrap();
        let series = |seed: u64| {
            let mut be =
                GpuModelBackend::new("gpu0".into(), WorkerPool::new(1), seed);
            be.load(&mnist_spec(), &artifacts).unwrap();
            let z = Tensor::from_fn(vec![1, 100], |i| (i as f32 * 0.01).sin());
            (0..25)
                .map(|_| be.execute("mnist", &z).unwrap().device_time_s)
                .collect::<Vec<f64>>()
        };
        let a = series(9);
        assert_eq!(a, series(9), "noise stream is seed-deterministic");
        assert_ne!(a, series(10), "seeds matter");
        let s = crate::stats::Summary::of(&a);
        assert!(
            s.std / s.mean > 0.03,
            "GPU serving lane must show the paper's run-to-run variation, \
             cv={}",
            s.std / s.mean
        );
    }

    #[test]
    fn cost_probe_tracks_the_governor_clock() {
        let dir = TempDir::new().unwrap();
        let artifacts = write_synthetic(dir.path(), &["mnist"], 2, 5).unwrap();
        let mut be =
            GpuModelBackend::new("gpu0".into(), WorkerPool::new(1), 1);
        be.load(&mnist_spec(), &artifacts).unwrap();
        let boost = be.cost_model("mnist").unwrap();
        // hold the die hot: the governor steps the clock down and the
        // re-probed cost model must get slower (this is what the
        // executor's throttle-transition refresh feeds the scheduler)
        be.throttle.temp_c = 40.0;
        be.throttle.step(0.0, 0.0, 1e-9);
        assert!(be.throttle.throttled());
        let throttled = be.cost_model("mnist").unwrap();
        assert!(
            throttled.c1_s > boost.c1_s && throttled.c8_s > boost.c8_s,
            "throttled probe must cost more: {throttled:?} vs {boost:?}"
        );
        // probing still never advances the thermal state
        let t = be.throttle.temp_c;
        let _ = be.cost_model("mnist");
        assert_eq!(be.throttle.temp_c, t);
    }
}
