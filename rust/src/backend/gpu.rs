//! The Jetson TX1 as a schedulable backend: numerics through the shared
//! reverse-loop substrate (f32 only — the paper's cuDNN baseline has no
//! fixed-point datapath), timing/energy from the analytical kernel model
//! with the [`ThermalThrottle`] as **owned device state**.  This is the
//! refactor the old executor loop could not express: the throttle used
//! to be executor-local ad hoc state shared by whatever networks landed
//! on that thread; now it is the GPU device itself — back-to-back
//! batches heat the die, and a later batch (any network) sees the
//! stepped-down clock, exactly the run-to-run variance mechanism the
//! paper attributes to DVFS.

use super::{
    Backend, Capabilities, CostModel, DeviceState, ExecutionOutcome, NetSpec,
};
use crate::artifacts::ArtifactDir;
use crate::config::{DeviceKind, NetworkCfg, JETSON_TX1};
use crate::deconv::generator_forward_par;
use crate::gpu::{
    expected_gpu_network_run, expected_gpu_network_time_at, ThermalThrottle,
};
use crate::tensor::Tensor;
use crate::util::WorkerPool;
use anyhow::Result;
use std::collections::HashMap;
use std::time::Instant;

struct GpuNet {
    cfg: NetworkCfg,
    weights: Vec<(Tensor, Vec<f32>)>,
}

/// [`crate::gpu`] wrapped as a [`Backend`], owning the thermal state.
pub struct GpuModelBackend {
    name: String,
    caps: Capabilities,
    pool: WorkerPool,
    nets: HashMap<String, GpuNet>,
    /// The device: DVFS/thermal state advanced per executed batch.
    throttle: ThermalThrottle,
}

impl GpuModelBackend {
    pub fn new(name: String, pool: WorkerPool) -> Self {
        GpuModelBackend {
            name,
            caps: Capabilities::of_kind(DeviceKind::Gpu),
            pool,
            nets: HashMap::new(),
            throttle: ThermalThrottle::new(JETSON_TX1),
        }
    }
}

impl Backend for GpuModelBackend {
    fn kind(&self) -> DeviceKind {
        DeviceKind::Gpu
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn capabilities(&self) -> &Capabilities {
        &self.caps
    }

    fn load(&mut self, spec: &NetSpec, _artifacts: &ArtifactDir) -> Result<()> {
        anyhow::ensure!(
            self.caps.supports(spec.precision),
            "{}: precision {} not supported (f32-only datapath)",
            self.name,
            spec.precision
        );
        self.nets.insert(
            spec.name.clone(),
            GpuNet {
                cfg: spec.cfg.clone(),
                weights: spec.weights.clone(),
            },
        );
        Ok(())
    }

    fn cost_model(&self, network: &str) -> Option<CostModel> {
        // boost-clock estimate: the scheduler's probe must not depend on
        // (or advance) the live thermal state
        let net = self.nets.get(network)?;
        let clock = JETSON_TX1.boost_clock_hz;
        Some(CostModel {
            c1_s: expected_gpu_network_time_at(&net.cfg, &JETSON_TX1, clock, 1),
            c8_s: expected_gpu_network_time_at(&net.cfg, &JETSON_TX1, clock, 8),
        })
    }

    fn execute(&mut self, network: &str, z: &Tensor) -> Result<ExecutionOutcome> {
        let net = self.nets.get(network).ok_or_else(|| {
            anyhow::anyhow!("{}: network {network:?} not loaded", self.name)
        })?;
        let n = z.shape()[0];
        let t0 = Instant::now();
        let images = generator_forward_par(&net.cfg, &net.weights, z, &self.pool);
        let execute_s = t0.elapsed().as_secs_f64();
        // the device accounting: advance the thermal state by this batch
        let (device_time_s, energy_j) =
            expected_gpu_network_run(&net.cfg, &JETSON_TX1, &mut self.throttle, n);
        Ok(ExecutionOutcome {
            images,
            execute_s,
            device_time_s,
            energy_j,
            ops: net.cfg.total_ops() * n as u64,
            state: DeviceState {
                temp_c: self.throttle.temp_c,
                clock_hz: self.throttle.clock_hz,
                throttled: self.throttle.throttled(),
            },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::artifacts::write_synthetic;
    use crate::backend::NetSpec;
    use crate::config::{network_by_name, Precision, QFormat};
    use crate::util::{Rng, TempDir};

    fn mnist_spec() -> NetSpec {
        let cfg = network_by_name("mnist").unwrap();
        let mut rng = Rng::seed_from_u64(3);
        let weights = cfg
            .layers
            .iter()
            .map(|l| {
                (
                    Tensor::from_fn(vec![l.c_in, l.c_out, l.k, l.k], |_| {
                        0.05 * rng.normal_f32()
                    }),
                    vec![0.0; l.c_out],
                )
            })
            .collect();
        NetSpec {
            name: "mnist".into(),
            base: "mnist".into(),
            precision: Precision::F32,
            weights,
            buckets: vec![1, 4],
            cfg,
        }
    }

    #[test]
    fn owned_thermal_state_evolves_across_batches() {
        let dir = TempDir::new().unwrap();
        let artifacts = write_synthetic(dir.path(), &["mnist"], 2, 5).unwrap();
        let mut be =
            GpuModelBackend::new("gpu0".into(), WorkerPool::new(1));
        be.load(&mnist_spec(), &artifacts).unwrap();
        // the cost probe must not heat the die
        let cost = be.cost_model("mnist").unwrap();
        assert!(cost.c1_s > 0.0 && cost.c8_s > cost.c1_s);
        assert_eq!(be.throttle.temp_c, 0.0, "probe touched thermal state");
        let z = Tensor::from_fn(vec![2, 100], |i| (i as f32 * 0.01).sin());
        let a = be.execute("mnist", &z).unwrap();
        let b = be.execute("mnist", &z).unwrap();
        assert_eq!(a.images.data(), b.images.data(), "numerics are stateless");
        assert!(a.device_time_s > 0.0 && a.energy_j > 0.0);
        assert!(
            b.state.temp_c > 0.0,
            "back-to-back batches must heat the owned die"
        );
        assert!(a.state.clock_hz > 0.0);
    }

    #[test]
    fn fixed_point_networks_are_rejected() {
        let mut be =
            GpuModelBackend::new("gpu0".into(), WorkerPool::new(1));
        let dir = TempDir::new().unwrap();
        let artifacts = write_synthetic(dir.path(), &["mnist"], 2, 5).unwrap();
        let mut spec = mnist_spec();
        spec.precision = Precision::Fixed(QFormat::new(16, 8));
        assert!(be.load(&spec, &artifacts).is_err(), "f32-only datapath");
    }
}
