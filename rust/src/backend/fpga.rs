//! The PYNQ-Z2 accelerator as a schedulable backend: numerics through
//! the shared reverse-loop substrate (f32 or the calibrated fixed-point
//! twin), timing/energy from the cycle-level pipeline simulator at the
//! network's served datapath precision.  The accelerator has no dynamic
//! device state (no DVFS, no thermal governor — the paper's Section V
//! point about FPGA run-to-run stability), so its cost model is a pure
//! per-image linear ramp computed once at load.

use super::{
    Backend, Capabilities, CostModel, DeviceState, ExecutionOutcome, NetSpec,
};
use crate::artifacts::ArtifactDir;
use crate::config::{DeviceKind, NetworkCfg, Precision, PYNQ_Z2};
use crate::deconv::generator_forward_par;
use crate::fpga::{measured_account, simulate_network, NetworkSim, SimOpts};
use crate::quant::{QuantizedGenerator, Rounding};
use crate::tensor::Tensor;
use crate::util::{Rng, WorkerPool};
use anyhow::Result;
use std::collections::HashMap;
use std::time::Instant;

/// Dense accelerator simulation of a network at its *effective*
/// datapath precision: f32-served networks time at the manifest's
/// declared precision, fixed-point twins at their Qm.n format.  The
/// fallback rule lives here once — shared by [`FpgaSimBackend::load`]
/// and the coordinator executor's per-response FPGA annotation.
pub fn dense_network_sim(cfg: &NetworkCfg, served: Precision) -> NetworkSim {
    let sim_precision = match served {
        Precision::F32 => cfg.precision,
        p => p,
    };
    let opts: Vec<SimOpts> = cfg
        .layers
        .iter()
        .map(|_| SimOpts::dense_at(cfg.tile, sim_precision))
        .collect();
    simulate_network(cfg, &PYNQ_Z2, &opts)
}

struct FpgaNet {
    cfg: NetworkCfg,
    weights: Vec<(Tensor, Vec<f32>)>,
    /// Fixed-point twin (serving precision `Fixed(..)`), calibrated at
    /// load from the f32 weights.
    quant: Option<QuantizedGenerator>,
    /// Simulated dense per-image latency/energy at the served precision.
    per_image_s: f64,
    per_image_j: f64,
}

/// [`crate::fpga`] wrapped as a [`Backend`].
pub struct FpgaSimBackend {
    name: String,
    caps: Capabilities,
    pool: WorkerPool,
    nets: HashMap<String, FpgaNet>,
    /// Measurement-noise stream: each executed batch is one *measured*
    /// run with the board's tiny clock/DDR jitter (σ/μ ≈ 0.3%) — the
    /// workload-insensitive stability half of the paper's Table II.
    noise: Rng,
}

impl FpgaSimBackend {
    pub fn new(name: String, pool: WorkerPool, noise_seed: u64) -> Self {
        FpgaSimBackend {
            name,
            caps: Capabilities::of_kind(DeviceKind::Fpga),
            pool,
            nets: HashMap::new(),
            noise: Rng::seed_from_u64(noise_seed),
        }
    }
}

impl Backend for FpgaSimBackend {
    fn kind(&self) -> DeviceKind {
        DeviceKind::Fpga
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn capabilities(&self) -> &Capabilities {
        &self.caps
    }

    fn load(&mut self, spec: &NetSpec, _artifacts: &ArtifactDir) -> Result<()> {
        let quant = match spec.precision {
            Precision::F32 => None,
            Precision::Fixed(fmt) => Some(QuantizedGenerator::quantize(
                fmt,
                &spec.weights,
                Rounding::Nearest,
            )?),
        };
        let sim = dense_network_sim(&spec.cfg, spec.precision);
        self.nets.insert(
            spec.name.clone(),
            FpgaNet {
                cfg: spec.cfg.clone(),
                weights: spec.weights.clone(),
                quant,
                per_image_s: sim.total_time_s,
                per_image_j: sim.total_time_s * sim.mean_power_w,
            },
        );
        Ok(())
    }

    fn cost_model(&self, network: &str) -> Option<CostModel> {
        self.nets
            .get(network)
            .map(|n| CostModel::linear(n.per_image_s))
    }

    fn execute(&mut self, network: &str, z: &Tensor) -> Result<ExecutionOutcome> {
        let net = self.nets.get(network).ok_or_else(|| {
            anyhow::anyhow!("{}: network {network:?} not loaded", self.name)
        })?;
        let n = z.shape()[0];
        let t0 = Instant::now();
        let images = match &net.quant {
            Some(qgen) => qgen.generate(&net.cfg, z, &self.pool).0,
            None => generator_forward_par(&net.cfg, &net.weights, z, &self.pool),
        };
        let execute_s = t0.elapsed().as_secs_f64();
        // one measured run: dense schedule × the board's jitter
        let (device_time_s, energy_j) = measured_account(
            net.per_image_s * n as f64,
            net.per_image_j * n as f64,
            &mut self.noise,
        );
        Ok(ExecutionOutcome {
            images,
            execute_s,
            device_time_s,
            energy_j,
            ops: net.cfg.total_ops() * n as u64,
            state: DeviceState {
                temp_c: 0.0,
                clock_hz: PYNQ_Z2.clock_hz,
                throttled: false,
            },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::artifacts::write_synthetic;
    use crate::backend::NetSpec;
    use crate::config::{network_by_name, QFormat};
    use crate::util::{Rng, TempDir};

    fn spec_at(precision: Precision) -> NetSpec {
        let cfg = network_by_name("mnist").unwrap();
        let mut rng = Rng::seed_from_u64(7);
        let weights = cfg
            .layers
            .iter()
            .map(|l| {
                (
                    Tensor::from_fn(vec![l.c_in, l.c_out, l.k, l.k], |_| {
                        0.05 * rng.normal_f32()
                    }),
                    vec![0.0; l.c_out],
                )
            })
            .collect();
        NetSpec {
            name: match precision {
                Precision::F32 => "mnist".into(),
                _ => "mnist.q".into(),
            },
            base: "mnist".into(),
            precision,
            weights,
            buckets: vec![1, 4],
            cfg,
        }
    }

    #[test]
    fn quant_twin_times_at_the_narrower_datapath() {
        let dir = TempDir::new().unwrap();
        let artifacts = write_synthetic(dir.path(), &["mnist"], 2, 9).unwrap();
        let mut be = FpgaSimBackend::new("fpga0".into(), WorkerPool::new(1), 5);
        be.load(&spec_at(Precision::F32), &artifacts).unwrap();
        be.load(
            &spec_at(Precision::Fixed(QFormat::new(16, 8))),
            &artifacts,
        )
        .unwrap();
        let f32_cost = be.cost_model("mnist").unwrap();
        let q_cost = be.cost_model("mnist.q").unwrap();
        assert!(
            q_cost.c1_s < f32_cost.c1_s,
            "q8.8 datapath must simulate faster than f32"
        );
        let z = Tensor::from_fn(vec![1, 100], |i| (i as f32 * 0.02).cos());
        let f = be.execute("mnist", &z).unwrap();
        let q = be.execute("mnist.q", &z).unwrap();
        assert_eq!(f.images.shape(), q.images.shape());
        assert!(q.device_time_s < f.device_time_s);
        assert!(!f.state.throttled, "no thermal governor on the FPGA");
        assert_eq!(f.state.clock_hz, PYNQ_Z2.clock_hz);
        // device accounting scales linearly with the batch, up to the
        // ±0.6% measured-run jitter each executed batch carries
        let z2 = Tensor::from_fn(vec![2, 100], |i| (i as f32 * 0.02).cos());
        let f2 = be.execute("mnist", &z2).unwrap();
        assert!(
            (f2.device_time_s / (2.0 * f.device_time_s) - 1.0).abs() < 0.02,
            "{} vs {}",
            f2.device_time_s,
            2.0 * f.device_time_s
        );
    }

    #[test]
    fn measured_runs_jitter_tiny_and_seeded() {
        let dir = TempDir::new().unwrap();
        let artifacts = write_synthetic(dir.path(), &["mnist"], 2, 9).unwrap();
        let series = |seed: u64| {
            let mut be =
                FpgaSimBackend::new("fpga0".into(), WorkerPool::new(1), seed);
            be.load(&spec_at(Precision::F32), &artifacts).unwrap();
            let z = Tensor::from_fn(vec![1, 100], |i| (i as f32 * 0.02).cos());
            (0..20)
                .map(|_| be.execute("mnist", &z).unwrap().device_time_s)
                .collect::<Vec<f64>>()
        };
        let a = series(7);
        assert_eq!(a, series(7), "noise stream is seed-deterministic");
        assert_ne!(a, series(8), "seeds matter");
        let s = crate::stats::Summary::of(&a);
        assert!(s.std > 0.0, "measured runs must vary");
        assert!(s.std / s.mean < 0.01, "FPGA jitter stays tiny (Table II)");
    }
}
