//! Pluggable device backends — the abstraction that turns the paper's
//! FPGA-vs-GPU comparison into a *live scheduling decision*.
//!
//! A [`Backend`] is a schedulable device: it declares what it can serve
//! ([`Capabilities`] — supported datapath precisions and its native
//! batch bucket), how much a `(network, batch)` would cost
//! ([`CostModel`], consumed by the scheduler's capability- and
//! cost-aware routing), and executes batches
//! ([`Backend::execute`] → [`ExecutionOutcome`] carrying outputs,
//! simulated device latency, energy, and the device-state delta).
//!
//! Three implementations, refactored out of the old monolithic
//! coordinator executor loop:
//!
//! * [`FpgaSimBackend`] — the PYNQ-Z2 datapath via
//!   [`crate::fpga::simulate_network`]; stateless timing, f32 or
//!   fixed-point.
//! * [`GpuModelBackend`] — the Jetson TX1 analytical model; the
//!   [`crate::gpu::ThermalThrottle`] is **owned device state** (batches
//!   heat the die, later batches see the throttled clock), and the
//!   datapath is f32-only (the paper's cuDNN baseline).
//! * [`CpuBackend`] — the host numeric path ([`crate::runtime::Runtime`]
//!   bucketed f32 executables, [`crate::quant::QuantizedGenerator`] for
//!   `.q` twins); its cost model is *measured* at load time.
//!
//! Every backend produces **bit-identical f32 images** for the same
//! latents: numerics always run through the shared reverse-loop
//! substrate, only the timing/energy/state model differs.  That is the
//! invariant that lets the scheduler route a batch to whichever device
//! is cheapest without changing what the client sees (asserted by
//! `tests/integration_backends.rs`).

mod cpu;
mod fpga;
mod gpu;

pub use cpu::CpuBackend;
pub use fpga::{dense_network_sim, FpgaSimBackend};
pub use gpu::GpuModelBackend;

use crate::artifacts::ArtifactDir;
use crate::config::{DeviceKind, NetworkCfg, Precision};
use crate::quant::supported_formats;
use crate::tensor::Tensor;
use crate::util::WorkerPool;
use anyhow::Result;

/// What a backend can serve: the datapath precisions it implements and
/// the largest batch it accepts in one scheduling unit.  The scheduler
/// consults both ([`Capabilities::supports`] at registry build,
/// [`Capabilities::admits`] per batch) — a batch larger than a lane's
/// bucket is never routed there, so keep the dynamic batcher's
/// `max_batch` within every capable lane's bucket.
#[derive(Debug, Clone)]
pub struct Capabilities {
    pub precisions: Vec<Precision>,
    pub max_batch: usize,
}

impl Capabilities {
    /// Does this backend implement the given datapath precision?
    pub fn supports(&self, p: Precision) -> bool {
        self.precisions.contains(&p)
    }

    /// Can this backend take a batch of `n_images` in one go?  (The
    /// three built-in backends are unbounded — the FPGA/GPU models are
    /// analytic and the CPU path loops its buckets — but a backend with
    /// a hard device bucket gates routing here.)
    pub fn admits(&self, n_images: usize) -> bool {
        n_images <= self.max_batch
    }

    /// Static capability table per device class — what the registry
    /// consults *before* instantiating backends: the FPGA datapath and
    /// the host path serve f32 and every supported Qm.n format; the GPU
    /// baseline is f32-only (the paper's cuDNN path has no fixed-point
    /// datapath).
    pub fn of_kind(kind: DeviceKind) -> Capabilities {
        let mut precisions = vec![Precision::F32];
        if kind != DeviceKind::Gpu {
            precisions.extend(supported_formats().into_iter().map(Precision::Fixed));
        }
        Capabilities {
            precisions,
            max_batch: usize::MAX,
        }
    }
}

/// Affine per-network cost model `cost(n) ≈ intercept + slope·n`,
/// reported by each backend at load time and consumed leader-side by the
/// scheduler (which cannot call into lane-owned backends).  Two probe
/// points capture the batch-amortization shape: the GPU's launch
/// overhead gives it a large intercept, the FPGA is almost purely
/// linear, the CPU's is measured.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostModel {
    /// Estimated device seconds at batch 1.
    pub c1_s: f64,
    /// Estimated device seconds at batch 8.
    pub c8_s: f64,
}

impl CostModel {
    pub fn linear(per_image_s: f64) -> Self {
        CostModel {
            c1_s: per_image_s,
            c8_s: 8.0 * per_image_s,
        }
    }

    /// Interpolated/extrapolated cost for `n` images (clamped ≥ 0).
    pub fn cost_s(&self, n: usize) -> f64 {
        let slope = (self.c8_s - self.c1_s) / 7.0;
        let intercept = self.c1_s - slope;
        (intercept + slope * n as f64).max(0.0)
    }

    /// Estimated completion time for a request of `n` images joining a
    /// lane that already queues `depth` batches: each queued batch is
    /// charged at the full bucket cost (`c8_s` — the pessimistic bound a
    /// shed-early admission check wants), then the request's own batch.
    /// This is the "queue depth × predicted cost" feasibility query of
    /// the deadline-aware intake.
    pub fn eta_s(&self, depth: usize, n: usize) -> f64 {
        depth as f64 * self.c8_s + self.cost_s(n)
    }

    /// Slack a request with `budget_s` seconds to its deadline would
    /// have left after this device served `n` images behind `depth`
    /// queued batches.  Negative slack = infeasible: serving it would
    /// only produce a served-late response, so intake sheds it instead.
    pub fn slack_s(&self, budget_s: f64, depth: usize, n: usize) -> f64 {
        budget_s - self.eta_s(depth, n)
    }
}

/// Everything a backend needs to load one logical network: the base
/// artifact data plus the serving precision (a `.q` twin carries
/// `Precision::Fixed(..)` and the *f32* weights it calibrates from).
#[derive(Debug, Clone)]
pub struct NetSpec {
    /// Logical serving name (`mnist`, `mnist.q`, …).
    pub name: String,
    /// Base artifact name (`.q` stripped).
    pub base: String,
    pub cfg: NetworkCfg,
    /// Datapath precision this logical network is served at.
    pub precision: Precision,
    /// f32 weight set (the `.q` path quantizes at load).
    pub weights: Vec<(Tensor, Vec<f32>)>,
    /// AOT-exported batch buckets of the base network.
    pub buckets: Vec<usize>,
}

/// Device state after a batch — the delta the executor surfaces in
/// metrics/telemetry.  Static devices report their nominal point.
#[derive(Debug, Clone, Copy, Default)]
pub struct DeviceState {
    /// Die temperature above ambient, °C (0 for unmodeled devices).
    pub temp_c: f64,
    /// Clock the device ran the batch at, Hz.
    pub clock_hz: f64,
    /// Was the device thermally throttled during the batch?
    pub throttled: bool,
}

/// One executed batch: the generated images plus the device's account
/// of the work.
#[derive(Debug)]
pub struct ExecutionOutcome {
    /// Images for the whole batch, `[n, C, H, W]`.
    pub images: Tensor,
    /// Host wall time spent in the numeric substrate, seconds.
    pub execute_s: f64,
    /// Device latency for the batch (simulated for fpga/gpu, measured
    /// for cpu), seconds.
    pub device_time_s: f64,
    /// Device energy for the batch, joules.
    pub energy_j: f64,
    /// Arithmetic operations the batch represents.
    pub ops: u64,
    /// Device state after the batch.
    pub state: DeviceState,
}

/// A schedulable device: owns its serving state (loaded networks,
/// thermal state, …) and lives on exactly one executor lane thread —
/// it is created, used and dropped there, so no `Send`/`Sync` bound is
/// required (PJRT handles inside [`CpuBackend`] are neither).
pub trait Backend {
    fn kind(&self) -> DeviceKind;

    /// Lane name, e.g. `fpga0` (unique within the pool).
    fn name(&self) -> &str;

    fn capabilities(&self) -> &Capabilities;

    /// Load one logical network; called once per routable network at
    /// lane startup, never on the request path.
    fn load(&mut self, spec: &NetSpec, artifacts: &ArtifactDir) -> Result<()>;

    /// The cost model for a loaded network (None if not loaded).
    fn cost_model(&self, network: &str) -> Option<CostModel>;

    /// Execute one batch: `z` is the `[n, z_dim]` f32 latent block (the
    /// executor derives it from request seeds, so every backend sees
    /// identical inputs).
    fn execute(&mut self, network: &str, z: &Tensor) -> Result<ExecutionOutcome>;
}

/// Instantiate a backend of the given kind under the given lane name
/// (the registry is the naming authority — `fpga0`, `cpu1`, …); `pool`
/// is the lane's share of the host compute budget and `noise_seed`
/// seeds the device's measurement-noise stream (every executed batch
/// is one *measured* run, Table-2 style — FPGA clock/DDR jitter, GPU
/// nvprof-style noise; the CPU path measures real wall time and needs
/// no synthetic noise).
pub fn instantiate(
    kind: DeviceKind,
    name: String,
    pool: WorkerPool,
    noise_seed: u64,
) -> Result<Box<dyn Backend>> {
    Ok(match kind {
        DeviceKind::Fpga => Box::new(FpgaSimBackend::new(name, pool, noise_seed)),
        DeviceKind::Gpu => Box::new(GpuModelBackend::new(name, pool, noise_seed)),
        DeviceKind::Cpu => Box::new(CpuBackend::new(name, pool)?),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capability_table_matches_paper_datapaths() {
        let fpga = Capabilities::of_kind(DeviceKind::Fpga);
        let gpu = Capabilities::of_kind(DeviceKind::Gpu);
        let cpu = Capabilities::of_kind(DeviceKind::Cpu);
        let q88 = Precision::Fixed(crate::quant::QFormat::new(16, 8));
        let q8 = Precision::Fixed(crate::quant::QFormat::new(8, 6));
        assert!(fpga.supports(Precision::F32) && fpga.supports(q88));
        assert!(cpu.supports(Precision::F32) && cpu.supports(q88));
        assert!(fpga.supports(q8) && cpu.supports(q8), "i8 datapath");
        assert!(gpu.supports(Precision::F32));
        assert!(!gpu.supports(q88), "the cuDNN baseline is f32-only");
        assert!(!gpu.supports(q8), "`.q8` routes around the GPU too");
    }

    #[test]
    fn max_batch_gates_admission() {
        let caps = Capabilities {
            precisions: vec![Precision::F32],
            max_batch: 8,
        };
        assert!(caps.admits(8));
        assert!(!caps.admits(9));
        // the built-in backends are unbounded
        assert!(Capabilities::of_kind(DeviceKind::Cpu).admits(usize::MAX));
    }

    #[test]
    fn cost_model_interpolates_affine() {
        // intercept 10ms, slope 1ms/image
        let m = CostModel {
            c1_s: 0.011,
            c8_s: 0.018,
        };
        assert!((m.cost_s(1) - 0.011).abs() < 1e-12);
        assert!((m.cost_s(8) - 0.018).abs() < 1e-12);
        assert!((m.cost_s(15) - 0.025).abs() < 1e-12, "extrapolates");
        let lin = CostModel::linear(0.002);
        assert!((lin.cost_s(5) - 0.010).abs() < 1e-12);
    }

    #[test]
    fn eta_charges_queued_batches_at_the_bucket_cost() {
        let m = CostModel {
            c1_s: 0.011,
            c8_s: 0.018,
        };
        assert!((m.eta_s(0, 1) - 0.011).abs() < 1e-12, "idle lane = own cost");
        assert!((m.eta_s(2, 1) - (2.0 * 0.018 + 0.011)).abs() < 1e-12);
        // slack is the budget minus that ETA, signed
        assert!((m.slack_s(0.050, 0, 1) - 0.039).abs() < 1e-12);
        assert!(m.slack_s(0.040, 2, 1) < 0.0, "deep queue turns infeasible");
    }
}
