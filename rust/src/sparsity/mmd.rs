//! Maximum Mean Discrepancy (Gretton et al., JMLR 2012) with the
//! Gaussian kernel and the median-distance bandwidth heuristic — the
//! paper's generative-quality measure for the Fig. 6 sparsity study:
//!
//! `MMD²(μ, ν) = E[k(X,X')] + E[k(Y,Y')] − 2·E[k(X,Y)]`
//!
//! computed between generator samples (P_θ, produced by the PJRT runtime
//! from pruned weights) and ground-truth samples (P_g, the corpus batch
//! exported by `make artifacts`).

use crate::stats::median;

/// Flattened-sample view: `n` vectors of dimension `d`, row-major.
fn row<'a>(data: &'a [f32], d: usize, i: usize) -> &'a [f32] {
    &data[i * d..(i + 1) * d]
}

fn sq_dist(a: &[f32], b: &[f32]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(x, y)| {
            let d = (*x - *y) as f64;
            d * d
        })
        .sum()
}

/// Median pairwise Euclidean distance among ground-truth samples — the
/// kernel bandwidth `σ` (the paper selects "the median euclidean distance
/// between ground truth samples as the bandwidth").
pub fn median_heuristic_bandwidth(truth: &[f32], d: usize) -> f64 {
    let n = truth.len() / d;
    assert!(n >= 2, "need at least two samples for the median heuristic");
    let mut dists = Vec::with_capacity(n * (n - 1) / 2);
    for i in 0..n {
        for j in (i + 1)..n {
            dists.push(sq_dist(row(truth, d, i), row(truth, d, j)).sqrt());
        }
    }
    median(&dists).max(1e-12)
}

/// Gaussian kernel `k(x, y) = exp(−‖x−y‖² / (2σ²))`.
fn kernel(a: &[f32], b: &[f32], sigma: f64) -> f64 {
    (-sq_dist(a, b) / (2.0 * sigma * sigma)).exp()
}

/// MMD estimator configuration.
#[derive(Debug, Clone, Copy)]
pub struct Mmd {
    pub sigma: f64,
}

impl Mmd {
    /// Bandwidth from the ground-truth set via the median heuristic.
    pub fn with_median_bandwidth(truth: &[f32], d: usize) -> Self {
        Mmd {
            sigma: median_heuristic_bandwidth(truth, d),
        }
    }
}

/// Biased (V-statistic) MMD² estimate between sample sets `x` (n×d) and
/// `y` (m×d).  Non-negative by construction.
pub fn mmd_biased(x: &[f32], y: &[f32], d: usize, mmd: &Mmd) -> f64 {
    let n = x.len() / d;
    let m = y.len() / d;
    assert!(n > 0 && m > 0, "empty sample set");
    let mut kxx = 0.0;
    for i in 0..n {
        for j in 0..n {
            kxx += kernel(row(x, d, i), row(x, d, j), mmd.sigma);
        }
    }
    let mut kyy = 0.0;
    for i in 0..m {
        for j in 0..m {
            kyy += kernel(row(y, d, i), row(y, d, j), mmd.sigma);
        }
    }
    let mut kxy = 0.0;
    for i in 0..n {
        for j in 0..m {
            kxy += kernel(row(x, d, i), row(y, d, j), mmd.sigma);
        }
    }
    (kxx / (n * n) as f64 + kyy / (m * m) as f64
        - 2.0 * kxy / (n * m) as f64)
        .max(0.0)
}

/// Unbiased (U-statistic) MMD² estimate (diagonal terms excluded); can be
/// slightly negative for close distributions.
pub fn mmd_unbiased(x: &[f32], y: &[f32], d: usize, mmd: &Mmd) -> f64 {
    let n = x.len() / d;
    let m = y.len() / d;
    assert!(n > 1 && m > 1, "U-statistic needs ≥ 2 samples per set");
    let mut kxx = 0.0;
    for i in 0..n {
        for j in 0..n {
            if i != j {
                kxx += kernel(row(x, d, i), row(x, d, j), mmd.sigma);
            }
        }
    }
    let mut kyy = 0.0;
    for i in 0..m {
        for j in 0..m {
            if i != j {
                kyy += kernel(row(y, d, i), row(y, d, j), mmd.sigma);
            }
        }
    }
    let mut kxy = 0.0;
    for i in 0..n {
        for j in 0..m {
            kxy += kernel(row(x, d, i), row(y, d, j), mmd.sigma);
        }
    }
    kxx / (n * (n - 1)) as f64 + kyy / (m * (m - 1)) as f64
        - 2.0 * kxy / (n * m) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn gaussian_set(n: usize, d: usize, mean: f32, seed: u64) -> Vec<f32> {
        let mut rng = Rng::seed_from_u64(seed);
        (0..n * d)
            .map(|_| mean + rng.range_f32(-1.0, 1.0))
            .collect()
    }

    #[test]
    fn identical_sets_have_zero_biased_mmd_vs_shifted() {
        let d = 8;
        let a = gaussian_set(40, d, 0.0, 1);
        let b = gaussian_set(40, d, 0.0, 2);
        let c = gaussian_set(40, d, 3.0, 3);
        let mmd = Mmd::with_median_bandwidth(&a, d);
        let near = mmd_biased(&a, &b, d, &mmd);
        let far = mmd_biased(&a, &c, d, &mmd);
        assert!(near < far, "near={near} far={far}");
        assert!(far > 0.1);
    }

    #[test]
    fn self_mmd_is_zero() {
        let d = 4;
        let a = gaussian_set(20, d, 0.0, 7);
        let mmd = Mmd { sigma: 1.0 };
        assert!(mmd_biased(&a, &a, d, &mmd) < 1e-12);
        // the U-statistic on shared samples is biased low by O(1/n)
        assert!(mmd_unbiased(&a, &a, d, &mmd).abs() < 0.15);
    }

    #[test]
    fn mmd_grows_with_distribution_shift() {
        let d = 6;
        let truth = gaussian_set(30, d, 0.0, 11);
        let mmd = Mmd::with_median_bandwidth(&truth, d);
        let mut prev = -1.0;
        for shift in [0.0f32, 0.5, 1.0, 2.0, 4.0] {
            let moved = gaussian_set(30, d, shift, 13);
            let v = mmd_biased(&truth, &moved, d, &mmd);
            assert!(v >= prev - 5e-3, "shift {shift}: {v} < {prev}");
            prev = v;
        }
    }

    #[test]
    fn median_bandwidth_positive_and_scale_tracking() {
        let d = 5;
        let a = gaussian_set(20, d, 0.0, 17);
        let wide: Vec<f32> = a.iter().map(|v| v * 10.0).collect();
        let s1 = median_heuristic_bandwidth(&a, d);
        let s2 = median_heuristic_bandwidth(&wide, d);
        assert!(s1 > 0.0);
        assert!((s2 / s1 - 10.0).abs() < 0.5);
    }

    #[test]
    fn unbiased_close_to_biased_for_large_n() {
        let d = 4;
        let a = gaussian_set(60, d, 0.0, 19);
        let b = gaussian_set(60, d, 1.0, 23);
        let mmd = Mmd::with_median_bandwidth(&a, d);
        let bi = mmd_biased(&a, &b, d, &mmd);
        let un = mmd_unbiased(&a, &b, d, &mmd);
        assert!((bi - un).abs() < 0.05, "bi={bi} un={un}");
    }
}
