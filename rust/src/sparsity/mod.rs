//! Sparsity experiments substrate (Section V-C, Fig. 6): magnitude
//! pruning, the Maximum Mean Discrepancy quality metric, and the paper's
//! Eq. 6 latency/quality trade-off score.

mod metric;
mod mmd;
mod prune;

pub use metric::{peak_index, tradeoff_curve, tradeoff_score, TradeoffPoint};
pub use mmd::{mmd_biased, mmd_unbiased, median_heuristic_bandwidth, Mmd};
pub use prune::{magnitude_prune, magnitude_prune_network, prune_threshold};
