//! The paper's Eq. 6 design metric: `(d₀/d_p) × (t₀/t_p)` — the product
//! of the *quality retention* rate (MMD of the dense model over MMD of
//! the pruned model) and the *speed-up* rate (dense latency over pruned
//! latency).  Speed-up grows with sparsity while quality retention
//! shrinks, so the product is concave with an interior peak: the sparsity
//! level that balances image quality against execution time.

/// One point of the Fig. 6 trade-off curve.
#[derive(Debug, Clone, Copy)]
pub struct TradeoffPoint {
    pub sparsity: f64,
    /// System latency at this sparsity (zero-skipping FPGA), seconds.
    pub latency_s: f64,
    /// MMD distance to the ground-truth distribution.
    pub mmd: f64,
    /// FPGA speed-up `t₀ / t_p` (Fig. 6a).
    pub speedup: f64,
    /// Quality retention `d₀ / d_p` (reciprocal of Fig. 6b growth).
    pub quality: f64,
    /// Eq. 6 score.
    pub score: f64,
}

/// Eq. 6 for a single (t_p, d_p) pair against the dense baseline
/// (t₀, d₀).
pub fn tradeoff_score(t0: f64, d0: f64, tp: f64, dp: f64) -> f64 {
    assert!(t0 > 0.0 && tp > 0.0, "latencies must be positive");
    assert!(d0 >= 0.0 && dp >= 0.0, "distances must be non-negative");
    let dp = dp.max(1e-12);
    let d0 = d0.max(1e-12);
    (d0 / dp) * (t0 / tp)
}

/// Build the full trade-off curve from aligned sparsity/latency/MMD
/// series. The first entry is taken as the dense baseline (sparsity 0).
pub fn tradeoff_curve(
    sparsities: &[f64],
    latencies: &[f64],
    mmds: &[f64],
) -> Vec<TradeoffPoint> {
    assert_eq!(sparsities.len(), latencies.len());
    assert_eq!(sparsities.len(), mmds.len());
    assert!(!sparsities.is_empty());
    let t0 = latencies[0];
    let d0 = mmds[0].max(1e-12);
    sparsities
        .iter()
        .zip(latencies)
        .zip(mmds)
        .map(|((&s, &t), &d)| {
            let d = d.max(1e-12);
            TradeoffPoint {
                sparsity: s,
                latency_s: t,
                mmd: d,
                speedup: t0 / t,
                quality: d0 / d,
                score: tradeoff_score(t0, d0, t, d),
            }
        })
        .collect()
}

/// Index of the Eq. 6 peak (the balanced sparsity level).
pub fn peak_index(curve: &[TradeoffPoint]) -> usize {
    curve
        .iter()
        .enumerate()
        .max_by(|(_, a), (_, b)| a.score.partial_cmp(&b.score).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baseline_score_is_one() {
        assert!((tradeoff_score(2.0, 0.5, 2.0, 0.5) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn faster_same_quality_scores_higher() {
        assert!(tradeoff_score(2.0, 0.5, 1.0, 0.5) > 1.0);
    }

    #[test]
    fn worse_quality_same_speed_scores_lower() {
        assert!(tradeoff_score(2.0, 0.5, 2.0, 1.0) < 1.0);
    }

    #[test]
    fn synthetic_concave_curve_has_interior_peak() {
        // latency improves linearly; quality degrades slowly then sharply
        // (the empirical Fig. 6b shape) → interior peak
        let sparsities: Vec<f64> = (0..10).map(|i| i as f64 * 0.1).collect();
        let latencies: Vec<f64> =
            sparsities.iter().map(|s| 1.0 - 0.7 * s).collect();
        let mmds: Vec<f64> = sparsities
            .iter()
            .map(|s| 0.1 * (1.0 + (3.0 * s).powi(4) * 0.1))
            .collect();
        let curve = tradeoff_curve(&sparsities, &latencies, &mmds);
        let peak = peak_index(&curve);
        assert!(peak > 0 && peak < curve.len() - 1, "peak={peak}");
        assert!((curve[0].score - 1.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic]
    fn zero_latency_rejected() {
        tradeoff_score(0.0, 1.0, 1.0, 1.0);
    }
}
