//! Magnitude-based weight pruning (Han et al., 2015), as the paper
//! applies it: zero the smallest-|w| fraction of each layer's weights.

use crate::tensor::Tensor;

/// |w| threshold below which a fraction `frac` of the weights falls.
/// (`frac` = 0 → 0.0 threshold; `frac` = 1 → +∞-ish, everything pruned.)
pub fn prune_threshold(weights: &[f32], frac: f64) -> f32 {
    assert!((0.0..=1.0).contains(&frac), "fraction out of range");
    if weights.is_empty() || frac == 0.0 {
        return 0.0;
    }
    let mut mags: Vec<f32> = weights.iter().map(|w| w.abs()).collect();
    mags.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let k = ((weights.len() as f64) * frac).round() as usize;
    if k == 0 {
        0.0
    } else if k >= mags.len() {
        f32::INFINITY
    } else {
        mags[k - 1]
    }
}

/// Prune one weight tensor in place to the target sparsity; returns the
/// achieved zero fraction.
pub fn magnitude_prune(w: &mut Tensor, frac: f64) -> f64 {
    let thr = prune_threshold(w.data(), frac);
    if frac > 0.0 {
        for v in w.data_mut().iter_mut() {
            if v.abs() <= thr {
                *v = 0.0;
            }
        }
    }
    w.zero_fraction()
}

/// Prune every layer of a network's weight set (biases untouched, as in
/// the paper); returns per-layer achieved sparsity.
pub fn magnitude_prune_network(
    weights: &mut [(Tensor, Vec<f32>)],
    frac: f64,
) -> Vec<f64> {
    weights
        .iter_mut()
        .map(|(w, _b)| magnitude_prune(w, frac))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn threshold_selects_fraction() {
        let w = [0.1f32, -0.2, 0.3, -0.4, 0.5, -0.6, 0.7, -0.8, 0.9, -1.0];
        let thr = prune_threshold(&w, 0.3);
        let below = w.iter().filter(|v| v.abs() <= thr).count();
        assert_eq!(below, 3);
    }

    #[test]
    fn prune_zero_keeps_everything() {
        let mut t = Tensor::from_fn(vec![4, 4], |i| (i as f32) - 8.0);
        let before = t.clone();
        let z = magnitude_prune(&mut t, 0.0);
        // only the pre-existing exact zero stays zero
        assert_eq!(t, before);
        assert!(z < 0.1);
    }

    #[test]
    fn prune_full_zeroes_everything() {
        let mut t = Tensor::from_fn(vec![3, 3], |i| i as f32 + 1.0);
        let z = magnitude_prune(&mut t, 1.0);
        assert_eq!(z, 1.0);
        assert!(t.data().iter().all(|v| *v == 0.0));
    }

    #[test]
    fn prune_is_monotone_and_magnitude_ordered() {
        let base = Tensor::from_fn(vec![100], |i| ((i as f32) - 50.0) / 10.0);
        let mut prev_zero = 0.0;
        for frac in [0.1, 0.3, 0.5, 0.7, 0.9] {
            let mut t = base.clone();
            let z = magnitude_prune(&mut t, frac);
            assert!(z >= prev_zero, "sparsity must grow with fraction");
            assert!((z - frac).abs() < 0.06, "achieved {z} vs target {frac}");
            prev_zero = z;
            // every surviving weight is at least as large as every pruned one
            let surviving_min = t
                .data()
                .iter()
                .zip(base.data())
                .filter(|(v, _)| **v != 0.0)
                .map(|(_, o)| o.abs())
                .fold(f32::INFINITY, f32::min);
            let pruned_max = t
                .data()
                .iter()
                .zip(base.data())
                .filter(|(v, o)| **v == 0.0 && **o != 0.0)
                .map(|(_, o)| o.abs())
                .fold(0.0, f32::max);
            assert!(surviving_min >= pruned_max);
        }
    }

    #[test]
    fn network_prune_spares_biases() {
        let mut net = vec![
            (Tensor::from_fn(vec![2, 2, 2, 2], |i| i as f32 - 8.0), vec![1.0f32, 2.0]),
            (Tensor::from_fn(vec![2, 2, 2, 2], |i| i as f32 * 0.1), vec![3.0f32]),
        ];
        let sparsities = magnitude_prune_network(&mut net, 0.5);
        assert_eq!(sparsities.len(), 2);
        assert_eq!(net[0].1, vec![1.0, 2.0]);
        assert_eq!(net[1].1, vec![3.0]);
        for s in sparsities {
            assert!(s >= 0.4);
        }
    }
}
