//! Table II — per-layer and total GOps/s/W, FPGA vs GPU, mean (σ) over
//! N measured runs (the paper uses 50).
//!
//! FPGA: the cycle-accurate pipeline simulation per layer, with the tiny
//! clock/DDR jitter real boards show.  GPU: the TX1 model with its DVFS
//! thermal state carrying over from run to run (the paper's variance
//! mechanism) plus nvprof measurement noise.

use crate::config::{network_by_name, FpgaBoard, GpuBoard, NetworkCfg, Precision};
use crate::fpga::{self, SimOpts};
use crate::gpu::{self, GpuRunOpts, ThermalThrottle};
use crate::quant::QFormat;
use crate::stats::Summary;
use crate::telemetry::{variation_of, Variation};
use anyhow::Result;
use crate::util::Rng;

/// Per-device measurement rows: one Summary per layer plus the total,
/// with the total's run-to-run variation statistics (CV + bootstrap CI
/// of the mean — the quantitative form of the paper's stability claim)
/// and the per-run whole-network latency samples behind the deadline-
/// attainment restatement of that claim.
#[derive(Debug, Clone)]
pub struct DeviceRows {
    pub per_layer: Vec<Summary>,
    pub total: Summary,
    pub total_var: Variation,
    /// Whole-network latency of each measured run, seconds.
    pub total_time_s: Vec<f64>,
}

impl DeviceRows {
    /// Fraction of measured runs whose whole-network latency met a
    /// per-inference deadline of `budget_s` — the variation verdict as
    /// a deadline verdict: at a budget the stable device clears, the
    /// noisy device's tail misses.
    pub fn attainment_at(&self, budget_s: f64) -> f64 {
        if self.total_time_s.is_empty() {
            return 1.0;
        }
        let met = self
            .total_time_s
            .iter()
            .filter(|t| **t <= budget_s)
            .count();
        met as f64 / self.total_time_s.len() as f64
    }

    /// Mean whole-network latency over the measured runs, seconds.
    pub fn mean_time_s(&self) -> f64 {
        if self.total_time_s.is_empty() {
            return 0.0;
        }
        self.total_time_s.iter().sum::<f64>() / self.total_time_s.len() as f64
    }
}

/// The full Table II for one network.
#[derive(Debug, Clone)]
pub struct Table2Data {
    pub network: String,
    pub fpga: DeviceRows,
    /// The packed-int8 datapath (per-channel q2.6, ×4 MAC lanes per
    /// DSP): the same board re-measured at the narrow precision — the
    /// verdict restated where the FPGA's packing advantage is largest
    /// (the GPU stays f32; its tensor path in this model has no int8
    /// mode to fall back to).
    pub fpga_q8: DeviceRows,
    pub gpu: DeviceRows,
}

/// Run the Table II measurement campaign for one network.
pub fn run_table2(
    network: &str,
    fpga_board: &FpgaBoard,
    gpu_board: &GpuBoard,
    runs: usize,
    seed: u64,
) -> Result<Table2Data> {
    let net = network_by_name(network)?;
    Ok(Table2Data {
        network: network.to_string(),
        fpga: fpga_rows(&net, fpga_board, runs, seed, Precision::F32),
        fpga_q8: fpga_rows(
            &net,
            fpga_board,
            runs,
            seed ^ 0x5851_f42d,
            Precision::Fixed(QFormat::new(8, 6)),
        ),
        gpu: gpu_rows(&net, gpu_board, runs, seed ^ 0x9e3779b9),
    })
}

fn fpga_rows(
    net: &NetworkCfg,
    board: &FpgaBoard,
    runs: usize,
    seed: u64,
    precision: Precision,
) -> DeviceRows {
    let opts: Vec<SimOpts> = net
        .layers
        .iter()
        .map(|_| SimOpts::dense_at(net.tile, precision))
        .collect();
    let base: Vec<fpga::LayerSim> = net
        .layers
        .iter()
        .zip(&opts)
        .map(|(l, o)| fpga::simulate_layer(l, board, o))
        .collect();
    let mut rng = fpga::measurement_rng(seed);
    let mut per_layer_samples: Vec<Vec<f64>> =
        vec![Vec::with_capacity(runs); net.layers.len()];
    let mut total_samples = Vec::with_capacity(runs);
    let mut time_samples = Vec::with_capacity(runs);
    for _ in 0..runs {
        let mut ops = 0u64;
        let mut time = 0.0;
        let mut energy = 0.0;
        for (i, b) in base.iter().enumerate() {
            let m = fpga::measured_run(b, &mut rng);
            per_layer_samples[i].push(m.gops_per_w);
            ops += m.ops;
            time += m.time_s;
            energy += m.time_s * m.power_w;
        }
        let gops = ops as f64 / time / 1e9;
        total_samples.push(gops / (energy / time));
        time_samples.push(time);
    }
    DeviceRows {
        per_layer: per_layer_samples.iter().map(|s| Summary::of(s)).collect(),
        total: Summary::of(&total_samples),
        total_var: variation_of(&total_samples, seed),
        total_time_s: time_samples,
    }
}

fn gpu_rows(
    net: &NetworkCfg,
    board: &GpuBoard,
    runs: usize,
    seed: u64,
) -> DeviceRows {
    let mut throttle = ThermalThrottle::new(*board);
    let mut rng = Rng::seed_from_u64(seed);
    let opts = GpuRunOpts::default();
    let mut per_layer_samples: Vec<Vec<f64>> =
        vec![Vec::with_capacity(runs); net.layers.len()];
    let mut total_samples = Vec::with_capacity(runs);
    let mut time_samples = Vec::with_capacity(runs);
    for _ in 0..runs {
        let layer_runs =
            gpu::simulate_gpu_network(net, board, &opts, &mut throttle, &mut rng);
        let mut ops = 0u64;
        let mut time = 0.0;
        let mut energy = 0.0;
        for (i, r) in layer_runs.iter().enumerate() {
            per_layer_samples[i].push(r.gops_per_w);
            ops += r.ops;
            time += r.time_s;
            energy += r.time_s * r.power_w;
        }
        let gops = ops as f64 / time / 1e9;
        total_samples.push(gops / (energy / time));
        time_samples.push(time);
    }
    DeviceRows {
        per_layer: per_layer_samples.iter().map(|s| Summary::of(s)).collect(),
        total: Summary::of(&total_samples),
        total_var: variation_of(&total_samples, seed),
        total_time_s: time_samples,
    }
}

/// Render in the paper's format ("mean (std)" per cell), plus the
/// run-to-run-variation summary rows (CV and the bootstrap 95% CI of
/// the total's mean) that make the stability claim explicit.
pub fn render(data: &Table2Data) -> String {
    let n = data.fpga.per_layer.len();
    let mut s = format!("{} (GOps/second/Watt)\n        ", data.network);
    for i in 0..n {
        s.push_str(&format!("{:>13}", format!("L{}", i + 1)));
    }
    s.push_str(&format!("{:>13}\n", "Total"));
    let devices = [
        ("FPGA", &data.fpga),
        ("FPGA-q8", &data.fpga_q8),
        ("GPU", &data.gpu),
    ];
    for (name, rows) in devices {
        s.push_str(&format!("{name:<8}"));
        for l in &rows.per_layer {
            s.push_str(&format!("{:>13}", l.cell()));
        }
        s.push_str(&format!("{:>13}\n", rows.total.cell()));
    }
    for (name, rows) in devices {
        let v = &rows.total_var;
        s.push_str(&format!(
            "{name:<8}total cv {:>6.2}%   95% CI of mean [{:.2}, {:.2}]\n",
            v.cv * 100.0,
            v.ci_lo,
            v.ci_hi
        ));
    }
    // the variation rows restated as a deadline row: a per-inference
    // budget 10% above the FPGA's mean latency — headroom the stable
    // FPGA always clears, while the GPU's noisy/thermal tail decides
    // its own attainment
    let budget = 1.1 * data.fpga.mean_time_s();
    s.push_str(&format!(
        "deadline @ {:.2} ms (fpga mean +10%): FPGA att {:>5.1}%   GPU att \
         {:>5.1}%\n",
        budget * 1e3,
        data.fpga.attainment_at(budget) * 100.0,
        data.gpu.attainment_at(budget) * 100.0,
    ));
    // the paper's verdict restated at the packed-int8 datapath: ×4 MAC
    // lanes per DSP widen the FPGA's efficiency lead over the f32 GPU
    s.push_str(&format!(
        "verdict @ q8: FPGA int8 {:.2} vs GPU f32 {:.2} GOps/s/W — \
         FPGA leads {:.1}x (f32 lead {:.1}x)\n",
        data.fpga_q8.total.mean,
        data.gpu.total.mean,
        data.fpga_q8.total.mean / data.gpu.total.mean,
        data.fpga.total.mean / data.gpu.total.mean,
    ));
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{JETSON_TX1, PYNQ_Z2};

    fn data(net: &str) -> Table2Data {
        run_table2(net, &PYNQ_Z2, &JETSON_TX1, 50, 42).unwrap()
    }

    #[test]
    fn paper_shape_mnist() {
        let d = data("mnist");
        // headline: FPGA wins the total with far lower variance
        assert!(
            d.fpga.total.mean > d.gpu.total.mean,
            "FPGA {} vs GPU {}",
            d.fpga.total.mean,
            d.gpu.total.mean
        );
        assert!(d.fpga.total.std * 5.0 < d.gpu.total.std.max(1e-9));
        // the variation rows say the same thing as CVs and CIs
        assert!(
            d.fpga.total_var.cv * 5.0 < d.gpu.total_var.cv,
            "FPGA cv {} vs GPU cv {}",
            d.fpga.total_var.cv,
            d.gpu.total_var.cv
        );
        assert!(d.fpga.total_var.ci_lo <= d.fpga.total_var.mean);
        assert!(d.fpga.total_var.mean <= d.fpga.total_var.ci_hi);
        let s = render(&d);
        assert!(s.contains("total cv"), "{s}");
        assert!(s.contains("95% CI"), "{s}");
    }

    #[test]
    fn paper_shape_celeba() {
        let d = data("celeba");
        assert!(d.fpga.total.mean > d.gpu.total.mean);
        // the unified T_OH leaves some CelebA layers GPU-favoured
        let gpu_wins = d
            .fpga
            .per_layer
            .iter()
            .zip(&d.gpu.per_layer)
            .filter(|(f, g)| g.mean > f.mean)
            .count();
        assert!(
            gpu_wins >= 1,
            "at least one CelebA layer must favour the GPU (paper: L2, L4)"
        );
        // ...but not all of them
        assert!(gpu_wins < d.fpga.per_layer.len());
    }

    #[test]
    fn deadline_attainment_restates_the_stability_claim() {
        let d = data("mnist");
        assert_eq!(d.fpga.total_time_s.len(), 50, "one sample per run");
        // at a budget 10% above the FPGA's own mean, the jitter-free
        // FPGA always makes it; the GPU's noisy tail decides its fate
        let budget = 1.1 * d.fpga.mean_time_s();
        let fpga_att = d.fpga.attainment_at(budget);
        let gpu_att = d.gpu.attainment_at(budget);
        assert_eq!(fpga_att, 1.0, "±0.6% jitter inside a 10% margin");
        assert!(
            fpga_att >= gpu_att,
            "FPGA attainment {fpga_att} must be >= GPU {gpu_att} at equal \
             deadlines"
        );
        // attainment is monotone in the budget and hits the extremes
        assert_eq!(d.gpu.attainment_at(f64::INFINITY), 1.0);
        assert_eq!(d.gpu.attainment_at(0.0), 0.0);
        let s = render(&d);
        assert!(s.contains("deadline @"), "{s}");
    }

    #[test]
    fn determinism_given_seed() {
        let a = data("mnist");
        let b = data("mnist");
        assert_eq!(a.fpga.total.mean, b.fpga.total.mean);
        assert_eq!(a.fpga_q8.total.mean, b.fpga_q8.total.mean);
        assert_eq!(a.gpu.total.mean, b.gpu.total.mean);
    }

    #[test]
    fn q8_datapath_widens_the_verdict() {
        for net in ["mnist", "celeba"] {
            let d = data(net);
            // packed int8: same ops, fewer cycles, no extra DSPs — the
            // efficiency lead over both the f32 FPGA and the GPU grows
            assert!(
                d.fpga_q8.total.mean > d.fpga.total.mean,
                "{net}: q8 {} vs f32 {}",
                d.fpga_q8.total.mean,
                d.fpga.total.mean
            );
            assert!(d.fpga_q8.total.mean > d.gpu.total.mean);
            // and the FPGA's stability story carries over to int8
            assert!(
                d.fpga_q8.total_var.cv * 5.0 < d.gpu.total_var.cv,
                "{net}: q8 cv {} vs GPU cv {}",
                d.fpga_q8.total_var.cv,
                d.gpu.total_var.cv
            );
        }
        let s = render(&data("mnist"));
        assert!(s.contains("FPGA-q8"), "{s}");
        assert!(s.contains("verdict @ q8"), "{s}");
    }

    #[test]
    fn render_has_layers_and_total() {
        let s = render(&data("mnist"));
        assert!(s.contains("L1") && s.contains("L3") && s.contains("Total"));
        assert!(s.contains("FPGA") && s.contains("GPU"));
    }
}
