//! Fig. 5 — design-space exploration plots: every legal square tiling
//! factor's (CTC ratio, attainable GOps/s) point, the peak-bandwidth
//! slope, and the selected optimum.

use crate::config::{network_by_name, FpgaBoard};
use crate::dse::{explore, optimal_tile, DesignPoint};
use anyhow::Result;

/// The Fig. 5 dataset for one network.
#[derive(Debug, Clone)]
pub struct Fig5Data {
    pub network: String,
    pub points: Vec<DesignPoint>,
    pub optimal: usize, // index into points
    pub peak_bw_gbs: f64,
    pub peak_gops: f64,
}

/// Regenerate Fig. 5 for one network.
pub fn run_fig5(network: &str, board: &FpgaBoard) -> Result<Fig5Data> {
    let net = network_by_name(network)?;
    let points = explore(&net, board);
    let best = optimal_tile(&points)
        .ok_or_else(|| anyhow::anyhow!("no feasible design point"))?;
    let optimal = points
        .iter()
        .position(|p| p.tile == best.tile)
        .expect("optimum comes from the same vector");
    Ok(Fig5Data {
        network: network.to_string(),
        points,
        optimal,
        peak_bw_gbs: board.stream_bw_bytes / 1e9,
        peak_gops: board.peak_gops(),
    })
}

/// Render the figure as a data table (one row per design point; the plot
/// series the paper draws).
pub fn render(data: &Fig5Data) -> String {
    let mut s = format!(
        "{}: peak BW {:.2} GB/s, peak compute {:.1} GOps/s\n\
         {:>5} {:>10} {:>12} {:>12} {:>12}  legal  optimal\n",
        data.network,
        data.peak_bw_gbs,
        data.peak_gops,
        "T_OH",
        "CTC",
        "comp GOps/s",
        "att GOps/s",
        "BW req GB/s",
    );
    for (i, p) in data.points.iter().enumerate() {
        s.push_str(&format!(
            "{:>5} {:>10.2} {:>12.2} {:>12.2} {:>12.2}  {:>5}  {}\n",
            p.tile,
            p.ctc,
            p.comp_roof_gops,
            p.attainable_gops,
            p.bw_required / 1e9,
            if p.fits_resources && p.bandwidth_feasible {
                "yes"
            } else if p.fits_resources {
                "bw!"
            } else {
                "no"
            },
            if i == data.optimal { "  <== T_OH*" } else { "" },
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PYNQ_Z2;

    /// The paper selects T_OH* = 12 (MNIST) and 24 (CelebA).  Our roofline
    /// model reproduces the *methodology*; its exact tie-break lands on a
    /// neighbouring point of the same feasible plateau (Vivado-level
    /// constraints the paper never enumerates bound their candidate set —
    /// see EXPERIMENTS.md §Fig5).  What must hold: the paper's choice is
    /// feasible, right of the bandwidth slope, and within the top tier of
    /// attainable throughput.
    #[test]
    fn paper_tiles_sit_on_the_feasible_plateau() {
        for (net, paper_t) in [("mnist", 12usize), ("celeba", 24usize)] {
            let d = run_fig5(net, &PYNQ_Z2).unwrap();
            let p = d
                .points
                .iter()
                .find(|p| p.tile == paper_t)
                .expect("paper tile must be a legal candidate");
            assert!(p.fits_resources, "{net}: paper tile must fit");
            // the design is memory-bound at every tile size (the paper's
            // Table II magnitudes are far below the 32 GOps/s compute
            // roof); the paper tile must clear the *left* of the slope —
            // i.e. deliver far more than the halo-thrashed small tiles
            let smallest = d.points.first().unwrap();
            assert!(
                p.attainable_gops > 2.0 * smallest.attainable_gops,
                "{net}: paper tile must beat the bandwidth-starved region"
            );
            let best = &d.points[d.optimal];
            assert!(
                p.attainable_gops >= 0.5 * best.attainable_gops,
                "{net}: paper tile attainable {} vs model optimum {}",
                p.attainable_gops,
                best.attainable_gops
            );
        }
    }

    #[test]
    fn small_tiles_are_bandwidth_starved() {
        // the left side of Fig. 5: tiny tiles refetch halos so often that
        // the CTC·BW roof collapses below the compute roof
        for net in ["mnist", "celeba"] {
            let d = run_fig5(net, &PYNQ_Z2).unwrap();
            let smallest = d.points.first().unwrap();
            let best = &d.points[d.optimal];
            assert!(smallest.attainable_gops < best.attainable_gops);
            assert!(smallest.ctc < best.ctc);
        }
    }

    #[test]
    fn optimum_dominates_feasible_points() {
        for net in ["mnist", "celeba"] {
            let d = run_fig5(net, &PYNQ_Z2).unwrap();
            let best = &d.points[d.optimal];
            for p in &d.points {
                if p.fits_resources {
                    assert!(
                        best.attainable_gops >= p.attainable_gops - 1e-9,
                        "{net}: T={} beats the chosen optimum",
                        p.tile
                    );
                }
            }
        }
    }

    #[test]
    fn render_marks_optimum() {
        let d = run_fig5("mnist", &PYNQ_Z2).unwrap();
        assert!(render(&d).contains("T_OH*"));
    }
}
