//! `edgedcnn bench` — the regression-defended microbenchmark suite
//! over the numeric hot path (schema v2, `BENCH_edgedcnn.json`).
//!
//! One fixed deconvolution geometry (a smoke and a full variant) is
//! timed through every kernel × precision cell: the three production
//! kernels (`standard`, `reverse-loop`, `tdc`) plus the **frozen
//! scalar reference** of the reverse loop
//! ([`crate::deconv::deconv_reverse_loop_ref`]) in `f32`, packed-int8
//! Q2.6 (`q8`), Q8.8 and Q16.16.  Each cell records robust
//! [`TrialStats`] (median + MAD +
//! p99 over individually timed trials) and the derived img/s and
//! ns/MAC figures; a serving section drives each backend kind through
//! the coordinator over synthetic artifacts and records its img/s and
//! request p99.
//!
//! The regression policy has two tiers:
//!
//! * **Ratio gates** — `reverse-loop` must beat its own frozen scalar
//!   reference by the baseline's `min_speedup_*` factors (the ISSUE's
//!   ≥1.5× f32 / ≥1.2× fixed-point trajectory).  Both sides are
//!   measured *in the same run on the same machine*, so the gate is
//!   self-normalizing and always enforced.
//! * **Absolute medians** — fresh vs baseline per row, tolerance
//!   `max(50%, 8·(rel_MAD_base + rel_MAD_fresh))` so a noisy machine
//!   widens its own band.  Skipped while the committed baseline is
//!   marked `provisional` (authored without a measured run); CI
//!   uploads every fresh suite so a maintainer can promote one to a
//!   measured baseline by committing it with `provisional: false`.
//!
//! Serving rows are informational (queueing latencies are far noisier
//! than kernel medians); they ride the JSON so the trajectory is
//! visible, but never gate.

use crate::artifacts::write_synthetic;
use crate::config::{BackendCfg, DeviceKind};
use crate::coordinator::{BatcherConfig, Coordinator, CoordinatorConfig};
use crate::deconv::{
    deconv_reverse_loop, deconv_reverse_loop_blocked,
    deconv_reverse_loop_ref, deconv_standard, deconv_tdc, ReverseLoopOpts,
};
use crate::quant::{Element, QFormat, Q16_16, Q2_6, Q8_8};
use crate::tensor::TensorT;
use crate::util::{
    escape_json, parse_json, Bencher, Json, Rng, TempDir, TrialStats,
    WorkerPool,
};
use anyhow::{bail, Context, Result};
use std::time::Duration;

/// Schema version of `BENCH_edgedcnn.json`.  v1 was the ad-hoc CI
/// artifact the bench-smoke job emitted from the criterion-stand-in
/// binaries; v2 is this suite (rows × precisions, robust statistics,
/// the provisional flag and the speedup gates).
pub const BENCH_SCHEMA_VERSION: u64 = 2;

/// Default ratio gates: how much faster the restructured reverse loop
/// must be than its frozen scalar reference, same run, same machine.
pub const MIN_SPEEDUP_F32: f64 = 1.5;
pub const MIN_SPEEDUP_FIXED: f64 = 1.2;

/// Within-run ceiling on `blocked-*` vs `reverse-loop-*` medians: the
/// cache-blocked dispatch (tune table or static default, host pool)
/// may cost at most this factor over the plain tiled kernel — blocking
/// must never regress the hot path it restructures.  Like the speedup
/// gates, both sides are measured in the same run, so the gate is
/// always enforced; the comparison widens by the same MAD-scaled noise
/// band the absolute tier uses.
pub const MAX_BLOCKED_RATIO: f64 = 1.10;

/// Knobs of one suite run.
#[derive(Debug, Clone)]
pub struct BenchOpts {
    /// Small geometry + few trials (the CI mode).
    pub smoke: bool,
    /// Timed trials per cell (each timed individually).
    pub trials: usize,
    /// Untimed warm-up iterations per cell.
    pub warmup: usize,
    /// Measure the serving section (coordinator over synthetic
    /// artifacts, one row per backend kind).
    pub serving: bool,
}

impl BenchOpts {
    pub fn new(smoke: bool) -> Self {
        BenchOpts {
            smoke,
            trials: if smoke { 5 } else { 20 },
            warmup: if smoke { 1 } else { 3 },
            serving: true,
        }
    }
}

/// One kernel × precision cell.
#[derive(Debug, Clone, PartialEq)]
pub struct KernelRow {
    /// `<kernel>-<precision>`, e.g. `reverse-loop-q8.8`.
    pub name: String,
    /// Batch images generated per iteration.
    pub images: usize,
    /// Dense MACs per iteration (zero-skip off), from the reverse
    /// loop's own [`crate::deconv::OpStats`] accounting.
    pub macs: u64,
    pub stats: TrialStats,
}

impl KernelRow {
    pub fn img_per_s(&self) -> f64 {
        self.images as f64 / self.stats.median_s
    }

    pub fn ns_per_mac(&self) -> f64 {
        self.stats.median_s * 1e9 / self.macs as f64
    }
}

/// One serving-path row (informational, never gated).
#[derive(Debug, Clone, PartialEq)]
pub struct ServingRow {
    /// `serve-<backend>`, e.g. `serve-fpga` — or `serve-fpga-q8` for
    /// the packed-int8 `.q8` twin.
    pub name: String,
    pub images_per_s: f64,
    pub p99_s: f64,
}

/// A complete suite run (or a committed baseline).
#[derive(Debug, Clone, PartialEq)]
pub struct BenchSuite {
    /// `true` = authored without a measured run on the target machine;
    /// absolute-median comparisons are skipped against it (the ratio
    /// gates still apply, they are within-run).
    pub provisional: bool,
    pub smoke: bool,
    pub min_speedup_f32: f64,
    pub min_speedup_fixed: f64,
    /// Ceiling on the within-run `blocked-*` / `reverse-loop-*` median
    /// ratio.  Additive schema field: absent in pre-blocking suites and
    /// defaulted to [`MAX_BLOCKED_RATIO`] on read.
    pub max_blocked_ratio: f64,
    pub rows: Vec<KernelRow>,
    pub serving: Vec<ServingRow>,
}

/// Fixed benchmark geometry (one deconvolution layer).
struct Geo {
    n: usize,
    c_in: usize,
    c_out: usize,
    i: usize,
    k: usize,
    s: usize,
    p: usize,
    tile: usize,
}

impl Geo {
    fn new(smoke: bool) -> Self {
        if smoke {
            // mnist-layer-2-like, small enough for CI
            Geo { n: 2, c_in: 8, c_out: 8, i: 7, k: 4, s: 2, p: 1, tile: 12 }
        } else {
            Geo {
                n: 4,
                c_in: 32,
                c_out: 32,
                i: 14,
                k: 4,
                s: 2,
                p: 1,
                tile: 12,
            }
        }
    }
}

/// Time every kernel at one precision and append the four rows.
fn rows_for<T: Element>(
    suffix: &str,
    g: &Geo,
    opts: &BenchOpts,
    rows: &mut Vec<KernelRow>,
) {
    // same f32 value stream for every precision (comparability)
    let mut rng = Rng::seed_from_u64(0xBE9C4);
    let x = TensorT::<T>::from_fn(vec![g.n, g.c_in, g.i, g.i], |_| {
        T::from_f32(rng.range_f32(-1.0, 1.0))
    });
    let w = TensorT::<T>::from_fn(vec![g.c_in, g.c_out, g.k, g.k], |_| {
        T::from_f32(rng.range_f32(-0.5, 0.5))
    });
    let b: Vec<T> = (0..g.c_out)
        .map(|_| T::from_f32(rng.range_f32(-0.1, 0.1)))
        .collect();
    let rl = ReverseLoopOpts { tile: g.tile, zero_skip: false };
    // dense MAC count for the ns/MAC column (identical across kernels:
    // all three visit the same multiset of taps)
    let (_, dense) = deconv_reverse_loop(&x, &w, &b, g.s, g.p, rl);
    let macs = dense.macs_issued;

    let bench =
        |name: &str| Bencher::new(name).iters(opts.trials).warmup(opts.warmup);
    let mut push = |name: String, stats: TrialStats| {
        rows.push(KernelRow { name, images: g.n, macs, stats });
    };
    push(
        format!("standard-{suffix}"),
        bench("standard")
            .run_trials(|| deconv_standard(&x, &w, &b, g.s, g.p)),
    );
    push(
        format!("reverse-loop-{suffix}"),
        bench("reverse-loop")
            .run_trials(|| deconv_reverse_loop(&x, &w, &b, g.s, g.p, rl)),
    );
    push(
        format!("tdc-{suffix}"),
        bench("tdc").run_trials(|| deconv_tdc(&x, &w, &b, g.s, g.p)),
    );
    push(
        format!("reverse-loop-ref-{suffix}"),
        bench("reverse-loop-ref")
            .run_trials(|| deconv_reverse_loop_ref(&x, &w, &b, g.s, g.p, rl)),
    );
    // the cache-blocked production dispatch: schedule from the tune
    // table when one is persisted, static default otherwise, host pool
    let pool = WorkerPool::with_default_parallelism();
    push(
        format!("blocked-{suffix}"),
        bench("blocked").run_trials(|| {
            deconv_reverse_loop_blocked(&x, &w, &b, g.s, g.p, false, None, &pool)
        }),
    );
}

/// Drive one backend kind through the coordinator and record its row.
/// `q8` serves the packed-int8 `mnist.q8` twin instead of f32 (only
/// meaningful for kinds whose capability set admits fixed-point).
fn serving_row(
    dir: &std::path::Path,
    kind: DeviceKind,
    smoke: bool,
    q8: bool,
) -> Result<ServingRow> {
    let coord = Coordinator::start(CoordinatorConfig {
        artifacts_dir: dir.to_path_buf(),
        networks: vec!["mnist".to_string()],
        batcher: BatcherConfig::default(),
        backends: BackendCfg { kinds: vec![kind], ..Default::default() },
        executors: 0,
        quant: None,
        quant8: q8.then_some(QFormat::new(8, 6)),
        shard_batches: false,
        clock: None,
    })
    .with_context(|| format!("starting a {} lane", kind.as_str()))?;
    let network = if q8 { "mnist.q8" } else { "mnist" };
    let report = coord.serve_workload(&crate::coordinator::WorkloadSpec {
        network: network.to_string(),
        requests: if smoke { 8 } else { 32 },
        images_per_request: 2,
        interarrival: Duration::from_millis(1),
        seed: 42,
    })?;
    Ok(ServingRow {
        name: if q8 {
            format!("serve-{}-q8", kind.as_str())
        } else {
            format!("serve-{}", kind.as_str())
        },
        images_per_s: report.images_per_s,
        p99_s: report.latency.p99_s,
    })
}

/// Run the whole suite.  The result is a *measured* suite
/// (`provisional: false`).
pub fn run_bench(opts: &BenchOpts) -> Result<BenchSuite> {
    let g = Geo::new(opts.smoke);
    let mut rows = Vec::with_capacity(20);
    rows_for::<f32>("f32", &g, opts, &mut rows);
    rows_for::<Q2_6>("q8", &g, opts, &mut rows);
    rows_for::<Q8_8>("q8.8", &g, opts, &mut rows);
    rows_for::<Q16_16>("q16.16", &g, opts, &mut rows);

    let mut serving = Vec::new();
    if opts.serving {
        let dir = TempDir::new()?;
        write_synthetic(dir.path(), &["mnist"], 2, 17)?;
        for kind in [DeviceKind::Fpga, DeviceKind::Gpu, DeviceKind::Cpu] {
            serving.push(serving_row(dir.path(), kind, opts.smoke, false)?);
        }
        // the packed-int8 twin on the FPGA lane (routes around the
        // f32-only GPU, so only the fixed-point-capable kind gets a row)
        serving.push(serving_row(dir.path(), DeviceKind::Fpga, opts.smoke, true)?);
    }
    Ok(BenchSuite {
        provisional: false,
        smoke: opts.smoke,
        min_speedup_f32: MIN_SPEEDUP_F32,
        min_speedup_fixed: MIN_SPEEDUP_FIXED,
        max_blocked_ratio: MAX_BLOCKED_RATIO,
        rows,
        serving,
    })
}

impl BenchSuite {
    /// Median-over-median speedup of the restructured reverse loop vs
    /// its frozen scalar reference at one precision suffix.
    pub fn speedup(&self, suffix: &str) -> Option<f64> {
        let find = |name: String| {
            self.rows.iter().find(|r| r.name == name)
        };
        let vec = find(format!("reverse-loop-{suffix}"))?;
        let reference = find(format!("reverse-loop-ref-{suffix}"))?;
        Some(reference.stats.median_s / vec.stats.median_s)
    }

    /// Within-run cost of the cache-blocked dispatch over the plain
    /// tiled kernel at one precision suffix, with the two rows' MAD
    /// noise figures (for the gate's tolerance band).
    pub fn blocked_ratio(&self, suffix: &str) -> Option<(f64, f64)> {
        let find = |name: String| {
            self.rows.iter().find(|r| r.name == name)
        };
        let blocked = find(format!("blocked-{suffix}"))?;
        let rl = find(format!("reverse-loop-{suffix}"))?;
        Some((
            blocked.stats.median_s / rl.stats.median_s,
            blocked.stats.rel_mad() + rl.stats.rel_mad(),
        ))
    }

    pub fn to_json(&self) -> String {
        let rows = self
            .rows
            .iter()
            .map(|r| {
                format!(
                    "    {{\"name\": \"{}\", \"images\": {}, \"macs\": {}, \
                     \"trials\": {}, \"median_s\": {}, \"mad_s\": {}, \
                     \"p99_s\": {}, \"min_s\": {}, \"img_per_s\": {}, \
                     \"ns_per_mac\": {}}}",
                    escape_json(&r.name),
                    r.images,
                    r.macs,
                    r.stats.trials,
                    r.stats.median_s,
                    r.stats.mad_s,
                    r.stats.p99_s,
                    r.stats.min_s,
                    r.img_per_s(),
                    r.ns_per_mac(),
                )
            })
            .collect::<Vec<_>>()
            .join(",\n");
        let serving = self
            .serving
            .iter()
            .map(|s| {
                format!(
                    "    {{\"name\": \"{}\", \"images_per_s\": {}, \
                     \"p99_s\": {}}}",
                    escape_json(&s.name),
                    s.images_per_s,
                    s.p99_s,
                )
            })
            .collect::<Vec<_>>()
            .join(",\n");
        format!(
            "{{\n  \"version\": {BENCH_SCHEMA_VERSION},\n  \
             \"provisional\": {},\n  \"smoke\": {},\n  \
             \"min_speedup_f32\": {},\n  \"min_speedup_fixed\": {},\n  \
             \"max_blocked_ratio\": {},\n  \
             \"rows\": [\n{}\n  ],\n  \"serving\": [\n{}\n  ]\n}}\n",
            self.provisional,
            self.smoke,
            self.min_speedup_f32,
            self.min_speedup_fixed,
            self.max_blocked_ratio,
            rows,
            serving,
        )
    }

    pub fn from_json(s: &str) -> Result<BenchSuite> {
        fn as_bool(j: &Json) -> Result<bool> {
            match j {
                Json::Bool(b) => Ok(*b),
                other => bail!("expected bool, got {other:?}"),
            }
        }
        let v = parse_json(s).context("parsing bench suite JSON")?;
        let version = v.req("version")?.as_u64()?;
        if version != BENCH_SCHEMA_VERSION {
            bail!(
                "bench schema version {version} != {BENCH_SCHEMA_VERSION} \
                 (refusing to compare across schemas)"
            );
        }
        let rows = v
            .req("rows")?
            .as_arr()?
            .iter()
            .map(|r| {
                Ok(KernelRow {
                    name: r.req("name")?.as_str()?.to_string(),
                    images: r.req("images")?.as_usize()?,
                    macs: r.req("macs")?.as_u64()?,
                    stats: TrialStats {
                        trials: r.req("trials")?.as_usize()?,
                        median_s: r.req("median_s")?.as_f64()?,
                        mad_s: r.req("mad_s")?.as_f64()?,
                        p99_s: r.req("p99_s")?.as_f64()?,
                        min_s: r.req("min_s")?.as_f64()?,
                    },
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let serving = v
            .req("serving")?
            .as_arr()?
            .iter()
            .map(|r| {
                Ok(ServingRow {
                    name: r.req("name")?.as_str()?.to_string(),
                    images_per_s: r.req("images_per_s")?.as_f64()?,
                    p99_s: r.req("p99_s")?.as_f64()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(BenchSuite {
            provisional: as_bool(v.req("provisional")?)?,
            smoke: as_bool(v.req("smoke")?)?,
            min_speedup_f32: v.req("min_speedup_f32")?.as_f64()?,
            min_speedup_fixed: v.req("min_speedup_fixed")?.as_f64()?,
            // additive in schema v2: pre-blocking baselines lack it
            max_blocked_ratio: match v.get("max_blocked_ratio") {
                Some(x) => x.as_f64()?,
                None => MAX_BLOCKED_RATIO,
            },
            rows,
            serving,
        })
    }

    /// Human-readable table (the default `edgedcnn bench` output).
    pub fn render(&self) -> String {
        let mut out = format!(
            "== edgedcnn bench ({}{}) ==\n{:<24} {:>11} {:>9} {:>11} \
             {:>9} {:>9}\n",
            if self.smoke { "smoke" } else { "full" },
            if self.provisional { ", provisional" } else { "" },
            "row",
            "median ms",
            "mad ms",
            "p99 ms",
            "img/s",
            "ns/MAC",
        );
        for r in &self.rows {
            out.push_str(&format!(
                "{:<24} {:>11.4} {:>9.4} {:>11.4} {:>9.1} {:>9.3}\n",
                r.name,
                r.stats.median_s * 1e3,
                r.stats.mad_s * 1e3,
                r.stats.p99_s * 1e3,
                r.img_per_s(),
                r.ns_per_mac(),
            ));
        }
        for suffix in ["f32", "q8", "q8.8", "q16.16"] {
            if let Some(sp) = self.speedup(suffix) {
                let gate = if suffix == "f32" {
                    self.min_speedup_f32
                } else {
                    self.min_speedup_fixed
                };
                out.push_str(&format!(
                    "speedup reverse-loop-{suffix} vs ref: {sp:.2}x \
                     (gate {gate:.2}x)\n",
                ));
            }
            if let Some((ratio, _)) = self.blocked_ratio(suffix) {
                out.push_str(&format!(
                    "ratio blocked-{suffix} vs reverse-loop: {ratio:.2} \
                     (gate {:.2})\n",
                    self.max_blocked_ratio,
                ));
            }
        }
        for s in &self.serving {
            out.push_str(&format!(
                "{:<24} {:>9.1} img/s  p99 {:>8.3} ms\n",
                s.name,
                s.images_per_s,
                s.p99_s * 1e3,
            ));
        }
        out
    }
}

/// Compare a fresh suite against the committed baseline.  Returns the
/// rendered comparison on success; any tripped gate is an `Err` (the
/// CLI exits nonzero, failing the CI job).
pub fn compare_suites(base: &BenchSuite, fresh: &BenchSuite) -> Result<String> {
    let mut out = String::new();
    let mut failures: Vec<String> = Vec::new();

    // ratio gates: within-run, always enforced, thresholds come off the
    // committed baseline (the defended trajectory)
    for suffix in ["f32", "q8", "q8.8", "q16.16"] {
        let gate = if suffix == "f32" {
            base.min_speedup_f32
        } else {
            base.min_speedup_fixed
        };
        match fresh.speedup(suffix) {
            Some(sp) if sp >= gate => out.push_str(&format!(
                "PASS speedup reverse-loop-{suffix}: {sp:.2}x >= {gate:.2}x\n"
            )),
            Some(sp) => failures.push(format!(
                "speedup reverse-loop-{suffix}: {sp:.2}x < gate {gate:.2}x"
            )),
            None => failures.push(format!(
                "fresh suite is missing the reverse-loop-{suffix} rows"
            )),
        }
    }

    // blocked-dispatch ratio gate: within-run like the speedups, the
    // MAD noise of both rows widening the band the same way the
    // absolute tier does
    for suffix in ["f32", "q8", "q8.8", "q16.16"] {
        match fresh.blocked_ratio(suffix) {
            Some((ratio, rel_mad)) => {
                let band = base.max_blocked_ratio + 8.0 * rel_mad;
                if ratio <= band {
                    out.push_str(&format!(
                        "PASS ratio blocked-{suffix}: {ratio:.2} <= \
                         {band:.2}\n"
                    ));
                } else {
                    failures.push(format!(
                        "ratio blocked-{suffix}: {ratio:.2} > gate \
                         {band:.2} (blocking regressed the hot path)"
                    ));
                }
            }
            None => failures.push(format!(
                "fresh suite is missing the blocked-{suffix} rows"
            )),
        }
    }

    // absolute medians, vs a *measured* baseline only
    if base.provisional {
        out.push_str(
            "baseline is provisional — absolute-median comparisons skipped \
             (commit a measured run with \"provisional\": false to arm \
             them)\n",
        );
    } else {
        for f in &fresh.rows {
            let Some(b) = base.rows.iter().find(|b| b.name == f.name) else {
                out.push_str(&format!("NEW  {} (no baseline row)\n", f.name));
                continue;
            };
            let tol =
                0.50f64.max(8.0 * (b.stats.rel_mad() + f.stats.rel_mad()));
            let ratio = f.stats.median_s / b.stats.median_s;
            if ratio > 1.0 + tol {
                failures.push(format!(
                    "{}: median {:.4} ms vs baseline {:.4} ms \
                     ({:.0}% over, tolerance {:.0}%)",
                    f.name,
                    f.stats.median_s * 1e3,
                    b.stats.median_s * 1e3,
                    (ratio - 1.0) * 100.0,
                    tol * 100.0,
                ));
            } else if ratio < 1.0 - tol {
                out.push_str(&format!(
                    "FASTER {}: {:.2}x below baseline — consider \
                     re-baselining\n",
                    f.name,
                    1.0 / ratio,
                ));
            } else {
                out.push_str(&format!(
                    "PASS {}: median within {:.0}% of baseline\n",
                    f.name,
                    tol * 100.0,
                ));
            }
        }
    }

    // serving rows: informational only (queueing latencies are noisy)
    for s in &fresh.serving {
        out.push_str(&format!(
            "info {}: {:.1} img/s  p99 {:.3} ms\n",
            s.name,
            s.images_per_s,
            s.p99_s * 1e3,
        ));
    }

    if failures.is_empty() {
        Ok(out)
    } else {
        bail!("bench regression:\n{}\n\n{out}", failures.join("\n"));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(name: &str, median: f64, mad: f64) -> KernelRow {
        KernelRow {
            name: name.to_string(),
            images: 2,
            macs: 1000,
            stats: TrialStats {
                trials: 5,
                median_s: median,
                mad_s: mad,
                p99_s: median,
                min_s: median,
            },
        }
    }

    fn suite(rows: Vec<KernelRow>, provisional: bool) -> BenchSuite {
        BenchSuite {
            provisional,
            smoke: true,
            min_speedup_f32: MIN_SPEEDUP_F32,
            min_speedup_fixed: MIN_SPEEDUP_FIXED,
            max_blocked_ratio: MAX_BLOCKED_RATIO,
            rows,
            serving: vec![ServingRow {
                name: "serve-fpga".to_string(),
                images_per_s: 120.0,
                p99_s: 0.004,
            }],
        }
    }

    /// Every speedup gate passing at exactly the stated margins.
    fn passing_rows() -> Vec<KernelRow> {
        let mut rows = Vec::new();
        for suffix in ["f32", "q8", "q8.8", "q16.16"] {
            rows.push(row(&format!("standard-{suffix}"), 2e-3, 1e-5));
            rows.push(row(&format!("reverse-loop-{suffix}"), 1e-3, 1e-5));
            rows.push(row(&format!("tdc-{suffix}"), 2e-3, 1e-5));
            rows.push(row(&format!("reverse-loop-ref-{suffix}"), 3e-3, 1e-5));
            // blocked at 1.05x the plain loop: inside the 1.10 gate
            rows.push(row(&format!("blocked-{suffix}"), 1.05e-3, 1e-5));
        }
        rows
    }

    #[test]
    fn json_roundtrips_and_refuses_other_schemas() {
        let s = suite(passing_rows(), true);
        let json = s.to_json();
        let back = BenchSuite::from_json(&json).unwrap();
        assert_eq!(back, s);
        assert_eq!(back.to_json(), json, "stable re-serialization");
        let v9 = json.replacen("\"version\": 2", "\"version\": 9", 1);
        let err = BenchSuite::from_json(&v9).unwrap_err().to_string();
        assert!(err.contains("schema version 9"), "{err}");
        assert!(BenchSuite::from_json("{}").is_err());
    }

    #[test]
    fn speedup_gates_are_enforced_even_against_provisional_baselines() {
        let base = suite(passing_rows(), true);
        let fresh = suite(passing_rows(), false);
        let report = compare_suites(&base, &fresh).unwrap();
        assert!(report.contains("PASS speedup reverse-loop-f32: 3.00x"));
        assert!(report.contains("provisional"));

        // slow the vectorized f32 loop to a 1.2x speedup: under the
        // 1.5x f32 gate even though the fixed gates still pass
        let mut slow = passing_rows();
        slow.iter_mut()
            .filter(|r| r.name == "reverse-loop-f32")
            .for_each(|r| r.stats.median_s = 2.5e-3);
        let err = compare_suites(&base, &suite(slow, false))
            .unwrap_err()
            .to_string();
        assert!(err.contains("speedup reverse-loop-f32"), "{err}");
        assert!(err.contains("1.20x < gate 1.50x"), "{err}");
    }

    #[test]
    fn absolute_medians_gate_only_against_measured_baselines() {
        let mut regressed = passing_rows();
        regressed
            .iter_mut()
            .filter(|r| r.name == "tdc-q8.8")
            .for_each(|r| r.stats.median_s = 4e-3); // 2x the baseline
        // provisional baseline: the regression is invisible
        let provisional = suite(passing_rows(), true);
        assert!(
            compare_suites(&provisional, &suite(regressed.clone(), false))
                .is_ok()
        );
        // measured baseline: 2x > 1 + max(0.50, ~0) trips
        let measured = suite(passing_rows(), false);
        let err = compare_suites(&measured, &suite(regressed, false))
            .unwrap_err()
            .to_string();
        assert!(err.contains("tdc-q8.8"), "{err}");
        // and an in-tolerance run passes with per-row PASS lines
        let report =
            compare_suites(&measured, &suite(passing_rows(), false)).unwrap();
        assert!(report.contains("PASS standard-f32"), "{report}");
    }

    #[test]
    fn bench_runs_in_smoke_mode() {
        let opts = BenchOpts {
            smoke: true,
            trials: 2,
            warmup: 0,
            serving: false,
        };
        let suite = run_bench(&opts).unwrap();
        assert!(!suite.provisional, "a measured run is not provisional");
        assert_eq!(suite.rows.len(), 20, "5 kernels x 4 precisions");
        for r in &suite.rows {
            assert!(r.stats.median_s > 0.0, "{}", r.name);
            assert!(r.macs > 0, "{}", r.name);
            assert!(r.img_per_s() > 0.0 && r.ns_per_mac() > 0.0);
        }
        assert!(suite.rows.iter().any(|r| r.name == "reverse-loop-q8"));
        assert!(suite.rows.iter().any(|r| r.name == "reverse-loop-q8.8"));
        assert!(suite.rows.iter().any(|r| r.name == "blocked-q16.16"));
        for suffix in ["f32", "q8", "q8.8", "q16.16"] {
            assert!(suite.speedup(suffix).is_some(), "{suffix}");
            let (ratio, _) = suite.blocked_ratio(suffix).unwrap();
            assert!(ratio > 0.0, "{suffix}");
        }
        let rendered = suite.render();
        assert!(rendered.contains("reverse-loop-ref-q16.16"), "{rendered}");
        assert!(rendered.contains("speedup reverse-loop-f32"), "{rendered}");
        assert!(rendered.contains("ratio blocked-f32"), "{rendered}");
    }

    #[test]
    fn blocked_ratio_gate_trips_when_blocking_regresses() {
        let base = suite(passing_rows(), true);
        // in-gate run passes and prints the ratio PASS lines
        let report =
            compare_suites(&base, &suite(passing_rows(), false)).unwrap();
        assert!(report.contains("PASS ratio blocked-f32"), "{report}");
        // blocked 2x the plain loop: over the 1.10 gate even with the
        // MAD band (quiet rows)
        let mut slow = passing_rows();
        slow.iter_mut()
            .filter(|r| r.name == "blocked-q8.8")
            .for_each(|r| r.stats.median_s = 2e-3);
        let err = compare_suites(&base, &suite(slow, false))
            .unwrap_err()
            .to_string();
        assert!(err.contains("ratio blocked-q8.8"), "{err}");
        assert!(err.contains("blocking regressed"), "{err}");
        // a fresh suite without blocked rows cannot pass the gate
        let legacy: Vec<KernelRow> = passing_rows()
            .into_iter()
            .filter(|r| !r.name.starts_with("blocked-"))
            .collect();
        let err = compare_suites(&base, &suite(legacy, false))
            .unwrap_err()
            .to_string();
        assert!(err.contains("missing the blocked-f32 rows"), "{err}");
        // …but a *baseline* without the field still compares: the gate
        // defaults on read (additive schema)
        let mut legacy_base = suite(passing_rows(), true);
        legacy_base.max_blocked_ratio = MAX_BLOCKED_RATIO;
        let json = legacy_base
            .to_json()
            .replacen("  \"max_blocked_ratio\": 1.1,\n", "", 1);
        let back = BenchSuite::from_json(&json).unwrap();
        assert_eq!(back.max_blocked_ratio, MAX_BLOCKED_RATIO);
    }
}
