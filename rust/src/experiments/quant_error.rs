//! Quantization-error sweep — the precision analogue of the Fig. 6
//! sparsity study: sweep fraction bits (Qm.n formats) and measure, per
//! format, the end-to-end generator output error against the f32
//! reference (PSNR, max |err|), the generative quality against the
//! ground-truth corpus (MMD, like Fig. 6b), and the simulated FPGA
//! latency/efficiency at the quantized datapath (narrow AXI words +
//! packed MAC lanes).  The interesting read is the knee: fraction bits
//! below it collapse quality for no latency win, above it buy nothing.

use crate::artifacts::ArtifactDir;
use crate::config::{network_by_name, FpgaBoard, Precision, JETSON_TX1};
use crate::deconv::generator_forward;
use crate::fpga::{simulate_network, SimOpts};
use crate::gpu::{self, GpuRunOpts, ThermalThrottle};
use crate::quant::{psnr_db, QFormat, QuantizedGenerator, Rounding};
use crate::sparsity::{mmd_biased, Mmd};
use crate::tensor::Tensor;
use crate::util::{Rng, WorkerPool};
use anyhow::{ensure, Result};

/// One point of the sweep.
#[derive(Debug, Clone)]
pub struct QuantErrorPoint {
    pub format: QFormat,
    /// PSNR of the quantized output vs the f32 reference (dB, peak 2.0),
    /// with per-output-channel scale calibration (the production path).
    pub psnr_db: f64,
    /// Same measurement at the per-layer (uniform) calibration — the
    /// baseline the per-channel refinement is judged against.
    pub psnr_per_layer_db: f64,
    /// Worst-case per-pixel deviation from the f32 reference.
    pub max_abs_err: f64,
    /// MMD of the quantized generator's distribution vs ground truth.
    pub mmd: f64,
    /// Simulated FPGA latency per inference at this datapath.
    pub fpga_time_s: f64,
    pub fpga_gops_per_w: f64,
}

/// The sweep dataset for one network.
#[derive(Debug, Clone)]
pub struct QuantErrorData {
    pub network: String,
    pub f32_mmd: f64,
    pub f32_time_s: f64,
    pub f32_gops_per_w: f64,
    /// One deterministic TX1 reference run at f32 (the GPU has no int8
    /// fallback in this model) — what the verdict line compares the
    /// narrow-format FPGA efficiency against.
    pub gpu_f32_gops_per_w: f64,
    pub points: Vec<QuantErrorPoint>,
}

/// Default sweep grid: every format the dispatcher supports.
pub fn default_quant_formats() -> Vec<QFormat> {
    crate::quant::supported_formats()
}

/// Run the sweep: quantize the trained (or synthetic) weights at each
/// format with per-layer scale calibration, run the fixed-point forward
/// on a shared latent set, and compare against the f32 forward.
pub fn run_quant_error(
    network: &str,
    board: &FpgaBoard,
    artifacts: &ArtifactDir,
    formats: &[QFormat],
    n_samples: usize,
    seed: u64,
) -> Result<QuantErrorData> {
    ensure!(!formats.is_empty(), "need at least one format");
    ensure!(n_samples >= 2, "need at least two samples");
    let net = network_by_name(network)?;
    let weights = artifacts.load_weights(network)?;
    let truth = artifacts.load_truth(network)?;
    let d = net.image_channels * net.image_size * net.image_size;
    let n_truth = truth.shape()[0].min(n_samples);
    let truth_flat = &truth.data()[..n_truth * d];
    let mmd_cfg = Mmd::with_median_bandwidth(truth_flat, d);

    // fixed latent set across formats (paired comparison, like Fig. 6)
    let mut rng = Rng::seed_from_u64(seed);
    let z = Tensor::from_fn(vec![n_samples, net.z_dim], |_| rng.normal_f32());
    let reference = generator_forward(&net, &weights, &z);
    let ref_flat = &reference.data()[..n_samples * d];
    let f32_mmd = mmd_biased(ref_flat, truth_flat, d, &mmd_cfg);
    let dense: Vec<SimOpts> =
        net.layers.iter().map(|_| SimOpts::dense(net.tile)).collect();
    let f32_sim = simulate_network(&net, board, &dense);
    let gpu_f32_gops_per_w = {
        let mut throttle = ThermalThrottle::new(JETSON_TX1);
        let mut grng = Rng::seed_from_u64(seed ^ 0x9e37_79b9);
        let runs = gpu::simulate_gpu_network(
            &net,
            &JETSON_TX1,
            &GpuRunOpts::default(),
            &mut throttle,
            &mut grng,
        );
        let ops: u64 = runs.iter().map(|r| r.ops).sum();
        let time: f64 = runs.iter().map(|r| r.time_s).sum();
        let energy: f64 = runs.iter().map(|r| r.time_s * r.power_w).sum();
        (ops as f64 / time / 1e9) / (energy / time)
    };

    let pool = WorkerPool::with_default_parallelism();
    let mut points = Vec::with_capacity(formats.len());
    for &format in formats {
        let qgen =
            QuantizedGenerator::quantize(format, &weights, Rounding::Nearest)?;
        let (images, _stats) = qgen.generate(&net, &z, &pool);
        let psnr = psnr_db(&reference, &images, 2.0);
        let per_layer = QuantizedGenerator::quantize_per_layer(
            format,
            &weights,
            Rounding::Nearest,
        )?;
        let (images_layer, _) = per_layer.generate(&net, &z, &pool);
        let psnr_per_layer = psnr_db(&reference, &images_layer, 2.0);
        let max_abs_err = reference
            .data()
            .iter()
            .zip(images.data())
            .map(|(a, b)| (a - b).abs() as f64)
            .fold(0.0, f64::max);
        let got_flat = &images.data()[..n_samples * d];
        let mmd = mmd_biased(got_flat, truth_flat, d, &mmd_cfg);
        let opts: Vec<SimOpts> = net
            .layers
            .iter()
            .map(|_| SimOpts::dense_at(net.tile, Precision::Fixed(format)))
            .collect();
        let sim = simulate_network(&net, board, &opts);
        points.push(QuantErrorPoint {
            format,
            psnr_db: psnr,
            psnr_per_layer_db: psnr_per_layer,
            max_abs_err,
            mmd,
            fpga_time_s: sim.total_time_s,
            fpga_gops_per_w: sim.gops_per_w,
        });
    }
    Ok(QuantErrorData {
        network: network.to_string(),
        f32_mmd,
        f32_time_s: f32_sim.total_time_s,
        f32_gops_per_w: f32_sim.gops_per_w,
        gpu_f32_gops_per_w,
        points,
    })
}

/// Render the sweep as a table (f32 reference row first).
pub fn render(data: &QuantErrorData) -> String {
    let mut s = format!(
        "{}: fixed-point sweep ({} formats)\n\
         {:>8} {:>10} {:>10} {:>10} {:>10} {:>12} {:>10}\n",
        data.network,
        data.points.len(),
        "format",
        "PSNR dB",
        "PSNR/lyr",
        "max|err|",
        "MMD",
        "latency ms",
        "GOps/s/W",
    );
    s.push_str(&format!(
        "{:>8} {:>10} {:>10} {:>10} {:>10.4} {:>12.3} {:>10.2}\n",
        "f32",
        "-",
        "-",
        "-",
        data.f32_mmd,
        data.f32_time_s * 1e3,
        data.f32_gops_per_w,
    ));
    for p in &data.points {
        s.push_str(&format!(
            "{:>8} {:>10.1} {:>10.1} {:>10.4} {:>10.4} {:>12.3} {:>10.2}\n",
            p.format.to_string(),
            p.psnr_db,
            p.psnr_per_layer_db,
            p.max_abs_err,
            p.mmd,
            p.fpga_time_s * 1e3,
            p.fpga_gops_per_w,
        ));
    }
    // the FPGA-vs-GPU verdict restated at the packed-int8 datapath
    if let Some(p) =
        data.points.iter().find(|p| p.format == QFormat::new(8, 6))
    {
        s.push_str(&format!(
            "verdict @ q2.6: FPGA {:.2} vs GPU f32 {:.2} GOps/s/W \
             ({:.1}x) — per-channel {:.1} dB vs per-layer {:.1} dB\n",
            p.fpga_gops_per_w,
            data.gpu_f32_gops_per_w,
            p.fpga_gops_per_w / data.gpu_f32_gops_per_w,
            p.psnr_db,
            p.psnr_per_layer_db,
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::artifacts::write_synthetic;
    use crate::config::PYNQ_Z2;
    use crate::util::TempDir;

    #[test]
    fn sweep_runs_and_orders_by_resolution() {
        let dir = TempDir::new().unwrap();
        let artifacts = write_synthetic(dir.path(), &["mnist"], 8, 3).unwrap();
        let formats =
            vec![QFormat::new(16, 4), QFormat::new(16, 8), QFormat::new(16, 12)];
        let data = run_quant_error(
            "mnist", &PYNQ_Z2, &artifacts, &formats, 8, 11,
        )
        .unwrap();
        assert_eq!(data.points.len(), 3);
        for p in &data.points {
            assert!(p.fpga_time_s > 0.0);
            assert!(p.fpga_time_s < data.f32_time_s, "{}: 16-bit wins", p.format);
            assert!(p.max_abs_err.is_finite());
            assert!(p.mmd.is_finite());
        }
        // more fraction bits → closer to the f32 reference
        assert!(
            data.points[2].psnr_db > data.points[0].psnr_db,
            "q4.12 ({:.1} dB) must beat q12.4 ({:.1} dB)",
            data.points[2].psnr_db,
            data.points[0].psnr_db
        );
        let table = render(&data);
        assert!(table.contains("q8.8"));
        assert!(table.contains("f32"));
    }

    #[test]
    fn q8_per_channel_calibration_beats_per_layer() {
        let dir = TempDir::new().unwrap();
        let artifacts = write_synthetic(dir.path(), &["mnist"], 8, 5).unwrap();
        let formats = vec![QFormat::new(8, 6)];
        let data =
            run_quant_error("mnist", &PYNQ_Z2, &artifacts, &formats, 8, 13)
                .unwrap();
        let p = &data.points[0];
        // per-channel exponents are never larger than the layer's, so
        // every weight quantizes on a grid at least as fine
        assert!(
            p.psnr_db >= p.psnr_per_layer_db,
            "per-channel {:.2} dB must not trail per-layer {:.2} dB",
            p.psnr_db,
            p.psnr_per_layer_db
        );
        // and the int8 datapath restates the paper's verdict: the
        // packed FPGA beats the f32 GPU on efficiency
        assert!(data.gpu_f32_gops_per_w > 0.0);
        assert!(
            p.fpga_gops_per_w > data.gpu_f32_gops_per_w,
            "FPGA q8 {:.2} vs GPU f32 {:.2}",
            p.fpga_gops_per_w,
            data.gpu_f32_gops_per_w
        );
        assert!(p.fpga_time_s < data.f32_time_s, "1-byte AXI words win");
        let table = render(&data);
        assert!(table.contains("verdict @ q2.6"), "{table}");
        assert!(table.contains("q2.6"));
    }
}
