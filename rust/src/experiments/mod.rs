//! Experiment drivers — one per paper table/figure (see DESIGN.md
//! per-experiment index).  Each driver returns structured data *and*
//! renders the paper's presentation, so the CLI, the examples, and the
//! criterion benches all share one implementation.

mod ablations;
mod bench;
mod fig5;
mod fig6;
mod quant_error;
mod table1;
mod table2;

pub use ablations::{render as render_ablations, run_ablations, AblationRow};
pub use bench::{
    compare_suites, run_bench, BenchOpts, BenchSuite, KernelRow, ServingRow,
    BENCH_SCHEMA_VERSION, MIN_SPEEDUP_F32, MIN_SPEEDUP_FIXED,
};
pub use fig5::{render as render_fig5, run_fig5, Fig5Data};
pub use fig6::{
    default_levels, render as render_fig6, run_fig6, run_fig6_with_runtime,
    Fig6Data,
};
pub use quant_error::{
    default_quant_formats, render as render_quant_error, run_quant_error,
    QuantErrorData, QuantErrorPoint,
};
pub use table1::{render as render_table1, run_table1, Table1Row};
pub use table2::{render as render_table2, run_table2, DeviceRows, Table2Data};
