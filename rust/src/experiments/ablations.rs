//! Ablations of the paper's three Section III enhancements — DESIGN.md
//! calls these out as the design choices worth quantifying:
//!
//! 1. pre-computed Eq. 3 offsets vs inline modulo arithmetic,
//! 2. weight-stationary loop order + zero-skipping vs no skipping,
//! 3. decoupled sequential DDR access vs serialized random access,
//! 4. reverse-loop vs the TDC (stride² filters) transform overhead.

use crate::config::{network_by_name, FpgaBoard};
use crate::deconv::{
    modulo_cost_naive, modulo_cost_precomputed, tdc_filter_count,
    tdc_subfilter_extent,
};
use crate::fpga::{simulate_network, SimOpts};
use anyhow::Result;

/// One ablation result: the enhancement on vs off.
#[derive(Debug, Clone)]
pub struct AblationRow {
    pub name: String,
    pub network: String,
    /// Metric with the enhancement enabled (lower is better for the
    /// *_cost rows, time rows in seconds).
    pub with_enh: f64,
    /// Metric with the enhancement disabled.
    pub without_enh: f64,
    pub unit: &'static str,
}

impl AblationRow {
    pub fn factor(&self) -> f64 {
        self.without_enh / self.with_enh.max(1e-18)
    }
}

/// Run all ablations for one network.
pub fn run_ablations(
    network: &str,
    board: &FpgaBoard,
    sparsity: f64,
) -> Result<Vec<AblationRow>> {
    let net = network_by_name(network)?;
    let mut rows = Vec::new();

    // (1) modulo pre-computation (op counts over the whole network)
    let pre: u64 = net
        .layers
        .iter()
        .map(|l| modulo_cost_precomputed(l.k))
        .sum();
    let naive: u64 = net
        .layers
        .iter()
        .map(|l| modulo_cost_naive(l.k, l.stride, l.o_h(), l.o_h()))
        .sum();
    rows.push(AblationRow {
        name: "eq3-offset-precompute".into(),
        network: network.into(),
        with_enh: pre as f64,
        without_enh: naive as f64,
        unit: "modulo ops",
    });

    // (2) zero-skipping at the given sparsity
    let dense: Vec<SimOpts> =
        net.layers.iter().map(|_| SimOpts::dense(net.tile)).collect();
    let skipping: Vec<SimOpts> = net
        .layers
        .iter()
        .map(|_| SimOpts {
            zero_skip: true,
            weight_sparsity: sparsity,
            ..SimOpts::dense(net.tile)
        })
        .collect();
    let t_dense = simulate_network(&net, board, &dense).total_time_s;
    let t_skip = simulate_network(&net, board, &skipping).total_time_s;
    rows.push(AblationRow {
        name: format!("zero-skipping@{sparsity:.0e}"),
        network: network.into(),
        with_enh: t_skip,
        without_enh: t_dense,
        unit: "s/inference",
    });

    // (3) decoupled external memory access
    let coupled: Vec<SimOpts> = net
        .layers
        .iter()
        .map(|_| SimOpts {
            decouple: false,
            ..SimOpts::dense(net.tile)
        })
        .collect();
    let t_coupled = simulate_network(&net, board, &coupled).total_time_s;
    rows.push(AblationRow {
        name: "decoupled-ddr-access".into(),
        network: network.into(),
        with_enh: t_dense,
        without_enh: t_coupled,
        unit: "s/inference",
    });

    // (4) TDC transform overhead: extra taps materialized by stride²
    // sub-filter zero padding, vs the reverse-loop's exact tap count
    let mut exact = 0u64;
    let mut tdc = 0u64;
    for l in &net.layers {
        exact += l.macs();
        let kc = tdc_subfilter_extent(l.k, l.stride);
        tdc += (l.c_in * l.c_out) as u64
            * (tdc_filter_count(l.stride) * kc * kc) as u64
            * (l.o_h() as u64 / l.stride.max(1) as u64).pow(2);
    }
    rows.push(AblationRow {
        name: "reverse-loop-vs-tdc".into(),
        network: network.into(),
        with_enh: exact as f64,
        without_enh: tdc as f64,
        unit: "MACs",
    });

    Ok(rows)
}

/// Render as a table.
pub fn render(rows: &[AblationRow]) -> String {
    let mut s = format!(
        "{:<26} {:>14} {:>14} {:>8}  unit\n",
        "ablation", "with", "without", "factor"
    );
    for r in rows {
        s.push_str(&format!(
            "{:<26} {:>14.6} {:>14.6} {:>7.2}x  {}\n",
            r.name,
            r.with_enh,
            r.without_enh,
            r.factor(),
            r.unit
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PYNQ_Z2;

    #[test]
    fn all_enhancements_help() {
        for net in ["mnist", "celeba"] {
            let rows = run_ablations(net, &PYNQ_Z2, 0.8).unwrap();
            assert_eq!(rows.len(), 4);
            for r in &rows {
                assert!(
                    r.factor() >= 1.0,
                    "{}: enhancement must not hurt ({} vs {})",
                    r.name,
                    r.with_enh,
                    r.without_enh
                );
            }
            // modulo precompute is the dramatic one
            assert!(rows[0].factor() > 100.0);
        }
    }
}
