//! Table I — PYNQ-Z2 resource utilization at the DSE-chosen tiling
//! factors.

use crate::config::{network_by_name, FpgaBoard};
use crate::fpga::{estimate_resources, Utilization};
use anyhow::Result;

/// One Table I row.
#[derive(Debug, Clone)]
pub struct Table1Row {
    pub network: String,
    pub t_oh: usize,
    pub utilization: Utilization,
    pub fits: bool,
}

/// Regenerate Table I for both networks (paper values in comments:
/// MNIST 12/134/50/43218/36469, CelebA 24/134/74/48938/40923).
pub fn run_table1(board: &FpgaBoard) -> Result<Vec<Table1Row>> {
    let mut rows = Vec::new();
    for name in ["mnist", "celeba"] {
        let net = network_by_name(name)?;
        let u = estimate_resources(&net, net.tile, board.n_cu);
        rows.push(Table1Row {
            network: name.to_string(),
            t_oh: net.tile,
            utilization: u,
            fits: u.fits(board),
        });
    }
    Ok(rows)
}

/// Render in the paper's format.
pub fn render(rows: &[Table1Row]) -> String {
    let mut s = String::from(
        "          T_OH   DSP48s   BRAMs   Flip-Flops     LUTs   fits\n",
    );
    for r in rows {
        s.push_str(&format!(
            "{:<8} {:>5} {:>8} {:>7} {:>12} {:>8}   {}\n",
            r.network,
            r.t_oh,
            r.utilization.dsp,
            r.utilization.bram18,
            r.utilization.ff,
            r.utilization.lut,
            if r.fits { "yes" } else { "NO" },
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PYNQ_Z2;

    #[test]
    fn both_rows_fit_the_board() {
        let rows = run_table1(&PYNQ_Z2).unwrap();
        assert_eq!(rows.len(), 2);
        assert!(rows.iter().all(|r| r.fits));
        assert_eq!(rows[0].t_oh, 12);
        assert_eq!(rows[1].t_oh, 24);
        // paper's DSP figure is tile-independent
        assert_eq!(rows[0].utilization.dsp, 134);
        assert_eq!(rows[1].utilization.dsp, 134);
    }

    #[test]
    fn render_shows_all_columns() {
        let rows = run_table1(&PYNQ_Z2).unwrap();
        let s = render(&rows);
        assert!(s.contains("DSP48s"));
        assert!(s.contains("mnist"));
        assert!(s.contains("celeba"));
        assert!(s.contains("134"));
    }
}
