//! Fig. 6 — the sparsity study: (a) FPGA speed-up from zero-skipping as
//! weights are magnitude-pruned, (b) MMD degradation of the generated
//! distribution, (c) the Eq. 6 trade-off metric and its peak.
//!
//! Latency comes from the FPGA pipeline simulator with zero-skipping at
//! each level's *achieved* per-layer sparsity; generative quality comes
//! from actually running the pruned generator (PJRT artifact path, or the
//! pure-Rust reverse-loop forward as a numerics-identical fallback) and
//! measuring MMD against the ground-truth corpus batch.

use crate::artifacts::ArtifactDir;
use crate::config::{network_by_name, FpgaBoard};
use crate::deconv::generator_forward;
use crate::fpga::{simulate_network, SimOpts};
use crate::runtime::Runtime;
use crate::sparsity::{
    magnitude_prune_network, mmd_biased, peak_index, tradeoff_curve, Mmd,
    TradeoffPoint,
};
use crate::tensor::Tensor;
use crate::util::Rng;
use anyhow::{ensure, Result};

/// The Fig. 6 dataset for one network.
#[derive(Debug, Clone)]
pub struct Fig6Data {
    pub network: String,
    pub sparsities: Vec<f64>,
    /// Fig. 6a inputs: simulated FPGA latency per inference.
    pub latencies_s: Vec<f64>,
    /// Fig. 6b: MMD(P_g, P_θp).
    pub mmds: Vec<f64>,
    /// Fig. 6c: the Eq. 6 curve.
    pub curve: Vec<TradeoffPoint>,
    /// Sparsity at the Eq. 6 peak.
    pub peak_sparsity: f64,
}

/// Common driver; `gen` produces images from a pruned weight set.
fn run_fig6_impl<F>(
    network: &str,
    board: &FpgaBoard,
    artifacts: &ArtifactDir,
    levels: &[f64],
    n_samples: usize,
    seed: u64,
    mut gen: F,
) -> Result<Fig6Data>
where
    F: FnMut(&[(Tensor, Vec<f32>)], &Tensor) -> Result<Tensor>,
{
    ensure!(!levels.is_empty(), "need at least one sparsity level");
    ensure!(levels[0] == 0.0, "first level must be the dense baseline");
    let net = network_by_name(network)?;
    let dense_weights = artifacts.load_weights(network)?;
    let truth = artifacts.load_truth(network)?;
    let d = net.image_channels * net.image_size * net.image_size;
    let n_truth = truth.shape()[0].min(n_samples);
    let truth_flat = &truth.data()[..n_truth * d];
    let mmd_cfg = Mmd::with_median_bandwidth(truth_flat, d);

    // fixed latent set across sparsity levels (paired comparison)
    let mut rng = Rng::seed_from_u64(seed);
    let z =
        Tensor::from_fn(vec![n_samples, net.z_dim], |_| rng.normal_f32());

    let mut sparsities = Vec::with_capacity(levels.len());
    let mut latencies = Vec::with_capacity(levels.len());
    let mut mmds = Vec::with_capacity(levels.len());
    for &level in levels {
        let mut weights = dense_weights.clone();
        let per_layer = magnitude_prune_network(&mut weights, level);
        let mean_sparsity =
            per_layer.iter().sum::<f64>() / per_layer.len() as f64;

        // Fig. 6a: zero-skipping FPGA latency at the achieved sparsity
        let opts: Vec<SimOpts> = net
            .layers
            .iter()
            .zip(&per_layer)
            .map(|(_, &s)| SimOpts {
                zero_skip: true,
                weight_sparsity: s,
                ..SimOpts::dense(net.tile)
            })
            .collect();
        let sim = simulate_network(&net, board, &opts);

        // Fig. 6b: distribution quality of the pruned generator
        let images = gen(&weights, &z)?;
        let gen_flat = &images.data()[..n_samples * d];
        let mmd = mmd_biased(gen_flat, truth_flat, d, &mmd_cfg);

        sparsities.push(mean_sparsity);
        latencies.push(sim.total_time_s);
        mmds.push(mmd);
    }

    let curve = tradeoff_curve(&sparsities, &latencies, &mmds);
    let peak = peak_index(&curve);
    Ok(Fig6Data {
        network: network.to_string(),
        peak_sparsity: curve[peak].sparsity,
        sparsities,
        latencies_s: latencies,
        mmds,
        curve,
    })
}

/// Fig. 6 with the pure-Rust generator forward (no PJRT needed; identical
/// numerics to the artifact, asserted by integration tests).
pub fn run_fig6(
    network: &str,
    board: &FpgaBoard,
    artifacts: &ArtifactDir,
    levels: &[f64],
    n_samples: usize,
    seed: u64,
) -> Result<Fig6Data> {
    let net = network_by_name(network)?;
    run_fig6_impl(
        network, board, artifacts, levels, n_samples, seed,
        move |weights, z| Ok(generator_forward(&net, weights, z)),
    )
}

/// Fig. 6 with the real AOT artifact executed through PJRT — the full
/// three-layer path (the pruned weights are fed as HLO parameters).
pub fn run_fig6_with_runtime(
    network: &str,
    board: &FpgaBoard,
    artifacts: &ArtifactDir,
    runtime: &Runtime,
    levels: &[f64],
    n_samples: usize,
    seed: u64,
) -> Result<Fig6Data> {
    let exe = runtime.load_generator(artifacts, network, n_samples)?;
    let bucket = exe.batch;
    run_fig6_impl(
        network, board, artifacts, levels, n_samples, seed,
        move |weights, z| {
            // run the fixed latent set through the bucketed executable
            let n = z.shape()[0];
            let z_dim = z.shape()[1];
            let mut rows: Vec<f32> = Vec::new();
            let mut shape = None;
            let mut i = 0;
            while i < n {
                let take = bucket.min(n - i);
                let mut zb = vec![0.0f32; bucket * z_dim];
                zb[..take * z_dim]
                    .copy_from_slice(&z.data()[i * z_dim..(i + take) * z_dim]);
                let zt = Tensor::new(vec![bucket, z_dim], zb)?;
                let out = exe.generate(&zt, weights)?;
                let numel: usize = out.shape()[1..].iter().product();
                rows.extend_from_slice(&out.data()[..take * numel]);
                shape = Some(out.shape()[1..].to_vec());
                i += take;
            }
            let s = shape.unwrap();
            Tensor::new(vec![n, s[0], s[1], s[2]], rows)
        },
    )
}

/// Render the three panels as data tables.
pub fn render(data: &Fig6Data) -> String {
    let mut s = format!(
        "{}: Eq.6 peak at sparsity {:.2}\n\
         {:>9} {:>12} {:>10} {:>10} {:>10} {:>10}\n",
        data.network,
        data.peak_sparsity,
        "sparsity",
        "latency ms",
        "speedup",
        "MMD",
        "quality",
        "Eq6",
    );
    for p in &data.curve {
        s.push_str(&format!(
            "{:>9.2} {:>12.3} {:>10.2} {:>10.4} {:>10.3} {:>10.3}{}\n",
            p.sparsity,
            p.latency_s * 1e3,
            p.speedup,
            p.mmd,
            p.quality,
            p.score,
            if (p.sparsity - data.peak_sparsity).abs() < 1e-9 {
                "  <== peak"
            } else {
                ""
            },
        ));
    }
    s
}

/// The default sparsity grid used by the CLI/benches (matches the
/// paper's 0→extreme sweep; the far tail is where generative quality
/// collapses and the Eq. 6 curve turns over).
pub fn default_levels() -> Vec<f64> {
    vec![
        0.0, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9, 0.95, 0.98, 0.99,
    ]
}
