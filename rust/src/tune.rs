//! `edgedcnn tune` — bench-driven autotuning over the legal block-
//! schedule space, and the persisted tune table kernel dispatch
//! consults.
//!
//! The tuner sweeps every legal `(micro, macro, lanes)` triple from
//! [`legal_block_schedules`] (a pruned subset in `--smoke` mode) for
//! each kernel × precision cell of the bench geometry, timing each
//! candidate with the same robust-median harness the bench suite uses
//! and keeping the fastest.  Winners persist to `TUNE_edgedcnn.json`
//! (schema-versioned, hand-rolled JSON like every other artifact in
//! this repo); at dispatch time [`schedule_for`] looks the calling
//! shape up in the table loaded once per process from the
//! `EDGEDCNN_TUNE` path (default `./TUNE_edgedcnn.json`), falling back
//! to [`BlockSchedule::default_for`] when the file or the entry is
//! absent.  A missing, malformed or future-versioned table is never an
//! error on the hot path — dispatch silently uses the static default,
//! so the tune file is a pure performance hint, not a correctness
//! input (every candidate is bit-identical by construction, and the
//! tuner asserts it anyway).

use crate::deconv::{
    deconv_reverse_loop, deconv_reverse_loop_blocked, deconv_standard,
    deconv_standard_blocked, deconv_tdc, deconv_tdc_blocked,
    legal_block_schedules, output_size, BlockSchedule, ReverseLoopOpts,
};
use crate::quant::{Element, Q16_16, Q2_6, Q8_8};
use crate::tensor::TensorT;
use crate::util::{escape_json, parse_json, Bencher, Rng, WorkerPool};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::sync::OnceLock;

/// Schema version of `TUNE_edgedcnn.json`.
pub const TUNE_SCHEMA_VERSION: u64 = 1;
/// Default tune-table path, relative to the working directory.
pub const TUNE_FILE: &str = "TUNE_edgedcnn.json";
/// Environment override for the tune-table path.
pub const TUNE_ENV: &str = "EDGEDCNN_TUNE";
/// Micro-tile the static default schedule uses when the caller does
/// not pin one (the paper's T=12 working point).
pub const DEFAULT_MICRO: usize = 12;

/// Which deconvolution kernel a tune entry applies to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TuneKernel {
    Standard,
    ReverseLoop,
    Tdc,
}

impl TuneKernel {
    pub const ALL: [TuneKernel; 3] =
        [TuneKernel::Standard, TuneKernel::ReverseLoop, TuneKernel::Tdc];

    pub fn as_str(self) -> &'static str {
        match self {
            TuneKernel::Standard => "standard",
            TuneKernel::ReverseLoop => "reverse-loop",
            TuneKernel::Tdc => "tdc",
        }
    }
}

/// Precision label of an [`Element`] type, derived from its storage
/// and accumulator widths (the same cell labels the bench suite uses).
pub fn elem_label<T: Element>() -> String {
    match (T::BYTES, std::mem::size_of::<T::Acc>()) {
        (4, 4) => "f32".to_string(),
        (1, 4) => "q8".to_string(),
        (2, 8) => "q8.8".to_string(),
        (4, 8) => "q16.16".to_string(),
        (b, a) => format!("elem{b}acc{a}"),
    }
}

/// Lookup key of one tuned cell: kernel, precision, and the shape
/// parameters the block geometry actually depends on.
pub fn shape_key(
    kernel: TuneKernel,
    elem: &str,
    c_in: usize,
    c_out: usize,
    k: usize,
    s: usize,
    o_h: usize,
) -> String {
    format!("{}/{elem}/k{k}s{s}ci{c_in}co{c_out}oh{o_h}", kernel.as_str())
}

/// One tuned winner: the fastest schedule seen and its median runtime
/// (informational — dispatch only reads the schedule).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TuneEntry {
    pub sched: BlockSchedule,
    pub median_s: f64,
}

/// The persisted tune table: shape key → winning schedule.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TuneTable {
    entries: BTreeMap<String, TuneEntry>,
}

impl TuneTable {
    pub fn get(&self, key: &str) -> Option<&TuneEntry> {
        self.entries.get(key)
    }

    pub fn insert(&mut self, key: String, entry: TuneEntry) {
        self.entries.insert(key, entry);
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn to_json(&self) -> String {
        let entries = self
            .entries
            .iter()
            .map(|(key, e)| {
                format!(
                    "    {{\"key\": \"{}\", \"micro\": {}, \
                     \"macro_tiles\": {}, \"lanes\": {}, \
                     \"median_s\": {}}}",
                    escape_json(key),
                    e.sched.micro,
                    e.sched.macro_tiles,
                    e.sched.lanes,
                    e.median_s,
                )
            })
            .collect::<Vec<_>>()
            .join(",\n");
        format!(
            "{{\n  \"version\": {TUNE_SCHEMA_VERSION},\n  \
             \"entries\": [\n{entries}\n  ]\n}}\n"
        )
    }

    pub fn from_json(s: &str) -> Result<TuneTable> {
        let v = parse_json(s).context("parsing tune table JSON")?;
        let version = v.req("version")?.as_u64()?;
        if version != TUNE_SCHEMA_VERSION {
            bail!(
                "tune schema version {version} != {TUNE_SCHEMA_VERSION} \
                 (refusing to dispatch off an unknown table)"
            );
        }
        let mut entries = BTreeMap::new();
        for e in v.req("entries")?.as_arr()? {
            entries.insert(
                e.req("key")?.as_str()?.to_string(),
                TuneEntry {
                    sched: BlockSchedule {
                        micro: e.req("micro")?.as_usize()?,
                        macro_tiles: e.req("macro_tiles")?.as_usize()?,
                        lanes: e.req("lanes")?.as_usize()?,
                    },
                    median_s: e.req("median_s")?.as_f64()?,
                },
            );
        }
        Ok(TuneTable { entries })
    }

    /// Human-readable winners listing (the `edgedcnn tune` output).
    pub fn render(&self) -> String {
        let mut out = format!(
            "== edgedcnn tune ({} entries) ==\n",
            self.entries.len()
        );
        for (key, e) in &self.entries {
            out.push_str(&format!(
                "{:<44} micro {:>3}  macro {:>2}  lanes {:>2}  \
                 median {:>9.4} ms\n",
                key,
                e.sched.micro,
                e.sched.macro_tiles,
                e.sched.lanes,
                e.median_s * 1e3,
            ));
        }
        out
    }
}

/// The process-wide table, loaded once from `EDGEDCNN_TUNE` (default
/// `./TUNE_edgedcnn.json`).  Unreadable or unparseable files resolve
/// to the empty table — dispatch falls back to the static default.
fn global_table() -> &'static TuneTable {
    static TABLE: OnceLock<TuneTable> = OnceLock::new();
    TABLE.get_or_init(|| {
        let path = std::env::var(TUNE_ENV)
            .unwrap_or_else(|_| TUNE_FILE.to_string());
        std::fs::read_to_string(&path)
            .ok()
            .and_then(|s| TuneTable::from_json(&s).ok())
            .unwrap_or_default()
    })
}

/// [`schedule_for`] against an explicit table (the testable core).
///
/// A tuned entry wins; `pin_micro` overrides its micro-tile (the
/// classic kernel entries pin `micro` to their caller's tile factor so
/// `OpStats` geometry is schedule-independent, while macro grouping
/// and lane width still come from the table).  On a miss the static
/// default at the pinned (or [`DEFAULT_MICRO`]) tile applies.  The
/// result is always normalized, so hand-edited tables cannot produce
/// an illegal geometry.
pub fn schedule_from_table<T: Element>(
    table: &TuneTable,
    kernel: TuneKernel,
    c_in: usize,
    c_out: usize,
    k: usize,
    s: usize,
    o_h: usize,
    pin_micro: Option<usize>,
) -> BlockSchedule {
    let key = shape_key(kernel, &elem_label::<T>(), c_in, c_out, k, s, o_h);
    match table.get(&key) {
        Some(e) => {
            let mut sched = e.sched;
            if let Some(m) = pin_micro {
                sched.micro = m;
            }
            sched.normalized()
        }
        None => BlockSchedule::default_for(pin_micro.unwrap_or(DEFAULT_MICRO)),
    }
}

/// Block schedule for one kernel invocation: the persisted tune
/// table's entry for this (kernel, precision, shape), else the static
/// default.  This is what every blocked kernel's dispatch calls.
pub fn schedule_for<T: Element>(
    kernel: TuneKernel,
    c_in: usize,
    c_out: usize,
    k: usize,
    s: usize,
    o_h: usize,
    pin_micro: Option<usize>,
) -> BlockSchedule {
    schedule_from_table::<T>(
        global_table(),
        kernel,
        c_in,
        c_out,
        k,
        s,
        o_h,
        pin_micro,
    )
}

/// Knobs of one tuner run.
#[derive(Debug, Clone)]
pub struct TuneOpts {
    /// Small geometry + pruned candidate set (the CI mode).
    pub smoke: bool,
    /// Timed trials per candidate.
    pub trials: usize,
    /// Untimed warm-up iterations per candidate.
    pub warmup: usize,
}

impl TuneOpts {
    pub fn new(smoke: bool) -> Self {
        TuneOpts {
            smoke,
            trials: if smoke { 3 } else { 10 },
            warmup: if smoke { 1 } else { 2 },
        }
    }
}

/// Tuning geometry — deliberately identical to the bench suite's
/// smoke/full geometries, so the winners land on exactly the shape
/// keys the `blocked-*` bench rows dispatch with.
struct TuneGeo {
    n: usize,
    c_in: usize,
    c_out: usize,
    i: usize,
    k: usize,
    s: usize,
    p: usize,
}

impl TuneGeo {
    fn new(smoke: bool) -> Self {
        if smoke {
            TuneGeo { n: 2, c_in: 8, c_out: 8, i: 7, k: 4, s: 2, p: 1 }
        } else {
            TuneGeo { n: 4, c_in: 32, c_out: 32, i: 14, k: 4, s: 2, p: 1 }
        }
    }
}

/// The candidate schedules one cell sweeps: the full legal space, or
/// in smoke mode a pruned subset (default micro-tile, coarse macro and
/// lane grid) sized for CI.
fn candidates(o_h: usize, s: usize, smoke: bool) -> Vec<BlockSchedule> {
    let all = legal_block_schedules(o_h, s);
    if !smoke {
        return all;
    }
    let micro = all
        .iter()
        .map(|b| b.micro)
        .filter(|m| *m <= DEFAULT_MICRO)
        .max()
        .unwrap_or(all[0].micro);
    all.into_iter()
        .filter(|b| {
            b.micro == micro
                && matches!(b.macro_tiles, 1 | 4)
                // 16 keeps the doubled i8 lane width in the CI sweep
                && matches!(b.lanes, 1 | 4 | 8 | 16)
        })
        .collect()
}

/// Sweep one kernel × precision cell and record the winner.  Every
/// candidate's output is asserted bit-identical to the unblocked
/// kernel of the same family before it is timed — a slow tune run must
/// never persist a wrong one.
fn sweep_cell<T: Element>(
    kernel: TuneKernel,
    g: &TuneGeo,
    cands: &[BlockSchedule],
    opts: &TuneOpts,
    pool: &WorkerPool,
    table: &mut TuneTable,
) {
    let mut rng = Rng::seed_from_u64(0x7E4E);
    let x = TensorT::<T>::from_fn(vec![g.n, g.c_in, g.i, g.i], |_| {
        T::from_f32(rng.range_f32(-1.0, 1.0))
    });
    let w = TensorT::<T>::from_fn(vec![g.c_in, g.c_out, g.k, g.k], |_| {
        T::from_f32(rng.range_f32(-0.5, 0.5))
    });
    let b: Vec<T> = (0..g.c_out)
        .map(|_| T::from_f32(rng.range_f32(-0.1, 0.1)))
        .collect();
    let o_h = output_size(g.i, g.k, g.s, g.p);
    let want: Vec<T> = match kernel {
        TuneKernel::Standard => {
            deconv_standard(&x, &w, &b, g.s, g.p).data().to_vec()
        }
        TuneKernel::ReverseLoop => {
            let opts =
                ReverseLoopOpts { tile: DEFAULT_MICRO, zero_skip: false };
            deconv_reverse_loop(&x, &w, &b, g.s, g.p, opts).0.data().to_vec()
        }
        TuneKernel::Tdc => deconv_tdc(&x, &w, &b, g.s, g.p).data().to_vec(),
    };
    let mut best: Option<(BlockSchedule, f64)> = None;
    for &sched in cands {
        let run = || -> TensorT<T> {
            match kernel {
                TuneKernel::Standard => deconv_standard_blocked(
                    &x,
                    &w,
                    &b,
                    g.s,
                    g.p,
                    Some(sched),
                    pool,
                ),
                TuneKernel::ReverseLoop => {
                    deconv_reverse_loop_blocked(
                        &x,
                        &w,
                        &b,
                        g.s,
                        g.p,
                        false,
                        Some(sched),
                        pool,
                    )
                    .0
                }
                TuneKernel::Tdc => deconv_tdc_blocked(
                    &x,
                    &w,
                    &b,
                    g.s,
                    g.p,
                    Some(sched),
                    pool,
                ),
            }
        };
        let got = run();
        assert_eq!(
            got.data(),
            &want[..],
            "tuner correctness guard: {} {sched:?}",
            kernel.as_str()
        );
        let stats = Bencher::new("tune")
            .iters(opts.trials)
            .warmup(opts.warmup)
            .run_trials(run);
        let better = match best {
            None => true,
            Some((_, m)) => stats.median_s < m,
        };
        if better {
            best = Some((sched, stats.median_s));
        }
    }
    let (sched, median_s) = best.expect("non-empty candidate set");
    table.insert(
        shape_key(
            kernel,
            &elem_label::<T>(),
            g.c_in,
            g.c_out,
            g.k,
            g.s,
            o_h,
        ),
        TuneEntry { sched, median_s },
    );
}

/// Run the full tuner: every kernel × precision cell of the bench
/// geometry, winners collected into a fresh table (the CLI persists it
/// to [`TUNE_FILE`]).
pub fn run_tune(opts: &TuneOpts) -> TuneTable {
    let g = TuneGeo::new(opts.smoke);
    let o_h = output_size(g.i, g.k, g.s, g.p);
    let cands = candidates(o_h, g.s, opts.smoke);
    let pool = WorkerPool::with_default_parallelism();
    let mut table = TuneTable::default();
    for kernel in TuneKernel::ALL {
        sweep_cell::<f32>(kernel, &g, &cands, opts, &pool, &mut table);
        sweep_cell::<Q2_6>(kernel, &g, &cands, opts, &pool, &mut table);
        sweep_cell::<Q8_8>(kernel, &g, &cands, opts, &pool, &mut table);
        sweep_cell::<Q16_16>(kernel, &g, &cands, opts, &pool, &mut table);
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::deconv::SUPPORTED_LANES;

    #[test]
    fn elem_labels_cover_the_four_precisions() {
        assert_eq!(elem_label::<f32>(), "f32");
        assert_eq!(elem_label::<Q2_6>(), "q8");
        assert_eq!(elem_label::<Q8_8>(), "q8.8");
        assert_eq!(elem_label::<Q16_16>(), "q16.16");
    }

    #[test]
    fn table_json_roundtrips_and_refuses_other_schemas() {
        let mut t = TuneTable::default();
        t.insert(
            shape_key(TuneKernel::ReverseLoop, "f32", 8, 8, 4, 2, 14),
            TuneEntry {
                sched: BlockSchedule {
                    micro: 12,
                    macro_tiles: 4,
                    lanes: 8,
                },
                median_s: 1.5e-3,
            },
        );
        let json = t.to_json();
        let back = TuneTable::from_json(&json).unwrap();
        assert_eq!(back, t);
        assert_eq!(back.to_json(), json, "stable re-serialization");
        let v9 = json.replacen("\"version\": 1", "\"version\": 9", 1);
        let err = TuneTable::from_json(&v9).unwrap_err().to_string();
        assert!(err.contains("schema version 9"), "{err}");
        assert!(TuneTable::from_json("{}").is_err());
        let empty = TuneTable::default();
        assert_eq!(TuneTable::from_json(&empty.to_json()).unwrap(), empty);
        assert!(empty.is_empty());
    }

    #[test]
    fn dispatch_prefers_the_tuned_entry_and_honours_the_pin() {
        let mut t = TuneTable::default();
        t.insert(
            shape_key(TuneKernel::ReverseLoop, "f32", 8, 8, 4, 2, 14),
            TuneEntry {
                sched: BlockSchedule {
                    micro: 6,
                    macro_tiles: 8,
                    lanes: 2,
                },
                median_s: 1e-3,
            },
        );
        // hit: the tuned schedule verbatim
        let s = schedule_from_table::<f32>(
            &t,
            TuneKernel::ReverseLoop,
            8,
            8,
            4,
            2,
            14,
            None,
        );
        assert_eq!(
            s,
            BlockSchedule { micro: 6, macro_tiles: 8, lanes: 2 }
        );
        // hit with a pinned micro: macro/lanes tuned, micro pinned
        let s = schedule_from_table::<f32>(
            &t,
            TuneKernel::ReverseLoop,
            8,
            8,
            4,
            2,
            14,
            Some(12),
        );
        assert_eq!(
            s,
            BlockSchedule { micro: 12, macro_tiles: 8, lanes: 2 }
        );
        // miss (different precision): the static default
        let s = schedule_from_table::<Q8_8>(
            &t,
            TuneKernel::ReverseLoop,
            8,
            8,
            4,
            2,
            14,
            None,
        );
        assert_eq!(s, BlockSchedule::default_for(DEFAULT_MICRO));
        // miss with a pin: the default at the pinned micro
        let s = schedule_from_table::<f32>(
            &t,
            TuneKernel::Standard,
            8,
            8,
            4,
            2,
            14,
            Some(5),
        );
        assert_eq!(s, BlockSchedule::default_for(5));
    }

    #[test]
    fn smoke_sweep_tunes_every_cell_and_winners_are_legal() {
        let opts = TuneOpts { smoke: true, trials: 1, warmup: 0 };
        let table = run_tune(&opts);
        assert_eq!(table.len(), 12, "3 kernels x 4 precisions");
        let o_h = output_size(7, 4, 2, 1);
        for elem in ["q8", "q8.8"] {
            let key =
                shape_key(TuneKernel::ReverseLoop, elem, 8, 8, 4, 2, o_h);
            let e = table.get(&key).expect("bench-geometry key present");
            assert!(e.median_s > 0.0);
            assert!(SUPPORTED_LANES.contains(&e.sched.lanes));
        }
        assert!(table.render().contains("reverse-loop/q8.8"));
        assert!(table.render().contains("tdc/q8/"));
        // the persisted form round-trips and dispatch consults it
        let back = TuneTable::from_json(&table.to_json()).unwrap();
        let s = schedule_from_table::<Q8_8>(
            &back,
            TuneKernel::ReverseLoop,
            8,
            8,
            4,
            2,
            o_h,
            None,
        );
        assert_eq!(s, e.sched.normalized());
    }
}
