//! Fixed-point quantization — the element-type axis of the substrate.
//!
//! The paper's PYNQ-Z2 accelerator earns its throughput-per-watt edge by
//! running the reverse-loop deconvolution in low-precision fixed point;
//! this module makes that datapath real on the Rust side:
//!
//! * [`Element`] — the scalar trait [`crate::tensor::TensorT`], all
//!   three deconvolution kernels and the generator forward are generic
//!   over (`f32` is the identity backend);
//! * [`Fixed<S, F>`](Fixed) — Qm.n fixed point over `i8`/`i16`/`i32`
//!   with saturating element ops, configurable [`Rounding`], and an
//!   exact wrapping accumulator sized to the store (`i32` for i8, `i64`
//!   otherwise — the DSP48 shape: narrow inputs, wide accumulator, one
//!   round/saturate at write-back);
//! * [`QFormat`] / [`Precision`] — runtime descriptors threaded through
//!   the config, the FPGA simulator (element/accumulator widths drive
//!   the AXI byte counts, BRAM sizing and DSP lane packing) and the
//!   artifact manifest;
//! * [`QuantizedGenerator`] — per-output-channel scale-calibrated
//!   quantized networks ([`ChannelScales`]) behind runtime format
//!   dispatch, used by the serving coordinator (`<name>.q` / `<name>.q8`
//!   logical networks), the `edgedcnn quant` CLI and the
//!   quantization-error experiment.

mod element;
mod fixed;
mod net;

pub use element::Element;
pub use fixed::{
    AccWord, Fixed, Rounding, Storage, Q10_6, Q12_4, Q16_16, Q2_6, Q4_12,
    Q6_10, Q8_24, Q8_8,
};
pub use net::{
    calibrate_channel_exps, calibrate_pow2_exp, generator_forward_quant,
    quantize_network, quantize_network_per_layer, ChannelScales,
    QuantLayerRaw, QuantizedGenerator, QuantizedLayer,
};

use crate::tensor::{Tensor, TensorT};
use std::fmt;
use std::str::FromStr;

/// Runtime descriptor of a Qm.n fixed-point format (`bits` total,
/// `frac` fraction bits, `bits - frac` integer bits including sign).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QFormat {
    pub bits: u32,
    pub frac: u32,
}

impl QFormat {
    pub const fn new(bits: u32, frac: u32) -> Self {
        QFormat { bits, frac }
    }

    /// Integer bits (including sign).
    pub const fn int_bits(&self) -> u32 {
        self.bits - self.frac
    }

    /// Quantization step `2^-frac`.
    pub fn step(&self) -> f64 {
        2f64.powi(-(self.frac as i32))
    }
}

impl fmt::Display for QFormat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "q{}.{}", self.int_bits(), self.frac)
    }
}

/// The formats [`QuantizedGenerator`] can dispatch to (the quant-error
/// sweep's grid).
pub fn supported_formats() -> Vec<QFormat> {
    vec![
        QFormat::new(8, 6),
        QFormat::new(16, 4),
        QFormat::new(16, 6),
        QFormat::new(16, 8),
        QFormat::new(16, 10),
        QFormat::new(16, 12),
        QFormat::new(32, 16),
        QFormat::new(32, 24),
    ]
}

/// Datapath precision — `f32` (the historical path) or a fixed-point
/// format.  Carried by the network config and the FPGA simulator
/// options; drives external-memory byte counts, BRAM word widths,
/// accumulator sizing and DSP lane packing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    F32,
    Fixed(QFormat),
}

impl Precision {
    /// Bytes per element in external memory / BRAM data words.
    pub fn elem_bytes(self) -> u64 {
        match self {
            Precision::F32 => 4,
            Precision::Fixed(q) => (q.bits as u64).div_ceil(8),
        }
    }

    /// Bytes per accumulator word the datapath carries for each output
    /// element before write-back: one f32 register, a 32-bit exact
    /// accumulator for 8-bit operands, the DSP48's 48-bit accumulator
    /// for 16-bit operands, a 64-bit chain for 32-bit.
    pub fn acc_bytes(self) -> u64 {
        match self {
            Precision::F32 => 4,
            Precision::Fixed(q) if q.bits <= 8 => 4,
            Precision::Fixed(q) if q.bits <= 16 => 6,
            Precision::Fixed(_) => 8,
        }
    }

    /// MAC-lane multiplier relative to the f32 datapath: four 8-bit
    /// MACs pack into one DSP48 (INT8 packing à la DPUCZDX8G), two
    /// 16-bit MACs pack via the pre-adder/SIMD path, so the CU issues
    /// 4×/2× the MACs per cycle at the same DSP budget.
    pub fn lane_factor(self) -> usize {
        match self {
            Precision::F32 => 1,
            Precision::Fixed(q) if q.bits <= 8 => 4,
            Precision::Fixed(q) if q.bits <= 16 => 2,
            Precision::Fixed(_) => 1,
        }
    }
}

impl fmt::Display for Precision {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Precision::F32 => write!(f, "f32"),
            Precision::Fixed(q) => write!(f, "{q}"),
        }
    }
}

impl FromStr for Precision {
    type Err = anyhow::Error;

    /// Parse `"f32"` or `"q<I>.<F>"` (total bits = I + F, e.g. `q8.8`
    /// is 16-bit with 8 fraction bits).
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let t = s.trim();
        if t.eq_ignore_ascii_case("f32") {
            return Ok(Precision::F32);
        }
        let body = t
            .strip_prefix('q')
            .or_else(|| t.strip_prefix('Q'))
            .ok_or_else(|| {
                anyhow::anyhow!("bad precision {s:?} (expected f32 or qI.F)")
            })?;
        let (i, f) = body.split_once('.').ok_or_else(|| {
            anyhow::anyhow!("bad precision {s:?} (expected f32 or qI.F)")
        })?;
        let int: u32 = i
            .parse()
            .map_err(|_| anyhow::anyhow!("bad integer bits in {s:?}"))?;
        let frac: u32 = f
            .parse()
            .map_err(|_| anyhow::anyhow!("bad fraction bits in {s:?}"))?;
        anyhow::ensure!(
            int >= 1 && frac >= 1 && int + frac <= 64,
            "implausible precision {s:?}"
        );
        Ok(Precision::Fixed(QFormat::new(int + frac, frac)))
    }
}

/// Quantize an `f32` tensor elementwise (unit scale).
pub fn quantize_tensor<S: Storage, const F: u32>(
    t: &Tensor,
    rounding: Rounding,
) -> TensorT<Fixed<S, F>> {
    TensorT::from_fn(t.shape().to_vec(), |i| {
        Fixed::<S, F>::from_f32_round(t.data()[i], rounding)
    })
}

/// Dequantize a fixed-point tensor back to `f32`.
pub fn dequantize_tensor<S: Storage, const F: u32>(
    t: &TensorT<Fixed<S, F>>,
) -> Tensor {
    TensorT::from_fn(t.shape().to_vec(), |i| t.data()[i].to_f32())
}

/// Peak signal-to-noise ratio in dB between two same-shape tensors
/// (`peak` is the signal range, e.g. 2.0 for tanh-range images).
/// Identical tensors report `f64::INFINITY`.
pub fn psnr_db(reference: &Tensor, got: &Tensor, peak: f32) -> f64 {
    assert_eq!(reference.shape(), got.shape(), "psnr shape mismatch");
    assert!(reference.numel() > 0, "psnr of empty tensors");
    let mse: f64 = reference
        .data()
        .iter()
        .zip(got.data())
        .map(|(a, b)| {
            let d = (a - b) as f64;
            d * d
        })
        .sum::<f64>()
        / reference.numel() as f64;
    if mse == 0.0 {
        return f64::INFINITY;
    }
    10.0 * ((peak as f64) * (peak as f64) / mse).log10()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qformat_labels_and_step() {
        let q = QFormat::new(16, 8);
        assert_eq!(q.to_string(), "q8.8");
        assert_eq!(q.int_bits(), 8);
        assert!((q.step() - 1.0 / 256.0).abs() < 1e-12);
        assert_eq!(QFormat::new(32, 16).to_string(), "q16.16");
        assert_eq!(QFormat::new(8, 6).to_string(), "q2.6");
    }

    #[test]
    fn precision_parse_roundtrip() {
        assert_eq!("f32".parse::<Precision>().unwrap(), Precision::F32);
        assert_eq!(
            "q8.8".parse::<Precision>().unwrap(),
            Precision::Fixed(QFormat::new(16, 8))
        );
        assert_eq!(
            "q16.16".parse::<Precision>().unwrap(),
            Precision::Fixed(QFormat::new(32, 16))
        );
        assert_eq!(
            "q2.6".parse::<Precision>().unwrap(),
            Precision::Fixed(QFormat::new(8, 6))
        );
        for p in [Precision::F32, Precision::Fixed(QFormat::new(16, 12))] {
            assert_eq!(p.to_string().parse::<Precision>().unwrap(), p);
        }
        assert!("int8".parse::<Precision>().is_err());
        assert!("q8".parse::<Precision>().is_err());
    }

    #[test]
    fn precision_datapath_parameters() {
        assert_eq!(Precision::F32.elem_bytes(), 4);
        assert_eq!(Precision::F32.acc_bytes(), 4);
        assert_eq!(Precision::F32.lane_factor(), 1);
        let q16 = Precision::Fixed(QFormat::new(16, 8));
        assert_eq!(q16.elem_bytes(), 2);
        assert_eq!(q16.acc_bytes(), 6);
        assert_eq!(q16.lane_factor(), 2);
        let q32 = Precision::Fixed(QFormat::new(32, 16));
        assert_eq!(q32.elem_bytes(), 4);
        assert_eq!(q32.acc_bytes(), 8);
        assert_eq!(q32.lane_factor(), 1);
        let q8 = Precision::Fixed(QFormat::new(8, 6));
        assert_eq!(q8.elem_bytes(), 1, "no 2-byte floor on i8 elements");
        assert_eq!(q8.acc_bytes(), 4);
        assert_eq!(q8.lane_factor(), 4, "×4 INT8 MACs per DSP");
    }

    #[test]
    fn quantize_dequantize_tensor_roundtrip() {
        let t = Tensor::from_fn(vec![2, 3], |i| i as f32 * 0.25 - 0.5);
        let q = quantize_tensor::<i16, 8>(&t, Rounding::Nearest);
        let back = dequantize_tensor(&q);
        assert_eq!(back.shape(), t.shape());
        // all inputs are on the Q8.8 grid → exact
        assert_eq!(back.data(), t.data());
    }

    #[test]
    fn psnr_behaves() {
        let a = Tensor::from_fn(vec![16], |i| (i as f32 * 0.37).sin());
        assert_eq!(psnr_db(&a, &a, 2.0), f64::INFINITY);
        let b = Tensor::from_fn(vec![16], |i| (i as f32 * 0.37).sin() + 0.1);
        let c = Tensor::from_fn(vec![16], |i| (i as f32 * 0.37).sin() + 0.01);
        assert!(psnr_db(&a, &c, 2.0) > psnr_db(&a, &b, 2.0));
        assert!((psnr_db(&a, &b, 2.0) - 26.02).abs() < 0.1, "20·log10(2/0.1)");
    }

    #[test]
    fn supported_formats_dispatch() {
        for f in supported_formats() {
            let weights = vec![(Tensor::from_fn(vec![1, 1, 2, 2], |_| 0.3), vec![0.0])];
            assert!(
                QuantizedGenerator::quantize(f, &weights, Rounding::Nearest)
                    .is_ok(),
                "{f} must dispatch"
            );
        }
    }
}
