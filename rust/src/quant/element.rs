//! The [`Element`] trait — the element-type axis of the numeric
//! substrate.  Tensors, all three deconvolution kernels and the
//! generator forward are generic over it, so the same Algorithm 1 code
//! runs in `f32` (the historical path) or Qm.n fixed point (the
//! datapath the paper's PYNQ-Z2 accelerator actually executes).
//!
//! The central design rule is the split between the *element* domain
//! (storage width, saturating, rounded) and the *accumulator* domain
//! ([`Element::Acc`]: wide, exact-or-wrapping, never saturating
//! mid-chain).  Because accumulation is order-independent in the
//! accumulator domain, the standard, reverse-loop and TDC kernels —
//! which visit the same multiset of taps in different loop orders — are
//! **bit-identical** in fixed point, which the property tests assert.

/// A scalar the tensor/deconvolution substrate can compute in.
pub trait Element:
    Copy + PartialEq + Send + Sync + std::fmt::Debug + 'static
{
    /// Wide accumulator carried through a MAC chain.  Accumulation must
    /// be exact or wrapping (never saturating or rounding mid-chain) so
    /// the sum is independent of accumulation order.  Order-independence
    /// holds unconditionally; *overflow-freedom* is storage-dependent —
    /// see [`crate::quant::Fixed`]'s `mac` for the per-width headroom.
    /// (`'static` so accumulator blocks can live in the type-keyed
    /// per-worker scratch arena, [`crate::util::with_scratch`].)
    type Acc: Copy + Send + 'static;

    /// Additive identity in the element domain.
    const ZERO: Self;
    /// Additive identity in the accumulator domain.
    const ACC_ZERO: Self::Acc;
    /// Bytes one element occupies in external memory — this is what the
    /// kernel's `OpStats` byte accounting and the FPGA AXI model charge.
    const BYTES: usize;

    /// Quantize from `f32` (round-to-nearest for fixed point).
    fn from_f32(v: f32) -> Self;
    /// Dequantize back to `f32`.
    fn to_f32(self) -> f32;
    /// Exact-zero test (the zero-skipping predicate).
    fn is_zero(self) -> bool;
    /// Widen into the accumulator domain (bias initialization).
    fn widen(self) -> Self::Acc;
    /// `acc + w · x` in the accumulator domain.
    fn mac(acc: Self::Acc, w: Self, x: Self) -> Self::Acc;
    /// Round/saturate the accumulator back to the element domain — the
    /// hardware's one-shot write-back stage.
    fn narrow(acc: Self::Acc) -> Self;
    /// `max(0, x)` — the inter-layer activation.
    fn relu(self) -> Self;
    /// `tanh(x)` — the output-layer squash (fixed-point backends model
    /// the hardware's LUT by round-tripping through `f32`).
    fn tanh(self) -> Self;
}

impl Element for f32 {
    type Acc = f32;

    const ZERO: f32 = 0.0;
    const ACC_ZERO: f32 = 0.0;
    const BYTES: usize = 4;

    #[inline]
    fn from_f32(v: f32) -> f32 {
        v
    }

    #[inline]
    fn to_f32(self) -> f32 {
        self
    }

    #[inline]
    fn is_zero(self) -> bool {
        self == 0.0
    }

    #[inline]
    fn widen(self) -> f32 {
        self
    }

    #[inline]
    fn mac(acc: f32, w: f32, x: f32) -> f32 {
        acc + w * x
    }

    #[inline]
    fn narrow(acc: f32) -> f32 {
        acc
    }

    #[inline]
    fn relu(self) -> f32 {
        f32::max(self, 0.0)
    }

    #[inline]
    fn tanh(self) -> f32 {
        f32::tanh(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_is_the_identity_backend() {
        assert_eq!(<f32 as Element>::from_f32(1.5), 1.5);
        assert_eq!(1.5f32.to_f32(), 1.5);
        assert!(<f32 as Element>::is_zero(0.0));
        assert!(!<f32 as Element>::is_zero(1e-20));
        assert_eq!(<f32 as Element>::mac(1.0, 2.0, 3.0), 7.0);
        assert_eq!(Element::relu(-2.0f32), 0.0);
        assert_eq!(Element::relu(2.0f32), 2.0);
        assert_eq!(<f32 as Element>::BYTES, 4);
    }
}
