//! `Fixed<S, F>` — Qm.n fixed point over an `i8`/`i16`/`i32` backing
//! store with saturating element ops, configurable rounding and an exact
//! (wrapping) accumulator sized to the storage (`i32` for i8, `i64`
//! otherwise), mirroring the DSP48 datapath: narrow multiplier inputs,
//! wide accumulator, one round/saturate at write-back.

use super::element::Element;

/// Accumulator word backing a fixed-point MAC chain (`i32` or `i64`).
/// Arithmetic is wrapping, so accumulation order never changes bits —
/// the cross-kernel bit-exactness guarantee at every storage width.
pub trait AccWord:
    Copy + PartialEq + Eq + Send + Sync + std::fmt::Debug + 'static
{
    const ZERO: Self;

    fn to_i64(self) -> i64;
    /// Wrap an `i64` into the accumulator width (as-cast truncation).
    fn from_i64_wrap(v: i64) -> Self;
    fn wrapping_add(self, rhs: Self) -> Self;
}

impl AccWord for i32 {
    const ZERO: i32 = 0;

    #[inline]
    fn to_i64(self) -> i64 {
        self as i64
    }

    #[inline]
    fn from_i64_wrap(v: i64) -> i32 {
        v as i32
    }

    #[inline]
    fn wrapping_add(self, rhs: i32) -> i32 {
        i32::wrapping_add(self, rhs)
    }
}

impl AccWord for i64 {
    const ZERO: i64 = 0;

    #[inline]
    fn to_i64(self) -> i64 {
        self
    }

    #[inline]
    fn from_i64_wrap(v: i64) -> i64 {
        v
    }

    #[inline]
    fn wrapping_add(self, rhs: i64) -> i64 {
        i64::wrapping_add(self, rhs)
    }
}

/// Integer backing store for a fixed-point element (`i8`, `i16` or
/// `i32`), paired with the accumulator width its MAC chain runs at.
pub trait Storage:
    Copy + PartialEq + Eq + Send + Sync + std::fmt::Debug + 'static
{
    const BITS: u32;
    const BYTES: usize;
    const MIN_I64: i64;
    const MAX_I64: i64;
    const ZERO: Self;

    /// Accumulator word for `w · x` chains over this storage.  `i8`
    /// products are ≤ 2^14, so an `i32` accumulator is exact for every
    /// realistic layer; wider stores keep the `i64` accumulator.
    type Acc: AccWord;

    fn to_i64(self) -> i64;
    /// Saturate an `i64` into the storage range.
    fn from_i64_sat(v: i64) -> Self;
}

impl Storage for i8 {
    const BITS: u32 = 8;
    const BYTES: usize = 1;
    const MIN_I64: i64 = i8::MIN as i64;
    const MAX_I64: i64 = i8::MAX as i64;
    const ZERO: i8 = 0;

    type Acc = i32;

    #[inline]
    fn to_i64(self) -> i64 {
        self as i64
    }

    #[inline]
    fn from_i64_sat(v: i64) -> i8 {
        v.clamp(i8::MIN as i64, i8::MAX as i64) as i8
    }
}

impl Storage for i16 {
    const BITS: u32 = 16;
    const BYTES: usize = 2;
    const MIN_I64: i64 = i16::MIN as i64;
    const MAX_I64: i64 = i16::MAX as i64;
    const ZERO: i16 = 0;

    type Acc = i64;

    #[inline]
    fn to_i64(self) -> i64 {
        self as i64
    }

    #[inline]
    fn from_i64_sat(v: i64) -> i16 {
        v.clamp(i16::MIN as i64, i16::MAX as i64) as i16
    }
}

impl Storage for i32 {
    const BITS: u32 = 32;
    const BYTES: usize = 4;
    const MIN_I64: i64 = i32::MIN as i64;
    const MAX_I64: i64 = i32::MAX as i64;
    const ZERO: i32 = 0;

    type Acc = i64;

    #[inline]
    fn to_i64(self) -> i64 {
        self as i64
    }

    #[inline]
    fn from_i64_sat(v: i64) -> i32 {
        v.clamp(i32::MIN as i64, i32::MAX as i64) as i32
    }
}

/// Rounding mode applied when quantizing from `f32`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Rounding {
    /// Round to nearest (ties away from zero) — the default; gives the
    /// `≤ 2^-F` roundtrip error bound the property tests assert.
    #[default]
    Nearest,
    /// Truncate toward zero — the cheap-hardware mode.
    Truncate,
}

/// A Qm.n fixed-point number with `F` fraction bits over storage `S`
/// (`m = S::BITS - F` integer bits including sign).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Fixed<S: Storage, const F: u32>(S);

impl<S: Storage, const F: u32> Fixed<S, F> {
    /// Fraction bits of this format.
    pub const FRAC: u32 = F;

    /// Quantization step `2^-F`.
    pub fn step() -> f32 {
        1.0 / (1i64 << F) as f32
    }

    /// Largest representable value.
    pub fn max_value_f32() -> f32 {
        S::MAX_I64 as f32 / (1i64 << F) as f32
    }

    /// Smallest (most negative) representable value.
    pub fn min_value_f32() -> f32 {
        S::MIN_I64 as f32 / (1i64 << F) as f32
    }

    /// Reinterpret a raw storage word (artifact import).
    pub fn from_raw(raw: S) -> Self {
        Fixed(raw)
    }

    /// The raw storage word (artifact export).
    pub fn raw(self) -> S {
        self.0
    }

    /// Quantize with an explicit rounding mode, saturating to range.
    /// NaN quantizes to zero (Rust float→int casts saturate/zero).
    pub fn from_f32_round(v: f32, rounding: Rounding) -> Self {
        let scaled = v as f64 * (1i64 << F) as f64;
        let q = match rounding {
            Rounding::Nearest => scaled.round(),
            Rounding::Truncate => scaled.trunc(),
        };
        Fixed(S::from_i64_sat(q as i64))
    }

    /// Multiply by `2^e` with saturation (the per-layer power-of-two
    /// rescale of the activation epilogue; `e` may be negative, in
    /// which case the shift rounds half-up like [`Element::narrow`]).
    pub fn scale_pow2(self, e: i32) -> Self {
        let v = self.0.to_i64();
        if e >= 0 {
            let sh = (e as u32).min(62);
            Fixed(S::from_i64_sat(v.saturating_mul(1i64 << sh)))
        } else {
            let sh = ((-e) as u32).min(62);
            let half = 1i64 << (sh - 1);
            Fixed(S::from_i64_sat(v.wrapping_add(half) >> sh))
        }
    }
}

impl<S: Storage, const F: u32> Element for Fixed<S, F> {
    type Acc = S::Acc;

    const ZERO: Self = Fixed(S::ZERO);
    const ACC_ZERO: S::Acc = <S::Acc as AccWord>::ZERO;
    const BYTES: usize = S::BYTES;

    #[inline]
    fn from_f32(v: f32) -> Self {
        Self::from_f32_round(v, Rounding::Nearest)
    }

    #[inline]
    fn to_f32(self) -> f32 {
        self.0.to_i64() as f32 / (1i64 << F) as f32
    }

    #[inline]
    fn is_zero(self) -> bool {
        self.0.to_i64() == 0
    }

    /// Widen a Q(F) element into the Q(2F) accumulator domain, so the
    /// bias sits in the same units as the `w · x` products.
    #[inline]
    fn widen(self) -> S::Acc {
        S::Acc::from_i64_wrap(self.0.to_i64() << F)
    }

    /// Exact product, wrapping accumulation.  Wrapping (never
    /// saturating) addition keeps the chain commutative, which is the
    /// bit-exactness guarantee across kernels.  Overflow-freedom is a
    /// separate, storage-dependent property: `i8` products are ≤ 2^14
    /// in an `i32` accumulator (2^17 of headroom over the deepest
    /// reduction here — exact); `i16` products are ≤ 2^30 in `i64`,
    /// leaving 2^33 of headroom — no realistic layer wraps.  `i32`
    /// products can reach 2^62, so a 32-bit format *can* wrap the
    /// accumulator when calibrated magnitudes are extreme; the result
    /// is then deterministic and loop-order-independent but wrong-sign
    /// after [`Element::narrow`]'s saturation — the same finite-
    /// accumulator behaviour real wide-accumulator hardware exhibits.
    /// The edge-serving formats are the 8- and 16-bit ones.
    #[inline]
    fn mac(acc: S::Acc, w: Self, x: Self) -> S::Acc {
        acc.wrapping_add(S::Acc::from_i64_wrap(
            w.0.to_i64().wrapping_mul(x.0.to_i64()),
        ))
    }

    /// Q(2F) → Q(F): round half-up, then saturate into storage.
    #[inline]
    fn narrow(acc: S::Acc) -> Self {
        let a = acc.to_i64();
        if F == 0 {
            return Fixed(S::from_i64_sat(a));
        }
        let half = 1i64 << (F.saturating_sub(1));
        Fixed(S::from_i64_sat(a.wrapping_add(half) >> F))
    }

    #[inline]
    fn relu(self) -> Self {
        if self.0.to_i64() < 0 {
            Self::ZERO
        } else {
            self
        }
    }

    /// LUT-style tanh: dequantize, evaluate, requantize.
    #[inline]
    fn tanh(self) -> Self {
        Self::from_f32(f32::tanh(self.to_f32()))
    }
}

/// Q2.6 — 8-bit, 6 fraction bits (the DPU-class INT8 serving format;
/// labelled `q8` in bench/tune/serving output).
pub type Q2_6 = Fixed<i8, 6>;
/// Q12.4 — 16-bit, 4 fraction bits.
pub type Q12_4 = Fixed<i16, 4>;
/// Q10.6 — 16-bit, 6 fraction bits.
pub type Q10_6 = Fixed<i16, 6>;
/// Q8.8 — 16-bit, 8 fraction bits (the workhorse edge format).
pub type Q8_8 = Fixed<i16, 8>;
/// Q6.10 — 16-bit, 10 fraction bits.
pub type Q6_10 = Fixed<i16, 10>;
/// Q4.12 — 16-bit, 12 fraction bits.
pub type Q4_12 = Fixed<i16, 12>;
/// Q16.16 — 32-bit, 16 fraction bits.
pub type Q16_16 = Fixed<i32, 16>;
/// Q8.24 — 32-bit, 24 fraction bits.
pub type Q8_24 = Fixed<i32, 24>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_hits_grid_points() {
        for v in [-3.5f32, -0.25, 0.0, 0.5, 1.0, 7.75] {
            let q = Q8_8::from_f32(v);
            assert_eq!(q.to_f32(), v, "{v} is on the Q8.8 grid");
        }
    }

    #[test]
    fn roundtrip_error_bounded_by_step() {
        for i in 0..200 {
            let v = (i as f32 - 100.0) * 0.3127;
            let q = Q8_8::from_f32(v);
            assert!(
                (q.to_f32() - v).abs() <= Q8_8::step(),
                "v={v} deq={} step={}",
                q.to_f32(),
                Q8_8::step()
            );
        }
    }

    #[test]
    fn saturation_clamps_to_range() {
        let hi = Q8_8::from_f32(1e9);
        assert_eq!(hi.raw(), i16::MAX);
        let lo = Q8_8::from_f32(-1e9);
        assert_eq!(lo.raw(), i16::MIN);
        assert!(Q8_8::max_value_f32() < 128.0);
        assert!(Q8_8::min_value_f32() >= -128.0);
    }

    #[test]
    fn truncate_rounds_toward_zero() {
        let v = 0.9999 * Q8_8::step();
        assert_eq!(Q8_8::from_f32_round(v, Rounding::Truncate).raw(), 0);
        assert_eq!(Q8_8::from_f32_round(v, Rounding::Nearest).raw(), 1);
        assert_eq!(Q8_8::from_f32_round(-v, Rounding::Truncate).raw(), 0);
    }

    #[test]
    fn mac_narrow_matches_float_math() {
        // 1.5 * 2.0 + 0.25 in Q8.8: all values on the grid, so exact
        let w = Q8_8::from_f32(1.5);
        let x = Q8_8::from_f32(2.0);
        let b = Q8_8::from_f32(0.25);
        let acc = Q8_8::mac(b.widen(), w, x);
        assert_eq!(Q8_8::narrow(acc).to_f32(), 3.25);
    }

    #[test]
    fn narrow_saturates_overflowing_accumulators() {
        let big = Q8_8::from_f32(100.0);
        let mut acc = <Q8_8 as Element>::ACC_ZERO;
        for _ in 0..10 {
            acc = Q8_8::mac(acc, big, big);
        }
        assert_eq!(Q8_8::narrow(acc).raw(), i16::MAX, "must clamp, not wrap");
    }

    #[test]
    fn scale_pow2_shifts_both_ways() {
        let v = Q8_8::from_f32(1.5);
        assert_eq!(v.scale_pow2(2).to_f32(), 6.0);
        assert_eq!(v.scale_pow2(-1).to_f32(), 0.75);
        assert_eq!(v.scale_pow2(0), v);
        // saturates instead of overflowing
        assert_eq!(Q8_8::from_f32(100.0).scale_pow2(10).raw(), i16::MAX);
    }

    #[test]
    fn relu_and_tanh_behave() {
        assert_eq!(Element::relu(Q8_8::from_f32(-2.0)), Q8_8::ZERO);
        assert_eq!(Element::relu(Q8_8::from_f32(2.0)).to_f32(), 2.0);
        let t = Element::tanh(Q4_12::from_f32(1000.0)).to_f32();
        assert!((t - 1.0).abs() < 2.0 * Q4_12::step(), "tanh(large)≈1: {t}");
    }

    #[test]
    fn i8_roundtrip_and_saturation() {
        // grid points are exact
        for v in [-1.5f32, -0.25, 0.0, 0.5, 1.0, 1.984_375] {
            assert_eq!(Q2_6::from_f32(v).to_f32(), v, "{v} is on the Q2.6 grid");
        }
        // off-grid error bounded by one step
        for i in 0..100 {
            let v = (i as f32 - 50.0) * 0.0317;
            let q = Q2_6::from_f32(v);
            assert!((q.to_f32() - v).abs() <= Q2_6::step());
        }
        assert_eq!(Q2_6::from_f32(1e9).raw(), i8::MAX);
        assert_eq!(Q2_6::from_f32(-1e9).raw(), i8::MIN);
        assert!(Q2_6::max_value_f32() < 2.0);
        assert!(Q2_6::min_value_f32() >= -2.0);
    }

    #[test]
    fn i8_mac_narrow_is_exact_in_i32() {
        // 0.5 * 1.5 + 0.25 in Q2.6: all values on the grid, so exact
        let w = Q2_6::from_f32(0.5);
        let x = Q2_6::from_f32(1.5);
        let b = Q2_6::from_f32(0.25);
        let acc = Q2_6::mac(b.widen(), w, x);
        assert_eq!(Q2_6::narrow(acc).to_f32(), 1.0);
        // accumulator is i32, not i64
        assert_eq!(std::mem::size_of::<<Q2_6 as Element>::Acc>(), 4);
        // the deepest reduction in the model (512·49 taps at max
        // magnitude 127·127) stays far below i32::MAX: the i32
        // accumulator is exact, never wrapping.
        let worst = 512i64 * 49 * 127 * 127;
        assert!(worst < i32::MAX as i64);
        // and narrow saturates an over-range accumulator
        let big = Q2_6::from_f32(1.9);
        let mut acc = <Q2_6 as Element>::ACC_ZERO;
        for _ in 0..100 {
            acc = Q2_6::mac(acc, big, big);
        }
        assert_eq!(Q2_6::narrow(acc).raw(), i8::MAX, "must clamp, not wrap");
    }

    #[test]
    fn i8_matches_i16_on_shared_grid() {
        // Q2.6 values live on the Q10.6 grid too: identical frac bits,
        // so mac/narrow round identically where both representations
        // are in range — the narrow store only changes saturation.
        for (wv, xv, bv) in [(0.5f32, 0.75f32, 0.125f32), (-1.25, 0.5, 0.0)] {
            let a8 = Q2_6::mac(
                Q2_6::from_f32(bv).widen(),
                Q2_6::from_f32(wv),
                Q2_6::from_f32(xv),
            );
            let a16 = Q10_6::mac(
                Q10_6::from_f32(bv).widen(),
                Q10_6::from_f32(wv),
                Q10_6::from_f32(xv),
            );
            assert_eq!(Q2_6::narrow(a8).to_f32(), Q10_6::narrow(a16).to_f32());
        }
    }

    #[test]
    fn wide_format_is_finer() {
        assert!(Q16_16::step() < Q8_8::step());
        let v = 0.123_456_7f32;
        let e8 = (Q8_8::from_f32(v).to_f32() - v).abs();
        let e16 = (Q16_16::from_f32(v).to_f32() - v).abs();
        assert!(e16 < e8);
    }
}
