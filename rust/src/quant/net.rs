//! Quantized networks: per-layer power-of-two scale calibration, the
//! fixed-point generator forward (reverse-loop kernels + shift/LUT
//! epilogue), and [`QuantizedGenerator`] — the runtime-dispatch wrapper
//! that lets non-generic code (coordinator, CLI, artifact I/O) own a
//! quantized network without naming a concrete `Fixed<S, F>` type.

use super::element::Element;
use super::fixed::{Fixed, Rounding, Storage};
use super::{dequantize_tensor, QFormat};
use crate::config::NetworkCfg;
use crate::deconv::{deconv_reverse_loop_par, OpStats, ReverseLoopOpts};
use crate::tensor::{Tensor, TensorT};
use crate::util::WorkerPool;
use anyhow::{ensure, Result};

/// One quantized deconvolution layer: weights and bias stored as
/// `stored · 2^scale_exp ≈ real`, so the kernel runs scale-free and the
/// epilogue undoes the scale with a single shift.
pub struct QuantizedLayer<S: Storage, const F: u32> {
    pub w: TensorT<Fixed<S, F>>,
    pub b: Vec<Fixed<S, F>>,
    /// Per-layer power-of-two weight scale exponent (calibrated).
    pub scale_exp: i32,
}

/// Calibrate the per-layer power-of-two scale: the smallest exponent
/// `e` such that `max(|w|, |b|) / 2^e` fits the representable range of
/// `Fixed<S, F>` — small-magnitude layers get a *negative* exponent,
/// spending the spare integer bits on resolution.  The bias must be
/// part of the calibration because it is stored at the same scale as
/// the weights (it seeds the accumulator in weight units); calibrating
/// on weights alone would saturate ordinary biases in tiny-weight
/// layers.
pub fn calibrate_pow2_exp<S: Storage, const F: u32>(
    w: &Tensor,
    b: &[f32],
) -> i32 {
    let max_abs = w
        .data()
        .iter()
        .chain(b.iter())
        .fold(0.0f32, |m, v| m.max(v.abs()));
    if max_abs == 0.0 || !max_abs.is_finite() {
        return 0;
    }
    let limit = Fixed::<S, F>::max_value_f32();
    let mut e = ((max_abs / limit).log2().ceil() as i32).clamp(-30, 30);
    // guard against log2/powi rounding right at the boundary
    while max_abs / 2f32.powi(e) > limit && e < 30 {
        e += 1;
    }
    e
}

/// Quantize a whole weight set with per-layer calibrated scales.
pub fn quantize_network<S: Storage, const F: u32>(
    weights: &[(Tensor, Vec<f32>)],
    rounding: Rounding,
) -> Vec<QuantizedLayer<S, F>> {
    weights
        .iter()
        .map(|(w, b)| {
            let scale_exp = calibrate_pow2_exp::<S, F>(w, b);
            let inv = 2f32.powi(-scale_exp);
            let wq = TensorT::from_fn(w.shape().to_vec(), |i| {
                Fixed::<S, F>::from_f32_round(w.data()[i] * inv, rounding)
            });
            let bq = b
                .iter()
                .map(|v| Fixed::<S, F>::from_f32_round(*v * inv, rounding))
                .collect();
            QuantizedLayer {
                w: wq,
                b: bq,
                scale_exp,
            }
        })
        .collect()
}

/// Full generator forward pass in Qm.n fixed point: activations are
/// quantized once at the input, every layer runs the (generic)
/// reverse-loop kernel on fixed-point tensors, and the epilogue applies
/// the layer's power-of-two rescale plus ReLU/tanh — exactly the
/// shift-and-LUT epilogue the hardware pipeline executes.
///
/// Returns the dequantized images plus the per-layer [`OpStats`] (whose
/// byte counts now reflect the narrow element width).
pub fn generator_forward_quant<S: Storage, const F: u32>(
    net: &NetworkCfg,
    layers: &[QuantizedLayer<S, F>],
    z: &Tensor,
    pool: &WorkerPool,
) -> (Tensor, Vec<OpStats>) {
    assert_eq!(layers.len(), net.layers.len());
    assert_eq!(z.shape()[1], net.z_dim);
    let n = z.shape()[0];
    let mut xq: TensorT<Fixed<S, F>> =
        super::quantize_tensor::<S, F>(z, Rounding::Nearest)
            .reshape(vec![n, net.z_dim, 1, 1])
            .expect("z reshape");
    let last = net.layers.len() - 1;
    let mut stats_all = Vec::with_capacity(layers.len());
    for (i, (cfg, ql)) in net.layers.iter().zip(layers).enumerate() {
        let (mut y, stats) = deconv_reverse_loop_par(
            &xq,
            &ql.w,
            &ql.b,
            cfg.stride,
            cfg.padding,
            ReverseLoopOpts {
                tile: net.tile,
                zero_skip: true,
            },
            pool,
        );
        for v in y.data_mut().iter_mut() {
            let r = v.scale_pow2(ql.scale_exp);
            *v = if i == last {
                Element::tanh(r)
            } else {
                Element::relu(r)
            };
        }
        stats_all.push(stats);
        xq = y;
    }
    (dequantize_tensor(&xq), stats_all)
}

/// Raw (format-erased) form of one quantized layer — the artifact
/// interchange unit (`i16` raws are widened to `i32` losslessly).
#[derive(Debug, Clone, PartialEq)]
pub struct QuantLayerRaw {
    pub w_shape: Vec<usize>,
    pub w_raw: Vec<i32>,
    pub b_raw: Vec<i32>,
    pub scale_exp: i32,
}

trait QuantForwardDyn: Send + Sync {
    fn generate(
        &self,
        net: &NetworkCfg,
        z: &Tensor,
        pool: &WorkerPool,
    ) -> (Tensor, Vec<OpStats>);
    fn format(&self) -> QFormat;
    fn export_raw(&self) -> Vec<QuantLayerRaw>;
}

struct QuantNet<S: Storage, const F: u32> {
    layers: Vec<QuantizedLayer<S, F>>,
}

impl<S: Storage, const F: u32> QuantForwardDyn for QuantNet<S, F> {
    fn generate(
        &self,
        net: &NetworkCfg,
        z: &Tensor,
        pool: &WorkerPool,
    ) -> (Tensor, Vec<OpStats>) {
        generator_forward_quant(net, &self.layers, z, pool)
    }

    fn format(&self) -> QFormat {
        QFormat::new(S::BITS, F)
    }

    fn export_raw(&self) -> Vec<QuantLayerRaw> {
        self.layers
            .iter()
            .map(|l| QuantLayerRaw {
                w_shape: l.w.shape().to_vec(),
                w_raw: l.w.data().iter().map(|q| q.raw().to_i64() as i32).collect(),
                b_raw: l.b.iter().map(|q| q.raw().to_i64() as i32).collect(),
                scale_exp: l.scale_exp,
            })
            .collect()
    }
}

/// Dispatch a runtime [`QFormat`] onto the supported monomorphizations.
macro_rules! for_format {
    ($bits:expr, $frac:expr, $mk:ident) => {
        match ($bits, $frac) {
            (16, 4) => $mk!(i16, 4),
            (16, 6) => $mk!(i16, 6),
            (16, 8) => $mk!(i16, 8),
            (16, 10) => $mk!(i16, 10),
            (16, 12) => $mk!(i16, 12),
            (32, 16) => $mk!(i32, 16),
            (32, 24) => $mk!(i32, 24),
            (b, f) => anyhow::bail!(
                "unsupported fixed-point format ({b} bits, {f} frac) — \
                 supported: {}",
                super::supported_formats()
                    .iter()
                    .map(|q| q.to_string())
                    .collect::<Vec<_>>()
                    .join(", ")
            ),
        }
    };
}

/// A quantized generator behind runtime format dispatch: quantize once
/// (with calibration), then serve `z → images` forwards.  This is what
/// the coordinator holds per `.q` logical network and what the artifact
/// layer exports/imports.
pub struct QuantizedGenerator {
    inner: Box<dyn QuantForwardDyn>,
}

impl QuantizedGenerator {
    /// Quantize an `f32` weight set at the given format.
    pub fn quantize(
        format: QFormat,
        weights: &[(Tensor, Vec<f32>)],
        rounding: Rounding,
    ) -> Result<Self> {
        macro_rules! mk {
            ($s:ty, $f:literal) => {
                Box::new(QuantNet::<$s, $f> {
                    layers: quantize_network::<$s, $f>(weights, rounding),
                }) as Box<dyn QuantForwardDyn>
            };
        }
        let inner = for_format!(format.bits, format.frac, mk);
        Ok(QuantizedGenerator { inner })
    }

    /// Rebuild from raw storage words (artifact import); bit-exact
    /// against the exported generator.
    pub fn from_raw(format: QFormat, layers: &[QuantLayerRaw]) -> Result<Self> {
        macro_rules! mk {
            ($s:ty, $f:literal) => {{
                let mut built = Vec::with_capacity(layers.len());
                for l in layers {
                    ensure!(
                        l.w_shape.iter().product::<usize>() == l.w_raw.len(),
                        "quantized layer shape/data mismatch"
                    );
                    let w = TensorT::from_fn(l.w_shape.clone(), |i| {
                        Fixed::<$s, $f>::from_raw(
                            <$s as Storage>::from_i64_sat(l.w_raw[i] as i64),
                        )
                    });
                    let b = l
                        .b_raw
                        .iter()
                        .map(|r| {
                            Fixed::<$s, $f>::from_raw(
                                <$s as Storage>::from_i64_sat(*r as i64),
                            )
                        })
                        .collect();
                    built.push(QuantizedLayer {
                        w,
                        b,
                        scale_exp: l.scale_exp,
                    });
                }
                Box::new(QuantNet::<$s, $f> { layers: built })
                    as Box<dyn QuantForwardDyn>
            }};
        }
        let inner = for_format!(format.bits, format.frac, mk);
        Ok(QuantizedGenerator { inner })
    }

    /// Run the quantized forward for a latent batch `[N, z_dim]`.
    pub fn generate(
        &self,
        net: &NetworkCfg,
        z: &Tensor,
        pool: &WorkerPool,
    ) -> (Tensor, Vec<OpStats>) {
        self.inner.generate(net, z, pool)
    }

    pub fn format(&self) -> QFormat {
        self.inner.format()
    }

    /// Format-erased raw layers (for artifact export).
    pub fn export_raw(&self) -> Vec<QuantLayerRaw> {
        self.inner.export_raw()
    }
}

#[cfg(test)]
mod tests {
    use super::super::fixed::Q8_8;
    use super::*;
    use crate::config::network_by_name;
    use crate::util::Rng;

    fn tiny_weights(seed: u64) -> Vec<(Tensor, Vec<f32>)> {
        let net = network_by_name("mnist").unwrap();
        let mut rng = Rng::seed_from_u64(seed);
        net.layers
            .iter()
            .map(|l| {
                (
                    Tensor::from_fn(vec![l.c_in, l.c_out, l.k, l.k], |_| {
                        0.05 * rng.normal_f32()
                    }),
                    (0..l.c_out).map(|_| 0.01 * rng.normal_f32()).collect(),
                )
            })
            .collect()
    }

    #[test]
    fn calibration_uses_spare_range() {
        // tiny weights → negative exponent (scale-up for resolution)
        let w = Tensor::from_fn(vec![1, 1, 2, 2], |_| 0.01);
        let e = calibrate_pow2_exp::<i16, 8>(&w, &[]);
        assert!(e < 0, "e={e}");
        // huge weights → positive exponent (scale-down to fit)
        let w = Tensor::from_fn(vec![1, 1, 2, 2], |_| 1.0e4);
        let e = calibrate_pow2_exp::<i16, 8>(&w, &[]);
        assert!(e > 0, "e={e}");
        assert!(1.0e4 / 2f32.powi(e) <= Fixed::<i16, 8>::max_value_f32());
        // all-zero weights are fine
        let w = Tensor::zeros(vec![1, 1, 2, 2]);
        assert_eq!(calibrate_pow2_exp::<i16, 8>(&w, &[]), 0);
    }

    #[test]
    fn calibration_covers_the_bias_range_too() {
        // tiny weights with an ordinary bias: the bias must survive
        // quantization (it is stored at the weight scale), so it has to
        // participate in the calibration
        let w = Tensor::from_fn(vec![1, 1, 2, 2], |_| 0.01);
        let b = [0.5f32];
        let e = calibrate_pow2_exp::<i16, 8>(&w, &b);
        let scale = 2f32.powi(e);
        assert!(
            0.5 / scale <= Fixed::<i16, 8>::max_value_f32(),
            "bias must fit at the calibrated scale (e={e})"
        );
        let q = quantize_network::<i16, 8>(
            &[(w, b.to_vec())],
            Rounding::Nearest,
        );
        let back = q[0].b[0].to_f32() * scale;
        assert!((back - 0.5).abs() < 1e-3, "bias roundtrip: {back}");
    }

    #[test]
    fn quantize_network_calibrates_per_layer() {
        let weights = tiny_weights(3);
        let q = quantize_network::<i16, 8>(&weights, Rounding::Nearest);
        assert_eq!(q.len(), weights.len());
        for (ql, (w, _)) in q.iter().zip(&weights) {
            assert_eq!(ql.w.shape(), w.shape());
            // calibrated reconstruction error ≤ step · scale
            let s = 2f32.powi(ql.scale_exp);
            for (qv, fv) in ql.w.data().iter().zip(w.data()) {
                let err = (qv.to_f32() * s - fv).abs();
                assert!(err <= Q8_8::step() * s, "err={err} scale={s}");
            }
        }
    }

    #[test]
    fn quantized_forward_tracks_f32_forward() {
        let net = network_by_name("mnist").unwrap();
        let weights = tiny_weights(11);
        let mut rng = Rng::seed_from_u64(5);
        let z = Tensor::from_fn(vec![2, net.z_dim], |_| rng.normal_f32());
        let reference = crate::deconv::generator_forward(&net, &weights, &z);
        let pool = WorkerPool::new(1);
        let gen = QuantizedGenerator::quantize(
            QFormat::new(16, 12),
            &weights,
            Rounding::Nearest,
        )
        .unwrap();
        let (images, stats) = gen.generate(&net, &z, &pool);
        assert_eq!(images.shape(), reference.shape());
        assert_eq!(stats.len(), net.layers.len());
        // tanh range, finite error
        assert!(images.data().iter().all(|v| v.abs() <= 1.0 + 1e-3));
        let err = images.max_abs_diff(&reference);
        assert!(err < 0.25, "Q4.12 end-to-end error too large: {err}");
        // byte accounting reflects the 2-byte elements
        let o = net.layers[0].o_h();
        assert_eq!(
            stats[0].ext_write_bytes,
            2 * (2 * net.layers[0].c_out * o * o) as u64
        );
    }

    #[test]
    fn dyn_dispatch_matches_direct_call() {
        let net = network_by_name("mnist").unwrap();
        let weights = tiny_weights(7);
        let mut rng = Rng::seed_from_u64(9);
        let z = Tensor::from_fn(vec![1, net.z_dim], |_| rng.normal_f32());
        let pool = WorkerPool::new(1);
        let direct = {
            let layers = quantize_network::<i16, 8>(&weights, Rounding::Nearest);
            generator_forward_quant(&net, &layers, &z, &pool).0
        };
        let gen = QuantizedGenerator::quantize(
            QFormat::new(16, 8),
            &weights,
            Rounding::Nearest,
        )
        .unwrap();
        assert_eq!(gen.format(), QFormat::new(16, 8));
        let (boxed, _) = gen.generate(&net, &z, &pool);
        assert_eq!(direct.data(), boxed.data(), "dispatch must be a no-op");
    }

    #[test]
    fn unsupported_format_errors() {
        let weights = tiny_weights(1);
        let bad = QuantizedGenerator::quantize(
            QFormat::new(8, 4),
            &weights,
            Rounding::Nearest,
        );
        assert!(bad.is_err());
    }

    #[test]
    fn raw_roundtrip_is_bit_exact() {
        let net = network_by_name("mnist").unwrap();
        let weights = tiny_weights(21);
        let gen = QuantizedGenerator::quantize(
            QFormat::new(16, 10),
            &weights,
            Rounding::Nearest,
        )
        .unwrap();
        let raw = gen.export_raw();
        let back =
            QuantizedGenerator::from_raw(QFormat::new(16, 10), &raw).unwrap();
        assert_eq!(back.export_raw(), raw);
        let mut rng = Rng::seed_from_u64(2);
        let z = Tensor::from_fn(vec![1, net.z_dim], |_| rng.normal_f32());
        let pool = WorkerPool::new(1);
        let (a, _) = gen.generate(&net, &z, &pool);
        let (b, _) = back.generate(&net, &z, &pool);
        assert_eq!(a.data(), b.data());
    }
}
