//! Quantized networks: per-output-channel power-of-two scale
//! calibration ([`ChannelScales`]), the fixed-point generator forward
//! (reverse-loop kernels + shift/LUT epilogue), and
//! [`QuantizedGenerator`] — the runtime-dispatch wrapper that lets
//! non-generic code (coordinator, CLI, artifact I/O) own a quantized
//! network without naming a concrete `Fixed<S, F>` type.

use super::element::Element;
use super::fixed::{Fixed, Rounding, Storage};
use super::{dequantize_tensor, QFormat};
use crate::config::NetworkCfg;
use crate::deconv::{deconv_reverse_loop_par, OpStats, ReverseLoopOpts};
use crate::tensor::{Tensor, TensorT};
use crate::util::WorkerPool;
use anyhow::{ensure, Result};

/// Per-output-channel power-of-two scale exponents for one layer:
/// channel `co` stores `stored · 2^exps[co] ≈ real`.  Every exponent is
/// a shift, so the epilogue stays multiplier-free — the per-channel
/// refinement narrow 8-bit stores need (one outlier channel no longer
/// drags the whole layer's resolution down), at zero datapath cost.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ChannelScales {
    exps: Vec<i32>,
}

impl ChannelScales {
    pub fn new(exps: Vec<i32>) -> Self {
        ChannelScales { exps }
    }

    /// The pre-PR-10 per-layer form: one exponent for every channel
    /// (how v1 `_quant.json` sidecars import).
    pub fn uniform(e: i32, c_out: usize) -> Self {
        ChannelScales {
            exps: vec![e; c_out],
        }
    }

    /// Exponent for output channel `co`.
    #[inline]
    pub fn exp(&self, co: usize) -> i32 {
        self.exps[co]
    }

    pub fn exps(&self) -> &[i32] {
        &self.exps
    }

    pub fn len(&self) -> usize {
        self.exps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.exps.is_empty()
    }
}

/// One quantized deconvolution layer: weights and bias of output
/// channel `co` stored as `stored · 2^scales.exp(co) ≈ real`, so the
/// kernel runs scale-free and the epilogue undoes each channel's scale
/// with a single shift.
pub struct QuantizedLayer<S: Storage, const F: u32> {
    pub w: TensorT<Fixed<S, F>>,
    pub b: Vec<Fixed<S, F>>,
    /// Per-output-channel power-of-two scale exponents (calibrated).
    pub scales: ChannelScales,
}

/// Calibrate the per-layer power-of-two scale: the smallest exponent
/// `e` such that `max(|w|, |b|) / 2^e` fits the representable range of
/// `Fixed<S, F>` — small-magnitude layers get a *negative* exponent,
/// spending the spare integer bits on resolution.  The bias must be
/// part of the calibration because it is stored at the same scale as
/// the weights (it seeds the accumulator in weight units); calibrating
/// on weights alone would saturate ordinary biases in tiny-weight
/// layers.
pub fn calibrate_pow2_exp<S: Storage, const F: u32>(
    w: &Tensor,
    b: &[f32],
) -> i32 {
    let max_abs = w
        .data()
        .iter()
        .chain(b.iter())
        .fold(0.0f32, |m, v| m.max(v.abs()));
    exp_for_max_abs(max_abs, Fixed::<S, F>::max_value_f32())
}

/// Smallest exponent `e` (clamped to ±30) such that `max_abs / 2^e`
/// fits `limit`.
fn exp_for_max_abs(max_abs: f32, limit: f32) -> i32 {
    if max_abs == 0.0 || !max_abs.is_finite() {
        return 0;
    }
    let mut e = ((max_abs / limit).log2().ceil() as i32).clamp(-30, 30);
    // guard against log2/powi rounding right at the boundary
    while max_abs / 2f32.powi(e) > limit && e < 30 {
        e += 1;
    }
    e
}

/// Calibrate one exponent *per output channel* of a `[c_in, c_out, k,
/// k]` weight tensor (bias `b[co]` participates in channel `co`'s
/// range, same reasoning as [`calibrate_pow2_exp`]).  A quiet channel
/// next to a loud one gets its own, smaller exponent — the per-layer
/// calibration is exactly the uniform special case.
pub fn calibrate_channel_exps<S: Storage, const F: u32>(
    w: &Tensor,
    b: &[f32],
) -> ChannelScales {
    assert_eq!(w.shape().len(), 4, "weights must be [c_in, c_out, k, k]");
    let c_out = w.shape()[1];
    let plane = w.shape()[2] * w.shape()[3];
    let mut max_abs = vec![0.0f32; c_out];
    for (i, v) in w.data().iter().enumerate() {
        let co = (i / plane) % c_out;
        max_abs[co] = max_abs[co].max(v.abs());
    }
    for (co, v) in b.iter().enumerate() {
        max_abs[co] = max_abs[co].max(v.abs());
    }
    let limit = Fixed::<S, F>::max_value_f32();
    ChannelScales::new(
        max_abs.iter().map(|m| exp_for_max_abs(*m, limit)).collect(),
    )
}

/// Quantize a whole weight set with per-output-channel calibrated
/// scales.
pub fn quantize_network<S: Storage, const F: u32>(
    weights: &[(Tensor, Vec<f32>)],
    rounding: Rounding,
) -> Vec<QuantizedLayer<S, F>> {
    weights
        .iter()
        .map(|(w, b)| {
            let scales = calibrate_channel_exps::<S, F>(w, b);
            let c_out = w.shape()[1];
            let plane = w.shape()[2] * w.shape()[3];
            let wq = TensorT::from_fn(w.shape().to_vec(), |i| {
                let co = (i / plane) % c_out;
                let inv = 2f32.powi(-scales.exp(co));
                Fixed::<S, F>::from_f32_round(w.data()[i] * inv, rounding)
            });
            let bq = b
                .iter()
                .enumerate()
                .map(|(co, v)| {
                    let inv = 2f32.powi(-scales.exp(co));
                    Fixed::<S, F>::from_f32_round(*v * inv, rounding)
                })
                .collect();
            QuantizedLayer {
                w: wq,
                b: bq,
                scales,
            }
        })
        .collect()
}

/// Per-layer (uniform) variant of [`quantize_network`]: one calibrated
/// exponent for the whole layer.  This is the pre-per-channel
/// behaviour, kept as the measurable baseline the per-channel
/// refinement is compared against (`edgedcnn quant` reports both at
/// the 8-bit formats, where the difference is largest).
pub fn quantize_network_per_layer<S: Storage, const F: u32>(
    weights: &[(Tensor, Vec<f32>)],
    rounding: Rounding,
) -> Vec<QuantizedLayer<S, F>> {
    weights
        .iter()
        .map(|(w, b)| {
            let e = calibrate_pow2_exp::<S, F>(w, b);
            let inv = 2f32.powi(-e);
            let wq = TensorT::from_fn(w.shape().to_vec(), |i| {
                Fixed::<S, F>::from_f32_round(w.data()[i] * inv, rounding)
            });
            let bq = b
                .iter()
                .map(|v| Fixed::<S, F>::from_f32_round(*v * inv, rounding))
                .collect();
            QuantizedLayer {
                w: wq,
                b: bq,
                scales: ChannelScales::uniform(e, w.shape()[1]),
            }
        })
        .collect()
}

/// Full generator forward pass in Qm.n fixed point: activations are
/// quantized once at the input, every layer runs the (generic)
/// reverse-loop kernel on fixed-point tensors, and the epilogue applies
/// the layer's power-of-two rescale plus ReLU/tanh — exactly the
/// shift-and-LUT epilogue the hardware pipeline executes.
///
/// Returns the dequantized images plus the per-layer [`OpStats`] (whose
/// byte counts now reflect the narrow element width).
pub fn generator_forward_quant<S: Storage, const F: u32>(
    net: &NetworkCfg,
    layers: &[QuantizedLayer<S, F>],
    z: &Tensor,
    pool: &WorkerPool,
) -> (Tensor, Vec<OpStats>) {
    assert_eq!(layers.len(), net.layers.len());
    assert_eq!(z.shape()[1], net.z_dim);
    let n = z.shape()[0];
    let mut xq: TensorT<Fixed<S, F>> =
        super::quantize_tensor::<S, F>(z, Rounding::Nearest)
            .reshape(vec![n, net.z_dim, 1, 1])
            .expect("z reshape");
    let last = net.layers.len() - 1;
    let mut stats_all = Vec::with_capacity(layers.len());
    for (i, (cfg, ql)) in net.layers.iter().zip(layers).enumerate() {
        let (mut y, stats) = deconv_reverse_loop_par(
            &xq,
            &ql.w,
            &ql.b,
            cfg.stride,
            cfg.padding,
            ReverseLoopOpts {
                tile: net.tile,
                zero_skip: true,
            },
            pool,
        );
        // per-channel shift epilogue: output is [n, c_out, o_h, o_w],
        // so channel planes are contiguous
        let c_out = y.shape()[1];
        let plane = y.shape()[2] * y.shape()[3];
        for (idx, v) in y.data_mut().iter_mut().enumerate() {
            let co = (idx / plane) % c_out;
            let r = v.scale_pow2(ql.scales.exp(co));
            *v = if i == last {
                Element::tanh(r)
            } else {
                Element::relu(r)
            };
        }
        stats_all.push(stats);
        xq = y;
    }
    (dequantize_tensor(&xq), stats_all)
}

/// Raw (format-erased) form of one quantized layer — the artifact
/// interchange unit (`i16` raws are widened to `i32` losslessly).
#[derive(Debug, Clone, PartialEq)]
pub struct QuantLayerRaw {
    pub w_shape: Vec<usize>,
    pub w_raw: Vec<i32>,
    pub b_raw: Vec<i32>,
    /// One exponent per output channel (v1 sidecars import their single
    /// per-layer exponent as a uniform vector).
    pub scale_exps: Vec<i32>,
}

trait QuantForwardDyn: Send + Sync {
    fn generate(
        &self,
        net: &NetworkCfg,
        z: &Tensor,
        pool: &WorkerPool,
    ) -> (Tensor, Vec<OpStats>);
    fn format(&self) -> QFormat;
    fn export_raw(&self) -> Vec<QuantLayerRaw>;
}

struct QuantNet<S: Storage, const F: u32> {
    layers: Vec<QuantizedLayer<S, F>>,
}

impl<S: Storage, const F: u32> QuantForwardDyn for QuantNet<S, F> {
    fn generate(
        &self,
        net: &NetworkCfg,
        z: &Tensor,
        pool: &WorkerPool,
    ) -> (Tensor, Vec<OpStats>) {
        generator_forward_quant(net, &self.layers, z, pool)
    }

    fn format(&self) -> QFormat {
        QFormat::new(S::BITS, F)
    }

    fn export_raw(&self) -> Vec<QuantLayerRaw> {
        self.layers
            .iter()
            .map(|l| QuantLayerRaw {
                w_shape: l.w.shape().to_vec(),
                w_raw: l.w.data().iter().map(|q| q.raw().to_i64() as i32).collect(),
                b_raw: l.b.iter().map(|q| q.raw().to_i64() as i32).collect(),
                scale_exps: l.scales.exps().to_vec(),
            })
            .collect()
    }
}

/// Dispatch a runtime [`QFormat`] onto the supported monomorphizations.
macro_rules! for_format {
    ($bits:expr, $frac:expr, $mk:ident) => {
        match ($bits, $frac) {
            (8, 6) => $mk!(i8, 6),
            (16, 4) => $mk!(i16, 4),
            (16, 6) => $mk!(i16, 6),
            (16, 8) => $mk!(i16, 8),
            (16, 10) => $mk!(i16, 10),
            (16, 12) => $mk!(i16, 12),
            (32, 16) => $mk!(i32, 16),
            (32, 24) => $mk!(i32, 24),
            (b, f) => anyhow::bail!(
                "unsupported fixed-point format ({b} bits, {f} frac) — \
                 supported: {}",
                super::supported_formats()
                    .iter()
                    .map(|q| q.to_string())
                    .collect::<Vec<_>>()
                    .join(", ")
            ),
        }
    };
}

/// A quantized generator behind runtime format dispatch: quantize once
/// (with calibration), then serve `z → images` forwards.  This is what
/// the coordinator holds per `.q` logical network and what the artifact
/// layer exports/imports.
pub struct QuantizedGenerator {
    inner: Box<dyn QuantForwardDyn>,
}

impl QuantizedGenerator {
    /// Quantize an `f32` weight set at the given format.
    pub fn quantize(
        format: QFormat,
        weights: &[(Tensor, Vec<f32>)],
        rounding: Rounding,
    ) -> Result<Self> {
        macro_rules! mk {
            ($s:ty, $f:literal) => {
                Box::new(QuantNet::<$s, $f> {
                    layers: quantize_network::<$s, $f>(weights, rounding),
                }) as Box<dyn QuantForwardDyn>
            };
        }
        let inner = for_format!(format.bits, format.frac, mk);
        Ok(QuantizedGenerator { inner })
    }

    /// Like [`QuantizedGenerator::quantize`] but with the per-layer
    /// (uniform) calibration — the baseline the per-channel refinement
    /// is measured against.
    pub fn quantize_per_layer(
        format: QFormat,
        weights: &[(Tensor, Vec<f32>)],
        rounding: Rounding,
    ) -> Result<Self> {
        macro_rules! mk {
            ($s:ty, $f:literal) => {
                Box::new(QuantNet::<$s, $f> {
                    layers: quantize_network_per_layer::<$s, $f>(
                        weights, rounding,
                    ),
                }) as Box<dyn QuantForwardDyn>
            };
        }
        let inner = for_format!(format.bits, format.frac, mk);
        Ok(QuantizedGenerator { inner })
    }

    /// Rebuild from raw storage words (artifact import); bit-exact
    /// against the exported generator.
    pub fn from_raw(format: QFormat, layers: &[QuantLayerRaw]) -> Result<Self> {
        macro_rules! mk {
            ($s:ty, $f:literal) => {{
                let mut built = Vec::with_capacity(layers.len());
                for l in layers {
                    ensure!(
                        l.w_shape.iter().product::<usize>() == l.w_raw.len(),
                        "quantized layer shape/data mismatch"
                    );
                    ensure!(
                        l.scale_exps.len() == l.b_raw.len(),
                        "quantized layer scale_exps/channel mismatch \
                         ({} exps, {} channels)",
                        l.scale_exps.len(),
                        l.b_raw.len()
                    );
                    let w = TensorT::from_fn(l.w_shape.clone(), |i| {
                        Fixed::<$s, $f>::from_raw(
                            <$s as Storage>::from_i64_sat(l.w_raw[i] as i64),
                        )
                    });
                    let b = l
                        .b_raw
                        .iter()
                        .map(|r| {
                            Fixed::<$s, $f>::from_raw(
                                <$s as Storage>::from_i64_sat(*r as i64),
                            )
                        })
                        .collect();
                    built.push(QuantizedLayer {
                        w,
                        b,
                        scales: ChannelScales::new(l.scale_exps.clone()),
                    });
                }
                Box::new(QuantNet::<$s, $f> { layers: built })
                    as Box<dyn QuantForwardDyn>
            }};
        }
        let inner = for_format!(format.bits, format.frac, mk);
        Ok(QuantizedGenerator { inner })
    }

    /// Run the quantized forward for a latent batch `[N, z_dim]`.
    pub fn generate(
        &self,
        net: &NetworkCfg,
        z: &Tensor,
        pool: &WorkerPool,
    ) -> (Tensor, Vec<OpStats>) {
        self.inner.generate(net, z, pool)
    }

    pub fn format(&self) -> QFormat {
        self.inner.format()
    }

    /// Format-erased raw layers (for artifact export).
    pub fn export_raw(&self) -> Vec<QuantLayerRaw> {
        self.inner.export_raw()
    }
}

#[cfg(test)]
mod tests {
    use super::super::fixed::{Q2_6, Q8_8};
    use super::*;
    use crate::config::network_by_name;
    use crate::util::Rng;

    fn tiny_weights(seed: u64) -> Vec<(Tensor, Vec<f32>)> {
        let net = network_by_name("mnist").unwrap();
        let mut rng = Rng::seed_from_u64(seed);
        net.layers
            .iter()
            .map(|l| {
                (
                    Tensor::from_fn(vec![l.c_in, l.c_out, l.k, l.k], |_| {
                        0.05 * rng.normal_f32()
                    }),
                    (0..l.c_out).map(|_| 0.01 * rng.normal_f32()).collect(),
                )
            })
            .collect()
    }

    #[test]
    fn calibration_uses_spare_range() {
        // tiny weights → negative exponent (scale-up for resolution)
        let w = Tensor::from_fn(vec![1, 1, 2, 2], |_| 0.01);
        let e = calibrate_pow2_exp::<i16, 8>(&w, &[]);
        assert!(e < 0, "e={e}");
        // huge weights → positive exponent (scale-down to fit)
        let w = Tensor::from_fn(vec![1, 1, 2, 2], |_| 1.0e4);
        let e = calibrate_pow2_exp::<i16, 8>(&w, &[]);
        assert!(e > 0, "e={e}");
        assert!(1.0e4 / 2f32.powi(e) <= Fixed::<i16, 8>::max_value_f32());
        // all-zero weights are fine
        let w = Tensor::zeros(vec![1, 1, 2, 2]);
        assert_eq!(calibrate_pow2_exp::<i16, 8>(&w, &[]), 0);
    }

    #[test]
    fn calibration_covers_the_bias_range_too() {
        // tiny weights with an ordinary bias: the bias must survive
        // quantization (it is stored at the weight scale), so it has to
        // participate in the calibration
        let w = Tensor::from_fn(vec![1, 1, 2, 2], |_| 0.01);
        let b = [0.5f32];
        let e = calibrate_pow2_exp::<i16, 8>(&w, &b);
        let scale = 2f32.powi(e);
        assert!(
            0.5 / scale <= Fixed::<i16, 8>::max_value_f32(),
            "bias must fit at the calibrated scale (e={e})"
        );
        let q = quantize_network::<i16, 8>(
            &[(w, b.to_vec())],
            Rounding::Nearest,
        );
        let back = q[0].b[0].to_f32() * scale;
        assert!((back - 0.5).abs() < 1e-3, "bias roundtrip: {back}");
    }

    #[test]
    fn quantize_network_calibrates_per_channel() {
        let weights = tiny_weights(3);
        let q = quantize_network::<i16, 8>(&weights, Rounding::Nearest);
        assert_eq!(q.len(), weights.len());
        for (ql, (w, _)) in q.iter().zip(&weights) {
            assert_eq!(ql.w.shape(), w.shape());
            let c_out = w.shape()[1];
            let plane = w.shape()[2] * w.shape()[3];
            assert_eq!(ql.scales.len(), c_out);
            // calibrated reconstruction error ≤ step · channel scale
            for (i, (qv, fv)) in ql.w.data().iter().zip(w.data()).enumerate()
            {
                let co = (i / plane) % c_out;
                let s = 2f32.powi(ql.scales.exp(co));
                let err = (qv.to_f32() * s - fv).abs();
                assert!(err <= Q8_8::step() * s, "err={err} scale={s}");
            }
        }
    }

    #[test]
    fn per_channel_scales_isolate_outlier_channels() {
        // channel 0 is loud (8.0), channel 1 is quiet (0.01): per-layer
        // calibration would spend channel 1's resolution on channel 0's
        // range; per-channel keeps the quiet channel sharp.
        let w = Tensor::from_fn(vec![1, 2, 2, 2], |i| {
            if i < 4 {
                8.0
            } else {
                0.01
            }
        });
        let b = vec![0.0f32, 0.0];
        let scales = calibrate_channel_exps::<i8, 6>(&w, &b);
        assert!(
            scales.exp(0) > scales.exp(1),
            "loud channel needs the bigger exponent: {:?}",
            scales.exps()
        );
        let q = quantize_network::<i8, 6>(&[(w.clone(), b)], Rounding::Nearest);
        // the quiet channel reconstructs to well under the per-layer
        // step at the loud channel's scale
        let s1 = 2f32.powi(q[0].scales.exp(1));
        for i in 4..8 {
            let err = (q[0].w.data()[i].to_f32() * s1 - w.data()[i]).abs();
            assert!(err <= 0.5 * Q2_6::step() * s1, "err={err}");
        }
    }

    #[test]
    fn quantized_forward_tracks_f32_forward() {
        let net = network_by_name("mnist").unwrap();
        let weights = tiny_weights(11);
        let mut rng = Rng::seed_from_u64(5);
        let z = Tensor::from_fn(vec![2, net.z_dim], |_| rng.normal_f32());
        let reference = crate::deconv::generator_forward(&net, &weights, &z);
        let pool = WorkerPool::new(1);
        let gen = QuantizedGenerator::quantize(
            QFormat::new(16, 12),
            &weights,
            Rounding::Nearest,
        )
        .unwrap();
        let (images, stats) = gen.generate(&net, &z, &pool);
        assert_eq!(images.shape(), reference.shape());
        assert_eq!(stats.len(), net.layers.len());
        // tanh range, finite error
        assert!(images.data().iter().all(|v| v.abs() <= 1.0 + 1e-3));
        let err = images.max_abs_diff(&reference);
        assert!(err < 0.25, "Q4.12 end-to-end error too large: {err}");
        // byte accounting reflects the 2-byte elements
        let o = net.layers[0].o_h();
        assert_eq!(
            stats[0].ext_write_bytes,
            2 * (2 * net.layers[0].c_out * o * o) as u64
        );
    }

    #[test]
    fn dyn_dispatch_matches_direct_call() {
        let net = network_by_name("mnist").unwrap();
        let weights = tiny_weights(7);
        let mut rng = Rng::seed_from_u64(9);
        let z = Tensor::from_fn(vec![1, net.z_dim], |_| rng.normal_f32());
        let pool = WorkerPool::new(1);
        let direct = {
            let layers = quantize_network::<i16, 8>(&weights, Rounding::Nearest);
            generator_forward_quant(&net, &layers, &z, &pool).0
        };
        let gen = QuantizedGenerator::quantize(
            QFormat::new(16, 8),
            &weights,
            Rounding::Nearest,
        )
        .unwrap();
        assert_eq!(gen.format(), QFormat::new(16, 8));
        let (boxed, _) = gen.generate(&net, &z, &pool);
        assert_eq!(direct.data(), boxed.data(), "dispatch must be a no-op");
    }

    #[test]
    fn unsupported_format_errors() {
        let weights = tiny_weights(1);
        let bad = QuantizedGenerator::quantize(
            QFormat::new(8, 4),
            &weights,
            Rounding::Nearest,
        );
        assert!(bad.is_err());
    }

    #[test]
    fn raw_roundtrip_is_bit_exact() {
        let net = network_by_name("mnist").unwrap();
        let weights = tiny_weights(21);
        let gen = QuantizedGenerator::quantize(
            QFormat::new(16, 10),
            &weights,
            Rounding::Nearest,
        )
        .unwrap();
        let raw = gen.export_raw();
        let back =
            QuantizedGenerator::from_raw(QFormat::new(16, 10), &raw).unwrap();
        assert_eq!(back.export_raw(), raw);
        let mut rng = Rng::seed_from_u64(2);
        let z = Tensor::from_fn(vec![1, net.z_dim], |_| rng.normal_f32());
        let pool = WorkerPool::new(1);
        let (a, _) = gen.generate(&net, &z, &pool);
        let (b, _) = back.generate(&net, &z, &pool);
        assert_eq!(a.data(), b.data());
    }
}
