//! Dependency-free utilities.  The build environment mirrors only the
//! `xla` crate's dependency closure, so the usual ecosystem crates
//! (rand, serde_json, clap, criterion, tempfile…) are implemented here
//! at the size this project actually needs.

mod bench;
mod flags;
mod json;
mod pool;
mod rng;
mod scratch;
mod tempdir;

pub use bench::{
    bench_header, smoke_mode, BenchReport, Bencher, TrialStats,
};
pub use flags::Flags;
pub use json::{escape_json, parse_json, Json};
pub use pool::WorkerPool;
pub use rng::Rng;
pub use scratch::{
    reset_scratch_stats, scratch_allocs, scratch_hits,
    scratch_hwm_bytes, scratch_stats, with_scratch, ScratchStats,
};
pub use tempdir::TempDir;
