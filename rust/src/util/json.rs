//! Minimal JSON parser — just enough for `artifacts/manifest.json` and
//! the training logs (objects, arrays, strings, numbers, booleans,
//! null; UTF-8; `\uXXXX` escapes).  No serialization framework: the
//! manifest schema is navigated explicitly by `artifacts::`.

use anyhow::{bail, ensure, Result};
use std::collections::BTreeMap;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object member lookup that errors with the key name.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing JSON key {key:?}"))
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => bail!("expected string, got {other:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => bail!("expected number, got {other:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        ensure!(n >= 0.0 && n.fract() == 0.0, "expected usize, got {n}");
        Ok(n as usize)
    }

    pub fn as_u64(&self) -> Result<u64> {
        let n = self.as_f64()?;
        ensure!(n >= 0.0 && n.fract() == 0.0, "expected u64, got {n}");
        Ok(n as u64)
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            other => bail!("expected array, got {other:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            other => bail!("expected object, got {other:?}"),
        }
    }
}

/// Escape a string for embedding in a JSON document (the inverse of
/// this parser's `string()` — quotes, backslashes and control
/// characters), so the hand-rolled writers round-trip any legal name.
pub fn escape_json(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out
}

/// Parse a complete JSON document.
pub fn parse_json(input: &str) -> Result<Json> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    ensure!(p.pos == p.bytes.len(), "trailing garbage at byte {}", p.pos);
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Result<u8> {
        let b = self
            .peek()
            .ok_or_else(|| anyhow::anyhow!("unexpected end of JSON"))?;
        self.pos += 1;
        Ok(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        let got = self.bump()?;
        ensure!(
            got == b,
            "expected {:?} at byte {}, got {:?}",
            b as char,
            self.pos - 1,
            got as char
        );
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected JSON byte {other:?} at {}", self.pos),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        ensure!(
            self.bytes[self.pos..].starts_with(word.as_bytes()),
            "bad literal at byte {}",
            self.pos
        );
        self.pos += word.len();
        Ok(v)
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => break,
                c => bail!("expected ',' or '}}', got {:?}", c as char),
            }
        }
        Ok(Json::Obj(map))
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => break,
                c => bail!("expected ',' or ']', got {:?}", c as char),
            }
        }
        Ok(Json::Arr(items))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let b = self.bump()?;
            match b {
                b'"' => break,
                b'\\' => {
                    let esc = self.bump()?;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let mut code = 0u32;
                            for _ in 0..4 {
                                let h = self.bump()? as char;
                                code = code * 16
                                    + h.to_digit(16).ok_or_else(|| {
                                        anyhow::anyhow!("bad \\u escape")
                                    })?;
                            }
                            out.push(
                                char::from_u32(code).unwrap_or('\u{FFFD}'),
                            );
                        }
                        c => bail!("bad escape \\{}", c as char),
                    }
                }
                _ => {
                    // copy the raw UTF-8 byte run
                    let start = self.pos - 1;
                    while let Some(nb) = self.peek() {
                        if nb == b'"' || nb == b'\\' {
                            break;
                        }
                        self.pos += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| anyhow::anyhow!("invalid utf8"))?,
                    );
                }
            }
        }
        Ok(out)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        Ok(Json::Num(s.parse::<f64>().map_err(|e| {
            anyhow::anyhow!("bad number {s:?}: {e}")
        })?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{
          "version": 1,
          "networks": {
            "mnist": {
              "batch_sizes": [1, 4, 8],
              "generators": {"1": "a.hlo.txt"},
              "tile": 12,
              "neg": -3.5e2,
              "flag": true,
              "nothing": null
            }
          }
        }"#;
        let v = parse_json(doc).unwrap();
        assert_eq!(v.req("version").unwrap().as_usize().unwrap(), 1);
        let net = v.req("networks").unwrap().req("mnist").unwrap();
        assert_eq!(net.req("tile").unwrap().as_usize().unwrap(), 12);
        assert_eq!(
            net.req("batch_sizes").unwrap().as_arr().unwrap().len(),
            3
        );
        assert_eq!(net.req("neg").unwrap().as_f64().unwrap(), -350.0);
        assert_eq!(net.req("flag").unwrap(), &Json::Bool(true));
        assert_eq!(net.req("nothing").unwrap(), &Json::Null);
    }

    #[test]
    fn string_escapes() {
        let v = parse_json(r#""a\n\"b\"A""#).unwrap();
        assert_eq!(v, Json::Str("a\n\"b\"A".into()));
    }

    #[test]
    fn escape_roundtrips_through_the_parser() {
        for s in ["plain", "with \"quotes\"", "back\\slash", "nl\nand\ttab", "\u{1}ctl"] {
            let doc = format!("\"{}\"", escape_json(s));
            assert_eq!(parse_json(&doc).unwrap(), Json::Str(s.into()), "{s:?}");
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_json("{").is_err());
        assert!(parse_json("[1,]").is_err());
        assert!(parse_json("{\"a\": 1} extra").is_err());
        assert!(parse_json("nope").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse_json("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(parse_json("[]").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn type_errors_are_reported() {
        let v = parse_json(r#"{"a": "s"}"#).unwrap();
        assert!(v.req("a").unwrap().as_f64().is_err());
        assert!(v.req("b").is_err());
        assert!(v.req("a").unwrap().as_str().is_ok());
    }
}
