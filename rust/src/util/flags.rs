//! Tiny CLI flag parser — `--key value` pairs after a subcommand (the
//! offline build environment mirrors only the `xla` dependency closure,
//! so no clap).  Promoted out of `main.rs` so the shared config layer
//! ([`crate::config::PoolCfg`] / [`crate::config::TrafficCfg`]) can
//! parse the same flags with identical semantics for every subcommand.

use anyhow::{bail, Result};
use std::collections::HashMap;

/// Parsed `--key value` flags; a `--key` followed by another flag (or
/// nothing) is a boolean and reads back as `"true"`.
pub struct Flags(HashMap<String, String>);

impl Flags {
    pub fn parse(args: &[String]) -> Result<Flags> {
        let mut map = HashMap::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if let Some(key) = a.strip_prefix("--") {
                // boolean flags have no value or are followed by a flag
                if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                    map.insert(key.to_string(), args[i + 1].clone());
                    i += 2;
                } else {
                    map.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                bail!("unexpected argument {a:?} (see `edgedcnn help`)");
            }
        }
        Ok(Flags(map))
    }

    /// Typed lookup with a default for absent flags; a present flag
    /// that fails to parse is an error, not the default.
    pub fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.0.get(key) {
            None => Ok(default),
            Some(raw) => raw
                .parse::<T>()
                .map_err(|_| anyhow::anyhow!("bad value for --{key}: {raw}")),
        }
    }

    /// Typed lookup that distinguishes "absent" from any value.
    pub fn get_opt<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>> {
        match self.0.get(key) {
            None => Ok(None),
            Some(raw) => raw
                .parse::<T>()
                .map(Some)
                .map_err(|_| anyhow::anyhow!("bad value for --{key}: {raw}")),
        }
    }

    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.0
            .get(key)
            .cloned()
            .unwrap_or_else(|| default.to_string())
    }

    pub fn has(&self, key: &str) -> bool {
        self.0.contains_key(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|a| a.to_string()).collect()
    }

    #[test]
    fn parses_pairs_booleans_and_typed_values() {
        let f = Flags::parse(&argv(&[
            "--requests", "24", "--shard", "--scenario", "flash",
        ]))
        .unwrap();
        assert_eq!(f.get("requests", 0usize).unwrap(), 24);
        assert!(f.has("shard"));
        assert_eq!(f.get_str("scenario", "steady"), "flash");
        assert_eq!(f.get_str("missing", "fallback"), "fallback");
        assert_eq!(f.get_opt::<u64>("requests").unwrap(), Some(24));
        assert_eq!(f.get_opt::<u64>("missing").unwrap(), None);
    }

    #[test]
    fn rejects_positional_args_and_bad_values() {
        assert!(Flags::parse(&argv(&["oops"])).is_err());
        let f = Flags::parse(&argv(&["--requests", "many"])).unwrap();
        assert!(f.get("requests", 0usize).is_err());
        assert!(f.get_opt::<usize>("requests").is_err());
    }
}
