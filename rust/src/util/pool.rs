//! Scoped worker pool — the spatio-temporal parallel execution engine's
//! substrate.  Dependency-free (std threads only), deterministic result
//! ordering, panic propagation.
//!
//! The pool mirrors the paper's hardware shape in software: a fixed set
//! of workers (the CU array) pulls independent jobs (output tiles / CU
//! workloads / layer simulations) from a shared counter and writes each
//! result into its own pre-assigned slot, so the caller always observes
//! results in job-index order regardless of scheduling.  Workers are
//! scoped (`std::thread::scope`), so jobs may borrow from the caller's
//! stack — no `'static` bound, no channels, no queues.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// A fixed-width pool of scoped worker threads.
///
/// `WorkerPool::new(1)` degenerates to inline serial execution (no
/// threads are spawned), which keeps the serial/parallel code paths
/// literally identical for the equivalence tests.
#[derive(Debug, Clone, Copy)]
pub struct WorkerPool {
    workers: usize,
}

impl WorkerPool {
    /// A pool with exactly `workers` workers (clamped to ≥ 1).
    pub fn new(workers: usize) -> Self {
        WorkerPool {
            workers: workers.max(1),
        }
    }

    /// A pool sized to the host (`available_parallelism`), honouring the
    /// `EDGEDCNN_WORKERS` override.
    pub fn with_default_parallelism() -> Self {
        let workers = std::env::var("EDGEDCNN_WORKERS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .unwrap_or_else(|| {
                std::thread::available_parallelism()
                    .map(|n| n.get())
                    .unwrap_or(1)
            });
        WorkerPool::new(workers)
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Evaluate `f(0), f(1), …, f(n-1)` across the pool and return the
    /// results in index order.
    ///
    /// Jobs are claimed from an atomic counter (work stealing by
    /// exhaustion); each result lands in its own slot, so the output
    /// order is deterministic no matter how the OS schedules workers.
    /// A panic in any job propagates to the caller (the scope re-raises
    /// it when the panicked worker is joined).
    ///
    /// Each call spawns one scoped thread set and joins it before
    /// returning — there are no persistent workers.  Callers in hot
    /// loops should batch their jobs into one `map_indexed` call per
    /// loop body (the way [`crate::fpga::simulate_layer_par`] folds all
    /// tile batches of a layer into one dispatch) rather than calling
    /// per tiny job set.
    pub fn map_indexed<R, F>(&self, n: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize) -> R + Sync,
    {
        self.map_indexed_chunked(n, 1, f)
    }

    /// [`Self::map_indexed`] with chunked claims: workers grab `chunk`
    /// consecutive indices per atomic fetch, so very small jobs amortize
    /// the claim/slot overhead instead of paying it per job.  Results
    /// are identical to `map_indexed` for any chunk size (every job
    /// still writes its own pre-assigned slot); only the claim
    /// granularity — and therefore load-balance vs overhead — changes.
    pub fn map_indexed_chunked<R, F>(&self, n: usize, chunk: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize) -> R + Sync,
    {
        let chunk = chunk.max(1);
        if n == 0 {
            return Vec::new();
        }
        if self.workers == 1 || n == 1 {
            return (0..n).map(f).collect();
        }
        let next = AtomicUsize::new(0);
        let slots: Vec<Mutex<Option<R>>> =
            (0..n).map(|_| Mutex::new(None)).collect();
        std::thread::scope(|s| {
            let spawn_workers = self.workers.min(n.div_ceil(chunk));
            for _ in 0..spawn_workers {
                s.spawn(|| loop {
                    let start = next.fetch_add(chunk, Ordering::Relaxed);
                    if start >= n {
                        break;
                    }
                    for i in start..(start + chunk).min(n) {
                        let r = f(i);
                        *slots[i].lock().unwrap() = Some(r);
                    }
                });
            }
        });
        slots
            .into_iter()
            .map(|m| {
                m.into_inner()
                    .expect("worker poisoned a result slot")
                    .expect("worker pool left a slot unfilled")
            })
            .collect()
    }

    /// Chunk size for [`Self::map_indexed_chunked`] given a measured
    /// per-job cost: claims get batched until the per-claim overhead
    /// (one atomic fetch + one slot store, `CLAIM_OVERHEAD_NS`) is at
    /// most `CLAIM_OVERHEAD_BUDGET` of a chunk's work — but never so
    /// large that a worker holds fewer than ~4 chunks (load balance
    /// degrades to static partitioning otherwise).  Results are chunk-
    /// size independent; only dispatch overhead vs balance changes.
    pub fn chunk_for_cost(per_job_cost_ns: f64, n: usize, workers: usize) -> usize {
        /// Measured cost of one claim/slot round trip, nanoseconds
        /// (atomic fetch_add + mutex slot store on a contended line).
        const CLAIM_OVERHEAD_NS: f64 = 200.0;
        /// Fraction of a chunk's work the claim may cost.
        const CLAIM_OVERHEAD_BUDGET: f64 = 0.02;
        if n == 0 {
            return 1;
        }
        // The cost probe can land on a degenerate job — an empty macro
        // tile measures ~0 ns, and a pathological caller could even pass
        // a non-finite duration.  Sanitize to the 1 ns floor so `ideal`
        // is always a well-defined positive integer (a NaN or ±inf cost
        // must never turn into a zero-length or oversized chunk).
        let per_job = if per_job_cost_ns.is_finite() {
            per_job_cost_ns.max(1.0)
        } else {
            1.0
        };
        let ideal = (CLAIM_OVERHEAD_NS / (CLAIM_OVERHEAD_BUDGET * per_job))
            .ceil() as usize;
        // `balance_cap ≤ max(n/4, 1) ≤ n` for every n ≥ 1, so the
        // returned chunk is always in `1..=n`: dispatch never sees a
        // zero-length chunk and never claims past the job set in one
        // fetch, even when n is smaller than one macro-tile.
        let balance_cap = (n / (workers.max(1) * 4)).max(1).min(n);
        ideal.clamp(1, balance_cap)
    }

    /// [`Self::map_indexed_chunked`] with **adaptive** chunk sizing:
    /// job 0 runs inline and its measured duration seeds
    /// [`Self::chunk_for_cost`] for the remaining jobs.  Expensive jobs
    /// degenerate to per-job claims (best balance), tiny jobs get large
    /// chunks (amortized dispatch) — no caller-side cost heuristics
    /// needed.  Results are identical to `map_indexed` for any measured
    /// cost.
    pub fn map_indexed_auto<R, F>(&self, n: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize) -> R + Sync,
    {
        if n == 0 {
            return Vec::new();
        }
        let t0 = std::time::Instant::now();
        let first = f(0);
        if self.workers == 1 || n == 1 {
            let mut out = Vec::with_capacity(n);
            out.push(first);
            out.extend((1..n).map(f));
            return out;
        }
        let cost_ns = t0.elapsed().as_nanos() as f64;
        let chunk = Self::chunk_for_cost(cost_ns, n - 1, self.workers);
        let rest = self.map_indexed_chunked(n - 1, chunk, |i| f(i + 1));
        let mut out = Vec::with_capacity(n);
        out.push(first);
        out.extend(rest);
        out
    }

    /// Map `f` over a slice, preserving element order.
    pub fn map<T, R, F>(&self, items: &[T], f: F) -> Vec<R>
    where
        T: Sync,
        R: Send,
        F: Fn(&T) -> R + Sync,
    {
        self.map_indexed(items.len(), |i| f(&items[i]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::panic::{catch_unwind, AssertUnwindSafe};
    use std::sync::atomic::AtomicU64;

    #[test]
    fn results_are_in_index_order() {
        let pool = WorkerPool::new(4);
        let got = pool.map_indexed(100, |i| i * i);
        let want: Vec<usize> = (0..100).map(|i| i * i).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn deterministic_under_contention() {
        // jitter the per-job runtime so workers constantly interleave;
        // the output order must still be exactly the input order
        let pool = WorkerPool::new(8);
        for round in 0..5u64 {
            let got = pool.map_indexed(200, |i| {
                if (i as u64 + round) % 7 == 0 {
                    std::thread::sleep(std::time::Duration::from_micros(50));
                }
                (i, i as u64 * 31 + round)
            });
            for (slot, (i, v)) in got.iter().enumerate() {
                assert_eq!(slot, *i);
                assert_eq!(*v, *i as u64 * 31 + round);
            }
        }
    }

    #[test]
    fn single_worker_runs_inline() {
        let pool = WorkerPool::new(1);
        let tid = std::thread::current().id();
        let ids = pool.map_indexed(4, |_| std::thread::current().id());
        assert!(ids.iter().all(|id| *id == tid), "no threads for w=1");
    }

    #[test]
    fn empty_and_singleton_inputs() {
        let pool = WorkerPool::new(4);
        assert_eq!(pool.map_indexed(0, |i| i), Vec::<usize>::new());
        assert_eq!(pool.map_indexed(1, |i| i + 7), vec![7]);
    }

    #[test]
    fn map_over_slice_borrows() {
        let pool = WorkerPool::new(3);
        let items = vec![1.0f64, 2.0, 3.0, 4.0];
        let got = pool.map(&items, |x| x * 2.0);
        assert_eq!(got, vec![2.0, 4.0, 6.0, 8.0]);
    }

    #[test]
    fn panics_propagate_to_caller() {
        let pool = WorkerPool::new(4);
        let result = catch_unwind(AssertUnwindSafe(|| {
            pool.map_indexed(16, |i| {
                if i == 9 {
                    panic!("job 9 exploded");
                }
                i
            })
        }));
        assert!(result.is_err(), "a job panic must reach the caller");
    }

    #[test]
    fn every_job_runs_exactly_once() {
        let pool = WorkerPool::new(6);
        let counter = AtomicU64::new(0);
        let n = 500;
        let got = pool.map_indexed(n, |i| {
            counter.fetch_add(1, Ordering::Relaxed);
            i
        });
        assert_eq!(counter.load(Ordering::Relaxed), n as u64);
        assert_eq!(got.len(), n);
    }

    #[test]
    fn chunked_matches_unchunked_for_any_chunk() {
        let pool = WorkerPool::new(5);
        let want: Vec<usize> = (0..123).map(|i| i * 3 + 1).collect();
        for chunk in [0, 1, 2, 7, 32, 123, 1000] {
            let got = pool.map_indexed_chunked(123, chunk, |i| i * 3 + 1);
            assert_eq!(got, want, "chunk={chunk}");
        }
    }

    #[test]
    fn chunked_runs_every_job_exactly_once() {
        let pool = WorkerPool::new(4);
        let counter = AtomicU64::new(0);
        let got = pool.map_indexed_chunked(97, 8, |i| {
            counter.fetch_add(1, Ordering::Relaxed);
            i
        });
        assert_eq!(counter.load(Ordering::Relaxed), 97);
        assert_eq!(got, (0..97).collect::<Vec<_>>());
    }

    #[test]
    fn chunked_deterministic_under_contention() {
        let pool = WorkerPool::new(8);
        for round in 0..3u64 {
            let got = pool.map_indexed_chunked(200, 6, |i| {
                if (i as u64 + round) % 11 == 0 {
                    std::thread::sleep(std::time::Duration::from_micros(40));
                }
                (i, i as u64 * 13 + round)
            });
            for (slot, (i, v)) in got.iter().enumerate() {
                assert_eq!(slot, *i);
                assert_eq!(*v, *i as u64 * 13 + round);
            }
        }
    }

    #[test]
    fn zero_workers_clamps_to_one() {
        let pool = WorkerPool::new(0);
        assert_eq!(pool.workers(), 1);
        assert_eq!(pool.map_indexed(3, |i| i), vec![0, 1, 2]);
    }

    #[test]
    fn chunk_for_cost_pins_known_cost_ratios() {
        // expensive jobs: the claim overhead (200 ns) is noise → chunk 1
        assert_eq!(WorkerPool::chunk_for_cost(1e6, 10_000, 4), 1);
        // 10 µs jobs: 200/(0.02·10000) = 1 → still per-job claims
        assert_eq!(WorkerPool::chunk_for_cost(10_000.0, 10_000, 4), 1);
        // 100 ns jobs: 200/(0.02·100) = 100 → chunk 100 (cap 625)
        assert_eq!(WorkerPool::chunk_for_cost(100.0, 10_000, 4), 100);
        // 1 ns jobs: ideal 10000 but load balance caps at n/(4·workers)
        assert_eq!(WorkerPool::chunk_for_cost(1.0, 10_000, 4), 625);
        // the cap itself scales with worker count
        assert_eq!(WorkerPool::chunk_for_cost(1.0, 10_000, 8), 312);
        // degenerate inputs stay sane
        assert_eq!(WorkerPool::chunk_for_cost(0.0, 7, 4), 1);
        assert_eq!(WorkerPool::chunk_for_cost(1.0, 0, 4), 1);
    }

    #[test]
    fn chunk_for_cost_survives_degenerate_probes() {
        // an empty macro-tile measures ~0 ns; non-finite probes are the
        // pathological caller — all must yield a chunk in 1..=n
        for cost in [0.0, -1.0, f64::NAN, f64::INFINITY, f64::NEG_INFINITY]
        {
            for n in [1usize, 2, 3, 7, 100] {
                for workers in [1usize, 4, 64] {
                    let c = WorkerPool::chunk_for_cost(cost, n, workers);
                    assert!(
                        (1..=n).contains(&c),
                        "cost={cost} n={n} w={workers} -> chunk={c}"
                    );
                }
            }
        }
        // n smaller than one claim quantum: chunk must not exceed n
        assert_eq!(WorkerPool::chunk_for_cost(1.0, 1, 1), 1);
        assert_eq!(WorkerPool::chunk_for_cost(1.0, 2, 1), 1);
        assert_eq!(WorkerPool::chunk_for_cost(f64::NAN, 1, 8), 1);
    }

    #[test]
    fn adaptive_handles_job_sets_smaller_than_a_macro_tile() {
        // blocked dispatch hands map_indexed_auto one job per macro
        // tile; tiny outputs produce 1-3 jobs where the cost probe eats
        // job 0 and the remainder must still all run exactly once
        let pool = WorkerPool::new(8);
        for n in 1..=6 {
            let counter = AtomicU64::new(0);
            let got = pool.map_indexed_auto(n, |i| {
                counter.fetch_add(1, Ordering::Relaxed);
                i
            });
            assert_eq!(counter.load(Ordering::Relaxed), n as u64);
            assert_eq!(got, (0..n).collect::<Vec<_>>());
        }
    }

    #[test]
    fn adaptive_matches_plain_map() {
        let pool = WorkerPool::new(5);
        let want: Vec<usize> = (0..300).map(|i| i * 7 + 3).collect();
        // cheap jobs (large chunks) and artificially slow jobs (chunk 1)
        assert_eq!(pool.map_indexed_auto(300, |i| i * 7 + 3), want);
        let got = pool.map_indexed_auto(300, |i| {
            if i == 0 {
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            i * 7 + 3
        });
        assert_eq!(got, want);
        // single-worker and tiny inputs run inline
        assert_eq!(WorkerPool::new(1).map_indexed_auto(4, |i| i), vec![0, 1, 2, 3]);
        assert_eq!(pool.map_indexed_auto(0, |i| i), Vec::<usize>::new());
        assert_eq!(pool.map_indexed_auto(1, |i| i + 9), vec![9]);
    }

    #[test]
    fn adaptive_runs_every_job_exactly_once() {
        let pool = WorkerPool::new(4);
        let counter = AtomicU64::new(0);
        let got = pool.map_indexed_auto(97, |i| {
            counter.fetch_add(1, Ordering::Relaxed);
            i
        });
        assert_eq!(counter.load(Ordering::Relaxed), 97);
        assert_eq!(got, (0..97).collect::<Vec<_>>());
    }
}
