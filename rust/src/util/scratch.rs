//! Per-worker scratch arena for the numeric hot path.
//!
//! Every tile the reverse-loop kernel executes needs one accumulator
//! block in the wide [`Element::Acc`](crate::quant::Element::Acc)
//! domain.  Allocating that block per tile puts a `malloc`/`free` pair
//! on the innermost serving path; this arena keeps one reusable buffer
//! per element type **per worker thread** (worker threads each execute
//! many tiles per dispatch, and the serial path reuses the caller
//! thread's buffer across entire forward passes).  Buffers only ever
//! grow — a smaller tile reuses the capacity of the largest tile shape
//! seen so far — and are re-zeroed to the requested fill value on every
//! acquisition, so reuse is observationally identical to a fresh
//! `vec![zero; len]`.
//!
//! The arena is plain safe Rust: a `thread_local!` map from the
//! buffer's element `TypeId` to its `Vec`.  The buffer is *removed*
//! from the map for the duration of the closure, so a nested
//! `with_scratch` of the same type simply takes a second buffer instead
//! of aliasing the first.
//!
//! [`scratch_allocs`] / [`scratch_hits`] expose the current thread's
//! acquisition counters so tests can assert that two successive tiles
//! reuse (and correctly re-zero) the same backing buffer.

use std::any::{Any, TypeId};
use std::cell::{Cell, RefCell};
use std::collections::HashMap;

thread_local! {
    static ARENA: RefCell<HashMap<TypeId, Box<dyn Any>>> =
        RefCell::new(HashMap::new());
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
    static HITS: Cell<u64> = const { Cell::new(0) };
    static HWM_BYTES: Cell<u64> = const { Cell::new(0) };
}

/// Snapshot of this thread's arena counters — see [`scratch_stats`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ScratchStats {
    /// Fresh allocations (capacity misses) since the last reset.
    pub allocs: u64,
    /// Buffer reuses (capacity hits) since the last reset.
    pub hits: u64,
    /// Peak bytes borrowed by a single acquisition since the last
    /// reset (`len · size_of::<A>()` — the high-water-mark that says
    /// how much arena memory a kernel's tile geometry pins per worker).
    pub hwm_bytes: u64,
}

/// Run `f` with a scratch slice of `len` elements, every element set to
/// `zero`.  The backing buffer is reused from this thread's arena when
/// its capacity suffices (counted by [`scratch_hits`]); otherwise a
/// fresh allocation is made (counted by [`scratch_allocs`]).
pub fn with_scratch<A, R>(
    len: usize,
    zero: A,
    f: impl FnOnce(&mut [A]) -> R,
) -> R
where
    A: Copy + Send + 'static,
{
    let key = TypeId::of::<Vec<A>>();
    let borrowed = (len * std::mem::size_of::<A>()) as u64;
    HWM_BYTES.with(|c| c.set(c.get().max(borrowed)));
    let mut buf: Vec<A> = ARENA
        .with(|a| a.borrow_mut().remove(&key))
        .and_then(|b| b.downcast::<Vec<A>>().ok())
        .map(|b| *b)
        .unwrap_or_default();
    if buf.capacity() < len {
        ALLOCS.with(|c| c.set(c.get() + 1));
        buf = Vec::with_capacity(len);
    } else {
        HITS.with(|c| c.set(c.get() + 1));
    }
    buf.clear();
    buf.resize(len, zero);
    let r = f(&mut buf);
    ARENA.with(|a| a.borrow_mut().insert(key, Box::new(buf)));
    r
}

/// Fresh allocations this thread's arena has made (capacity misses).
pub fn scratch_allocs() -> u64 {
    ALLOCS.with(|c| c.get())
}

/// Buffer reuses this thread's arena has served (capacity hits).
pub fn scratch_hits() -> u64 {
    HITS.with(|c| c.get())
}

/// Peak bytes borrowed by a single acquisition on this thread since
/// the last [`reset_scratch_stats`].
pub fn scratch_hwm_bytes() -> u64 {
    HWM_BYTES.with(|c| c.get())
}

/// Full snapshot of this thread's arena counters.
pub fn scratch_stats() -> ScratchStats {
    ScratchStats {
        allocs: scratch_allocs(),
        hits: scratch_hits(),
        hwm_bytes: scratch_hwm_bytes(),
    }
}

/// Reset this thread's arena counters — including the borrowed-bytes
/// high-water-mark — for test isolation; the buffers themselves are
/// kept so a reset never forces a re-allocation.
pub fn reset_scratch_stats() {
    ALLOCS.with(|c| c.set(0));
    HITS.with(|c| c.set(0));
    HWM_BYTES.with(|c| c.set(0));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn second_acquisition_reuses_the_buffer() {
        // use a type no other test in this binary touches, so the
        // per-thread counters are exact
        #[derive(Clone, Copy, PartialEq, Debug)]
        struct Probe(u64);
        reset_scratch_stats();
        let a0 = scratch_allocs();
        with_scratch(64, Probe(0), |s| {
            assert_eq!(s.len(), 64);
            s[0] = Probe(7);
        });
        assert_eq!(scratch_allocs(), a0 + 1, "first use allocates");
        with_scratch(32, Probe(1), |s| {
            // re-zeroed to the new fill, not the stale Probe(7)
            assert!(s.iter().all(|v| *v == Probe(1)), "must be re-zeroed");
        });
        assert_eq!(scratch_allocs(), a0 + 1, "smaller request reuses");
        assert!(scratch_hits() >= 1);
        with_scratch(128, Probe(2), |s| assert_eq!(s.len(), 128));
        assert_eq!(scratch_allocs(), a0 + 2, "growth allocates once more");
        with_scratch(128, Probe(3), |s| {
            assert!(s.iter().all(|v| *v == Probe(3)));
        });
        assert_eq!(scratch_allocs(), a0 + 2, "steady state: no allocs");
    }

    #[test]
    fn hwm_tracks_the_peak_borrow_and_resets() {
        #[derive(Clone, Copy)]
        struct HwmProbe([u8; 8]);
        reset_scratch_stats();
        with_scratch(16, HwmProbe([0; 8]), |_| {});
        assert_eq!(scratch_hwm_bytes(), 128, "16 × 8-byte elements");
        with_scratch(4, HwmProbe([0; 8]), |_| {});
        assert_eq!(scratch_hwm_bytes(), 128, "smaller borrow keeps peak");
        with_scratch(32, HwmProbe([0; 8]), |_| {});
        assert_eq!(scratch_hwm_bytes(), 256, "larger borrow raises peak");
        let stats = scratch_stats();
        assert_eq!(stats.hwm_bytes, 256);
        assert_eq!(stats.allocs + stats.hits, 3);
        reset_scratch_stats();
        assert_eq!(scratch_hwm_bytes(), 0, "reset clears the peak");
    }

    #[test]
    fn nested_same_type_does_not_alias() {
        let outer = with_scratch(8, 1u128, |s| {
            s[0] = 42;
            let inner = with_scratch(8, 2u128, |t| {
                assert!(t.iter().all(|v| *v == 2));
                t[0]
            });
            assert_eq!(s[0], 42, "inner call must not clobber the outer");
            s[0] + inner
        });
        assert_eq!(outer, 44);
    }

    #[test]
    fn distinct_types_get_distinct_buffers() {
        with_scratch(4, 1.5f64, |s| {
            with_scratch(4, 3i8, |t| {
                assert!(s.iter().all(|v| *v == 1.5));
                assert!(t.iter().all(|v| *v == 3));
            });
        });
    }
}
