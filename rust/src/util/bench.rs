//! Tiny benchmarking harness (criterion stand-in): warm-up, N timed
//! iterations, mean/σ/min, throughput annotation, and a stable text
//! report consumed by `cargo bench` (harness = false bench binaries).
//! [`TrialStats`] adds the robust (median + MAD) trial statistics the
//! regression-defended `edgedcnn bench` suite records.

use crate::stats::{median, percentile, Summary};
use std::time::Instant;

/// One benchmark runner.
pub struct Bencher {
    name: String,
    warmup_iters: usize,
    iters: usize,
}

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchReport {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
    /// Optional ops-per-iteration for throughput reporting.
    pub ops_per_iter: Option<f64>,
}

impl Bencher {
    pub fn new(name: &str) -> Self {
        Bencher {
            name: name.to_string(),
            warmup_iters: 1,
            iters: 10,
        }
    }

    pub fn iters(mut self, n: usize) -> Self {
        self.iters = n.max(1);
        self
    }

    pub fn warmup(mut self, n: usize) -> Self {
        self.warmup_iters = n;
        self
    }

    /// Time `f` (which should return something observable to keep the
    /// optimizer honest) and report.
    pub fn run<T>(&self, mut f: impl FnMut() -> T) -> BenchReport {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        let s = Summary::of(&samples);
        BenchReport {
            name: self.name.clone(),
            iters: self.iters,
            mean_s: s.mean,
            std_s: s.std,
            min_s: samples.iter().cloned().fold(f64::INFINITY, f64::min),
            ops_per_iter: None,
        }
    }

    /// Like [`run`](Self::run) with an ops-per-iteration annotation for
    /// GOps/s reporting.
    pub fn run_with_ops<T>(
        &self,
        ops_per_iter: f64,
        f: impl FnMut() -> T,
    ) -> BenchReport {
        let mut r = self.run(f);
        r.ops_per_iter = Some(ops_per_iter);
        r
    }
}

/// Robust per-trial timing statistics: median (location), MAD (noise
/// scale — median absolute deviation from the median), and p99.  The
/// benchmark regression gate compares *medians* with a tolerance scaled
/// by the *MAD*, so a noisy machine widens its own acceptance band
/// instead of tripping false regressions.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TrialStats {
    pub trials: usize,
    pub median_s: f64,
    pub mad_s: f64,
    pub p99_s: f64,
    pub min_s: f64,
}

impl TrialStats {
    /// Compute the statistics over raw per-trial wall times (seconds).
    pub fn of(samples: &[f64]) -> TrialStats {
        assert!(!samples.is_empty(), "TrialStats over no samples");
        let med = median(samples);
        let devs: Vec<f64> =
            samples.iter().map(|s| (s - med).abs()).collect();
        TrialStats {
            trials: samples.len(),
            median_s: med,
            mad_s: median(&devs),
            p99_s: percentile(samples, 99.0),
            min_s: samples.iter().cloned().fold(f64::INFINITY, f64::min),
        }
    }

    /// MAD relative to the median (0 when the median is 0) — the
    /// dimensionless noise figure the regression tolerance is built on.
    pub fn rel_mad(&self) -> f64 {
        if self.median_s > 0.0 {
            self.mad_s / self.median_s
        } else {
            0.0
        }
    }
}

impl Bencher {
    /// Warm up, then time each iteration individually and return the
    /// robust [`TrialStats`] over the per-trial samples (the form the
    /// `edgedcnn bench` JSON records).
    pub fn run_trials<T>(&self, mut f: impl FnMut() -> T) -> TrialStats {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        TrialStats::of(&samples)
    }
}

impl BenchReport {
    pub fn render(&self) -> String {
        let mut s = format!(
            "{:<44} {:>10.3} ms ±{:>7.3} (min {:>9.3}, n={})",
            self.name,
            self.mean_s * 1e3,
            self.std_s * 1e3,
            self.min_s * 1e3,
            self.iters
        );
        if let Some(ops) = self.ops_per_iter {
            s.push_str(&format!(
                "  [{:>8.3} GOps/s]",
                ops / self.mean_s / 1e9
            ));
        }
        s
    }
}

/// Print a standard bench header (so `cargo bench` output is greppable).
pub fn bench_header(title: &str) {
    println!("\n=== bench: {title} ===");
}

/// Shared quick-mode switch for the bench binaries: `--smoke` on the
/// command line, or `EDGEDCNN_BENCH_SMOKE` set to anything but `0`/empty
/// (so `EDGEDCNN_BENCH_SMOKE=0` disables it, as one would expect).
pub fn smoke_mode() -> bool {
    std::env::args().any(|a| a == "--smoke")
        || std::env::var("EDGEDCNN_BENCH_SMOKE")
            .map(|v| v != "0" && !v.is_empty())
            .unwrap_or(false)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let r = Bencher::new("spin").iters(5).run(|| {
            let mut x = 0u64;
            for i in 0..10_000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert!(r.mean_s > 0.0);
        assert!(r.min_s <= r.mean_s);
        assert_eq!(r.iters, 5);
    }

    #[test]
    fn trial_stats_are_robust_to_one_outlier() {
        // 9 quiet samples + 1 wild outlier: median and MAD ignore it.
        let mut samples = vec![1.0; 9];
        samples.push(100.0);
        let t = TrialStats::of(&samples);
        assert_eq!(t.trials, 10);
        assert_eq!(t.median_s, 1.0);
        assert_eq!(t.mad_s, 0.0);
        assert_eq!(t.min_s, 1.0);
        assert!(t.p99_s > 1.0, "p99 does see the outlier");
        assert_eq!(t.rel_mad(), 0.0);
    }

    #[test]
    fn run_trials_measures_something_positive() {
        let t = Bencher::new("spin").iters(4).run_trials(|| {
            let mut x = 0u64;
            for i in 0..10_000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert_eq!(t.trials, 4);
        assert!(t.median_s > 0.0);
        assert!(t.min_s <= t.median_s && t.median_s <= t.p99_s);
    }

    #[test]
    fn render_includes_throughput() {
        let r = Bencher::new("x").iters(2).run_with_ops(1e9, || 1 + 1);
        assert!(r.render().contains("GOps/s"));
    }
}
