//! Deterministic pseudo-random numbers: SplitMix64 core with uniform,
//! range, Bernoulli and Box-Muller normal draws.  Every stochastic
//! component of the simulators seeds one of these, so all experiments
//! are reproducible bit-for-bit given a seed.

/// SplitMix64 generator (Steele et al., 2014) — tiny, fast, and
/// statistically solid for simulation jitter.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    /// cached second Box-Muller variate
    spare_normal: Option<f64>,
}

impl Rng {
    pub fn seed_from_u64(seed: u64) -> Self {
        Rng {
            state: seed,
            spare_normal: None,
        }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform f64 in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        // 53 high bits → [0,1)
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform f64 in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        assert!(hi > lo, "empty range");
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform f32 in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        self.range_f64(lo as f64, hi as f64) as f32
    }

    /// Uniform usize in [lo, hi).
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo, "empty range");
        lo + (self.next_u64() % (hi - lo) as u64) as usize
    }

    /// Bernoulli draw.
    pub fn gen_bool(&mut self, p: f64) -> bool {
        assert!((0.0..=1.0).contains(&p), "probability out of range");
        self.next_f64() < p
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with mean/σ.
    pub fn normal_with(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Standard normal as f32 (latent vectors).
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval_with_sane_mean() {
        let mut rng = Rng::seed_from_u64(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = rng.next_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::seed_from_u64(11);
        let n = 50_000;
        let mut sum = 0.0;
        let mut sumsq = 0.0;
        for _ in 0..n {
            let z = rng.normal();
            sum += z;
            sumsq += z * z;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn range_usize_covers_bounds() {
        let mut rng = Rng::seed_from_u64(13);
        let mut seen = [false; 5];
        for _ in 0..200 {
            seen[rng.range_usize(0, 5)] = true;
        }
        assert!(seen.iter().all(|s| *s));
    }

    #[test]
    fn gen_bool_frequency() {
        let mut rng = Rng::seed_from_u64(17);
        let hits = (0..10_000).filter(|_| rng.gen_bool(0.25)).count();
        assert!((hits as f64 / 10_000.0 - 0.25).abs() < 0.02);
    }
}
