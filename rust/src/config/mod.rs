//! Static configuration: the paper's two DCNN generator architectures
//! (Fig. 4), the two hardware platforms (PYNQ-Z2 FPGA, Jetson TX1 GPU),
//! the datapath precision axis ([`Precision`], defined in
//! [`crate::quant`] and re-exported here as part of the config surface),
//! and the shared CLI config structs ([`PoolCfg`] / [`TrafficCfg`]) the
//! serve/loadtest/fleet subcommands all parse their flags into.

mod backend;
mod cli;
mod hw;
mod network;

pub use crate::quant::{Precision, QFormat};
pub use backend::{BackendCfg, DeviceKind};
pub use cli::{ObsCfg, PoolCfg, TrafficCfg};
pub use hw::{FpgaBoard, GpuBoard, PYNQ_Z2, JETSON_TX1};
pub use network::{celeba, mnist, network_by_name, DeconvLayerCfg, NetworkCfg};
