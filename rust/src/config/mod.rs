//! Static configuration: the paper's two DCNN generator architectures
//! (Fig. 4) and the two hardware platforms (PYNQ-Z2 FPGA, Jetson TX1 GPU).

mod hw;
mod network;

pub use hw::{FpgaBoard, GpuBoard, PYNQ_Z2, JETSON_TX1};
pub use network::{celeba, mnist, network_by_name, DeconvLayerCfg, NetworkCfg};
