//! Shared CLI configuration — the `serve`, `loadtest` and `fleet`
//! subcommands parse the *same* flags into the *same* structs with
//! identical semantics, instead of each subcommand keeping its own
//! copy of the `--backends` / `--queue-depth` / `--scenario` /
//! `--deadline-ms` handling in `main.rs` (where the duplicates had
//! already started to drift: `serve` had no `--max-deferred`, and only
//! `loadtest` validated `--deadline-ms`).
//!
//! The structs are plain data: [`TrafficCfg`] names a scenario but does
//! not resolve it — materialization lives in
//! [`crate::workload`](crate::workload) (`resolve_trace`), keeping the
//! config layer free of workload dependencies.

use super::backend::BackendCfg;
use crate::util::Flags;
use anyhow::Result;
use std::path::PathBuf;

/// Backend-pool flags shared by every serving subcommand:
/// `--backends fpga,gpu,cpu`, `--queue-depth D`, `--max-deferred N`,
/// `--executors E`.
#[derive(Debug, Clone, Default)]
pub struct PoolCfg {
    pub backends: BackendCfg,
    /// Lane-count override, as in
    /// [`crate::coordinator::CoordinatorConfig::executors`]
    /// (`0` = one lane per `backends.kinds` entry).
    pub executors: usize,
}

impl PoolCfg {
    pub fn from_flags(flags: &Flags) -> Result<PoolCfg> {
        let mut backends = BackendCfg::default();
        if flags.has("backends") {
            backends.kinds =
                BackendCfg::parse_kinds(&flags.get_str("backends", ""))?;
        }
        backends.max_queue_depth =
            flags.get("queue-depth", backends.max_queue_depth)?;
        backends.admit_max_deferred =
            flags.get("max-deferred", backends.admit_max_deferred)?;
        anyhow::ensure!(
            backends.max_queue_depth >= 1,
            "--queue-depth must be >= 1"
        );
        Ok(PoolCfg {
            backends,
            executors: flags.get("executors", 0usize)?,
        })
    }
}

/// Traffic flags shared by `loadtest` and `fleet`: `--scenario
/// NAME|FILE`, `--requests N`, `--seed S`, `--deadline-ms D`,
/// `--replay FILE`, `--record FILE`.  `None` fields mean "keep the
/// scenario's own value".
#[derive(Debug, Clone)]
pub struct TrafficCfg {
    /// Built-in scenario name (`steady|burst|diurnal|flash`) or a JSON
    /// scenario file path.
    pub scenario: String,
    pub requests: Option<usize>,
    pub seed: Option<u64>,
    /// Relative-deadline override, seconds.
    pub deadline_s: Option<f64>,
    /// Replay a recorded trace instead of generating one (wins over
    /// `scenario`).
    pub replay: Option<PathBuf>,
    /// Record the materialized trace to this path.
    pub record: Option<PathBuf>,
}

impl Default for TrafficCfg {
    fn default() -> Self {
        TrafficCfg {
            scenario: "steady".to_string(),
            requests: None,
            seed: None,
            deadline_s: None,
            replay: None,
            record: None,
        }
    }
}

impl TrafficCfg {
    pub fn from_flags(flags: &Flags) -> Result<TrafficCfg> {
        let deadline_s = match flags.get_opt::<f64>("deadline-ms")? {
            Some(d_ms) => {
                anyhow::ensure!(d_ms > 0.0, "--deadline-ms must be positive");
                Some(d_ms / 1e3)
            }
            None => None,
        };
        Ok(TrafficCfg {
            scenario: flags.get_str("scenario", "steady"),
            requests: flags.get_opt("requests")?,
            seed: flags.get_opt("seed")?,
            deadline_s,
            replay: flags.get_opt("replay")?,
            record: flags.get_opt("record")?,
        })
    }
}

/// Observability sinks shared by the serving subcommands:
/// `--trace-out FILE` (Chrome trace-event JSON of the sampled request
/// lifecycles — load it in Perfetto / `chrome://tracing`) on `serve`,
/// `loadtest` and `fleet`; `--prom-out FILE` (Prometheus text
/// exposition of the serving report) on `serve`.
#[derive(Debug, Clone, Default)]
pub struct ObsCfg {
    pub trace_out: Option<PathBuf>,
    pub prom_out: Option<PathBuf>,
}

impl ObsCfg {
    pub fn from_flags(flags: &Flags) -> Result<ObsCfg> {
        Ok(ObsCfg {
            trace_out: flags.get_opt("trace-out")?,
            prom_out: flags.get_opt("prom-out")?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flags(s: &[&str]) -> Flags {
        let argv: Vec<String> = s.iter().map(|a| a.to_string()).collect();
        Flags::parse(&argv).unwrap()
    }

    #[test]
    fn pool_cfg_parses_shared_backend_flags() {
        let p = PoolCfg::from_flags(&flags(&[
            "--backends",
            "fpga,cpu",
            "--queue-depth",
            "2",
            "--max-deferred",
            "8",
            "--executors",
            "4",
        ]))
        .unwrap();
        assert_eq!(p.backends.kinds.len(), 2);
        assert_eq!(p.backends.max_queue_depth, 2);
        assert_eq!(p.backends.admit_max_deferred, 8);
        assert_eq!(p.executors, 4);
        // defaults mirror BackendCfg::default
        let d = PoolCfg::from_flags(&flags(&[])).unwrap();
        assert_eq!(d.backends.max_queue_depth, 4);
        assert_eq!(d.executors, 0);
        assert!(PoolCfg::from_flags(&flags(&["--queue-depth", "0"])).is_err());
        assert!(PoolCfg::from_flags(&flags(&["--backends", "tpu"])).is_err());
    }

    #[test]
    fn traffic_cfg_parses_shared_traffic_flags() {
        let t = TrafficCfg::from_flags(&flags(&[
            "--scenario",
            "flash",
            "--requests",
            "48",
            "--seed",
            "7",
            "--deadline-ms",
            "25",
        ]))
        .unwrap();
        assert_eq!(t.scenario, "flash");
        assert_eq!(t.requests, Some(48));
        assert_eq!(t.seed, Some(7));
        assert_eq!(t.deadline_s, Some(0.025));
        assert!(t.replay.is_none());
        let d = TrafficCfg::from_flags(&flags(&[])).unwrap();
        assert_eq!(d.scenario, "steady");
        assert_eq!(d.requests, None, "absent flags keep scenario values");
        assert!(
            TrafficCfg::from_flags(&flags(&["--deadline-ms", "0"])).is_err()
        );
    }

    #[test]
    fn obs_cfg_parses_sink_paths() {
        let o = ObsCfg::from_flags(&flags(&[
            "--trace-out",
            "trace.json",
            "--prom-out",
            "metrics.prom",
        ]))
        .unwrap();
        assert_eq!(o.trace_out, Some(PathBuf::from("trace.json")));
        assert_eq!(o.prom_out, Some(PathBuf::from("metrics.prom")));
        let d = ObsCfg::from_flags(&flags(&[])).unwrap();
        assert!(d.trace_out.is_none() && d.prom_out.is_none());
    }
}
