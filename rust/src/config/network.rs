//! DCNN generator architectures (paper Fig. 4) and their op accounting.
//!
//! The layer geometry and the MAC/op counters here are the single source
//! of truth on the Rust side; they mirror `python/compile/model.py`
//! exactly (asserted by the integration tests against the artifact
//! manifest).


/// One transposed-convolution layer (square kernel/stride/padding, as in
/// the paper).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DeconvLayerCfg {
    pub c_in: usize,
    pub c_out: usize,
    pub k: usize,
    pub stride: usize,
    pub padding: usize,
    /// Input spatial extent (square).
    pub i_h: usize,
}

impl DeconvLayerCfg {
    /// Output extent: `O = (I-1)·S + K - 2P` (Eq. 1 solved for max o).
    pub fn o_h(&self) -> usize {
        (self.i_h - 1) * self.stride + self.k - 2 * self.padding
    }

    /// Eq. 3 stride-hole offsets `f[k] = mod(S - mod(P - k, S), S)`.
    pub fn offsets(&self) -> Vec<usize> {
        crate::deconv::stride_hole_offsets(self.k, self.stride, self.padding)
    }

    /// Exact Algorithm-1 trip count per (c_in, c_out) pair:
    /// `Σ_{k_h,k_w} |{o_h ≡ f(k_h)}| · |{o_w ≡ f(k_w)}|`.
    pub fn taps(&self) -> usize {
        let o = self.o_h();
        let f = self.offsets();
        let rows: usize = f
            .iter()
            .map(|&fk| if fk < o { (o - fk).div_ceil(self.stride) } else { 0 })
            .sum();
        rows * rows
    }

    /// Dense MACs of the reverse-loop schedule.
    pub fn macs(&self) -> u64 {
        self.c_in as u64 * self.c_out as u64 * self.taps() as u64
    }

    /// Arithmetic operations (1 MAC = 2 ops) — the paper's GOps numerator.
    pub fn ops(&self) -> u64 {
        2 * self.macs()
    }

    /// Input feature-map bytes (f32).
    pub fn input_bytes(&self) -> u64 {
        4 * self.c_in as u64 * (self.i_h * self.i_h) as u64
    }

    /// Output feature-map bytes (f32).
    pub fn output_bytes(&self) -> u64 {
        4 * self.c_out as u64 * (self.o_h() * self.o_h()) as u64
    }

    /// Weight + bias bytes (f32).
    pub fn weight_bytes(&self) -> u64 {
        4 * (self.c_in * self.c_out * self.k * self.k + self.c_out) as u64
    }
}

/// A DCNN generator: latent dim + deconvolution stack + the unified output
/// tiling factor `T_OH` the paper selects per network (Table I) + the
/// datapath precision the network is served at (`f32` for the historical
/// path; a Qm.n format for the quantized edge path).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NetworkCfg {
    pub name: String,
    pub z_dim: usize,
    pub layers: Vec<DeconvLayerCfg>,
    pub image_channels: usize,
    pub image_size: usize,
    pub tile: usize,
    pub precision: crate::quant::Precision,
}

impl NetworkCfg {
    pub fn total_ops(&self) -> u64 {
        self.layers.iter().map(|l| l.ops()).sum()
    }

    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs()).sum()
    }

    /// Total learned parameters (weights + biases).
    pub fn total_params(&self) -> usize {
        self.layers
            .iter()
            .map(|l| l.c_in * l.c_out * l.k * l.k + l.c_out)
            .sum()
    }
}

/// MNIST generator: `100×1×1 → 128×7×7 → 64×14×14 → 1×28×28` (3 layers).
pub fn mnist() -> NetworkCfg {
    NetworkCfg {
        name: "mnist".into(),
        z_dim: 100,
        layers: vec![
            DeconvLayerCfg { c_in: 100, c_out: 128, k: 7, stride: 1, padding: 0, i_h: 1 },
            DeconvLayerCfg { c_in: 128, c_out: 64, k: 4, stride: 2, padding: 1, i_h: 7 },
            DeconvLayerCfg { c_in: 64, c_out: 1, k: 4, stride: 2, padding: 1, i_h: 14 },
        ],
        image_channels: 1,
        image_size: 28,
        tile: 12,
        precision: crate::quant::Precision::F32,
    }
}

/// CelebA generator: `100×1×1 → 512×4×4 → 256×8×8 → 128×16×16 → 64×32×32
/// → 3×64×64` (5 layers).
pub fn celeba() -> NetworkCfg {
    NetworkCfg {
        name: "celeba".into(),
        z_dim: 100,
        layers: vec![
            DeconvLayerCfg { c_in: 100, c_out: 512, k: 4, stride: 1, padding: 0, i_h: 1 },
            DeconvLayerCfg { c_in: 512, c_out: 256, k: 4, stride: 2, padding: 1, i_h: 4 },
            DeconvLayerCfg { c_in: 256, c_out: 128, k: 4, stride: 2, padding: 1, i_h: 8 },
            DeconvLayerCfg { c_in: 128, c_out: 64, k: 4, stride: 2, padding: 1, i_h: 16 },
            DeconvLayerCfg { c_in: 64, c_out: 3, k: 4, stride: 2, padding: 1, i_h: 32 },
        ],
        image_channels: 3,
        image_size: 64,
        tile: 24,
        precision: crate::quant::Precision::F32,
    }
}

/// Look up one of the two benchmark networks by name.
pub fn network_by_name(name: &str) -> anyhow::Result<NetworkCfg> {
    match name {
        "mnist" => Ok(mnist()),
        "celeba" => Ok(celeba()),
        other => anyhow::bail!("unknown network {other:?} (mnist|celeba)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mnist_geometry_chains() {
        let net = mnist();
        let o: Vec<usize> = net.layers.iter().map(|l| l.o_h()).collect();
        assert_eq!(o, vec![7, 14, 28]);
        for (a, b) in net.layers.iter().zip(net.layers.iter().skip(1)) {
            assert_eq!(a.o_h(), b.i_h);
            assert_eq!(a.c_out, b.c_in);
        }
        assert_eq!(net.layers[0].c_in, net.z_dim);
    }

    #[test]
    fn celeba_geometry_chains() {
        let net = celeba();
        let o: Vec<usize> = net.layers.iter().map(|l| l.o_h()).collect();
        assert_eq!(o, vec![4, 8, 16, 32, 64]);
        assert_eq!(net.layers.last().unwrap().c_out, 3);
    }

    #[test]
    fn taps_bruteforce_small() {
        let l = DeconvLayerCfg { c_in: 2, c_out: 3, k: 4, stride: 2, padding: 1, i_h: 5 };
        // brute force over output space
        let o = l.o_h();
        let f = l.offsets();
        let mut count = 0usize;
        for kh in 0..l.k {
            for kw in 0..l.k {
                let nh = (f[kh]..o).step_by(l.stride).count();
                let nw = (f[kw]..o).step_by(l.stride).count();
                count += nh * nw;
            }
        }
        assert_eq!(l.taps(), count);
        assert_eq!(l.macs(), (2 * 3 * count) as u64);
    }

    #[test]
    fn ops_are_twice_macs() {
        for net in [mnist(), celeba()] {
            for l in &net.layers {
                assert_eq!(l.ops(), 2 * l.macs());
            }
            assert_eq!(net.total_ops(), 2 * net.total_macs());
        }
    }

    #[test]
    fn unknown_network_errors() {
        assert!(network_by_name("imagenet").is_err());
    }
}
