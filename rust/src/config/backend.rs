//! Device-backend pool configuration — which devices the coordinator
//! schedules across ([`DeviceKind`]) and the scheduler's queue bounds
//! ([`BackendCfg`]).  The backend implementations themselves live in
//! [`crate::backend`]; this module is only the config surface the CLI
//! (`edgedcnn serve --backends fpga,gpu,cpu`) and
//! [`crate::coordinator::CoordinatorConfig`] speak.

use std::fmt;
use std::str::FromStr;

/// The device classes the executor pool can schedule onto.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DeviceKind {
    /// The simulated PYNQ-Z2 accelerator datapath
    /// ([`crate::backend::FpgaSimBackend`]).
    Fpga,
    /// The Jetson TX1 analytical model with owned thermal state
    /// ([`crate::backend::GpuModelBackend`]).
    Gpu,
    /// The host numeric path — PJRT or the pure-Rust reverse-loop
    /// substrate ([`crate::backend::CpuBackend`]).
    Cpu,
}

impl DeviceKind {
    pub fn as_str(self) -> &'static str {
        match self {
            DeviceKind::Fpga => "fpga",
            DeviceKind::Gpu => "gpu",
            DeviceKind::Cpu => "cpu",
        }
    }
}

impl fmt::Display for DeviceKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

impl FromStr for DeviceKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.trim() {
            "fpga" => Ok(DeviceKind::Fpga),
            "gpu" => Ok(DeviceKind::Gpu),
            "cpu" => Ok(DeviceKind::Cpu),
            other => anyhow::bail!(
                "unknown backend {other:?} (fpga|gpu|cpu)"
            ),
        }
    }
}

/// The heterogeneous executor pool: one FIFO lane (thread) per entry in
/// `kinds`, plus the scheduler's backpressure bounds.
#[derive(Debug, Clone)]
pub struct BackendCfg {
    /// One executor lane per entry; duplicates are allowed (e.g.
    /// `[Cpu, Cpu]` = two CPU lanes).  Order is the lane index order.
    pub kinds: Vec<DeviceKind>,
    /// Backpressure bound: a lane whose queue holds this many
    /// not-yet-executed batches stops accepting new ones; when every
    /// capable lane is at the bound the batch is deferred.
    pub max_queue_depth: usize,
    /// Admission-control bound: when this many deferred batches are
    /// already waiting for a lane, new requests are rejected outright
    /// (their callers observe an error instead of unbounded queueing).
    pub admit_max_deferred: usize,
    /// Seed for the backends' measurement-noise streams (FPGA clock/DDR
    /// jitter, GPU nvprof-style noise).  Deterministic per run; the
    /// loadtest varies it per trial so repeated trials are independent
    /// measurements rather than replays.
    pub noise_seed: u64,
}

impl Default for BackendCfg {
    fn default() -> Self {
        BackendCfg {
            kinds: vec![DeviceKind::Fpga, DeviceKind::Gpu, DeviceKind::Cpu],
            max_queue_depth: 4,
            admit_max_deferred: 256,
            noise_seed: 0,
        }
    }
}

impl BackendCfg {
    /// Parse the CLI's `--backends fpga,gpu,cpu` list.
    pub fn parse_kinds(list: &str) -> anyhow::Result<Vec<DeviceKind>> {
        let kinds: Vec<DeviceKind> = list
            .split(',')
            .filter(|s| !s.trim().is_empty())
            .map(str::parse)
            .collect::<anyhow::Result<_>>()?;
        anyhow::ensure!(!kinds.is_empty(), "--backends list is empty");
        Ok(kinds)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_kinds_roundtrips() {
        let kinds = BackendCfg::parse_kinds("fpga,gpu,cpu").unwrap();
        assert_eq!(
            kinds,
            vec![DeviceKind::Fpga, DeviceKind::Gpu, DeviceKind::Cpu]
        );
        assert_eq!(
            BackendCfg::parse_kinds("cpu, cpu").unwrap(),
            vec![DeviceKind::Cpu, DeviceKind::Cpu],
            "duplicates and whitespace are fine"
        );
        assert!(BackendCfg::parse_kinds("tpu").is_err());
        assert!(BackendCfg::parse_kinds("").is_err());
    }

    #[test]
    fn kind_display_matches_parse() {
        for k in [DeviceKind::Fpga, DeviceKind::Gpu, DeviceKind::Cpu] {
            assert_eq!(k.as_str().parse::<DeviceKind>().unwrap(), k);
        }
    }
}
