//! Hardware platform models: the Xilinx PYNQ-Z2 (Zynq-7020) the paper
//! implements on, and the NVIDIA Jetson TX1 it benchmarks against.
//!
//! Every constant is documented with its source. The two `*_BOARD`
//! statics are calibration anchors: the simulators consume them through
//! the live models (cycle counting, roofline legality, DVFS), never as
//! answer lookup tables.


/// FPGA board description (Zynq-7020 / PYNQ-Z2 class).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FpgaBoard {
    /// Programmable-logic clock the paper synthesizes at (Hz). [§V: 125 MHz]
    pub clock_hz: f64,
    /// Replicated compute units the paper fits on the board. [§V: 16 CUs]
    pub n_cu: usize,
    /// DSP48 slices available on the device. [Zynq-7020: 220]
    pub dsp_total: usize,
    /// BRAM (18 Kbit blocks) available. [Zynq-7020: 280 × 18Kb = 140 × 36Kb]
    pub bram18_total: usize,
    /// Flip-flops available. [Zynq-7020: 106,400]
    pub ff_total: usize,
    /// LUTs available. [Zynq-7020: 53,200]
    pub lut_total: usize,
    /// Peak *sustainable* DDR bandwidth in bytes/s, as measured by the
    /// STREAM benchmark on the PS DDR3 (the Fig. 5 bandwidth slope).
    /// [STREAM copy on Zynq-7020 PS DDR3-1050 ≈ 1.0-1.2 GB/s]
    pub stream_bw_bytes: f64,
    /// MACs each CU can issue per cycle (DSP lanes per CU; 8×16 = 128
    /// lanes ≈ 134 DSP48s in Table I including address generation).
    pub macs_per_cu_cycle: usize,
    /// Board power floor in watts (PS + idle PL). [PYNQ-Z2 idle ≈ 1.8 W
    /// measured by USB power meters in comparable studies]
    pub static_power_w: f64,
    /// Dynamic power at full CU activity, watts. [≈ 0.7 W for this
    /// design's 134 DSPs + BRAM/AXI traffic → ~2.5 W total]
    pub dynamic_power_w: f64,
}

/// The PYNQ-Z2 board as the paper uses it.
pub const PYNQ_Z2: FpgaBoard = FpgaBoard {
    clock_hz: 125e6,
    n_cu: 16,
    dsp_total: 220,
    bram18_total: 280,
    ff_total: 106_400,
    lut_total: 53_200,
    stream_bw_bytes: 1.05e9,
    macs_per_cu_cycle: 8,
    static_power_w: 1.8,
    dynamic_power_w: 0.7,
};

impl FpgaBoard {
    /// Peak MAC throughput (MACs/s) with all CUs busy.
    pub fn peak_macs_per_s(&self) -> f64 {
        self.clock_hz * (self.n_cu * self.macs_per_cu_cycle) as f64
    }

    /// Peak arithmetic throughput in GOps/s (1 MAC = 2 ops).
    pub fn peak_gops(&self) -> f64 {
        2.0 * self.peak_macs_per_s() / 1e9
    }

    /// Full-activity power draw (W).
    pub fn max_power_w(&self) -> f64 {
        self.static_power_w + self.dynamic_power_w
    }
}

/// Edge GPU description (Jetson TX1 class).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GpuBoard {
    /// CUDA cores. [TX1: 256 Maxwell cores]
    pub cuda_cores: usize,
    /// Nominal (boost) core clock, Hz. [TX1: 998 MHz]
    pub boost_clock_hz: f64,
    /// Clock floor under full thermal throttle, Hz. [TX1 throttles to
    /// ≈ 614 MHz under sustained load per the Jetson Linux docs]
    pub throttle_clock_hz: f64,
    /// FMA throughput: 2 flops/core/cycle fp32.
    pub flops_per_core_cycle: f64,
    /// LPDDR4 bandwidth, bytes/s. [TX1: 25.6 GB/s]
    pub mem_bw_bytes: f64,
    /// Fixed per-kernel-launch overhead, seconds. [cudaLaunch + Torch
    /// dispatch ≈ 20 µs on TX1-class SoCs]
    pub launch_overhead_s: f64,
    /// Idle board power, W. [TX1 module idle ≈ 2.5 W]
    pub idle_power_w: f64,
    /// Full-load board power, W. [TX1 sustained GPU load ≈ 10-12 W]
    pub load_power_w: f64,
}

/// The Jetson TX1 as the paper benchmarks it (Torch + nvprof).
pub const JETSON_TX1: GpuBoard = GpuBoard {
    cuda_cores: 256,
    boost_clock_hz: 998e6,
    throttle_clock_hz: 614e6,
    flops_per_core_cycle: 2.0,
    mem_bw_bytes: 25.6e9,
    launch_overhead_s: 20e-6,
    idle_power_w: 2.5,
    load_power_w: 11.0,
};

impl GpuBoard {
    /// Peak fp32 throughput at a given clock (GOps/s = GFLOP/s here).
    pub fn peak_gops_at(&self, clock_hz: f64) -> f64 {
        self.cuda_cores as f64 * self.flops_per_core_cycle * clock_hz / 1e9
    }

    /// Peak fp32 throughput at boost clock.
    pub fn peak_gops(&self) -> f64 {
        self.peak_gops_at(self.boost_clock_hz)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pynq_peak_numbers() {
        // 16 CUs × 8 MACs × 125 MHz = 16 GMAC/s = 32 GOps/s
        assert!((PYNQ_Z2.peak_macs_per_s() - 16e9).abs() < 1.0);
        assert!((PYNQ_Z2.peak_gops() - 32.0).abs() < 1e-9);
        assert!(PYNQ_Z2.max_power_w() < 3.0, "edge budget");
    }

    #[test]
    fn tx1_peak_numbers() {
        // 256 cores × 2 flops × 998 MHz ≈ 511 GFLOP/s fp32
        let peak = JETSON_TX1.peak_gops();
        assert!(peak > 500.0 && peak < 520.0, "peak={peak}");
        assert!(JETSON_TX1.throttle_clock_hz < JETSON_TX1.boost_clock_hz);
    }

    #[test]
    fn dsp_budget_accommodates_paper_design() {
        // Table I uses 134 DSPs; the device must fit it.
        assert!(134 <= PYNQ_Z2.dsp_total);
    }
}
