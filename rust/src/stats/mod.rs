//! Small statistics toolkit shared by the simulators and experiments:
//! streaming mean/σ (Welford), medians/percentiles, and run summaries —
//! the machinery behind every "mean (std) over 50 runs" cell of Table II.

mod welford;

pub use welford::Welford;

/// Summary of repeated measurements, printed as `mean (std)` like the
/// paper's Table II cells.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub mean: f64,
    pub std: f64,
    pub n: usize,
}

impl Summary {
    /// Summarize a slice of measurements (sample standard deviation).
    pub fn of(values: &[f64]) -> Self {
        let mut w = Welford::new();
        for &v in values {
            w.push(v);
        }
        Summary {
            mean: w.mean(),
            std: w.sample_std(),
            n: w.count(),
        }
    }

    /// The paper's table cell format, e.g. `2.9 (0.01)`.
    pub fn cell(&self) -> String {
        format!("{:.1} ({:.2})", self.mean, self.std)
    }
}

/// Median of a slice (interpolated for even lengths). Used for the MMD
/// median-bandwidth heuristic (Gretton et al., 2012) and robust timing.
pub fn median(values: &[f64]) -> f64 {
    assert!(!values.is_empty(), "median of empty slice");
    let mut v: Vec<f64> = values.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).expect("NaN in median input"));
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Linear-interpolated percentile in `[0, 100]`; used for serving latency
/// p50/p95/p99 reporting.
pub fn percentile(values: &[f64], p: f64) -> f64 {
    assert!(!values.is_empty(), "percentile of empty slice");
    assert!((0.0..=100.0).contains(&p), "percentile out of range");
    let mut v: Vec<f64> = values.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).expect("NaN in percentile input"));
    if v.len() == 1 {
        return v[0];
    }
    let rank = p / 100.0 * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    v[lo] + (v[hi] - v[lo]) * frac
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_matches_hand_computation() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert!((s.mean - 2.5).abs() < 1e-12);
        // sample std of 1..4 = sqrt(5/3)
        assert!((s.std - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
        assert_eq!(s.n, 4);
    }

    #[test]
    fn summary_cell_format() {
        let s = Summary::of(&[2.9, 2.9, 2.9]);
        assert_eq!(s.cell(), "2.9 (0.00)");
    }

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 3.0, 2.0]), 2.5);
    }

    #[test]
    fn percentile_endpoints_and_middle() {
        let v = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&v, 0.0), 10.0);
        assert_eq!(percentile(&v, 100.0), 40.0);
        assert_eq!(percentile(&v, 50.0), 25.0);
    }

    #[test]
    #[should_panic]
    fn median_empty_panics() {
        median(&[]);
    }
}
