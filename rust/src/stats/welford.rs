//! Welford's online algorithm for numerically stable streaming mean and
//! variance — the accumulator behind the 50-run Table II cells and the
//! power-meter integrator.

/// Streaming mean/variance accumulator.
#[derive(Debug, Clone, Copy, Default)]
pub struct Welford {
    n: usize,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add one observation.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        let delta2 = x - self.mean;
        self.m2 += delta * delta2;
    }

    pub fn count(&self) -> usize {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance (n denominator); 0 for n < 1.
    pub fn variance(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Sample variance (n-1 denominator); 0 for n < 2.
    pub fn sample_variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn sample_std(&self) -> f64 {
        self.sample_variance().sqrt()
    }

    /// Merge another accumulator (parallel Welford / Chan et al.).
    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = *other;
            return;
        }
        let n = self.n + other.n;
        let delta = other.mean - self.mean;
        let mean =
            self.mean + delta * other.n as f64 / n as f64;
        let m2 = self.m2
            + other.m2
            + delta * delta * self.n as f64 * other.n as f64 / n as f64;
        *self = Welford { n, mean, m2 };
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_naive_two_pass() {
        let xs = [1.5, 2.5, -0.5, 7.25, 3.0, 3.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let mean: f64 = xs.iter().sum::<f64>() / xs.len() as f64;
        let var: f64 = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / (xs.len() - 1) as f64;
        assert!((w.mean() - mean).abs() < 1e-12);
        assert!((w.sample_variance() - var).abs() < 1e-12);
    }

    #[test]
    fn merge_equals_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut a = Welford::new();
        let mut b = Welford::new();
        for &x in &xs[..37] {
            a.push(x);
        }
        for &x in &xs[37..] {
            b.push(x);
        }
        let mut whole = Welford::new();
        for &x in &xs {
            whole.push(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert!((a.mean() - whole.mean()).abs() < 1e-10);
        assert!((a.sample_variance() - whole.sample_variance()).abs() < 1e-9);
    }

    #[test]
    fn degenerate_cases() {
        let w = Welford::new();
        assert_eq!(w.count(), 0);
        assert_eq!(w.variance(), 0.0);
        let mut w1 = Welford::new();
        w1.push(5.0);
        assert_eq!(w1.mean(), 5.0);
        assert_eq!(w1.sample_variance(), 0.0);
    }
}
