//! `edgedcnn` — CLI for the reproduction: regenerate every paper table
//! and figure, run the edge-serving coordinator, and inspect the
//! networks/ablations.  Run `edgedcnn help` for usage.
//!
//! (Arg parsing is hand-rolled: the offline build environment mirrors
//! only the `xla` dependency closure — no clap.)

use anyhow::{bail, Result};
use edgedcnn::artifacts::ArtifactDir;
use edgedcnn::config::{
    network_by_name, ObsCfg, PoolCfg, Precision, TrafficCfg, JETSON_TX1,
    PYNQ_Z2,
};
use edgedcnn::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, WorkloadSpec,
};
use edgedcnn::experiments as exp;
use edgedcnn::fleet::{run_fleet, FleetCfg};
use edgedcnn::quant::{QFormat, QuantizedGenerator, Rounding};
use edgedcnn::runtime::Runtime;
use edgedcnn::util::Flags;
use edgedcnn::workload::{resolve_trace, run_loadtest, LoadtestOpts, Trace};
use std::time::Duration;

const USAGE: &str = "\
edgedcnn — FPGA-vs-GPU DCNN inference study (Colbert et al. 2021)
           three-layer Rust + JAX + Pallas reproduction

USAGE: edgedcnn [--artifacts DIR] <command> [options]

COMMANDS:
  table1                     Table I  — resource utilization at T_OH*
  table2    [--runs N] [--seed S]
                             Table II — GOps/s/W mean(σ), FPGA vs GPU
  dse                        Fig. 5   — design-space exploration
  sparsity  [--network NET] [--samples N] [--seed S] [--pjrt]
                             Fig. 6   — pruning: speed-up / MMD / Eq. 6
  ablations [--sparsity F]   Section III enhancements on vs off
  networks                   Fig. 4 architectures and op counts
  serve     [--network NET] [--requests N] [--images K]
            [--interarrival-ms MS] [--seed S] [--executors E]
            [--backends fpga,gpu,cpu] [--queue-depth D] [--max-deferred N]
            [--quant qI.F] [--shard] [--json]
            [--trace-out FILE] [--prom-out FILE]
                             drive the edge-serving coordinator over a
                             heterogeneous device-backend pool (one FIFO
                             lane per --backends entry; batches route to
                             the cheapest idle capable device and the
                             report shows per-backend columns); --quant
                             additionally serves fixed-point twins as
                             NET.q (e.g. --quant q8.8 --network mnist.q)
                             and --network NET.q8 serves the packed int8
                             twin (per-channel q2.6 scales, x4 MAC lanes
                             per DSP on the FPGA model) — both route
                             around the f32-only GPU,
                             --shard splits batches across the capable
                             lanes (intra-batch parallelism),
                             --queue-depth bounds each lane's queue
                             (backpressure), --executors E cycles the
                             backends list to E lanes, --json prints the
                             versioned report schema instead of the table;
                             --trace-out writes the sampled request
                             lifecycles as Chrome trace-event JSON
                             (Perfetto-loadable), --prom-out writes the
                             report as Prometheus text exposition
  bench     [--smoke] [--trials N] [--json] [--out FILE]
            [--compare FILE] [--no-serving]
                             regression-defended microbenchmark suite
                             over the numeric hot path: every deconv
                             kernel (standard / reverse-loop / tdc plus
                             the frozen scalar reverse-loop reference)
                             at f32, q8.8 and q16.16, with robust
                             median+MAD trial statistics, img/s and
                             ns/MAC columns, and per-backend serving
                             throughput rows.  --out writes the schema
                             v2 BENCH_edgedcnn.json; --compare checks
                             this run against a committed baseline and
                             exits nonzero on regression (speedup gates
                             always, absolute medians when the baseline
                             is not provisional); --no-serving skips
                             the coordinator rows.  The blocked-* rows
                             time the cache-blocked dispatch (tune
                             table when present, static default
                             otherwise) and gate within-run against
                             the plain reverse loop
  tune      [--smoke] [--trials N] [--out FILE] [--json]
                             bench-driven autotuner: sweep the legal
                             (micro, macro, lanes) block schedules for
                             every deconv kernel x precision cell of
                             the bench geometry (pruned grid under
                             --smoke), verify each candidate bit-
                             identical, and persist the winners to
                             TUNE_edgedcnn.json (--out overrides; the
                             EDGEDCNN_TUNE env var points dispatch at
                             a table elsewhere)
  loadtest  [--scenario NAME|FILE] [--trials N] [--requests N] [--seed S]
            [--backends fpga,gpu,cpu] [--queue-depth D] [--executors E]
            [--record FILE] [--replay FILE] [--no-shard] [--smoke]
            [--closed N] [--think-ms T] [--deadline-ms D]
            [--drift-csv FILE] [--trace-out FILE]
                             scenario-driven load generation against the
                             backend pool, repeated over N seeded
                             trials, with the paper's Table-2-style run-
                             to-run-variation verdict: per-backend
                             p50/p95/p99/p99.9 (coordinated-omission
                             corrected), SLO + deadline attainment with
                             the shed / served-late split, and device-
                             latency CV columns.  Every request carries
                             a deadline + priority class from the
                             scenario; infeasible ones are shed at
                             intake (EDF scheduling, see DESIGN.md
                             §Deadline scheduling).  --scenario is a
                             built-in (steady|burst|diurnal|flash) or a
                             JSON scenario file; --record writes the
                             materialized trace (a shareable artifact),
                             --replay drives a recorded trace instead of
                             generating one; --no-shard keeps per-network
                             ordering (batches stop spreading over the
                             pool); --closed N drives N closed-loop
                             clients with --think-ms of think time
                             instead of the open-loop schedule;
                             --deadline-ms overrides the scenario's
                             relative deadline; --drift-csv writes the
                             final trial's windowed latency-drift
                             histogram shards as CSV (plot with
                             python/plot_drift.py); --trace-out writes
                             the final trial's sampled request
                             lifecycles as Chrome trace-event JSON
                             (Perfetto-loadable, one track per lane,
                             one slice per stage); --smoke is the
                             short CI mode
  fleet     [--sites N] [--scenario NAME|FILE] [--requests N] [--seed S]
            [--backends fpga,gpu,cpu] [--queue-depth D] [--max-deferred N]
            [--executors E] [--placement hash|round-robin] [--vnodes V]
            [--no-spill] [--skew-ms MS] [--fail-site I] [--fail-at-ms MS]
            [--fleet-seed S] [--replay FILE] [--record FILE]
            [--deadline-ms D] [--no-shard] [--smoke] [--json]
            [--trace-out FILE]
                             distributed edge fleet: replay one trace
                             across N per-site coordinators (each with
                             its own backend pool and seeded clock skew
                             of up to ±--skew-ms) behind a front tier
                             that places requests by consistent hashing
                             (--placement round-robin is the unstable
                             control) and spills admission-control
                             denials to the next site in preference
                             order, keeping the original arrival stamp
                             and deadline; per-site telemetry shards
                             merge into one fleet-level report with
                             s0/, s1/, … lane columns.  --fail-site I
                             fail-stops site I at --fail-at-ms (trace
                             time): it drains, goes dark, its hash
                             range re-places, and its shard still
                             merges.  Traffic flags (--scenario /
                             --requests / --seed / --deadline-ms /
                             --replay / --record) and pool flags
                             (--backends / --queue-depth /
                             --max-deferred / --executors) mean exactly
                             what they do for loadtest; --json prints
                             the fleet envelope with the embedded
                             versioned report schema; --trace-out
                             writes the fleet's sampled request
                             lifecycles as Chrome trace-event JSON —
                             one Perfetto process per site (clock-skew
                             corrected) with flow arrows following each
                             spilled request across sites
  quant     [--network NET] [--samples N] [--seed S]
            [--bits B --frac F] [--export]
                             fixed-point quantized inference: sweep
                             fraction bits vs output error (PSNR / MMD)
                             and FPGA latency at the quantized datapath;
                             --bits/--frac pin one Qm.n format, --export
                             writes calibrated quantized weights next to
                             the artifact set
  synth     [--samples N] [--seed S]
                             write a synthetic (untrained) artifact set
                             to the --artifacts dir, enough to serve
                             without the Python build layer
  all       [--runs N]       every table/figure in sequence
  help                       this text
";

/// Record the materialized trace when `--record` asked for it.
fn maybe_record(trace: &Trace, traffic: &TrafficCfg) -> Result<()> {
    if let Some(path) = &traffic.record {
        trace.save(path)?;
        println!(
            "trace recorded to {} ({} events over {:.3} s)",
            path.display(),
            trace.events.len(),
            trace.duration_s()
        );
    }
    Ok(())
}

/// Parse the serve command's `--quant` flag: absent → `None`; a bare
/// `--quant` → the default q8.8; `--quant qI.F` → that format.
fn parse_quant_flag(flags: &Flags) -> Result<Option<QFormat>> {
    if !flags.has("quant") {
        return Ok(None);
    }
    let raw = flags.get_str("quant", "q8.8");
    if raw == "true" {
        return Ok(Some(QFormat::new(16, 8)));
    }
    match raw.parse::<Precision>()? {
        Precision::Fixed(f) => Ok(Some(f)),
        // an explicit `--quant f32` is contradictory (the flag *adds*
        // fixed-point twins); rejecting beats silently re-defaulting
        Precision::F32 => bail!(
            "--quant f32 is contradictory — omit --quant for the f32 path"
        ),
    }
}

fn main() -> Result<()> {
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    // global --artifacts flag may precede the subcommand
    let mut artifacts_dir = std::path::PathBuf::from("artifacts");
    if args.first().map(|a| a == "--artifacts").unwrap_or(false) {
        if args.len() < 2 {
            bail!("--artifacts needs a directory");
        }
        artifacts_dir = args[1].clone().into();
        args.drain(0..2);
    }
    let Some(cmd) = args.first().cloned() else {
        print!("{USAGE}");
        return Ok(());
    };
    let flags = Flags::parse(&args[1..])?;

    match cmd.as_str() {
        "table1" => {
            print!("{}", exp::render_table1(&exp::run_table1(&PYNQ_Z2)?));
        }
        "table2" => {
            let runs = flags.get("runs", 50usize)?;
            let seed = flags.get("seed", 42u64)?;
            for net in ["mnist", "celeba"] {
                let d =
                    exp::run_table2(net, &PYNQ_Z2, &JETSON_TX1, runs, seed)?;
                println!("{}", exp::render_table2(&d));
            }
        }
        "dse" => {
            for net in ["mnist", "celeba"] {
                println!("{}", exp::render_fig5(&exp::run_fig5(net, &PYNQ_Z2)?));
            }
        }
        "sparsity" => {
            let network = flags.get_str("network", "mnist");
            let samples = flags.get("samples", 64usize)?;
            let seed = flags.get("seed", 7u64)?;
            let artifacts = ArtifactDir::open(&artifacts_dir)?;
            let levels = exp::default_levels();
            let data = if flags.has("pjrt") {
                let runtime = Runtime::cpu()?;
                exp::run_fig6_with_runtime(
                    &network, &PYNQ_Z2, &artifacts, &runtime, &levels,
                    samples, seed,
                )?
            } else {
                exp::run_fig6(
                    &network, &PYNQ_Z2, &artifacts, &levels, samples, seed,
                )?
            };
            print!("{}", exp::render_fig6(&data));
        }
        "ablations" => {
            let sparsity = flags.get("sparsity", 0.8f64)?;
            for net in ["mnist", "celeba"] {
                println!("== {net} ==");
                print!(
                    "{}",
                    exp::render_ablations(&exp::run_ablations(
                        net, &PYNQ_Z2, sparsity
                    )?)
                );
            }
        }
        "networks" => {
            for name in ["mnist", "celeba"] {
                let net = network_by_name(name)?;
                println!(
                    "{name}: z={} tile={} params={} total {:.2} MOps",
                    net.z_dim,
                    net.tile,
                    net.total_params(),
                    net.total_ops() as f64 / 1e6
                );
                for (i, l) in net.layers.iter().enumerate() {
                    println!(
                        "  L{}: {}x{}x{} -> {}x{}x{}  K={} S={} P={}  \
                         {:.2} MOps",
                        i + 1,
                        l.c_in,
                        l.i_h,
                        l.i_h,
                        l.c_out,
                        l.o_h(),
                        l.o_h(),
                        l.k,
                        l.stride,
                        l.padding,
                        l.ops() as f64 / 1e6
                    );
                }
            }
        }
        "serve" => {
            let network = flags.get_str("network", "mnist");
            let requests = flags.get("requests", 64usize)?;
            let images = flags.get("images", 2usize)?;
            let interarrival_ms = flags.get("interarrival-ms", 2.0f64)?;
            let seed = flags.get("seed", 42u64)?;
            let mut quant = parse_quant_flag(&flags)?;
            let mut quant8 = None;
            if network.ends_with(".q8") {
                quant8 = Some(QFormat::new(8, 6)); // default q2.6 twin
            } else if network.ends_with(".q") && quant.is_none() {
                quant = Some(QFormat::new(16, 8)); // default q8.8 twin
            }
            // base network to preload: "mnist.q" / "mnist.q8" serve
            // from "mnist" (.q8 first: ".q8".strip_suffix(".q") = None)
            let base = network
                .strip_suffix(".q8")
                .or_else(|| network.strip_suffix(".q"))
                .unwrap_or(network.as_str())
                .to_string();
            let pool = PoolCfg::from_flags(&flags)?;
            let coord = Coordinator::start(CoordinatorConfig {
                artifacts_dir,
                networks: vec![base],
                batcher: BatcherConfig::default(),
                backends: pool.backends,
                executors: pool.executors,
                quant,
                quant8,
                shard_batches: flags.has("shard"),
                clock: None,
            })?;
            let report = coord.serve_workload(&WorkloadSpec {
                network,
                requests,
                images_per_request: images,
                interarrival: Duration::from_secs_f64(interarrival_ms / 1e3),
                seed,
            })?;
            let obs = ObsCfg::from_flags(&flags)?;
            if let Some(path) = &obs.trace_out {
                let snapshot = coord.metrics_snapshot();
                std::fs::write(
                    path,
                    edgedcnn::telemetry::chrome_trace(
                        snapshot.span_lanes(),
                        &[],
                    ),
                )?;
                println!("trace written to {}", path.display());
            }
            if let Some(path) = &obs.prom_out {
                std::fs::write(path, report.prometheus_text())?;
                println!("prometheus metrics written to {}", path.display());
            }
            if flags.has("json") {
                print!("{}", report.to_json());
            } else {
                println!("{}", report.render());
            }
        }
        "bench" => {
            let smoke = flags.has("smoke");
            let mut opts = exp::BenchOpts::new(smoke);
            opts.trials = flags.get("trials", opts.trials)?;
            opts.serving = !flags.has("no-serving");
            let suite = exp::run_bench(&opts)?;
            if let Some(path) =
                flags.get_opt::<std::path::PathBuf>("out")?
            {
                std::fs::write(&path, suite.to_json())?;
                println!("bench suite written to {}", path.display());
            }
            if flags.has("json") {
                print!("{}", suite.to_json());
            } else {
                print!("{}", suite.render());
            }
            if let Some(base_path) =
                flags.get_opt::<std::path::PathBuf>("compare")?
            {
                let base = exp::BenchSuite::from_json(
                    &std::fs::read_to_string(&base_path).map_err(|e| {
                        anyhow::anyhow!(
                            "reading baseline {}: {e}",
                            base_path.display()
                        )
                    })?,
                )?;
                // a tripped gate is an Err → nonzero exit (CI fails)
                print!("{}", exp::compare_suites(&base, &suite)?);
            }
        }
        "tune" => {
            let smoke = flags.has("smoke");
            let mut opts = edgedcnn::tune::TuneOpts::new(smoke);
            opts.trials = flags.get("trials", opts.trials)?;
            let table = edgedcnn::tune::run_tune(&opts);
            let out = flags
                .get_opt::<std::path::PathBuf>("out")?
                .unwrap_or_else(|| {
                    std::path::PathBuf::from(edgedcnn::tune::TUNE_FILE)
                });
            std::fs::write(&out, table.to_json())?;
            println!("tune table written to {}", out.display());
            if flags.has("json") {
                print!("{}", table.to_json());
            } else {
                print!("{}", table.render());
            }
        }
        "loadtest" => {
            let smoke = flags.has("smoke");
            let pool = PoolCfg::from_flags(&flags)?;
            let traffic = TrafficCfg::from_flags(&flags)?;
            let trace = resolve_trace(&traffic, smoke)?;
            maybe_record(&trace, &traffic)?;
            let trials =
                flags.get("trials", if smoke { 1 } else { 5usize })?;
            let think_ms: f64 = flags.get("think-ms", 0.0)?;
            anyhow::ensure!(think_ms >= 0.0, "--think-ms must be >= 0");
            let report = run_loadtest(
                &trace,
                &LoadtestOpts {
                    artifacts_dir,
                    backends: pool.backends,
                    executors: pool.executors,
                    trials,
                    shard_batches: !flags.has("no-shard"),
                    closed: flags.get("closed", 0usize)?,
                    think: Duration::from_secs_f64(think_ms / 1e3),
                    drift_csv: flags.get_opt("drift-csv")?,
                    trace_out: ObsCfg::from_flags(&flags)?.trace_out,
                },
            )?;
            print!("{}", report.render());
        }
        "fleet" => {
            let smoke = flags.has("smoke");
            let pool = PoolCfg::from_flags(&flags)?;
            let traffic = TrafficCfg::from_flags(&flags)?;
            let trace = resolve_trace(&traffic, smoke)?;
            maybe_record(&trace, &traffic)?;
            let skew_ms: f64 = flags.get("skew-ms", 0.0)?;
            anyhow::ensure!(skew_ms >= 0.0, "--skew-ms must be >= 0");
            let fail_at_ms: f64 = flags.get("fail-at-ms", 0.0)?;
            anyhow::ensure!(fail_at_ms >= 0.0, "--fail-at-ms must be >= 0");
            let cfg = FleetCfg {
                artifacts_dir,
                sites: flags.get("sites", 3usize)?,
                backends: pool.backends,
                executors: pool.executors,
                shard_batches: !flags.has("no-shard"),
                placement: flags.get_str("placement", "hash"),
                vnodes: flags.get("vnodes", 64usize)?,
                spill: !flags.has("no-spill"),
                skew_s: skew_ms / 1e3,
                seed: flags.get("fleet-seed", trace.seed)?,
                fail_site: flags.get_opt("fail-site")?,
                fail_at_s: fail_at_ms / 1e3,
            };
            let run = run_fleet(&trace, &cfg)?;
            if let Some(path) = &ObsCfg::from_flags(&flags)?.trace_out {
                std::fs::write(path, run.chrome_trace())?;
                println!("trace written to {}", path.display());
            }
            if flags.has("json") {
                print!("{}", run.to_json());
            } else {
                print!("{}", run.render());
            }
        }
        "quant" => {
            let network = flags.get_str("network", "mnist");
            let samples = flags.get("samples", 32usize)?;
            let seed = flags.get("seed", 7u64)?;
            let artifacts = ArtifactDir::open(&artifacts_dir)?;
            let pinned = flags.has("bits") || flags.has("frac");
            let formats = if pinned {
                let bits = flags.get("bits", 16u32)?;
                let frac = flags.get("frac", 8u32)?;
                vec![QFormat::new(bits, frac)]
            } else {
                exp::default_quant_formats()
            };
            let data = exp::run_quant_error(
                &network, &PYNQ_Z2, &artifacts, &formats, samples, seed,
            )?;
            print!("{}", exp::render_quant_error(&data));
            if flags.has("export") {
                // a pinned format exports itself; a full sweep exports
                // the workhorse q8.8, not an arbitrary grid corner
                let fmt = if pinned { formats[0] } else { QFormat::new(16, 8) };
                let weights = artifacts.load_weights(&network)?;
                let gen = QuantizedGenerator::quantize(
                    fmt,
                    &weights,
                    Rounding::Nearest,
                )?;
                let path = edgedcnn::artifacts::export_quantized(
                    &artifacts.root,
                    &network,
                    &gen,
                )?;
                println!(
                    "quantized weights ({}) exported — sidecar {}",
                    fmt,
                    path.display()
                );
            }
        }
        "synth" => {
            let samples = flags.get("samples", 64usize)?;
            let seed = flags.get("seed", 0u64)?;
            let a = edgedcnn::artifacts::write_synthetic(
                &artifacts_dir,
                &["mnist", "celeba"],
                samples,
                seed,
            )?;
            println!(
                "synthetic artifact set written to {} ({} samples/network)",
                a.root.display(),
                samples
            );
        }
        "all" => {
            let runs = flags.get("runs", 50usize)?;
            println!("== Table I ==");
            print!("{}", exp::render_table1(&exp::run_table1(&PYNQ_Z2)?));
            println!("\n== Table II ==");
            for net in ["mnist", "celeba"] {
                let d = exp::run_table2(net, &PYNQ_Z2, &JETSON_TX1, runs, 42)?;
                println!("{}", exp::render_table2(&d));
            }
            println!("== Fig. 5 ==");
            for net in ["mnist", "celeba"] {
                println!("{}", exp::render_fig5(&exp::run_fig5(net, &PYNQ_Z2)?));
            }
            match ArtifactDir::open(&artifacts_dir) {
                Ok(artifacts) => {
                    println!("== Fig. 6 ==");
                    for net in ["mnist", "celeba"] {
                        let d = exp::run_fig6(
                            net,
                            &PYNQ_Z2,
                            &artifacts,
                            &exp::default_levels(),
                            32,
                            7,
                        )?;
                        print!("{}", exp::render_fig6(&d));
                    }
                }
                Err(_) => {
                    println!("(skipping Fig. 6 — run `make artifacts`)");
                }
            }
            println!("\n== Ablations ==");
            for net in ["mnist", "celeba"] {
                println!("-- {net} --");
                print!(
                    "{}",
                    exp::render_ablations(&exp::run_ablations(
                        net, &PYNQ_Z2, 0.8
                    )?)
                );
            }
        }
        "help" | "--help" | "-h" => print!("{USAGE}"),
        other => bail!("unknown command {other:?} (see `edgedcnn help`)"),
    }
    Ok(())
}
