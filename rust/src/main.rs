//! `edgedcnn` — CLI for the reproduction: regenerate every paper table
//! and figure, run the edge-serving coordinator, and inspect the
//! networks/ablations.  Run `edgedcnn help` for usage.
//!
//! (Arg parsing is hand-rolled: the offline build environment mirrors
//! only the `xla` dependency closure — no clap.)

use anyhow::{bail, Result};
use edgedcnn::artifacts::ArtifactDir;
use edgedcnn::config::{
    network_by_name, BackendCfg, Precision, JETSON_TX1, PYNQ_Z2,
};
use edgedcnn::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, WorkloadSpec,
};
use edgedcnn::experiments as exp;
use edgedcnn::quant::{QFormat, QuantizedGenerator, Rounding};
use edgedcnn::runtime::Runtime;
use edgedcnn::workload::{run_loadtest, LoadtestOpts, Scenario, Trace};
use std::collections::HashMap;
use std::path::Path;
use std::time::Duration;

const USAGE: &str = "\
edgedcnn — FPGA-vs-GPU DCNN inference study (Colbert et al. 2021)
           three-layer Rust + JAX + Pallas reproduction

USAGE: edgedcnn [--artifacts DIR] <command> [options]

COMMANDS:
  table1                     Table I  — resource utilization at T_OH*
  table2    [--runs N] [--seed S]
                             Table II — GOps/s/W mean(σ), FPGA vs GPU
  dse                        Fig. 5   — design-space exploration
  sparsity  [--network NET] [--samples N] [--seed S] [--pjrt]
                             Fig. 6   — pruning: speed-up / MMD / Eq. 6
  ablations [--sparsity F]   Section III enhancements on vs off
  networks                   Fig. 4 architectures and op counts
  serve     [--network NET] [--requests N] [--images K]
            [--interarrival-ms MS] [--seed S] [--executors E]
            [--backends fpga,gpu,cpu] [--queue-depth D]
            [--quant qI.F] [--shard]
                             drive the edge-serving coordinator over a
                             heterogeneous device-backend pool (one FIFO
                             lane per --backends entry; batches route to
                             the cheapest idle capable device and the
                             report shows per-backend columns); --quant
                             additionally serves fixed-point twins as
                             NET.q (e.g. --quant q8.8 --network mnist.q)
                             which route around the f32-only GPU,
                             --shard splits batches across the capable
                             lanes (intra-batch parallelism),
                             --queue-depth bounds each lane's queue
                             (backpressure), --executors E cycles the
                             backends list to E lanes
  loadtest  [--scenario NAME|FILE] [--trials N] [--requests N] [--seed S]
            [--backends fpga,gpu,cpu] [--queue-depth D] [--executors E]
            [--record FILE] [--replay FILE] [--no-shard] [--smoke]
            [--closed N] [--think-ms T] [--deadline-ms D]
                             scenario-driven load generation against the
                             backend pool, repeated over N seeded
                             trials, with the paper's Table-2-style run-
                             to-run-variation verdict: per-backend
                             p50/p95/p99/p99.9 (coordinated-omission
                             corrected), SLO + deadline attainment with
                             the shed / served-late split, and device-
                             latency CV columns.  Every request carries
                             a deadline + priority class from the
                             scenario; infeasible ones are shed at
                             intake (EDF scheduling, see DESIGN.md
                             §Deadline scheduling).  --scenario is a
                             built-in (steady|burst|diurnal|flash) or a
                             JSON scenario file; --record writes the
                             materialized trace (a shareable artifact),
                             --replay drives a recorded trace instead of
                             generating one; --no-shard keeps per-network
                             ordering (batches stop spreading over the
                             pool); --closed N drives N closed-loop
                             clients with --think-ms of think time
                             instead of the open-loop schedule;
                             --deadline-ms overrides the scenario's
                             relative deadline; --smoke is the short CI
                             mode
  quant     [--network NET] [--samples N] [--seed S]
            [--bits B --frac F] [--export]
                             fixed-point quantized inference: sweep
                             fraction bits vs output error (PSNR / MMD)
                             and FPGA latency at the quantized datapath;
                             --bits/--frac pin one Qm.n format, --export
                             writes calibrated quantized weights next to
                             the artifact set
  synth     [--samples N] [--seed S]
                             write a synthetic (untrained) artifact set
                             to the --artifacts dir, enough to serve
                             without the Python build layer
  all       [--runs N]       every table/figure in sequence
  help                       this text
";

/// Tiny flag parser: `--key value` pairs after the subcommand.
struct Flags(HashMap<String, String>);

impl Flags {
    fn parse(args: &[String]) -> Result<Flags> {
        let mut map = HashMap::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if let Some(key) = a.strip_prefix("--") {
                // boolean flags have no value or are followed by a flag
                if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                    map.insert(key.to_string(), args[i + 1].clone());
                    i += 2;
                } else {
                    map.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                bail!("unexpected argument {a:?} (see `edgedcnn help`)");
            }
        }
        Ok(Flags(map))
    }

    fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.0.get(key) {
            None => Ok(default),
            Some(raw) => raw
                .parse::<T>()
                .map_err(|_| anyhow::anyhow!("bad value for --{key}: {raw}")),
        }
    }

    fn get_str(&self, key: &str, default: &str) -> String {
        self.0
            .get(key)
            .cloned()
            .unwrap_or_else(|| default.to_string())
    }

    fn has(&self, key: &str) -> bool {
        self.0.contains_key(key)
    }
}

/// Parse the serve command's `--quant` flag: absent → `None`; a bare
/// `--quant` → the default q8.8; `--quant qI.F` → that format.
fn parse_quant_flag(flags: &Flags) -> Result<Option<QFormat>> {
    if !flags.has("quant") {
        return Ok(None);
    }
    let raw = flags.get_str("quant", "q8.8");
    if raw == "true" {
        return Ok(Some(QFormat::new(16, 8)));
    }
    match raw.parse::<Precision>()? {
        Precision::Fixed(f) => Ok(Some(f)),
        // an explicit `--quant f32` is contradictory (the flag *adds*
        // fixed-point twins); rejecting beats silently re-defaulting
        Precision::F32 => bail!(
            "--quant f32 is contradictory — omit --quant for the f32 path"
        ),
    }
}

fn main() -> Result<()> {
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    // global --artifacts flag may precede the subcommand
    let mut artifacts_dir = std::path::PathBuf::from("artifacts");
    if args.first().map(|a| a == "--artifacts").unwrap_or(false) {
        if args.len() < 2 {
            bail!("--artifacts needs a directory");
        }
        artifacts_dir = args[1].clone().into();
        args.drain(0..2);
    }
    let Some(cmd) = args.first().cloned() else {
        print!("{USAGE}");
        return Ok(());
    };
    let flags = Flags::parse(&args[1..])?;

    match cmd.as_str() {
        "table1" => {
            print!("{}", exp::render_table1(&exp::run_table1(&PYNQ_Z2)?));
        }
        "table2" => {
            let runs = flags.get("runs", 50usize)?;
            let seed = flags.get("seed", 42u64)?;
            for net in ["mnist", "celeba"] {
                let d =
                    exp::run_table2(net, &PYNQ_Z2, &JETSON_TX1, runs, seed)?;
                println!("{}", exp::render_table2(&d));
            }
        }
        "dse" => {
            for net in ["mnist", "celeba"] {
                println!("{}", exp::render_fig5(&exp::run_fig5(net, &PYNQ_Z2)?));
            }
        }
        "sparsity" => {
            let network = flags.get_str("network", "mnist");
            let samples = flags.get("samples", 64usize)?;
            let seed = flags.get("seed", 7u64)?;
            let artifacts = ArtifactDir::open(&artifacts_dir)?;
            let levels = exp::default_levels();
            let data = if flags.has("pjrt") {
                let runtime = Runtime::cpu()?;
                exp::run_fig6_with_runtime(
                    &network, &PYNQ_Z2, &artifacts, &runtime, &levels,
                    samples, seed,
                )?
            } else {
                exp::run_fig6(
                    &network, &PYNQ_Z2, &artifacts, &levels, samples, seed,
                )?
            };
            print!("{}", exp::render_fig6(&data));
        }
        "ablations" => {
            let sparsity = flags.get("sparsity", 0.8f64)?;
            for net in ["mnist", "celeba"] {
                println!("== {net} ==");
                print!(
                    "{}",
                    exp::render_ablations(&exp::run_ablations(
                        net, &PYNQ_Z2, sparsity
                    )?)
                );
            }
        }
        "networks" => {
            for name in ["mnist", "celeba"] {
                let net = network_by_name(name)?;
                println!(
                    "{name}: z={} tile={} params={} total {:.2} MOps",
                    net.z_dim,
                    net.tile,
                    net.total_params(),
                    net.total_ops() as f64 / 1e6
                );
                for (i, l) in net.layers.iter().enumerate() {
                    println!(
                        "  L{}: {}x{}x{} -> {}x{}x{}  K={} S={} P={}  \
                         {:.2} MOps",
                        i + 1,
                        l.c_in,
                        l.i_h,
                        l.i_h,
                        l.c_out,
                        l.o_h(),
                        l.o_h(),
                        l.k,
                        l.stride,
                        l.padding,
                        l.ops() as f64 / 1e6
                    );
                }
            }
        }
        "serve" => {
            let network = flags.get_str("network", "mnist");
            let requests = flags.get("requests", 64usize)?;
            let images = flags.get("images", 2usize)?;
            let interarrival_ms = flags.get("interarrival-ms", 2.0f64)?;
            let seed = flags.get("seed", 42u64)?;
            let executors = flags.get("executors", 0usize)?;
            let mut quant = parse_quant_flag(&flags)?;
            if network.ends_with(".q") && quant.is_none() {
                quant = Some(QFormat::new(16, 8)); // default q8.8 twin
            }
            // base network to preload: "mnist.q" serves from "mnist"
            let base = network
                .strip_suffix(".q")
                .unwrap_or(network.as_str())
                .to_string();
            let mut backends = BackendCfg::default();
            if flags.has("backends") {
                backends.kinds =
                    BackendCfg::parse_kinds(&flags.get_str("backends", ""))?;
            }
            backends.max_queue_depth =
                flags.get("queue-depth", backends.max_queue_depth)?;
            let coord = Coordinator::start(CoordinatorConfig {
                artifacts_dir,
                networks: vec![base],
                batcher: BatcherConfig::default(),
                backends,
                executors,
                quant,
                shard_batches: flags.has("shard"),
            })?;
            let report = coord.serve_workload(&WorkloadSpec {
                network,
                requests,
                images_per_request: images,
                interarrival: Duration::from_secs_f64(interarrival_ms / 1e3),
                seed,
            })?;
            println!("{}", report.render());
        }
        "loadtest" => {
            let smoke = flags.has("smoke");
            let mut scenario =
                Scenario::resolve(&flags.get_str("scenario", "steady"))?;
            scenario.seed = flags.get("seed", scenario.seed)?;
            let default_requests =
                if smoke { 24 } else { scenario.requests };
            scenario.requests = flags.get("requests", default_requests)?;
            if flags.has("deadline-ms") {
                let d_ms: f64 = flags.get("deadline-ms", 0.0)?;
                anyhow::ensure!(d_ms > 0.0, "--deadline-ms must be positive");
                scenario.deadline_s = Some(d_ms / 1e3);
            }
            let trials =
                flags.get("trials", if smoke { 1 } else { 5usize })?;
            let trace = if flags.has("replay") {
                Trace::load(Path::new(&flags.get_str("replay", "")))?
            } else {
                Trace::generate(&scenario)?
            };
            if flags.has("record") {
                let path = flags.get_str("record", "trace.json");
                trace.save(Path::new(&path))?;
                println!(
                    "trace recorded to {path} ({} events over {:.3} s)",
                    trace.events.len(),
                    trace.duration_s()
                );
            }
            let mut backends = BackendCfg::default();
            if flags.has("backends") {
                backends.kinds =
                    BackendCfg::parse_kinds(&flags.get_str("backends", ""))?;
            }
            backends.max_queue_depth =
                flags.get("queue-depth", backends.max_queue_depth)?;
            let think_ms: f64 = flags.get("think-ms", 0.0)?;
            anyhow::ensure!(think_ms >= 0.0, "--think-ms must be >= 0");
            let report = run_loadtest(
                &trace,
                &LoadtestOpts {
                    artifacts_dir,
                    backends,
                    executors: flags.get("executors", 0usize)?,
                    trials,
                    shard_batches: !flags.has("no-shard"),
                    closed: flags.get("closed", 0usize)?,
                    think: Duration::from_secs_f64(think_ms / 1e3),
                },
            )?;
            print!("{}", report.render());
        }
        "quant" => {
            let network = flags.get_str("network", "mnist");
            let samples = flags.get("samples", 32usize)?;
            let seed = flags.get("seed", 7u64)?;
            let artifacts = ArtifactDir::open(&artifacts_dir)?;
            let pinned = flags.has("bits") || flags.has("frac");
            let formats = if pinned {
                let bits = flags.get("bits", 16u32)?;
                let frac = flags.get("frac", 8u32)?;
                vec![QFormat::new(bits, frac)]
            } else {
                exp::default_quant_formats()
            };
            let data = exp::run_quant_error(
                &network, &PYNQ_Z2, &artifacts, &formats, samples, seed,
            )?;
            print!("{}", exp::render_quant_error(&data));
            if flags.has("export") {
                // a pinned format exports itself; a full sweep exports
                // the workhorse q8.8, not an arbitrary grid corner
                let fmt = if pinned { formats[0] } else { QFormat::new(16, 8) };
                let weights = artifacts.load_weights(&network)?;
                let gen = QuantizedGenerator::quantize(
                    fmt,
                    &weights,
                    Rounding::Nearest,
                )?;
                let path = edgedcnn::artifacts::export_quantized(
                    &artifacts.root,
                    &network,
                    &gen,
                )?;
                println!(
                    "quantized weights ({}) exported — sidecar {}",
                    fmt,
                    path.display()
                );
            }
        }
        "synth" => {
            let samples = flags.get("samples", 64usize)?;
            let seed = flags.get("seed", 0u64)?;
            let a = edgedcnn::artifacts::write_synthetic(
                &artifacts_dir,
                &["mnist", "celeba"],
                samples,
                seed,
            )?;
            println!(
                "synthetic artifact set written to {} ({} samples/network)",
                a.root.display(),
                samples
            );
        }
        "all" => {
            let runs = flags.get("runs", 50usize)?;
            println!("== Table I ==");
            print!("{}", exp::render_table1(&exp::run_table1(&PYNQ_Z2)?));
            println!("\n== Table II ==");
            for net in ["mnist", "celeba"] {
                let d = exp::run_table2(net, &PYNQ_Z2, &JETSON_TX1, runs, 42)?;
                println!("{}", exp::render_table2(&d));
            }
            println!("== Fig. 5 ==");
            for net in ["mnist", "celeba"] {
                println!("{}", exp::render_fig5(&exp::run_fig5(net, &PYNQ_Z2)?));
            }
            match ArtifactDir::open(&artifacts_dir) {
                Ok(artifacts) => {
                    println!("== Fig. 6 ==");
                    for net in ["mnist", "celeba"] {
                        let d = exp::run_fig6(
                            net,
                            &PYNQ_Z2,
                            &artifacts,
                            &exp::default_levels(),
                            32,
                            7,
                        )?;
                        print!("{}", exp::render_fig6(&d));
                    }
                }
                Err(_) => {
                    println!("(skipping Fig. 6 — run `make artifacts`)");
                }
            }
            println!("\n== Ablations ==");
            for net in ["mnist", "celeba"] {
                println!("-- {net} --");
                print!(
                    "{}",
                    exp::render_ablations(&exp::run_ablations(
                        net, &PYNQ_Z2, 0.8
                    )?)
                );
            }
        }
        "help" | "--help" | "-h" => print!("{USAGE}"),
        other => bail!("unknown command {other:?} (see `edgedcnn help`)"),
    }
    Ok(())
}
