//! Sampled power meter — the software analogue of the paper's "USB Power
//! Meter Voltage Detector": a sampler integrates instantaneous power
//! (from the device model's activity) into energy over the serving run.

/// Trapezoidal power-to-energy integrator with sample statistics.
#[derive(Debug, Default)]
pub struct PowerMeter {
    last_sample_w: Option<f64>,
    energy_j: f64,
    samples: usize,
    peak_w: f64,
}

impl PowerMeter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record an instantaneous power reading covering `dt_s` seconds
    /// since the previous one (trapezoidal rule).
    pub fn sample(&mut self, power_w: f64, dt_s: f64) {
        assert!(power_w >= 0.0 && dt_s >= 0.0, "bad sample");
        let prev = self.last_sample_w.unwrap_or(power_w);
        self.energy_j += 0.5 * (prev + power_w) * dt_s;
        self.last_sample_w = Some(power_w);
        self.samples += 1;
        self.peak_w = self.peak_w.max(power_w);
    }

    /// Convenience: a constant-power interval (e.g. one simulated layer).
    pub fn add_interval(&mut self, power_w: f64, dt_s: f64) {
        assert!(power_w >= 0.0 && dt_s >= 0.0, "bad interval");
        self.energy_j += power_w * dt_s;
        self.last_sample_w = Some(power_w);
        self.samples += 1;
        self.peak_w = self.peak_w.max(power_w);
    }

    pub fn energy_j(&self) -> f64 {
        self.energy_j
    }

    pub fn peak_w(&self) -> f64 {
        self.peak_w
    }

    pub fn samples(&self) -> usize {
        self.samples
    }

    /// Mean power over a known wall time.
    pub fn mean_power_w(&self, wall_s: f64) -> f64 {
        if wall_s <= 0.0 {
            0.0
        } else {
            self.energy_j / wall_s
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_power_integrates_exactly() {
        let mut m = PowerMeter::new();
        for _ in 0..10 {
            m.add_interval(2.5, 0.1);
        }
        assert!((m.energy_j() - 2.5).abs() < 1e-12);
        assert!((m.mean_power_w(1.0) - 2.5).abs() < 1e-12);
        assert_eq!(m.peak_w(), 2.5);
    }

    #[test]
    fn trapezoid_averages_ramp() {
        let mut m = PowerMeter::new();
        m.sample(0.0, 0.0);
        m.sample(10.0, 1.0); // ramp 0→10 over 1 s = 5 J
        assert!((m.energy_j() - 5.0).abs() < 1e-12);
        assert_eq!(m.peak_w(), 10.0);
    }

    #[test]
    #[should_panic]
    fn negative_power_rejected() {
        PowerMeter::new().sample(-1.0, 0.1);
    }
}
