//! Serving metrics — latency distribution, throughput, arithmetic
//! throughput, the energy integration that yields the GOps/s/W headline
//! for the end-to-end example, and the **per-backend columns** (where
//! the scheduler routed the work, and at what device latency/energy).

use crate::stats::{percentile, Summary};
use std::collections::BTreeMap;

/// Per-backend accumulator (keyed by lane name, e.g. `fpga0`).
#[derive(Debug, Default, Clone)]
struct BackendStats {
    batches: u64,
    images: u64,
    ops: u64,
    device_time_s: f64,
    energy_j: f64,
}

/// Accumulates per-request and per-batch telemetry during a serving run.
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    latencies_s: Vec<f64>,
    execute_s: Vec<f64>,
    batch_sizes: Vec<usize>,
    images: u64,
    requests: u64,
    rejected: u64,
    ops: u64,
    energy_j: f64,
    wall_s: f64,
    backends: BTreeMap<String, BackendStats>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_request(&mut self, latency_s: f64, n_images: usize) {
        self.latencies_s.push(latency_s);
        self.requests += 1;
        self.images += n_images as u64;
    }

    pub fn record_batch(&mut self, execute_s: f64, batch: usize, ops: u64) {
        self.execute_s.push(execute_s);
        self.batch_sizes.push(batch);
        self.ops += ops;
    }

    pub fn record_energy(&mut self, joules: f64) {
        self.energy_j += joules;
    }

    /// Account one executed batch to the backend lane that served it.
    pub fn record_backend_batch(
        &mut self,
        backend: &str,
        images: usize,
        ops: u64,
        device_time_s: f64,
        energy_j: f64,
    ) {
        let b = self.backends.entry(backend.to_string()).or_default();
        b.batches += 1;
        b.images += images as u64;
        b.ops += ops;
        b.device_time_s += device_time_s;
        b.energy_j += energy_j;
    }

    /// Count one request turned away by admission control.
    pub fn record_rejected(&mut self) {
        self.rejected += 1;
    }

    pub fn set_wall(&mut self, wall_s: f64) {
        self.wall_s = wall_s;
    }

    pub fn requests(&self) -> u64 {
        self.requests
    }

    pub fn report(&self) -> ServingReport {
        let lat = if self.latencies_s.is_empty() {
            LatencyReport::default()
        } else {
            LatencyReport {
                mean_s: Summary::of(&self.latencies_s).mean,
                p50_s: percentile(&self.latencies_s, 50.0),
                p95_s: percentile(&self.latencies_s, 95.0),
                p99_s: percentile(&self.latencies_s, 99.0),
            }
        };
        let wall = self.wall_s.max(1e-12);
        let mean_power = if self.wall_s > 0.0 {
            self.energy_j / self.wall_s
        } else {
            0.0
        };
        let gops = self.ops as f64 / wall / 1e9;
        let per_backend = self
            .backends
            .iter()
            .map(|(name, b)| BackendReport {
                name: name.clone(),
                batches: b.batches,
                images: b.images,
                images_per_s: b.images as f64 / wall,
                device_gops: if b.device_time_s > 0.0 {
                    b.ops as f64 / b.device_time_s / 1e9
                } else {
                    0.0
                },
                mean_device_latency_s: if b.batches > 0 {
                    b.device_time_s / b.batches as f64
                } else {
                    0.0
                },
                energy_j: b.energy_j,
            })
            .collect();
        ServingReport {
            requests: self.requests,
            images: self.images,
            rejected: self.rejected,
            batches: self.execute_s.len() as u64,
            wall_s: self.wall_s,
            latency: lat,
            images_per_s: self.images as f64 / wall,
            gops,
            mean_batch: if self.batch_sizes.is_empty() {
                0.0
            } else {
                self.batch_sizes.iter().sum::<usize>() as f64
                    / self.batch_sizes.len() as f64
            },
            mean_power_w: mean_power,
            gops_per_w: if mean_power > 0.0 { gops / mean_power } else { 0.0 },
            per_backend,
        }
    }
}

/// Latency distribution summary.
#[derive(Debug, Default, Clone, Copy)]
pub struct LatencyReport {
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub p99_s: f64,
}

/// One backend lane's column in the serving report.
#[derive(Debug, Clone)]
pub struct BackendReport {
    /// Lane name (`fpga0`, `gpu0`, `cpu0`, …).
    pub name: String,
    pub batches: u64,
    pub images: u64,
    /// Images served by this backend per wall second.
    pub images_per_s: f64,
    /// Device arithmetic throughput (ops / device time).
    pub device_gops: f64,
    /// Mean device latency per batch, seconds.
    pub mean_device_latency_s: f64,
    pub energy_j: f64,
}

/// Final serving report (printed by the `serve` CLI and the edge_serving
/// example; recorded in EXPERIMENTS.md §E9).
#[derive(Debug, Clone)]
pub struct ServingReport {
    pub requests: u64,
    pub images: u64,
    /// Requests turned away by admission control.
    pub rejected: u64,
    pub batches: u64,
    pub wall_s: f64,
    pub latency: LatencyReport,
    pub images_per_s: f64,
    pub gops: f64,
    pub mean_batch: f64,
    pub mean_power_w: f64,
    pub gops_per_w: f64,
    /// Per-backend columns, sorted by lane name.
    pub per_backend: Vec<BackendReport>,
}

impl ServingReport {
    pub fn render(&self) -> String {
        let mut out = format!(
            "requests {:>6}   images {:>6}   batches {:>5}  (mean batch {:.2})\n\
             wall {:>8.3} s   throughput {:>8.2} img/s   {:>7.2} GOps/s\n\
             latency mean {:.2} ms  p50 {:.2} ms  p95 {:.2} ms  p99 {:.2} ms\n\
             power {:>6.2} W   {:>6.2} GOps/s/W",
            self.requests,
            self.images,
            self.batches,
            self.mean_batch,
            self.wall_s,
            self.images_per_s,
            self.gops,
            self.latency.mean_s * 1e3,
            self.latency.p50_s * 1e3,
            self.latency.p95_s * 1e3,
            self.latency.p99_s * 1e3,
            self.mean_power_w,
            self.gops_per_w,
        );
        if self.rejected > 0 {
            out.push_str(&format!("\nrejected {:>6}  (admission control)", self.rejected));
        }
        for b in &self.per_backend {
            out.push_str(&format!(
                "\nbackend {:<6} batches {:>5}   images {:>6}   device {:>7.2} ms/batch   \
                 {:>7.2} GOps/s   energy {:>8.3} J   {:>8.2} img/s",
                b.name,
                b.batches,
                b.images,
                b.mean_device_latency_s * 1e3,
                b.device_gops,
                b.energy_j,
                b.images_per_s,
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_aggregates() {
        let mut m = MetricsRegistry::new();
        for i in 0..10 {
            m.record_request(0.001 * (i + 1) as f64, 2);
        }
        m.record_batch(0.004, 4, 1_000_000_000);
        m.record_batch(0.006, 4, 1_000_000_000);
        m.record_energy(5.0);
        m.set_wall(1.0);
        let r = m.report();
        assert_eq!(r.requests, 10);
        assert_eq!(r.images, 20);
        assert_eq!(r.batches, 2);
        assert!((r.images_per_s - 20.0).abs() < 1e-9);
        assert!((r.gops - 2.0).abs() < 1e-9);
        assert!((r.mean_power_w - 5.0).abs() < 1e-9);
        assert!((r.gops_per_w - 0.4).abs() < 1e-9);
        assert!(r.latency.p99_s >= r.latency.p50_s);
    }

    #[test]
    fn empty_registry_reports_zeroes() {
        let r = MetricsRegistry::new().report();
        assert_eq!(r.requests, 0);
        assert_eq!(r.gops_per_w, 0.0);
    }

    #[test]
    fn render_contains_key_fields() {
        let mut m = MetricsRegistry::new();
        m.record_request(0.002, 1);
        m.set_wall(0.5);
        let s = m.report().render();
        assert!(s.contains("GOps/s/W"));
        assert!(s.contains("p99"));
    }

    #[test]
    fn per_backend_columns_aggregate_and_render() {
        let mut m = MetricsRegistry::new();
        m.record_backend_batch("fpga0", 8, 2_000_000_000, 0.5, 1.25);
        m.record_backend_batch("fpga0", 8, 2_000_000_000, 0.5, 1.25);
        m.record_backend_batch("gpu0", 4, 1_000_000_000, 0.1, 1.1);
        m.set_wall(2.0);
        let r = m.report();
        assert_eq!(r.per_backend.len(), 2);
        let fpga = &r.per_backend[0];
        assert_eq!(fpga.name, "fpga0", "BTreeMap order is deterministic");
        assert_eq!(fpga.batches, 2);
        assert_eq!(fpga.images, 16);
        assert!((fpga.images_per_s - 8.0).abs() < 1e-9);
        assert!((fpga.device_gops - 4.0).abs() < 1e-9);
        assert!((fpga.mean_device_latency_s - 0.5).abs() < 1e-9);
        assert!((fpga.energy_j - 2.5).abs() < 1e-9);
        let s = r.render();
        assert!(s.contains("backend fpga0"), "{s}");
        assert!(s.contains("backend gpu0"), "{s}");
        assert!(!s.contains("rejected"), "no admission line when zero");
    }

    #[test]
    fn rejected_requests_are_reported() {
        let mut m = MetricsRegistry::new();
        m.record_rejected();
        m.record_rejected();
        m.set_wall(1.0);
        let r = m.report();
        assert_eq!(r.rejected, 2);
        assert!(r.render().contains("rejected"));
    }
}
