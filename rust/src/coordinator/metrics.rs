//! Serving metrics — latency distribution, throughput, arithmetic
//! throughput, the energy integration that yields the GOps/s/W headline
//! for the end-to-end example, the **per-backend columns** (where the
//! scheduler routed the work, at what device latency/energy, and with
//! how much run-to-run variation), and the scheduler's per-lane
//! queue-depth/deferral telemetry.
//!
//! Latency is accumulated in streaming log-bucketed histograms
//! ([`crate::telemetry::LogHistogram`]) — O(1) memory under sustained
//! load, where the old `Vec<f64>` grew 8 bytes per request forever.
//! Means, counts and energy stay exact; p50/p95/p99/p99.9 are bucketed
//! (within 2% relative error; see DESIGN.md §Telemetry).

use super::request::PriorityClass;
use crate::stats::Welford;
use crate::telemetry::{
    cv_of, weighted_cv, LogHistogram, SpanRecord, SpanRecorder, Stage,
    WindowedHistogram, STAGE_COUNT,
};
use crate::util::{escape_json, parse_json, Json};
use anyhow::Result;
use std::collections::BTreeMap;
use std::time::Instant;

/// Schema version written by [`ServingReport::to_json`].  Mirrors the
/// trace-v2 contract: older readers refuse *future* versions instead of
/// misreading them.
const REPORT_VERSION: u64 = 1;

/// Deadline outcome counters for one (backend, priority class) cell.
#[derive(Debug, Default, Clone, Copy)]
struct DeadlineCount {
    met: u64,
    late: u64,
}

/// Per-backend accumulator (keyed by lane name, e.g. `fpga0`).
#[derive(Debug, Clone)]
struct BackendStats {
    batches: u64,
    images: u64,
    ops: u64,
    device_time_s: f64,
    energy_j: f64,
    /// Request latencies resolved by this lane (histogram shard).
    latency: LogHistogram,
    /// Deadline attainment per priority class (edge-charged completion
    /// vs the request's absolute deadline; best-effort requests are not
    /// counted).
    deadline: BTreeMap<PriorityClass, DeadlineCount>,
    /// Per-image device seconds per batch, keyed by **(logical
    /// network, batch size)** — the run-to-run variation series behind
    /// the CV column.  Both key halves matter: a lane serving `mnist`
    /// and its `mnist.q` twin has two legitimately different service
    /// times, and the GPU's per-image time legitimately shrinks as
    /// launch overhead amortizes over bigger batches — pooling either
    /// axis would report workload mix as device jitter instead of the
    /// paper's fixed-operating-point run-to-run variation.
    per_image_dev: BTreeMap<(String, usize), Welford>,
}

impl Default for BackendStats {
    fn default() -> Self {
        BackendStats {
            batches: 0,
            images: 0,
            ops: 0,
            device_time_s: 0.0,
            energy_j: 0.0,
            latency: LogHistogram::latency_default(),
            deadline: BTreeMap::new(),
            per_image_dev: BTreeMap::new(),
        }
    }
}

/// Per-(backend, class) lifecycle-stage accumulators: one latency
/// histogram shard plus one Welford series per stage — the histogram
/// gives mergeable quantiles, the Welford gives the per-stage CV that
/// separates device-execute jitter from queue-wait jitter.
#[derive(Debug, Clone)]
struct StageStats {
    hist: [LogHistogram; STAGE_COUNT],
    spread: [Welford; STAGE_COUNT],
}

impl Default for StageStats {
    fn default() -> Self {
        StageStats {
            hist: std::array::from_fn(|_| LogHistogram::latency_default()),
            spread: [Welford::new(); STAGE_COUNT],
        }
    }
}

/// Per-lane scheduler telemetry (dispatch-time queue depths).
#[derive(Debug, Default, Clone)]
struct LaneQueueStats {
    dispatches: u64,
    depth: Welford,
    max_depth: usize,
    cost_refreshes: u64,
}

/// Accumulates per-request and per-batch telemetry during a serving run.
///
/// Registries are **mergeable** ([`Self::merge_from`]): every field is
/// either a sum-monoid counter, a mergeable histogram/Welford, or a
/// keyed map of those — so a fleet of per-site registries folds into
/// one fleet-level registry whose report equals recording the same
/// events in a single process (the fleet integration test asserts the
/// fold against the direct aggregate).
#[derive(Debug, Clone)]
pub struct MetricsRegistry {
    latency: LogHistogram,
    /// Time-sliced latency shards (the drift column: is the tail a
    /// burst or the steady state?).  Slices are anchored to the
    /// registry's creation instant — each serving window resets the
    /// registry, so the clock starts with the window.
    windowed: WindowedHistogram,
    t0: Instant,
    batches: u64,
    batch_images: u64,
    images: u64,
    requests: u64,
    rejected: u64,
    /// Requests shed at intake because their deadline was already
    /// infeasible (distinct from `rejected` = overload).
    shed: u64,
    shed_by_class: BTreeMap<PriorityClass, u64>,
    deferred: u64,
    ops: u64,
    energy_j: f64,
    wall_s: f64,
    /// High-water mark of the hot-path scratch arena as observed by the
    /// lane thread (bytes).  Max-monoid: merging shards takes the max,
    /// matching the semantics of a high-water mark.
    scratch_hwm_bytes: u64,
    backends: BTreeMap<String, BackendStats>,
    lanes: BTreeMap<String, LaneQueueStats>,
    /// Lifecycle-stage accumulators, backend → class → 7 stage cells
    /// (fed by the executor from completed [`StageStamps`] span sets).
    ///
    /// [`StageStamps`]: crate::telemetry::StageStamps
    stages: BTreeMap<String, BTreeMap<PriorityClass, StageStats>>,
    /// Per-lane flight-recorder rings of head-sampled span sets.  Not
    /// part of the serving-report JSON (the report carries the folded
    /// `stage_breakdown` instead); drained by the `--trace-out`
    /// exporters via [`Self::span_lanes`].
    spans: BTreeMap<String, SpanRecorder>,
}

impl Default for MetricsRegistry {
    fn default() -> Self {
        MetricsRegistry {
            latency: LogHistogram::latency_default(),
            // 250 ms slices, 64 retained → 16 s of time structure
            windowed: WindowedHistogram::latency_default(0.25, 64),
            t0: Instant::now(),
            batches: 0,
            batch_images: 0,
            images: 0,
            requests: 0,
            rejected: 0,
            shed: 0,
            shed_by_class: BTreeMap::new(),
            deferred: 0,
            ops: 0,
            energy_j: 0.0,
            wall_s: 0.0,
            scratch_hwm_bytes: 0,
            backends: BTreeMap::new(),
            lanes: BTreeMap::new(),
            stages: BTreeMap::new(),
            spans: BTreeMap::new(),
        }
    }
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_request(&mut self, latency_s: f64, n_images: usize) {
        self.record_request_at(
            self.t0.elapsed().as_secs_f64(),
            latency_s,
            n_images,
        );
    }

    /// [`Self::record_request`] with an explicit run-relative timestamp
    /// (tests drive the window clock deterministically through this).
    pub fn record_request_at(
        &mut self,
        at_s: f64,
        latency_s: f64,
        n_images: usize,
    ) {
        self.latency.record(latency_s);
        self.windowed.record(at_s, latency_s);
        self.requests += 1;
        self.images += n_images as u64;
    }

    /// Count one executed batch.  (`_execute_s` is part of the stable
    /// recording interface; the host wall time is reported per response,
    /// not aggregated here.)
    pub fn record_batch(&mut self, _execute_s: f64, batch: usize, ops: u64) {
        self.batches += 1;
        self.batch_images += batch as u64;
        self.ops += ops;
    }

    pub fn record_energy(&mut self, joules: f64) {
        self.energy_j += joules;
    }

    /// Account one executed batch (of `network`) to the backend lane
    /// that served it.
    pub fn record_backend_batch(
        &mut self,
        backend: &str,
        network: &str,
        images: usize,
        ops: u64,
        device_time_s: f64,
        energy_j: f64,
    ) {
        let b = self.backends.entry(backend.to_string()).or_default();
        b.batches += 1;
        b.images += images as u64;
        b.ops += ops;
        b.device_time_s += device_time_s;
        b.energy_j += energy_j;
        b.per_image_dev
            .entry((network.to_string(), images))
            .or_default()
            .push(device_time_s / images.max(1) as f64);
    }

    /// Account one resolved request's latency to the lane that served
    /// its batch (per-backend histogram shard).
    pub fn record_backend_request(&mut self, backend: &str, latency_s: f64) {
        self.backends
            .entry(backend.to_string())
            .or_default()
            .latency
            .record(latency_s);
    }

    /// Count one request turned away by admission control.
    pub fn record_rejected(&mut self) {
        self.rejected += 1;
    }

    /// Count one request shed at intake because its deadline was
    /// already infeasible (shed-early instead of serve-late).
    pub fn record_shed(&mut self, class: PriorityClass) {
        self.shed += 1;
        *self.shed_by_class.entry(class).or_insert(0) += 1;
    }

    /// Account one deadline-bearing request's outcome to the lane that
    /// served it: did the edge-charged completion make the deadline?
    pub fn record_backend_deadline(
        &mut self,
        backend: &str,
        class: PriorityClass,
        met: bool,
    ) {
        let d = self
            .backends
            .entry(backend.to_string())
            .or_default()
            .deadline
            .entry(class)
            .or_default();
        if met {
            d.met += 1;
        } else {
            d.late += 1;
        }
    }

    /// Count one batch entering the deferred (waiting-for-capacity)
    /// queue.
    pub fn record_deferred(&mut self) {
        self.deferred += 1;
    }

    /// Fold one observation of the hot-path scratch-arena high-water
    /// mark (bytes, as read by the observing thread via
    /// [`crate::util::scratch_hwm_bytes`]).  Keeps the max: the column
    /// answers "how big did the per-worker arena ever get this window".
    pub fn record_scratch_hwm(&mut self, bytes: usize) {
        self.scratch_hwm_bytes = self.scratch_hwm_bytes.max(bytes as u64);
    }

    /// Scheduler telemetry: one batch dispatched to `lane`, which then
    /// held `depth` not-yet-executed batches.
    pub fn record_lane_dispatch(&mut self, lane: &str, depth: usize) {
        let l = self.lanes.entry(lane.to_string()).or_default();
        l.dispatches += 1;
        l.depth.push(depth as f64);
        l.max_depth = l.max_depth.max(depth);
    }

    /// Count one cost-model re-probe on `lane` (DVFS throttle
    /// transition observed by the executor).
    pub fn record_cost_refresh(&mut self, lane: &str) {
        self.lanes.entry(lane.to_string()).or_default().cost_refreshes += 1;
    }

    /// Fold one completed request's lifecycle stage spans (indexed by
    /// [`Stage::index`]) into the per-(backend, class) breakdown.  The
    /// steady-state path allocates nothing: the key `String`s are
    /// created only on a cell's first observation.
    pub fn record_stages(
        &mut self,
        backend: &str,
        class: PriorityClass,
        spans: &[f64; STAGE_COUNT],
    ) {
        if !self.stages.contains_key(backend) {
            self.stages.insert(backend.to_string(), BTreeMap::new());
        }
        let cell = self
            .stages
            .get_mut(backend)
            .expect("just inserted")
            .entry(class)
            .or_default();
        for (i, &s) in spans.iter().enumerate() {
            cell.hist[i].record(s);
            cell.spread[i].push(s);
        }
    }

    /// Push one head-sampled span set into `lane`'s flight-recorder
    /// ring (bounded, overwrite-oldest; the ring buffer is allocated
    /// lazily on the lane's first sampled request, then reused).
    pub fn record_span(&mut self, lane: &str, rec: SpanRecord) {
        if !self.spans.contains_key(lane) {
            self.spans.insert(lane.to_string(), SpanRecorder::new());
        }
        self.spans.get_mut(lane).expect("just inserted").push(rec);
    }

    /// The per-lane span rings, lane-name order (what the `--trace-out`
    /// exporters hand to [`crate::telemetry::chrome_trace`]).
    pub fn span_lanes(&self) -> impl Iterator<Item = (&str, &SpanRecorder)> {
        self.spans.iter().map(|(name, ring)| (name.as_str(), ring))
    }

    pub fn set_wall(&mut self, wall_s: f64) {
        self.wall_s = wall_s;
    }

    pub fn requests(&self) -> u64 {
        self.requests
    }

    /// Fold another registry (a per-site telemetry shard) into this
    /// one.  Counters add, histograms merge (bucket-count addition —
    /// exact), Welford accumulators combine (Chan et al.), and the wall
    /// clock takes the max: fleet sites serve *concurrently*, so the
    /// fleet measurement window is the longest site window, not the
    /// sum.  Every constituent merge is associative, so fleet folds
    /// give the same report in any association order.
    pub fn merge_from(&mut self, other: &MetricsRegistry) {
        self.latency.merge(&other.latency);
        self.windowed.merge(&other.windowed);
        self.batches += other.batches;
        self.batch_images += other.batch_images;
        self.images += other.images;
        self.requests += other.requests;
        self.rejected += other.rejected;
        self.shed += other.shed;
        for (class, n) in &other.shed_by_class {
            *self.shed_by_class.entry(*class).or_insert(0) += n;
        }
        self.deferred += other.deferred;
        self.ops += other.ops;
        self.energy_j += other.energy_j;
        self.wall_s = self.wall_s.max(other.wall_s);
        self.scratch_hwm_bytes =
            self.scratch_hwm_bytes.max(other.scratch_hwm_bytes);
        for (name, b) in &other.backends {
            let mine = self.backends.entry(name.clone()).or_default();
            mine.batches += b.batches;
            mine.images += b.images;
            mine.ops += b.ops;
            mine.device_time_s += b.device_time_s;
            mine.energy_j += b.energy_j;
            mine.latency.merge(&b.latency);
            for (class, d) in &b.deadline {
                let cell = mine.deadline.entry(*class).or_default();
                cell.met += d.met;
                cell.late += d.late;
            }
            for (key, w) in &b.per_image_dev {
                mine.per_image_dev.entry(key.clone()).or_default().merge(w);
            }
        }
        for (name, l) in &other.lanes {
            let mine = self.lanes.entry(name.clone()).or_default();
            mine.dispatches += l.dispatches;
            mine.depth.merge(&l.depth);
            mine.max_depth = mine.max_depth.max(l.max_depth);
            mine.cost_refreshes += l.cost_refreshes;
        }
        for (backend, classes) in &other.stages {
            let mine = self.stages.entry(backend.clone()).or_default();
            for (class, st) in classes {
                let cell = mine.entry(*class).or_default();
                for i in 0..STAGE_COUNT {
                    cell.hist[i].merge(&st.hist[i]);
                    cell.spread[i].merge(&st.spread[i]);
                }
            }
        }
        for (name, ring) in &other.spans {
            self.spans.entry(name.clone()).or_default().merge(ring);
        }
    }

    /// Rename every backend/lane key to `{prefix}{name}` — how the
    /// fleet keeps per-site columns distinguishable after the fold
    /// (site 0's `fpga0` becomes `s0/fpga0`, so the merged report still
    /// shows where each site's work landed).
    pub fn prefix_lanes(&mut self, prefix: &str) {
        self.backends = std::mem::take(&mut self.backends)
            .into_iter()
            .map(|(name, b)| (format!("{prefix}{name}"), b))
            .collect();
        self.lanes = std::mem::take(&mut self.lanes)
            .into_iter()
            .map(|(name, l)| (format!("{prefix}{name}"), l))
            .collect();
        self.stages = std::mem::take(&mut self.stages)
            .into_iter()
            .map(|(name, s)| (format!("{prefix}{name}"), s))
            .collect();
        self.spans = std::mem::take(&mut self.spans)
            .into_iter()
            .map(|(name, r)| (format!("{prefix}{name}"), r))
            .collect();
    }

    pub fn report(&self) -> ServingReport {
        let lat = LatencyReport {
            mean_s: self.latency.mean(),
            p50_s: self.latency.quantile(50.0),
            p95_s: self.latency.quantile(95.0),
            p99_s: self.latency.quantile(99.0),
            p999_s: self.latency.quantile(99.9),
        };
        let wall = self.wall_s.max(1e-12);
        let mean_power = if self.wall_s > 0.0 {
            self.energy_j / self.wall_s
        } else {
            0.0
        };
        let gops = self.ops as f64 / wall / 1e9;
        let per_backend = self
            .backends
            .iter()
            .map(|(name, b)| BackendReport {
                name: name.clone(),
                batches: b.batches,
                images: b.images,
                images_per_s: b.images as f64 / wall,
                device_gops: if b.device_time_s > 0.0 {
                    b.ops as f64 / b.device_time_s / 1e9
                } else {
                    0.0
                },
                mean_device_latency_s: if b.batches > 0 {
                    b.device_time_s / b.batches as f64
                } else {
                    0.0
                },
                energy_j: b.energy_j,
                p50_s: b.latency.quantile(50.0),
                p95_s: b.latency.quantile(95.0),
                p99_s: b.latency.quantile(99.0),
                p999_s: b.latency.quantile(99.9),
                latency_cv: weighted_cv(b.per_image_dev.values()),
                deadline: b
                    .deadline
                    .iter()
                    .map(|(class, d)| ClassAttainment {
                        class: *class,
                        met: d.met,
                        late: d.late,
                    })
                    .collect(),
            })
            .collect();
        let lanes = self
            .lanes
            .iter()
            .map(|(name, l)| LaneQueueReport {
                name: name.clone(),
                dispatches: l.dispatches,
                mean_depth: l.depth.mean(),
                max_depth: l.max_depth,
                cost_refreshes: l.cost_refreshes,
            })
            .collect();
        let mut stage_breakdown = Vec::new();
        for (backend, classes) in &self.stages {
            for (class, st) in classes {
                stage_breakdown.push(StageBreakdown {
                    backend: backend.clone(),
                    class: *class,
                    count: st.hist[0].count(),
                    stages: Stage::ALL
                        .into_iter()
                        .map(|stage| {
                            let i = stage.index();
                            StageRow {
                                stage,
                                mean_s: st.hist[i].mean(),
                                p50_s: st.hist[i].quantile(50.0),
                                p99_s: st.hist[i].quantile(99.0),
                                cv: cv_of(&st.spread[i]),
                            }
                        })
                        .collect(),
                });
            }
        }
        ServingReport {
            requests: self.requests,
            images: self.images,
            rejected: self.rejected,
            shed: self.shed,
            shed_by_class: self
                .shed_by_class
                .iter()
                .map(|(c, n)| (*c, *n))
                .collect(),
            deferred: self.deferred,
            batches: self.batches,
            wall_s: self.wall_s,
            latency: lat,
            latency_drift: self.windowed.drift(),
            drift_windows: self
                .windowed
                .windows()
                .iter()
                .map(|(start_s, h)| DriftWindow {
                    start_s: *start_s,
                    count: h.count(),
                    p50_s: h.quantile(50.0),
                    p99_s: h.quantile(99.0),
                })
                .collect(),
            images_per_s: self.images as f64 / wall,
            gops,
            mean_batch: if self.batches == 0 {
                0.0
            } else {
                self.batch_images as f64 / self.batches as f64
            },
            mean_power_w: mean_power,
            gops_per_w: if mean_power > 0.0 { gops / mean_power } else { 0.0 },
            scratch_hwm_bytes: self.scratch_hwm_bytes,
            stage_breakdown,
            per_backend,
            lanes,
        }
    }
}

/// One time-sliced latency window of the drift telemetry — the shard
/// behind the scalar `latency_drift` column, exported so operators can
/// localize *when* the tail moved instead of only knowing it did.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DriftWindow {
    /// Window start, seconds since the serving window opened.
    pub start_s: f64,
    /// Requests recorded in this window.
    pub count: u64,
    pub p50_s: f64,
    pub p99_s: f64,
}

/// Latency distribution summary.  The mean is exact (tracked sum); the
/// quantiles are histogram-bucketed (2% relative error).
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct LatencyReport {
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub p99_s: f64,
    pub p999_s: f64,
}

/// Deadline attainment of one (backend, priority class) cell.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClassAttainment {
    pub class: PriorityClass,
    /// Requests whose edge-charged completion made their deadline.
    pub met: u64,
    /// Served-late requests (completed, but past the deadline).
    pub late: u64,
}

impl ClassAttainment {
    /// Attainment in `[0, 1]`; an empty cell attains vacuously.
    pub fn attainment(&self) -> f64 {
        let total = self.met + self.late;
        if total == 0 {
            1.0
        } else {
            self.met as f64 / total as f64
        }
    }
}

/// One backend lane's column in the serving report.
#[derive(Debug, Clone, PartialEq)]
pub struct BackendReport {
    /// Lane name (`fpga0`, `gpu0`, `cpu0`, …).
    pub name: String,
    pub batches: u64,
    pub images: u64,
    /// Images served by this backend per wall second.
    pub images_per_s: f64,
    /// Device arithmetic throughput (ops / device time).
    pub device_gops: f64,
    /// Mean device latency per batch, seconds.
    pub mean_device_latency_s: f64,
    pub energy_j: f64,
    /// Request latency quantiles for requests resolved by this lane.
    pub p50_s: f64,
    pub p95_s: f64,
    pub p99_s: f64,
    pub p999_s: f64,
    /// Coefficient of variation of the per-image device latency across
    /// this lane's batches — the paper's run-to-run-stability metric,
    /// live (FPGA ≈ clock jitter only, GPU ≈ DVFS + measurement noise).
    pub latency_cv: f64,
    /// Deadline attainment per priority class (empty when no
    /// deadline-bearing request resolved on this lane).
    pub deadline: Vec<ClassAttainment>,
}

/// One lifecycle stage's latency summary within a
/// [`StageBreakdown`] cell.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StageRow {
    pub stage: Stage,
    /// Exact mean stage latency (tracked sum), seconds.
    pub mean_s: f64,
    /// Bucketed quantiles (2% relative error), seconds.
    pub p50_s: f64,
    pub p99_s: f64,
    /// Coefficient of variation of the stage latency — the
    /// stage-attributed form of the paper's run-to-run stability
    /// metric (device-execute CV vs queue-wait CV).
    pub cv: f64,
}

/// Stage-attributed latency breakdown of one (backend, class) cell —
/// the flight recorder's aggregate consumer.  Additive schema section:
/// legacy reports parse with it empty.
#[derive(Debug, Clone, PartialEq)]
pub struct StageBreakdown {
    /// Lane name (fleet folds carry the site prefix, e.g. `s0/fpga0`).
    pub backend: String,
    pub class: PriorityClass,
    /// Completed requests folded into this cell.
    pub count: u64,
    /// One row per lifecycle stage, in [`Stage::ALL`] order.
    pub stages: Vec<StageRow>,
}

impl StageBreakdown {
    /// This cell's row for `stage` (`None` only on a malformed report).
    pub fn stage(&self, stage: Stage) -> Option<&StageRow> {
        self.stages.iter().find(|r| r.stage == stage)
    }
}

/// Scheduler-side telemetry for one lane.
#[derive(Debug, Clone, PartialEq)]
pub struct LaneQueueReport {
    pub name: String,
    /// Batches the scheduler dispatched to this lane.
    pub dispatches: u64,
    /// Mean queue depth observed at dispatch time.
    pub mean_depth: f64,
    /// Deepest the lane's queue got.
    pub max_depth: usize,
    /// Cost-model re-probes triggered by DVFS throttle transitions.
    pub cost_refreshes: u64,
}

/// Final serving report (printed by the `serve`/`loadtest` CLIs and the
/// edge_serving example; recorded in EXPERIMENTS.md §E9).  Serializes
/// to a versioned JSON schema ([`Self::to_json`]) so the fleet merge
/// path and CI assertions parse structs instead of scraping table text.
#[derive(Debug, Clone, PartialEq)]
pub struct ServingReport {
    pub requests: u64,
    pub images: u64,
    /// Requests turned away by admission control (overload).
    pub rejected: u64,
    /// Requests shed at intake because their deadline was already
    /// infeasible given queue depth × predicted cost.
    pub shed: u64,
    /// The shed counter split by priority class.
    pub shed_by_class: Vec<(PriorityClass, u64)>,
    /// Batches that had to wait for lane capacity (backpressure).
    pub deferred: u64,
    pub batches: u64,
    pub wall_s: f64,
    pub latency: LatencyReport,
    /// Tail drift across the retained latency time slices: worst-window
    /// p99 over best-window p99 (1.0 = steady).
    pub latency_drift: f64,
    /// The time-sliced windows behind `latency_drift`, in time order
    /// (empty when no request carried latency telemetry).  Additive
    /// schema field: absent in pre-drift v1 reports, tolerated on read.
    pub drift_windows: Vec<DriftWindow>,
    pub images_per_s: f64,
    pub gops: f64,
    pub mean_batch: f64,
    pub mean_power_w: f64,
    pub gops_per_w: f64,
    /// High-water mark of the hot-path scratch arena (bytes) as
    /// observed by the lane thread — the serving-side view of
    /// [`crate::util::scratch_hwm_bytes`].  Additive schema field:
    /// absent in pre-blocking v1 reports, defaults to 0 on read.
    pub scratch_hwm_bytes: u64,
    /// Stage-attributed latency cells, sorted by (backend, class).
    /// Additive schema field: absent in pre-trace v1 reports, parsed
    /// as empty.
    pub stage_breakdown: Vec<StageBreakdown>,
    /// Per-backend columns, sorted by lane name.
    pub per_backend: Vec<BackendReport>,
    /// Per-lane scheduler telemetry, sorted by lane name.
    pub lanes: Vec<LaneQueueReport>,
}

fn latency_from_json(v: &Json) -> Result<LatencyReport> {
    Ok(LatencyReport {
        mean_s: v.req("mean_s")?.as_f64()?,
        p50_s: v.req("p50_s")?.as_f64()?,
        p95_s: v.req("p95_s")?.as_f64()?,
        p99_s: v.req("p99_s")?.as_f64()?,
        p999_s: v.req("p999_s")?.as_f64()?,
    })
}

fn attainment_from_json(v: &Json) -> Result<ClassAttainment> {
    Ok(ClassAttainment {
        class: v.req("class")?.as_str()?.parse()?,
        met: v.req("met")?.as_u64()?,
        late: v.req("late")?.as_u64()?,
    })
}

fn backend_from_json(v: &Json) -> Result<BackendReport> {
    Ok(BackendReport {
        name: v.req("name")?.as_str()?.to_string(),
        batches: v.req("batches")?.as_u64()?,
        images: v.req("images")?.as_u64()?,
        images_per_s: v.req("images_per_s")?.as_f64()?,
        device_gops: v.req("device_gops")?.as_f64()?,
        mean_device_latency_s: v.req("mean_device_latency_s")?.as_f64()?,
        energy_j: v.req("energy_j")?.as_f64()?,
        p50_s: v.req("p50_s")?.as_f64()?,
        p95_s: v.req("p95_s")?.as_f64()?,
        p99_s: v.req("p99_s")?.as_f64()?,
        p999_s: v.req("p999_s")?.as_f64()?,
        latency_cv: v.req("latency_cv")?.as_f64()?,
        deadline: v
            .req("deadline")?
            .as_arr()?
            .iter()
            .map(attainment_from_json)
            .collect::<Result<Vec<_>>>()?,
    })
}

fn stage_row_from_json(v: &Json) -> Result<StageRow> {
    let name = v.req("stage")?.as_str()?;
    let stage = Stage::parse(name)
        .ok_or_else(|| anyhow::anyhow!("unknown lifecycle stage {name:?}"))?;
    Ok(StageRow {
        stage,
        mean_s: v.req("mean_s")?.as_f64()?,
        p50_s: v.req("p50_s")?.as_f64()?,
        p99_s: v.req("p99_s")?.as_f64()?,
        cv: v.req("cv")?.as_f64()?,
    })
}

fn stage_breakdown_from_json(v: &Json) -> Result<StageBreakdown> {
    Ok(StageBreakdown {
        backend: v.req("backend")?.as_str()?.to_string(),
        class: v.req("class")?.as_str()?.parse()?,
        count: v.req("count")?.as_u64()?,
        stages: v
            .req("stages")?
            .as_arr()?
            .iter()
            .map(stage_row_from_json)
            .collect::<Result<Vec<_>>>()?,
    })
}

fn lane_from_json(v: &Json) -> Result<LaneQueueReport> {
    Ok(LaneQueueReport {
        name: v.req("name")?.as_str()?.to_string(),
        dispatches: v.req("dispatches")?.as_u64()?,
        mean_depth: v.req("mean_depth")?.as_f64()?,
        max_depth: v.req("max_depth")?.as_usize()?,
        cost_refreshes: v.req("cost_refreshes")?.as_u64()?,
    })
}

impl ServingReport {
    /// Serialize (schema v1).  Every f64 prints shortest-roundtrip, so
    /// `from_json(to_json(r)) == r` bit-exactly — which is also what
    /// lets the fleet integration test compare a folded report against
    /// a direct aggregate by comparing their JSON strings.
    pub fn to_json(&self) -> String {
        let shed_by_class = self
            .shed_by_class
            .iter()
            .map(|(c, n)| format!("{{\"class\": \"{c}\", \"count\": {n}}}"))
            .collect::<Vec<_>>()
            .join(", ");
        let lat = &self.latency;
        let per_backend = self
            .per_backend
            .iter()
            .map(|b| {
                let deadline = b
                    .deadline
                    .iter()
                    .map(|d| {
                        format!(
                            "{{\"class\": \"{}\", \"met\": {}, \"late\": {}}}",
                            d.class, d.met, d.late
                        )
                    })
                    .collect::<Vec<_>>()
                    .join(", ");
                format!(
                    "    {{\"name\": \"{}\", \"batches\": {}, \"images\": {}, \
                     \"images_per_s\": {}, \"device_gops\": {}, \
                     \"mean_device_latency_s\": {}, \"energy_j\": {}, \
                     \"p50_s\": {}, \"p95_s\": {}, \"p99_s\": {}, \
                     \"p999_s\": {}, \"latency_cv\": {}, \"deadline\": [{}]}}",
                    escape_json(&b.name),
                    b.batches,
                    b.images,
                    b.images_per_s,
                    b.device_gops,
                    b.mean_device_latency_s,
                    b.energy_j,
                    b.p50_s,
                    b.p95_s,
                    b.p99_s,
                    b.p999_s,
                    b.latency_cv,
                    deadline,
                )
            })
            .collect::<Vec<_>>()
            .join(",\n");
        let lanes = self
            .lanes
            .iter()
            .map(|l| {
                format!(
                    "    {{\"name\": \"{}\", \"dispatches\": {}, \
                     \"mean_depth\": {}, \"max_depth\": {}, \
                     \"cost_refreshes\": {}}}",
                    escape_json(&l.name),
                    l.dispatches,
                    l.mean_depth,
                    l.max_depth,
                    l.cost_refreshes,
                )
            })
            .collect::<Vec<_>>()
            .join(",\n");
        let stage_breakdown = self
            .stage_breakdown
            .iter()
            .map(|cell| {
                let rows = cell
                    .stages
                    .iter()
                    .map(|r| {
                        format!(
                            "{{\"stage\": \"{}\", \"mean_s\": {}, \
                             \"p50_s\": {}, \"p99_s\": {}, \"cv\": {}}}",
                            r.stage.as_str(),
                            r.mean_s,
                            r.p50_s,
                            r.p99_s,
                            r.cv,
                        )
                    })
                    .collect::<Vec<_>>()
                    .join(", ");
                format!(
                    "    {{\"backend\": \"{}\", \"class\": \"{}\", \
                     \"count\": {}, \"stages\": [{}]}}",
                    escape_json(&cell.backend),
                    cell.class,
                    cell.count,
                    rows,
                )
            })
            .collect::<Vec<_>>()
            .join(",\n");
        let drift_windows = self
            .drift_windows
            .iter()
            .map(|w| {
                format!(
                    "{{\"start_s\": {}, \"count\": {}, \"p50_s\": {}, \
                     \"p99_s\": {}}}",
                    w.start_s, w.count, w.p50_s, w.p99_s,
                )
            })
            .collect::<Vec<_>>()
            .join(", ");
        format!(
            "{{\n  \"version\": {REPORT_VERSION},\n  \
             \"requests\": {},\n  \"images\": {},\n  \"rejected\": {},\n  \
             \"shed\": {},\n  \"shed_by_class\": [{}],\n  \
             \"deferred\": {},\n  \"batches\": {},\n  \"wall_s\": {},\n  \
             \"latency\": {{\"mean_s\": {}, \"p50_s\": {}, \"p95_s\": {}, \
             \"p99_s\": {}, \"p999_s\": {}}},\n  \
             \"latency_drift\": {},\n  \"drift_windows\": [{}],\n  \
             \"images_per_s\": {},\n  \
             \"gops\": {},\n  \"mean_batch\": {},\n  \"mean_power_w\": {},\n  \
             \"gops_per_w\": {},\n  \"scratch_hwm_bytes\": {},\n  \
             \"stage_breakdown\": [\n{}\n  ],\n  \
             \"per_backend\": [\n{}\n  ],\n  \
             \"lanes\": [\n{}\n  ]\n}}\n",
            self.requests,
            self.images,
            self.rejected,
            self.shed,
            shed_by_class,
            self.deferred,
            self.batches,
            self.wall_s,
            lat.mean_s,
            lat.p50_s,
            lat.p95_s,
            lat.p99_s,
            lat.p999_s,
            self.latency_drift,
            drift_windows,
            self.images_per_s,
            self.gops,
            self.mean_batch,
            self.mean_power_w,
            self.gops_per_w,
            self.scratch_hwm_bytes,
            stage_breakdown,
            per_backend,
            lanes,
        )
    }

    /// Prometheus text-exposition export (version 0.0.4): the serving
    /// counters, latency quantile summaries, per-backend columns, and
    /// the stage-attributed breakdown as labeled series.  Written by
    /// `serve --prom-out FILE`; format-pinned by a golden unit test.
    pub fn prometheus_text(&self) -> String {
        let mut out = String::new();
        let counter = |o: &mut String, name: &str, help: &str, v: String| {
            o.push_str(&format!(
                "# HELP {name} {help}\n# TYPE {name} counter\n{name} {v}\n"
            ));
        };
        counter(
            &mut out,
            "edgedcnn_requests_total",
            "Requests resolved by the coordinator.",
            self.requests.to_string(),
        );
        counter(
            &mut out,
            "edgedcnn_images_total",
            "Images served.",
            self.images.to_string(),
        );
        counter(
            &mut out,
            "edgedcnn_rejected_total",
            "Requests turned away by overload admission control.",
            self.rejected.to_string(),
        );
        counter(
            &mut out,
            "edgedcnn_shed_total",
            "Requests shed at intake (deadline infeasible).",
            self.shed.to_string(),
        );
        counter(
            &mut out,
            "edgedcnn_energy_joules_total",
            "Device energy integrated over the serving window.",
            format!("{}", self.mean_power_w * self.wall_s),
        );
        out.push_str(
            "# HELP edgedcnn_latency_seconds Request end-to-end latency.\n\
             # TYPE edgedcnn_latency_seconds summary\n",
        );
        for (q, v) in [
            ("0.5", self.latency.p50_s),
            ("0.95", self.latency.p95_s),
            ("0.99", self.latency.p99_s),
            ("0.999", self.latency.p999_s),
        ] {
            out.push_str(&format!(
                "edgedcnn_latency_seconds{{quantile=\"{q}\"}} {v}\n"
            ));
        }
        out.push_str(&format!(
            "edgedcnn_latency_seconds_count {}\n",
            self.requests
        ));
        out.push_str(
            "# HELP edgedcnn_backend_images_total Images served per backend lane.\n\
             # TYPE edgedcnn_backend_images_total counter\n",
        );
        for b in &self.per_backend {
            out.push_str(&format!(
                "edgedcnn_backend_images_total{{backend=\"{}\"}} {}\n",
                escape_json(&b.name),
                b.images
            ));
        }
        out.push_str(
            "# HELP edgedcnn_backend_latency_seconds Request latency per backend lane.\n\
             # TYPE edgedcnn_backend_latency_seconds summary\n",
        );
        for b in &self.per_backend {
            for (q, v) in [("0.5", b.p50_s), ("0.99", b.p99_s)] {
                out.push_str(&format!(
                    "edgedcnn_backend_latency_seconds{{backend=\"{}\",\
                     quantile=\"{q}\"}} {v}\n",
                    escape_json(&b.name),
                ));
            }
        }
        out.push_str(
            "# HELP edgedcnn_backend_latency_cv Per-image device latency \
             coefficient of variation per backend lane.\n\
             # TYPE edgedcnn_backend_latency_cv gauge\n",
        );
        for b in &self.per_backend {
            out.push_str(&format!(
                "edgedcnn_backend_latency_cv{{backend=\"{}\"}} {}\n",
                escape_json(&b.name),
                b.latency_cv
            ));
        }
        out.push_str(
            "# HELP edgedcnn_stage_latency_seconds Lifecycle stage latency \
             per (backend, class, stage).\n\
             # TYPE edgedcnn_stage_latency_seconds summary\n",
        );
        for cell in &self.stage_breakdown {
            for r in &cell.stages {
                for (q, v) in [("0.5", r.p50_s), ("0.99", r.p99_s)] {
                    out.push_str(&format!(
                        "edgedcnn_stage_latency_seconds{{backend=\"{}\",\
                         class=\"{}\",stage=\"{}\",quantile=\"{q}\"}} {v}\n",
                        escape_json(&cell.backend),
                        cell.class,
                        r.stage.as_str(),
                    ));
                }
            }
        }
        out.push_str(
            "# HELP edgedcnn_stage_cv Lifecycle stage latency coefficient \
             of variation per (backend, class, stage).\n\
             # TYPE edgedcnn_stage_cv gauge\n",
        );
        for cell in &self.stage_breakdown {
            for r in &cell.stages {
                out.push_str(&format!(
                    "edgedcnn_stage_cv{{backend=\"{}\",class=\"{}\",\
                     stage=\"{}\"}} {}\n",
                    escape_json(&cell.backend),
                    cell.class,
                    r.stage.as_str(),
                    r.cv
                ));
            }
        }
        out
    }

    /// CSV export of the windowed drift histogram shards — one row per
    /// retained time slice, `window_start_s,count,p50_s,p99_s`.  Always
    /// includes the header line, so the file is non-empty (and trivially
    /// assertable in CI) even for a run with no latency telemetry.
    pub fn drift_csv(&self) -> String {
        let mut out = String::from("window_start_s,count,p50_s,p99_s\n");
        for w in &self.drift_windows {
            out.push_str(&format!(
                "{},{},{},{}\n",
                w.start_s, w.count, w.p50_s, w.p99_s
            ));
        }
        out
    }

    /// Parse a schema-v1 report; refuses *future* schema versions
    /// instead of misreading them (the trace-v2 contract).
    pub fn from_json(text: &str) -> Result<ServingReport> {
        let v = parse_json(text)?;
        let version = v.req("version")?.as_u64()?;
        anyhow::ensure!(
            version <= REPORT_VERSION,
            "report schema v{version} is newer than this build \
             (v{REPORT_VERSION})"
        );
        Ok(ServingReport {
            requests: v.req("requests")?.as_u64()?,
            images: v.req("images")?.as_u64()?,
            rejected: v.req("rejected")?.as_u64()?,
            shed: v.req("shed")?.as_u64()?,
            shed_by_class: v
                .req("shed_by_class")?
                .as_arr()?
                .iter()
                .map(|e| {
                    Ok((
                        e.req("class")?.as_str()?.parse()?,
                        e.req("count")?.as_u64()?,
                    ))
                })
                .collect::<Result<Vec<_>>>()?,
            deferred: v.req("deferred")?.as_u64()?,
            batches: v.req("batches")?.as_u64()?,
            wall_s: v.req("wall_s")?.as_f64()?,
            latency: latency_from_json(v.req("latency")?)?,
            latency_drift: v.req("latency_drift")?.as_f64()?,
            // additive field: pre-drift v1 reports simply lack it
            drift_windows: match v.get("drift_windows") {
                Some(arr) => arr
                    .as_arr()?
                    .iter()
                    .map(|w| {
                        Ok(DriftWindow {
                            start_s: w.req("start_s")?.as_f64()?,
                            count: w.req("count")?.as_u64()?,
                            p50_s: w.req("p50_s")?.as_f64()?,
                            p99_s: w.req("p99_s")?.as_f64()?,
                        })
                    })
                    .collect::<Result<Vec<_>>>()?,
                None => Vec::new(),
            },
            images_per_s: v.req("images_per_s")?.as_f64()?,
            gops: v.req("gops")?.as_f64()?,
            mean_batch: v.req("mean_batch")?.as_f64()?,
            mean_power_w: v.req("mean_power_w")?.as_f64()?,
            gops_per_w: v.req("gops_per_w")?.as_f64()?,
            // additive field: pre-blocking v1 reports simply lack it
            scratch_hwm_bytes: match v.get("scratch_hwm_bytes") {
                Some(x) => x.as_u64()?,
                None => 0,
            },
            // additive field: pre-trace v1 reports simply lack it
            stage_breakdown: match v.get("stage_breakdown") {
                Some(arr) => arr
                    .as_arr()?
                    .iter()
                    .map(stage_breakdown_from_json)
                    .collect::<Result<Vec<_>>>()?,
                None => Vec::new(),
            },
            per_backend: v
                .req("per_backend")?
                .as_arr()?
                .iter()
                .map(backend_from_json)
                .collect::<Result<Vec<_>>>()?,
            lanes: v
                .req("lanes")?
                .as_arr()?
                .iter()
                .map(lane_from_json)
                .collect::<Result<Vec<_>>>()?,
        })
    }

    pub fn render(&self) -> String {
        let mut out = format!(
            "requests {:>6}   images {:>6}   batches {:>5}  (mean batch {:.2})\n\
             wall {:>8.3} s   throughput {:>8.2} img/s   {:>7.2} GOps/s\n\
             latency mean {:.2} ms  p50 {:.2} ms  p95 {:.2} ms  p99 {:.2} ms  \
             p99.9 {:.2} ms  drift {:.2}x\n\
             power {:>6.2} W   {:>6.2} GOps/s/W",
            self.requests,
            self.images,
            self.batches,
            self.mean_batch,
            self.wall_s,
            self.images_per_s,
            self.gops,
            self.latency.mean_s * 1e3,
            self.latency.p50_s * 1e3,
            self.latency.p95_s * 1e3,
            self.latency.p99_s * 1e3,
            self.latency.p999_s * 1e3,
            self.latency_drift,
            self.mean_power_w,
            self.gops_per_w,
        );
        if self.rejected > 0 {
            out.push_str(&format!("\nrejected {:>6}  (admission control)", self.rejected));
        }
        if self.shed > 0 {
            let by_class = self
                .shed_by_class
                .iter()
                .map(|(c, n)| format!("{c} {n}"))
                .collect::<Vec<_>>()
                .join("  ");
            out.push_str(&format!(
                "\nshed     {:>6}  (deadline infeasible at intake: {by_class})",
                self.shed
            ));
        }
        if self.deferred > 0 {
            out.push_str(&format!("\ndeferred {:>6}  (backpressure)", self.deferred));
        }
        // its own line (never appended to a backend row): the backend
        // lines below must keep img/s as their trailing field
        if self.scratch_hwm_bytes > 0 {
            out.push_str(&format!(
                "\nscratch  {:>6} B  (hot-path arena high-water, per lane thread)",
                self.scratch_hwm_bytes
            ));
        }
        // per-backend columns keep img/s as the trailing field (the CI
        // smoke awk keys off it)
        for b in &self.per_backend {
            out.push_str(&format!(
                "\nbackend {:<6} batches {:>5}   images {:>6}   device {:>7.2} ms/batch   \
                 {:>7.2} GOps/s   energy {:>8.3} J   p50 {:.2} p99 {:.2} ms   \
                 cv {:.2}%   {:>8.2} img/s",
                b.name,
                b.batches,
                b.images,
                b.mean_device_latency_s * 1e3,
                b.device_gops,
                b.energy_j,
                b.p50_s * 1e3,
                b.p99_s * 1e3,
                b.latency_cv * 100.0,
                b.images_per_s,
            ));
        }
        // per-(backend, class) deadline attainment on dedicated lines
        // (the backend lines above keep img/s as their trailing field —
        // the CI smoke awk keys off it)
        for b in &self.per_backend {
            for d in &b.deadline {
                out.push_str(&format!(
                    "\ndeadline {:<6} class {:<6} met {:>5} late {:>5} att {:.1}%",
                    b.name,
                    d.class,
                    d.met,
                    d.late,
                    d.attainment() * 100.0,
                ));
            }
        }
        // stage-attributed variation: the queue-wait vs device-execute
        // CV split that makes the paper's stability verdict explainable
        for cell in &self.stage_breakdown {
            let (Some(q), Some(d)) = (
                cell.stage(Stage::QueueWait),
                cell.stage(Stage::DeviceExecute),
            ) else {
                continue;
            };
            out.push_str(&format!(
                "\nstages  {:<6} class {:<6} n {:>5}   queue p50 {:.2} ms cv {:.1}%   \
                 device p50 {:.2} ms cv {:.1}%",
                cell.backend,
                cell.class,
                cell.count,
                q.p50_s * 1e3,
                q.cv * 100.0,
                d.p50_s * 1e3,
                d.cv * 100.0,
            ));
        }
        for l in &self.lanes {
            out.push_str(&format!(
                "\nlane    {:<6} dispatches {:>4}   queue depth mean {:.2} max {}{}",
                l.name,
                l.dispatches,
                l.mean_depth,
                l.max_depth,
                if l.cost_refreshes > 0 {
                    format!("   cost refreshes {}", l.cost_refreshes)
                } else {
                    String::new()
                },
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_aggregates() {
        let mut m = MetricsRegistry::new();
        for i in 0..10 {
            m.record_request(0.001 * (i + 1) as f64, 2);
        }
        m.record_batch(0.004, 4, 1_000_000_000);
        m.record_batch(0.006, 4, 1_000_000_000);
        m.record_energy(5.0);
        m.set_wall(1.0);
        let r = m.report();
        assert_eq!(r.requests, 10);
        assert_eq!(r.images, 20);
        assert_eq!(r.batches, 2);
        assert!((r.mean_batch - 4.0).abs() < 1e-12);
        assert!((r.images_per_s - 20.0).abs() < 1e-9);
        assert!((r.gops - 2.0).abs() < 1e-9);
        assert!((r.mean_power_w - 5.0).abs() < 1e-9);
        assert!((r.gops_per_w - 0.4).abs() < 1e-9);
        assert!(r.latency.p99_s >= r.latency.p50_s);
        assert!(r.latency.p999_s >= r.latency.p99_s);
        // the mean is exact; the quantiles are bucketed to 2%
        assert!((r.latency.mean_s - 0.0055).abs() < 1e-12);
        assert!((r.latency.p50_s / 0.005 - 1.0).abs() <= 0.02 + 1e-9);
    }

    #[test]
    fn empty_registry_reports_zeroes() {
        let r = MetricsRegistry::new().report();
        assert_eq!(r.requests, 0);
        assert_eq!(r.gops_per_w, 0.0);
        assert_eq!(r.latency.p99_s, 0.0);
    }

    #[test]
    fn render_contains_key_fields() {
        let mut m = MetricsRegistry::new();
        m.record_request(0.002, 1);
        m.set_wall(0.5);
        let s = m.report().render();
        assert!(s.contains("GOps/s/W"));
        assert!(s.contains("p99"));
        assert!(s.contains("p99.9"));
    }

    #[test]
    fn per_backend_columns_aggregate_and_render() {
        let mut m = MetricsRegistry::new();
        m.record_backend_batch("fpga0", "mnist", 8, 2_000_000_000, 0.5, 1.25);
        m.record_backend_batch("fpga0", "mnist", 8, 2_000_000_000, 0.5, 1.25);
        m.record_backend_batch("gpu0", "mnist", 4, 1_000_000_000, 0.1, 1.1);
        m.record_backend_request("fpga0", 0.6);
        m.record_backend_request("fpga0", 0.7);
        m.record_backend_request("gpu0", 0.2);
        m.set_wall(2.0);
        let r = m.report();
        assert_eq!(r.per_backend.len(), 2);
        let fpga = &r.per_backend[0];
        assert_eq!(fpga.name, "fpga0", "BTreeMap order is deterministic");
        assert_eq!(fpga.batches, 2);
        assert_eq!(fpga.images, 16);
        assert!((fpga.images_per_s - 8.0).abs() < 1e-9);
        assert!((fpga.device_gops - 4.0).abs() < 1e-9);
        assert!((fpga.mean_device_latency_s - 0.5).abs() < 1e-9);
        assert!((fpga.energy_j - 2.5).abs() < 1e-9);
        // identical per-image device times ⇒ zero variation
        assert_eq!(fpga.latency_cv, 0.0);
        assert!(fpga.p99_s >= fpga.p50_s && fpga.p50_s > 0.0);
        let s = r.render();
        assert!(s.contains("backend fpga0"), "{s}");
        assert!(s.contains("backend gpu0"), "{s}");
        assert!(s.contains("cv "), "{s}");
        assert!(!s.contains("rejected"), "no admission line when zero");
        // img/s stays the trailing field of a backend line (CI contract)
        let line = s.lines().find(|l| l.starts_with("backend fpga0")).unwrap();
        assert!(line.trim_end().ends_with("img/s"), "{line}");
    }

    #[test]
    fn device_variation_feeds_the_cv_column() {
        let mut m = MetricsRegistry::new();
        // steady lane serving two networks at *different* speeds: the
        // per-network split must keep the mix out of the CV
        for _ in 0..10 {
            m.record_backend_batch("fpga0", "mnist", 4, 1, 0.004, 0.1);
            m.record_backend_batch("fpga0", "mnist.q", 4, 1, 0.002, 0.1);
        }
        // drifting lane: per-image device time rises (thermal throttle)
        for i in 0..10 {
            let t = 0.004 * (1.0 + 0.1 * i as f64);
            m.record_backend_batch("gpu0", "mnist", 4, 1, t, 0.1);
        }
        m.set_wall(1.0);
        let r = m.report();
        let fpga = r.per_backend.iter().find(|b| b.name == "fpga0").unwrap();
        let gpu = r.per_backend.iter().find(|b| b.name == "gpu0").unwrap();
        assert_eq!(
            fpga.latency_cv, 0.0,
            "two constant-speed networks on one lane must not read as jitter"
        );
        assert!(gpu.latency_cv > 0.1, "cv={}", gpu.latency_cv);
    }

    #[test]
    fn deadline_and_shed_columns_aggregate() {
        let mut m = MetricsRegistry::new();
        m.record_backend_deadline("fpga0", PriorityClass::Normal, true);
        m.record_backend_deadline("fpga0", PriorityClass::Normal, true);
        m.record_backend_deadline("fpga0", PriorityClass::Normal, false);
        m.record_backend_deadline("fpga0", PriorityClass::Low, true);
        m.record_shed(PriorityClass::Low);
        m.set_wall(1.0);
        let r = m.report();
        assert_eq!(r.shed, 1);
        assert_eq!(r.shed_by_class, vec![(PriorityClass::Low, 1)]);
        let fpga = r.per_backend.iter().find(|b| b.name == "fpga0").unwrap();
        assert_eq!(fpga.deadline.len(), 2, "one row per class");
        let normal = fpga
            .deadline
            .iter()
            .find(|d| d.class == PriorityClass::Normal)
            .unwrap();
        assert_eq!((normal.met, normal.late), (2, 1));
        assert!((normal.attainment() - 2.0 / 3.0).abs() < 1e-12);
        let low = fpga
            .deadline
            .iter()
            .find(|d| d.class == PriorityClass::Low)
            .unwrap();
        assert_eq!((low.met, low.late), (1, 0));
        assert_eq!(low.attainment(), 1.0);
        let s = r.render();
        assert!(s.contains("shed"), "{s}");
        assert!(s.contains("deadline fpga0"), "{s}");
        assert!(s.contains("att 66.7%"), "{s}");
        // a backend line still ends in img/s (CI contract) even with
        // deadline rows present
        m.record_backend_batch("fpga0", "mnist", 4, 1, 0.004, 0.1);
        let s = m.report().render();
        let line = s.lines().find(|l| l.starts_with("backend fpga0")).unwrap();
        assert!(line.trim_end().ends_with("img/s"), "{line}");
    }

    #[test]
    fn windowed_drift_flags_a_tail_burst() {
        let mut m = MetricsRegistry::new();
        for i in 0..100 {
            m.record_request_at(i as f64 * 0.01, 0.002, 1);
        }
        m.set_wall(1.0);
        let steady = m.report().latency_drift;
        assert!((steady - 1.0).abs() < 1e-9, "steady run: drift {steady}");
        for _ in 0..20 {
            m.record_request_at(2.0, 0.100, 1);
        }
        let burst = m.report().latency_drift;
        assert!(burst > 5.0, "a confined tail burst must drift: {burst}");
        assert!(m.report().render().contains("drift"));
    }

    #[test]
    fn rejected_and_deferred_are_reported() {
        let mut m = MetricsRegistry::new();
        m.record_rejected();
        m.record_rejected();
        m.record_deferred();
        m.set_wall(1.0);
        let r = m.report();
        assert_eq!(r.rejected, 2);
        assert_eq!(r.deferred, 1);
        let s = r.render();
        assert!(s.contains("rejected"));
        assert!(s.contains("deferred"));
    }

    /// A registry shard exercising every mergeable field, derived
    /// deterministically from `site`.
    fn shard(site: u64) -> MetricsRegistry {
        let mut m = MetricsRegistry::new();
        for i in 0..(8 + site * 3) {
            let at = i as f64 * 0.05 + site as f64 * 0.2;
            m.record_request_at(at, 0.001 * (site + 1) as f64 + 1e-4 * i as f64, 2);
        }
        m.record_batch(0.004, 4, 1_000_000 * (site + 1));
        m.record_energy(0.5 * (site + 1) as f64);
        m.record_backend_batch("fpga0", "mnist", 4, 1_000_000, 0.004, 0.1);
        m.record_backend_batch("gpu0", "mnist", 2, 500_000, 0.001 * (site + 1) as f64, 0.2);
        m.record_backend_request("fpga0", 0.002 + 1e-4 * site as f64);
        m.record_backend_deadline("fpga0", PriorityClass::Normal, site != 1);
        m.record_backend_deadline("gpu0", PriorityClass::Low, true);
        if site == 0 {
            m.record_rejected();
            m.record_shed(PriorityClass::Low);
        }
        m.record_deferred();
        m.record_scratch_hwm(4096 * (site as usize + 1));
        m.record_lane_dispatch("fpga0", 1 + site as usize);
        m.record_cost_refresh("gpu0");
        // identical stage spans on every site: the stage Welfords merge
        // with zero Chan deltas, so the folded CV stays bit-exact under
        // any association order (the fold test compares JSON strings)
        m.record_stages(
            "fpga0",
            PriorityClass::Normal,
            &[0.001, 0.002, 0.004, 0.008, 0.016, 0.032, 0.064],
        );
        m.set_wall(1.0 + 0.1 * site as f64);
        m
    }

    #[test]
    fn merge_is_associative_across_three_shards_and_equals_direct() {
        let [a, b, c] = [shard(0), shard(1), shard(2)];
        // fold(fold(a, b), c)
        let mut left = a.clone();
        left.merge_from(&b);
        left.merge_from(&c);
        // fold(a, fold(b, c))
        let mut bc = b.clone();
        bc.merge_from(&c);
        let mut right = a.clone();
        right.merge_from(&bc);
        // direct aggregate: the same shards folded into a fresh
        // registry in the same left-to-right order (fixed f64 summation
        // order ⇒ bit-identical sums)
        let mut direct = MetricsRegistry::new();
        direct.merge_from(&a);
        direct.merge_from(&b);
        direct.merge_from(&c);
        let l = left.report().to_json();
        let r = right.report().to_json();
        let d = direct.report().to_json();
        assert_eq!(l, d, "fold(fold(a,b),c) == direct, bit-identical");
        assert_eq!(l, r, "fold(a,fold(b,c)) == fold(fold(a,b),c)");
        // and the integer/extremes side of the report is what the three
        // shards say it should be
        let rep = left.report();
        assert_eq!(rep.requests, 8 + 11 + 14);
        assert_eq!(rep.images, 2 * (8 + 11 + 14));
        assert_eq!(rep.rejected, 1);
        assert_eq!(rep.shed, 1);
        assert_eq!(rep.deferred, 3);
        assert!((rep.wall_s - 1.2).abs() < 1e-12, "fleet wall = max site wall");
        assert_eq!(
            rep.scratch_hwm_bytes, 12288,
            "fleet scratch HWM = max site HWM, not the sum"
        );
        let fpga = rep.per_backend.iter().find(|x| x.name == "fpga0").unwrap();
        assert_eq!(fpga.batches, 3);
        let normal = fpga
            .deadline
            .iter()
            .find(|x| x.class == PriorityClass::Normal)
            .unwrap();
        assert_eq!((normal.met, normal.late), (2, 1));
        let lane = rep.lanes.iter().find(|x| x.name == "fpga0").unwrap();
        assert_eq!(lane.max_depth, 3);
        assert!((lane.mean_depth - 2.0).abs() < 1e-12);
    }

    #[test]
    fn prefix_lanes_keeps_per_site_columns_distinguishable() {
        let mut a = shard(0);
        a.prefix_lanes("s0/");
        let mut b = shard(1);
        b.prefix_lanes("s1/");
        a.merge_from(&b);
        let rep = a.report();
        let names: Vec<&str> =
            rep.per_backend.iter().map(|x| x.name.as_str()).collect();
        assert_eq!(names, ["s0/fpga0", "s0/gpu0", "s1/fpga0", "s1/gpu0"]);
        assert!(rep.lanes.iter().all(|l| l.name.starts_with("s0/")
            || l.name.starts_with("s1/")));
        // prefixed shards no longer collide: each keeps its own counts
        let s0 = rep.per_backend.iter().find(|x| x.name == "s0/fpga0").unwrap();
        assert_eq!(s0.batches, 1);
    }

    #[test]
    fn report_json_roundtrips_bit_exactly_and_refuses_future_versions() {
        let mut m = shard(0);
        m.merge_from(&shard(1));
        let rep = m.report();
        let json = rep.to_json();
        let back = ServingReport::from_json(&json).unwrap();
        assert_eq!(back, rep, "schema v1 roundtrip");
        assert_eq!(back.to_json(), json, "re-serialization is stable");
        // empty report roundtrips too (empty arrays, zeroed floats)
        let empty = MetricsRegistry::new().report();
        assert_eq!(ServingReport::from_json(&empty.to_json()).unwrap(), empty);
        // a future schema is refused instead of misread
        let v9 = json.replacen("\"version\": 1", "\"version\": 9", 1);
        let err = ServingReport::from_json(&v9).unwrap_err().to_string();
        assert!(err.contains("newer than this build"), "{err}");
        assert!(ServingReport::from_json("{}").is_err());
    }

    #[test]
    fn scratch_hwm_is_a_max_monoid_and_defaults_on_old_reports() {
        let mut m = MetricsRegistry::new();
        m.record_scratch_hwm(9000);
        m.record_scratch_hwm(4000);
        m.set_wall(1.0);
        let r = m.report();
        assert_eq!(r.scratch_hwm_bytes, 9000, "HWM keeps the max");
        let s = r.render();
        assert!(s.contains("scratch"), "{s}");
        assert!(s.contains("9000 B"), "{s}");
        // zero HWM (no hot-path telemetry) stays off the report text
        assert!(!MetricsRegistry::new().report().render().contains("scratch"));
        // JSON roundtrip carries the column; a report written before
        // the field existed parses with the 0 default
        let json = r.to_json();
        assert_eq!(
            ServingReport::from_json(&json).unwrap().scratch_hwm_bytes,
            9000
        );
        let legacy = json.replacen("  \"scratch_hwm_bytes\": 9000,\n", "", 1);
        assert!(!legacy.contains("scratch_hwm_bytes"));
        assert_eq!(
            ServingReport::from_json(&legacy).unwrap().scratch_hwm_bytes,
            0
        );
    }

    #[test]
    fn stage_breakdown_separates_device_cv_from_queue_cv() {
        let mut m = MetricsRegistry::new();
        // fpga0: both stages steady; gpu0: steady queue, jittery device
        for i in 0..8 {
            let mut spans = [0.001; STAGE_COUNT];
            spans[Stage::QueueWait.index()] = 0.004;
            spans[Stage::DeviceExecute.index()] = 0.002;
            m.record_stages("fpga0", PriorityClass::Normal, &spans);
            spans[Stage::DeviceExecute.index()] =
                0.002 * (1.0 + 0.2 * i as f64);
            m.record_stages("gpu0", PriorityClass::Normal, &spans);
        }
        m.set_wall(1.0);
        let r = m.report();
        assert_eq!(r.stage_breakdown.len(), 2);
        let fpga = &r.stage_breakdown[0];
        assert_eq!(fpga.backend, "fpga0");
        assert_eq!(fpga.class, PriorityClass::Normal);
        assert_eq!(fpga.count, 8);
        assert_eq!(fpga.stages.len(), STAGE_COUNT);
        let dev = fpga.stage(Stage::DeviceExecute).unwrap();
        assert_eq!(dev.cv, 0.0, "steady device must read zero CV");
        assert!((dev.mean_s - 0.002).abs() < 1e-15);
        let gpu = &r.stage_breakdown[1];
        let gpu_dev = gpu.stage(Stage::DeviceExecute).unwrap();
        assert!(gpu_dev.cv > 0.2, "device jitter must surface: {}", gpu_dev.cv);
        let gpu_q = gpu.stage(Stage::QueueWait).unwrap();
        assert_eq!(gpu_q.cv, 0.0, "steady queue wait must stay steady");
        // JSON roundtrip carries the section bit-exactly
        let back = ServingReport::from_json(&r.to_json()).unwrap();
        assert_eq!(back, r);
        // render shows the split
        let s = r.render();
        assert!(s.contains("stages  fpga0"), "{s}");
        assert!(s.contains("device p50"), "{s}");
    }

    #[test]
    fn stage_breakdown_is_schema_additive() {
        let empty = MetricsRegistry::new().report();
        let json = empty.to_json();
        let legacy = json.replacen("  \"stage_breakdown\": [\n\n  ],\n", "", 1);
        assert!(
            !legacy.contains("stage_breakdown"),
            "the section must strip cleanly: {legacy}"
        );
        let parsed = ServingReport::from_json(&legacy).unwrap();
        assert!(parsed.stage_breakdown.is_empty(), "legacy parses as empty");
    }

    /// A fully-stamped span record for ring tests.
    fn stamped(id: u64) -> SpanRecord {
        use std::time::Duration;
        let epoch = Instant::now();
        let clock = crate::telemetry::RunClock::at(epoch);
        let mut st = crate::telemetry::StageStamps::default();
        let t = |k: u64| epoch + Duration::from_millis(k);
        st.on_ingest(&clock, t(0), t(1), id);
        st.on_admit(&clock, t(2));
        st.on_cut(&clock, t(3));
        st.on_dispatch(&clock, t(4));
        st.on_exec_start(&clock, t(5));
        st.on_exec_end(&clock, t(6));
        st.on_reply(&clock, t(7));
        SpanRecord {
            id,
            seed: id,
            class: PriorityClass::Normal,
            n_images: 1,
            stamps: st,
        }
    }

    #[test]
    fn span_rings_merge_and_take_lane_prefixes() {
        let mut a = MetricsRegistry::new();
        a.record_span("fpga0", stamped(1));
        let mut b = MetricsRegistry::new();
        b.record_span("fpga0", stamped(2));
        a.prefix_lanes("s0/");
        b.prefix_lanes("s1/");
        a.merge_from(&b);
        let lanes: Vec<&str> = a.span_lanes().map(|(n, _)| n).collect();
        assert_eq!(lanes, ["s0/fpga0", "s1/fpga0"]);
        let total: usize = a.span_lanes().map(|(_, r)| r.len()).sum();
        assert_eq!(total, 2);
        // the rings stay out of the report JSON — the fold-bit-identity
        // contract covers the report, the trace exporter drains rings
        assert!(!a.report().to_json().contains("\"spans\""));
    }

    #[test]
    fn prometheus_text_pins_the_exposition_format() {
        let mut m = MetricsRegistry::new();
        m.record_request(0.002, 2);
        m.record_backend_batch("fpga0", "mnist", 2, 1_000, 0.001, 0.5);
        m.record_backend_request("fpga0", 0.002);
        m.record_stages(
            "fpga0",
            PriorityClass::Normal,
            &[0.001; STAGE_COUNT],
        );
        m.set_wall(2.0);
        let text = m.report().prometheus_text();
        for needle in [
            "# TYPE edgedcnn_requests_total counter",
            "edgedcnn_requests_total 1",
            "edgedcnn_images_total 2",
            "edgedcnn_latency_seconds_count 1",
            "edgedcnn_latency_seconds{quantile=\"0.5\"}",
            "edgedcnn_backend_images_total{backend=\"fpga0\"} 2",
            "edgedcnn_backend_latency_cv{backend=\"fpga0\"}",
            "edgedcnn_stage_latency_seconds{backend=\"fpga0\",\
             class=\"normal\",stage=\"queue_wait\",quantile=\"0.99\"}",
            "edgedcnn_stage_cv{backend=\"fpga0\",class=\"normal\",\
             stage=\"device_execute\"} 0",
        ] {
            assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
        }
        // exposition skeleton: every line is a comment or `name value`
        for line in text.lines() {
            assert!(
                line.starts_with('#') || line.split(' ').count() == 2,
                "malformed exposition line: {line:?}"
            );
        }
    }

    #[test]
    fn lane_queue_telemetry_aggregates() {
        let mut m = MetricsRegistry::new();
        m.record_lane_dispatch("fpga0", 1);
        m.record_lane_dispatch("fpga0", 3);
        m.record_lane_dispatch("gpu0", 1);
        m.record_cost_refresh("gpu0");
        m.set_wall(1.0);
        let r = m.report();
        assert_eq!(r.lanes.len(), 2);
        let fpga = &r.lanes[0];
        assert_eq!(fpga.name, "fpga0");
        assert_eq!(fpga.dispatches, 2);
        assert_eq!(fpga.max_depth, 3);
        assert!((fpga.mean_depth - 2.0).abs() < 1e-12);
        let gpu = &r.lanes[1];
        assert_eq!(gpu.cost_refreshes, 1);
        let s = r.render();
        assert!(s.contains("lane    fpga0"), "{s}");
        assert!(s.contains("cost refreshes 1"), "{s}");
    }
}
