//! Serving metrics — latency distribution, throughput, arithmetic
//! throughput, and the energy integration that yields the GOps/s/W
//! headline for the end-to-end example.

use crate::stats::{percentile, Summary};

/// Accumulates per-request and per-batch telemetry during a serving run.
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    latencies_s: Vec<f64>,
    execute_s: Vec<f64>,
    batch_sizes: Vec<usize>,
    images: u64,
    requests: u64,
    ops: u64,
    energy_j: f64,
    wall_s: f64,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_request(&mut self, latency_s: f64, n_images: usize) {
        self.latencies_s.push(latency_s);
        self.requests += 1;
        self.images += n_images as u64;
    }

    pub fn record_batch(&mut self, execute_s: f64, batch: usize, ops: u64) {
        self.execute_s.push(execute_s);
        self.batch_sizes.push(batch);
        self.ops += ops;
    }

    pub fn record_energy(&mut self, joules: f64) {
        self.energy_j += joules;
    }

    pub fn set_wall(&mut self, wall_s: f64) {
        self.wall_s = wall_s;
    }

    pub fn requests(&self) -> u64 {
        self.requests
    }

    pub fn report(&self) -> ServingReport {
        let lat = if self.latencies_s.is_empty() {
            LatencyReport::default()
        } else {
            LatencyReport {
                mean_s: Summary::of(&self.latencies_s).mean,
                p50_s: percentile(&self.latencies_s, 50.0),
                p95_s: percentile(&self.latencies_s, 95.0),
                p99_s: percentile(&self.latencies_s, 99.0),
            }
        };
        let wall = self.wall_s.max(1e-12);
        let mean_power = if self.wall_s > 0.0 {
            self.energy_j / self.wall_s
        } else {
            0.0
        };
        let gops = self.ops as f64 / wall / 1e9;
        ServingReport {
            requests: self.requests,
            images: self.images,
            batches: self.execute_s.len() as u64,
            wall_s: self.wall_s,
            latency: lat,
            images_per_s: self.images as f64 / wall,
            gops,
            mean_batch: if self.batch_sizes.is_empty() {
                0.0
            } else {
                self.batch_sizes.iter().sum::<usize>() as f64
                    / self.batch_sizes.len() as f64
            },
            mean_power_w: mean_power,
            gops_per_w: if mean_power > 0.0 { gops / mean_power } else { 0.0 },
        }
    }
}

/// Latency distribution summary.
#[derive(Debug, Default, Clone, Copy)]
pub struct LatencyReport {
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub p99_s: f64,
}

/// Final serving report (printed by the `serve` CLI and the edge_serving
/// example; recorded in EXPERIMENTS.md §E9).
#[derive(Debug, Clone, Copy)]
pub struct ServingReport {
    pub requests: u64,
    pub images: u64,
    pub batches: u64,
    pub wall_s: f64,
    pub latency: LatencyReport,
    pub images_per_s: f64,
    pub gops: f64,
    pub mean_batch: f64,
    pub mean_power_w: f64,
    pub gops_per_w: f64,
}

impl ServingReport {
    pub fn render(&self) -> String {
        format!(
            "requests {:>6}   images {:>6}   batches {:>5}  (mean batch {:.2})\n\
             wall {:>8.3} s   throughput {:>8.2} img/s   {:>7.2} GOps/s\n\
             latency mean {:.2} ms  p50 {:.2} ms  p95 {:.2} ms  p99 {:.2} ms\n\
             power {:>6.2} W   {:>6.2} GOps/s/W",
            self.requests,
            self.images,
            self.batches,
            self.mean_batch,
            self.wall_s,
            self.images_per_s,
            self.gops,
            self.latency.mean_s * 1e3,
            self.latency.p50_s * 1e3,
            self.latency.p95_s * 1e3,
            self.latency.p99_s * 1e3,
            self.mean_power_w,
            self.gops_per_w,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_aggregates() {
        let mut m = MetricsRegistry::new();
        for i in 0..10 {
            m.record_request(0.001 * (i + 1) as f64, 2);
        }
        m.record_batch(0.004, 4, 1_000_000_000);
        m.record_batch(0.006, 4, 1_000_000_000);
        m.record_energy(5.0);
        m.set_wall(1.0);
        let r = m.report();
        assert_eq!(r.requests, 10);
        assert_eq!(r.images, 20);
        assert_eq!(r.batches, 2);
        assert!((r.images_per_s - 20.0).abs() < 1e-9);
        assert!((r.gops - 2.0).abs() < 1e-9);
        assert!((r.mean_power_w - 5.0).abs() < 1e-9);
        assert!((r.gops_per_w - 0.4).abs() < 1e-9);
        assert!(r.latency.p99_s >= r.latency.p50_s);
    }

    #[test]
    fn empty_registry_reports_zeroes() {
        let r = MetricsRegistry::new().report();
        assert_eq!(r.requests, 0);
        assert_eq!(r.gops_per_w, 0.0);
    }

    #[test]
    fn render_contains_key_fields() {
        let mut m = MetricsRegistry::new();
        m.record_request(0.002, 1);
        m.set_wall(0.5);
        let s = m.report().render();
        assert!(s.contains("GOps/s/W"));
        assert!(s.contains("p99"));
    }
}
