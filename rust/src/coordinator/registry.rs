//! Backend registry — the capability map between logical networks
//! (including `.q` quantized twins) and the executor lanes that can
//! serve them.  Built once at coordinator startup and consulted by the
//! scheduler on every routing decision; an unservable network (e.g. a
//! fixed-point twin in a GPU-only pool) is a *startup* error, never a
//! request-time surprise.

use crate::backend::Capabilities;
use crate::config::{DeviceKind, Precision};
use anyhow::Result;
use std::collections::HashMap;

/// One executor lane as the scheduler sees it (the live [`Backend`]
/// object lives on the lane's thread; this is its static description).
///
/// [`Backend`]: crate::backend::Backend
#[derive(Debug, Clone)]
pub struct LaneInfo {
    /// Unique lane name (`fpga0`, `cpu1`, …) — also the backend name.
    pub name: String,
    pub kind: DeviceKind,
    pub caps: Capabilities,
}

/// The pool's capability map: lanes plus, per logical network, the
/// lanes capable of serving it.
#[derive(Debug, Clone)]
pub struct BackendRegistry {
    lanes: Vec<LaneInfo>,
    routes: HashMap<String, Vec<usize>>,
}

impl BackendRegistry {
    /// Build the registry for a lane list and the logical networks
    /// (name, served precision) the coordinator will preload.  Errors
    /// if any network has no capable lane.
    pub fn build(
        kinds: &[DeviceKind],
        networks: &[(String, Precision)],
    ) -> Result<Self> {
        let mut per_kind: HashMap<DeviceKind, usize> = HashMap::new();
        let lanes: Vec<LaneInfo> = kinds
            .iter()
            .map(|&kind| {
                let i = per_kind.entry(kind).or_insert(0);
                let name = format!("{kind}{i}");
                *i += 1;
                LaneInfo {
                    name,
                    kind,
                    caps: Capabilities::of_kind(kind),
                }
            })
            .collect();
        let mut routes = HashMap::new();
        for (name, precision) in networks {
            let capable: Vec<usize> = lanes
                .iter()
                .enumerate()
                .filter(|(_, l)| l.caps.supports(*precision))
                .map(|(i, _)| i)
                .collect();
            anyhow::ensure!(
                !capable.is_empty(),
                "network {name:?} (precision {precision}) has no capable \
                 backend in pool [{}]",
                lanes
                    .iter()
                    .map(|l| l.name.as_str())
                    .collect::<Vec<_>>()
                    .join(",")
            );
            routes.insert(name.clone(), capable);
        }
        Ok(BackendRegistry { lanes, routes })
    }

    pub fn lanes(&self) -> &[LaneInfo] {
        &self.lanes
    }

    /// Lanes capable of serving `network` (empty slice if unknown).
    pub fn capable(&self, network: &str) -> &[usize] {
        self.routes.get(network).map(Vec::as_slice).unwrap_or(&[])
    }

    /// The logical networks lane `idx` must preload (every network it
    /// could be routed).
    pub fn networks_for_lane(&self, idx: usize) -> Vec<String> {
        let mut names: Vec<String> = self
            .routes
            .iter()
            .filter(|(_, lanes)| lanes.contains(&idx))
            .map(|(n, _)| n.clone())
            .collect();
        names.sort(); // deterministic load order
        names
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::QFormat;

    fn q88() -> Precision {
        Precision::Fixed(QFormat::new(16, 8))
    }

    #[test]
    fn quant_twins_route_around_the_gpu() {
        let kinds = [DeviceKind::Fpga, DeviceKind::Gpu, DeviceKind::Cpu];
        let nets = [
            ("mnist".to_string(), Precision::F32),
            ("mnist.q".to_string(), q88()),
        ];
        let r = BackendRegistry::build(&kinds, &nets).unwrap();
        assert_eq!(r.capable("mnist"), &[0, 1, 2]);
        assert_eq!(r.capable("mnist.q"), &[0, 2], "gpu lane excluded");
        assert_eq!(r.capable("unknown"), &[] as &[usize]);
        assert_eq!(r.networks_for_lane(1), vec!["mnist".to_string()]);
        assert_eq!(
            r.networks_for_lane(0),
            vec!["mnist".to_string(), "mnist.q".to_string()]
        );
    }

    #[test]
    fn unservable_network_is_a_startup_error() {
        let kinds = [DeviceKind::Gpu];
        let nets = [("mnist.q".to_string(), q88())];
        let err = BackendRegistry::build(&kinds, &nets).unwrap_err();
        assert!(err.to_string().contains("no capable backend"), "{err}");
    }

    #[test]
    fn duplicate_kinds_get_distinct_names() {
        let kinds = [DeviceKind::Cpu, DeviceKind::Cpu, DeviceKind::Fpga];
        let r = BackendRegistry::build(&kinds, &[]).unwrap();
        let names: Vec<&str> =
            r.lanes().iter().map(|l| l.name.as_str()).collect();
        assert_eq!(names, vec!["cpu0", "cpu1", "fpga0"]);
    }
}
