//! The coordinator proper: a **leader thread** (request intake + dynamic
//! batching + dispatch) and a **device-executor thread** (PJRT numerics +
//! FPGA/GPU edge-timing annotations + power integration), joined by
//! channels — the same split a vLLM-style router runs, implemented on
//! std threads (the offline build environment ships no async runtime;
//! see DESIGN.md §Offline-environment).

use super::batcher::{Batch, BatcherConfig, DynamicBatcher};
use super::metrics::{MetricsRegistry, ServingReport};
use super::request::{InferenceRequest, InferenceResponse};
use crate::artifacts::ArtifactDir;
use crate::config::{network_by_name, NetworkCfg, JETSON_TX1, PYNQ_Z2};
use crate::fpga::{simulate_network, SimOpts};
use crate::gpu::{expected_gpu_network_time, ThermalThrottle};
use crate::runtime::{GeneratorExecutable, Runtime};
use crate::tensor::Tensor;
use crate::util::Rng;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// Coordinator construction options.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub artifacts_dir: std::path::PathBuf,
    /// Networks to preload (executables compile at startup, never on the
    /// request path).
    pub networks: Vec<String>,
    pub batcher: BatcherConfig,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            artifacts_dir: "artifacts".into(),
            networks: vec!["mnist".to_string()],
            batcher: BatcherConfig::default(),
        }
    }
}

/// A synthetic open-loop workload for [`Coordinator::serve_workload`].
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    pub network: String,
    pub requests: usize,
    pub images_per_request: usize,
    /// Mean inter-arrival gap (uniform ±50% jitter applied).
    pub interarrival: Duration,
    pub seed: u64,
}

enum LeaderCmd {
    Submit(InferenceRequest, mpsc::Sender<InferenceResponse>),
    Shutdown,
}

enum DeviceCmd {
    Execute {
        batch: Batch,
        reply: mpsc::Sender<Result<ExecutedBatch>>,
    },
    Shutdown,
}

struct ExecutedBatch {
    responses: Vec<InferenceResponse>,
    execute_s: f64,
    ops: u64,
    energy_j: f64,
}

/// Per-network state owned by the device thread.
struct NetState {
    cfg: NetworkCfg,
    /// Executables keyed by batch bucket.
    executables: HashMap<usize, GeneratorExecutable>,
    buckets: Vec<usize>,
    weights: Vec<(Tensor, Vec<f32>)>,
    /// Precomputed dense FPGA edge timing/energy for one image.
    fpga_time_s: f64,
    fpga_energy_j: f64,
}

/// Pending-response handle (resolves when the request's batch executes).
pub struct ResponseHandle {
    rx: mpsc::Receiver<InferenceResponse>,
}

impl ResponseHandle {
    pub fn wait(self) -> Result<InferenceResponse> {
        self.rx
            .recv()
            .map_err(|_| anyhow::anyhow!("request dropped by coordinator"))
    }

    pub fn wait_timeout(self, dur: Duration) -> Result<InferenceResponse> {
        self.rx
            .recv_timeout(dur)
            .map_err(|e| anyhow::anyhow!("response not ready: {e}"))
    }
}

/// The edge-serving coordinator (leader).
pub struct Coordinator {
    tx_leader: mpsc::Sender<LeaderCmd>,
    metrics: Arc<Mutex<MetricsRegistry>>,
    next_id: AtomicU64,
    started: Instant,
    leader: Option<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Start the device thread (compiling all executables) and the
    /// leader/batching thread.
    pub fn start(config: CoordinatorConfig) -> Result<Self> {
        let (tx_dev, rx_dev) = mpsc::channel::<DeviceCmd>();
        let (tx_ready, rx_ready) = mpsc::channel::<Result<()>>();
        let cfg = config.clone();
        std::thread::Builder::new()
            .name("edgedcnn-device".into())
            .spawn(move || device_thread(cfg, rx_dev, tx_ready))
            .context("spawning device thread")?;
        rx_ready
            .recv()
            .context("device thread died during startup")??;

        let metrics = Arc::new(Mutex::new(MetricsRegistry::new()));
        let (tx_leader, rx_leader) = mpsc::channel::<LeaderCmd>();
        let m = metrics.clone();
        let batcher_cfg = config.batcher;
        let leader = std::thread::Builder::new()
            .name("edgedcnn-leader".into())
            .spawn(move || leader_thread(batcher_cfg, rx_leader, tx_dev, m))
            .context("spawning leader thread")?;
        Ok(Coordinator {
            tx_leader,
            metrics,
            next_id: AtomicU64::new(1),
            started: Instant::now(),
            leader: Some(leader),
        })
    }

    /// Submit one request; returns a handle resolving when its batch has
    /// executed.
    pub fn submit(
        &self,
        network: &str,
        n_images: usize,
        seed: u64,
    ) -> Result<ResponseHandle> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let req = InferenceRequest::new(id, network, n_images, seed);
        let (tx, rx) = mpsc::channel();
        self.tx_leader
            .send(LeaderCmd::Submit(req, tx))
            .map_err(|_| anyhow::anyhow!("coordinator is shut down"))?;
        Ok(ResponseHandle { rx })
    }

    /// Submit and block for the response.
    pub fn submit_blocking(
        &self,
        network: &str,
        n_images: usize,
        seed: u64,
    ) -> Result<InferenceResponse> {
        self.submit(network, n_images, seed)?.wait()
    }

    /// Drive a synthetic open-loop workload and return the serving
    /// report.
    pub fn serve_workload(&self, spec: &WorkloadSpec) -> Result<ServingReport> {
        self.reset_metrics(); // each workload reports its own window
        let mut rng = Rng::seed_from_u64(spec.seed);
        let mut handles = Vec::with_capacity(spec.requests);
        let t0 = Instant::now();
        for i in 0..spec.requests {
            let seed = rng.next_u64();
            handles.push(self.submit(
                &spec.network,
                spec.images_per_request,
                seed,
            )?);
            if i + 1 < spec.requests && !spec.interarrival.is_zero() {
                let jitter = rng.range_f64(0.5, 1.5);
                std::thread::sleep(spec.interarrival.mul_f64(jitter));
            }
        }
        for h in handles {
            let resp = h.wait()?;
            debug_assert!(resp.images.numel() > 0);
        }
        let wall = t0.elapsed().as_secs_f64();
        let mut m = self.metrics.lock().unwrap();
        m.set_wall(wall);
        Ok(m.report())
    }

    /// Clear accumulated metrics (each `serve_workload` call reports its
    /// own measurement window).
    pub fn reset_metrics(&self) {
        *self.metrics.lock().unwrap() = MetricsRegistry::new();
    }

    /// Snapshot of the current serving metrics.
    pub fn report(&self) -> ServingReport {
        let mut m = self.metrics.lock().unwrap();
        m.set_wall(self.started.elapsed().as_secs_f64());
        m.report()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx_leader.send(LeaderCmd::Shutdown);
        if let Some(h) = self.leader.take() {
            let _ = h.join();
        }
    }
}

/// Leader loop: intake → dynamic batching (deadline-driven) → dispatch.
fn leader_thread(
    config: BatcherConfig,
    rx: mpsc::Receiver<LeaderCmd>,
    tx_dev: mpsc::Sender<DeviceCmd>,
    metrics: Arc<Mutex<MetricsRegistry>>,
) {
    let mut batcher = DynamicBatcher::new(config);
    let mut waiters: HashMap<u64, mpsc::Sender<InferenceResponse>> =
        HashMap::new();
    let mut shutdown = false;
    'outer: loop {
        // wait for a request or the next batching deadline
        let cmd = match batcher.next_deadline() {
            Some(deadline) => {
                let now = Instant::now();
                let timeout = deadline.saturating_duration_since(now);
                match rx.recv_timeout(timeout) {
                    Ok(cmd) => Some(cmd),
                    Err(mpsc::RecvTimeoutError::Timeout) => None,
                    Err(mpsc::RecvTimeoutError::Disconnected) => break,
                }
            }
            None => match rx.recv() {
                Ok(cmd) => Some(cmd),
                Err(_) => break,
            },
        };
        // §Perf L3: requests arriving while the device executes pile up in
        // the channel — drain the whole burst into the batcher *before*
        // cutting, so continuous batching actually coalesces (before this
        // drain the mean served batch was ~2 at max_batch 8).
        let mut cuts: Vec<Batch> = Vec::new();
        let ingest = |cmd: LeaderCmd,
                          batcher: &mut DynamicBatcher,
                          waiters: &mut HashMap<
            u64,
            mpsc::Sender<InferenceResponse>,
        >,
                          cuts: &mut Vec<Batch>,
                          shutdown: &mut bool| {
            match cmd {
                LeaderCmd::Submit(req, reply) => {
                    waiters.insert(req.id, reply);
                    if let Some(b) = batcher.push(req, Instant::now()) {
                        cuts.push(b);
                    }
                }
                LeaderCmd::Shutdown => *shutdown = true,
            }
        };
        match cmd {
            Some(c) => {
                ingest(c, &mut batcher, &mut waiters, &mut cuts, &mut shutdown);
                while let Ok(more) = rx.try_recv() {
                    ingest(
                        more,
                        &mut batcher,
                        &mut waiters,
                        &mut cuts,
                        &mut shutdown,
                    );
                }
            }
            None => {
                if let Some(b) = batcher.poll(Instant::now()) {
                    cuts.push(b);
                }
            }
        }
        for batch in cuts {
            dispatch(&tx_dev, batch, &mut waiters, &metrics);
        }
        // drain any additional ready batches (e.g. other networks)
        while let Some(batch) = batcher.poll(Instant::now()) {
            dispatch(&tx_dev, batch, &mut waiters, &metrics);
        }
        if shutdown {
            break 'outer;
        }
    }
    // flush whatever is still queued, then stop the device
    let flush_at = Instant::now() + config.max_wait + Duration::from_secs(1);
    while batcher.queued() > 0 {
        match batcher.poll(flush_at) {
            Some(batch) => dispatch(&tx_dev, batch, &mut waiters, &metrics),
            None => break,
        }
    }
    let _ = tx_dev.send(DeviceCmd::Shutdown);
}

fn dispatch(
    tx_dev: &mpsc::Sender<DeviceCmd>,
    batch: Batch,
    waiters: &mut HashMap<u64, mpsc::Sender<InferenceResponse>>,
    metrics: &Arc<Mutex<MetricsRegistry>>,
) {
    let ids: Vec<u64> = batch.requests.iter().map(|r| r.id).collect();
    // on any failure below, drop the waiters so callers observe an error
    // instead of hanging
    let fail = |waiters: &mut HashMap<u64, mpsc::Sender<InferenceResponse>>| {
        for id in &ids {
            waiters.remove(id);
        }
    };
    let (tx, rx) = mpsc::channel();
    if tx_dev
        .send(DeviceCmd::Execute { batch, reply: tx })
        .is_err()
    {
        fail(waiters);
        return;
    }
    match rx.recv() {
        Ok(Ok(done)) => {
            let mut m = metrics.lock().unwrap();
            m.record_batch(
                done.execute_s,
                done.responses.iter().map(|r| r.images.shape()[0]).sum(),
                done.ops,
            );
            m.record_energy(done.energy_j);
            for resp in done.responses {
                m.record_request(resp.latency_s, resp.images.shape()[0]);
                if let Some(w) = waiters.remove(&resp.id) {
                    let _ = w.send(resp);
                }
            }
        }
        Ok(Err(e)) => {
            eprintln!("device execution failed: {e:#}");
            fail(waiters);
        }
        Err(_) => {
            eprintln!("device thread dropped a batch");
            fail(waiters);
        }
    }
}

/// The device-executor thread: owns the PJRT runtime and all compiled
/// executables; also carries the FPGA/GPU edge models for annotations.
fn device_thread(
    config: CoordinatorConfig,
    rx: mpsc::Receiver<DeviceCmd>,
    ready: mpsc::Sender<Result<()>>,
) {
    let setup = (|| -> Result<(Runtime, HashMap<String, NetState>)> {
        let artifacts = ArtifactDir::open(&config.artifacts_dir)?;
        let runtime = Runtime::cpu()?;
        let mut nets = HashMap::new();
        for name in &config.networks {
            let manifest_net = artifacts.network(name)?;
            let cfg = artifacts.network_cfg(name)?;
            // sanity: manifest must agree with the built-in architecture
            let builtin = network_by_name(name)?;
            anyhow::ensure!(
                cfg.layers == builtin.layers,
                "manifest/{name} diverges from built-in config"
            );
            let mut executables = HashMap::new();
            for &bs in &manifest_net.batch_sizes {
                executables
                    .insert(bs, runtime.load_generator(&artifacts, name, bs)?);
            }
            let weights = artifacts.load_weights(name)?;
            let opts: Vec<SimOpts> =
                cfg.layers.iter().map(|_| SimOpts::dense(cfg.tile)).collect();
            let sim = simulate_network(&cfg, &PYNQ_Z2, &opts);
            nets.insert(
                name.clone(),
                NetState {
                    buckets: manifest_net.batch_sizes.clone(),
                    executables,
                    weights,
                    fpga_time_s: sim.total_time_s,
                    fpga_energy_j: sim.total_time_s * sim.mean_power_w,
                    cfg,
                },
            );
        }
        Ok((runtime, nets))
    })();

    let (_runtime, mut nets) = match setup {
        Ok(v) => {
            let _ = ready.send(Ok(()));
            v
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };

    let mut gpu_throttle = ThermalThrottle::new(JETSON_TX1);
    while let Ok(cmd) = rx.recv() {
        match cmd {
            DeviceCmd::Shutdown => break,
            DeviceCmd::Execute { batch, reply } => {
                let result =
                    execute_batch(&mut nets, &mut gpu_throttle, batch);
                let _ = reply.send(result);
            }
        }
    }
}

fn execute_batch(
    nets: &mut HashMap<String, NetState>,
    gpu_throttle: &mut ThermalThrottle,
    batch: Batch,
) -> Result<ExecutedBatch> {
    let state = nets.get_mut(&batch.network).ok_or_else(|| {
        anyhow::anyhow!("network {:?} not loaded", batch.network)
    })?;

    // deterministic latents: one RNG per request, in order
    let mut latents: Vec<f32> =
        Vec::with_capacity(batch.n_images * state.cfg.z_dim);
    for req in &batch.requests {
        let mut rng = Rng::seed_from_u64(req.seed);
        for _ in 0..req.n_images * state.cfg.z_dim {
            latents.push(rng.normal_f32());
        }
    }

    // bucket execution: smallest exported bucket ≥ remaining, else the
    // largest repeatedly (vLLM-style bucketed continuous batching)
    let largest = *state.buckets.iter().max().unwrap();
    let mut remaining = batch.n_images;
    let mut offset = 0usize;
    let mut all_rows: Vec<f32> = Vec::with_capacity(
        batch.n_images
            * state.cfg.image_channels
            * state.cfg.image_size
            * state.cfg.image_size,
    );
    let mut execute_s = 0.0;
    while remaining > 0 {
        let bucket = state
            .buckets
            .iter()
            .copied()
            .filter(|b| *b >= remaining)
            .min()
            .unwrap_or(largest);
        let take = bucket.min(remaining);
        let exe = state.executables.get(&bucket).unwrap();
        // pad the bucket with zero latents when partially filled
        let mut z = vec![0.0f32; bucket * state.cfg.z_dim];
        z[..take * state.cfg.z_dim].copy_from_slice(
            &latents
                [offset * state.cfg.z_dim..(offset + take) * state.cfg.z_dim],
        );
        let zt = Tensor::new(vec![bucket, state.cfg.z_dim], z)?;
        let t0 = Instant::now();
        let out = exe.generate(&zt, &state.weights)?;
        execute_s += t0.elapsed().as_secs_f64();
        let numel = exe.image_numel();
        all_rows.extend_from_slice(&out.data()[..take * numel]);
        remaining -= take;
        offset += take;
    }

    // edge-device annotations for the whole batch
    let fpga_time = state.fpga_time_s * batch.n_images as f64;
    let gpu_time = expected_gpu_network_time(
        &state.cfg,
        &JETSON_TX1,
        gpu_throttle,
        batch.n_images,
    );
    let energy = state.fpga_energy_j * batch.n_images as f64;
    let ops = state.cfg.total_ops() * batch.n_images as u64;

    // split images back to requests
    let numel = state.cfg.image_channels
        * state.cfg.image_size
        * state.cfg.image_size;
    let mut responses = Vec::with_capacity(batch.requests.len());
    let mut row = 0usize;
    for req in &batch.requests {
        let n = req.n_images;
        let data = all_rows[row * numel..(row + n) * numel].to_vec();
        row += n;
        responses.push(InferenceResponse {
            id: req.id,
            images: Tensor::new(
                vec![
                    n,
                    state.cfg.image_channels,
                    state.cfg.image_size,
                    state.cfg.image_size,
                ],
                data,
            )?,
            latency_s: req.enqueued_at.elapsed().as_secs_f64(),
            execute_s,
            batch_size: batch.n_images,
            fpga_time_s: fpga_time * n as f64 / batch.n_images as f64,
            gpu_time_s: gpu_time * n as f64 / batch.n_images as f64,
        });
    }
    Ok(ExecutedBatch {
        responses,
        execute_s,
        ops,
        energy_j: energy,
    })
}
