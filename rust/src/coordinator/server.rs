//! The coordinator's public face: configuration, startup wiring and the
//! client API (submit / serve_workload / report).  The work happens in
//! the submodules it wires together:
//!
//! * [`super::registry`] — which lanes *can* serve which logical
//!   networks (capability map, built at startup);
//! * [`super::scheduler`] — the leader thread: intake, dynamic
//!   batching, capability- and cost-aware routing with per-network
//!   ordering, backpressure and admission control;
//! * [`super::executor`] — one FIFO lane thread per pool backend, each
//!   owning a live [`crate::backend::Backend`] (FPGA simulator, GPU
//!   thermal model, or the host CPU numeric path).
//!
//! Every lane loads every network it is capable of serving (routing is
//! dynamic — any capable lane may receive any batch), and all lanes
//! produce bit-identical f32 images for the same seeds, so the pool
//! composition only changes *timing*, never *content*.

use super::batcher::BatcherConfig;
use super::executor::{lane_thread, LaneCmd, LaneShared, LaneSpec};
use super::metrics::{MetricsRegistry, ServingReport};
use super::registry::BackendRegistry;
use super::request::{
    InferenceRequest, InferenceResponse, PriorityClass, RequestCtx,
    RequestOutcome,
};
use super::scheduler::{leader_thread, LaneHandle, LeaderCmd};
use crate::config::{BackendCfg, DeviceKind, Precision, QFormat};
use crate::telemetry::RunClock;
use crate::util::Rng;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// Coordinator construction options.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub artifacts_dir: std::path::PathBuf,
    /// Networks to preload (executables compile at startup, never on the
    /// request path).
    pub networks: Vec<String>,
    pub batcher: BatcherConfig,
    /// Heterogeneous device pool: one executor lane per entry in
    /// `backends.kinds`, plus the scheduler's queue bounds.
    pub backends: BackendCfg,
    /// Total lane override: `0` = one lane per `backends.kinds` entry;
    /// `n > 0` = cycle the kinds list to `n` lanes (e.g. kinds
    /// `[fpga, cpu]` with `executors: 4` → `fpga0 cpu0 fpga1 cpu1`).
    pub executors: usize,
    /// When set, every preloaded network also serves a fixed-point twin
    /// under the logical name `<name>.q` (quantized at startup with
    /// per-output-channel scale calibration) — side by side with the
    /// f32 path.  Twins route only to fixed-point-capable backends (not
    /// the GPU).
    pub quant: Option<QFormat>,
    /// When set, every preloaded network also serves an 8-bit twin
    /// under the logical name `<name>.q8` (default format q2.6) —
    /// independent of `quant`, so a pool can serve f32, `.q` and `.q8`
    /// side by side.  Like `.q`, the `.q8` twins route around the
    /// f32-only GPU lane.
    pub quant8: Option<QFormat>,
    /// Intra-batch parallelism: split multi-request batches across the
    /// capable lanes (round-robin at request granularity) instead of
    /// batch-at-a-time dispatch.  Trades the per-network ordering
    /// guarantee for tail latency.
    pub shard_batches: bool,
    /// Run clock every lifecycle stamp is taken against.  `None` (the
    /// default) starts a fresh unskewed clock at coordinator startup;
    /// the fleet passes a shared-epoch, per-site-skewed clock so
    /// cross-site spans fold onto one timeline.
    pub clock: Option<RunClock>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            artifacts_dir: "artifacts".into(),
            networks: vec!["mnist".to_string()],
            batcher: BatcherConfig::default(),
            backends: BackendCfg::default(),
            executors: 0,
            quant: None,
            quant8: None,
            shard_batches: false,
            clock: None,
        }
    }
}

/// All logical networks this config serves, with served precisions:
/// the base (f32) networks plus their `.q` / `.q8` quantized twins
/// when enabled.
fn logical_networks(config: &CoordinatorConfig) -> Vec<(String, Precision)> {
    let mut names: Vec<(String, Precision)> = config
        .networks
        .iter()
        .map(|n| (n.clone(), Precision::F32))
        .collect();
    if let Some(fmt) = config.quant {
        names.extend(
            config
                .networks
                .iter()
                .map(|n| (format!("{n}.q"), Precision::Fixed(fmt))),
        );
    }
    if let Some(fmt) = config.quant8 {
        names.extend(
            config
                .networks
                .iter()
                .map(|n| (format!("{n}.q8"), Precision::Fixed(fmt))),
        );
    }
    names
}

/// Expand the kinds list to the requested lane count (cycling).
fn expand_kinds(kinds: &[DeviceKind], executors: usize) -> Vec<DeviceKind> {
    if executors == 0 || kinds.is_empty() {
        return kinds.to_vec();
    }
    (0..executors).map(|i| kinds[i % kinds.len()]).collect()
}

/// A synthetic open-loop workload for [`Coordinator::serve_workload`].
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    pub network: String,
    pub requests: usize,
    pub images_per_request: usize,
    /// Mean inter-arrival gap (uniform ±50% jitter applied).
    pub interarrival: Duration,
    pub seed: u64,
}

/// Pending-outcome handle (resolves when the request's batch executes,
/// or immediately with a typed denial when intake turns it away).
pub struct ResponseHandle {
    rx: mpsc::Receiver<RequestOutcome>,
}

impl ResponseHandle {
    /// Block until the request resolves and return the typed outcome —
    /// [`RequestOutcome::Served`] / `Shed` / `Rejected`, with a dropped
    /// reply channel normalized to [`RequestOutcome::Lost`].  This is
    /// the exact-accounting surface: the loadtest and the fleet front
    /// tier match on it instead of reconciling error counts after the
    /// fact.
    pub fn outcome(self) -> RequestOutcome {
        self.rx.recv().unwrap_or(RequestOutcome::Lost)
    }

    /// Block for a response; every denial maps to a descriptive error
    /// (the legacy `Result` shape most callers want).
    pub fn wait(self) -> Result<InferenceResponse> {
        self.outcome().into_response()
    }

    pub fn wait_timeout(self, dur: Duration) -> Result<InferenceResponse> {
        match self.rx.recv_timeout(dur) {
            Ok(outcome) => outcome.into_response(),
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                RequestOutcome::Lost.into_response()
            }
            Err(e) => Err(anyhow::anyhow!("response not ready: {e}")),
        }
    }
}

/// A cloneable, thread-safe submission handle onto a running
/// [`Coordinator`] — what a closed-loop client (one blocking wait per
/// in-flight request) holds, since the coordinator itself is pinned to
/// the thread that owns its shutdown.  Each clone shares the request-id
/// counter, so ids stay unique across clients.
#[derive(Clone)]
pub struct CoordinatorClient {
    tx_leader: mpsc::Sender<LeaderCmd>,
    next_id: Arc<AtomicU64>,
}

impl CoordinatorClient {
    /// Begin one request for `network` — the single client entry point.
    /// Everything else (image count, latent seed, class, deadline,
    /// arrival charge point) is builder state with sane defaults; the
    /// builder ends in [`RequestBuilder::submit`] (a typed handle) or
    /// [`RequestBuilder::blocking`].
    pub fn request(&self, network: &str) -> RequestBuilder {
        RequestBuilder::new(self.clone(), network)
    }

    /// Submit one request under an explicit lifecycle context.
    #[deprecated(
        since = "0.2.0",
        note = "use `request(network).images(n).ctx(ctx).submit()`"
    )]
    pub fn submit_with(
        &self,
        network: &str,
        n_images: usize,
        ctx: RequestCtx,
    ) -> Result<ResponseHandle> {
        self.request(network).images(n_images).ctx(ctx).submit()
    }

    /// The submission primitive every builder terminal lands on.
    fn send(
        &self,
        network: &str,
        n_images: usize,
        ctx: RequestCtx,
    ) -> Result<ResponseHandle> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let req = InferenceRequest::with_ctx(id, network, n_images, ctx);
        let (tx, rx) = mpsc::channel();
        self.tx_leader
            .send(LeaderCmd::Submit(req, tx))
            .map_err(|_| anyhow::anyhow!("coordinator is shut down"))?;
        Ok(ResponseHandle { rx })
    }
}

/// Builder for one inference request — the public submission surface
/// (`coordinator.request("mnist").images(2).seed(42).submit()`).
///
/// Defaults mirror the old `submit` shape: one image, seed 0, Normal
/// class, best-effort (no deadline), arrival charged "now".  The
/// deadline setters keep *relative* deadlines relative to whatever
/// arrival is in force at submit time, so `.deadline_in(..)` and
/// `.arrive_at(..)` compose in either order.
#[must_use = "a request builder does nothing until .submit() or .blocking()"]
pub struct RequestBuilder {
    client: CoordinatorClient,
    network: String,
    n_images: usize,
    seed: u64,
    class: PriorityClass,
    arrival: Instant,
    deadline_at: Option<Instant>,
    deadline_in: Option<Duration>,
    stamps: crate::telemetry::StageStamps,
}

impl RequestBuilder {
    fn new(client: CoordinatorClient, network: &str) -> Self {
        RequestBuilder {
            client,
            network: network.to_string(),
            n_images: 1,
            seed: 0,
            class: PriorityClass::Normal,
            arrival: Instant::now(),
            deadline_at: None,
            deadline_in: None,
            stamps: Default::default(),
        }
    }

    /// Images to generate (the request payload size).  Default 1.
    pub fn images(mut self, n: usize) -> Self {
        self.n_images = n;
        self
    }

    /// Latent seed (deterministic generation).  Default 0.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Priority class (the load-shedding axis).  Default Normal.
    pub fn class(mut self, class: PriorityClass) -> Self {
        self.class = class;
        self
    }

    /// Absolute deadline (wins over [`Self::deadline_in`] if both are
    /// set).  Default: best-effort.
    pub fn deadline_at(mut self, deadline: Instant) -> Self {
        self.deadline_at = Some(deadline);
        self
    }

    /// Relative deadline, counted from the arrival charge point in
    /// force at submit time.
    pub fn deadline_in(mut self, budget: Duration) -> Self {
        self.deadline_in = Some(budget);
        self
    }

    /// Arrival instant the request is *charged from* (open-loop drivers
    /// pass the scheduled arrival so generator lag counts against the
    /// system).  Default: builder creation time.
    pub fn arrive_at(mut self, arrival: Instant) -> Self {
        self.arrival = arrival;
        self
    }

    /// Replace the whole lifecycle context (arrival, deadline, class,
    /// seed) with a pre-built one — the trace-replay path, where the
    /// context is constructed once per event.
    pub fn ctx(mut self, ctx: RequestCtx) -> Self {
        self.arrival = ctx.arrival;
        self.deadline_at = ctx.deadline;
        self.deadline_in = None;
        self.class = ctx.class;
        self.seed = ctx.seed;
        // carried stamps survive re-submission: a fleet spill re-ingests
        // on the target site with the origin hop's intake intact
        self.stamps = ctx.stamps;
        self
    }

    /// The context this builder would submit.
    fn build_ctx(&self) -> RequestCtx {
        RequestCtx {
            arrival: self.arrival,
            deadline: self
                .deadline_at
                .or_else(|| self.deadline_in.map(|d| self.arrival + d)),
            class: self.class,
            seed: self.seed,
            stamps: self.stamps,
        }
    }

    /// Submit; returns a typed handle resolving when the request's
    /// batch executes (or immediately with a typed denial).
    pub fn submit(self) -> Result<ResponseHandle> {
        let ctx = self.build_ctx();
        self.client.send(&self.network, self.n_images, ctx)
    }

    /// Submit and block for the response (denials become errors).
    pub fn blocking(self) -> Result<InferenceResponse> {
        self.submit()?.wait()
    }
}

/// The edge-serving coordinator (scheduler + heterogeneous lane pool).
pub struct Coordinator {
    tx_leader: mpsc::Sender<LeaderCmd>,
    metrics: Arc<Mutex<MetricsRegistry>>,
    next_id: Arc<AtomicU64>,
    started: Instant,
    lanes: usize,
    lane_names: Vec<String>,
    leader: Option<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Start the lane pool (each thread instantiating its backend and
    /// loading its routable networks) and the scheduler thread.
    pub fn start(config: CoordinatorConfig) -> Result<Self> {
        let logical = logical_networks(&config);
        let kinds = expand_kinds(&config.backends.kinds, config.executors);
        let registry = BackendRegistry::build(&kinds, &logical)?;
        let n_lanes = registry.lanes().len();
        anyhow::ensure!(n_lanes > 0, "backend pool is empty");
        let metrics = Arc::new(Mutex::new(MetricsRegistry::new()));
        let clock = config
            .clock
            .unwrap_or_else(|| RunClock::at(Instant::now()));
        let precisions: HashMap<String, Precision> =
            logical.iter().cloned().collect();
        let outstanding: HashMap<String, Arc<AtomicUsize>> = logical
            .iter()
            .map(|(n, _)| (n.clone(), Arc::new(AtomicUsize::new(0))))
            .collect();
        let exec_seq = Arc::new(AtomicU64::new(0));

        let mut lane_txs = Vec::with_capacity(n_lanes);
        let mut depths = Vec::with_capacity(n_lanes);
        let mut costs = Vec::with_capacity(n_lanes);
        let mut exec_handles = Vec::with_capacity(n_lanes);
        let mut readiness = Vec::with_capacity(n_lanes);
        for (i, info) in registry.lanes().iter().enumerate() {
            // decorrelate the lanes' measurement-noise streams (and let
            // the loadtest re-seed the whole pool per trial)
            let noise_seed = Rng::seed_from_u64(
                config.backends.noise_seed.wrapping_add(i as u64),
            )
            .next_u64();
            let spec = LaneSpec {
                name: info.name.clone(),
                kind: info.kind,
                networks: registry
                    .networks_for_lane(i)
                    .into_iter()
                    .map(|n| {
                        let p = precisions[&n];
                        (n, p)
                    })
                    .collect(),
                n_lanes,
                artifacts_dir: config.artifacts_dir.clone(),
                noise_seed,
            };
            let depth = Arc::new(AtomicUsize::new(0));
            let lane_costs = Arc::new(Mutex::new(HashMap::new()));
            let shared = LaneShared {
                metrics: metrics.clone(),
                depth: depth.clone(),
                outstanding: outstanding.clone(),
                exec_seq: exec_seq.clone(),
                costs: lane_costs.clone(),
                clock,
            };
            let (tx_lane, rx_lane) = mpsc::channel::<LaneCmd>();
            let (tx_ready, rx_ready) = mpsc::channel();
            let handle = std::thread::Builder::new()
                .name(format!("edgedcnn-{}", info.name))
                .spawn(move || lane_thread(spec, rx_lane, tx_ready, shared))
                .context("spawning executor lane")?;
            lane_txs.push(tx_lane);
            depths.push(depth);
            costs.push(lane_costs);
            exec_handles.push(handle);
            readiness.push(rx_ready);
        }
        let mut lanes = Vec::with_capacity(n_lanes);
        for (i, ((rx, tx), (depth, lane_costs))) in readiness
            .into_iter()
            .zip(lane_txs)
            .zip(depths.into_iter().zip(costs))
            .enumerate()
        {
            rx.recv()
                .context("executor lane died during startup")??;
            lanes.push(LaneHandle {
                name: registry.lanes()[i].name.clone(),
                tx,
                depth,
                costs: lane_costs,
            });
        }

        let (tx_leader, rx_leader) = mpsc::channel::<LeaderCmd>();
        let batcher_cfg = config.batcher;
        let backend_cfg = config.backends.clone();
        let shard_batches = config.shard_batches;
        let lane_names: Vec<String> = registry
            .lanes()
            .iter()
            .map(|l| l.name.clone())
            .collect();
        let m = metrics.clone();
        let leader = std::thread::Builder::new()
            .name("edgedcnn-leader".into())
            .spawn(move || {
                leader_thread(
                    batcher_cfg,
                    backend_cfg,
                    shard_batches,
                    rx_leader,
                    lanes,
                    registry,
                    outstanding,
                    m,
                    clock,
                    exec_handles,
                )
            })
            .context("spawning leader thread")?;
        Ok(Coordinator {
            tx_leader,
            metrics,
            next_id: Arc::new(AtomicU64::new(1)),
            started: Instant::now(),
            lanes: n_lanes,
            lane_names,
            leader: Some(leader),
        })
    }

    /// Width of the lane pool actually running.
    pub fn executors(&self) -> usize {
        self.lanes
    }

    /// Lane (backend) names in lane-index order, e.g.
    /// `["fpga0", "gpu0", "cpu0"]`.
    pub fn backend_names(&self) -> &[String] {
        &self.lane_names
    }

    /// A cloneable, thread-safe submission handle (closed-loop clients
    /// hold one per thread).
    pub fn client(&self) -> CoordinatorClient {
        CoordinatorClient {
            tx_leader: self.tx_leader.clone(),
            next_id: self.next_id.clone(),
        }
    }

    /// Begin one request for `network` — convenience for
    /// `self.client().request(network)`; see
    /// [`CoordinatorClient::request`].
    pub fn request(&self, network: &str) -> RequestBuilder {
        self.client().request(network)
    }

    /// Submit one best-effort request arriving now; returns a handle
    /// resolving when its batch has executed.
    #[deprecated(
        since = "0.2.0",
        note = "use `request(network).images(n).seed(s).submit()`"
    )]
    pub fn submit(
        &self,
        network: &str,
        n_images: usize,
        seed: u64,
    ) -> Result<ResponseHandle> {
        self.request(network).images(n_images).seed(seed).submit()
    }

    /// Submit one request under an explicit lifecycle context — the
    /// deadline-aware path: the caller stamps the (scheduled) arrival,
    /// absolute deadline and priority class, and the context flows
    /// intact through batching, routing, execution and telemetry.
    #[deprecated(
        since = "0.2.0",
        note = "use `request(network).images(n).ctx(ctx).submit()`"
    )]
    pub fn submit_with(
        &self,
        network: &str,
        n_images: usize,
        ctx: RequestCtx,
    ) -> Result<ResponseHandle> {
        self.request(network).images(n_images).ctx(ctx).submit()
    }

    /// Submit and block for the response.
    #[deprecated(
        since = "0.2.0",
        note = "use `request(network).images(n).seed(s).blocking()`"
    )]
    pub fn submit_blocking(
        &self,
        network: &str,
        n_images: usize,
        seed: u64,
    ) -> Result<InferenceResponse> {
        self.request(network).images(n_images).seed(seed).blocking()
    }

    /// Drive a synthetic open-loop workload and return the serving
    /// report.
    pub fn serve_workload(&self, spec: &WorkloadSpec) -> Result<ServingReport> {
        self.reset_metrics(); // each workload reports its own window
        let mut rng = Rng::seed_from_u64(spec.seed);
        let mut handles = Vec::with_capacity(spec.requests);
        let t0 = Instant::now();
        for i in 0..spec.requests {
            let seed = rng.next_u64();
            handles.push(
                self.request(&spec.network)
                    .images(spec.images_per_request)
                    .seed(seed)
                    .submit()?,
            );
            if i + 1 < spec.requests && !spec.interarrival.is_zero() {
                let jitter = rng.range_f64(0.5, 1.5);
                std::thread::sleep(spec.interarrival.mul_f64(jitter));
            }
        }
        for h in handles {
            let resp = h.wait()?;
            debug_assert!(resp.images.numel() > 0);
        }
        let wall = t0.elapsed().as_secs_f64();
        let mut m = self.metrics.lock().unwrap();
        m.set_wall(wall);
        Ok(m.report())
    }

    /// Clear accumulated metrics (each `serve_workload` call reports its
    /// own measurement window).
    pub fn reset_metrics(&self) {
        *self.metrics.lock().unwrap() = MetricsRegistry::new();
    }

    /// Snapshot of the serving metrics with an explicit measurement
    /// window (callers driving their own open-loop clock — the
    /// loadtest — pass the wall time they actually measured).
    pub fn report_for_wall(&self, wall_s: f64) -> ServingReport {
        let mut m = self.metrics.lock().unwrap();
        m.set_wall(wall_s);
        m.report()
    }

    /// Snapshot of the current serving metrics.
    pub fn report(&self) -> ServingReport {
        let mut m = self.metrics.lock().unwrap();
        m.set_wall(self.started.elapsed().as_secs_f64());
        m.report()
    }

    /// Clone of the raw metrics registry — the fleet front tier takes
    /// one per site and folds them ([`MetricsRegistry::merge_from`])
    /// into a fleet-level report.  Also how a site's telemetry survives
    /// the site going dark: snapshot, then drop the coordinator.
    pub fn metrics_snapshot(&self) -> MetricsRegistry {
        self.metrics.lock().unwrap().clone()
    }

    /// Fail-stop the coordinator: drain in-flight work (every pending
    /// reply channel resolves — served or `Lost` — before the leader
    /// exits) and return the site's final telemetry.  This is the
    /// drain-then-dark model the fleet's site-failure scenario uses: a
    /// site that goes dark still contributes its shard to the merged
    /// fleet report.
    pub fn shutdown(self) -> MetricsRegistry {
        let metrics = self.metrics.clone();
        drop(self); // Drop sends Shutdown and joins the leader
        metrics.lock().unwrap().clone()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx_leader.send(LeaderCmd::Shutdown);
        if let Some(h) = self.leader.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kinds_expand_cyclically() {
        let kinds = [DeviceKind::Fpga, DeviceKind::Cpu];
        assert_eq!(expand_kinds(&kinds, 0), kinds.to_vec());
        assert_eq!(
            expand_kinds(&kinds, 5),
            vec![
                DeviceKind::Fpga,
                DeviceKind::Cpu,
                DeviceKind::Fpga,
                DeviceKind::Cpu,
                DeviceKind::Fpga
            ]
        );
    }

    #[test]
    fn logical_networks_carry_precisions() {
        let mut cfg = CoordinatorConfig {
            networks: vec!["mnist".into()],
            ..Default::default()
        };
        assert_eq!(
            logical_networks(&cfg),
            vec![("mnist".to_string(), Precision::F32)]
        );
        cfg.quant = Some(QFormat::new(16, 8));
        let nets = logical_networks(&cfg);
        assert_eq!(nets.len(), 2);
        assert_eq!(nets[1].0, "mnist.q");
        assert_eq!(
            nets[1].1,
            Precision::Fixed(QFormat::new(16, 8))
        );
        // the int8 twin is independent of `quant`: enabling both serves
        // f32, `.q` and `.q8` side by side
        cfg.quant8 = Some(QFormat::new(8, 6));
        let nets = logical_networks(&cfg);
        assert_eq!(nets.len(), 3);
        assert_eq!(nets[2].0, "mnist.q8");
        assert_eq!(nets[2].1, Precision::Fixed(QFormat::new(8, 6)));
    }
}
