//! The coordinator proper: a **leader thread** (request intake + dynamic
//! batching + dispatch) and a **pool of device-executor threads** (PJRT
//! or pure-Rust numerics + FPGA/GPU edge-timing annotations + power
//! integration), joined by channels — the same split a vLLM-style router
//! runs, implemented on std threads (the offline build environment ships
//! no async runtime; see DESIGN.md §Offline-environment).
//!
//! Executor-pool design:
//!
//! * each executor owns its own `Runtime` and compiled executables (PJRT
//!   handles are not `Sync`), plus its own GPU thermal state;
//! * batches route by **per-network affinity** (network → executor), so
//!   one network's batches stay ordered on one device and its DVFS/cache
//!   state remains coherent, while distinct networks execute truly
//!   concurrently;
//! * the leader never blocks on execution: the reply channels travel
//!   with the batch, the executor records metrics and resolves waiters
//!   itself, and the leader goes straight back to intake/batching — so
//!   `serve_workload` scales with cores instead of serializing through
//!   one dispatch round-trip.

use super::batcher::{Batch, BatcherConfig, DynamicBatcher};
use super::metrics::{MetricsRegistry, ServingReport};
use super::request::{InferenceRequest, InferenceResponse};
use crate::artifacts::ArtifactDir;
use crate::config::{
    network_by_name, NetworkCfg, Precision, QFormat, JETSON_TX1, PYNQ_Z2,
};
use crate::fpga::{simulate_network, SimOpts};
use crate::gpu::{expected_gpu_network_time, ThermalThrottle};
use crate::quant::{QuantizedGenerator, Rounding};
use crate::runtime::{GeneratorExecutable, Runtime};
use crate::tensor::Tensor;
use crate::util::{Rng, WorkerPool};
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// Coordinator construction options.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub artifacts_dir: std::path::PathBuf,
    /// Networks to preload (executables compile at startup, never on the
    /// request path).
    pub networks: Vec<String>,
    pub batcher: BatcherConfig,
    /// Device-executor threads.  `0` = auto: one per preloaded network
    /// (per-network affinity makes more executors than networks idle).
    pub executors: usize,
    /// When set, every preloaded network also serves a fixed-point twin
    /// under the logical name `<name>.q` (quantized at startup with
    /// per-layer scale calibration) — side by side with the f32 path.
    pub quant: Option<QFormat>,
    /// Intra-batch parallelism: split multi-request batches across the
    /// executor pool (round-robin at request granularity) instead of
    /// batch-at-a-time dispatch.  Requires every executor to load every
    /// network, so it trades startup memory for tail latency.
    pub shard_batches: bool,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            artifacts_dir: "artifacts".into(),
            networks: vec!["mnist".to_string()],
            batcher: BatcherConfig::default(),
            executors: 0,
            quant: None,
            shard_batches: false,
        }
    }
}

/// All logical network names this config serves: the base (f32)
/// networks plus their `.q` quantized twins when enabled.
fn logical_networks(config: &CoordinatorConfig) -> Vec<String> {
    let mut names = config.networks.clone();
    if config.quant.is_some() {
        names.extend(config.networks.iter().map(|n| format!("{n}.q")));
    }
    names
}

/// A synthetic open-loop workload for [`Coordinator::serve_workload`].
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    pub network: String,
    pub requests: usize,
    pub images_per_request: usize,
    /// Mean inter-arrival gap (uniform ±50% jitter applied).
    pub interarrival: Duration,
    pub seed: u64,
}

enum LeaderCmd {
    Submit(InferenceRequest, mpsc::Sender<InferenceResponse>),
    Shutdown,
}

enum DeviceCmd {
    Execute {
        batch: Batch,
        /// Reply channel per request id; dropped on failure so callers
        /// observe an error instead of hanging.
        replies: Vec<(u64, mpsc::Sender<InferenceResponse>)>,
    },
    Shutdown,
}

struct ExecutedBatch {
    responses: Vec<InferenceResponse>,
    execute_s: f64,
    ops: u64,
    energy_j: f64,
}

/// Per-network state owned by one executor thread.
struct NetState {
    cfg: NetworkCfg,
    /// Executables keyed by batch bucket (f32 path; empty for `.q`).
    executables: HashMap<usize, GeneratorExecutable>,
    buckets: Vec<usize>,
    weights: Vec<(Tensor, Vec<f32>)>,
    /// Quantized twin (`.q` logical networks): the calibrated
    /// fixed-point generator, executed through the reverse-loop
    /// substrate directly.
    quant: Option<QuantizedGenerator>,
    /// Precomputed dense FPGA edge timing/energy for one image (at the
    /// network's served precision).
    fpga_time_s: f64,
    fpga_energy_j: f64,
}

/// Pending-response handle (resolves when the request's batch executes).
pub struct ResponseHandle {
    rx: mpsc::Receiver<InferenceResponse>,
}

impl ResponseHandle {
    pub fn wait(self) -> Result<InferenceResponse> {
        self.rx
            .recv()
            .map_err(|_| anyhow::anyhow!("request dropped by coordinator"))
    }

    pub fn wait_timeout(self, dur: Duration) -> Result<InferenceResponse> {
        self.rx
            .recv_timeout(dur)
            .map_err(|e| anyhow::anyhow!("response not ready: {e}"))
    }
}

/// The edge-serving coordinator (leader + executor pool).
pub struct Coordinator {
    tx_leader: mpsc::Sender<LeaderCmd>,
    metrics: Arc<Mutex<MetricsRegistry>>,
    next_id: AtomicU64,
    started: Instant,
    executors: usize,
    leader: Option<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Start the executor pool (each thread compiling all executables)
    /// and the leader/batching thread.
    pub fn start(config: CoordinatorConfig) -> Result<Self> {
        // auto sizing counts *logical* networks (the `.q` twins are
        // full serving paths of their own), so mixed f32/quant traffic
        // actually runs concurrently
        let n_exec = if config.executors == 0 {
            logical_networks(&config).len().max(1)
        } else {
            config.executors
        };
        let metrics = Arc::new(Mutex::new(MetricsRegistry::new()));

        let mut exec_txs = Vec::with_capacity(n_exec);
        let mut exec_handles = Vec::with_capacity(n_exec);
        let mut readiness = Vec::with_capacity(n_exec);
        for i in 0..n_exec {
            let (tx_dev, rx_dev) = mpsc::channel::<DeviceCmd>();
            let (tx_ready, rx_ready) = mpsc::channel::<Result<()>>();
            let cfg = config.clone();
            let m = metrics.clone();
            let handle = std::thread::Builder::new()
                .name(format!("edgedcnn-device-{i}"))
                .spawn(move || device_thread(cfg, i, n_exec, rx_dev, tx_ready, m))
                .context("spawning device thread")?;
            exec_txs.push(tx_dev);
            exec_handles.push(handle);
            readiness.push(rx_ready);
        }
        for rx in readiness {
            rx.recv()
                .context("device thread died during startup")??;
        }

        // Per-network affinity: logical network i → executor i mod pool
        // (the `.q` twins land after the f32 names, so mixed f32/quant
        // workloads spread across the pool).
        let affinity: HashMap<String, usize> = logical_networks(&config)
            .into_iter()
            .enumerate()
            .map(|(i, n)| (n, i % n_exec))
            .collect();

        let (tx_leader, rx_leader) = mpsc::channel::<LeaderCmd>();
        let batcher_cfg = config.batcher;
        let shard_batches = config.shard_batches;
        let leader = std::thread::Builder::new()
            .name("edgedcnn-leader".into())
            .spawn(move || {
                leader_thread(
                    batcher_cfg,
                    shard_batches,
                    rx_leader,
                    exec_txs,
                    affinity,
                    exec_handles,
                )
            })
            .context("spawning leader thread")?;
        Ok(Coordinator {
            tx_leader,
            metrics,
            next_id: AtomicU64::new(1),
            started: Instant::now(),
            executors: n_exec,
            leader: Some(leader),
        })
    }

    /// Width of the executor pool actually running.
    pub fn executors(&self) -> usize {
        self.executors
    }

    /// Submit one request; returns a handle resolving when its batch has
    /// executed.
    pub fn submit(
        &self,
        network: &str,
        n_images: usize,
        seed: u64,
    ) -> Result<ResponseHandle> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let req = InferenceRequest::new(id, network, n_images, seed);
        let (tx, rx) = mpsc::channel();
        self.tx_leader
            .send(LeaderCmd::Submit(req, tx))
            .map_err(|_| anyhow::anyhow!("coordinator is shut down"))?;
        Ok(ResponseHandle { rx })
    }

    /// Submit and block for the response.
    pub fn submit_blocking(
        &self,
        network: &str,
        n_images: usize,
        seed: u64,
    ) -> Result<InferenceResponse> {
        self.submit(network, n_images, seed)?.wait()
    }

    /// Drive a synthetic open-loop workload and return the serving
    /// report.
    pub fn serve_workload(&self, spec: &WorkloadSpec) -> Result<ServingReport> {
        self.reset_metrics(); // each workload reports its own window
        let mut rng = Rng::seed_from_u64(spec.seed);
        let mut handles = Vec::with_capacity(spec.requests);
        let t0 = Instant::now();
        for i in 0..spec.requests {
            let seed = rng.next_u64();
            handles.push(self.submit(
                &spec.network,
                spec.images_per_request,
                seed,
            )?);
            if i + 1 < spec.requests && !spec.interarrival.is_zero() {
                let jitter = rng.range_f64(0.5, 1.5);
                std::thread::sleep(spec.interarrival.mul_f64(jitter));
            }
        }
        for h in handles {
            let resp = h.wait()?;
            debug_assert!(resp.images.numel() > 0);
        }
        let wall = t0.elapsed().as_secs_f64();
        let mut m = self.metrics.lock().unwrap();
        m.set_wall(wall);
        Ok(m.report())
    }

    /// Clear accumulated metrics (each `serve_workload` call reports its
    /// own measurement window).
    pub fn reset_metrics(&self) {
        *self.metrics.lock().unwrap() = MetricsRegistry::new();
    }

    /// Snapshot of the current serving metrics.
    pub fn report(&self) -> ServingReport {
        let mut m = self.metrics.lock().unwrap();
        m.set_wall(self.started.elapsed().as_secs_f64());
        m.report()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx_leader.send(LeaderCmd::Shutdown);
        if let Some(h) = self.leader.take() {
            let _ = h.join();
        }
    }
}

/// Leader loop: intake → dynamic batching (deadline-driven) → dispatch
/// to the affine executor (never blocking on execution), optionally
/// sharding multi-request batches across the pool.
fn leader_thread(
    config: BatcherConfig,
    shard_batches: bool,
    rx: mpsc::Receiver<LeaderCmd>,
    executors: Vec<mpsc::Sender<DeviceCmd>>,
    affinity: HashMap<String, usize>,
    exec_handles: Vec<std::thread::JoinHandle<()>>,
) {
    let mut batcher = DynamicBatcher::new(config);
    let mut waiters: HashMap<u64, mpsc::Sender<InferenceResponse>> =
        HashMap::new();
    let mut shutdown = false;
    'outer: loop {
        // wait for a request or the next batching deadline
        let cmd = match batcher.next_deadline() {
            Some(deadline) => {
                let now = Instant::now();
                let timeout = deadline.saturating_duration_since(now);
                match rx.recv_timeout(timeout) {
                    Ok(cmd) => Some(cmd),
                    Err(mpsc::RecvTimeoutError::Timeout) => None,
                    Err(mpsc::RecvTimeoutError::Disconnected) => break,
                }
            }
            None => match rx.recv() {
                Ok(cmd) => Some(cmd),
                Err(_) => break,
            },
        };
        // §Perf L3: requests arriving while the devices execute pile up
        // in the channel — drain the whole burst into the batcher
        // *before* cutting, so continuous batching actually coalesces.
        let mut cuts: Vec<Batch> = Vec::new();
        let ingest = |cmd: LeaderCmd,
                          batcher: &mut DynamicBatcher,
                          waiters: &mut HashMap<
            u64,
            mpsc::Sender<InferenceResponse>,
        >,
                          cuts: &mut Vec<Batch>,
                          shutdown: &mut bool| {
            match cmd {
                LeaderCmd::Submit(req, reply) => {
                    waiters.insert(req.id, reply);
                    if let Some(b) = batcher.push(req, Instant::now()) {
                        cuts.push(b);
                    }
                }
                LeaderCmd::Shutdown => *shutdown = true,
            }
        };
        match cmd {
            Some(c) => {
                ingest(c, &mut batcher, &mut waiters, &mut cuts, &mut shutdown);
                while let Ok(more) = rx.try_recv() {
                    ingest(
                        more,
                        &mut batcher,
                        &mut waiters,
                        &mut cuts,
                        &mut shutdown,
                    );
                }
            }
            None => {
                if let Some(b) = batcher.poll(Instant::now()) {
                    cuts.push(b);
                }
            }
        }
        for batch in cuts {
            dispatch(&executors, &affinity, batch, &mut waiters, shard_batches);
        }
        // drain any additional ready batches (e.g. other networks)
        while let Some(batch) = batcher.poll(Instant::now()) {
            dispatch(&executors, &affinity, batch, &mut waiters, shard_batches);
        }
        if shutdown {
            break 'outer;
        }
    }
    // flush whatever is still queued, then stop the executor pool
    let flush_at = Instant::now() + config.max_wait + Duration::from_secs(1);
    while batcher.queued() > 0 {
        match batcher.poll(flush_at) {
            Some(batch) => {
                dispatch(&executors, &affinity, batch, &mut waiters, shard_batches)
            }
            None => break,
        }
    }
    for tx in &executors {
        let _ = tx.send(DeviceCmd::Shutdown);
    }
    for h in exec_handles {
        let _ = h.join();
    }
}

/// Route a batch to its network's executor.  Non-blocking: the reply
/// channels travel with the batch, so the leader returns to intake
/// immediately and distinct networks execute concurrently.
///
/// With `shard` enabled and ≥ 2 requests in the batch, the batch is
/// split round-robin at *request* granularity across the executor pool
/// (intra-batch parallelism).  Request boundaries keep every response
/// self-contained, so no reassembly step is needed — and since latents
/// derive from per-request seeds, per-request images are identical with
/// sharding on or off (asserted by the integration tests).
fn dispatch(
    executors: &[mpsc::Sender<DeviceCmd>],
    affinity: &HashMap<String, usize>,
    batch: Batch,
    waiters: &mut HashMap<u64, mpsc::Sender<InferenceResponse>>,
    shard: bool,
) {
    let base = affinity
        .get(&batch.network)
        .copied()
        .unwrap_or(0)
        .min(executors.len().saturating_sub(1));
    if shard && batch.requests.len() >= 2 && executors.len() >= 2 {
        let n_shards = executors.len().min(batch.requests.len());
        let network = batch.network;
        let mut groups: Vec<Vec<InferenceRequest>> =
            (0..n_shards).map(|_| Vec::new()).collect();
        for (i, r) in batch.requests.into_iter().enumerate() {
            groups[i % n_shards].push(r);
        }
        for (gi, requests) in groups.into_iter().enumerate() {
            let n_images = requests.iter().map(|r| r.n_images).sum();
            let shard_batch = Batch {
                network: network.clone(),
                requests,
                n_images,
            };
            send_to_executor(
                executors,
                (base + gi) % executors.len(),
                shard_batch,
                waiters,
            );
        }
    } else {
        send_to_executor(executors, base, batch, waiters);
    }
}

fn send_to_executor(
    executors: &[mpsc::Sender<DeviceCmd>],
    idx: usize,
    batch: Batch,
    waiters: &mut HashMap<u64, mpsc::Sender<InferenceResponse>>,
) {
    let mut replies = Vec::with_capacity(batch.requests.len());
    for r in &batch.requests {
        if let Some(tx) = waiters.remove(&r.id) {
            replies.push((r.id, tx));
        }
    }
    if executors[idx]
        .send(DeviceCmd::Execute { batch, replies })
        .is_err()
    {
        // executor gone: the replies just dropped, so every caller of
        // this batch observes an error instead of hanging
        eprintln!("executor {idx} is down; dropping a batch");
    }
}

/// One device-executor thread: owns a runtime and the compiled
/// executables of *its affine networks only* (affinity is static, so
/// loading the rest would waste startup time and memory pool-wide —
/// unless intra-batch sharding is on, which routes any network to any
/// executor and therefore loads everything everywhere); also carries
/// the FPGA/GPU edge models for annotations.  Records metrics and
/// resolves waiters itself so the leader never blocks on execution.
fn device_thread(
    config: CoordinatorConfig,
    exec_index: usize,
    n_exec: usize,
    rx: mpsc::Receiver<DeviceCmd>,
    ready: mpsc::Sender<Result<()>>,
    metrics: Arc<Mutex<MetricsRegistry>>,
) {
    let setup = (|| -> Result<(Runtime, WorkerPool, HashMap<String, NetState>)> {
        let artifacts = ArtifactDir::open(&config.artifacts_dir)?;
        // split the host's compute budget across the pool so executors
        // running concurrently don't oversubscribe the CPU (the width
        // honours the EDGEDCNN_WORKERS override)
        let host_workers = WorkerPool::with_default_parallelism().workers();
        let exec_pool = WorkerPool::new((host_workers / n_exec).max(1));
        let runtime = Runtime::cpu_with_workers(exec_pool.workers())?;
        let mut nets = HashMap::new();
        let names = logical_networks(&config);
        for (ni, name) in names.iter().enumerate() {
            // mirror of the leader's affinity map: logical network i →
            // executor i mod n_exec (sharding loads all networks on all
            // executors)
            if !config.shard_batches && ni % n_exec != exec_index {
                continue;
            }
            let base = name.strip_suffix(".q").unwrap_or(name);
            let manifest_net = artifacts.network(base)?;
            let cfg = artifacts.network_cfg(base)?;
            // sanity: manifest must agree with the built-in architecture
            let builtin = network_by_name(base)?;
            anyhow::ensure!(
                cfg.layers == builtin.layers,
                "manifest/{base} diverges from built-in config"
            );
            let weights = artifacts.load_weights(base)?;
            if name.ends_with(".q") {
                // quantized twin: calibrate+quantize at startup, and
                // annotate with the FPGA model at the fixed-point
                // datapath (narrower AXI words, packed MAC lanes)
                let fmt = config
                    .quant
                    .expect("`.q` network names require `quant: Some(..)`");
                let qgen = QuantizedGenerator::quantize(
                    fmt,
                    &weights,
                    Rounding::Nearest,
                )?;
                let opts: Vec<SimOpts> = cfg
                    .layers
                    .iter()
                    .map(|_| {
                        SimOpts::dense_at(cfg.tile, Precision::Fixed(fmt))
                    })
                    .collect();
                let sim = simulate_network(&cfg, &PYNQ_Z2, &opts);
                nets.insert(
                    name.clone(),
                    NetState {
                        buckets: Vec::new(),
                        executables: HashMap::new(),
                        weights: Vec::new(),
                        quant: Some(qgen),
                        fpga_time_s: sim.total_time_s,
                        fpga_energy_j: sim.total_time_s * sim.mean_power_w,
                        cfg,
                    },
                );
                continue;
            }
            let mut executables = HashMap::new();
            for &bs in &manifest_net.batch_sizes {
                executables
                    .insert(bs, runtime.load_generator(&artifacts, base, bs)?);
            }
            // edge annotations honour the manifest's declared datapath
            // precision (f32 when absent)
            let opts: Vec<SimOpts> = cfg
                .layers
                .iter()
                .map(|_| SimOpts::dense_at(cfg.tile, cfg.precision))
                .collect();
            let sim = simulate_network(&cfg, &PYNQ_Z2, &opts);
            nets.insert(
                name.clone(),
                NetState {
                    buckets: manifest_net.batch_sizes.clone(),
                    executables,
                    weights,
                    quant: None,
                    fpga_time_s: sim.total_time_s,
                    fpga_energy_j: sim.total_time_s * sim.mean_power_w,
                    cfg,
                },
            );
        }
        Ok((runtime, exec_pool, nets))
    })();

    let (_runtime, exec_pool, mut nets) = match setup {
        Ok(v) => {
            let _ = ready.send(Ok(()));
            v
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };

    let mut gpu_throttle = ThermalThrottle::new(JETSON_TX1);
    while let Ok(cmd) = rx.recv() {
        match cmd {
            DeviceCmd::Shutdown => break,
            DeviceCmd::Execute { batch, replies } => {
                match execute_batch(&mut nets, &mut gpu_throttle, &exec_pool, batch) {
                    Ok(done) => {
                        let mut reply_by_id: HashMap<
                            u64,
                            mpsc::Sender<InferenceResponse>,
                        > = replies.into_iter().collect();
                        let mut m = metrics.lock().unwrap();
                        m.record_batch(
                            done.execute_s,
                            done.responses
                                .iter()
                                .map(|r| r.images.shape()[0])
                                .sum(),
                            done.ops,
                        );
                        m.record_energy(done.energy_j);
                        for resp in done.responses {
                            m.record_request(
                                resp.latency_s,
                                resp.images.shape()[0],
                            );
                            if let Some(tx) = reply_by_id.remove(&resp.id) {
                                let _ = tx.send(resp);
                            }
                        }
                    }
                    Err(e) => {
                        eprintln!("device execution failed: {e:#}");
                        // dropping `replies` errors the callers
                    }
                }
            }
        }
    }
}

fn execute_batch(
    nets: &mut HashMap<String, NetState>,
    gpu_throttle: &mut ThermalThrottle,
    exec_pool: &WorkerPool,
    batch: Batch,
) -> Result<ExecutedBatch> {
    let state = nets.get_mut(&batch.network).ok_or_else(|| {
        anyhow::anyhow!("network {:?} not loaded", batch.network)
    })?;

    // deterministic latents: one RNG per request, in order
    let mut latents: Vec<f32> =
        Vec::with_capacity(batch.n_images * state.cfg.z_dim);
    for req in &batch.requests {
        let mut rng = Rng::seed_from_u64(req.seed);
        for _ in 0..req.n_images * state.cfg.z_dim {
            latents.push(rng.normal_f32());
        }
    }

    let mut execute_s = 0.0;
    let all_rows: Vec<f32> = if let Some(qgen) = &state.quant {
        // quantized twin: one fixed-point forward for the whole batch
        // (no bucketing — the reverse-loop substrate takes any N)
        let zt = Tensor::new(vec![batch.n_images, state.cfg.z_dim], latents)?;
        let t0 = Instant::now();
        let (images, _stats) = qgen.generate(&state.cfg, &zt, exec_pool);
        execute_s += t0.elapsed().as_secs_f64();
        images.into_data()
    } else {
        // bucket execution: smallest exported bucket ≥ remaining, else
        // the largest repeatedly (vLLM-style bucketed continuous
        // batching)
        let largest = *state.buckets.iter().max().unwrap();
        let mut remaining = batch.n_images;
        let mut offset = 0usize;
        let mut rows: Vec<f32> = Vec::with_capacity(
            batch.n_images
                * state.cfg.image_channels
                * state.cfg.image_size
                * state.cfg.image_size,
        );
        while remaining > 0 {
            let bucket = state
                .buckets
                .iter()
                .copied()
                .filter(|b| *b >= remaining)
                .min()
                .unwrap_or(largest);
            let take = bucket.min(remaining);
            let exe = state.executables.get(&bucket).unwrap();
            // pad the bucket with zero latents when partially filled
            let mut z = vec![0.0f32; bucket * state.cfg.z_dim];
            z[..take * state.cfg.z_dim].copy_from_slice(
                &latents[offset * state.cfg.z_dim
                    ..(offset + take) * state.cfg.z_dim],
            );
            let zt = Tensor::new(vec![bucket, state.cfg.z_dim], z)?;
            let t0 = Instant::now();
            let out = exe.generate(&zt, &state.weights)?;
            execute_s += t0.elapsed().as_secs_f64();
            let numel = exe.image_numel();
            rows.extend_from_slice(&out.data()[..take * numel]);
            remaining -= take;
            offset += take;
        }
        rows
    };

    // edge-device annotations for the whole batch
    let fpga_time = state.fpga_time_s * batch.n_images as f64;
    let gpu_time = expected_gpu_network_time(
        &state.cfg,
        &JETSON_TX1,
        gpu_throttle,
        batch.n_images,
    );
    let energy = state.fpga_energy_j * batch.n_images as f64;
    let ops = state.cfg.total_ops() * batch.n_images as u64;

    // split images back to requests
    let numel = state.cfg.image_channels
        * state.cfg.image_size
        * state.cfg.image_size;
    let mut responses = Vec::with_capacity(batch.requests.len());
    let mut row = 0usize;
    for req in &batch.requests {
        let n = req.n_images;
        let data = all_rows[row * numel..(row + n) * numel].to_vec();
        row += n;
        responses.push(InferenceResponse {
            id: req.id,
            images: Tensor::new(
                vec![
                    n,
                    state.cfg.image_channels,
                    state.cfg.image_size,
                    state.cfg.image_size,
                ],
                data,
            )?,
            latency_s: req.enqueued_at.elapsed().as_secs_f64(),
            execute_s,
            batch_size: batch.n_images,
            fpga_time_s: fpga_time * n as f64 / batch.n_images as f64,
            gpu_time_s: gpu_time * n as f64 / batch.n_images as f64,
        });
    }
    Ok(ExecutedBatch {
        responses,
        execute_s,
        ops,
        energy_j: energy,
    })
}
